"""Pallas TPU kernel: two-tier feature gather (the GIDS aggregation hot-spot).

The paper's feature-aggregation kernel lets each GPU thread fetch one feature
vector from the BaM software cache or (on miss) from an NVMe request buffer.
TPU adaptation: there are no per-thread random accesses; instead the gather
over the HBM-resident cache + host-staged miss buffer is expressed as a
scalar-prefetch gather — request slot ids are known before the block runs, so
the BlockSpec `index_map` *itself* selects which cache row to DMA into VMEM.
The paper's thread-per-request access pattern becomes TPU-native
double-buffered row DMA (HBM->VMEM) with the slot table prefetched to SMEM.

Inputs
  slots:   (B,)  int32; >= 0 -> row in `cache`; -1 -> row i of `staged`
  cache:   (L, D) feature cache rows resident in HBM
  staged:  (B, D) host-staged rows (miss path; row i used iff slots[i] < 0)
Output
  out:     (B, D)

Grid: (B, D // bd) — one request row per grid step, feature dim blocked so a
row block always fits VMEM (bd aligned to the 128-lane VPU width).  Both
candidate rows are DMA'd and selected in-register: the select is free next to
the DMA and keeps the pipeline branch-free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(slots_pf, cache_blk, staged_blk, out_ref):
    i = pl.program_id(0)
    use_cache = slots_pf[i] >= 0
    out_ref[...] = jnp.where(use_cache, cache_blk[...], staged_blk[...])


def tiered_gather(slots: jax.Array, cache: jax.Array, staged: jax.Array,
                  *, block_d: int = 512, interpret: bool = False
                  ) -> jax.Array:
    B, = slots.shape
    _, D = cache.shape
    assert staged.shape == (B, D), (staged.shape, B, D)
    bd = min(block_d, D)
    assert D % bd == 0, (D, bd)

    def cache_index(i, j, slots_pf):
        return (jnp.maximum(slots_pf[i], 0), j)  # clamp: -1 rows unused

    def staged_index(i, j, slots_pf):
        del slots_pf
        return (i, j)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, D // bd),
        in_specs=[
            pl.BlockSpec((1, bd), cache_index),
            pl.BlockSpec((1, bd), staged_index),
        ],
        out_specs=pl.BlockSpec((1, bd), staged_index),
    )
    fn = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), staged.dtype),
        interpret=interpret,
        name="tiered_gather",
    )
    return fn(slots, cache, staged)


tiered_gather_cpu = functools.partial(tiered_gather, interpret=True)
