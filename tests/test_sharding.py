"""Sharded storage data plane: placement policies, shard-carrying gather
plans, shard-local 4 KB-line coalescing, per-shard burst pricing (straggler +
imbalance telemetry, heterogeneous specs), bit-identity of the n_shards=1
plane vs gids, and checkpoint round-trip of shard assignment state."""
import numpy as np
import pytest

from repro.core import (DataPlaneSpec, GIDSDataLoader, INTEL_OPTANE,
                        LoaderConfig, SAMSUNG_980PRO, ShardedStorageTier,
                        StorageTimeline, coalesce_lines,
                        coalesce_lines_by_shard, make_placement,
                        placement_names, price_sharded_burst)
from repro.core.sharding import (DegreePlacement, HashPlacement,
                                 RangePlacement, SkewedPlacement)
from repro.core.storage_sim import IO_BYTES
from repro.core.tiers import StorageTier, build_plan
from repro.graph.synthetic import rmat_graph


@pytest.fixture(scope="module")
def graph_and_feats():
    g = rmat_graph(10_000, 12, 16, seed=1)
    feats = np.random.default_rng(0).standard_normal(
        (g.num_nodes, 16)).astype(np.float32)
    return g, feats


def _mk(g, feats, plane, seed=7, **kw):
    cfg = dict(batch_size=128, fanouts=(4, 4), cache_lines=2048,
               window_depth=4, seed=seed)
    cfg.update(kw)
    return GIDSDataLoader(g, feats, LoaderConfig(data_plane=plane, **cfg))


# -- placement policies --------------------------------------------------------

def test_placement_registry():
    for name in ("hash", "range", "degree", "skewed"):
        assert name in placement_names()
    with pytest.raises(KeyError, match="unknown placement"):
        make_placement("no-such-policy", 4)


@pytest.mark.parametrize("name", ["hash", "range", "degree", "skewed"])
def test_placements_total_and_deterministic(name):
    rng = np.random.default_rng(3)
    degrees = rng.integers(0, 50, 5000)
    pol = make_placement(name, 4, num_nodes=5000, degrees=degrees)
    ids = np.arange(5000)
    s1, s2 = pol.shard_of(ids), pol.shard_of(ids)
    np.testing.assert_array_equal(s1, s2)          # deterministic
    assert ((s1 >= 0) & (s1 < 4)).all()            # total over the namespace
    if name != "skewed":                           # balanced-ish policies
        counts = np.bincount(s1, minlength=4)
        assert counts.max() < 2 * counts.min()


def test_single_shard_is_all_zero():
    for name in ("hash", "range", "degree", "skewed"):
        pol = make_placement(name, 1, num_nodes=100,
                             degrees=np.ones(100, np.int64))
        np.testing.assert_array_equal(pol.shard_of(np.arange(100)), 0)


def test_range_placement_contiguous():
    pol = RangePlacement(4, num_nodes=100)
    shard = pol.shard_of(np.arange(100))
    # contiguous blocks, non-decreasing over the id space
    assert (np.diff(shard) >= 0).all()
    assert set(shard.tolist()) == {0, 1, 2, 3}


def test_degree_placement_stripes_hot_nodes():
    """The top-n_shards hottest nodes must land on n_shards DIFFERENT
    shards — the policy's whole point is that the power-law head never
    hammers one queue."""
    rng = np.random.default_rng(0)
    degrees = rng.zipf(1.5, 4096).astype(np.int64)
    pol = DegreePlacement(4, degrees)
    hot = np.argsort(-degrees, kind="stable")[:4]
    assert set(pol.shard_of(hot).tolist()) == {0, 1, 2, 3}
    # and each round of 4 in degree order is a full stripe
    order = np.argsort(-degrees, kind="stable")
    shards = pol.shard_of(order)
    assert (shards.reshape(-1, 4) == np.arange(4)).all() \
        or sorted(shards[:4].tolist()) == [0, 1, 2, 3]


def test_skewed_placement_overloads_shard_zero():
    pol = SkewedPlacement(4)
    counts = np.bincount(pol.shard_of(np.arange(40_000)), minlength=4)
    assert counts[0] > 2 * counts[1:].max()        # deliberately imbalanced


def test_hash_placement_invalid_shards():
    with pytest.raises(ValueError, match="n_shards"):
        HashPlacement(0)
    with pytest.raises(ValueError, match="num_nodes"):
        RangePlacement(2, num_nodes=None)
    with pytest.raises(ValueError, match="degrees"):
        DegreePlacement(2, None)


def test_range_placement_rejects_resized_namespace():
    """Restoring range boundaries against a different-size feature array
    would silently shift every shard boundary — fail loudly instead."""
    pol = RangePlacement(4, num_nodes=1000)
    pol.load_state_dict(pol.state_dict())           # round-trips
    bigger = RangePlacement(4, num_nodes=2000)
    with pytest.raises(ValueError, match="boundaries would shift"):
        bigger.load_state_dict(pol.state_dict())


def test_sharded_plane_rejects_legacy_n_ssd(graph_and_feats):
    """n_ssd is the legacy pooled-queue multiplier; a sharded plane models
    the same devices as per-shard queues — combining both double-counts."""
    g, feats = graph_and_feats
    with pytest.raises(ValueError, match="n_ssd"):
        _mk(g, feats, "gids-sharded", n_shards=4, n_ssd=4)
    # n_shards=1 keeps the legacy multiplier working
    _mk(g, feats, "gids-sharded", n_shards=1, n_ssd=4).next_batch()


# -- shard-local line coalescing (satellite regression) ------------------------

def test_coalesce_lines_shard_boundary_regression():
    """Two rows on the SAME 4 KB line but DIFFERENT shards are two IOs —
    one per device queue.  Before shard-local keys this silently merged
    rows living on different devices."""
    ids = np.array([0, 1])                          # 1 KB rows: same line
    assert coalesce_lines(ids, 1024) == 1
    assert coalesce_lines(ids, 1024, shard=np.array([0, 1])) == 2
    assert coalesce_lines(ids, 1024, shard=np.array([1, 1])) == 1


def test_coalesce_lines_sharded_matches_per_shard_sum():
    rng = np.random.default_rng(2)
    ids = np.unique(rng.integers(0, 4000, 600))
    shard = (ids % 4).astype(np.int16)
    total = coalesce_lines(ids, 1024, shard=shard)
    per = coalesce_lines_by_shard(ids, shard, 4, 1024)
    assert per.sum() == total
    assert total >= coalesce_lines(ids, 1024)       # never fewer IOs
    # wide rows never coalesce, sharded or not
    assert coalesce_lines(ids, IO_BYTES, shard=shard) == len(ids)
    # the vectorized pass agrees with the per-shard oracle everywhere
    for bpr in (256, 1024, 3000, IO_BYTES, 2 * IO_BYTES):
        expect = np.array([coalesce_lines(ids[shard == s], bpr)
                           for s in range(4)])
        np.testing.assert_array_equal(
            coalesce_lines_by_shard(ids, shard, 4, bpr), expect)
    np.testing.assert_array_equal(
        coalesce_lines_by_shard(np.array([], np.int64),
                                np.array([], np.int16), 4, 1024),
        np.zeros(4, np.int64))


# -- ShardedStorageTier --------------------------------------------------------

def test_sharded_tier_is_backstop_with_shard_of():
    feats = np.zeros((256, 4), np.float32)
    tier = ShardedStorageTier(feats, make_placement("hash", 4))
    assert tier.latency_class == "storage"
    assert tier.probe(np.arange(32)).all()
    assert tier.n_shards == 4
    s = tier.shard_of(np.arange(32))
    assert s.dtype == np.int16 and ((s >= 0) & (s < 4)).all()


def test_sharded_tier_heterogeneous_specs():
    feats = np.zeros((64, 4), np.float32)
    specs = (SAMSUNG_980PRO, INTEL_OPTANE, INTEL_OPTANE, INTEL_OPTANE)
    tier = ShardedStorageTier(feats, make_placement("hash", 4), specs=specs)
    assert tier.resolve_shard_specs(INTEL_OPTANE) == specs
    # a single spec replicates; None inherits the loader's device
    t2 = ShardedStorageTier(feats, make_placement("hash", 2),
                            specs=SAMSUNG_980PRO)
    assert t2.resolve_shard_specs(INTEL_OPTANE) == (SAMSUNG_980PRO,) * 2
    t3 = ShardedStorageTier(feats, make_placement("hash", 2))
    assert t3.resolve_shard_specs(INTEL_OPTANE) == (INTEL_OPTANE,) * 2
    with pytest.raises(ValueError, match="shard specs"):
        ShardedStorageTier(feats, make_placement("hash", 4),
                           specs=[SAMSUNG_980PRO] * 3)


# -- shard ids through the gather plan -----------------------------------------

def test_build_plan_carries_shard_ids():
    feats = np.zeros((512, 4), np.float32)
    tier = ShardedStorageTier(feats, make_placement("hash", 4))
    ids = np.arange(100)
    plan = build_plan([tier], ids)
    assert plan.is_partition() and plan.shard_consistent()
    assert plan.n_shards == 4
    np.testing.assert_array_equal(plan.shard, tier.shard_of(ids))
    np.testing.assert_array_equal(plan.shard_counts(),
                                  np.bincount(plan.shard, minlength=4))


def test_build_plan_unsharded_storage_is_shard_zero():
    feats = np.zeros((512, 4), np.float32)
    plan = build_plan([StorageTier(feats)], np.arange(50))
    assert plan.n_shards == 1
    np.testing.assert_array_equal(plan.shard, 0)
    assert plan.shard_consistent()


# -- per-shard burst pricing ---------------------------------------------------

def test_price_sharded_burst_max_over_shards():
    specs = (INTEL_OPTANE,) * 4
    res = price_sharded_burst(specs, (100, 200, 50, 0), (25, 50, 13, 0),
                              1024)
    assert res.n_shards == 4
    assert res.elapsed_s == max(res.per_shard_s)
    assert res.straggler == 1                      # the 200-row queue
    assert res.per_shard_s[3] == 0.0               # empty queue costs nothing
    assert res.imbalance > 1.0


def test_price_sharded_burst_balanced_beats_one_queue():
    """The multi-SSD story: 4 balanced shards drain strictly faster than
    the same rows through one queue."""
    tl = StorageTimeline(SAMSUNG_980PRO)
    one = price_sharded_burst((SAMSUNG_980PRO,), (4000,), (1000,), 256)
    four = price_sharded_burst((SAMSUNG_980PRO,) * 4, (1000,) * 4,
                               (250,) * 4, 256)
    assert four.elapsed_s < one.elapsed_s
    assert four.imbalance == pytest.approx(1.0)
    del tl


def test_price_sharded_burst_heterogeneous_straggler():
    """One 980Pro among Optanes: the slow device's queue sets the critical
    path and is named in the telemetry."""
    specs = (SAMSUNG_980PRO, INTEL_OPTANE, INTEL_OPTANE, INTEL_OPTANE)
    res = price_sharded_burst(specs, (100,) * 4, (25,) * 4, 1024)
    assert res.straggler == 0
    assert res.straggler_spec == "samsung-980pro"
    assert res.imbalance > 1.5
    with pytest.raises(ValueError, match="arity"):
        price_sharded_burst(specs, (1, 2), (1, 2), 64)


def test_loader_surfaces_straggler_telemetry(graph_and_feats):
    g, feats = graph_and_feats
    dl = _mk(g, feats, "gids-merged-sharded", n_shards=4)
    for _ in range(6):
        b = dl.next_batch()
    burst = dl.timeline.shard_burst
    assert burst is not None and burst.n_shards == 4
    assert 0 <= burst.straggler < 4
    assert burst.imbalance >= 1.0
    assert b.report.shard_rows and len(b.report.shard_rows) == 4
    assert sum(b.report.shard_lines) == b.report.n_storage_lines


# -- bit-identity of the sharded plane -----------------------------------------

def test_one_shard_plane_bit_identical_to_gids(graph_and_feats):
    """Acceptance: n_shards=1 sharded plane == gids in features, blocks,
    per-tier counts — and (n_ssd=1) even in modelled prep."""
    g, feats = graph_and_feats
    a, b = _mk(g, feats, "gids"), _mk(g, feats, "gids-sharded", n_shards=1)
    for _ in range(8):
        ba, bb = a.next_batch(), b.next_batch()
        np.testing.assert_array_equal(ba.blocks.seeds, bb.blocks.seeds)
        np.testing.assert_array_equal(ba.blocks.all_nodes,
                                      bb.blocks.all_nodes)
        np.testing.assert_array_equal(ba.features, bb.features)
        assert ba.report.tier_counts == bb.report.tier_counts
        assert ba.prep_time_s == bb.prep_time_s


def test_sharded_merged_features_match_unsharded(graph_and_feats):
    """Sharding changes pricing and telemetry, never bytes: the 4-shard
    merged plane returns bit-identical features to gids-merged."""
    g, feats = graph_and_feats
    a = _mk(g, feats, "gids-merged")
    b = _mk(g, feats, "gids-merged-sharded", n_shards=4)
    for _ in range(10):
        ba, bb = a.next_batch(), b.next_batch()
        np.testing.assert_array_equal(ba.features, bb.features)
        assert ba.report.tier_counts == bb.report.tier_counts
        assert ba.report.n_storage_unique == bb.report.n_storage_unique
        # shard-local coalescing can only split lines, never merge more
        assert bb.report.n_storage_lines >= ba.report.n_storage_lines


def test_sharded_prep_drops_with_shard_count(graph_and_feats):
    """The point of the PR: per-shard queues drain concurrently, so
    modelled prep is monotonically non-increasing in shard count."""
    g, feats = graph_and_feats
    means = {}
    for n in (1, 2, 4):
        dl = _mk(g, feats, "gids-merged-sharded", n_shards=n)
        ps = [dl.next_batch().prep_time_s for _ in range(16)]
        means[n] = float(np.mean(ps[6:]))
    assert means[2] <= means[1]
    assert means[4] <= means[2]
    assert means[4] < means[1]                     # strict across the sweep


# -- hypothesis property: every preset's plan partitions + shard rule ----------

def _storage_backed_presets():
    out = []
    for name in DataPlaneSpec.names():
        spec = DataPlaneSpec.preset(name)
        if spec.tiers and spec.tiers[-1].kind in ("storage",
                                                  "sharded_storage"):
            out.append(name)
    return out


def test_plan_partition_property_over_presets(graph_and_feats):
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    g, feats = graph_and_feats
    presets = [p for p in _storage_backed_presets() if p != "gids-device"]
    assert {"gids", "gids-sharded", "gids-merged-sharded"} <= set(presets)

    @settings(max_examples=12, deadline=None)
    @given(
        preset=st.sampled_from(presets),
        n_shards=st.sampled_from([1, 2, 4]),
        placement=st.sampled_from(["hash", "range", "degree", "skewed"]),
        seed=st.integers(0, 3),
    )
    def check(preset, n_shards, placement, seed):
        dl = _mk(g, feats, preset, seed=seed, batch_size=32,
                 n_shards=n_shards, placement=placement)
        for _ in range(3):
            dl.next_batch()
            plan = dl.store.last_plan
            # every request claimed by exactly one tier...
            assert plan.is_partition()
            masks = [plan.mask(i) for i in range(len(plan.tiers))]
            assert (np.sum(masks, axis=0) == 1).all()
            # ...and shard ids defined iff the serving tier is storage-class
            assert plan.shard_consistent()
            sm = plan.storage_mask()
            assert (plan.shard[sm] >= 0).all()
            assert (plan.shard[~sm] == -1).all()
        # checkpoint save/restore round-trips shard assignment state
        state = dl.state_dict()
        fresh = _mk(g, feats, preset, seed=seed, batch_size=32,
                    n_shards=n_shards, placement=placement)
        fresh.load_state_dict(state)
        probe = np.arange(0, g.num_nodes, 97)
        old_tier, new_tier = dl.store.tiers[-1], fresh.store.tiers[-1]
        if hasattr(old_tier, "shard_of"):
            np.testing.assert_array_equal(old_tier.shard_of(probe),
                                          new_tier.shard_of(probe))
        b_old, b_new = dl.next_batch(), fresh.next_batch()
        np.testing.assert_array_equal(b_old.blocks.seeds, b_new.blocks.seeds)
        np.testing.assert_array_equal(b_old.features, b_new.features)

    check()


# -- checkpoint round-trip of shard assignment ---------------------------------

def test_sharded_checkpoint_roundtrips_assignment(graph_and_feats):
    g, feats = graph_and_feats
    dl = _mk(g, feats, "gids-sharded", n_shards=4, placement="degree")
    for _ in range(3):
        dl.next_batch()
    state = dl.state_dict()
    assert "tier_state" in state
    tier_state = state["tier_state"]["sharded-storage"]
    assert tier_state["n_shards"] == 4
    assert tier_state["placement"]["name"] == "degree"

    # resumed loaders agree with each other bit-for-bit (resume resets tier
    # contents, so the comparison is resumed-vs-resumed)
    r1 = _mk(g, feats, "gids-sharded", n_shards=4, placement="degree")
    r2 = _mk(g, feats, "gids-sharded", n_shards=4, placement="degree")
    r1.load_state_dict(state)
    r2.load_state_dict(state)
    probe = np.arange(0, g.num_nodes, 37)
    np.testing.assert_array_equal(
        r1.store.tiers[-1].shard_of(probe),
        dl.store.tiers[-1].shard_of(probe))
    for _ in range(4):
        b1, b2 = r1.next_batch(), r2.next_batch()
        np.testing.assert_array_equal(b1.features, b2.features)
        assert b1.report == b2.report
        assert b1.prep_time_s == b2.prep_time_s

    # a mutated assignment (what an online rebalancer would do) round-trips
    dtier = dl.store.tiers[-1]
    dtier.placement.table[:100] = 2
    st2 = dl.state_dict()
    r3 = _mk(g, feats, "gids-sharded", n_shards=4, placement="degree")
    r3.load_state_dict(st2)
    np.testing.assert_array_equal(
        r3.store.tiers[-1].shard_of(np.arange(100)), 2)

    # shard-count mismatch fails loudly, not silently
    r4 = _mk(g, feats, "gids-sharded", n_shards=2, placement="degree")
    with pytest.raises(ValueError, match="shards"):
        r4.load_state_dict(state)
