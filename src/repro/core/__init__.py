# The paper's primary contribution: the GIDS dataloader — storage-direct
# feature aggregation with dynamic access accumulation (§3.2), constant
# host buffer (§3.3), and window-buffered device software cache (§3.4),
# composed as a pluggable tier stack (tiers.py) declared by a
# DataPlaneSpec (dataplane.py).
from .accumulator import (AccumulatorConfig, DeadlineWindowConfig,
                          DeadlineWindowPolicy, DynamicAccessAccumulator,
                          MergedWindow, merge_window)
from .constant_buffer import ConstantBuffer
from .dataplane import (BuildContext, DataPlane, DataPlaneSpec, TierSpec,
                        register_tier_kind, tier)
from .faults import (BrownoutEvent, FailoverRouter, FaultInjector,
                     FaultSchedule, FaultedBurstResult, FlakyReadsEvent,
                     HedgePolicy, OutageEvent, RetryPolicy)
from .feature_store import (CoalescedReport, FeatureStore, GatherReport,
                            TieredFeatureStore)
from .feedback import (AmortizedCost, MigrationEvent, QuotaController,
                       RefreshEvent, ShardHealthMonitor, ShardRebalancer,
                       TopologyRefresher, TouchTable)
from .hosts import (NIC_100GBE, NIC_400GBE, TPU_ICI, CoPartitionedPlacement,
                    HostLinkSpec, HostShardTier, cut_edge_fraction,
                    default_hosts, requester_hosts)
from .pipeline import Batch, BatchPlan, GIDSDataLoader, LoaderConfig
from .prefetch import PrefetchEngine, PrefetchStats
from .sharding import (AdaptivePlacement, MetisLitePlacement,
                       PlacementPolicy, ReplicatedPlacement, make_placement,
                       placement_names, register_placement)
from .software_cache import CacheStats, WindowBufferedCache, run_trace
from .storage_sim import (INTEL_OPTANE, SAMSUNG_980PRO, HostBurstResult,
                          SSDSpec, ShardedBurstResult, StorageTimeline,
                          coalesce_lines, coalesce_lines_by_shard,
                          model_burst, price_sharded_burst,
                          required_accesses, simulate_burst)
from .tiers import (ConstantBufferTier, DeviceCacheTier, GatherPlan,
                    KVSlotTier, ShardedStorageTier, StorageTier,
                    TenantCacheTier, Tier, build_plan)
from .topology import (TieredTopologyStore, TopologyGatherReport,
                       admission_names, host_sampling_time, make_admission,
                       register_admission)

__all__ = [
    "AccumulatorConfig", "DeadlineWindowConfig", "DeadlineWindowPolicy",
    "DynamicAccessAccumulator", "MergedWindow",
    "merge_window", "ConstantBuffer",
    "BuildContext", "DataPlane", "DataPlaneSpec", "TierSpec",
    "register_tier_kind", "tier",
    "BrownoutEvent", "FailoverRouter", "FaultInjector", "FaultSchedule",
    "FaultedBurstResult", "FlakyReadsEvent", "HedgePolicy", "OutageEvent",
    "RetryPolicy",
    "CoalescedReport", "FeatureStore", "GatherReport", "TieredFeatureStore",
    "AmortizedCost", "MigrationEvent", "QuotaController", "RefreshEvent",
    "ShardHealthMonitor", "ShardRebalancer", "TopologyRefresher",
    "TouchTable",
    "NIC_100GBE", "NIC_400GBE", "TPU_ICI", "CoPartitionedPlacement",
    "HostLinkSpec", "HostShardTier", "cut_edge_fraction", "default_hosts",
    "requester_hosts",
    "Batch", "BatchPlan", "GIDSDataLoader", "LoaderConfig",
    "PrefetchEngine", "PrefetchStats",
    "AdaptivePlacement", "MetisLitePlacement", "PlacementPolicy",
    "ReplicatedPlacement",
    "make_placement", "placement_names", "register_placement",
    "CacheStats", "WindowBufferedCache", "run_trace", "INTEL_OPTANE",
    "SAMSUNG_980PRO", "HostBurstResult", "SSDSpec", "ShardedBurstResult",
    "StorageTimeline",
    "coalesce_lines", "coalesce_lines_by_shard", "model_burst",
    "price_sharded_burst", "required_accesses", "simulate_burst",
    "ConstantBufferTier", "DeviceCacheTier", "GatherPlan", "KVSlotTier",
    "ShardedStorageTier", "StorageTier", "TenantCacheTier", "Tier",
    "build_plan",
    "TieredTopologyStore", "TopologyGatherReport", "admission_names",
    "host_sampling_time", "make_admission", "register_admission",
]
