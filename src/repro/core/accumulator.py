"""Dynamic storage access accumulator (paper §3.2).

The accumulator exploits the logical independence of (sampling, aggregation)
from the training stage: it runs sampling *ahead* of training and merges the
storage requests of consecutive mini-batch data preparations until the number
of outstanding storage accesses crosses the analytic threshold (Eq. 2-3)
needed to hit the target fraction of peak SSD throughput.

Redirected accesses (GPU-cache hits, constant-buffer hits) do not occupy SSD
queue slots, so the controller tracks the measured redirection rate and
re-inflates the merge depth accordingly — this is the "dynamic" part.

TPU adaptation: "outstanding storage accesses" become outstanding prefetch
requests in the host->device staging pipeline; the same Little's-law model
applies with the staging link's latency/throughput constants, and the merge
depth doubles as the dispatch-ahead depth of the async pipeline.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .storage_sim import SSDSpec, required_accesses


@dataclasses.dataclass
class AccumulatorConfig:
    target_efficiency: float = 0.95
    n_ssd: int = 1
    max_merge_iters: int = 16       # buffer-memory guard (paper: "excessive
                                    # buffer memory usage" bound)
    ema: float = 0.9                # smoothing for the redirection estimate


@dataclasses.dataclass
class MergedWindow:
    """The §3.2 merge made concrete: the union of `n_batches` consecutive
    mini-batch request lists, deduplicated so each unique row is fetched
    from storage exactly once.

    unique_nodes: (U,) sorted unique node ids across the window
    inverse:      (sum_i B_i,) index into `unique_nodes`; batch i's slice
                  reconstructs its request list in original order
                  (`unique_nodes[inverse[offsets[i]:offsets[i+1]]]`) and is
                  the scatter index that expands unique feature rows back to
                  per-batch feature arrays
    offsets:      (n_batches + 1,) slice boundaries into `inverse`
    """

    unique_nodes: np.ndarray
    inverse: np.ndarray
    offsets: np.ndarray

    @property
    def n_batches(self) -> int:
        return len(self.offsets) - 1

    @property
    def n_requests(self) -> int:
        return int(self.offsets[-1])

    @property
    def n_unique(self) -> int:
        return len(self.unique_nodes)

    @property
    def n_duplicate(self) -> int:
        """Rows the per-batch path would have fetched again."""
        return self.n_requests - self.n_unique

    @property
    def dedup_factor(self) -> float:
        return self.n_requests / max(self.n_unique, 1)

    def batch_inverse(self, i: int) -> np.ndarray:
        return self.inverse[self.offsets[i]:self.offsets[i + 1]]

    def batch_multiplicity(self) -> np.ndarray:
        """Per-unique-node count of merged batches requesting it (each
        batch's request list is already deduplicated, so occurrences in the
        inverse == batches).  Windowed tiers consume this many reuse
        reservations in one merged access."""
        return np.bincount(self.inverse, minlength=self.n_unique)


def merge_window(node_lists) -> MergedWindow:
    """Merge consecutive batches' request lists into one deduplicated burst:
    `np.unique(..., return_inverse=True)` over the concatenation gives the
    unique set (gathered once) and the inverse index (scatters rows back to
    each batch).  This is the accumulator's merge *executed*, not just its
    depth computed."""
    lists = [np.asarray(x) for x in node_lists]
    if not lists:
        raise ValueError("merge_window needs at least one batch")
    offsets = np.zeros(len(lists) + 1, np.int64)
    np.cumsum([len(x) for x in lists], out=offsets[1:])
    unique, inverse = np.unique(np.concatenate(lists), return_inverse=True)
    return MergedWindow(unique_nodes=unique,
                        inverse=inverse.astype(np.int64),
                        offsets=offsets)


@dataclasses.dataclass
class DeadlineWindowConfig:
    max_window: int = 16            # depth cap — same buffer-memory guard as
                                    # AccumulatorConfig.max_merge_iters
    ema: float = 0.7                # smoothing for the service estimate
    init_request_s: float = 2e-4    # cold-start per-request service guess
    safety: float = 1.5             # close early by this factor over the
                                    # estimate (estimate error eats slack,
                                    # not the SLO)


class DeadlineWindowPolicy:
    """Deadline-bounded twin of `merge_depth` for ONLINE serving windows.

    Training merges a fixed lookahead depth because epochs have no deadlines;
    a serving window instead keeps admitting compatible in-flight requests
    until the OLDEST staged request's slack is spent: service must start by

        close_by = arrival + deadline - safety * est_service(n_staged)

    for that request to have any chance of meeting its SLO.  The per-request
    service estimate is an EMA over completed windows' measured service, so
    the close bound tightens as windows deepen and the estimate converges —
    the serving analogue of the accumulator's redirection-rate EMA.
    `max_window` keeps the same buffer-memory guard the merge depth has.
    """

    def __init__(self, config: DeadlineWindowConfig | None = None):
        self.config = config or DeadlineWindowConfig()
        self._request_s = self.config.init_request_s

    @property
    def est_request_s(self) -> float:
        return self._request_s

    def observe(self, service_s: float, n_requests: int) -> None:
        """Feed one completed window's measured service time."""
        if n_requests <= 0 or service_s < 0:
            return
        a = self.config.ema
        self._request_s = a * self._request_s \
            + (1 - a) * service_s / n_requests

    def est_service_s(self, n_staged: int) -> float:
        return self._request_s * max(n_staged, 1)

    def full(self, n_staged: int) -> bool:
        return n_staged >= self.config.max_window

    def close_by(self, oldest_arrival_s: float, oldest_deadline_s: float,
                 n_staged: int) -> float:
        """Latest virtual time the window can start service and still meet
        the oldest staged request's deadline (never before its arrival)."""
        slack_close = (oldest_arrival_s + oldest_deadline_s
                       - self.config.safety * self.est_service_s(n_staged))
        return max(oldest_arrival_s, slack_close)

    def reset(self) -> None:
        self._request_s = self.config.init_request_s


class DynamicAccessAccumulator:
    """Decides how many future iterations' sampling to merge.

    update(n_sampled, n_redirected) feeds per-iteration telemetry;
    merge_depth(requests_per_iter) returns the number of iterations whose
    data preparation should be in flight simultaneously.
    """

    def __init__(self, spec: SSDSpec, config: AccumulatorConfig | None = None):
        self.spec = spec
        self.config = config or AccumulatorConfig()
        self.threshold = required_accesses(
            spec, self.config.target_efficiency, self.config.n_ssd)
        self._redirect_rate = 0.0

    # -- telemetry ----------------------------------------------------------
    def update(self, n_sampled: int, n_redirected: int) -> None:
        if n_sampled <= 0:
            return
        r = n_redirected / n_sampled
        a = self.config.ema
        self._redirect_rate = a * self._redirect_rate + (1 - a) * r

    @property
    def redirect_rate(self) -> float:
        return self._redirect_rate

    def reset_telemetry(self) -> None:
        """Drop the redirection-rate EMA back to the fresh-accumulator state.
        Checkpoint resume calls this so a restored loader and a freshly-built
        loader make bit-identical merge-depth decisions."""
        self._redirect_rate = 0.0

    # -- policy --------------------------------------------------------------
    def storage_fraction(self) -> float:
        return max(1.0 - self._redirect_rate, 1e-3)

    def merge_depth(self, requests_per_iter: int) -> int:
        """Iterations to merge so that outstanding *storage-bound* requests
        >= threshold: depth * requests * (1 - redirect_rate) >= N_access."""
        if requests_per_iter <= 0:
            return 1
        eff_per_iter = requests_per_iter * self.storage_fraction()
        depth = int(-(-self.threshold // max(eff_per_iter, 1.0)))  # ceil
        return max(1, min(depth, self.config.max_merge_iters))

    def outstanding(self, requests_per_iter: int) -> int:
        d = self.merge_depth(requests_per_iter)
        return int(d * requests_per_iter * self.storage_fraction())

    # -- merge execution ------------------------------------------------------
    def merge(self, node_lists) -> MergedWindow:
        """Execute the merge the depth policy only *sizes*: union the staged
        batches' request lists into one deduplicated window whose unique set
        is gathered once and issued as a single storage burst."""
        return merge_window(node_lists)
