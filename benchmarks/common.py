"""Shared benchmark harness."""
from __future__ import annotations

import time

import numpy as np


def timeit(fn, *args, warmup=2, iters=5, **kw):
    for _ in range(warmup):
        fn(*args, **kw)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def row(name: str, us_per_call: float, derived: str = "") -> str:
    line = f"{name},{us_per_call:.2f},{derived}"
    print(line, flush=True)
    return line


class Capture:
    """Collects benchmark rows for bench_output.txt."""

    def __init__(self):
        self.rows: list[str] = []

    def add(self, name, us, derived=""):
        self.rows.append(row(name, us, derived))
