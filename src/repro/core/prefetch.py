"""Overlapped prefetch execution engine (paper §3.2, Fig. 13).

The paper's core speedup comes from decoupling data preparation from model
compute: sampling and gather/staging for batch *k+1* run while batch *k*
trains, so storage latency stops adding serially to the iteration time.
`PrefetchEngine` is that decoupling for the two-stage loader: it drives the
loader's `plan_next()` (sampling + tier `admit()` staging through the
lookahead window) and `execute()` (tier fold, gather, pricing) for up to
`depth` future batches ahead of the consumer, then discounts each consumed
batch's prep time by the model-compute time the caller reports
(`StorageTimeline.price_batch_overlapped` — only the excess is exposed).

Determinism contract: the engine performs *exactly* the same plan/execute
calls in *exactly* the same order as a synchronous loader — earlier in wall
time, never reordered — so the `Batch` sequence (blocks, rows, reports,
raw prep times) is bit-identical to the sync plane's; only `exposed_prep_s`
differs.  `tests/test_prefetch.py` pins this, including across
`state_dict`/`load_state_dict` resume.

PyTorch-Direct (arXiv:2101.07956) applies the same overlap to pinned-host
access; here it is a property of the *plane* — any `DataPlaneSpec` with
`prefetch > 0` (e.g. the `gids-async` preset) runs through this engine.

On a merged plane (`merge_execute`, e.g. `gids-merged-async`) the engine's
staging unit is the whole merged window: `plan_window()` /
`execute_window()` dedupe and price a window of batches as one burst, and
every batch of the window enters the ready queue together, each with its
own resume snapshot.

Sharded planes (`gids-sharded`, `gids-merged-sharded`) need nothing extra
here: shard awareness rides inside the loader's execute stages — the plans
the engine stages already carry per-request shard ids through their
`GatherPlan`s, and the prep times it discounts were already priced at the
max over per-shard queue drains.

Topology planes (`gids-topo`, `gids-topo-merged`) likewise ride through
unchanged: the priced sampling stage runs inside `plan_next()` (the blocks
the engine stages already carry their per-hop `TopologyGatherReport`s and
summed `sample_time_s`), `Batch.prep_time_s` arrives with sampling folded
in, and the overlap discount therefore hides sampling time behind model
compute exactly like gather time — the paper's full prep path, decoupled.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import TYPE_CHECKING

if TYPE_CHECKING:                       # pipeline imports this module
    from .pipeline import Batch, BatchPlan, GIDSDataLoader


@dataclasses.dataclass
class PrefetchStats:
    """Engine telemetry: how much modelled prep time the overlap hid."""

    staged_batches: int = 0
    consumed_batches: int = 0
    prep_s_total: float = 0.0
    exposed_s_total: float = 0.0

    @property
    def hidden_s_total(self) -> float:
        return self.prep_s_total - self.exposed_s_total

    @property
    def hidden_fraction(self) -> float:
        if self.prep_s_total <= 0:
            return 0.0
        return self.hidden_s_total / self.prep_s_total


class PrefetchEngine:
    """Stage up to `depth` executed batches ahead of consumption.

    `next(compute_s)` returns the oldest staged batch with its
    `exposed_prep_s` re-priced against the `compute_s` seconds of model
    compute the caller overlapped it with, then tops the stage queue back
    up.  `depth` bounds staging memory (each staged batch holds its gathered
    feature rows) the same way the accumulator's `max_merge_iters` bounds
    sample-ahead memory.
    """

    def __init__(self, loader: "GIDSDataLoader", depth: int):
        self.loader = loader
        self.depth = max(1, int(depth))
        self._ready: deque[tuple[dict, "Batch"]] = deque()
        self.stats = PrefetchStats()

    def __len__(self) -> int:
        return len(self._ready)

    def _stage(self) -> None:
        while len(self._ready) < self.depth:
            if self.loader.plane.merge_execute:
                # a merged plane's executable unit is the whole window: the
                # engine stages it atomically (the queue may transiently
                # exceed `depth` by window-1 batches — the same bound the
                # accumulator's max_merge_iters already imposes on staging
                # memory), each batch keeping its own resume snapshot
                plans = self.loader.plan_window()
                batches = self.loader.execute_window(plans)
            else:
                plan: "BatchPlan" = self.loader.plan_next()
                plans, batches = [plan], [self.loader.execute(plan)]
            for p, b in zip(plans, batches):
                self._ready.append((p.snapshot, b))
                self.stats.staged_batches += 1

    def next(self, compute_s: float = 0.0) -> "Batch":
        self._stage()
        _, batch = self._ready.popleft()
        exposed = self.loader.plane.exposed_prep(
            self.loader.timeline, batch.prep_time_s, compute_s)
        batch = dataclasses.replace(batch, exposed_prep_s=exposed)
        self.stats.consumed_batches += 1
        self.stats.prep_s_total += batch.prep_time_s
        self.stats.exposed_s_total += exposed
        return batch

    # -- checkpoint/resume -----------------------------------------------------
    def oldest_snapshot(self) -> dict | None:
        """Sampler snapshot of the oldest staged-but-unconsumed batch — the
        loader resumes from the logical consumption point, so staged work is
        deterministically re-done after a restore."""
        if self._ready:
            return self._ready[0][0]
        return None

    def reset(self) -> None:
        self._ready.clear()
        self.stats = PrefetchStats()
