"""CSR graph container.

The paper keeps graph *structure* pinned in CPU memory (fine-grained 4-8B
accesses would amplify I/O if it lived on storage) while node *features* live
on storage.  We mirror that split: `CSRGraph` is a host-resident numpy
structure; features are owned by `repro.core.feature_store`.

A device-resident copy (`DeviceCSR`) is provided for on-device sampling
(the TPU analogue of DGL's UVA zero-copy sampling path).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def index_dtype(max_index: int) -> type:
    """Smallest numpy integer dtype that can index `max_index` items.
    Shared by the host and device samplers (and `to_device`) so node/edge
    id handling cannot drift between them: past 2^31 ids everything widens
    to int64 together instead of one path silently truncating."""
    return np.int64 if max_index >= 2 ** 31 else np.int32


def device_index_dtype(num_nodes: int, num_edges: int):
    """The jnp dtype device-side sampling must use for this graph's node and
    edge ids.  Graphs beyond 2^31 nodes/edges need int64, which JAX only
    provides under `jax_enable_x64` — fail loudly instead of overflowing."""
    if index_dtype(max(num_nodes, num_edges)) is np.int64:
        if not jax.config.jax_enable_x64:
            raise ValueError(
                f"graph has {num_nodes:,} nodes / {num_edges:,} edges — "
                "device sampling needs int64 ids; enable jax_enable_x64 "
                "(int32 would silently wrap past 2^31)")
        return jnp.int64
    return jnp.int32


@dataclasses.dataclass
class CSRGraph:
    """Host (numpy) CSR adjacency: out-neighbors of node v are
    ``indices[indptr[v]:indptr[v+1]]``."""

    indptr: np.ndarray   # (N+1,) int64
    indices: np.ndarray  # (E,)  int32/int64
    num_nodes: int
    feature_dim: int = 0
    name: str = "graph"

    @property
    def num_edges(self) -> int:
        return int(self.indices.shape[0])

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    def reverse(self) -> "CSRGraph":
        """Transpose (in-neighbors), used by reverse PageRank."""
        n = self.num_nodes
        counts = np.bincount(self.indices, minlength=n)
        indptr = np.zeros(n + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        indices = np.empty(self.num_edges, dtype=self.indices.dtype)
        cursor = indptr[:-1].copy()
        src = np.repeat(np.arange(n, dtype=self.indices.dtype), self.degrees())
        # stable counting-sort scatter
        order = np.argsort(self.indices, kind="stable")
        indices[:] = src[order]
        return CSRGraph(indptr=indptr, indices=indices, num_nodes=n,
                        feature_dim=self.feature_dim, name=self.name + "_rev")

    def to_device(self, pad_degree: Optional[int] = None) -> "DeviceCSR":
        # indptr values run up to num_edges, indices up to num_nodes: one
        # shared dtype decision (int64-safe, loud past 2^31 without x64)
        dt = device_index_dtype(self.num_nodes, self.num_edges)
        return DeviceCSR(
            indptr=jnp.asarray(self.indptr, dtype=dt),
            indices=jnp.asarray(self.indices, dtype=dt),
            num_nodes=self.num_nodes,
        )

    def structure_bytes(self) -> int:
        return self.indptr.nbytes + self.indices.nbytes

    def feature_bytes(self, dtype_size: int = 4) -> int:
        return self.num_nodes * self.feature_dim * dtype_size


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeviceCSR:
    """Device-resident CSR for jittable sampling."""

    indptr: jnp.ndarray
    indices: jnp.ndarray
    num_nodes: int

    def tree_flatten(self):
        return (self.indptr, self.indices), (self.num_nodes,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(indptr=children[0], indices=children[1], num_nodes=aux[0])


def disjoint_union(graphs: Sequence["CSRGraph"],
                   name: str = "union") -> CSRGraph:
    """Concatenate CSR graphs into one graph over disjoint node-id ranges:
    graph k's node v becomes `sum(n_i for i < k) + v`, with no edges between
    components.  This is the multi-tenant colocation layout — each tenant
    serves its own dataset, all tenants share one feature plane, one cache,
    and one storage device — and the node ranges let a workload pin each
    tenant's traffic to its own component.
    """
    if not graphs:
        raise ValueError("need at least one graph")
    n_total = sum(g.num_nodes for g in graphs)
    e_total = sum(g.num_edges for g in graphs)
    idt = index_dtype(max(n_total, e_total))
    indptr = np.zeros(n_total + 1, dtype=np.int64)
    indices = np.empty(e_total, dtype=idt)
    node_off, edge_off = 0, 0
    for g in graphs:
        indptr[node_off + 1:node_off + g.num_nodes + 1] = \
            edge_off + g.indptr[1:]
        indices[edge_off:edge_off + g.num_edges] = \
            g.indices.astype(idt) + node_off
        node_off += g.num_nodes
        edge_off += g.num_edges
    return CSRGraph(indptr=indptr, indices=indices, num_nodes=n_total,
                    feature_dim=max(g.feature_dim for g in graphs),
                    name=name)


def from_edge_list(src: np.ndarray, dst: np.ndarray, num_nodes: int,
                   feature_dim: int = 0, name: str = "graph",
                   dedup: bool = True) -> CSRGraph:
    """Build CSR from COO edges (src -> dst)."""
    if dedup and len(src):
        key = src.astype(np.int64) * num_nodes + dst.astype(np.int64)
        _, uniq = np.unique(key, return_index=True)
        src, dst = src[uniq], dst[uniq]
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    counts = np.bincount(src, minlength=num_nodes)
    indptr = np.zeros(num_nodes + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSRGraph(indptr=indptr, indices=dst.astype(np.int32),
                    num_nodes=num_nodes, feature_dim=feature_dim, name=name)
