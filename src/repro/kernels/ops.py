"""Public jit'd entry points for the Pallas kernels.

On a CPU host (this container / unit tests) kernels execute in interpret
mode; on TPU they lower to Mosaic.  `use_pallas=False` falls back to the
pure-jnp oracle — the dry-run path uses the oracle so the compiled HLO's
cost analysis reflects the mathematically identical dense computation (XLA
cannot cost-model custom calls), while run-time paths use the kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention as _flash
from .segment_mean import segment_mean as _segmean
from .tiered_gather import frontier_gather as _frontier_gather
from .tiered_gather import tiered_gather as _tgather
from .tiered_gather import tiered_gather_unique as _tgather_unique

_ON_TPU = jax.default_backend() == "tpu"
_INTERPRET = not _ON_TPU


@functools.partial(jax.jit,
                   static_argnames=("use_pallas", "block_b", "block_d"))
def tiered_gather(slots, cache, staged, use_pallas: bool = True,
                  block_b: int | None = None, block_d: int = 512):
    # block_b=None defers to the kernel's backend-aware default (row-blocked
    # when interpret-validated, single-row on compiled TPU)
    if not use_pallas:
        return ref.tiered_gather_ref(slots, cache, staged)
    return _tgather(slots, cache, staged, block_b=block_b, block_d=block_d,
                    interpret=_INTERPRET)


@functools.partial(jax.jit,
                   static_argnames=("use_pallas", "block_b", "block_d"))
def tiered_gather_unique(slots, cache, staged, inverse,
                         use_pallas: bool = True,
                         block_b: int | None = None, block_d: int = 512):
    """Deduped tiered gather + inverse expansion: `slots`/`staged` cover the
    merged window's unique requests, `inverse` scatters the gathered rows
    back to request order (see the merged-window executor,
    core/pipeline.py)."""
    if not use_pallas:
        return jnp.take(ref.tiered_gather_ref(slots, cache, staged),
                        inverse, axis=0)
    return _tgather_unique(slots, cache, staged, inverse, block_b=block_b,
                           block_d=block_d, interpret=_INTERPRET)


@functools.partial(jax.jit,
                   static_argnames=("use_pallas", "block_b", "block_d"))
def tiered_frontier_gather(page_slots, hot_pages, staged_pages, inverse,
                           offsets, use_pallas: bool = True,
                           block_b: int | None = None, block_d: int = 512):
    """Tiered-frontier gather for GPU-initiated sampling: each unique edge
    page a hop touched is fetched once through the tiered gather kernel
    (HBM hot pages vs staged fallback), then every sampled read extracts
    its neighbor word via (inverse, offset) — see `TieredTopologyStore.
    frontier_gather` (core/topology.py) for the host-side page dedup."""
    if not use_pallas:
        pages = ref.tiered_gather_ref(page_slots, hot_pages, staged_pages)
        return pages[inverse, offsets]
    return _frontier_gather(page_slots, hot_pages, staged_pages, inverse,
                            offsets, block_b=block_b, block_d=block_d,
                            interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def segment_mean(idx, feats, use_pallas: bool = True):
    if not use_pallas:
        return ref.segment_mean_ref(idx, feats)
    return _segmean(idx, feats, interpret=_INTERPRET)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "use_pallas"))
def flash_attention(q, k, v, causal: bool = True, window=None,
                    use_pallas: bool = True):
    if not use_pallas:
        return ref.attention_ref(q, k, v, causal=causal, window=window)
    return _flash(q, k, v, causal=causal, window=window,
                  interpret=_INTERPRET)
