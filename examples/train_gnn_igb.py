"""End-to-end driver: train a ~100M-parameter GraphSAGE on an IGB-style
synthetic graph through the GIDS dataloader for a few hundred steps.

The parameter count comes from the paper's regime (1024-d features, wide
hidden layers): 1024x4096 + 4096x4096 x2 + ... ≈ 100M with --hidden 4096.

    PYTHONPATH=src python examples/train_gnn_igb.py --steps 300
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import GIDSDataLoader, LoaderConfig, INTEL_OPTANE
from repro.graph.synthetic import rmat_graph
from repro.models.gnn import GNN, GNNConfig, hop_indices
from repro.train import checkpoint as ckpt_lib
from repro.train.fault_tolerance import StepWatchdog, WatchdogConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--nodes", type=int, default=100_000)
    ap.add_argument("--feature-dim", type=int, default=1024)
    ap.add_argument("--hidden", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--plane", default="gids-async",
                    help="data-plane preset (gids-async overlaps prep with "
                         "the measured train step)")
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    graph = rmat_graph(args.nodes, 12, args.feature_dim, seed=0,
                       name="igb-synthetic")
    n_classes = 47                     # IGB label space
    labels_all = rng.integers(0, n_classes, graph.num_nodes)
    feats = (np.eye(n_classes, args.feature_dim)[labels_all] * 2.0
             + 0.5 * rng.standard_normal(
                 (graph.num_nodes, args.feature_dim))).astype(np.float32)

    cfg = GNNConfig(model="sage", in_dim=args.feature_dim,
                    hidden_dim=args.hidden, num_classes=n_classes,
                    fanouts=(10, 5))
    gnn = GNN(cfg)
    params = gnn.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"GraphSAGE params: {n_params/1e6:.1f}M "
          f"(hidden {args.hidden}, features {args.feature_dim})")

    loader = GIDSDataLoader(
        graph, feats,
        LoaderConfig(batch_size=args.batch, fanouts=cfg.fanouts,
                     data_plane=args.plane, cache_lines=1 << 14,
                     window_depth=8, cbuf_fraction=0.1),
        ssd=INTEL_OPTANE)

    @jax.jit
    def step(p, f, h0, h1, h2, y, lr):
        loss, grads = jax.value_and_grad(gnn.loss)(p, f, [h0, h1, h2], y)
        p = jax.tree.map(lambda a, g: a - lr * g, p, grads)
        return p, loss

    t0 = time.time()
    losses, prep_times, exposed_times = [], [], []
    last_step_s = 0.0     # measured compute the prefetch overlapped with
    watchdog = StepWatchdog(WatchdogConfig(checkpoint_every=100))
    for it in range(args.steps):
        watchdog.start_step(it)
        b = loader.next_batch(compute_s=last_step_s)
        hi = [jnp.asarray(i) for i in hop_indices(b.blocks)]
        y = jnp.asarray(labels_all[b.blocks.seeds])
        ts = time.perf_counter()
        params, loss = step(params, jnp.asarray(b.features),
                            hi[0], hi[1], hi[2], y,
                            jnp.float32(args.lr))
        loss = float(loss)                       # sync point: step finished
        if it > 0:      # step 0's wall time is dominated by jit compilation
            last_step_s = time.perf_counter() - ts
        if watchdog.end_step():
            print(f"iter {it:4d} STRAGGLER: step took "
                  f"{watchdog.flagged[-1][1]*1e3:.1f} ms "
                  f"(median {watchdog.median_step_s*1e3:.1f} ms)")
        losses.append(loss)
        prep_times.append(b.prep_time_s)
        exposed_times.append(b.exposed_prep_s)
        if it % 25 == 0 or it == args.steps - 1:
            print(f"iter {it:4d} loss {losses[-1]:.4f} "
                  f"prep {np.mean(prep_times[-25:])*1e3:.2f} ms "
                  f"(exposed {np.mean(exposed_times[-25:])*1e3:.2f} ms) "
                  f"cache_hit {loader.store.cache.stats.hit_ratio:.2f} "
                  f"redirect {loader.accumulator.redirect_rate:.2f}")
        if args.ckpt_dir and watchdog.should_checkpoint(it):
            ckpt_lib.save(args.ckpt_dir, it, params,
                          {"loader": loader.state_dict()})

    print(f"\n{args.steps} steps in {time.time()-t0:.1f}s | "
          f"loss {np.mean(losses[:10]):.3f} -> {np.mean(losses[-10:]):.3f} | "
          f"{len(watchdog.flagged)} straggler steps")


if __name__ == "__main__":
    main()
