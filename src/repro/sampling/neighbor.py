"""GraphSAGE uniform neighborhood sampling (paper §2.2.2).

Two implementations with identical semantics:

* `host_sample_blocks` — numpy, drives the prefetch pipeline (the paper's
  "CPU sampling" baseline path, Fig. 3/7).
* `device_sample_blocks` — jittable JAX over a `DeviceCSR` (the paper's
  GPU-sampling path: latency hidden by parallelism).  Fixed fan-out with
  self-padding (absent neighbors repeat the seed), so shapes are static.

A "block" (DGL terminology) for hop ``l`` maps destination nodes (seeds of
that hop) to their sampled neighbors.  The union of all hops' nodes is the
set of feature rows the aggregation stage must fetch — the quantity the GIDS
accumulator counts.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph, DeviceCSR


@dataclasses.dataclass
class SampledBlocks:
    """One mini-batch's sampled computational graph.

    seeds:      (B,) the hop-0 target nodes
    hop_nodes:  list per hop: (B * prod(fanouts[:l]),) source node ids
                (padded with the destination node itself when degree < fanout)
    all_nodes:  unique node ids whose features must be gathered
    counts:     per-hop edge counts (for request accounting)
    """
    seeds: np.ndarray
    hop_nodes: list
    all_nodes: np.ndarray
    num_requests: int


def host_sample_blocks(graph: CSRGraph, seeds: np.ndarray,
                       fanouts: Sequence[int], rng: np.random.Generator
                       ) -> SampledBlocks:
    frontier = seeds.astype(np.int64)
    hop_nodes = []
    for f in fanouts:
        start = graph.indptr[frontier]
        deg = graph.indptr[frontier + 1] - start
        # uniform with replacement (matches DGL replace=True fast path);
        # degree-0 nodes self-loop.
        r = rng.random((frontier.shape[0], f))
        offs = np.floor(r * np.maximum(deg, 1)[:, None]).astype(np.int64)
        base = start[:, None]
        nbr = graph.indices[np.minimum(base + offs,
                                       graph.num_edges - 1)].astype(np.int64)
        nbr = np.where(deg[:, None] > 0, nbr, frontier[:, None])
        nbr = nbr.reshape(-1)
        hop_nodes.append(nbr)
        frontier = nbr
    all_nodes = np.unique(np.concatenate([seeds.astype(np.int64), *hop_nodes]))
    n_req = int(seeds.shape[0] + sum(h.shape[0] for h in hop_nodes))
    return SampledBlocks(seeds=seeds, hop_nodes=hop_nodes,
                         all_nodes=all_nodes, num_requests=n_req)


def device_sample_blocks(csr: DeviceCSR, seeds: jnp.ndarray,
                         fanouts: Sequence[int], key: jax.Array):
    """Jittable fixed-fanout sampler. Returns (list of per-hop node arrays,
    flat concatenated node ids). Shapes are static given (|seeds|, fanouts)."""
    frontier = seeds.astype(jnp.int32)
    hops = []
    for i, f in enumerate(fanouts):
        key_i = jax.random.fold_in(key, i)
        start = csr.indptr[frontier]
        deg = csr.indptr[frontier + 1] - start
        r = jax.random.uniform(key_i, (frontier.shape[0], f))
        offs = jnp.floor(r * jnp.maximum(deg, 1)[:, None]).astype(jnp.int32)
        idx = jnp.minimum(start[:, None] + offs, csr.indices.shape[0] - 1)
        nbr = csr.indices[idx]
        nbr = jnp.where(deg[:, None] > 0, nbr, frontier[:, None])
        nbr = nbr.reshape(-1)
        hops.append(nbr)
        frontier = nbr
    flat = jnp.concatenate([seeds.astype(jnp.int32), *hops])
    return hops, flat


def subgraph_sizes(batch: int, fanouts: Sequence[int]) -> int:
    """Closed-form node count of a padded sampled subgraph
    (paper Fig. 2: 1 + 3 + 6 for fanout (3,2) on one seed... generally
    B * (1 + f1 + f1*f2 + ...))."""
    n, prod = batch, batch
    for f in fanouts:
        prod *= f
        n += prod
    return n
