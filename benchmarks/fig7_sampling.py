"""Fig. 7 — graph sampling time, host vs device path, across graph scales
(IGB tiny/small/medium stand-ins)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.graph.datasets import IGB_MEDIUM, IGB_SMALL, IGB_TINY
from repro.sampling.neighbor import device_sample_blocks, host_sample_blocks


def main(batch=512, fanouts=(10, 5)):
    for spec in (IGB_TINY, IGB_SMALL, IGB_MEDIUM):
        g = spec.materialize()
        rng = np.random.default_rng(0)
        seeds = rng.integers(0, g.num_nodes, batch)
        t_host = timeit(lambda: host_sample_blocks(g, seeds, fanouts, rng),
                        iters=3)
        csr = g.to_device()
        dseeds = jnp.asarray(seeds, jnp.int32)
        samp = jax.jit(
            lambda s, k: device_sample_blocks(csr, s, fanouts, k)[1])
        key = jax.random.PRNGKey(0)
        t_dev = timeit(lambda: samp(dseeds, key).block_until_ready(),
                       iters=3)
        row(f"fig7_sampling_{spec.name}", t_host * 1e6,
            f"host_ms={t_host*1e3:.2f}_device_ms={t_dev*1e3:.2f}"
            f"_speedup={t_host/t_dev:.2f}x_nodes={g.num_nodes}")


if __name__ == "__main__":
    main()
