"""LR schedules: cosine (default) and WSD (Warmup-Stable-Decay, the
minicpm-2b training schedule [arXiv:2404.06395] — constant LR plateau with a
short exponential-ish decay tail, enabling continuous pretraining)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine(step, *, peak_lr: float, warmup: int, total: int,
           min_ratio: float = 0.1):
    t = jnp.asarray(step, jnp.float32)
    warm = peak_lr * t / jnp.maximum(warmup, 1)
    frac = jnp.clip((t - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (min_ratio + (1 - min_ratio)
                     * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(t < warmup, warm, cos)


def wsd(step, *, peak_lr: float, warmup: int, total: int,
        decay_fraction: float = 0.1, min_ratio: float = 0.01):
    """Warmup -> stable plateau -> decay over the last `decay_fraction`."""
    t = jnp.asarray(step, jnp.float32)
    decay_steps = decay_fraction * total
    decay_start = total - decay_steps
    warm = peak_lr * t / jnp.maximum(warmup, 1)
    frac = jnp.clip((t - decay_start) / jnp.maximum(decay_steps, 1), 0.0, 1.0)
    decay = peak_lr * (min_ratio ** frac)
    out = jnp.where(t < warmup, warm, peak_lr)
    return jnp.where(t > decay_start, decay, out)


def make(name: str, **kw):
    fn = {"cosine": cosine, "wsd": wsd}[name]
    return lambda step: fn(step, **kw)
