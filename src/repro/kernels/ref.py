"""Pure-jnp oracles for every Pallas kernel (allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tiered_gather_ref(slots: jax.Array, cache: jax.Array,
                      staged: jax.Array) -> jax.Array:
    from_cache = cache[jnp.maximum(slots, 0)]
    return jnp.where((slots >= 0)[:, None], from_cache, staged)


def segment_mean_ref(idx: jax.Array, feats: jax.Array) -> jax.Array:
    rows = feats[idx]                      # (B, F, D)
    return rows.astype(jnp.float32).mean(axis=1).astype(feats.dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int | None = None,
                  scale: float | None = None) -> jax.Array:
    B, H, Sq, dh = q.shape
    _, KV, Sk, _ = k.shape
    group = H // KV
    scale = scale if scale is not None else dh ** -0.5
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)    # fully-masked rows
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
