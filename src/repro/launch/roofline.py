"""Roofline-term extraction from compiled dry-run artifacts.

Hardware model (TPU v5e, per chip):
    peak bf16 compute : 197 TFLOP/s
    HBM bandwidth     : 819 GB/s
    ICI link bandwidth: ~50 GB/s per link

All quantities from the SPMD module are PER-DEVICE (verified: a (1024,4096)
bf16 weight sharded 16-way reports 512 KiB of argument bytes), so terms are
computed directly against per-chip peaks.

Memory-term accounting.  `cost_analysis()['bytes accessed']` on the CPU
backend counts every un-fused elementwise op (converts/broadcasts dominate:
measured 528 GiB of `convert` traffic in a 2-layer qwen2 step) — the TPU
compiler fuses those chains away.  We therefore model TPU HBM traffic from
the HLO: entry arguments + entry outputs are read/written once; outputs of
fusion-barrier ops (dot / fusion / gather / scatter / copy / transpose /
sort / rng / custom-call) count write+read; elementwise, broadcast,
reshape/bitcast, converts, reduces and dynamic-update-slices (in-place on
TPU) are treated as fused.  Ops inside fusion bodies are excluded (their
traffic is the fusion node's output).  This requires the module to be
WHILE-FREE, which the dry-run guarantees by lowering the cost ladder with
unrolled layer loops.

    compute_term    = HLO_flops / PEAK_FLOPS
    memory_term     = modeled_hbm_traffic / HBM_BW
    collective_term = per_device_collective_bytes / ICI_BW
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops whose outputs materialise in HBM on TPU (fusion barriers)
_BARRIER_OPS = {
    "dot", "convolution", "gather", "scatter", "copy", "transpose",
    "sort", "rng-bit-generator", "custom-call", "fusion", "cholesky",
    "triangular-solve", "fft", "concatenate", "dynamic-slice",
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[\w\[\],{}\s/]*?\)?)\s+"
                    r"([a-z][\w\-]*)\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _computations(hlo_text: str):
    """Split HLO text into (name, is_entry, lines)."""
    comps = []
    cur_name, cur_entry, cur_lines = None, False, []
    for line in hlo_text.splitlines():
        m = re.match(r"\s*(ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*{",
                     line)
        if m:
            if cur_name is not None:
                comps.append((cur_name, cur_entry, cur_lines))
            cur_name, cur_entry, cur_lines = m.group(2), bool(m.group(1)), []
            continue
        if cur_name is not None:
            cur_lines.append(line)
    if cur_name is not None:
        comps.append((cur_name, cur_entry, cur_lines))
    return comps


def analyze_hlo(hlo_text: str) -> dict:
    """Model TPU HBM traffic + collective bytes from (while-free) HLO."""
    coll_bytes = {k: 0 for k in COLLECTIVES}
    coll_count = {k: 0 for k in COLLECTIVES}
    barrier_bytes = 0
    param_bytes = 0
    output_bytes = 0
    while_count = 0
    for name, is_entry, lines in _computations(hlo_text):
        fused = name.startswith("fused_") or ".fused" in name
        for line in lines:
            m = _OP_RE.match(line)
            if not m:
                continue
            shape_str, op = m.groups()
            if op == "while":
                while_count += 1
            base = re.sub(r"-(start|done)$", "", op)
            if base in COLLECTIVES:
                if not op.endswith("-done"):
                    coll_bytes[base] += _shape_bytes(shape_str)
                    coll_count[base] += 1
                continue
            if fused:
                continue
            if op == "parameter" and is_entry:
                param_bytes += _shape_bytes(shape_str)
            elif op in _BARRIER_OPS:
                barrier_bytes += _shape_bytes(shape_str)
            if is_entry and line.strip().startswith("ROOT"):
                output_bytes += _shape_bytes(shape_str)
    traffic = param_bytes + output_bytes + 2 * barrier_bytes
    return {
        "hbm_traffic": traffic,
        "param_bytes": param_bytes,
        "output_bytes": output_bytes,
        "barrier_bytes": barrier_bytes,
        "collective_bytes": coll_bytes,
        "collective_count": coll_count,
        "collective_total": sum(coll_bytes.values()),
        "while_ops": while_count,
    }


def collective_bytes(hlo_text: str) -> dict:
    a = analyze_hlo(hlo_text)
    return {"bytes": a["collective_bytes"], "count": a["collective_count"],
            "total_bytes": a["collective_total"]}


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    compute_term: float
    memory_term: float
    collective_term: float
    bottleneck: str
    step_time_s: float          # max of the three terms (overlap-optimistic)
    model_flops: float = 0.0
    useful_ratio: float = 0.0   # MODEL_FLOPS / (HLO_flops * chips)

    def to_dict(self):
        return dataclasses.asdict(self)


def analyze(flops: float, hbm_traffic: float, coll_total: float, *,
            model_flops: float = 0.0, chips: int = 256) -> Roofline:
    ct = flops / PEAK_FLOPS
    mt = hbm_traffic / HBM_BW
    lt = coll_total / ICI_BW
    terms = {"compute": ct, "memory": mt, "collective": lt}
    bottleneck = max(terms, key=terms.get)
    return Roofline(
        flops=flops, hbm_bytes=hbm_traffic, coll_bytes=coll_total,
        compute_term=ct, memory_term=mt, collective_term=lt,
        bottleneck=bottleneck, step_time_s=max(terms.values()),
        model_flops=model_flops,
        useful_ratio=(model_flops / (flops * chips)) if flops else 0.0,
    )
