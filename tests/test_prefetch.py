"""Prefetch execution engine: async/sync bit-identity, overlap pricing,
two-stage plan/execute split, and checkpoint resume through staged batches."""
import numpy as np
import pytest

from repro.core import (DataPlaneSpec, GIDSDataLoader, INTEL_OPTANE,
                        LoaderConfig, StorageTimeline)
from repro.graph.synthetic import rmat_graph


@pytest.fixture(scope="module")
def graph_and_feats():
    g = rmat_graph(10_000, 12, 16, seed=1)
    feats = np.random.default_rng(0).standard_normal(
        (g.num_nodes, 16)).astype(np.float32)
    return g, feats


def _mk(g, feats, plane, seed=7):
    return GIDSDataLoader(g, feats, LoaderConfig(
        batch_size=128, fanouts=(4, 4), data_plane=plane, cache_lines=2048,
        window_depth=4, seed=seed))


def _assert_batches_identical(ba, bb):
    np.testing.assert_array_equal(ba.blocks.seeds, bb.blocks.seeds)
    np.testing.assert_array_equal(ba.blocks.all_nodes, bb.blocks.all_nodes)
    np.testing.assert_array_equal(ba.features, bb.features)
    assert ba.report == bb.report
    assert ba.prep_time_s == bb.prep_time_s
    assert ba.merge_depth == bb.merge_depth


def test_async_plane_bit_identical_to_sync(graph_and_feats):
    """The engine executes the same plan/execute calls in the same order —
    only earlier — so blocks, rows, and reports match bit-for-bit."""
    g, feats = graph_and_feats
    sync, asyn = _mk(g, feats, "gids"), _mk(g, feats, "gids-async")
    assert asyn.prefetch is not None and sync.prefetch is None
    for _ in range(12):
        _assert_batches_identical(sync.next_batch(),
                                  asyn.next_batch(compute_s=1e-3))


def test_overlap_pricing_exposed_prep(graph_and_feats):
    g, feats = graph_and_feats
    dl = _mk(g, feats, "gids-async")
    # compute shorter than prep: the excess is exposed
    b = dl.next_batch(compute_s=1e-6)
    assert b.exposed_prep_s == pytest.approx(
        max(0.0, b.prep_time_s - 1e-6))
    # compute dominating prep: nothing exposed
    b = dl.next_batch(compute_s=10.0)
    assert b.exposed_prep_s == 0.0 and b.prep_time_s > 0.0
    # the sync plane ignores compute_s and exposes everything
    b = _mk(g, feats, "gids").next_batch(compute_s=10.0)
    assert b.exposed_prep_s == b.prep_time_s > 0.0


def test_engine_stages_ahead_and_counts(graph_and_feats):
    g, feats = graph_and_feats
    dl = _mk(g, feats, "gids-async")
    depth = DataPlaneSpec.preset("gids-async").prefetch
    assert dl.prefetch.depth == depth == 2
    dl.next_batch(compute_s=1.0)
    # after one consume the engine holds depth-1 staged batches and has
    # executed depth in total
    assert len(dl.prefetch) == depth - 1
    st = dl.prefetch.stats
    assert st.staged_batches == depth and st.consumed_batches == 1
    assert st.exposed_s_total == 0.0 and st.hidden_fraction == 1.0


def test_plan_execute_split_equivalent_to_next_batch(graph_and_feats):
    g, feats = graph_and_feats
    a, b = _mk(g, feats, "gids"), _mk(g, feats, "gids")
    for _ in range(5):
        _assert_batches_identical(a.next_batch(), b.execute(b.plan_next()))


def test_async_resume_bit_identical(graph_and_feats):
    """state_dict taken mid-stream (with batches staged) resumes both a
    fresh async loader and a fresh sync loader to identical sequences."""
    g, feats = graph_and_feats
    src = _mk(g, feats, "gids-async")
    for _ in range(5):
        src.next_batch(compute_s=1e-3)
    st = src.state_dict()
    cont = [src.next_batch() for _ in range(4)]

    fresh_async = _mk(g, feats, "gids-async")
    fresh_async.load_state_dict(st)
    fresh_sync = _mk(g, feats, "gids")
    fresh_sync.load_state_dict(st)
    for expect in cont:
        ra = fresh_async.next_batch()
        rs = fresh_sync.next_batch()
        _assert_batches_identical(ra, rs)
        # the resumed loaders replay the source's sampling stream
        np.testing.assert_array_equal(expect.blocks.seeds, ra.blocks.seeds)

    # resume drops staged work: a second load from the same state replays
    # the same sequence again (idempotent restore)
    fresh_async.load_state_dict(st)
    assert len(fresh_async.prefetch) == 0
    np.testing.assert_array_equal(fresh_async.next_batch().blocks.seeds,
                                  cont[0].blocks.seeds)


def test_price_batch_overlapped():
    tl = StorageTimeline(INTEL_OPTANE)
    assert tl.price_batch_overlapped(5.0, 2.0) == 3.0
    assert tl.price_batch_overlapped(2.0, 5.0) == 0.0
    assert tl.price_batch_overlapped(2.0, 0.0) == 2.0
    assert tl.price_batch_overlapped(2.0, -1.0) == 2.0  # clamp bad input


def test_gids_async_preset_shape():
    spec = DataPlaneSpec.preset("gids-async")
    assert spec.prefetch > 0 and spec.lookahead
    assert [t.kind for t in spec.tiers] == [
        t.kind for t in DataPlaneSpec.preset("gids").tiers]
    # any spec composes with prefetch: presets stay data, not code
    custom = DataPlaneSpec.preset("pinned-host").with_(
        name="pinned-host-async", prefetch=3)
    assert custom.prefetch == 3
