# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows; `python -m benchmarks.run [--quick]`.  `--json [path]` is the CI
# smoke mode: fig13 + fig14 headline numbers as JSON (default BENCH_pr2.json)
# so the perf trajectory is recorded per PR.
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback


def write_json_smoke(path: str) -> None:
    from benchmarks import fig13_e2e, fig14_overlap
    payload = {
        "fig13_e2e": fig13_e2e.headline(),
        "fig14_overlap": fig14_overlap.headline(),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"# wrote {path}", flush=True)
    print(json.dumps(payload, indent=2))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the slow E2E figures")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", nargs="?", const="BENCH_pr2.json",
                    default=None, metavar="PATH",
                    help="smoke mode: write fig13/fig14 headline numbers to "
                         "PATH (default BENCH_pr2.json) and exit")
    args = ap.parse_args()

    if args.json:
        write_json_smoke(args.json)
        return

    from benchmarks import (fig3_request_rates, fig7_sampling,
                            fig8_bandwidth_model, fig9_accumulator,
                            fig10_constant_buffer, fig11_window_buffering,
                            fig12_cache_size, fig13_e2e, fig14_overlap,
                            fig15_ladies, roofline, tables)
    suites = [
        ("tables", tables.main),
        ("fig3", fig3_request_rates.main),
        ("fig7", fig7_sampling.main),
        ("fig8", fig8_bandwidth_model.main),
        ("fig9", fig9_accumulator.main),
        ("fig10", fig10_constant_buffer.main),
        ("fig11", fig11_window_buffering.main),
        ("fig12", fig12_cache_size.main),
        ("fig13_14", fig13_e2e.main),
        ("fig14_overlap", fig14_overlap.main),
        ("fig15", fig15_ladies.main),
        ("roofline", roofline.main),
    ]
    if args.quick:
        suites = [s for s in suites if s[0] not in ("fig13_14", "fig3")]
    if args.only:
        suites = [s for s in suites if s[0] == args.only]

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        t0 = time.time()
        try:
            fn()
            print(f"# suite {name} done in {time.time()-t0:.1f}s",
                  flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# suite {name} FAILED", flush=True)
            traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == '__main__':
    main()
