import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production mesh, prove memory fit, and extract roofline terms.

MUST be run as its own process (the XLA flag above is read at first jax
init): ``PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b
--shape train_4k [--multi-pod]``, or ``--all`` to sweep every cell in
subprocesses (isolation: one compilation arena per cell).

Outputs one JSON per cell under experiments/dryrun/.
"""

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import subprocess        # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
from pathlib import Path # noqa: E402

import numpy as np       # noqa: E402

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def active_param_count(model) -> int:
    """Per-token active parameters (routed experts count topk/E)."""
    from repro.models.common import ParamDef
    import jax
    cfg = model.cfg
    total = 0
    leaves = jax.tree.leaves(model.param_defs(),
                             is_leaf=lambda x: isinstance(x, ParamDef))
    for d in leaves:
        n = int(np.prod(d.shape))
        if "expert" in d.axes and cfg.moe_experts:
            n = n * cfg.moe_top_k // cfg.moe_experts
        total += n
    return total


def _donate_for(kind: str) -> tuple:
    # params+opt for train; cache for serving (in-place KV update)
    return (0, 1) if kind == "train" else (2,)


def analytic_attn_flops(cfg, kind: str, batch: int, seq: int,
                        chips: int) -> float:
    """Per-device FLOPs of the flash-attention kernel (re-added when the
    dry-run lowers the IO stub — XLA cannot cost Pallas custom calls).

    fwd = 4*B*Sq*Skv*H*hd (scores + AV), halved for causal; train multiplies
    by 3.5 (flash-2 backward ~2.5x fwd incl. recompute).
    """
    if cfg.family == "ssm":
        return 0.0
    n_attn, n_local = 0, 0
    for i in range(cfg.num_layers):
        if cfg.family == "hybrid":
            n_local += cfg.is_attn_layer(i)
        else:
            n_attn += 1
    H, hd = cfg.num_heads, cfg.hd
    if kind == "train":
        sq = skv = seq
        mult, causal = 3.5, True
    elif kind == "prefill":
        sq = skv = seq
        mult, causal = 1.0, True
    else:  # decode
        sq, skv = 1, seq
        mult, causal = 1.0, False
    per_layer = 4.0 * batch * sq * skv * H * hd
    if causal:
        per_layer *= 0.5
    total = per_layer * n_attn
    # hybrid local attention: window-limited keys
    if n_local:
        w = min(cfg.local_window, skv)
        total += 4.0 * batch * sq * w * H * hd * (0.5 if causal else 1.0) \
            * n_local
    if cfg.attn_window is not None and kind != "decode":
        # SWA caps the key range for the dense layers too
        w = min(cfg.attn_window, skv)
        total = 4.0 * batch * sq * w * H * hd * 0.5 * n_attn
    if cfg.family == "encdec":
        # encoder self-attn (bidirectional) + decoder cross-attn
        total += 4.0 * batch * cfg.encoder_seq ** 2 * H * hd \
            * cfg.encoder_layers
        total += 4.0 * batch * sq * cfg.encoder_seq * H * hd \
            * cfg.num_layers
    return total * mult / chips


def scan_ladder(cfg) -> tuple[dict, list[tuple[dict, int]]]:
    """Scan-trip-count extrapolation plan.

    XLA cost_analysis counts each lax.scan body ONCE (not x trip count), so
    per-step cost is reconstructed from reduced-depth lowers:
        full = cost(A) + sum_i (G_i - 1) * (cost(B_i) - cost(A))
    where A has 1 group per scanned stack, B_i has 2 groups in stack i, and
    G_i is the full model's group count (exact: scan cost is linear in trip
    count).  Memory/compile validity still comes from the full-depth build.
    """
    U = {"scan_unroll": True}   # python-loop layers: exact HLO accounting
    if cfg.family == "encdec":
        A = {"num_layers": 1, "encoder_layers": 1, **U}
        return A, [({"num_layers": 2, "encoder_layers": 1, **U},
                    cfg.num_layers - 1),
                   ({"num_layers": 1, "encoder_layers": 2, **U},
                    cfg.encoder_layers - 1)]
    if cfg.family == "hybrid":
        k = cfg.hybrid_attn_every
        rem = cfg.num_layers % k
        groups = cfg.num_layers // k
        A = {"num_layers": k + rem, **U}
        return A, [({"num_layers": 2 * k + rem, **U}, groups - 1)]
    il = cfg.moe_interleave if cfg.moe_experts else 1
    groups = cfg.num_layers // il
    return {"num_layers": il, **U}, [({"num_layers": 2 * il, **U},
                                      groups - 1)]


def _measure(cell, mesh, multi_pod, donate):
    import jax
    from repro.distributed.ctx import activation_sharding
    from repro.launch import roofline as rl
    from repro.launch.specs import activation_specs
    from repro.launch.specs import SHAPES as _SHAPES
    batch = _SHAPES[cell.shape]["batch"]
    with mesh, activation_sharding(activation_specs(cell.cfg, mesh,
                                                    multi_pod, batch=batch,
                                                    kind=cell.kind,
                                                    expert_axis=cell.rules.get("expert") or "model")):
        lowered = jax.jit(cell.step_fn,
                          donate_argnums=donate).lower(*cell.abstract_args)
        compiled = lowered.compile()
    ca = compiled.cost_analysis() or {}
    h = rl.analyze_hlo(compiled.as_text())
    assert h["while_ops"] == 0, "cost ladder must be while-free (unrolled)"
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_raw": float(ca.get("bytes accessed", 0.0)),
        "hbm": float(h["hbm_traffic"]),
        "coll": float(h["collective_total"]),
    }, compiled


def extrapolated_cost(arch, shape, mesh, multi_pod, strategy, overrides,
                      base_cfg) -> dict:
    from repro.launch.specs import build_cell
    A_ov, Bs = scan_ladder(base_cfg)
    merged = dict(overrides or {})
    # cost ladder runs at microbatches=1 (the grad-accum scan is a while
    # op; per-token costs are identical, grad-accum adds only m tiny adds)
    merged.pop("microbatches", None)
    cell_a = build_cell(arch, shape, mesh, multi_pod=multi_pod,
                        strategy=strategy, overrides={**merged, **A_ov})
    donate = _donate_for(cell_a.kind)
    cost_a, _ = _measure(cell_a, mesh, multi_pod, donate)
    total = dict(cost_a)
    for B_ov, mult in Bs:
        cell_b = build_cell(arch, shape, mesh, multi_pod=multi_pod,
                            strategy=strategy, overrides={**merged, **B_ov})
        cost_b, _ = _measure(cell_b, mesh, multi_pod, donate)
        for key in total:
            total[key] += mult * (cost_b[key] - cost_a[key])
    return total


def run_cell(arch: str, shape: str, multi_pod: bool, *,
             strategy: str | None = None, overrides: dict | None = None,
             tag: str = "") -> dict:
    import jax
    from repro.distributed.ctx import activation_sharding
    from repro.launch import roofline as rl
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import (SHAPES, activation_specs, build_cell,
                                    cell_supported)

    import repro.configs as configs
    cfg = configs.get(arch)
    if overrides:
        cfg = dataclasses.replace(
            cfg, **{k: v for k, v in overrides.items()
                    if k != "microbatches"})
    ok, reason = cell_supported(cfg, shape)
    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "strategy": strategy, "tag": tag}
    if not ok:
        rec["status"] = reason
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    cell = build_cell(arch, shape, mesh, multi_pod=multi_pod,
                      strategy=strategy, overrides=overrides)
    rec["strategy"] = strategy or ("fsdp_tp" if cfg.moe_experts else "tp")
    donate = _donate_for(cell.kind)

    t0 = time.time()
    from repro.launch.specs import SHAPES as _SHAPES
    batch = _SHAPES[cell.shape]["batch"]
    with mesh, activation_sharding(activation_specs(cell.cfg, mesh,
                                                    multi_pod, batch=batch,
                                                    kind=cell.kind,
                                                    expert_axis=cell.rules.get("expert") or "model")):
        lowered = jax.jit(cell.step_fn,
                          donate_argnums=donate).lower(*cell.abstract_args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    print(ma)                                   # proves the cell fits
    ca = compiled.cost_analysis() or {}
    print({k: ca[k] for k in ("flops", "bytes accessed") if k in ca})
    hlo = compiled.as_text()

    spec = SHAPES[shape]
    tokens = spec["batch"] * (spec["seq"] if cell.kind != "decode" else 1)
    n_active = active_param_count(cell.model)
    mult = 6 if cell.kind == "train" else 2
    model_flops = mult * n_active * tokens

    # scan-depth-corrected per-device costs (see scan_ladder docstring)
    cost_x = extrapolated_cost(arch, shape, mesh, multi_pod, strategy,
                               overrides, cfg)
    if cfg.attn_impl == "flash_stub":
        cost_x["flops"] += analytic_attn_flops(
            cfg, cell.kind, spec["batch"], spec["seq"], chips)
    roof = rl.analyze(cost_x["flops"], cost_x["hbm"], cost_x["coll"],
                      model_flops=model_flops, chips=chips)
    coll = rl.collective_bytes(hlo)
    rec.update({
        "status": "OK",
        "kind": cell.kind,
        "chips": chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_per_device_gib": round(
                (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                 + ma.output_size_in_bytes - ma.alias_size_in_bytes)
                / 2**30, 3),
        },
        "cost_raw_scan_body_once": {k: ca.get(k)
                                    for k in ("flops", "bytes accessed")},
        "cost_extrapolated": cost_x,
        "collectives": coll,
        "active_params": n_active,
        "tokens_per_step": tokens,
        "roofline": roof.to_dict(),
    })
    return rec


def cell_filename(arch, shape, multi_pod, tag="") -> str:
    mesh = "2x16x16" if multi_pod else "16x16"
    suffix = f"_{tag}" if tag else ""
    return f"{arch}_{shape}_{mesh}{suffix}.json"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--strategy", default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg overrides key=value (python literal)")
    ap.add_argument("--all", action="store_true",
                    help="sweep every cell in subprocesses")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    OUT_DIR.mkdir(parents=True, exist_ok=True)

    if args.all:
        import repro.configs as configs
        from repro.launch.specs import SHAPES
        failures = []
        for multi_pod in (False, True):
            for arch in configs.ARCH_IDS:
                for shape in SHAPES:
                    fn = OUT_DIR / cell_filename(arch, shape, multi_pod)
                    if args.skip_existing and fn.exists():
                        ok = json.loads(fn.read_text()).get("status", "")
                        if ok == "OK" or ok.startswith("SKIP"):
                            continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape]
                    if multi_pod:
                        cmd.append("--multi-pod")
                    print("::", " ".join(cmd), flush=True)
                    r = subprocess.run(cmd)
                    if r.returncode != 0:
                        failures.append((arch, shape, multi_pod))
        print(f"sweep done; {len(failures)} failures: {failures}")
        return 1 if failures else 0

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        import ast
        try:
            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v

    try:
        rec = run_cell(args.arch, args.shape, args.multi_pod,
                       strategy=args.strategy,
                       overrides=overrides or None, tag=args.tag)
    except Exception as e:  # noqa: BLE001 — record the failure
        rec = {"arch": args.arch, "shape": args.shape,
               "mesh": "2x16x16" if args.multi_pod else "16x16",
               "status": f"FAIL: {type(e).__name__}: {e}"}
        print(rec["status"], file=sys.stderr)
        fn = OUT_DIR / cell_filename(args.arch, args.shape, args.multi_pod,
                                     args.tag)
        fn.write_text(json.dumps(rec, indent=2))
        return 1

    fn = OUT_DIR / cell_filename(args.arch, args.shape, args.multi_pod,
                                 args.tag)
    fn.write_text(json.dumps(rec, indent=2))
    print(f"wrote {fn}")
    if rec.get("roofline"):
        r = rec["roofline"]
        print(f"{args.arch} x {args.shape}: bottleneck={r['bottleneck']} "
              f"compute={r['compute_term']:.4f}s memory={r['memory_term']:.4f}s "
              f"collective={r['collective_term']:.4f}s "
              f"useful={r['useful_ratio']:.3f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
