"""GraphSAGE uniform neighborhood sampling (paper §2.2.2).

Three implementations with identical semantics:

* `host_sample_blocks` — numpy, drives the prefetch pipeline (the paper's
  "CPU sampling" baseline path, Fig. 3/7).
* `device_sample_blocks` — jittable JAX over a `DeviceCSR` (the paper's
  GPU-sampling path: latency hidden by parallelism).  Fixed fan-out with
  self-padding (absent neighbors repeat the seed), so shapes are static.
* `repro.sampling.tiered.tiered_sample_blocks` — the host math run against
  a `TieredTopologyStore` (core/topology.py): bit-identical blocks plus a
  priced per-hop `TopologyGatherReport`.

All three share `sample_hop` / the `index_dtype` policy (graph/csr.py), so
the uniform-with-replacement math and the id-width handling cannot drift:
ids stay int64-safe end to end, and a graph past 2^31 nodes/edges widens
the device path (or fails loudly without x64) instead of silently wrapping.

A "block" (DGL terminology) for hop ``l`` maps destination nodes (seeds of
that hop) to their sampled neighbors.  The union of all hops' nodes is the
set of feature rows the aggregation stage must fetch — the quantity the GIDS
accumulator counts.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph, DeviceCSR, device_index_dtype


@dataclasses.dataclass
class SampledBlocks:
    """One mini-batch's sampled computational graph.

    seeds:      (B,) the hop-0 target nodes
    hop_nodes:  list per hop: (B * prod(fanouts[:l]),) source node ids
                (padded with the destination node itself when degree < fanout)
    all_nodes:  unique node ids whose features must be gathered
    counts:     per-hop edge counts (for request accounting)
    """
    seeds: np.ndarray
    hop_nodes: list
    all_nodes: np.ndarray
    num_requests: int


def sample_hop(graph: CSRGraph, frontier: np.ndarray, fanout: int,
               rng: np.random.Generator
               ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """One uniform-with-replacement hop (matches DGL replace=True fast
    path); degree-0 destinations self-loop.  Shared by `host_sample_blocks`
    and the tiered sampler so their RNG consumption and neighbor math are
    bit-identical by construction.

    Returns `(neighbors, positions, deg)`: the flattened (F * fanout,)
    sampled source ids, the (F, fanout) edge positions read from
    `graph.indices` (clamped; rows with deg 0 read nothing physically —
    their entries are self-loop padding), and the (F,) frontier degrees."""
    start = graph.indptr[frontier]
    deg = graph.indptr[frontier + 1] - start
    r = rng.random((frontier.shape[0], fanout))
    offs = np.floor(r * np.maximum(deg, 1)[:, None]).astype(np.int64)
    pos = np.minimum(start[:, None] + offs, graph.num_edges - 1)
    nbr = graph.indices[pos].astype(np.int64)
    nbr = np.where(deg[:, None] > 0, nbr, frontier[:, None])
    return nbr.reshape(-1), pos, deg


def run_sample_hops(graph: CSRGraph, seeds: np.ndarray,
                    fanouts: Sequence[int], rng: np.random.Generator,
                    hop_cb=None) -> tuple[list, np.ndarray, int]:
    """The ONE multi-hop sampling driver: frontier loop over `sample_hop`,
    unique-union of all hops, request counting.  `hop_cb(hop, read_pos,
    n_frontier)` observes each hop's physical adjacency reads (positions of
    degree>0 rows only) — the tiered sampler prices them, the host sampler
    passes None.  Sharing the driver makes host/tiered block identity
    structural, not maintained-by-parallel-edits."""
    frontier = seeds.astype(np.int64)
    hop_nodes: list[np.ndarray] = []
    for hop, f in enumerate(fanouts):
        nbr, pos, deg = sample_hop(graph, frontier, f, rng)
        if hop_cb is not None:
            hop_cb(hop, pos[deg > 0].reshape(-1), len(frontier))
        hop_nodes.append(nbr)
        frontier = nbr
    all_nodes = np.unique(np.concatenate([seeds.astype(np.int64), *hop_nodes]))
    n_req = int(seeds.shape[0] + sum(h.shape[0] for h in hop_nodes))
    return hop_nodes, all_nodes, n_req


def host_sample_blocks(graph: CSRGraph, seeds: np.ndarray,
                       fanouts: Sequence[int], rng: np.random.Generator
                       ) -> SampledBlocks:
    hop_nodes, all_nodes, n_req = run_sample_hops(graph, seeds, fanouts, rng)
    return SampledBlocks(seeds=seeds, hop_nodes=hop_nodes,
                         all_nodes=all_nodes, num_requests=n_req)


def device_sample_blocks(csr: DeviceCSR, seeds: jnp.ndarray,
                         fanouts: Sequence[int], key: jax.Array):
    """Jittable fixed-fanout sampler. Returns (list of per-hop node arrays,
    flat concatenated node ids). Shapes are static given (|seeds|, fanouts).
    Ids carry the graph's shared index dtype (int32 below 2^31 nodes/edges,
    int64 with x64 beyond) — same policy as the host path."""
    dt = device_index_dtype(csr.num_nodes, csr.indices.shape[0])
    frontier = seeds.astype(dt)
    hops = []
    for i, f in enumerate(fanouts):
        key_i = jax.random.fold_in(key, i)
        start = csr.indptr[frontier]
        deg = csr.indptr[frontier + 1] - start
        r = jax.random.uniform(key_i, (frontier.shape[0], f))
        offs = jnp.floor(r * jnp.maximum(deg, 1)[:, None]).astype(dt)
        idx = jnp.minimum(start[:, None] + offs, csr.indices.shape[0] - 1)
        nbr = csr.indices[idx].astype(dt)
        nbr = jnp.where(deg[:, None] > 0, nbr, frontier[:, None])
        nbr = nbr.reshape(-1)
        hops.append(nbr)
        frontier = nbr
    flat = jnp.concatenate([seeds.astype(dt), *hops])
    return hops, flat


def subgraph_sizes(batch: int, fanouts: Sequence[int]) -> int:
    """Closed-form node count of a padded sampled subgraph
    (paper Fig. 2: 1 + 3 + 6 for fanout (3,2) on one seed... generally
    B * (1 + f1 + f1*f2 + ...)).  Equals `SampledBlocks.num_requests` and
    the length of `device_sample_blocks`' flat output (pinned by test)."""
    n, prod = batch, batch
    for f in fanouts:
        prod *= f
        n += prod
    return n
