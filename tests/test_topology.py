"""Tiered graph-topology subsystem: admission registry + budget partition,
page-granular hop accounting/pricing, bit-identity of tiered sampling and
the gids-topo planes vs their un-tiered twins, the device frontier-gather
kernel path, sharded page queues, and checkpoint resume mid-lookahead."""
import numpy as np
import pytest

from repro.core import (GIDSDataLoader, INTEL_OPTANE, LoaderConfig,
                        TieredTopologyStore, admission_names,
                        host_sampling_time, make_admission)
from repro.core.topology import (TIER_HBM, TIER_HOST, TIER_STORAGE,
                                 page_scores)
from repro.graph.synthetic import rmat_graph
from repro.sampling.neighbor import host_sample_blocks
from repro.sampling.tiered import tiered_sample_blocks


@pytest.fixture(scope="module")
def graph():
    return rmat_graph(20_000, 12, 32, seed=1)


@pytest.fixture(scope="module")
def feats(graph):
    return np.random.default_rng(0).standard_normal(
        (graph.num_nodes, 32)).astype(np.float32)


def _loader(graph, feats, plane, **kw):
    cfg = dict(batch_size=128, fanouts=(4, 4), data_plane=plane,
               cache_lines=2048, window_depth=2, seed=3)
    cfg.update(kw)
    return GIDSDataLoader(graph, feats, LoaderConfig(**cfg))


# -- admission registry --------------------------------------------------------

def test_admission_policies_partition_budgets():
    score = np.arange(100, dtype=float)
    for name in admission_names():
        a = make_admission(name, 100, gpu_pages=20, host_pages=30,
                           page_score=score, seed=0)
        counts = np.bincount(a, minlength=3)
        assert tuple(counts[:3]) == (20, 30, 50), name
        assert a.shape == (100,) and a.dtype == np.int8


def test_degree_admission_ranks_by_score():
    score = np.array([5.0, 50.0, 1.0, 40.0, 2.0])
    a = make_admission("degree", 5, gpu_pages=2, host_pages=2,
                       page_score=score)
    assert a[1] == TIER_HBM and a[3] == TIER_HBM      # hottest two
    assert a[0] == TIER_HOST and a[4] == TIER_HOST    # next two
    assert a[2] == TIER_STORAGE                       # coldest


def test_unknown_admission_raises():
    with pytest.raises(KeyError, match="unknown admission"):
        make_admission("lru", 10, gpu_pages=1, host_pages=1)


def test_page_scores_favor_hot_destinations(graph):
    score = page_scores(graph.indptr, graph.indices, 1024)
    n_pages = max(1, -(-graph.num_edges // 1024))
    assert score.shape == (n_pages,)
    assert (score >= 0).all() and score.sum() > 0


# -- store + hop accounting ----------------------------------------------------

def test_store_pages_partition(graph):
    topo = TieredTopologyStore.from_graph(graph, gpu_fraction=0.2,
                                          host_fraction=0.3)
    hbm, host, sto = topo.tier_pages()
    assert hbm + host + sto == topo.n_pages
    assert hbm == round(0.2 * topo.n_pages)
    # slot table covers exactly the HBM pages
    assert (topo.page_slot >= 0).sum() == hbm


def test_hop_report_accounting(graph):
    topo = TieredTopologyStore.from_graph(graph)
    rng = np.random.default_rng(2)
    pos = rng.integers(0, graph.num_edges, 5000)
    r = topo.hop_report(pos, hop=1, n_frontier=1000)
    assert r.n_edge_reads == 5000 and r.hop == 1 and r.n_frontier == 1000
    assert sum(r.reads_by_tier) == r.n_edge_reads
    assert r.n_pages == sum(r.pages_by_tier) <= topo.n_pages
    # pages are 4 KB lines: reads sharing a page coalesced into one IO
    assert r.n_storage_ios == r.pages_by_tier[TIER_STORAGE]
    assert r.coalesce_factor >= 1.0
    assert r.time_s > 0
    # empty hop prices to zero
    r0 = topo.hop_report(np.empty(0, np.int64))
    assert r0.n_edge_reads == 0 and r0.time_s == 0.0


def test_hop_time_monotone_in_gpu_budget(graph):
    """More GPU-resident pages can only speed a hop up (nested admission
    prefixes) — the fig7 benchmark sweeps this; pin the kernel of the claim
    on fixed positions here."""
    rng = np.random.default_rng(3)
    pos = rng.integers(0, graph.num_edges, 20000)
    times = []
    for f in (0.0, 0.25, 0.5, 1.0):
        topo = TieredTopologyStore.from_graph(graph, gpu_fraction=f,
                                              host_fraction=0.3)
        times.append(topo.hop_report(pos).time_s)
    assert all(b <= a + 1e-12 for a, b in zip(times, times[1:])), times


# -- tiered sampling -----------------------------------------------------------

def test_tiered_blocks_bit_identical_to_host(graph):
    topo = TieredTopologyStore.from_graph(graph)
    seeds = np.random.default_rng(0).integers(0, graph.num_nodes, 256)
    rng_h = np.random.default_rng(7)
    rng_t = np.random.default_rng(7)
    bh = host_sample_blocks(graph, seeds, (5, 3), rng_h)
    bt = tiered_sample_blocks(graph, topo, seeds, (5, 3), rng_t)
    for a, b in zip(bh.hop_nodes, bt.hop_nodes):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(bh.all_nodes, bt.all_nodes)
    assert bh.num_requests == bt.num_requests
    # the RNG streams stayed in lockstep
    assert rng_h.bit_generator.state == rng_t.bit_generator.state
    assert len(bt.hop_reports) == 2
    assert bt.sample_time_s == pytest.approx(
        sum(r.time_s for r in bt.hop_reports))
    assert host_sampling_time(bt.hop_reports) > 0


def test_frontier_gather_matches_host_adjacency(graph):
    topo = TieredTopologyStore.from_graph(graph, gpu_fraction=0.3,
                                          host_fraction=0.3)
    pos = np.random.default_rng(5).integers(0, graph.num_edges, 4096)
    for use_pallas in (False, True):
        out = topo.frontier_gather(pos, use_pallas=use_pallas)
        np.testing.assert_array_equal(out, graph.indices[pos])


def test_frontier_gather_zero_gpu_budget(graph):
    topo = TieredTopologyStore.from_graph(graph, gpu_fraction=0.0,
                                          host_fraction=0.5)
    pos = np.random.default_rng(6).integers(0, graph.num_edges, 512)
    np.testing.assert_array_equal(topo.frontier_gather(pos),
                                  graph.indices[pos])


# -- the gids-topo planes ------------------------------------------------------

def test_gids_topo_bit_identical_to_gids(graph, feats):
    dl_ref = _loader(graph, feats, "gids")
    dl_topo = _loader(graph, feats, "gids-topo")
    for _ in range(5):
        a, b = dl_ref.next_batch(), dl_topo.next_batch()
        np.testing.assert_array_equal(a.blocks.seeds, b.blocks.seeds)
        for ha, hb in zip(a.blocks.hop_nodes, b.blocks.hop_nodes):
            np.testing.assert_array_equal(ha, hb)
        np.testing.assert_array_equal(a.blocks.all_nodes, b.blocks.all_nodes)
        np.testing.assert_array_equal(a.features, b.features)
        assert a.report.tier_counts == b.report.tier_counts
        # sampling is now priced INTO prep; the gather share is unchanged
        assert b.sample_time_s > 0
        assert b.prep_time_s == pytest.approx(
            a.prep_time_s + b.sample_time_s, rel=1e-12)
        # synchronous plane: exposed == prep, so sampling is exposed too
        assert b.exposed_prep_s == b.prep_time_s
        # per-hop tier split travels with the batch
        reports = b.blocks.hop_reports
        assert len(reports) == len(dl_topo.config.fanouts)
        assert all(sum(r.pages_by_tier) > 0 for r in reports)


def test_gids_topo_merged_bit_identical_to_gids_merged(graph, feats):
    dl_ref = _loader(graph, feats, "gids-merged", window_depth=4)
    dl_topo = _loader(graph, feats, "gids-topo-merged", window_depth=4)
    for _ in range(8):
        a, b = dl_ref.next_batch(), dl_topo.next_batch()
        np.testing.assert_array_equal(a.features, b.features)
        np.testing.assert_array_equal(a.blocks.all_nodes, b.blocks.all_nodes)
        assert b.sample_time_s > 0
        assert b.prep_time_s == pytest.approx(
            a.prep_time_s + b.sample_time_s, rel=1e-12)


def test_topo_rejects_non_neighbor_sampler(graph, feats):
    with pytest.raises(ValueError, match="neighbor"):
        _loader(graph, feats, "gids-topo", sampler="ladies")


def test_topo_sharded_pages_and_pricing(graph):
    """n_shards > 1 stripes storage pages across queues (placement registry
    reused over PAGE ids) and the hop completes at the max over per-shard
    drains — never slower than the single-queue burst of the same pages."""
    rng = np.random.default_rng(4)
    pos = rng.integers(0, graph.num_edges, 20000)
    topo1 = TieredTopologyStore.from_graph(graph, seed=2)
    topo4 = TieredTopologyStore.from_graph(graph, n_shards=4,
                                           placement="hash", seed=2)
    r1, r4 = topo1.hop_report(pos), topo4.hop_report(pos)
    assert r4.pages_by_tier == r1.pages_by_tier     # placement, not bytes
    assert len(r4.shard_pages) == 4
    assert sum(r4.shard_pages) == r4.n_storage_ios
    assert r4.time_s <= r1.time_s + 1e-12
    assert topo4.timeline.shard_burst is not None


def test_topo_sharded_rejects_double_device_modelling(graph):
    with pytest.raises(ValueError, match="n_ssd"):
        TieredTopologyStore.from_graph(graph, n_shards=4, n_ssd=2)


def test_topo_checkpoint_resume_mid_lookahead(graph, feats):
    """A checkpoint taken while sampled-ahead batches sit in the lookahead
    deque resumes with bit-identical blocks, features, and hop reports."""
    a = _loader(graph, feats, "gids-topo", seed=11)
    for _ in range(4):
        a.next_batch()
    state = a.state_dict()          # lookahead is non-empty (sample-ahead)
    assert len(a._lookahead) > 0
    nxt_a = a.next_batch()

    b = _loader(graph, feats, "gids-topo", seed=11)
    b.load_state_dict(state)
    nxt_b = b.next_batch()
    np.testing.assert_array_equal(nxt_a.blocks.seeds, nxt_b.blocks.seeds)
    np.testing.assert_array_equal(nxt_a.blocks.all_nodes,
                                  nxt_b.blocks.all_nodes)
    np.testing.assert_array_equal(nxt_a.features, nxt_b.features)
    ra = nxt_a.blocks.hop_reports
    rb = nxt_b.blocks.hop_reports
    assert [r.pages_by_tier for r in ra] == [r.pages_by_tier for r in rb]
    assert nxt_a.sample_time_s == nxt_b.sample_time_s
