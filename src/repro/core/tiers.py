"""Pluggable data-plane tiers — the open hierarchy behind the feature store.

The paper fixes three placements (GPU software cache §3.4, constant host
buffer §3.3, GPU-direct storage §3.1).  Related systems show the hierarchy
should be open: PyTorch-Direct's zero-copy host tier and Data Tiering's
reorder-and-score placement are each "just another tier".  This module
defines the `Tier` protocol every placement implements plus adapters for the
existing components:

  DeviceCacheTier    — wraps `WindowBufferedCache` (HBM metadata, numpy ref)
  DeviceStoreTier    — wraps `device_store.DeviceStore` (jittable HBM rows +
                       Pallas `tiered_gather`)
  ConstantBufferTier — wraps `ConstantBuffer` (pinned host memory)
  StorageTier        — the memmap/array storage backstop (always hits)
  ShardedStorageTier — the backstop partitioned across `n_shards` SSD queues
                       by a pluggable `PlacementPolicy` (core/sharding.py);
                       per-request shard ids feed the per-shard burst
                       pricing (`storage_sim.price_sharded_burst`)
  KVSlotTier         — a KV-cache slot pool for the serve engine (a request
                       "hits" while it holds a slot; retirement = evictable)

This module owns the *feature-row* namespace.  The *topology* namespace —
the CSR adjacency partitioned into page-granular GPU/host/storage tiers for
GPU-initiated sampling — mirrors the same ideas one level down in
`core/topology.py` (`TieredTopologyStore`, with admission policies
registered like `core/sharding.py` placements); its tier vocabulary reuses
`LATENCY_CLASSES` so telemetry reads the same across both planes.

`build_plan` folds an ordered tier stack over one batch of requests into a
`GatherPlan`: a per-request tier-assignment array that is, by construction, a
partition — every request is served by exactly one tier.  The plan feeds both
the `tiered_gather` Pallas kernel (slot array) and the storage-timeline
pricing (per-tier counts).  Requests a storage-class tier claims additionally
carry a shard id (`GatherPlan.shard`): the serving tier's placement decision
for a sharded backstop, 0 for a single-queue one, -1 for requests faster
tiers redirected off storage entirely.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Protocol, Sequence, runtime_checkable

import numpy as np

from .constant_buffer import ConstantBuffer
from .software_cache import WindowBufferedCache
from .storage_sim import IO_BYTES

#: Valid latency classes, fastest first.  The storage-timeline pricing keys
#: off the class, not the concrete tier, so user tiers slot into the model.
LATENCY_CLASSES = ("hbm", "host", "storage")


@runtime_checkable
class Tier(Protocol):
    """One placement in the data plane.

    `probe(node_ids)` returns a boolean hit mask over the requests that
    reached this tier (requests claimed by faster tiers are not offered).
    Probing MAY mutate tier state (a cache fills its lines on miss — the
    paper's access path does exactly that).  `admit(node_ids)` announces the
    node list of a *future* batch so the tier can pin / prefetch (window
    buffering); tiers without look-ahead treat it as a no-op.
    """

    name: str
    latency_class: str

    @property
    def capacity_bytes(self) -> int | None: ...      # None = unbounded

    def probe(self, node_ids: np.ndarray) -> np.ndarray: ...

    def admit(self, node_ids: np.ndarray) -> None: ...

    def reset(self) -> None: ...


class _TierBase:
    """Default no-op admit/reset so simple tiers stay two methods."""

    name = "tier"
    latency_class = "storage"

    @property
    def capacity_bytes(self) -> int | None:
        return None

    def admit(self, node_ids: np.ndarray) -> None:
        del node_ids

    def reset(self) -> None:
        pass


class DeviceCacheTier(_TierBase):
    """HBM tier backed by the window-buffered software cache (§3.4).

    The wrapped cache is metadata-only (the reference numpy twin); the HBM
    row store it implies can be materialized for the Pallas kernel via
    `TieredFeatureStore.device_rows`.
    """

    latency_class = "hbm"

    def __init__(self, cache: WindowBufferedCache, name: str = "hbm-cache",
                 line_bytes: int = IO_BYTES):
        self.cache = cache
        self.name = name
        self.line_bytes = line_bytes

    @property
    def capacity_bytes(self) -> int:
        return self.cache.num_sets * self.cache.ways * self.line_bytes

    @property
    def window_depth(self) -> int:
        return self.cache.window_depth

    @property
    def window(self) -> deque:
        return self.cache.window

    def probe(self, node_ids: np.ndarray) -> np.ndarray:
        return self.cache.access(node_ids)

    def probe_merged(self, node_ids: np.ndarray,
                     multiplicity: np.ndarray) -> np.ndarray:
        """One deduplicated probe for a whole merged window: each node
        consumes its full reuse multiplicity at once (see
        `WindowBufferedCache.access_merged`; the caller has already retired
        the consumed window entries and pushed the next window's)."""
        return self.cache.access_merged(node_ids, multiplicity)

    def admit(self, node_ids: np.ndarray) -> None:
        self.cache.push_window(node_ids)

    def lookup_slots(self, node_ids: np.ndarray) -> np.ndarray:
        """Resident cache line per node (post-probe), -1 if absent."""
        return self.cache.lookup(node_ids)

    def reset(self) -> None:
        self.cache.reset()


class DeviceStoreTier(_TierBase):
    """Fully-jittable HBM tier: cache_jax metadata + HBM row store + the
    `tiered_gather` Pallas kernel, via `device_store.device_gather`.

    Requests are padded to a power-of-two bucket so the jitted step re-uses
    compiled shapes across batches.  `last_rows` holds the device-gathered
    rows of the most recent probe (the real data path of this tier).
    """

    latency_class = "hbm"

    def __init__(self, features: np.ndarray, num_lines: int, ways: int = 8,
                 window_depth: int = 0, use_pallas: bool = False,
                 name: str = "device-store"):
        import jax.numpy as jnp                      # deferred: numpy-only
        from . import device_store                   # users never pay for jax
        self._jnp = jnp
        self._mod = device_store
        self._host_features = features
        self._init_args = (num_lines, features.shape[1], ways)
        self.store = device_store.init_store(num_lines, features.shape[1],
                                             ways)
        self.window_depth = window_depth
        self.window: deque[np.ndarray] = deque()
        self.use_pallas = use_pallas
        self.name = name
        self.last_rows = None

    @property
    def capacity_bytes(self) -> int:
        return int(self.store.rows.nbytes)

    def _future_counts(self, ids: np.ndarray) -> np.ndarray:
        """Per-id count of future window batches containing it, in one
        concatenated membership pass: each window entry contributes its
        unique ids once, the sorted concatenation is binary-searched from
        both sides, and the span width is the count."""
        if not self.window:
            return np.zeros(len(ids), np.int32)
        cat = np.sort(np.concatenate(
            [np.unique(np.asarray(w)) for w in self.window]))
        lo = np.searchsorted(cat, ids, side="left")
        hi = np.searchsorted(cat, ids, side="right")
        return (hi - lo).astype(np.int32)

    def probe(self, node_ids: np.ndarray) -> np.ndarray:
        if self.window_depth > 0 and self.window:
            self.window.popleft()
        return self._probe_rows(node_ids)

    def probe_merged(self, node_ids: np.ndarray,
                     multiplicity: np.ndarray) -> np.ndarray:
        """Merged-window probe: one deduplicated device gather for the whole
        window (the caller has already retired the consumed look-ahead
        entries).  The jittable cache metadata decrements one reservation
        per hit, not the full multiplicity — surplus reservations keep
        lines pinned a little longer than the reference cache would
        (conservative: capacity, not correctness)."""
        del multiplicity
        return self._probe_rows(node_ids)

    def _probe_rows(self, node_ids: np.ndarray) -> np.ndarray:
        n = len(node_ids)
        pad = max(8, 1 << (n - 1).bit_length())      # shape bucket for jit
        ids = np.full(pad, -1, np.int32)
        ids[:n] = node_ids
        staged = self._host_features[np.maximum(ids, 0)]
        fc = np.zeros(pad, np.int32)
        fc[:n] = self._future_counts(node_ids)
        self.store, rows, hits = self._mod.device_gather(
            self.store, self._jnp.asarray(ids), self._jnp.asarray(staged),
            self._jnp.asarray(fc), use_pallas=self.use_pallas)
        self.last_rows = np.asarray(rows)[:n]
        return np.asarray(hits)[:n]

    def admit(self, node_ids: np.ndarray) -> None:
        if self.window_depth == 0:
            return
        self.window.append(np.asarray(node_ids))
        self.store = self.store._replace(cache=self._mod.push_window(
            self.store.cache,
            self._jnp.asarray(np.asarray(node_ids, np.int32))))

    def lookup_slots(self, node_ids: np.ndarray) -> np.ndarray:
        """Resident HBM row per node from the jittable cache metadata, -1 if
        absent (read-only; mirrors `WindowBufferedCache.lookup`)."""
        from .software_cache import _hash_ids   # the shared Fibonacci hash —
        tags = np.asarray(self.store.cache.tags)  # must match cache_jax
        slots = np.asarray(self.store.cache.slots)  # bit-exactly
        ids = np.asarray(node_ids)
        sets = _hash_ids(ids, tags.shape[0])
        match = tags[sets] == ids[:, None]        # (n, ways) tag compare
        way = match.argmax(axis=1)                # first matching way
        return np.where(match.any(axis=1),
                        slots[sets, way], -1).astype(np.int32)

    def device_rows(self) -> np.ndarray:
        """The resident HBM row store (already materialized on device)."""
        return np.asarray(self.store.rows)

    def reset(self) -> None:
        self.store = self._mod.init_store(*self._init_args)
        self.window.clear()
        self.last_rows = None


class TenantCacheTier(_TierBase):
    """HBM software-cache tier partitioned per tenant with priced isolation.

    The serving twin of `DeviceCacheTier`: the line budget is split into
    per-tenant `WindowBufferedCache` partitions (window_depth=0 — serving
    has no epoch lookahead, so eviction is BaM-random within the partition).
    A request fills and evicts ONLY inside its own tenant's partition, so a
    noisy tenant scanning the whole graph cannot evict another tenant's hot
    set — isolation is by construction, and it is *priced*: misses the
    partition bound creates surface in the storage burst like any other
    miss, so the benchmark sees exactly what the quota costs and buys.

    The serving engine announces who is asking via `stage_tenants(tenant_of)`
    immediately before the gather: one tenant id per node offered to the
    next `probe`/`probe_merged`.  This tier must therefore sit FIRST in the
    stack (the fold offers the full request set to the first tier, keeping
    the staged array positionally aligned).  A node two tenants share is
    served from (and filled into) the first requester's partition for that
    window — the shared data plane still dedupes the fetch; quotas govern
    eviction, not bytes on the wire.  Un-staged probes default to tenant 0,
    the single-tenant degenerate case.
    """

    latency_class = "hbm"

    def __init__(self, num_lines: int, ways: int = 8, tenants: int = 1,
                 quotas: Sequence[float] | None = None, seed: int = 0,
                 line_bytes: int = IO_BYTES, name: str = "hbm-tenant-cache"):
        if tenants < 1:
            raise ValueError(f"need at least one tenant, got {tenants}")
        if quotas is None:
            quotas = (1.0 / tenants,) * tenants
        self.ways = ways
        self.line_bytes = line_bytes
        self.name = name
        self._num_lines = int(num_lines)
        self._seed = seed
        self._tenants = tenants
        self._init_quotas = self._check_quotas(quotas)
        self.quotas = self._init_quotas
        self.partitions = self._build_partitions(self.quotas)
        self._staged: np.ndarray | None = None

    def _check_quotas(self, quotas: Sequence[float]) -> tuple[float, ...]:
        quotas = tuple(float(q) for q in quotas)
        if len(quotas) != self._tenants:
            raise ValueError(
                f"{len(quotas)} quotas for {self._tenants} tenants — pass "
                "one capacity share per tenant")
        if any(q <= 0 for q in quotas):
            raise ValueError(f"quotas must be positive, got {quotas}")
        return quotas

    def _build_partitions(self, quotas: tuple[float, ...]
                          ) -> tuple[WindowBufferedCache, ...]:
        total = sum(quotas)
        # per-partition line budget: quota share rounded down to a whole
        # number of sets (the cache asserts num_lines % ways == 0), floored
        # at one set so every tenant owns at least `ways` lines; partition
        # seeds derive from the tenant index, so a tenant's hash placement
        # is stable across repartitions
        return tuple(
            WindowBufferedCache(
                max(self.ways,
                    (int(self._num_lines * q / total) // self.ways)
                    * self.ways),
                self.ways, window_depth=0, seed=self._seed + 17 * t)
            for t, q in enumerate(quotas))

    def repartition(self, quotas: Sequence[float]) -> None:
        """Online quota re-split (the `QuotaController`'s actuator,
        core/feedback.py): rebuild the per-tenant partitions at the new
        shares.  Rebuilt partitions start COLD — the refill is priced as
        ordinary misses in subsequent bursts, which is exactly why the
        controller repartitions sparingly — but each tenant's cumulative
        hit/access counters carry over, so `hit_ratio(tenant)` telemetry
        (and the `ServeResult` rollup) stays a run-long signal."""
        quotas = self._check_quotas(quotas)
        stats = [c.stats for c in self.partitions]
        self.partitions = self._build_partitions(quotas)
        for cache, old in zip(self.partitions, stats):
            cache.stats = old
        self.quotas = quotas

    @property
    def tenants(self) -> int:
        return len(self.partitions)

    @property
    def capacity_bytes(self) -> int:
        return sum(c.num_sets * c.ways for c in self.partitions) \
            * self.line_bytes

    def partition_lines(self, tenant: int) -> int:
        c = self.partitions[tenant]
        return c.num_sets * c.ways

    def stage_tenants(self, tenant_of: np.ndarray) -> None:
        """Announce the requesting tenant of each node in the NEXT probe —
        (n,) int array positionally aligned with the node list the fold
        will offer.  Consumed by that one probe."""
        t = np.asarray(tenant_of)
        if len(t) and (t.min() < 0 or t.max() >= self.tenants):
            raise ValueError(
                f"tenant ids in [{t.min()}, {t.max()}] out of range for "
                f"{self.tenants} partitions")
        self._staged = t

    def _take_staged(self, n: int) -> np.ndarray:
        t = self._staged
        self._staged = None
        if t is None:
            return np.zeros(n, np.int64)
        if len(t) != n:
            raise ValueError(
                f"staged {len(t)} tenant ids but the fold offered {n} "
                "nodes — the tenant tier must be first in the stack")
        return t

    def probe(self, node_ids: np.ndarray) -> np.ndarray:
        return self._probe(node_ids, None)

    def probe_merged(self, node_ids: np.ndarray,
                     multiplicity: np.ndarray) -> np.ndarray:
        return self._probe(node_ids, multiplicity)

    def _probe(self, node_ids: np.ndarray,
               multiplicity: np.ndarray | None) -> np.ndarray:
        tenant = self._take_staged(len(node_ids))
        hits = np.zeros(len(node_ids), dtype=bool)
        for tid, cache in enumerate(self.partitions):
            m = tenant == tid
            if not m.any():
                continue
            mult = None if multiplicity is None else multiplicity[m]
            hits[m] = cache.access(node_ids[m], multiplicity=mult)
        return hits

    def lookup_slots(self, node_ids: np.ndarray) -> np.ndarray:
        """Resident line per node across the concatenated partitions
        (partition t's lines offset by the budgets before it), -1 if the
        node is resident in no partition.  Read-only, tenant-agnostic: a
        row in HBM is a row in HBM regardless of whose quota pinned it."""
        out = np.full(len(node_ids), -1, np.int64)
        offset = 0
        for cache in self.partitions:
            slot = cache.lookup(np.asarray(node_ids))
            found = (out == -1) & (slot >= 0)
            out[found] = slot[found] + offset
            offset += cache.num_sets * cache.ways
        return out

    def hit_ratio(self, tenant: int) -> float:
        return self.partitions[tenant].stats.hit_ratio

    def hit_ratios(self) -> tuple[float, ...]:
        """Cumulative per-tenant hit ratios — the quota controller's input,
        rolled up into `ServeResult.tenant_hit_ratios`."""
        return tuple(c.stats.hit_ratio for c in self.partitions)

    def reset(self) -> None:
        # full post-construction state: construction-time quotas restored
        # (an adaptive run may have repartitioned), partitions cold, fresh
        # counters — so replays of the same stream are bit-reproducible
        self.quotas = self._init_quotas
        self.partitions = self._build_partitions(self.quotas)
        self._staged = None


class ConstantBufferTier(_TierBase):
    """Pinned-host tier backed by the constant CPU buffer (§3.3).  Stateless
    membership lookup — the PyTorch-Direct zero-copy tier has the same shape
    with a different selection policy."""

    latency_class = "host"

    def __init__(self, cbuf: ConstantBuffer, row_bytes: int | None = None,
                 name: str = "host-cbuf"):
        self.cbuf = cbuf
        self.row_bytes = row_bytes
        self.name = name

    @property
    def capacity_bytes(self) -> int | None:
        if self.cbuf.rows is not None:
            return int(self.cbuf.rows.nbytes)
        if self.row_bytes is not None:
            return self.cbuf.size * self.row_bytes
        return None

    def probe(self, node_ids: np.ndarray) -> np.ndarray:
        return self.cbuf.redirect_mask(node_ids)


class StorageTier(_TierBase):
    """The storage namespace backstop (memmap file or in-memory array).
    Always hits — a tier stack is valid iff it ends in a backstop."""

    latency_class = "storage"

    def __init__(self, features: np.ndarray, name: str = "storage"):
        self.features = features
        self.name = name

    @property
    def capacity_bytes(self) -> int:
        return int(self.features.nbytes)

    def probe(self, node_ids: np.ndarray) -> np.ndarray:
        return np.ones(len(node_ids), dtype=bool)

    def rows(self, node_ids: np.ndarray) -> np.ndarray:
        return np.asarray(self.features[node_ids])


class ShardedStorageTier(StorageTier):
    """The storage backstop partitioned across `n_shards` independent SSD
    queues by a `PlacementPolicy` (core/sharding.py).

    The *bytes* are unchanged — one logical feature namespace, every probe
    hits — but each storage-bound request now carries the shard whose queue
    it drains through (`shard_of`, threaded into `GatherPlan.shard` by
    `build_plan`).  Pricing then completes the batch at the MAX over shards
    (`storage_sim.price_sharded_burst`), which is what makes multi-SSD
    scaling and placement skew measurable.

    `specs` may be one `SSDSpec` (homogeneous array), a sequence of
    `n_shards` specs (heterogeneous — e.g. one Optane + three 980Pros, the
    straggler story), or None (every shard inherits the loader's device
    spec).
    """

    def __init__(self, features: np.ndarray, placement,
                 specs=None, name: str = "sharded-storage"):
        super().__init__(features, name=name)
        self.placement = placement
        if specs is not None and not isinstance(specs, (list, tuple)):
            specs = (specs,) * placement.n_shards
        if specs is not None:
            specs = tuple(specs)
            if len(specs) != placement.n_shards:
                raise ValueError(
                    f"{len(specs)} shard specs for {placement.n_shards} "
                    "shards — pass one spec per shard (or a single spec "
                    "to replicate)")
        self.specs = specs
        # fault plane: a FailoverRouter (core/faults.py) rewrites the
        # placement decision at plan time — reads off dead/degraded shards
        # go to a live replica.  None (the default) keeps shard_of the
        # bare placement, bit-identical to the unrouted plane.
        self.router = None

    @property
    def n_shards(self) -> int:
        return self.placement.n_shards

    def shard_of(self, node_ids: np.ndarray) -> np.ndarray:
        """Per-request shard id (the placement decision), (B,) int16.
        With a router wired, the decision is failover-adjusted — same
        bytes, healthier queue."""
        primary = np.asarray(self.placement.shard_of(node_ids), np.int16)
        if self.router is None:
            return primary
        return np.asarray(self.router.route(node_ids, primary), np.int16)

    def resolve_shard_specs(self, default_spec) -> tuple:
        """Per-shard `SSDSpec`s, falling back to `default_spec` (the
        loader's device) when the tier was built spec-less."""
        if self.specs is not None:
            return self.specs
        return (default_spec,) * self.n_shards

    # -- checkpoint -----------------------------------------------------------
    def state_dict(self) -> dict:
        """Shard-assignment state for checkpoint round-trip.  Built-in
        policies are deterministic, but the table-based ones (`degree`) are
        exactly what an online rebalancer would mutate — resume restores the
        assignment rather than trusting reconstruction."""
        return {"n_shards": self.n_shards,
                "placement": self.placement.state_dict()}

    def load_state_dict(self, state: dict) -> None:
        if state.get("n_shards", self.n_shards) != self.n_shards:
            raise ValueError(
                f"checkpoint has {state.get('n_shards')} shards, tier has "
                f"{self.n_shards} — shard count is namespace layout, not "
                "runtime state")
        self.placement.load_state_dict(state["placement"])


class KVSlotTier(_TierBase):
    """KV-cache slot pool as a data-plane tier (serve engine).

    A request "hits" while it holds a slot — its KV lines are resident and
    un-evictable, the serving analogue of the window cache's USE state.  A
    retired request's slot returns to safe-to-evict and is recycled for the
    next admission.
    """

    latency_class = "hbm"

    def __init__(self, slots: int, bytes_per_slot: int = 0,
                 name: str = "kv-slots"):
        self.num_slots = slots
        self.bytes_per_slot = bytes_per_slot
        self.name = name
        self._free: deque[int] = deque(range(slots))
        self._held: dict[int, int] = {}              # rid -> slot

    @property
    def capacity_bytes(self) -> int:
        return self.num_slots * self.bytes_per_slot

    @property
    def occupancy(self) -> float:
        return len(self._held) / self.num_slots if self.num_slots else 0.0

    def probe(self, request_ids: np.ndarray) -> np.ndarray:
        held = np.fromiter(self._held.keys(), dtype=np.int64,
                           count=len(self._held))
        return np.isin(np.asarray(request_ids, dtype=np.int64), held)

    def admit(self, request_ids: np.ndarray) -> None:
        """Best-effort bulk admission: ids beyond the free capacity are NOT
        admitted (no queueing at this layer).  Callers that must know the
        outcome use `acquire()` per id — the serve engine does, keeping its
        own queue for the overflow."""
        for r in request_ids:
            self.acquire(int(r))

    def acquire(self, rid: int) -> int | None:
        """Assign a free slot to `rid` (idempotent); None when full."""
        if rid in self._held:
            return self._held[rid]
        if not self._free:
            return None
        slot = self._free.popleft()
        self._held[rid] = slot
        return slot

    def release(self, rid: int) -> int:
        slot = self._held.pop(rid)
        self._free.append(slot)
        return slot

    def reset(self) -> None:
        self._free = deque(range(self.num_slots))
        self._held.clear()


# -- gather plan ---------------------------------------------------------------

@dataclasses.dataclass
class GatherPlan:
    """Per-request tier assignment for one batch: `assignment[i]` indexes the
    tier stack entry that serves request i.  Folding guarantees a partition
    (`is_partition`); `kernel_slots` renders the device-tier portion as the
    slot array the `tiered_gather` Pallas kernel consumes.

    `shard[i]` is the storage shard serving request i: the placement
    decision of a `ShardedStorageTier`, 0 for a single-queue storage tier,
    and -1 iff the serving tier is not storage-class (`shard_consistent`
    pins that invariant).  Shard ids drive shard-local 4 KB-line coalescing
    and the max-over-shards burst pricing.

    `remote[i]` (host planes only — core/hosts.py) marks requests whose
    serving host differs from the host that REQUESTED them; those rows'
    lines additionally transit the serving host's link in
    `StorageTimeline.price_host_burst`.  None on single-host planes —
    remote-ness is a pricing/telemetry annotation, never a routing one, so
    gathered bytes cannot depend on it."""

    node_ids: np.ndarray
    assignment: np.ndarray          # (B,) int8 index into `tiers`
    tiers: tuple
    shard: np.ndarray | None = None  # (B,) int16; -1 = not storage-bound
    remote: np.ndarray | None = None  # (B,) bool; True = crosses a host link

    def counts(self) -> np.ndarray:
        return np.bincount(self.assignment, minlength=len(self.tiers))

    def mask(self, tier_index: int) -> np.ndarray:
        return self.assignment == tier_index

    def is_partition(self) -> bool:
        a = self.assignment
        return bool(((a >= 0) & (a < len(self.tiers))).all()
                    and int(self.counts().sum()) == len(self.node_ids))

    def storage_mask(self) -> np.ndarray:
        """Requests whose serving tier is storage-class."""
        classes = np.array([t.latency_class == "storage" for t in self.tiers])
        return classes[self.assignment]

    @property
    def n_shards(self) -> int:
        """Shard count of the stack's storage namespace (1 when unsharded)."""
        return max((getattr(t, "n_shards", 1) for t in self.tiers), default=1)

    def shard_consistent(self) -> bool:
        """Shard ids are defined exactly where the serving tier is
        storage-class, and always index a real shard."""
        if self.shard is None:
            return not self.storage_mask().any()
        sm = self.storage_mask()
        s = self.shard
        return bool(((s[sm] >= 0) & (s[sm] < self.n_shards)).all()
                    and (s[~sm] == -1).all())

    def shard_counts(self) -> np.ndarray:
        """Storage-bound requests per shard, (n_shards,)."""
        if self.shard is None:
            return np.zeros(self.n_shards, np.int64)
        sm = self.shard >= 0
        return np.bincount(self.shard[sm], minlength=self.n_shards)

    def remote_counts(self) -> np.ndarray:
        """Cross-host storage requests per SERVING shard, (n_shards,) —
        the rows each host ships over its link (zeros off host planes)."""
        if self.shard is None or self.remote is None:
            return np.zeros(self.n_shards, np.int64)
        rm = self.remote & (self.shard >= 0)
        return np.bincount(self.shard[rm], minlength=self.n_shards)

    def kernel_slots(self, tier_index: int = 0) -> np.ndarray:
        """Slot array for `ops.tiered_gather`: requests served by the device
        tier carry their cache line, everything else -1 (staged row i).

        Slots are resolved against the tier's *post-probe* metadata — the
        same state `TieredFeatureStore.device_rows` materializes — so the
        (slots, rows) pair is always coherent.  A hit whose line was evicted
        later in the same batch (a colliding fill in its set) resolves to -1
        and is demoted to the staged path: the gathered bytes stay correct,
        at worst the pricing report counted one extra HBM hit."""
        tier = self.tiers[tier_index]
        slots = np.full(len(self.node_ids), -1, np.int32)
        m = self.mask(tier_index)
        if m.any():
            slots[m] = tier.lookup_slots(self.node_ids[m])
        return slots


def build_plan(tiers: Sequence[Tier], node_ids: np.ndarray,
               multiplicity: np.ndarray | None = None) -> GatherPlan:
    """Fold the ordered tier stack over one batch: each tier is offered the
    requests every faster tier declined; its hits are claimed.  The last tier
    must be a backstop (probe everything True), else the fold fails loudly.

    With `multiplicity` the fold is a merged-window one: `node_ids` is a
    window's UNIQUE request set, and tiers that understand merged windows
    (`probe_merged`) consume each node's full reuse multiplicity in the one
    pass; stateless tiers see a plain probe of the union either way."""
    node_ids = np.asarray(node_ids)
    n = len(node_ids)
    assignment = np.full(n, -1, np.int8)
    unclaimed = np.ones(n, dtype=bool)
    for ti, tier in enumerate(tiers):
        idx = np.nonzero(unclaimed)[0]
        if len(idx) == 0:
            break
        if multiplicity is not None and hasattr(tier, "probe_merged"):
            hits = np.asarray(tier.probe_merged(
                node_ids[idx], multiplicity[idx]), dtype=bool)
        else:
            hits = np.asarray(tier.probe(node_ids[idx]), dtype=bool)
        took = idx[hits]
        assignment[took] = ti
        unclaimed[took] = False
    if unclaimed.any():
        raise RuntimeError(
            f"tier stack {[t.name for t in tiers]} left "
            f"{int(unclaimed.sum())} of {n} requests unserved — the stack "
            "must end in a storage backstop")
    # storage-bound requests carry the serving tier's shard decision; a
    # single-queue storage tier is shard 0, redirected requests stay -1
    shard = np.full(n, -1, np.int16)
    remote = None
    for ti, tier in enumerate(tiers):
        if tier.latency_class != "storage":
            continue
        m = assignment == ti
        if not m.any():
            continue
        if hasattr(tier, "shard_of"):
            shard[m] = tier.shard_of(node_ids[m])
            if hasattr(tier, "remote_mask"):
                # host-level backstop: stamp which requests the serving
                # host ships over its link (requester != server)
                if remote is None:
                    remote = np.zeros(n, bool)
                remote[m] = tier.remote_mask(node_ids[m], shard[m])
        else:
            shard[m] = 0
    return GatherPlan(node_ids=node_ids, assignment=assignment,
                      tiers=tuple(tiers), shard=shard, remote=remote)


def build_plan_merged(tiers: Sequence[Tier], unique_nodes: np.ndarray,
                      multiplicity: np.ndarray) -> GatherPlan:
    """Dedup-aware fold for a merged window — `build_plan` over the unique
    set with the window multiplicity.  Same partition guarantee."""
    return build_plan(tiers, unique_nodes, multiplicity=multiplicity)


def record_tier_metrics(tiers: Sequence[Tier], registry) -> None:
    """Fold the tier stack's cumulative cache telemetry into a
    MetricsRegistry (repro.obs): one ``tier.<name>.hit_ratio`` gauge per
    cache-bearing tier, per-tenant gauges for a partitioned tier.  The
    registry replaces ad-hoc ``loader.store.cache.stats`` spelunking —
    observation only, nothing here feeds back into probe or admission."""
    for tier in tiers:
        name = getattr(tier, "name", type(tier).__name__)
        stats = getattr(getattr(tier, "cache", None), "stats", None)
        if stats is not None and stats.accesses:
            registry.gauge(f"tier.{name}.hit_ratio").set(stats.hit_ratio)
            registry.gauge(f"tier.{name}.accesses").set(stats.accesses)
            registry.gauge(f"tier.{name}.evictions").set(stats.evictions)
        ratios = getattr(tier, "hit_ratios", None)
        if callable(ratios):
            for tenant, ratio in enumerate(ratios()):
                registry.gauge(
                    f"tier.{name}.tenant{tenant}.hit_ratio").set(ratio)
