"""GIDSDataLoader — the end-to-end data-preparation pipeline (paper Fig. 1).

Per training iteration the loader must deliver (sampled blocks, gathered
features).  Orchestration:

  * sampling runs `merge_depth` iterations AHEAD of training (decoupled —
    §3.2): a deque of pre-sampled batches doubles as the cache's window
    buffer and as the accumulator's outstanding-request pool;
  * the accumulator recomputes the merge depth from live telemetry
    (requests/iter, redirection rate);
  * feature gathers flow through the two-tier store (HBM cache + constant
    host buffer + storage);
  * the storage timeline simulator prices each batch (benchmarks); the
    actual bytes are returned for real training.

The same class drives the mmap/BaM baselines (Fig. 13/14) via `mode`:
  mode="mmap": CPU sampling, no cache, no cbuf, page-fault-priced storage
  mode="bam" : GPU-style sampling + plain cache (window=0), no cbuf
  mode="gids": everything on
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterator, Sequence

import numpy as np

from repro.graph.csr import CSRGraph
from repro.sampling.neighbor import host_sample_blocks, SampledBlocks
from repro.sampling.ladies import ladies_sample_blocks
from .accumulator import DynamicAccessAccumulator, AccumulatorConfig
from .constant_buffer import ConstantBuffer
from .feature_store import FeatureStore, GatherReport
from .software_cache import WindowBufferedCache
from .storage_sim import SSDSpec, StorageTimeline, INTEL_OPTANE


@dataclasses.dataclass
class LoaderConfig:
    batch_size: int = 4096
    fanouts: Sequence[int] = (10, 5, 5)       # 3 sampling layers (paper §4.1)
    sampler: str = "neighbor"                  # or "ladies"
    ladies_layer_sizes: Sequence[int] = (512, 512, 512)
    mode: str = "gids"                         # gids | bam | mmap
    window_depth: int = 8                      # paper default
    cache_lines: int = 1 << 15                 # 8GB @4KB in paper; scaled here
    cache_ways: int = 8
    cbuf_fraction: float = 0.1                 # 10% of dataset (paper default)
    cbuf_selection: str = "pagerank"
    target_efficiency: float = 0.95
    n_ssd: int = 1
    seed: int = 0


@dataclasses.dataclass
class Batch:
    blocks: SampledBlocks
    features: np.ndarray          # rows for blocks.all_nodes
    report: GatherReport
    prep_time_s: float            # modelled data-preparation time
    merge_depth: int


class GIDSDataLoader:
    def __init__(self, graph: CSRGraph, features: np.ndarray,
                 config: LoaderConfig | None = None,
                 ssd: SSDSpec = INTEL_OPTANE,
                 train_ids: np.ndarray | None = None):
        self.graph = graph
        self.config = cfg = config or LoaderConfig()
        self.rng = np.random.default_rng(cfg.seed)
        self.train_ids = (train_ids if train_ids is not None
                          else np.arange(graph.num_nodes))
        cache = None
        cbuf = None
        if cfg.mode in ("gids", "bam"):
            window = cfg.window_depth if cfg.mode == "gids" else 0
            cache = WindowBufferedCache(cfg.cache_lines, cfg.cache_ways,
                                        window_depth=window, seed=cfg.seed)
        if cfg.mode == "gids" and cfg.cbuf_fraction > 0:
            cbuf = ConstantBuffer.from_graph(graph, cfg.cbuf_fraction,
                                             selection=cfg.cbuf_selection,
                                             seed=cfg.seed)
        self.store = FeatureStore(features, cache=cache, constant_buffer=cbuf)
        self.accumulator = DynamicAccessAccumulator(
            ssd, AccumulatorConfig(target_efficiency=cfg.target_efficiency,
                                   n_ssd=cfg.n_ssd,
                                   max_merge_iters=max(cfg.window_depth, 8)))
        self.timeline = StorageTimeline(ssd, cfg.n_ssd)
        self._lookahead: deque[SampledBlocks] = deque()
        self._win_idx = 0   # lookahead entries already pushed to cache window
        self._requests_per_iter = 0

    # -- sampling -------------------------------------------------------------
    def _sample_one(self) -> SampledBlocks:
        cfg = self.config
        seeds = self.rng.choice(self.train_ids, size=cfg.batch_size,
                                replace=len(self.train_ids) < cfg.batch_size)
        if cfg.sampler == "neighbor":
            return host_sample_blocks(self.graph, seeds, cfg.fanouts, self.rng)
        elif cfg.sampler == "ladies":
            return ladies_sample_blocks(self.graph, seeds,
                                        cfg.ladies_layer_sizes, self.rng)
        raise ValueError(cfg.sampler)

    def _refill_lookahead(self) -> int:
        """Run sampling ahead until the accumulator's merge depth is covered
        (GIDS/BaM modes; mmap samples synchronously, depth 1)."""
        if self.config.mode == "mmap":
            depth = 1
        else:
            depth = self.accumulator.merge_depth(
                max(self._requests_per_iter, 1))
            depth = max(depth, self.config.window_depth
                        if self.config.mode == "gids" else 1)
        while len(self._lookahead) < depth:
            # snapshot the sampler PRNG before sampling so a checkpoint
            # resumes at the logical consumption point, not the sampling
            # frontier (the lookahead queue is rebuilt deterministically)
            snap = {"rng": self.rng.bit_generator.state,
                    "requests_per_iter": self._requests_per_iter}
            self._lookahead.append((snap, self._sample_one()))
        self._sync_window()
        return depth

    def _sync_window(self) -> None:
        """Keep the cache's window buffer = first `window_depth` lookahead
        entries.  The lookahead may run deeper than the window (accumulator
        merge depth > window depth); extra batches are sampled-ahead only."""
        cache = self.store.cache
        if cache is None or cache.window_depth == 0:
            return
        while (len(cache.window) < cache.window_depth
               and self._win_idx < len(self._lookahead)):
            self.store.push_window(
                self._lookahead[self._win_idx][1].all_nodes)
            self._win_idx += 1

    # -- iteration -------------------------------------------------------------
    def __iter__(self) -> Iterator[Batch]:
        while True:
            yield self.next_batch()

    def next_batch(self) -> Batch:
        depth = self._refill_lookahead()
        _, blocks = self._lookahead.popleft()
        self._win_idx = max(0, self._win_idx - 1)
        self._requests_per_iter = blocks.num_requests
        rows, report = self.store.gather(blocks.all_nodes)
        self.accumulator.update(report.n_requests, report.redirected)

        outstanding = self.accumulator.outstanding(blocks.num_requests)
        if self.config.mode == "mmap":
            # page-cache hit means the row was touched recently: approximate
            # with the cbuf-free, cache-free split — everything is storage on
            # first touch; the timeline prices fault overheads.
            t = self.timeline.mmap_batch_time(
                n_storage=report.n_storage + report.n_host_hits
                + report.n_hbm_hits,
                n_page_cache=0, feat_bytes=report.feat_bytes)
        else:
            t = self.timeline.gids_batch_time(
                n_storage=report.n_storage, n_host=report.n_host_hits,
                n_hbm=report.n_hbm_hits, feat_bytes=report.feat_bytes,
                outstanding=outstanding)
        return Batch(blocks=blocks, features=rows, report=report,
                     prep_time_s=t, merge_depth=depth)

    # -- state for checkpoint/restart (fault tolerance) -----------------------
    def state_dict(self) -> dict:
        if self._lookahead:
            return dict(self._lookahead[0][0])
        return {"rng": self.rng.bit_generator.state,
                "requests_per_iter": self._requests_per_iter}

    def load_state_dict(self, state: dict) -> None:
        self.rng.bit_generator.state = state["rng"]
        self._requests_per_iter = state["requests_per_iter"]
        self._lookahead.clear()
        self._win_idx = 0
        if self.store.cache is not None:
            self.store.cache.window.clear()
