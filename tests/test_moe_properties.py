"""Property tests for the capacity-based MoE dispatch (hypothesis)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models import layers as L
from repro.models.common import ModelConfig, init_params


def _cfg(E, k, cf, D=16, F=32):
    return ModelConfig(name="t", family="moe", num_layers=1, d_model=D,
                       num_heads=2, num_kv_heads=2, d_ff=F, vocab_size=64,
                       moe_experts=E, moe_top_k=k, moe_capacity_factor=cf,
                       param_dtype=jnp.float32, compute_dtype=jnp.float32)


def _dense_reference(p, x, cfg):
    """Ground truth: route every token to its top-k experts, no capacity."""
    B, S, D = x.shape
    xf = x.reshape(-1, D)
    logits = (xf @ p["router"].astype(xf.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gates, sel = jax.lax.top_k(probs, cfg.moe_top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    out = jnp.zeros_like(xf)
    for e in range(cfg.moe_experts):
        h = jax.nn.silu(xf @ p["w1"][e]) * (xf @ p["w3"][e])
        ye = h @ p["w2"][e]
        w = (gates * (sel == e)).sum(-1)[:, None]
        out = out + w * ye
    return out.reshape(B, S, D)


@given(seed=st.integers(0, 100), E=st.sampled_from([2, 4, 8]),
       k=st.sampled_from([1, 2]))
@settings(max_examples=12, deadline=None)
def test_lossless_capacity_matches_dense_routing(seed, E, k):
    cfg = _cfg(E, k, cf=1000.0)           # capacity >> tokens: no drops
    p = init_params(L.moe_defs(cfg), jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 8, 16))
    got = L.moe_block(p, x, cfg)
    want = _dense_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@given(seed=st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_dropped_tokens_output_zero_not_garbage(seed):
    """With capacity 0-ish every token is dropped: output must be exactly
    the shared/dense contribution (here: zero), never stale buffer rows."""
    cfg = _cfg(E=4, k=1, cf=1e-9)
    p = init_params(L.moe_defs(cfg), jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 16, 16))
    out = L.moe_block(p, x, cfg)
    # capacity is floored at 8 slots per expert -> at most 32 of 32 tokens
    # may fit; make tokens >> capacity instead
    cfg2 = _cfg(E=2, k=1, cf=1e-9)
    p2 = init_params(L.moe_defs(cfg2), jax.random.PRNGKey(seed))
    x2 = jax.random.normal(jax.random.PRNGKey(seed + 2), (8, 32, 16))
    out2 = L.moe_block(p2, x2, cfg2)          # 256 tokens, 16 slots
    dense = _dense_reference(p2, x2, cfg2)
    # every token's output is either its exact dense-routing value (kept)
    # or exactly zero (dropped)
    flat_o = np.asarray(out2).reshape(-1, 16)
    flat_d = np.asarray(dense).reshape(-1, 16)
    kept = np.abs(flat_o).sum(-1) > 1e-9
    np.testing.assert_allclose(flat_o[kept], flat_d[kept],
                               rtol=2e-4, atol=2e-4)
    assert kept.sum() <= 2 * 8 + 1            # <= total capacity
    assert (~kept).any()                      # drops actually happened


def test_aux_loss_balanced_router_is_minimal():
    cfg = _cfg(E=4, k=1, cf=1.25)
    p = init_params(L.moe_defs(cfg), jax.random.PRNGKey(0))
    # uniform router -> aux loss == E * E * (1/E * 1/E) ... == 1.0 exactly
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 16))
    aux = L.moe_aux_loss(p, x, cfg)
    assert float(aux) >= 1.0 - 1e-3           # 1.0 is the balanced floor
