"""Dynamic storage access accumulator (paper §3.2).

The accumulator exploits the logical independence of (sampling, aggregation)
from the training stage: it runs sampling *ahead* of training and merges the
storage requests of consecutive mini-batch data preparations until the number
of outstanding storage accesses crosses the analytic threshold (Eq. 2-3)
needed to hit the target fraction of peak SSD throughput.

Redirected accesses (GPU-cache hits, constant-buffer hits) do not occupy SSD
queue slots, so the controller tracks the measured redirection rate and
re-inflates the merge depth accordingly — this is the "dynamic" part.

TPU adaptation: "outstanding storage accesses" become outstanding prefetch
requests in the host->device staging pipeline; the same Little's-law model
applies with the staging link's latency/throughput constants, and the merge
depth doubles as the dispatch-ahead depth of the async pipeline.
"""
from __future__ import annotations

import dataclasses

from .storage_sim import SSDSpec, required_accesses


@dataclasses.dataclass
class AccumulatorConfig:
    target_efficiency: float = 0.95
    n_ssd: int = 1
    max_merge_iters: int = 16       # buffer-memory guard (paper: "excessive
                                    # buffer memory usage" bound)
    ema: float = 0.9                # smoothing for the redirection estimate


class DynamicAccessAccumulator:
    """Decides how many future iterations' sampling to merge.

    update(n_sampled, n_redirected) feeds per-iteration telemetry;
    merge_depth(requests_per_iter) returns the number of iterations whose
    data preparation should be in flight simultaneously.
    """

    def __init__(self, spec: SSDSpec, config: AccumulatorConfig | None = None):
        self.spec = spec
        self.config = config or AccumulatorConfig()
        self.threshold = required_accesses(
            spec, self.config.target_efficiency, self.config.n_ssd)
        self._redirect_rate = 0.0

    # -- telemetry ----------------------------------------------------------
    def update(self, n_sampled: int, n_redirected: int) -> None:
        if n_sampled <= 0:
            return
        r = n_redirected / n_sampled
        a = self.config.ema
        self._redirect_rate = a * self._redirect_rate + (1 - a) * r

    @property
    def redirect_rate(self) -> float:
        return self._redirect_rate

    def reset_telemetry(self) -> None:
        """Drop the redirection-rate EMA back to the fresh-accumulator state.
        Checkpoint resume calls this so a restored loader and a freshly-built
        loader make bit-identical merge-depth decisions."""
        self._redirect_rate = 0.0

    # -- policy --------------------------------------------------------------
    def storage_fraction(self) -> float:
        return max(1.0 - self._redirect_rate, 1e-3)

    def merge_depth(self, requests_per_iter: int) -> int:
        """Iterations to merge so that outstanding *storage-bound* requests
        >= threshold: depth * requests * (1 - redirect_rate) >= N_access."""
        if requests_per_iter <= 0:
            return 1
        eff_per_iter = requests_per_iter * self.storage_fraction()
        depth = int(-(-self.threshold // max(eff_per_iter, 1.0)))  # ceil
        return max(1, min(depth, self.config.max_merge_iters))

    def outstanding(self, requests_per_iter: int) -> int:
        d = self.merge_depth(requests_per_iter)
        return int(d * requests_per_iter * self.storage_fraction())
