"""Step builders: training (grad + optimizer, optional microbatch
accumulation) and serving (prefill / decode).  Pure functions suitable for
pjit with explicit in/out shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.train import optimizer as opt_lib
from repro.train.optimizer import OptimizerConfig


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: OptimizerConfig = OptimizerConfig()
    microbatches: int = 1
    schedule: Callable = staticmethod(lambda step: 3e-4)


def make_train_step(model, tcfg: TrainConfig):
    ocfg = tcfg.optimizer

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(params, opt_state, batch):
        if tcfg.microbatches > 1:
            m = tcfg.microbatches

            def micro(carry, mb):
                acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / m, acc, grads)
                return acc, loss

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mbatch = jax.tree.map(
                lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]),
                batch)
            grads, losses = jax.lax.scan(micro, zeros, mbatch)
            loss = losses.mean()
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        lr = tcfg.schedule(opt_state.step)
        new_params, new_state, gn = opt_lib.update(
            grads, opt_state, params, ocfg, lr)
        metrics = {"loss": loss, "grad_norm": gn, "lr": lr}
        return new_params, new_state, metrics

    return train_step


def make_prefill_step(model):
    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache)
    return prefill_step


def make_decode_step(model):
    def decode_step(params, token, cache, index):
        logits, new_cache = model.decode_step(params, token, cache, index)
        next_token = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_token[:, None], new_cache
    return decode_step
