"""minicpm-2b [dense] — 40L d_model=2304 36H (MHA kv=36) d_ff=5760
vocab=122753; WSD schedule, mup-style depth-scaled residuals
(scale_depth=1.4 -> residual_scale = 1.4/sqrt(40)), embedding scale 12.
[arXiv:2404.06395; hf]
"""
import dataclasses
import math
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm-2b", family="dense",
        num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36,
        d_ff=5760, vocab_size=122753,
        residual_scale=1.4 / math.sqrt(40), embed_scale=12.0,
        tie_embeddings=True,
    )


def reduced_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512, vocab_pad_to=64,
        residual_scale=1.4 / math.sqrt(3), remat=False)
