"""Serve-plane load sweep: goodput and tail latency vs offered load, and
noisy-tenant isolation — the two headline claims of the online inference
subsystem (serve/gnn_engine.py).

Experiment 1 — deadline-bounded merged admission vs per-request execution.
A two-tenant stream (steady Poisson + bursty MMPP, heavy-tail fanouts,
hot-set skew) is swept over offered load in both execution modes.  A load
point is SUSTAINED when p99 latency stays under the fixed target
(1.1x the SLO deadline; the batcher deliberately spends slack, so p99
rides just under the deadline by design) AND SLO attainment — the fraction
of OFFERED requests that complete within deadline, shed included — stays
over 95%.  The headline is the largest measured offered load on the sweep
grid below which every point is sustained (a frontier, so one lucky
overloaded point cannot win).  Merged admission amortizes the forward
launch and coalesces storage lines across requests, so it sustains a
strictly higher rate; the per-request baseline burns a full launch + an
un-coalesced burst per request and collapses early.

Experiment 2 — per-tenant cache partitioning under an adversarial tenant.
Two colocated datasets (`graph.csr.disjoint_union`): a victim with a tight
deadline and a hot-set-skewed workload on an r-mat component, and a noisy
tenant sweeping a hub-free uniform component (worst case for caching: its
fills are pure eviction pressure, never reuse).  Victim p99 is compared
across victim-alone, shared cache, and tenant-partitioned cache with a
priced 3:1 quota (the victim pays for reserved lines).  Partitioning keeps
the victim's hot set resident — the noisy tenant cannot evict another
tenant's partition — so the victim's p99 degradation vs running alone is
strictly smaller than under the shared cache.

Everything is virtual-time and deterministic: identical numbers on every
run, so the CI gates compare exactly.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row
from repro.graph.csr import disjoint_union
from repro.graph.synthetic import rmat_graph, uniform_graph
from repro.serve import (GNNServeConfig, GNNServeEngine, TenantSpec,
                         generate_stream)

DEADLINE_S = 3e-3
P99_TARGET_S = 1.1 * DEADLINE_S
ATTAINMENT_FLOOR = 0.95
LOAD_GRID_QPS = (2000, 4000, 8000, 16000, 24000, 32000)
N_REQUESTS = 400

SWEEP_TENANTS = (
    TenantSpec("steady", rate_share=1.0, hot_fraction=0.03, hot_prob=0.9,
               mean_seeds=4, deadline_s=DEADLINE_S, arrival="poisson"),
    TenantSpec("bursty", rate_share=1.0, hot_fraction=0.5, hot_prob=0.2,
               mean_seeds=8, deadline_s=DEADLINE_S, arrival="mmpp",
               burst_factor=8.0, burst_fraction=0.1, burst_cycle_s=0.02),
)

VICTIM_DEADLINE_S = 1.5e-3
ISO_QPS = 2000
ISO_REQUESTS = 600
ISO_QUOTAS = (3.0, 1.0)         # victim pays for 3/4 of the cache lines


def _clone(requests):
    # engines mutate nothing, but replays across modes must not share arrays
    return [type(r)(r.rid, r.tenant, r.arrival_s, r.seeds.copy(),
                    r.deadline_s) for r in requests]


def _serve(graph, feats, requests, **cfg_kw):
    engine = GNNServeEngine(graph, feats, GNNServeConfig(seed=3, **cfg_kw))
    return engine.run(_clone(requests)), engine


def load_curves(n_requests: int = N_REQUESTS,
                grid=LOAD_GRID_QPS) -> list[dict]:
    """Sweep offered load in both modes; one result dict per (load, mode)."""
    graph = rmat_graph(20_000, 12, 64, seed=7)
    feats = np.random.default_rng(0).standard_normal(
        (graph.num_nodes, 64)).astype(np.float32)
    out = []
    for qps in grid:
        requests = generate_stream(graph.num_nodes, SWEEP_TENANTS, qps,
                                   n_requests, seed=11)
        for merged in (True, False):
            res, _ = _serve(graph, feats, requests, merged=merged, tenants=2)
            met = sum(r.deadline_met for r in res.records)
            attainment = met / len(res.records)
            p99 = res.p99_s()
            out.append({
                "mode": "merged" if merged else "per_request",
                "nominal_qps": qps,
                "offered_qps": res.offered_qps(),
                "p99_s": p99,
                "p50_s": res.p50_s(),
                "attainment": attainment,
                "goodput_qps": res.goodput_qps(),
                "mean_window": res.mean_window,
                "breakdown_s": res.mean_breakdown_s(),
                "sustained": (p99 <= P99_TARGET_S
                              and attainment >= ATTAINMENT_FLOOR),
            })
    return out


def sustainable_qps(curves: list[dict], mode: str) -> float:
    """Largest measured offered load whose whole grid prefix is sustained."""
    best = 0.0
    for point in (c for c in curves if c["mode"] == mode):
        if not point["sustained"]:
            break
        best = point["offered_qps"]
    return best


def _isolation_tenants(with_noisy: bool):
    victim = TenantSpec(
        "victim", rate_share=1.0, hot_fraction=0.02, hot_prob=0.95,
        mean_seeds=10, deadline_s=VICTIM_DEADLINE_S, arrival="poisson",
        node_range=(0, 10_000))
    if not with_noisy:
        return (victim,)
    noisy = TenantSpec(
        "noisy", rate_share=1.0, hot_fraction=0.9, hot_prob=0.0,
        mean_seeds=8, deadline_s=8e-3, arrival="mmpp", burst_factor=8.0,
        burst_fraction=0.1, burst_cycle_s=0.02, node_range=(10_000, 20_000))
    return (victim, noisy)


def isolation(n_requests: int = ISO_REQUESTS) -> dict:
    """Victim p99 alone vs colocated-with-noisy on shared vs partitioned
    cache.  1 KiB feature rows (one per 4 KiB storage line) make the gather
    burst — the thing the cache protects — a first-order latency term."""
    graph = disjoint_union([rmat_graph(10_000, 12, 1024, seed=7),
                            uniform_graph(10_000, 12, 1024, seed=8)],
                           name="colocated")
    feats = np.random.default_rng(0).standard_normal(
        (graph.num_nodes, 1024)).astype(np.float32)
    alone = generate_stream(graph.num_nodes, _isolation_tenants(False),
                            ISO_QPS / 2, n_requests // 2, seed=11)
    both = generate_stream(graph.num_nodes, _isolation_tenants(True),
                           ISO_QPS, n_requests, seed=11)

    res_alone, _ = _serve(graph, feats, alone, merged=True, tenants=1,
                          data_plane="serve-gnn-shared")
    res_shared, _ = _serve(graph, feats, both, merged=True, tenants=2,
                           data_plane="serve-gnn-shared")
    res_part, engine = _serve(graph, feats, both, merged=True, tenants=2,
                              data_plane="serve-gnn",
                              tenant_quotas=ISO_QUOTAS)
    p99_alone = res_alone.p99_s(tenant=0)
    p99_shared = res_shared.p99_s(tenant=0)
    p99_part = res_part.p99_s(tenant=0)
    return {
        "victim_p99_alone_s": p99_alone,
        "victim_p99_shared_s": p99_shared,
        "victim_p99_partitioned_s": p99_part,
        "victim_degradation_shared": p99_shared / p99_alone,
        "victim_degradation_partitioned": p99_part / p99_alone,
        "victim_hit_ratio_partitioned": engine._tenant_tier.hit_ratio(0),
        "noisy_hit_ratio_partitioned": engine._tenant_tier.hit_ratio(1),
    }


def headline() -> dict:
    curves = load_curves()
    iso = isolation()
    merged_max = sustainable_qps(curves, "merged")
    per_request_max = sustainable_qps(curves, "per_request")
    peak = {m: max(c["goodput_qps"] for c in curves if c["mode"] == m)
            for m in ("merged", "per_request")}
    return {
        "deadline_s": DEADLINE_S,
        "p99_target_s": P99_TARGET_S,
        "attainment_floor": ATTAINMENT_FLOOR,
        "merged_max_qps": merged_max,
        "per_request_max_qps": per_request_max,
        "sustainable_qps_ratio": merged_max / max(per_request_max, 1e-9),
        "merged_peak_goodput_qps": peak["merged"],
        "per_request_peak_goodput_qps": peak["per_request"],
        **iso,
    }


def main() -> None:
    curves = load_curves()
    for c in curves:
        bd = c["breakdown_s"]
        row(f"fig_serve_load_{c['mode']}_{c['nominal_qps']}",
            c["p99_s"] * 1e6,
            f"offered={c['offered_qps']:,.0f}_goodput="
            f"{c['goodput_qps']:,.0f}_att={c['attainment']*100:.1f}%"
            f"_win={c['mean_window']:.1f}"
            f"_wait_us={bd['queue_wait_s']*1e6:.0f}"
            f"_gather_us={bd['gather_s']*1e6:.0f}"
            f"_{'OK' if c['sustained'] else 'over'}")
    merged_max = sustainable_qps(curves, "merged")
    per_request_max = sustainable_qps(curves, "per_request")
    row("fig_serve_load_sustainable", 0.0,
        f"merged={merged_max:,.0f}qps_per_request={per_request_max:,.0f}qps"
        f"_ratio={merged_max / max(per_request_max, 1e-9):.2f}x")
    iso = isolation()
    row("fig_serve_isolation", iso["victim_p99_partitioned_s"] * 1e6,
        f"alone_p99_ms={iso['victim_p99_alone_s']*1e3:.2f}"
        f"_shared_p99_ms={iso['victim_p99_shared_s']*1e3:.2f}"
        f"_partitioned_p99_ms={iso['victim_p99_partitioned_s']*1e3:.2f}"
        f"_victim_hit={iso['victim_hit_ratio_partitioned']:.3f}")


if __name__ == "__main__":
    main()
