"""Observability plane: span tracing, metrics registry, Perfetto export.

The one cross-cutting subsystem that sees all five data planes at once.
``Tracer`` records nested virtual (priced) spans and wall-clock stage
timings; ``MetricsRegistry`` replaces the scattered ``last_*`` telemetry
attributes; ``validate_trace`` is the CI schema gate.  Everything
defaults to :data:`NULL_TRACER`, which is bit- and price-invisible.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               NULL_METRICS, NullMetrics, Series)
from repro.obs.trace import (NULL_SPAN, NULL_TRACER, NullTracer, Span,
                             Tracer, attach_burst_spans)
from repro.obs.validate import validate_events, validate_trace, validate_tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "NULL_METRICS",
    "NullMetrics", "Series",
    "NULL_SPAN", "NULL_TRACER", "NullTracer", "Span", "Tracer",
    "attach_burst_spans",
    "validate_events", "validate_trace", "validate_tracer",
]
