"""Input ShapeDtypeStruct builders per (architecture x input-shape) cell.

Every stand-in is weak-type-correct and carries a NamedSharding, so
`jax.jit(step).lower(**specs)` infers all in_shardings without allocating a
byte.  The shape table is the assignment's:

    train_4k     seq 4096,    global_batch 256   -> train_step
    prefill_32k  seq 32768,   global_batch 32    -> prefill_step
    decode_32k   cache 32768, global_batch 128   -> decode_step (1 token)
    long_500k    cache 524288, global_batch 1    -> decode_step (1 token)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import batch_axes
from repro.models.common import (ModelConfig, abstract_params,
                                 sharding_rules)
from repro.models.transformer import LM
from repro.train import optimizer as opt_lib
from repro.train.optimizer import OptimizerConfig

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# archs whose attention is quadratic-full: long_500k is skipped
FULL_ATTENTION = {
    "llama4-maverick-400b-a17b", "arctic-480b", "minicpm-2b", "qwen3-14b",
    "qwen2-1.5b", "internvl2-1b", "whisper-small",
}


def cell_supported(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and cfg.name in FULL_ATTENTION:
        return False, "SKIP(full-attention)"
    return True, ""


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _divshard(dim: int, mesh: Mesh, axis: str):
    return axis if dim % mesh.shape[axis] == 0 else None


def batch_specs(cfg: ModelConfig, mesh: Mesh, batch: int, seq: int,
                multi_pod: bool) -> dict:
    """Training/prefill batch stand-ins."""
    ba = batch_axes(multi_pod)
    bsharded = ba if batch % int(np.prod([mesh.shape[a] for a in ba])) == 0 \
        else (ba[:-1] if batch % mesh.shape[ba[0]] == 0 else ())
    bspec = P(bsharded if bsharded else None)
    tok_seq = seq
    if cfg.frontend == "vision_stub":
        # patches occupy the first frontend_tokens positions of the
        # seq_len-long sequence (and of the serving cache)
        tok_seq = seq - cfg.frontend_tokens
    out = {
        "tokens": _sds((batch, tok_seq), jnp.int32, mesh, P(*bspec, None)),
        "labels": _sds((batch, tok_seq), jnp.int32, mesh, P(*bspec, None)),
    }
    if cfg.family == "encdec":
        out["frames"] = _sds((batch, cfg.encoder_seq, cfg.d_model),
                             jnp.float32, mesh, P(*bspec, None, None))
    if cfg.frontend == "vision_stub":
        out["patches"] = _sds((batch, cfg.frontend_tokens, cfg.d_model),
                              jnp.float32, mesh, P(*bspec, None, None))
    return out


def cache_pspec_tree(model: LM, mesh: Mesh, batch: int, seq: int,
                     multi_pod: bool, kind: str = "decode"):
    """PartitionSpecs for the serving cache.

    prefill: sequence dim over the model axis (the prompt write covers the
    full range, so the dynamic-update-slice is a plain copy).
    decode:  the per-token write is a dynamic-update-slice at a runtime
    index — along a sharded dim XLA must all-gather the WHOLE cache per
    token (measured 4 GiB/token/layer-pair on llama4).  Decode caches
    therefore shard kv-heads when divisible, else head_dim (always
    16-divisible in the zoo: 128/80/256/64); the score contraction then
    lowers to a tiny partial-sum all-reduce.
    """
    cfg = model.cfg
    ba = batch_axes(multi_pod)
    bsz = int(np.prod([mesh.shape[a] for a in ba]))
    bspec: Any = ba if batch % bsz == 0 else (
        ba[0] if batch % mesh.shape[ba[0]] == 0 else None)
    # both phases sequence-shard the cache over the model axis: prefill's
    # full-range write is a plain copy; decode writes via a one-hot mask
    # (elementwise over the sharded dim) and computes distributed softmax
    # (shard-local max/sum + tiny all-reduce).  See layers.attention.
    dims = (_divshard(seq, mesh, "model"), None, None)

    specs = []
    for si, (kinds, n) in enumerate(model.plan):
        group = {}
        for i, kind_i in enumerate(kinds):
            if kind_i in ("attn_dense", "attn_moe", "attn_local"):
                group[f"b{i}"] = {"k": P(None, bspec, *dims),
                                  "v": P(None, bspec, *dims)}
            elif kind_i == "dec":
                group[f"b{i}"] = {"k": P(None, bspec, *dims),
                                  "v": P(None, bspec, *dims),
                                  "xk": P(None, bspec, None, None, None),
                                  "xv": P(None, bspec, None, None, None)}
            elif kind_i == "rec":
                W = cfg.lru_width or cfg.d_model
                w = _divshard(W, mesh, "model")
                group[f"b{i}"] = {"conv": P(None, bspec, None, w),
                                  "h": P(None, bspec, w)}
            elif kind_i == "ssm":
                hshard = _divshard(cfg.ssm_heads, mesh, "model")
                group[f"b{i}"] = {"conv": P(None, bspec, None, None),
                                  "h": P(None, bspec, hshard, None, None)}
        specs.append(group)
    return specs


def abstract_cache(model: LM, mesh: Mesh, batch: int, seq: int,
                   multi_pod: bool, kind: str = "decode"):
    shapes = jax.eval_shape(lambda: model.init_cache(batch, seq))
    pspecs = cache_pspec_tree(model, mesh, batch, seq, multi_pod, kind)

    def attach(sds, spec):
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                    sharding=NamedSharding(mesh, spec))

    return jax.tree.map(attach, shapes, pspecs)


def activation_specs(cfg: ModelConfig, mesh: Mesh, multi_pod: bool,
                     batch: int | None = None, kind: str = "train",
                     expert_axis: str = "model") -> dict:
    ba = batch_axes(multi_pod)
    e_ax = _divshard(cfg.moe_experts or 1, mesh, expert_axis)
    e_ax = expert_axis if e_ax else None
    if cfg.moe_2d_dispatch and kind in ("decode", "prefill"):
        # serving: keep expert weights stationary (2D-sharded E x D); the
        # dispatch activations shard their d_model dim over the data axis
        # so the expert matmul produces partial sums + a tiny activation
        # all-reduce instead of re-gathering 100s of GB of weights per
        # token (measured 35 GB/device/token on llama4 decode).
        especs = P(e_ax, None, _divshard(cfg.d_model, mesh, "data"))
    else:
        especs = P(e_ax, None, None)
    # attention core: batch-parallel on the data axes (head-agnostic TP —
    # see layers.attention).  Splitting batch over the model axis too was
    # tried and REFUTED: XLA cannot reshard the 5-D score tensors between
    # the 256-way and (16,8,..,2) layouts and falls back to involuntary
    # full rematerialisation (~2 TiB/layer of collectives); see
    # EXPERIMENTS.md §Perf iteration 2.
    attn_axes = list(ba)
    if batch is not None:
        size = int(np.prod([mesh.shape[a] for a in ba]))
        if batch % size:
            attn_axes = [a for a in ba if batch % mesh.shape[a] == 0][:1]
    aspec = P(tuple(attn_axes) if attn_axes else None, None, None, None)
    if kind == "decode":
        # decode: five constraint/layout hypotheses measured WORSE than
        # XLA's own propagation (0.70 -> 2.1-5.1 s/token on llama4; see
        # EXPERIMENTS.md §Perf cell 3) — leave the partitioner alone.
        return {"activations": NamedSharding(mesh, P(ba, None, None))}
    return {
        "activations": NamedSharding(mesh, P(ba, None, None)),
        "moe_dispatch": NamedSharding(mesh, especs),
        "attn_act": NamedSharding(mesh, aspec),
        "attn_scores": NamedSharding(
            mesh, P(tuple(attn_axes) if attn_axes else None,
                    None, None, None, None)),
        # decode: key dim stays sequence-sharded on the model axis
        "attn_scores_decode": NamedSharding(
            mesh, P(tuple(attn_axes) if attn_axes else None,
                    None, None, None, "model")),
        # out feeds the row-parallel wo: batch on data axes, fused dim on
        # model (the model axis moves from batch back to the hidden dim)
        "attn_out": NamedSharding(mesh, P(ba, None, "model")),
    }


def optimizer_for(cfg: ModelConfig) -> OptimizerConfig:
    # Adafactor for the behemoth MoEs (§DESIGN: 4 B/param state vs 12),
    # AdamW elsewhere.
    if cfg.moe_experts:
        return OptimizerConfig(name="adafactor")
    return OptimizerConfig(name="adamw")


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    cfg: ModelConfig
    model: LM
    kind: str
    abstract_args: tuple            # positional args for the step fn
    step_fn: Any
    rules: dict


def build_cell(arch: str, shape: str, mesh: Mesh, *, multi_pod: bool,
               strategy: str | None = None,
               overrides: dict | None = None) -> Cell:
    import repro.configs as configs
    from repro.train.steps import (TrainConfig, make_decode_step,
                                   make_prefill_step, make_train_step)

    cfg = configs.get(arch)
    microbatches = 1
    if overrides:
        overrides = dict(overrides)
        microbatches = overrides.pop("microbatches", 1)
        cfg = dataclasses.replace(cfg, **overrides)
    spec = SHAPES[shape]
    kind, seq, batch = spec["kind"], spec["seq"], spec["batch"]
    model = LM(cfg)
    if strategy is None:
        # the 400B-class models cannot fit TP-only; everything else TP
        strategy = "fsdp_tp" if cfg.moe_experts else "tp"
    rules = sharding_rules(strategy, multi_pod)
    params = abstract_params(model.param_defs(), rules, mesh)

    if kind == "train":
        ocfg = optimizer_for(cfg)
        opt_state = opt_lib.abstract_state(ocfg.name, params, ocfg)
        # attach shardings to optimizer state
        pspecs = jax.tree.map(lambda a: a.sharding.spec, params)
        shapes_tree = jax.tree.map(lambda a: a.shape, params)
        ospecs = opt_lib.opt_state_pspecs(ocfg.name, shapes_tree, pspecs,
                                          mesh, zero1=True)
        opt_state = jax.tree.map(
            lambda s, sp: jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
            opt_state, ospecs)
        batch_abs = batch_specs(cfg, mesh, batch, seq, multi_pod)
        step = make_train_step(model, TrainConfig(optimizer=ocfg,
                                                  microbatches=microbatches))
        args = (params, opt_state, batch_abs)
    elif kind == "prefill":
        batch_abs = batch_specs(cfg, mesh, batch, seq, multi_pod)
        batch_abs.pop("labels")
        cache = abstract_cache(model, mesh, batch, seq, multi_pod,
                               kind="prefill")
        step = make_prefill_step(model)
        args = (params, batch_abs, cache)
    else:  # decode
        ba = batch_axes(multi_pod)
        bsz = int(np.prod([mesh.shape[a] for a in ba]))
        bspec = P(ba if batch % bsz == 0 else None)
        token = _sds((batch, 1), jnp.int32, mesh, P(*bspec, None))
        cache = abstract_cache(model, mesh, batch, seq, multi_pod)
        index = jax.ShapeDtypeStruct((), jnp.int32,
                                     sharding=NamedSharding(mesh, P()))
        step = make_decode_step(model)
        args = (params, token, cache, index)
    return Cell(arch=arch, shape=shape, cfg=cfg, model=model, kind=kind,
                abstract_args=args, step_fn=step, rules=rules)
