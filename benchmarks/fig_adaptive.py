"""Adaptive data plane — feedback-driven placement, priced migration, and
online cache re-partitioning (core/feedback.py) under workload drift.

Static placement policies (core/sharding.py) are priced once, at load
time, from the degree profile.  When the *measured* access distribution
drifts away from that prior — hot sets rotating across epochs, a freshly
ingested region going hot, one tenant's working set growing — a static
table leaves one shard queue draining long after the others.  The
adaptive loop closes this: a TouchTable EMA of measured per-node touches
feeds ShardRebalancer, which re-deals the measured-hot nodes and commits
only when the priced saving (per-batch straggler gap × amortization
horizon) exceeds the priced migration burst, whose cost is then amortized
into subsequent batches.  The same loop re-admits measured-hot edge pages
into topology budgets (TopologyRefresher) and re-partitions per-tenant
cache quotas online (QuotaController).

Five scenarios, every number net of priced migration IOs:

  * rotation (GATED): the adversarial drift — each epoch's hot set is
    exactly one shard of the static degree table, the cache (512 lines)
    cannot absorb the ~2.5k-node hot set, so static placement drains one
    queue while three idle.  Adaptive must win end-to-end
    (`adaptive_vs_degree_speedup >= 1.0` in CI).
  * static control (GATED): uniform workload, no drift.  Adaptive must be
    BIT-IDENTICAL to degree — same prep floats, same feature bytes, zero
    migrations — because its initial table is the degree deal and the
    economics gate never fires without imbalance.
  * growth (reported): a contiguous "newly ingested" id range goes hot
    each epoch.  Degree striping spreads contiguous ranges roughly
    evenly, so there is little to win; the interesting claim is that
    adaptive does not churn (few/no migrations, ~1.0x).
  * topology (reported): quarter-rotation over `gids-topo`; adaptive
    admission promotes measured-hot edge pages within fixed GPU/host
    budgets.  Sampled blocks stay bit-identical (re-admission moves
    pages between tiers, never changes the graph); sampling gets faster.
  * serve quota (reported): two tenants with a 30:1 hot-set-size ratio
    under equal initial quotas; QuotaController shifts lines toward the
    measured-miss-heavy tenant.

Everything is virtual-time and deterministic: identical numbers on every
run, so the CI gates compare exactly.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row
from repro.core import GIDSDataLoader, LoaderConfig, make_placement
from repro.graph.synthetic import rmat_graph
from repro.serve import (GNNServeConfig, GNNServeEngine, TenantSpec,
                         generate_stream)

N_SHARDS = 4
EPOCHS = 4
ROT_BATCHES = 64          # per epoch; 512 cache lines << ~2.5k-node hot set
STATIC_BATCHES = 24
TOPO_BATCHES = 32


def _graph_and_feats(num_nodes: int = 10_000):
    g = rmat_graph(num_nodes, 12, 16, seed=1)
    feats = np.random.default_rng(0).standard_normal(
        (g.num_nodes, 64)).astype(np.float32)
    return g, feats


def _sharded_cfg(placement: str, **over) -> LoaderConfig:
    kw = dict(batch_size=256, fanouts=(2,), data_plane="gids-merged-sharded",
              cache_lines=512, window_depth=4, n_shards=N_SHARDS,
              placement=placement, seed=7, rebalance_interval=4,
              migration_horizon=64)
    kw.update(over)
    return LoaderConfig(**kw)


def _drift_run(g, feats, placement: str, hot_sets, batches: int,
               **over) -> dict:
    """Train EPOCHS epochs, re-pointing train_ids at hot_sets[epoch] each
    epoch; returns total exposed prep (migration charges included) plus
    the migration ledger."""
    dl = GIDSDataLoader(g, feats, _sharded_cfg(placement, **over))
    prep = 0.0
    for epoch in range(EPOCHS):
        dl.train_ids = hot_sets[epoch % len(hot_sets)]
        for _ in range(batches):
            prep += dl.next_batch().exposed_prep_s
    reb = dl.rebalancer
    return {
        "exposed_prep_s": prep,
        "n_migrations": reb.n_migrations if reb else 0,
        "migration_cost_s": reb.total_migration_cost_s if reb else 0.0,
        "events": list(reb.events) if reb else [],
    }


def rotation() -> dict:
    """Adversarial hot-set rotation: epoch e trains exactly the nodes the
    static degree table assigns to shard e, so static placement serializes
    on one queue.  The CI-gated headline."""
    g, feats = _graph_and_feats()
    table = make_placement("degree", N_SHARDS,
                           degrees=np.diff(g.indptr)).table
    hot = [np.nonzero(table == s)[0] for s in range(N_SHARDS)]
    res = {pol: _drift_run(g, feats, pol, hot, ROT_BATCHES)
           for pol in ("degree", "adaptive")}
    return {
        "degree_prep_s": res["degree"]["exposed_prep_s"],
        "adaptive_prep_s": res["adaptive"]["exposed_prep_s"],
        "speedup": (res["degree"]["exposed_prep_s"]
                    / max(res["adaptive"]["exposed_prep_s"], 1e-12)),
        "n_migrations": res["adaptive"]["n_migrations"],
        "migration_cost_s": res["adaptive"]["migration_cost_s"],
        "events": res["adaptive"]["events"],
    }


def static_control() -> dict:
    """No drift → adaptive must be a zero-cost no-op: bit-identical
    batches, float-equal prep, zero migrations."""
    g, feats = _graph_and_feats()
    outs = {}
    migrations = 0
    for pol in ("degree", "adaptive"):
        dl = GIDSDataLoader(g, feats, _sharded_cfg(pol, cache_lines=2048))
        outs[pol] = [dl.next_batch() for _ in range(STATIC_BATCHES)]
        if pol == "adaptive":
            migrations = dl.rebalancer.n_migrations
    identical = migrations == 0 and all(
        a.prep_time_s == b.prep_time_s and np.array_equal(
            a.features, b.features)
        for a, b in zip(outs["degree"], outs["adaptive"]))
    return {"bit_identical": identical, "n_migrations": migrations}


def growth() -> dict:
    """Graph-growth drift: each epoch a fresh contiguous id range (the
    "newly ingested" region) goes hot.  Degree striping already spreads
    id ranges across shards, so the claim is non-churn, not speedup."""
    g, feats = _graph_and_feats()
    hot = [q for q in np.array_split(np.arange(g.num_nodes), EPOCHS)]
    res = {pol: _drift_run(g, feats, pol, hot, ROT_BATCHES)
           for pol in ("degree", "adaptive")}
    return {
        "speedup": (res["degree"]["exposed_prep_s"]
                    / max(res["adaptive"]["exposed_prep_s"], 1e-12)),
        "n_migrations": res["adaptive"]["n_migrations"],
        "migration_cost_s": res["adaptive"]["migration_cost_s"],
    }


def topology() -> dict:
    """Quarter-rotation over the tiered topology plane: adaptive admission
    re-fills the same GPU/host page budgets from measured touches.  The
    sampled blocks must stay bit-identical — only page *placement* moves."""
    g, feats = _graph_and_feats()
    quarters = np.array_split(np.arange(g.num_nodes), EPOCHS)
    totals, streams, refreshes = {}, {}, []
    for adm in ("degree", "adaptive"):
        dl = GIDSDataLoader(g, feats, LoaderConfig(
            batch_size=256, fanouts=(5, 3), data_plane="gids-topo",
            cache_lines=2048, topo_admission=adm, topo_gpu_fraction=0.05,
            topo_host_fraction=0.25, seed=7, rebalance_interval=4,
            migration_horizon=64))
        sample, sig = 0.0, []
        for epoch in range(EPOCHS):
            dl.train_ids = quarters[epoch % EPOCHS]
            for _ in range(TOPO_BATCHES):
                b = dl.next_batch()
                sample += b.sample_time_s
                sig.append(int(b.blocks.all_nodes.sum()))
        totals[adm] = sample
        streams[adm] = sig
        if adm == "adaptive":
            refreshes = list(dl.topo_refresher.events)
    return {
        "blocks_identical": streams["degree"] == streams["adaptive"],
        "sample_speedup": totals["degree"] / max(totals["adaptive"], 1e-12),
        "n_refreshes": len(refreshes),
        "refresh_cost_s": float(sum(e.cost_s for e in refreshes)),
    }


def serve_quota() -> dict:
    """Two tenants, equal initial quotas, 30:1 hot-set-size ratio: the big
    tenant's hot set thrashes its half of the cache while the small
    tenant's half sits mostly cold.  QuotaController re-partitions toward
    measured misses."""
    g, feats = _graph_and_feats()
    tenants = (
        TenantSpec("big", rate_share=2.0, hot_fraction=0.12, hot_prob=0.95,
                   deadline_s=4e-3),
        TenantSpec("small", rate_share=1.0, hot_fraction=0.004,
                   hot_prob=0.95, deadline_s=4e-3),
    )
    stream = generate_stream(g.num_nodes, tenants, offered_qps=3000,
                             n_requests=600, seed=3)
    out = {}
    for adaptive in (False, True):
        engine = GNNServeEngine(g, feats, GNNServeConfig(
            tenants=2, cache_lines=2048, adaptive_quotas=adaptive,
            quota_interval=8, seed=5))
        res = engine.run(list(stream))
        key = "adaptive" if adaptive else "fixed"
        out[f"{key}_p99_s"] = res.p99_s()
        out[f"{key}_big_p99_s"] = res.p99_s(0)
        out[f"{key}_big_hit_ratio"] = res.tenant_hit_ratios[0]
        if adaptive:
            out["repartitions"] = len(res.quota_trace)
            out["final_quotas"] = (res.quota_trace[-1][1]
                                   if res.quota_trace else None)
    return out


def headline() -> dict:
    """Smoke numbers for BENCH_*.json + the CI adaptive gates."""
    rot = rotation()
    static = static_control()
    grow = growth()
    topo = topology()
    quota = serve_quota()
    return {
        "adaptive_vs_degree_speedup": rot["speedup"],
        "rotation_n_migrations": rot["n_migrations"],
        "rotation_migration_cost_us": rot["migration_cost_s"] * 1e6,
        "rotation_degree_prep_us": rot["degree_prep_s"] * 1e6,
        "rotation_adaptive_prep_us": rot["adaptive_prep_s"] * 1e6,
        "static_bit_identical": static["bit_identical"],
        "static_n_migrations": static["n_migrations"],
        "growth_speedup": grow["speedup"],
        "growth_n_migrations": grow["n_migrations"],
        "topo_sample_speedup": topo["sample_speedup"],
        "topo_blocks_identical": topo["blocks_identical"],
        "topo_n_refreshes": topo["n_refreshes"],
        "quota_repartitions": quota["repartitions"],
        "quota_fixed_big_hit_ratio": quota["fixed_big_hit_ratio"],
        "quota_adaptive_big_hit_ratio": quota["adaptive_big_hit_ratio"],
        "quota_fixed_p99_ms": quota["fixed_p99_s"] * 1e3,
        "quota_adaptive_p99_ms": quota["adaptive_p99_s"] * 1e3,
    }


def main() -> None:
    rot = rotation()
    row("fig_adaptive_rotation_degree", rot["degree_prep_s"] * 1e6,
        "static_placement_total_exposed_prep")
    row("fig_adaptive_rotation_adaptive", rot["adaptive_prep_s"] * 1e6,
        f"speedup={rot['speedup']:.3f}x_migrations={rot['n_migrations']}"
        f"_cost_us={rot['migration_cost_s']*1e6:.1f}")
    for ev in rot["events"]:
        row("fig_adaptive_migration", ev.cost_s * 1e6,
            f"burst={ev.burst}_moved={ev.n_moved}"
            f"_imbalance={ev.imbalance_before:.2f}"
            f"_saving_us={ev.predicted_saving_s*1e6:.1f}")
    static = static_control()
    row("fig_adaptive_static_control", 0.0,
        f"bit_identical={static['bit_identical']}"
        f"_migrations={static['n_migrations']}")
    grow = growth()
    row("fig_adaptive_growth", 0.0,
        f"speedup={grow['speedup']:.3f}x_migrations={grow['n_migrations']}")
    topo = topology()
    row("fig_adaptive_topology", 0.0,
        f"sample_speedup={topo['sample_speedup']:.3f}x"
        f"_blocks_identical={topo['blocks_identical']}"
        f"_refreshes={topo['n_refreshes']}"
        f"_cost_us={topo['refresh_cost_s']*1e6:.1f}")
    quota = serve_quota()
    row("fig_adaptive_serve_quota", quota["adaptive_p99_s"] * 1e6,
        f"repartitions={quota['repartitions']}"
        f"_big_hit={quota['fixed_big_hit_ratio']:.3f}"
        f"->{quota['adaptive_big_hit_ratio']:.3f}"
        f"_p99_ms={quota['fixed_p99_s']*1e3:.3f}"
        f"->{quota['adaptive_p99_s']*1e3:.3f}")


if __name__ == "__main__":
    main()
