"""Online multi-tenant GNN inference over the shared tiered data plane.

`GNNServeEngine` runs sample -> gather -> GNN-forward per request against
the SAME data plane the training loader uses — a `TieredFeatureStore` built
from a `DataPlaneSpec` preset (default "serve-gnn": per-tenant partitioned
HBM cache + pinned-host hot set + direct storage) and, for priced
GPU-initiated sampling, a `TieredTopologyStore`.  The engine is a
virtual-time discrete-event simulation: arrivals come time-stamped from
`serve/workload.py`, every stage is priced by the storage-timeline models,
and no wall clock is involved, so runs are bit-reproducible.

Two execution modes share one code path:

  * merged (`config.merged=True`) — the tentpole: the `SLOBatcher`
    (serve/admission.py) forms deadline-bounded windows under the
    `DeadlineWindowPolicy`, compatible in-flight requests merge through the
    training plane's `merge_window`/`gather_merged` path (cross-REQUEST
    dedup is cross-batch dedup), and the window's storage rows coalesce
    into one priced burst; compatibility includes the tenant — windows are
    tenant-pure (see `run`);
  * per-request (`config.merged=False`) — the baseline: FIFO service, one
    tier fold and one `price_batch` burst per request, no dedup, no line
    coalescing across requests.

Sampling runs at ADMISSION (GPU-initiated, against the topology store) and
overlaps window formation — a window cannot start service before its last
staged sample lands, but slack usually hides sampling entirely; the
per-request baseline gets the same rule (sampling overlaps its queue wait).
Identical request streams produce bit-identical sampled blocks and feature
rows in both modes — merging changes latency, never results.

Every request retires with a priced latency breakdown: queue wait (window
formation + accelerator backlog), its own sampling hops, its share of the
window's gather burst (proportional to its row count), and forward compute
(modelled per-row cost; pass `model`/`params` to also run the real GNN
forward on the gathered rows).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Sequence

import numpy as np

from repro.core.accumulator import (DeadlineWindowConfig,
                                    DeadlineWindowPolicy, merge_window)
from repro.core.dataplane import DataPlane, DataPlaneSpec
from repro.core.storage_sim import SAMSUNG_980PRO, SSDSpec, StorageTimeline
from repro.core.tiers import TenantCacheTier, record_tier_metrics
from repro.core.topology import TieredTopologyStore
from repro.obs import NULL_TRACER, attach_burst_spans
from repro.sampling.neighbor import host_sample_blocks
from repro.sampling.tiered import tiered_sample_blocks

from .admission import SLOBatcher
from .workload import ServeRequest


@dataclasses.dataclass
class GNNServeConfig:
    fanouts: Sequence[int] = (10, 5)
    merged: bool = True             # deadline-bounded windows vs per-request
    data_plane: str = "serve-gnn"   # preset name or DataPlaneSpec
    cache_lines: int = 8192
    cache_ways: int = 8
    tenants: int = 1
    tenant_quotas: Sequence[float] | None = None
    # adaptive quotas (core/feedback.QuotaController): every
    # `quota_interval` served windows, re-split the tenant cache's line
    # budget by EMA-smoothed per-tenant miss traffic (each tenant floored
    # at `quota_floor` of the lines), via TenantCacheTier.repartition
    adaptive_quotas: bool = False
    quota_interval: int = 8
    quota_floor: float = 0.05
    cbuf_fraction: float = 0.05
    # deadline-bounded admission (core/accumulator.DeadlineWindowPolicy)
    max_window: int = 16
    slack_safety: float = 2.5       # heavy-tail fanouts make window service
                                    # variance large; the extra margin eats
                                    # slack, not the SLO
    shed_expired: bool = True
    # priced GPU-initiated sampling (core/topology.TieredTopologyStore)
    use_topology: bool = True
    topo_admission: str = "degree"
    topo_gpu_fraction: float = 0.25
    topo_host_fraction: float = 0.5
    # modelled forward compute: one launch per WINDOW (batching amortizes
    # the launch constant), base + per_row * total window rows
    forward_base_s: float = 3e-5
    forward_per_row_s: float = 2e-8
    keep_features: bool = False     # retain gathered rows on each record
    # fault plane (core/faults.py): a seeded FaultSchedule injected into
    # every priced gather burst (burst index == served-window index on the
    # merged path); None prices bit-identically to the fault-free engine
    fault_schedule: object | None = None
    # brownout degradation ladder (BrownoutController): under measured
    # gather-latency pressure (per-row burst EMA over its own running-min
    # baseline) the engine degrades in priced steps instead of letting
    # every request's p99 ride the straggling queue —
    #   level 1 (pressure >= degrade_at): shrink sampling fanout
    #   level 2 (>= stale_at): + serve requests whose whole neighborhood
    #           was gathered within `stale_window_s` from those rows
    #           (same immutable bytes, staleness accounted, no burst)
    #   level 3 (>= shed_at): + shed every `shed_every`-th staged request
    # one level step per window, de-escalating below recover * threshold
    brownout: bool = False
    brownout_degrade_at: float = 2.0
    brownout_stale_at: float = 3.5
    brownout_shed_at: float = 6.0
    brownout_recover: float = 0.7
    brownout_alpha: float = 0.5
    brownout_fanout_scale: float = 0.5
    brownout_stale_window_s: float = 0.25
    brownout_shed_every: int = 3
    seed: int = 0


@dataclasses.dataclass
class RequestRecord:
    """One retired request with its priced latency breakdown."""

    rid: int
    tenant: int
    arrival_s: float
    deadline_s: float
    rejected: bool = False          # shed at admission (goodput, not p99)
    shed_reason: str | None = None  # why rejected: "expired" (deadline
                                    # already spent at admission) or
                                    # "brownout" (load shed at level 3)
    start_s: float = 0.0            # window service start
    completion_s: float = 0.0
    queue_wait_s: float = 0.0       # arrival -> service start
    sample_s: float = 0.0           # own sampling hops (priced)
    gather_s: float = 0.0           # share of the window burst
    forward_s: float = 0.0          # modelled forward compute
    window_size: int = 0            # requests in the serving window
    n_rows: int = 0                 # unique feature rows of this request
    degraded_level: int = 0         # brownout ladder level when served
    stale: bool = False             # served from recently-gathered rows
    staleness_s: float = 0.0        # age of the oldest reused row
    all_nodes: np.ndarray | None = None
    features: np.ndarray | None = None   # kept iff config.keep_features
    logits: np.ndarray | None = None     # set iff a model was supplied

    @property
    def latency_s(self) -> float:
        return self.completion_s - self.arrival_s

    @property
    def deadline_met(self) -> bool:
        return (not self.rejected
                and self.latency_s <= self.deadline_s + 1e-12)


@dataclasses.dataclass
class WindowTrace:
    start_s: float
    n_requests: int
    burst_s: float
    service_s: float
    dedup_factor: float
    hit_cap: bool


@dataclasses.dataclass
class ServeResult:
    records: list[RequestRecord]
    windows: list[WindowTrace]
    # per-tenant cumulative cache hit ratio from the serving tier — the
    # quota controller's input surfaced in served telemetry (empty when the
    # plane has no tenant tier)
    tenant_hit_ratios: dict[int, float] = dataclasses.field(
        default_factory=dict)
    # committed quota re-splits: (window index, new quota shares) per
    # QuotaController event; empty on static-quota runs
    quota_trace: list[tuple[int, tuple[float, ...]]] = dataclasses.field(
        default_factory=list)

    @property
    def served(self) -> list[RequestRecord]:
        return [r for r in self.records if not r.rejected]

    @property
    def n_rejected(self) -> int:
        """All shed requests — see `n_shed_expired` / `n_shed_brownout`
        for the breakdown; deadline misses of SERVED requests are counted
        separately in `n_deadline_missed`, never here."""
        return sum(r.rejected for r in self.records)

    @property
    def n_shed_expired(self) -> int:
        """Shed at admission because the deadline was already spent."""
        return sum(r.rejected and r.shed_reason == "expired"
                   for r in self.records)

    @property
    def n_shed_brownout(self) -> int:
        """Shed by the brownout controller at degradation level 3."""
        return sum(r.rejected and r.shed_reason == "brownout"
                   for r in self.records)

    @property
    def n_deadline_missed(self) -> int:
        """Served to completion but past the deadline — distinct from any
        kind of shed (those never started service)."""
        return sum((not r.rejected) and not r.deadline_met
                   for r in self.records)

    @property
    def n_degraded(self) -> int:
        """Served under a non-zero brownout level (shrunk fanout and/or
        stale rows) — degraded service, not lost service."""
        return sum((not r.rejected) and (r.degraded_level > 0 or r.stale)
                   for r in self.records)

    @property
    def n_stale_served(self) -> int:
        return sum((not r.rejected) and r.stale for r in self.records)

    @property
    def shed_fraction(self) -> float:
        return self.n_rejected / max(len(self.records), 1)

    def attainment(self, tenant: int | None = None) -> float:
        """Fraction of OFFERED load (shed included) that met its deadline
        — the SLO view that shedding cannot flatter, unlike a p99 taken
        over survivors only."""
        recs = [r for r in self.records
                if tenant is None or r.tenant == tenant]
        if not recs:
            return 0.0
        return sum(r.deadline_met for r in recs) / len(recs)

    def latencies_s(self, tenant: int | None = None) -> np.ndarray:
        return np.array([r.latency_s for r in self.served
                         if tenant is None or r.tenant == tenant])

    def _pct(self, q: float, tenant: int | None) -> float:
        lat = self.latencies_s(tenant)
        return float(np.percentile(lat, q)) if len(lat) else float("nan")

    def p50_s(self, tenant: int | None = None) -> float:
        return self._pct(50, tenant)

    def p99_s(self, tenant: int | None = None) -> float:
        return self._pct(99, tenant)

    @property
    def makespan_s(self) -> float:
        served = self.served
        if not served:
            return 0.0
        return (max(r.completion_s for r in served)
                - min(r.arrival_s for r in self.records))

    def goodput_qps(self, tenant: int | None = None) -> float:
        """Completions within deadline per second of makespan — rejected
        and late requests produce no goodput."""
        span = self.makespan_s
        if span <= 0:
            return 0.0
        met = sum(r.deadline_met for r in self.records
                  if tenant is None or r.tenant == tenant)
        return met / span

    def offered_qps(self) -> float:
        if len(self.records) < 2:
            return 0.0
        arrivals = sorted(r.arrival_s for r in self.records)
        return (len(arrivals) - 1) / max(arrivals[-1] - arrivals[0], 1e-12)

    def mean_breakdown_s(self) -> dict:
        served = self.served
        if not served:
            return {k: 0.0 for k in
                    ("queue_wait_s", "sample_s", "gather_s", "forward_s")}
        n = len(served)
        return {
            "queue_wait_s": sum(r.queue_wait_s for r in served) / n,
            "sample_s": sum(r.sample_s for r in served) / n,
            "gather_s": sum(r.gather_s for r in served) / n,
            "forward_s": sum(r.forward_s for r in served) / n,
        }

    @property
    def mean_window(self) -> float:
        if not self.windows:
            return 0.0
        return sum(w.n_requests for w in self.windows) / len(self.windows)


class BrownoutController:
    """Gather-latency pressure ladder for graceful serve-plane degradation.

    Pressure is the EMA of per-row window burst latency over its own
    running-minimum baseline — a storage brownout inflates every line read
    so the per-ROW cost rises with it, while window size and dedup cancel
    out of the normalization.  The ladder moves at most one level per
    observed window (no thrash on a single slow burst) and de-escalates
    with hysteresis once pressure falls below `recover` times the
    threshold it climbed past.  Levels only reshape WHAT is served —
    fanout, staleness, admission — never the bytes of any row that is
    served, so the fault-plane data invariant holds through a brownout.
    """

    def __init__(self, config: GNNServeConfig):
        self.config = config
        self.reset()

    def reset(self) -> None:
        self.level = 0
        self.ema = 0.0
        self.baseline = float("inf")
        self.n_windows = 0
        # (window index, new level) — one entry per ladder move
        self.level_trace: list[tuple[int, int]] = []

    @property
    def thresholds(self) -> tuple[float, float, float]:
        cfg = self.config
        return (cfg.brownout_degrade_at, cfg.brownout_stale_at,
                cfg.brownout_shed_at)

    @property
    def pressure(self) -> float:
        if not np.isfinite(self.baseline) or self.baseline <= 0 \
                or self.ema <= 0:
            return 1.0
        return self.ema / self.baseline

    def observe(self, burst_s: float, n_rows: int) -> int:
        """Feed one served window's burst; returns the (new) level.
        Stale-only windows gather nothing and carry no signal — the EMA
        holds until a fresh burst confirms or denies the pressure."""
        self.n_windows += 1
        if n_rows <= 0:
            return self.level
        per_row = burst_s / n_rows
        a = self.config.brownout_alpha
        self.ema = per_row if self.ema <= 0 else \
            (1.0 - a) * self.ema + a * per_row
        self.baseline = min(self.baseline, self.ema)
        th = self.thresholds
        p = self.pressure
        target = sum(p >= x for x in th)
        if target > self.level:
            self.level += 1
        elif self.level > 0 \
                and p < th[self.level - 1] * self.config.brownout_recover:
            self.level -= 1
        last = self.level_trace[-1][1] if self.level_trace else 0
        if self.level != last:
            self.level_trace.append((self.n_windows, self.level))
        return self.level


class GNNServeEngine:
    """Virtual-time online inference engine over the shared data plane.

    `plane` / `topo` may be passed in to SHARE an existing data plane (e.g.
    the training loader's) — by default the engine builds its own from
    `config.data_plane`.  `model`/`params` (a `repro.models.gnn.GNN`)
    optionally run the real forward per request; timing always uses the
    modelled forward cost so load sweeps don't need jax.
    """

    def __init__(self, graph, features, config: GNNServeConfig | None = None,
                 ssd: SSDSpec = SAMSUNG_980PRO,
                 plane: DataPlane | None = None,
                 topo: TieredTopologyStore | None = None,
                 model=None, params=None, tracer=None):
        self.graph = graph
        self.features = np.asarray(features)
        self.config = cfg = config or GNNServeConfig()
        self.ssd = ssd
        # observation only — an enabled tracer records spans/metrics but the
        # priced results are bit-identical to a NULL_TRACER run
        self.tracer = tracer if tracer is not None else NULL_TRACER
        if plane is None:
            plane = DataPlaneSpec.resolve(cfg.data_plane).build(
                graph, self.features,
                cache_lines=cfg.cache_lines, cache_ways=cfg.cache_ways,
                cbuf_fraction=cfg.cbuf_fraction, tenants=cfg.tenants,
                tenant_quotas=cfg.tenant_quotas, seed=cfg.seed)
        self.plane = plane
        self.store = plane.store
        backstop = self.store.tiers[-1]
        shard_specs = None
        if hasattr(backstop, "resolve_shard_specs"):
            shard_specs = backstop.resolve_shard_specs(ssd)
        self.timeline = StorageTimeline(ssd, 1, shard_specs=shard_specs)
        self.fault_injector = None
        if cfg.fault_schedule is not None:
            from repro.core.faults import FaultInjector
            n_queues = len(shard_specs) if shard_specs else 1
            self.fault_injector = FaultInjector(cfg.fault_schedule, n_queues)
            self.timeline.injector = self.fault_injector
        self.brownout = BrownoutController(cfg) if cfg.brownout else None
        # node -> virtual time its row was last gathered (stale serving)
        self._recent: dict[int, float] = {}
        self._shed_tick = 0
        if topo is None and cfg.use_topology:
            topo = TieredTopologyStore.from_graph(
                graph, admission=cfg.topo_admission,
                gpu_fraction=cfg.topo_gpu_fraction,
                host_fraction=cfg.topo_host_fraction,
                ssd=ssd, seed=cfg.seed)
        self.topo = topo
        self.model, self.params = model, params
        self.policy = DeadlineWindowPolicy(DeadlineWindowConfig(
            max_window=cfg.max_window if cfg.merged else 1,
            safety=cfg.slack_safety))
        self.batcher = SLOBatcher(self.policy,
                                  shed_expired=cfg.shed_expired)
        self._tenant_tier = next(
            (t for t in self.store.tiers if isinstance(t, TenantCacheTier)),
            None)
        self.quota_controller = self._make_quota_controller()
        self._sample_cache: dict = {}
        if self.tracer.enabled:
            self.timeline.metrics = self.tracer.metrics
            if self.topo is not None:
                self.topo.timeline.metrics = self.tracer.metrics

    def _make_quota_controller(self):
        if not (self.config.adaptive_quotas and self._tenant_tier is not None
                and self._tenant_tier.tenants > 1):
            return None
        from repro.core.feedback import QuotaController
        qc = QuotaController(self._tenant_tier,
                             interval=self.config.quota_interval,
                             floor=self.config.quota_floor)
        qc.tracer = self.tracer
        return qc

    # -- stages ----------------------------------------------------------------
    def _sample(self, req: ServeRequest):
        """GPU-initiated sampling at admission, memoized per request.  The
        RNG stream is keyed by (engine seed, rid) — NOT by service order —
        so a request samples the same blocks whether it is served merged,
        per-request, or after a demotion; with a topology store the
        hop-page reads are priced and the modelled time returned."""
        fanouts = self._fanouts()
        hit = self._sample_cache.get((req.rid, fanouts))
        if hit is not None:
            return hit
        rng = np.random.default_rng([self.config.seed, req.rid])
        if self.topo is not None:
            blocks = tiered_sample_blocks(self.graph, self.topo, req.seeds,
                                          fanouts, rng)
            out = (blocks, float(blocks.sample_time_s))
        else:
            out = (host_sample_blocks(self.graph, req.seeds,
                                      fanouts, rng), 0.0)
        self._sample_cache[(req.rid, fanouts)] = out
        return out

    def _fanouts(self) -> tuple[int, ...]:
        """Brownout level >= 1 shrinks the sampling fanout by
        `fanout_scale ** level` — fewer neighbors per hop means fewer
        unique rows per window, the cheapest pressure release (accuracy
        degrades before latency does).  The sample memo is keyed by the
        fanout it was drawn with, so a backlogged request re-samples
        smaller when the ladder climbs while it queues — mitigation
        reaches the very requests the brownout stranded — and the
        fault-free path (level pinned at 0) never re-samples anything."""
        if self.brownout is None or self.brownout.level < 1:
            return tuple(self.config.fanouts)
        scale = self.config.brownout_fanout_scale ** self.brownout.level
        return tuple(max(1, int(round(f * scale)))
                     for f in self.config.fanouts)

    def _forward_s(self, n_rows: int) -> float:
        """One batched forward launch over `n_rows` gathered rows — the
        window pays the launch constant once, which is the other half of
        what merging buys (the per-request baseline pays it per request)."""
        return (self.config.forward_base_s
                + self.config.forward_per_row_s * n_rows)

    def _run_model(self, blocks, rows: np.ndarray):
        if self.model is None:
            return None
        import jax.numpy as jnp
        from repro.models.gnn import hop_indices
        hi = [jnp.asarray(h) for h in hop_indices(blocks)]
        return np.asarray(self.model.forward(self.params,
                                             jnp.asarray(rows), hi))

    def _stage_tenants(self, merged, staged: list[ServeRequest]) -> None:
        """Announce the serving tenant of each unique node to the tenant
        tier: the first requester (admission order) owns the fill for this
        window; later requesters share the deduplicated row."""
        if self._tenant_tier is None:
            return
        tenant_of = np.full(merged.n_unique, -1, np.int64)
        for i, req in enumerate(staged):
            inv = merged.batch_inverse(i)
            fresh = tenant_of[inv] < 0
            tenant_of[inv[fresh]] = req.tenant
        self._tenant_tier.stage_tenants(tenant_of)

    # -- main loop -------------------------------------------------------------
    def run(self, requests: Sequence[ServeRequest]) -> ServeResult:
        """Serve an arrival-time-stamped stream to completion — see `_run`
        for the scheduling rules.  With an enabled tracer the run is
        wall-clocked as one ``serve_run`` stage (modelled time = the priced
        makespan), every retired request gets a virtual span on its
        tenant's track, and the serve counters land in the registry."""
        with self.tracer.stage("serve_run", cat="serve",
                               n_requests=len(requests)) as sp:
            result = self._run(requests)
            sp.modelled(result.makespan_s)
        if self.tracer.enabled:
            self._trace_requests(result)
            self._record_serve_metrics(result)
        return result

    def _run(self, requests: Sequence[ServeRequest]) -> ServeResult:
        """Serve an arrival-time-stamped stream to completion.

        Windows are TENANT-PURE: each tenant has its own pending queue and
        a window only merges requests of one tenant.  Isolation extends to
        the batch dimension — a noisy tenant's burst can inflate its own
        windows but never another tenant's, and a victim request's latency
        reflects its own tenant's cache partition, not whoever happened to
        share the window.  Tenants still share the one engine: service is
        FCFS across tenants by oldest waiting request.
        """
        queues: dict[int, deque] = {}
        for r in sorted(requests, key=lambda r: (r.arrival_s, r.rid)):
            queues.setdefault(r.tenant, deque()).append(r)
        records: list[RequestRecord] = []
        windows: list[WindowTrace] = []
        self._sample_cache.clear()
        busy = 0.0
        while any(queues.values()):
            tenant = min((t for t, q in queues.items() if q),
                         key=lambda t: queues[t][0].arrival_s)
            pending = queues[tenant]
            decision = self.batcher.next_window(pending, busy)
            if decision is None:
                continue
            for req in decision.shed:
                records.append(RequestRecord(
                    rid=req.rid, tenant=req.tenant, arrival_s=req.arrival_s,
                    deadline_s=req.deadline_s, rejected=True,
                    shed_reason="expired"))
            if not decision.staged:
                continue
            # a staged request whose sampling would land after the oldest
            # request's slack bound would push the whole window — and that
            # deadline — out by its own sampling tail.  It doesn't hold the
            # window hostage: demote it to the next window (its sample is
            # memoized, nothing re-runs).  The oldest always stays — the
            # window exists for its deadline — and the bound is its slack,
            # not the intended open time, so a backlogged cap-closed window
            # may slip a little to keep its depth (amortization is worth
            # more than an early start while slack remains).
            oldest = decision.staged[0]
            bound = max(decision.start_s, self.policy.close_by(
                oldest.arrival_s, oldest.deadline_s, len(decision.staged)))
            staged, demoted = [oldest], []
            for req in decision.staged[1:]:
                _, sample_s = self._sample(req)
                if req.arrival_s + sample_s <= bound:
                    staged.append(req)
                else:
                    demoted.append(req)
            for req in reversed(demoted):    # arrival order preserved
                pending.appendleft(req)
            # level 3: counter-based load shedding — every shed_every'th
            # staged request (deterministic, not sampled) is dropped before
            # service so the survivors' window stays small enough to hold
            # the victim p99.  The oldest request never sheds: its deadline
            # is why the window opened.
            if self.brownout is not None and self.brownout.level >= 3 \
                    and len(staged) > 1:
                keep = [staged[0]]
                for req in staged[1:]:
                    self._shed_tick += 1
                    if self._shed_tick % self.config.brownout_shed_every == 0:
                        records.append(RequestRecord(
                            rid=req.rid, tenant=req.tenant,
                            arrival_s=req.arrival_s,
                            deadline_s=req.deadline_s, rejected=True,
                            shed_reason="brownout"))
                    else:
                        keep.append(req)
                staged = keep
            decision.staged = staged
            busy = self._execute(decision, records, windows)
            # close the quota loop once per served window: the controller
            # watches the tenant tier's cumulative counters and repartitions
            # when smoothed miss traffic drifts past its dead band
            if self.quota_controller is not None:
                self.quota_controller.step()
        records.sort(key=lambda r: r.rid)
        result = ServeResult(records=records, windows=windows)
        if self._tenant_tier is not None:
            result.tenant_hit_ratios = {
                t: self._tenant_tier.hit_ratio(t)
                for t in range(self._tenant_tier.tenants)}
        if self.quota_controller is not None:
            result.quota_trace = list(self.quota_controller.events)
        return result

    def _execute(self, decision, records, windows) -> float:
        staged = decision.staged
        level = self.brownout.level if self.brownout is not None else 0
        prev_burst = (self.timeline.shard_burst if self.tracer.enabled
                      else None)
        samples = [self._sample(r) for r in staged]
        # service cannot start before the last staged sample lands —
        # sampling is admission-time GPU work overlapping window formation
        start = max([decision.start_s]
                    + [r.arrival_s + s for r, (_, s) in zip(staged, samples)])
        blocks = [b for b, _ in samples]

        # level >= 2: a request whose WHOLE neighborhood was gathered
        # within the stale window is served from those rows directly —
        # identical bytes (features are immutable), zero storage burst,
        # staleness recorded on the record instead of latency on the tail
        stale_age: list[float | None] = [None] * len(staged)
        if self.config.merged and level >= 2 and self._recent:
            win = self.config.brownout_stale_window_s
            for i, blk in enumerate(blocks):
                last = [self._recent.get(int(n)) for n in blk.all_nodes]
                if last and all(ls is not None and start - ls <= win
                                for ls in last):
                    stale_age[i] = start - min(last)
        fresh = [i for i, a in enumerate(stale_age) if a is None]

        rows_by_idx: dict[int, np.ndarray] = {}
        gathered_unique = None
        if len(staged) == 1 and not self.config.merged:
            # per-request baseline: one fold, one un-coalesced burst whose
            # overlap efficiency comes from this request's own storage
            # concurrency alone (no accumulator ramping across requests)
            merged = merge_window([blocks[0].all_nodes])
            self._stage_tenants(merged, staged)
            rows, report = self.store.gather(blocks[0].all_nodes)
            rows_by_idx[0] = rows
            burst_s = self.timeline.price_batch(
                report, outstanding=max(report.n_storage, 1))
            dedup = 1.0
        elif fresh:
            merged = merge_window([blocks[i].all_nodes for i in fresh])
            self._stage_tenants(merged, [staged[i] for i in fresh])
            fresh_rows_list, _, wrep = self.store.gather_merged(merged)
            burst_s = self.timeline.price_merged_burst(wrep)
            dedup = wrep.dedup_factor
            rows_by_idx = dict(zip(fresh, fresh_rows_list))
            gathered_unique = merged.unique_nodes
        else:
            # every staged request is served stale — no burst at all
            burst_s, dedup = 0.0, 1.0

        total_rows = sum(len(b.all_nodes) for b in blocks)
        fresh_rows = sum(len(blocks[i].all_nodes) for i in fresh)
        forward_total_s = self._forward_s(total_rows)
        t = start + burst_s + forward_total_s
        for i, (req, (blk, sample_s)) in enumerate(zip(staged, samples)):
            n_rows = len(blk.all_nodes)
            stale = stale_age[i] is not None
            rows = rows_by_idx.get(i)
            if rows is None:
                rows = self.features[blk.all_nodes]
            rec = RequestRecord(
                rid=req.rid, tenant=req.tenant, arrival_s=req.arrival_s,
                deadline_s=req.deadline_s, start_s=start, completion_s=t,
                queue_wait_s=start - req.arrival_s, sample_s=sample_s,
                gather_s=(0.0 if stale
                          else burst_s * n_rows / max(fresh_rows, 1)),
                forward_s=forward_total_s * n_rows / max(total_rows, 1),
                window_size=len(staged),
                n_rows=n_rows, degraded_level=level, stale=stale,
                staleness_s=stale_age[i] or 0.0, all_nodes=blk.all_nodes)
            if self.config.keep_features:
                rec.features = rows
            if self.model is not None:
                rec.logits = self._run_model(blk, rows)
            records.append(rec)
        if self.brownout is not None:
            if gathered_unique is not None:
                for n in gathered_unique:
                    self._recent[int(n)] = start
            new_level = self.brownout.observe(
                burst_s,
                len(gathered_unique) if gathered_unique is not None else 0)
            if new_level != level:
                self.tracer.instant(
                    "brownout", track="controller", cat="controller", t0=t,
                    level=new_level, pressure=float(self.brownout.pressure))
        service_s = t - start
        # the policy's estimate absorbs the sampling-completion push-out of
        # `start` past the batcher's intended open time, so close_by leaves
        # room for it on the next window
        self.policy.observe(t - decision.start_s, len(staged))
        windows.append(WindowTrace(
            start_s=start, n_requests=len(staged), burst_s=burst_s,
            service_s=service_s, dedup_factor=dedup,
            hit_cap=decision.hit_cap))
        if self.tracer.enabled:
            self._trace_window(windows[-1], len(windows) - 1, level,
                               forward_total_s, dedup, prev_burst)
        return t

    # -- observability ---------------------------------------------------------
    def _trace_window(self, w: WindowTrace, index: int, level: int,
                      forward_total_s: float, dedup: float,
                      prev_burst) -> None:
        """Virtual span for one served window on the ``windows`` track:
        gather burst (with per-shard / fault overlays when the serve
        timeline produced a fresh sharded burst) then the batched forward.
        Window starts are monotone in service order, so the track lays out
        without any cursor fixups."""
        root = self.tracer.batch(
            "serve_window", track="windows", cat="window", t0=w.start_s,
            index=index, n_requests=w.n_requests, level=level,
            hit_cap=w.hit_cap)
        g = root.child("gather", w.burst_s, cat="gather",
                       dedup_factor=float(dedup))
        burst = self.timeline.shard_burst
        if burst is not None and burst is not prev_burst:
            attach_burst_spans(g, burst)
        root.child("forward", forward_total_s, cat="forward")
        root.close(w.service_s)
        m = self.tracer.metrics
        m.histogram("serve.window_size").observe(w.n_requests)
        m.histogram("serve.dedup_factor").observe(float(dedup))
        m.counter("serve.burst_s").inc(w.burst_s)
        m.counter("serve.forward_s").inc(forward_total_s)

    def _trace_requests(self, result: ServeResult) -> None:
        """One virtual span per retired request on its tenant's track,
        emitted AFTER the run in arrival order (demotion can serve requests
        out of arrival order, and track starts must be monotone).  The
        sequential children — queue wait, the window's gather burst, the
        window's batched forward — partition the end-to-end latency; the
        request's own shares ride along as annotations and its sampling
        overlays the queue wait as a parallel child."""
        window_of = {}
        for w in result.windows:
            window_of.setdefault(w.start_s, w)
        for rec in sorted(result.records,
                          key=lambda r: (r.arrival_s, r.rid)):
            track = f"tenant{rec.tenant}"
            if rec.rejected:
                self.tracer.instant("shed", track=track, cat="serve",
                                    t0=rec.arrival_s, rid=rec.rid,
                                    reason=rec.shed_reason)
                continue
            w = window_of.get(rec.start_s)
            burst_s = w.burst_s if w is not None else 0.0
            forward_s = (w.service_s - w.burst_s if w is not None
                         else rec.forward_s)
            root = self.tracer.batch(
                "request", track=track, cat="request", t0=rec.arrival_s,
                rid=rec.rid, tenant=rec.tenant, window_size=rec.window_size,
                n_rows=rec.n_rows, level=rec.degraded_level, stale=rec.stale,
                deadline_met=rec.deadline_met)
            root.child("queue_wait", rec.queue_wait_s, cat="serve")
            root.child("gather", burst_s, cat="gather",
                       share_s=rec.gather_s)
            root.child("forward", forward_s, cat="forward",
                       share_s=rec.forward_s)
            if rec.sample_s > 0.0:
                root.child("sample", rec.sample_s, cat="sample",
                           parallel=True)
            root.close(rec.latency_s)

    def _record_serve_metrics(self, result: ServeResult) -> None:
        m = self.tracer.metrics
        m.counter("serve.requests").inc(len(result.records))
        m.counter("serve.windows").inc(len(result.windows))
        m.counter("serve.shed_expired").inc(result.n_shed_expired)
        m.counter("serve.shed_brownout").inc(result.n_shed_brownout)
        m.counter("serve.deadline_missed").inc(result.n_deadline_missed)
        m.counter("serve.stale_served").inc(result.n_stale_served)
        m.gauge("serve.attainment").set(result.attainment())
        for rec in result.served:
            m.histogram("serve.latency_s").observe(rec.latency_s)
        for t, ratio in result.tenant_hit_ratios.items():
            m.gauge(f"serve.tenant{t}.hit_ratio").set(ratio)
        record_tier_metrics(self.store.tiers, m)

    def reset(self) -> None:
        """Fresh caches, fresh RNG, fresh service estimate — a reset engine
        replays a stream bit-identically."""
        self.plane.reset()
        # the topology store is stateless (fixed page assignment) — nothing
        # to reset there
        self.policy.reset()
        # plane.reset restored the construction-time quotas; the controller
        # restarts from the same initial demand estimate
        self.quota_controller = self._make_quota_controller()
        self._sample_cache.clear()
        if self.fault_injector is not None:
            self.fault_injector.reset()
        if self.brownout is not None:
            self.brownout.reset()
        self._recent.clear()
        self._shed_tick = 0
        # telemetry restarts with the replay: stale spans/metrics from the
        # previous stream would otherwise leak into the next export
        self.tracer.reset()
        self.timeline.reset_telemetry()
