"""qwen2-1.5b [dense] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936; QKV bias. [arXiv:2407.10671; hf]
"""
import dataclasses
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b", family="dense",
        num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
        d_ff=8960, vocab_size=151936,
        qkv_bias=True, tie_embeddings=True,
    )


def reduced_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, vocab_pad_to=64, remat=False)
