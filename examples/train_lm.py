"""Train any of the 10 assigned LM architectures (reduced config) with the
GIDS-fed token pipeline, checkpoint/restart and WSD or cosine schedule:

    PYTHONPATH=src python examples/train_lm.py --arch minicpm_2b \
        --steps 200 --schedule wsd

This is a thin veneer over the production driver (repro.launch.train);
kill it mid-run and rerun with the same --ckpt-dir to watch it resume.
"""
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

if __name__ == "__main__":
    args = sys.argv[1:] or ["--arch", "minicpm_2b", "--steps", "200",
                            "--schedule", "wsd", "--batch", "8",
                            "--seq", "128", "--ckpt-dir", "/tmp/lm_ckpt"]
    cmd = [sys.executable, "-m", "repro.launch.train", "--reduced"] + args
    sys.exit(subprocess.call(cmd, env={
        **__import__("os").environ,
        "PYTHONPATH": str(ROOT / "src"),
    }))
