"""Accumulator analytic model (paper Eq. 2-3) vs the discrete-event
simulator — the Fig. 8 correspondence — plus property tests."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.storage_sim import (INTEL_OPTANE, SAMSUNG_980PRO,
                                    model_burst, required_accesses,
                                    simulate_burst, StorageTimeline)


@pytest.mark.parametrize("spec", [INTEL_OPTANE, SAMSUNG_980PRO],
                         ids=lambda s: s.name)
def test_model_matches_simulation(spec):
    """Fig. 8: the Eq. 2-3 model tracks simulated bandwidth — loosely on
    the ramp (latency variance; the paper notes the same), tightly near
    saturation ("accurately estimates ... particularly when it approaches
    the peak bandwidth")."""
    for n in (64, 256, 1024, 4096, 16384):
        m = model_burst(spec, n)
        s = simulate_burst(spec, n, seed=1)
        tol = 0.15 if m.efficiency < 0.8 else 0.05
        assert m.efficiency == pytest.approx(s.efficiency, rel=tol), n
    # saturation: large bursts approach peak
    big = model_burst(spec, 10 * required_accesses(spec, 0.95))
    assert big.efficiency > 0.95


@pytest.mark.parametrize("spec", [INTEL_OPTANE, SAMSUNG_980PRO],
                         ids=lambda s: s.name)
def test_required_accesses_inverts_model(spec):
    for rho in (0.5, 0.8, 0.9, 0.95):
        n = required_accesses(spec, rho)
        assert model_burst(spec, n).efficiency >= rho - 1e-6
        # minimality: 20% fewer accesses miss the target
        assert model_burst(spec, int(n * 0.8)).efficiency < rho


@given(rho1=st.floats(0.1, 0.9), drho=st.floats(0.01, 0.09),
       n_ssd=st.integers(1, 8))
@settings(max_examples=50, deadline=None)
def test_required_accesses_monotone(rho1, drho, n_ssd):
    """More SSDs or a higher efficiency target need more outstanding
    accesses (Little's law monotonicity)."""
    lo = required_accesses(INTEL_OPTANE, rho1, n_ssd)
    hi = required_accesses(INTEL_OPTANE, rho1 + drho, n_ssd)
    assert hi >= lo
    assert required_accesses(INTEL_OPTANE, rho1, n_ssd + 1) >= lo


def test_higher_latency_ssd_needs_more_overlap():
    """980Pro (324us) demands more concurrency than Optane (11us) — §3.2."""
    assert (required_accesses(SAMSUNG_980PRO, 0.9)
            > required_accesses(INTEL_OPTANE, 0.9))


def test_timeline_gids_beats_mmap():
    """Same request mix: GIDS (overlapped direct access) must beat the
    page-faulting mmap path by a wide margin (Fig. 13/14 direction)."""
    tl = StorageTimeline(SAMSUNG_980PRO, n_ssd=1)
    n, fb = 100_000, 4096
    t_gids = tl.gids_batch_time(n_storage=n, n_host=0, n_hbm=0,
                                feat_bytes=fb, outstanding=8192)
    t_mmap = tl.mmap_batch_time(n_storage=n, n_page_cache=0, feat_bytes=fb)
    assert t_gids < t_mmap / 5


def test_timeline_redirection_amplifies_bandwidth():
    """Redirecting hot requests to the host buffer raises effective
    bandwidth until PCIe saturates (Fig. 10 direction)."""
    tl = StorageTimeline(INTEL_OPTANE, n_ssd=1)
    n, fb = 100_000, 4096
    base = tl.gids_batch_time(n, 0, 0, fb, outstanding=4096)
    redir = tl.gids_batch_time(int(n * 0.6), int(n * 0.4), 0, fb,
                               outstanding=4096)
    assert redir < base
