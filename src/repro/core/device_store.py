"""Device-resident GIDS feature tier: the fully-jittable composition of

    cache_jax (window-buffered cache metadata, HBM)      §3.4
  + an HBM row store (the BaM software cache's data)
  + the tiered_gather Pallas kernel (slot-indirect row DMA)

One `device_gather` call = lookup/fill metadata -> write missed rows from
the host-staged buffer into their assigned lines -> gather every requested
row from (cache | staged).  This is the TPU rendering of the paper's
GPU-thread gather loop: it fuses into the surrounding step, so cache
maintenance costs no host round-trip.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import cache_jax
from repro.kernels import ops


class DeviceStore(NamedTuple):
    cache: cache_jax.CacheState
    rows: jnp.ndarray               # (num_lines, D) HBM row storage


def init_store(num_lines: int, dim: int, ways: int = 8,
               dtype=jnp.float32) -> DeviceStore:
    return DeviceStore(cache=cache_jax.init_cache(num_lines, ways),
                       rows=jnp.zeros((num_lines, dim), dtype))


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def device_gather(store: DeviceStore, ids: jnp.ndarray,
                  staged: jnp.ndarray, future_counts: jnp.ndarray,
                  use_pallas: bool = True):
    """ids: (B,) node ids (-1 pad); staged: (B, D) host-fetched rows for
    potential misses; future_counts: window-buffer reuse counts.

    Returns (new_store, rows (B, D), hit_mask)."""
    state, hits, slots = cache_jax.access(store.cache, ids, future_counts)
    # fill: missed rows with an assigned line land in the row store
    fill_slots = jnp.where(~hits & (slots >= 0) & (ids >= 0),
                           slots, store.rows.shape[0])      # OOB -> dropped
    rows_store = store.rows.at[fill_slots].set(
        staged.astype(store.rows.dtype), mode="drop")
    # serve: hits from the row store, misses straight from staging
    gather_slots = jnp.where(hits, slots, -1)
    out = ops.tiered_gather(gather_slots, rows_store, staged,
                            use_pallas=use_pallas)
    return DeviceStore(cache=state, rows=rows_store), out, hits


push_window = cache_jax.push_window       # re-export: same metadata
count_in_window = cache_jax.count_in_window
