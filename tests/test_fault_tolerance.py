"""StepWatchdog: injectable clock, straggler flagging against the rolling
median, checkpoint cadence, and history bounds."""
import pytest

from repro.train.fault_tolerance import StepWatchdog, WatchdogConfig


class FakeClock:
    """Deterministic clock: each step takes whatever the test scripts."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def _run_steps(wd, clock, durations, start=0):
    flags = []
    for i, dt in enumerate(durations, start):
        wd.start_step(i)
        clock.advance(dt)
        flags.append(wd.end_step())
    return flags


def test_watchdog_flags_straggler_after_warmup():
    clock = FakeClock()
    wd = StepWatchdog(WatchdogConfig(step_timeout_factor=5.0,
                                     min_history=4), clock=clock)
    # warmup: nothing is flagged before min_history observations exist,
    # even a step 100x the others
    flags = _run_steps(wd, clock, [0.1, 0.1, 0.1, 10.0])
    assert flags == [False] * 4
    # median is now 0.1; a 5x+ step is a straggler, a 4x one is not
    assert _run_steps(wd, clock, [0.4], start=4) == [False]
    assert _run_steps(wd, clock, [0.6], start=5) == [True]
    assert wd.flagged == [(5, pytest.approx(0.6))]
    # upper median of [.1, .1, .1, .4, .6, 10]
    assert wd.median_step_s == pytest.approx(0.4)


def test_watchdog_median_tracks_drift():
    """The threshold follows the ROLLING median — a uniformly slower phase
    is a new normal, not an endless straggler alarm."""
    clock = FakeClock()
    wd = StepWatchdog(WatchdogConfig(step_timeout_factor=5.0, min_history=4,
                                     max_step_history=8), clock=clock)
    _run_steps(wd, clock, [0.1] * 8)
    # 8 slow-but-steady steps push the old regime out of the window
    flags = _run_steps(wd, clock, [0.45] * 8, start=8)
    assert not any(flags)                   # 4.5x median, under the factor
    assert wd.median_step_s == pytest.approx(0.45)
    assert len(wd.history) == 8             # bounded


def test_watchdog_end_without_start_raises():
    wd = StepWatchdog(clock=FakeClock())
    with pytest.raises(RuntimeError, match="start_step"):
        wd.end_step()


def test_watchdog_checkpoint_cadence():
    wd = StepWatchdog(WatchdogConfig(checkpoint_every=50), clock=FakeClock())
    assert not wd.should_checkpoint(0)      # step 0 never checkpoints
    assert wd.should_checkpoint(50)
    assert not wd.should_checkpoint(51)
    assert wd.should_checkpoint(100)


def test_watchdog_default_clock_is_wall_time():
    wd = StepWatchdog()
    wd.start_step(0)
    assert wd.end_step() is False
    assert wd.history and wd.history[0] >= 0.0
