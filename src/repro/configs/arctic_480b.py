"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) d_ff=4864
vocab=32000, MoE 128e top-2 in parallel with a dense residual FFN.
[hf:Snowflake/snowflake-arctic-base; hf]
"""
import dataclasses
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b", family="moe",
        num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
        d_ff=4864, vocab_size=32000,
        moe_experts=128, moe_top_k=2, moe_interleave=1,
        moe_dense_residual=True,
    )


def reduced_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=96, vocab_size=512, vocab_pad_to=64, moe_experts=4,
        remat=False)
