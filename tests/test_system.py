"""End-to-end behaviour of the full system: the GIDS dataloader feeding an
LM trainer, checkpoint/restart mid-run, and the dry-run cell builder on a
host mesh (sharding machinery sanity without 512 devices)."""
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

ROOT = Path(__file__).resolve().parents[1]


def test_lm_training_loss_decreases(tmp_path):
    """The production trainer drives a reduced arch for 60 steps on a
    learnable synthetic stream and the loss must drop."""
    from repro.launch.train import build
    from repro.train import optimizer as opt_lib

    cfg, model, step_fn, pipe, ocfg = build(
        "qwen2_1_5b", reduced=True, batch=8, seq=32, lr=3e-3,
        total_steps=60, schedule="cosine")
    # learnable stream: next token = (token + 1) % 50
    stream = (np.cumsum(np.ones(1 << 14)) % 50).astype(np.int32)
    pipe.tokens = stream

    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt_lib.init(params, ocfg)
    losses = []
    for _ in range(60):
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-10:]) < 0.5 * np.mean(losses[:5]), \
        (np.mean(losses[:5]), np.mean(losses[-10:]))


def test_cell_builder_on_host_mesh():
    """build_cell produces lowerable abstractions on the 1-device mesh —
    the same code path the 512-way dry-run uses."""
    from repro.launch.mesh import make_host_mesh
    from repro.launch.specs import build_cell

    mesh = make_host_mesh()
    cell = build_cell("qwen2_1_5b", "train_4k", mesh, multi_pod=False,
                      overrides={"num_layers": 2, "vocab_size": 512,
                                 "vocab_pad_to": 64, "d_model": 64,
                                 "num_heads": 4, "num_kv_heads": 2,
                                 "d_ff": 128})
    lowered = jax.jit(cell.step_fn).lower(*cell.abstract_args)
    assert lowered.as_text()                      # lowers cleanly
    assert cell.kind == "train"


def test_serve_cell_builder_on_host_mesh():
    from repro.launch.mesh import make_host_mesh
    from repro.launch.specs import build_cell

    mesh = make_host_mesh()
    cell = build_cell("mamba2_1_3b", "decode_32k", mesh, multi_pod=False,
                      overrides={"num_layers": 2, "vocab_size": 512,
                                 "vocab_pad_to": 64, "d_model": 64,
                                 "ssm_state": 16, "ssm_headdim": 8,
                                 "ssm_chunk": 8})
    jax.jit(cell.step_fn).lower(*cell.abstract_args)
    assert cell.kind == "decode"


def test_trainer_cli_resume(tmp_path):
    """The CLI trainer checkpoints and resumes (subprocess integration)."""
    import os
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
           "qwen2_1_5b", "--reduced", "--steps", "12", "--batch", "2",
           "--seq", "16", "--ckpt-dir", str(tmp_path), "--ckpt-every", "6"]
    r1 = subprocess.run(cmd, capture_output=True, text=True, env=env)
    assert r1.returncode == 0, r1.stderr[-2000:]
    cmd2 = [c if c != "12" else "18" for c in cmd]
    r2 = subprocess.run(cmd2, capture_output=True, text=True, env=env)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step 12" in r2.stdout
