"""Fault-tolerance runtime: step watchdog, straggler mitigation, elastic
restart policy.

On a real multi-pod deployment the failure modes are (a) hard node loss,
(b) slow/straggling hosts, (c) preemption.  This module provides the
host-side machinery; the data-plane contributions of the paper compose with
it naturally:

  * the GIDS accumulator's dispatch-ahead queue IS the straggler absorber —
    a host whose storage/preprocessing stalls for < merge_depth iterations
    never stalls the accelerators (the queue drains);
  * the window buffer + sampler PRNG state checkpoint with the model, so a
    restart replays the exact sample stream (no silently skipped data);
  * restore re-shards onto whatever mesh survives (see checkpoint.restore),
    so losing a pod degrades to single-pod training instead of aborting.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable


@dataclasses.dataclass
class WatchdogConfig:
    step_timeout_factor: float = 5.0   # flag a step slower than 5x median
    min_history: int = 16
    checkpoint_every: int = 100
    max_step_history: int = 256


class StepWatchdog:
    """Tracks step latencies; flags stragglers and drives checkpoint cadence.

    With dispatch-ahead (the accumulator), a flagged slow *data* step only
    re-issues prefetches; a flagged slow *compute* step on real hardware
    triggers the external orchestrator (restart-from-checkpoint)."""

    def __init__(self, cfg: WatchdogConfig | None = None,
                 clock: Callable[[], float] = time.monotonic):
        # `clock` is injectable so tests (and virtual-time harnesses) can
        # feed deterministic step durations instead of wall time
        self.cfg = cfg or WatchdogConfig()
        self.clock = clock
        self.history: list[float] = []
        self.flagged: list[tuple[int, float]] = []
        self._t0: float | None = None
        self._step = 0

    def start_step(self, step: int) -> None:
        self._step = step
        self._t0 = self.clock()

    def end_step(self) -> bool:
        """Returns True if this step was a straggler."""
        if self._t0 is None:
            raise RuntimeError("end_step() without a matching start_step()")
        dt = self.clock() - self._t0
        straggler = False
        if len(self.history) >= self.cfg.min_history:
            med = sorted(self.history)[len(self.history) // 2]
            if dt > self.cfg.step_timeout_factor * med:
                self.flagged.append((self._step, dt))
                straggler = True
        self.history.append(dt)
        if len(self.history) > self.cfg.max_step_history:
            self.history.pop(0)
        return straggler

    def should_checkpoint(self, step: int) -> bool:
        return step > 0 and step % self.cfg.checkpoint_every == 0

    @property
    def median_step_s(self) -> float:
        if not self.history:
            return 0.0
        return sorted(self.history)[len(self.history) // 2]


def run_with_restarts(make_state: Callable, train_one: Callable,
                      total_steps: int, *, ckpt_dir, save_every: int = 50,
                      inject_failure_at: int | None = None):
    """Crash-safe training loop driver used by tests/examples: builds state,
    optionally simulates a hard failure, restarts from the latest commit and
    proves bitwise-resumable iteration.

    make_state(restore_step | None) -> (state, start_step)
    train_one(state, step)          -> state
    """
    from repro.train import checkpoint as ckpt

    state, start = make_state(ckpt.latest_step(ckpt_dir))
    step = start
    while step < total_steps:
        if inject_failure_at is not None and step == inject_failure_at:
            inject_failure_at = None          # fail exactly once
            state, start = make_state(ckpt.latest_step(ckpt_dir))
            step = start
            continue
        state = train_one(state, step)
        step += 1
        if step % save_every == 0 or step == total_steps:
            ckpt.save(ckpt_dir, step, state)
    return state, step
