"""Fig. 10 — feature-aggregation effective bandwidth with the constant CPU
buffer at 0/10/20% of the dataset, random vs reverse-PageRank pinning,
single Optane SSD, 8 GB GPU cache, NO window buffering.

Paper: baseline 6.6 GBps; 20% + reverse-pagerank -> 23.4 GBps (3.53x); the
20% pagerank buffer makes one SSD look like four."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row
from repro.core import GIDSDataLoader, LoaderConfig, INTEL_OPTANE
from repro.graph.datasets import IGB_FULL


def measured_bw(dl: GIDSDataLoader, iters=12):
    bws = []
    for _ in range(iters):
        b = dl.next_batch()
        bws.append(b.report.n_requests * b.report.bytes_per_row
                   / b.prep_time_s)
    return float(np.mean(bws[2:]))


def main():
    g = IGB_FULL.materialize()
    feats = np.zeros((g.num_nodes, 1), np.float32)
    base_cfg = dict(batch_size=256, fanouts=(5, 5), data_plane="gids",
                    cache_lines=1 << 14, window_depth=0, n_ssd=1)

    dl = GIDSDataLoader(g, feats,
                        LoaderConfig(**base_cfg, cbuf_fraction=0.0),
                        ssd=INTEL_OPTANE)
    dl.store.feature_dim = IGB_FULL.feature_dim
    bw0 = measured_bw(dl)
    row("fig10_baseline", 0.0, f"bw={bw0/1e9:.2f}GBps")

    for frac in (0.1, 0.2):
        for sel in ("random", "pagerank"):
            dl = GIDSDataLoader(
                g, feats,
                LoaderConfig(**base_cfg, cbuf_fraction=frac,
                             cbuf_selection=sel),
                ssd=INTEL_OPTANE)
            dl.store.feature_dim = IGB_FULL.feature_dim
            bw = measured_bw(dl)
            row(f"fig10_cbuf{int(frac*100)}_{sel}", 0.0,
                f"bw={bw/1e9:.2f}GBps_speedup={bw/bw0:.2f}x")


if __name__ == "__main__":
    main()
