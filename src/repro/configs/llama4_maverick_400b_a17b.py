"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128e top-1 + shared expert, interleaved every
2nd layer (matches 400B total / 17B active; see DESIGN.md).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""
import dataclasses
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b", family="moe",
        num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
        d_ff=8192, vocab_size=202048,
        moe_experts=128, moe_top_k=1, moe_interleave=2,
        moe_shared_expert=True,
    )


def reduced_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, vocab_pad_to=64, moe_experts=4,
        remat=False)
