"""Quickstart: the GIDS dataloader in 60 lines.

Builds a synthetic power-law graph and streams mini-batches through four
declarative data planes — the paper's full GIDS stack (dynamic access
accumulator + constant CPU buffer + window-buffered cache), its prefetching
variant (gids-async: batch k+1 staged while batch k trains, only the excess
prep exposed), and the mmap/BaM baselines — printing each plane's tier split
and modelled data-prep time.  A data plane is a `DataPlaneSpec` preset (or
your own registered stack); the loader just consumes it.

The last section shards the storage namespace across 4 SSD queues
(`gids-sharded`, `LoaderConfig(n_shards=4, placement=...)`): a registered
placement policy (core/sharding.py — hash / range / degree-aware striping)
decides which shard owns each node, and pricing completes every batch at the
slowest shard's queue, surfacing the straggler and the queue imbalance.

Then the plane goes adaptive (`placement="adaptive"`, core/feedback.py): a
hot-set rotation drifts the workload away from the degree prior, the
measured queue imbalance crosses the rebalancer's threshold, and a PRICED
shard migration re-stripes the measured-hot nodes — the demo prints the
imbalance before and after the move, plus what the move cost.

Then the plane goes online: a bursty two-tenant request stream served by
`GNNServeEngine` through deadline-bounded merged windows over the
tenant-partitioned `serve-gnn` plane, printing goodput and the priced
p50/p99 latency breakdown per tenant.

The final section is the chaos demo (core/faults.py): a seeded
FaultSchedule browns one of the four shard queues out 25x, and the demo
prints the recovery timeline — the health monitor flags the sick queue,
hedged reads duplicate the straggler onto its chained replica, plan-time
failover routes new lines away, and the rebalancer drains the shard — then
compares total exposed prep with and without the replicated/hedged plane.
The serve half browns out one shard under the online engine and shows the
BrownoutController trading fidelity (fanout shrink -> stale serving ->
shed) for a bounded victim p99.

The closing section distributes the plane across 4 hosts
(`gids-hosts-merged`, core/hosts.py): each shard is a host with a NIC
link model and a local SSD, and one co-partitioned placement decision
puts a node's feature rows and its adjacency pages on the same machine.
The demo contrasts hash striping with the min-cut `metis-lite` grower on
a community-structured graph, printing per-host traffic (local rows vs
remote 4KB lines over the wire) and the cut-edge ratio that explains the
gap.

The last section turns on the observability plane (src/repro/obs/): the
same distributed loader runs 8 batches with a live `Tracer`, exports a
Perfetto-loadable Chrome trace (trace.json), and prints the top-3 spans
by priced time plus the modelled-vs-measured gap per pipeline stage from
the `MetricsRegistry` — with tracing guaranteed bit-invisible to every
number printed above.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (DataPlaneSpec, GIDSDataLoader, LoaderConfig,
                        SAMSUNG_980PRO)
from repro.graph.synthetic import rmat_graph

graph = rmat_graph(num_nodes=100_000, avg_degree=12, feature_dim=256,
                   seed=0)
features = np.random.default_rng(0).standard_normal(
    (graph.num_nodes, 256)).astype(np.float32)

print(f"graph: {graph.num_nodes:,} nodes, {graph.num_edges:,} edges, "
      f"features {features.nbytes/2**20:.0f} MiB")
print(f"registered data planes: {', '.join(DataPlaneSpec.names())}\n")

TRAIN_STEP_S = 2e-3          # pretend model compute, for the async overlap

for name in ("mmap", "bam", "gids", "gids-async"):
    spec = DataPlaneSpec.preset(name)
    loader = GIDSDataLoader(
        graph, features,
        LoaderConfig(batch_size=1024, fanouts=(10, 5), data_plane=spec,
                     cache_lines=8192, window_depth=8, cbuf_fraction=0.1),
        ssd=SAMSUNG_980PRO)
    prep, exposed = [], []
    for _ in range(10):
        # a prefetching plane (gids-async) stages the next batches ahead and
        # only prep in excess of the train step reaches the critical path
        batch = loader.next_batch(compute_s=TRAIN_STEP_S)
        prep.append(batch.prep_time_s)
        exposed.append(batch.exposed_prep_s)
    r = batch.report
    hit = loader.store.cache.stats.hit_ratio if loader.store.cache else 0.0
    tiers = " ".join(f"{t}={n}" for t, n in zip(r.tier_names, r.tier_counts))
    print(f"[{name:10s}] prep {np.mean(prep)*1e3:8.2f} ms/iter "
          f"(exposed {np.mean(exposed)*1e3:6.2f} ms) | "
          f"tier split {tiers} | cache hit {hit:.2f} | "
          f"lookahead depth {batch.merge_depth}")

print("\nfeatures gathered for the last batch:", batch.features.shape)

# -- sharded storage: the namespace striped across 4 SSD queues ---------------
# Same bytes, same blocks — only the storage pricing changes: each shard
# drains its own queue and the batch completes at the slowest one.  The
# degree-aware policy stripes hot high-degree nodes across shards so the
# power-law head never hammers a single queue.
for placement in ("hash", "degree"):
    loader = GIDSDataLoader(
        graph, features,
        LoaderConfig(batch_size=1024, fanouts=(10, 5),
                     data_plane="gids-sharded", n_shards=4,
                     placement=placement,
                     cache_lines=8192, window_depth=8, cbuf_fraction=0.1),
        ssd=SAMSUNG_980PRO)
    prep = [loader.next_batch().prep_time_s for _ in range(10)]
    r = loader.store.last_plan
    burst = loader.timeline.shard_burst
    print(f"[gids-sharded/{placement:6s}] prep {np.mean(prep)*1e3:6.2f} "
          f"ms/iter | rows/shard {r.shard_counts().tolist()} | "
          f"straggler shard {burst.straggler} "
          f"(imbalance {burst.imbalance:.3f})")

# -- adaptive placement: the telemetry loop, closed ---------------------------
# A hot-set rotation keyed to the static degree table (epoch e trains the
# nodes the degree deal put on shard e) is the adversarial drift: one queue
# drains while three idle.  With `placement="adaptive"` a TouchTable learns
# the measured touches, and when the priced saving beats the priced
# migration cost the rebalancer re-stripes the hot set — the cost amortized
# into subsequent batches, so the win below is net of the migration IOs.
from repro.core import make_placement

small = rmat_graph(num_nodes=10_000, avg_degree=12, feature_dim=64, seed=1)
small_feats = np.random.default_rng(0).standard_normal(
    (small.num_nodes, 64)).astype(np.float32)
table = make_placement("degree", 4, degrees=np.diff(small.indptr)).table
hot_sets = [np.nonzero(table == s)[0] for s in range(4)]
print()
for placement in ("degree", "adaptive"):
    loader = GIDSDataLoader(small, small_feats, LoaderConfig(
        batch_size=256, fanouts=(2,), data_plane="gids-merged-sharded",
        cache_lines=512, window_depth=4, n_shards=4, placement=placement,
        seed=7, rebalance_interval=4, migration_horizon=64))
    prep, imb_trace = 0.0, []
    for epoch in range(2):
        loader.train_ids = hot_sets[epoch]
        for _ in range(32):
            prep += loader.next_batch().exposed_prep_s
            imb_trace.append(loader.timeline.shard_burst.imbalance)
    print(f"[rotation/{placement:8s}] exposed prep {prep*1e3:6.2f} ms "
          f"over 2 epochs | queue imbalance at epoch ends "
          f"{imb_trace[31]:.2f}, {imb_trace[63]:.2f}")
    if placement == "adaptive":
        for ev in loader.rebalancer.events:
            # settled imbalance: end of the epoch the migration landed in
            settled = imb_trace[min(((ev.burst - 1) // 32 + 1) * 32,
                                    len(imb_trace)) - 1]
            print(f"  migration @burst {ev.burst}: imbalance "
                  f"{ev.imbalance_before:.2f} before -> {settled:.2f} "
                  f"settled, {ev.n_moved} rows moved for "
                  f"{ev.cost_s*1e6:.0f} us (modelled saving "
                  f"{ev.predicted_saving_s*1e6:.1f} us/batch)")

# -- topology plane: sampling itself becomes a priced, tiered stage -----------
# `gids-topo` partitions the CSR adjacency into 4 KB edge pages placed by a
# degree-aware admission policy: GPU-resident hot adjacency, a pinned-host
# middle, and storage-backed CSR pages.  Blocks and features are
# bit-identical to `gids` with the same seed — but plan_next() is now priced
# like execute(): every hop reports its edge-page tier split and the
# modelled sampling time folds into prep/exposed prep.
loader = GIDSDataLoader(
    graph, features,
    LoaderConfig(batch_size=1024, fanouts=(10, 5), data_plane="gids-topo",
                 topo_gpu_fraction=0.25, topo_host_fraction=0.5,
                 cache_lines=8192, window_depth=8, cbuf_fraction=0.1),
    ssd=SAMSUNG_980PRO)
batch = loader.next_batch()
topo = loader.topo
print(f"\n[gids-topo] adjacency pages (hbm, host, storage) = "
      f"{topo.tier_pages()} | prep {batch.prep_time_s*1e6:.1f} us "
      f"(sampling {batch.sample_time_s*1e6:.1f} us of it)")
for r in batch.blocks.hop_reports:
    print(f"  hop {r.hop}: {r.n_edge_reads} edge reads -> "
          f"pages hbm={r.pages_by_tier[0]} host={r.pages_by_tier[1]} "
          f"storage={r.pages_by_tier[2]} "
          f"({r.n_storage_ios} coalesced IOs, "
          f"{r.coalesce_factor:.0f} reads/IO) | {r.time_s*1e6:.1f} us")

# -- serve plane: the same data plane, online ---------------------------------
# Two tenants (one steady, one bursty MMPP) fire requests at a shared
# GNNServeEngine.  Admission forms deadline-bounded windows — a window
# closes when the oldest request's SLO slack is spent — and each window is
# one merged gather (cross-request dedup + 4KB-line coalescing) plus one
# batched forward.  The `serve-gnn` plane partitions the cache per tenant,
# so the bursty tenant cannot evict the steady tenant's hot set.
from repro.serve import GNNServeConfig, GNNServeEngine, TenantSpec, \
    generate_stream

tenants = (
    TenantSpec("steady", hot_fraction=0.03, hot_prob=0.9, mean_seeds=4),
    TenantSpec("bursty", hot_fraction=0.5, hot_prob=0.2, mean_seeds=8,
               arrival="mmpp", burst_factor=8.0, burst_fraction=0.1),
)
stream = generate_stream(graph.num_nodes, tenants, offered_qps=8_000,
                         n_requests=300, seed=11)
engine = GNNServeEngine(graph, features, GNNServeConfig(
    tenants=2, cache_lines=8192, seed=3))
res = engine.run(stream)
bd = res.mean_breakdown_s()
print(f"\n[serve-gnn] offered {res.offered_qps():,.0f} qps -> goodput "
      f"{res.goodput_qps():,.0f} qps | p50 {res.p50_s()*1e6:.0f} us "
      f"p99 {res.p99_s()*1e6:.0f} us | mean window {res.mean_window:.1f}")
print(f"  latency breakdown: wait {bd['queue_wait_s']*1e6:.0f} us, "
      f"sample {bd['sample_s']*1e6:.0f} us, "
      f"gather {bd['gather_s']*1e6:.0f} us, "
      f"forward {bd['forward_s']*1e6:.0f} us")
for t, spec in enumerate(tenants):
    print(f"  tenant {spec.name:6s}: p99 {res.p99_s(tenant=t)*1e6:6.0f} us "
          f"| cache hit {engine._tenant_tier.hit_ratio(t):.2f}")

# -- fault plane: detection -> hedge -> failover -> drain ---------------------
# A seeded FaultSchedule keys faults to the loader's priced-burst index:
# here shard 2 of 4 browns out 25x for 40 bursts.  The unreplicated plane
# eats the straggler queue; with 2-way chained declustering the injector
# hedges the straggler's residual onto the replica, the health monitor
# flags the queue from its priced per-row drains, plan-time failover
# routes fresh lines away, and the adaptive rebalancer emits a priced
# "drain" migration off the sick shard.  Data is bit-identical either
# way — faults perturb timing and routing, never bytes.
from repro.core import BrownoutEvent, FaultSchedule

chaos = FaultSchedule(events=(
    BrownoutEvent(shard=2, start=0, end=40, multiplier=25.0),))
runs = {}
for mode, extra in (("naive", dict(placement="degree")),
                    ("hedged", dict(placement="adaptive",
                                    replication_factor=2,
                                    rebalance_interval=4,
                                    migration_horizon=64))):
    loader = GIDSDataLoader(small, small_feats, LoaderConfig(
        batch_size=256, fanouts=(2,), data_plane="gids-merged-sharded",
        cache_lines=512, window_depth=4, n_shards=4, seed=7,
        fault_schedule=chaos, **extra))
    runs[mode] = (sum(loader.next_batch().exposed_prep_s
                      for _ in range(48)), loader)

t_naive, t_hedged = runs["naive"][0], runs["hedged"][0]
hl = runs["hedged"][1]
inj, router = hl.fault_injector, hl.store.tiers[-1].router
print(f"\n[faults/brownout] shard 2 browns out 25x: exposed prep "
      f"{t_naive*1e3:.2f} ms naive -> {t_hedged*1e3:.2f} ms hedged "
      f"({t_naive/t_hedged:.2f}x recovered)")
print(f"  recovery timeline: hedge fires @burst {inj.first_hedge_burst} "
      f"({inj.n_hedged_bursts} bursts, {inj.hedge_saving_s*1e6:.0f} us "
      f"saved) | monitor flags @burst {hl.health.first_flag_burst} | "
      f"failover reroutes @burst {router.first_reroute_burst} "
      f"({router.n_rerouted} lines)")
for ev in hl.rebalancer.events:
    if ev.reason == "drain":
        print(f"  drain @burst {ev.burst}: {ev.n_moved} rows off shard 2 "
              f"for {ev.cost_s*1e6:.0f} us")

# -- serve plane under brownout: degrade, don't die ---------------------------
# The same schedule axis plugs into the online engine.  A persistent 10x
# brownout on one serve shard would triple the victim p99; with
# `brownout=True` the BrownoutController watches per-row gather pressure
# and climbs a priced ladder — shrink fanouts, serve recently-gathered
# neighborhoods stale (same bytes, zero burst), shed as a last resort —
# holding p99 near fault-free at a small, accounted-for shed fraction.
wide_feats = np.random.default_rng(0).standard_normal(
    (small.num_nodes, 512)).astype(np.float32)
reqs = list(generate_stream(
    small.num_nodes,
    [TenantSpec(name="t0", deadline_s=3e-3, mean_seeds=8)],
    offered_qps=500, n_requests=150, seed=3))
sick = FaultSchedule(events=(
    BrownoutEvent(shard=0, start=3, end=10_000, multiplier=10.0),))
out = {}
for mode, kw in (("fault-free", {}), ("naive", dict(fault_schedule=sick)),
                 ("controlled", dict(fault_schedule=sick, brownout=True))):
    eng = GNNServeEngine(small, wide_feats, GNNServeConfig(
        seed=5, cache_lines=256, **kw))
    out[mode] = (eng.run(reqs), eng)
free_p99 = out["fault-free"][0].p99_s()
print()
for mode in ("fault-free", "naive", "controlled"):
    res, eng = out[mode]
    line = (f"[serve/{mode:10s}] p99 {res.p99_s()*1e3:5.2f} ms "
            f"({res.p99_s()/free_p99:.2f}x fault-free) | attainment "
            f"{res.attainment():.2f}")
    if mode == "controlled":
        line += (f" | shed {res.shed_fraction:.2f} "
                 f"(stale-served {res.n_stale_served}, "
                 f"degraded {res.n_degraded}) | ladder "
                 f"{[lv for _, lv in eng.brownout.level_trace]}")
    print(line)

# -- distributed plane: the namespace partitioned across 4 hosts --------------
# Each shard is now a HOST (NIC link + RTT + its own SSD).  Rows owned by
# the host that samples them drain locally; the rest pay a link transit,
# and the batch completes at the slowest host.  Placement is the whole
# game: hash striping scatters every community across the cluster (~75%
# of sampled edges cross hosts), while `metis-lite` grows
# degree-mass-balanced partitions along the community structure and
# co-partitioning puts each node's adjacency pages on the same host as
# its feature rows — most traffic never touches the interconnect.  Bytes
# are bit-identical either way; only modelled time and telemetry move.
from repro.graph.synthetic import clustered_graph

cg = clustered_graph(20_000, 12, 64, communities=32, intra=0.9, seed=1)
cg_feats = np.random.default_rng(0).standard_normal(
    (cg.num_nodes, 64)).astype(np.float32)
print(f"\n[hosts] {cg.num_nodes:,}-node community graph on 4 hosts "
      f"(100GbE links, one NVMe each)")
for placement, co in (("hash", False), ("metis-lite", True)):
    loader = GIDSDataLoader(cg, cg_feats, LoaderConfig(
        batch_size=256, fanouts=(6, 4), data_plane="gids-hosts-merged",
        n_hosts=4, placement=placement, co_partition=co,
        cache_lines=256, window_depth=4, seed=3), ssd=SAMSUNG_980PRO)
    prep = [loader.next_batch().exposed_prep_s for _ in range(10)]
    tier = loader.plane.store.tiers[-1]
    burst = loader.timeline.shard_burst
    rows = loader.store.last_plan.shard_counts().tolist()
    mode = "co-partitioned" if co else "independent topo"
    print(f"[gids-hosts/{placement:10s}] exposed prep "
          f"{np.mean(prep)*1e6:6.1f} us ({mode}) | "
          f"cut edges {tier.cut_edge_fraction():.2f} | "
          f"remote rows {tier.remote_fraction():.2f}")
    print(f"  per-host rows {rows} | remote lines over the wire "
          f"{list(burst.remote_lines)} | straggler host "
          f"{burst.straggler} (imbalance {burst.imbalance:.2f})")

# -- observability plane: the whole pipeline as a span tree -------------------
# Pass a Tracer and every priced stage becomes a nested span — plan_next
# (per-hop sampling, edge-page reads), execute (merged gather, per-shard
# storage drains, fault recovery sub-events) — in both virtual (priced)
# and wall-clock time, with a MetricsRegistry accumulating counters/
# histograms alongside.  Tracing is bit-invisible: the traced loader
# below prices the exact same floats as the untraced ones above.  The
# export is Chrome trace-event JSON — open trace.json in
# https://ui.perfetto.dev and every batch, window, shard, and hop is a
# track you can scrub.
from repro.obs import Tracer

tracer = Tracer()
loader = GIDSDataLoader(cg, cg_feats, LoaderConfig(
    batch_size=256, fanouts=(6, 4), data_plane="gids-hosts-merged",
    n_hosts=4, placement="metis-lite", co_partition=True,
    cache_lines=256, window_depth=4, seed=3),
    ssd=SAMSUNG_980PRO, tracer=tracer)
for _ in range(8):
    loader.next_batch()
tracer.write("trace.json")

spans = sorted(
    ((sp.name, sp.dur, sp.args) for root in tracer.roots()
     for sp in root.walk() if sp.dur),
    key=lambda s: -s[1])
print(f"\n[obs] 8 traced batches -> trace.json "
      f"({len(tracer.chrome_events())} events; load in ui.perfetto.dev)")
print("  top-3 spans by priced time:")
for name, dur, args in spans[:3]:
    tags = " ".join(f"{k}={v}" for k, v in sorted(args.items())
                    if isinstance(v, (int, str)))
    print(f"    {name:14s} {dur*1e6:8.2f} us  {tags}")
print("  modelled vs measured, per stage (virtual clock vs wall clock):")
m = tracer.metrics
for name in m.names():
    if not name.startswith("modelled_vs_measured."):
        continue
    pts = m.series(name).points
    modelled = sum(p["modelled_s"] for p in pts)
    measured = sum(p["measured_s"] for p in pts)
    print(f"    {name.split('.', 1)[1]:14s} modelled {modelled*1e6:8.2f} us"
          f" | simulated in {measured*1e6:8.2f} us wall"
          f" ({len(pts)} spans)")
