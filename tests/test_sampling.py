"""Samplers: shape contracts + every sampled edge is a real edge
(property, over the host, device AND tiered samplers), host/device
agreement on the neighbor relation, shared int64-safe id handling, and
checkpoint/restore mid-lookahead determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.graph.csr import device_index_dtype, index_dtype
from repro.graph.synthetic import rmat_graph, uniform_graph
from repro.sampling.ladies import ladies_sample_blocks
from repro.sampling.neighbor import (device_sample_blocks,
                                     host_sample_blocks, subgraph_sizes)


def _edge_set(g):
    es = set()
    for v in range(g.num_nodes):
        for u in g.neighbors(v):
            es.add((v, int(u)))
    return es


def _check_hops(g, es, seeds, fanouts, hop_nodes):
    """Every sampled neighbor is a true out-neighbor of its destination,
    or the self-loop fallback IFF the destination has degree 0."""
    deg = g.degrees()
    frontier = np.asarray(seeds)
    for f, hop in zip(fanouts, hop_nodes):
        parents = np.repeat(frontier, f)
        for p, c in zip(parents, np.asarray(hop)):
            p, c = int(p), int(c)
            if deg[p] == 0:
                assert c == p, f"deg-0 node {p} must self-loop, got {c}"
            else:
                assert (p, c) in es, f"({p},{c}) is not a real edge"
        frontier = np.asarray(hop)


@given(seed=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_host_and_tiered_samplers_edges_are_real(seed):
    from repro.core.topology import TieredTopologyStore
    from repro.sampling.tiered import tiered_sample_blocks
    g = rmat_graph(500, 6, 8, seed=seed % 7)
    topo = TieredTopologyStore.from_graph(g, gpu_fraction=0.3,
                                          host_fraction=0.3)
    rng = np.random.default_rng(seed)
    seeds = rng.integers(0, g.num_nodes, 16)
    blocks = host_sample_blocks(g, seeds, (3, 2), rng)
    assert blocks.hop_nodes[0].shape == (16 * 3,)
    assert blocks.hop_nodes[1].shape == (16 * 3 * 2,)
    es = _edge_set(g)
    _check_hops(g, es, seeds, (3, 2), blocks.hop_nodes)
    # the tiered sampler is the same math on the same stream: identical
    # blocks (so the same property holds), plus priced per-hop reports
    rng2 = np.random.default_rng(seed)
    rng2.integers(0, g.num_nodes, 16)   # burn the host path's seeds draw
    tb = tiered_sample_blocks(g, topo, seeds, (3, 2), rng2)
    for a, b in zip(blocks.hop_nodes, tb.hop_nodes):
        np.testing.assert_array_equal(a, b)
    _check_hops(g, es, seeds, (3, 2), tb.hop_nodes)
    assert all(r.n_pages >= 0 for r in tb.hop_reports)


@given(seed=st.integers(0, 200))
@settings(max_examples=5, deadline=None)
def test_checkpoint_restore_mid_lookahead_identical_blocks(seed):
    """With sampled-ahead batches staged in the lookahead, a checkpoint
    restored into a fresh loader replays the exact same blocks."""
    from repro.core import GIDSDataLoader, LoaderConfig
    g = rmat_graph(2000, 8, 8, seed=1)
    feats = np.zeros((g.num_nodes, 8), np.float32)
    mk = lambda: GIDSDataLoader(g, feats, LoaderConfig(
        batch_size=32, fanouts=(3, 2), data_plane="gids", cache_lines=512,
        window_depth=2, seed=seed))
    a = mk()
    for _ in range(3):
        a.next_batch()
    assert len(a._lookahead) > 0          # mid-lookahead by construction
    st_ = a.state_dict()
    nxt = a.next_batch()
    b = mk()
    b.load_state_dict(st_)
    nxt_b = b.next_batch()
    np.testing.assert_array_equal(nxt.blocks.seeds, nxt_b.blocks.seeds)
    for ha, hb in zip(nxt.blocks.hop_nodes, nxt_b.blocks.hop_nodes):
        np.testing.assert_array_equal(ha, hb)


def test_device_sampler_matches_contract():
    g = uniform_graph(400, 8, 4, seed=1)
    csr = g.to_device()
    seeds = jnp.arange(8, dtype=jnp.int32)
    hops, flat = jax.jit(
        lambda s, k: device_sample_blocks(csr, s, (4, 2), k)
    )(seeds, jax.random.PRNGKey(0))
    assert hops[0].shape == (8 * 4,)
    assert hops[1].shape == (8 * 4 * 2,)
    assert flat.shape == (8 + 32 + 64,)
    _check_hops(g, _edge_set(g), np.asarray(seeds), (4, 2),
                [np.asarray(h) for h in hops])


def test_index_dtype_policy_is_int64_safe():
    assert index_dtype(2 ** 31 - 1) is np.int32
    assert index_dtype(2 ** 31) is np.int64
    # below the cliff both paths agree on int32
    assert device_index_dtype(1000, 5000) == jnp.int32
    # past 2^31 ids the device path must not silently truncate: without
    # x64 it fails loudly (this container runs with x64 disabled)
    if not jax.config.jax_enable_x64:
        with pytest.raises(ValueError, match="x64"):
            device_index_dtype(2 ** 31 + 5, 10)
        with pytest.raises(ValueError, match="x64"):
            device_index_dtype(10, 2 ** 31 + 5)


def test_device_sampler_uses_shared_dtype():
    g = uniform_graph(200, 6, 4, seed=2)
    csr = g.to_device()
    assert csr.indptr.dtype == csr.indices.dtype == jnp.int32
    hops, flat = device_sample_blocks(csr, jnp.arange(4, dtype=jnp.int32),
                                      (3,), jax.random.PRNGKey(1))
    assert flat.dtype == jnp.int32


def test_subgraph_sizes_matches_actual_sampler_output():
    """The closed form is pinned to the real padded samplers: it equals the
    device sampler's flat length AND the host sampler's request count."""
    assert subgraph_sizes(1, (3, 2)) == 1 + 3 + 6  # paper Fig. 2
    assert subgraph_sizes(4, (10, 5, 5)) == 4 * (1 + 10 + 50 + 250)
    g = uniform_graph(300, 8, 4, seed=3)
    rng = np.random.default_rng(0)
    seeds = rng.integers(0, g.num_nodes, 8)
    blocks = host_sample_blocks(g, seeds, (4, 2), rng)
    assert blocks.num_requests == subgraph_sizes(8, (4, 2))
    _, flat = device_sample_blocks(g.to_device(),
                                   jnp.asarray(seeds, jnp.int32), (4, 2),
                                   jax.random.PRNGKey(0))
    assert flat.shape[0] == subgraph_sizes(8, (4, 2))


def test_ladies_fixed_layer_sizes():
    g = rmat_graph(1000, 8, 8, seed=2)
    rng = np.random.default_rng(0)
    blocks = ladies_sample_blocks(g, rng.integers(0, 1000, 32),
                                  (64, 64), rng)
    assert blocks.hop_nodes[0].shape == (64,)
    assert blocks.hop_nodes[1].shape == (64,)
    assert blocks.num_requests == 32 + 64 + 64


def test_ladies_importance_bias():
    """High in-degree nodes should be sampled more often by LADIES."""
    g = rmat_graph(2000, 10, 8, seed=3)
    rng = np.random.default_rng(1)
    counts = np.zeros(g.num_nodes)
    for _ in range(20):
        blocks = ladies_sample_blocks(g, rng.integers(0, 2000, 16),
                                      (128,), rng)
        counts[blocks.hop_nodes[0]] += 1
    indeg = np.bincount(g.indices, minlength=g.num_nodes)
    hot = np.argsort(-indeg)[:100]
    cold = np.argsort(-indeg)[-1000:]
    assert counts[hot].mean() > counts[cold].mean()
