"""Sharded checkpointing with atomic commit, resharding restore, and
pipeline-state capture — the fault-tolerance substrate.

Design (multi-host ready):
  * each host writes only the shards it owns (`addressable_shards`) as raw
    .npy files keyed by (param path, shard index);
  * a manifest.json records the global shape/dtype/sharding of every leaf
    plus step metadata and data-pipeline state;
  * writes go to ``step_XXXX.tmp/`` then a single atomic rename publishes
    the checkpoint — a mid-write crash never corrupts the latest commit;
  * restore reassembles global arrays and re-shards onto the *current*
    mesh, which may differ from the writer's (elastic scale up/down: a
    checkpoint written on 512 chips restores on 256, 8, or 1);
  * GIDS dataloader state (PRNG cursor, telemetry) rides in the manifest so
    sampling resumes deterministically after restart.
"""
from __future__ import annotations

import json
import shutil
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def _flatten(tree) -> dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        out[key] = leaf
    return out


def save(ckpt_dir: str | Path, step: int, tree: Any,
         extra_state: dict | None = None) -> Path:
    """Write a checkpoint; returns the committed directory."""
    ckpt_dir = Path(ckpt_dir)
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    final = ckpt_dir / f"step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    manifest = {"step": step, "leaves": {}, "extra": extra_state or {}}
    for key, leaf in _flatten(tree).items():
        arr = leaf
        entry = {"shape": list(arr.shape), "dtype": str(arr.dtype),
                 "shards": []}
        if isinstance(arr, jax.Array) and hasattr(arr, "addressable_shards"):
            for i, shard in enumerate(arr.addressable_shards):
                if shard.replica_id != 0:
                    continue  # one writer per distinct shard
                fn = f"{key.replace('/', '.')}.{i}.npy"
                data = np.asarray(shard.data)
                if data.dtype == jnp.bfloat16:
                    np.save(tmp / fn, data.view(np.uint16))
                    entry["bf16_as_u16"] = True
                else:
                    np.save(tmp / fn, data)
                entry["shards"].append({"file": fn,
                                        "index": _index_to_json(shard.index)})
        else:
            fn = f"{key.replace('/', '.')}.full.npy"
            np.save(tmp / fn, np.asarray(arr))
            entry["shards"].append({"file": fn, "index": None})
        manifest["leaves"][key] = entry
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                      # atomic commit
    # retention: keep last 3
    all_steps = sorted(ckpt_dir.glob("step_[0-9]*"))
    for old in all_steps[:-3]:
        if old.is_dir() and not old.name.endswith(".tmp"):
            shutil.rmtree(old)
    return final


def _index_to_json(index) -> list:
    out = []
    for sl in index:
        out.append([sl.start, sl.stop])
    return out


def latest_step(ckpt_dir: str | Path) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    steps = sorted(int(p.name.split("_")[1]) for p in
                   ckpt_dir.glob("step_[0-9]*") if p.is_dir()
                   and not p.name.endswith(".tmp"))
    return steps[-1] if steps else None


def restore(ckpt_dir: str | Path, step: int, like: Any,
            shardings: Any | None = None) -> tuple[Any, dict]:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs).  `shardings`: optional pytree of NamedShardings for
    the CURRENT mesh — enables elastic restore onto a different topology.
    Returns (tree, extra_state)."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    flat_like = _flatten(like)
    flat_shard = _flatten(shardings) if shardings is not None else {}

    rebuilt = {}
    for key, entry in manifest["leaves"].items():
        shape = tuple(entry["shape"])
        dtype = entry["dtype"]
        global_arr = np.zeros(shape, dtype=np.uint16
                              if entry.get("bf16_as_u16") else dtype)
        for sh in entry["shards"]:
            data = np.load(d / sh["file"])
            if sh["index"] is None:
                global_arr = data
            else:
                idx = tuple(slice(a, b) for a, b in sh["index"])
                global_arr[idx] = data
        if entry.get("bf16_as_u16"):
            global_arr = global_arr.view(jnp.bfloat16)
        sharding = flat_shard.get(key)
        if sharding is not None:
            arr = jax.device_put(global_arr, sharding)
        else:
            arr = jnp.asarray(global_arr)
        rebuilt[key] = arr

    # reassemble into like's structure
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    vals = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        vals.append(rebuilt[key])
    tree = jax.tree_util.tree_unflatten(treedef, vals)
    return tree, manifest.get("extra", {})
