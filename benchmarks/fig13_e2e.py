"""Fig. 13/14 — end-to-end GNN training time per iteration: DGL-mmap
baseline vs BaM vs GIDS, on Samsung 980 Pro (Fig. 13) and Intel Optane
(Fig. 14); homogeneous (IGB-Full, papers100M stand-ins).

E2E iteration = data preparation (storage-model-priced real pipeline with
real cache/cbuf telemetry) + training step (measured GraphSAGE on CPU).
Paper headline: up to 582x (980pro) / 17.3x (optane) over mmap; 1.3-3.1x
over BaM."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.core import (GIDSDataLoader, LoaderConfig, INTEL_OPTANE,
                        SAMSUNG_980PRO)
from repro.graph.datasets import IGB_FULL, OGBN_PAPERS100M
from repro.models.gnn import GNN, GNNConfig, hop_indices


def train_step_time(g, fanouts, batch):
    cfg = GNNConfig(model="sage", in_dim=64, hidden_dim=128, num_classes=47,
                    fanouts=fanouts, use_pallas=False)
    gnn = GNN(cfg)
    rng = np.random.default_rng(0)
    params = gnn.init(jax.random.PRNGKey(0))
    from repro.sampling.neighbor import host_sample_blocks
    blocks = host_sample_blocks(g, rng.integers(0, g.num_nodes, batch),
                                fanouts, rng)
    feats = jnp.asarray(rng.standard_normal(
        (len(blocks.all_nodes), 64)).astype(np.float32))
    hi = [jnp.asarray(i) for i in hop_indices(blocks)]
    y = jnp.asarray(rng.integers(0, 47, batch))

    @jax.jit
    def step(p, f, h0, h1, h2, yy):
        l, gr = jax.value_and_grad(gnn.loss)(p, f, [h0, h1, h2], yy)
        return jax.tree.map(lambda a, b: a - 1e-3 * b, p, gr), l

    return timeit(lambda: jax.block_until_ready(
        step(params, feats, hi[0], hi[1], hi[2], y)), iters=3)


def e2e(dataset, ssd, mode, t_train, fits_in_memory, iters=10, warmup=2,
        tracer=None):
    g = dataset.materialize()
    feats = np.zeros((g.num_nodes, 1), np.float32)
    dl = GIDSDataLoader(
        g, feats,
        LoaderConfig(batch_size=512, fanouts=(10, 5), data_plane=mode,
                     cache_lines=1 << 13, window_depth=8,
                     cbuf_fraction=0.1 if mode.startswith("gids") else 0.0),
        ssd=ssd, tracer=tracer)
    dl.store.feature_dim = dataset.feature_dim
    preps, last_report = [], None
    for _ in range(iters):
        # a prefetching plane (gids-async) overlaps this batch's prep with
        # the previous train step and only its exposed excess hits the
        # iteration critical path; sync planes expose everything
        b = dl.next_batch(compute_s=t_train)
        prep = b.exposed_prep_s
        last_report = b.report
        if mode == "mmap" and fits_in_memory:
            # paper: ogbn/MAG fit in CPU memory -> page cache absorbs
            # storage after warmup; only fault overhead remains
            prep = prep * 0.02
        preps.append(prep)
    # steady state only: a merged plane amortizes its cold first window's
    # storage burst into every batch of the window, so the warmup must
    # cover at least one whole window for the comparison to be fair (the
    # per-batch planes' expensive cold batches are dropped the same way)
    prep = float(np.mean(preps[warmup:]))
    return prep + t_train, prep, last_report


def headline(t_train: float = 0.005, iters: int = 24) -> dict:
    """Smoke numbers for BENCH_*.json: the plane ordering on a small
    synthetic stand-in (no GNN jit, fixed modelled train-step time) — fast
    enough for CI, same code path as the full figure.  The warmup covers
    the merged plane's first (cold, amortized) window so every plane is
    measured at steady state."""
    from repro.graph.datasets import DatasetSpec
    from repro.obs import Tracer
    ds = DatasetSpec("smoke", 20_000, 240_000, 64, exec_nodes=20_000)
    out, reports = {}, {}
    for m in ("mmap", "bam", "gids", "gids-async", "gids-merged"):
        # the gids run executes with a LIVE tracer: the exact-equality
        # baseline gate in run.py then proves tracing is bit-invisible on
        # the very numbers the PR trajectory records
        tracer = Tracer() if m == "gids" else None
        t, prep, rep = e2e(ds, SAMSUNG_980PRO, m, t_train,
                           fits_in_memory=False, iters=iters, warmup=8,
                           tracer=tracer)
        out[f"{m}_e2e_s"] = t
        out[f"{m}_exposed_prep_us"] = prep * 1e6
        reports[m] = rep
    out["e2e_speedup_gids_vs_mmap"] = out["mmap_e2e_s"] / out["gids_e2e_s"]
    out["e2e_speedup_gids_async_vs_gids"] = (
        out["gids_e2e_s"] / out["gids-async_e2e_s"])
    out["e2e_speedup_gids_merged_vs_gids"] = (
        out["gids_e2e_s"] / out["gids-merged_e2e_s"])
    out["prep_speedup_gids_merged_vs_gids"] = (
        out["gids_exposed_prep_us"] / out["gids-merged_exposed_prep_us"])
    # merged-burst headline telemetry (steady-state window of the run)
    rep = reports["gids-merged"]
    out["merged_window_batches"] = rep.window_batches
    out["merged_window_requests"] = rep.window_requests
    out["merged_unique_rows"] = rep.n_unique
    out["merged_duplicate_rows_eliminated"] = rep.n_duplicate
    out["merged_dedup_factor"] = rep.dedup_factor
    out["merged_storage_unique_rows"] = rep.n_storage_unique
    out["merged_coalesced_ios"] = rep.n_storage_lines
    out["merged_coalesce_factor"] = rep.coalesce_factor
    return out


def main():
    for ssd in (SAMSUNG_980PRO, INTEL_OPTANE):
        fig = "fig13" if ssd is SAMSUNG_980PRO else "fig14"
        for ds in (IGB_FULL, OGBN_PAPERS100M):
            g = ds.materialize()
            t_train = train_step_time(g, (10, 5), 512)
            fits = ds is OGBN_PAPERS100M
            times, preps, reps = {}, {}, {}
            for m in ("mmap", "bam", "gids", "gids-async", "gids-merged"):
                times[m], preps[m], reps[m] = e2e(ds, ssd, m, t_train, fits,
                                                  iters=20, warmup=8)
            mrep = reps["gids-merged"]
            row(f"{fig}_{ds.name}_{ssd.name}", times["gids"] * 1e6,
                f"mmap_s={times['mmap']:.3f}_bam_s={times['bam']:.4f}"
                f"_gids_s={times['gids']:.4f}"
                f"_gids_async_s={times['gids-async']:.4f}"
                f"_gids_merged_s={times['gids-merged']:.4f}"
                f"_e2e_speedup_vs_mmap={times['mmap']/times['gids']:.1f}x"
                f"_vs_bam={times['bam']/times['gids']:.2f}x"
                f"_prep_speedup={preps['mmap']/max(preps['gids'],1e-9):.0f}x"
                f"_async_exposed_prep_s={preps['gids-async']:.6f}"
                f"_merged_dedup={mrep.dedup_factor:.2f}x"
                f"_merged_coalesce={mrep.coalesce_factor:.2f}x")

    # paper-scale projection: mini-batch 4096, fan-out (10,5,5) -> ~1M
    # feature requests/iter (the regime where the 582x headline lives);
    # prep times from the storage model at true IGB-Full row counts.
    from repro.core.storage_sim import StorageTimeline
    n_req = 4096 * (1 + 10 + 50 + 250)          # ~1.27M
    fb = IGB_FULL.feature_dim * 4
    t_train_scaled = 0.02                        # A100-class step (paper)
    cases = [  # (fig, dataset tag, ssd, n_ssd, unique requests)
        ("fig13", "IGB-Full", SAMSUNG_980PRO, 1, int(n_req * 0.75)),
        ("fig13", "IGBH-Full", SAMSUNG_980PRO, 2, int(n_req * 1.5)),
        ("fig14", "IGB-Full", INTEL_OPTANE, 1, int(n_req * 0.75)),
    ]
    for fig, tag, ssd, n_ssd, uniq in cases:
        tl = StorageTimeline(ssd, n_ssd=n_ssd)
        t_mmap = tl.mmap_batch_time(uniq, 0, fb)
        # GIDS at measured telemetry: ~50% hbm hits, ~25% host, rest SSD
        t_gids = tl.gids_batch_time(int(uniq * 0.25), int(uniq * 0.25),
                                    int(uniq * 0.5), fb,
                                    outstanding=50_000)
        row(f"{fig}_paperscale_{tag}_{ssd.name}", t_gids * 1e6,
            f"mmap_s={t_mmap + t_train_scaled:.1f}"
            f"_gids_s={t_gids + t_train_scaled:.3f}"
            f"_e2e_speedup={(t_mmap + t_train_scaled) / (t_gids + t_train_scaled):.0f}x")


if __name__ == "__main__":
    main()
