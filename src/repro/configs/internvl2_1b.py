"""internvl2-1b [vlm] — InternViT (stub) + Qwen2-0.5B-style backbone:
24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.  The vision frontend
is a STUB: `input_specs` provides precomputed patch embeddings (B, P, D)
prepended to the token sequence; in the GIDS integration these embeddings
are fetched from the tiered feature store by image id (they are exactly a
node-feature table). [arXiv:2404.16821; hf]
"""
import dataclasses
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b", family="vlm",
        num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
        d_ff=4864, vocab_size=151655,
        qkv_bias=True, tie_embeddings=True,
        frontend="vision_stub", frontend_tokens=256,
    )


def reduced_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, vocab_pad_to=64, frontend_tokens=8,
        remat=False)
