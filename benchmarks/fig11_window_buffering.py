"""Fig. 11 — GPU software-cache hit ratio + aggregation time vs window
buffer depth (0 = BaM random eviction baseline, 4, 8).

Paper: depth 4 -> 1.2x hit ratio, 1.04x aggregation; depth 8 -> 2.19x hit
ratio, 1.13x aggregation time."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row
from repro.core import GIDSDataLoader, LoaderConfig, INTEL_OPTANE
from repro.graph.datasets import IGB_FULL


def run(depth: int, iters=25):
    g = IGB_FULL.materialize()
    feats = np.zeros((g.num_nodes, 1), np.float32)
    dl = GIDSDataLoader(
        g, feats,
        LoaderConfig(batch_size=256, fanouts=(5, 5), data_plane="gids",
                     cache_lines=1 << 13, window_depth=depth,
                     cbuf_fraction=0.0),
        ssd=INTEL_OPTANE)
    dl.store.feature_dim = IGB_FULL.feature_dim
    ts = [dl.next_batch().prep_time_s for _ in range(iters)]
    return dl.store.cache.stats.hit_ratio, float(np.mean(ts[5:]))


def main():
    hit0, t0 = run(0)
    row("fig11_window0", t0 * 1e6, f"hit={hit0:.3f} (BaM random eviction)")
    for depth in (4, 8):
        hit, t = run(depth)
        row(f"fig11_window{depth}", t * 1e6,
            f"hit={hit:.3f}_hit_gain={hit/max(hit0,1e-9):.2f}x"
            f"_agg_speedup={t0/t:.2f}x")


if __name__ == "__main__":
    main()
