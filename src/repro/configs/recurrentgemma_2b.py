"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (MQA kv=1, hd=256)
d_ff=7680 vocab=256000; Griffin pattern 2 RG-LRU blocks : 1 local-attention
(window 2048) block -> 8 full (rec,rec,attn) groups + 2 trailing rec layers.
Runs long_500k (constant-size recurrent state + windowed attention).
[arXiv:2402.19427; hf]
"""
import dataclasses
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
        d_ff=7680, vocab_size=256000, head_dim=256,
        hybrid_attn_every=3, lru_width=2560, local_window=2048,
        tie_embeddings=True,
    )


def reduced_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=5, d_model=64, num_heads=4, num_kv_heads=1,
        d_ff=128, vocab_size=512, vocab_pad_to=64, head_dim=16,
        lru_width=64, local_window=16, remat=False)
