"""Multi-tenant online GNN serving: workload generation, deadline-bounded
window formation, merged-vs-per-request bit-identity, tenant cache
isolation, and the serve-gnn data-plane presets."""
import numpy as np
import pytest

from repro.core import (DataPlaneSpec, DeadlineWindowConfig,
                        DeadlineWindowPolicy, TenantCacheTier)
from repro.graph.csr import disjoint_union
from repro.graph.synthetic import rmat_graph, uniform_graph
from repro.serve import (GNNServeConfig, GNNServeEngine, SLOBatcher,
                         ServeRequest, TenantSpec, generate_stream,
                         mmpp_arrivals, poisson_arrivals)
from collections import deque


@pytest.fixture(scope="module")
def small_graph():
    return rmat_graph(2_000, 8, 16, seed=5)


@pytest.fixture(scope="module")
def small_feats(small_graph):
    return np.random.default_rng(1).standard_normal(
        (small_graph.num_nodes, 16)).astype(np.float32)


def _replay(requests):
    return [ServeRequest(r.rid, r.tenant, r.arrival_s, r.seeds.copy(),
                         r.deadline_s) for r in requests]


# -- workload ------------------------------------------------------------------

def test_stream_deterministic_and_arrival_ordered():
    tenants = (TenantSpec("a"), TenantSpec("b", arrival="mmpp"))
    s1 = generate_stream(1000, tenants, 5000, 60, seed=9)
    s2 = generate_stream(1000, tenants, 5000, 60, seed=9)
    assert len(s1) == len(s2) == 60
    for a, b in zip(s1, s2):
        assert (a.rid, a.tenant, a.arrival_s) == (b.rid, b.tenant, b.arrival_s)
        assert np.array_equal(a.seeds, b.seeds)
    arrivals = [r.arrival_s for r in s1]
    assert arrivals == sorted(arrivals)
    assert [r.rid for r in s1] == list(range(60))
    s3 = generate_stream(1000, tenants, 5000, 60, seed=10)
    assert any(not np.array_equal(a.seeds, b.seeds) for a, b in zip(s1, s3))


def test_mmpp_burstier_than_poisson():
    rng = np.random.default_rng(0)
    po = poisson_arrivals(1000, 4000, rng)
    mm = mmpp_arrivals(1000, 4000, np.random.default_rng(0),
                       burst_factor=8.0, burst_fraction=0.1, cycle_s=0.02)
    def cv2(arr):
        gaps = np.diff(arr)
        return gaps.var() / gaps.mean() ** 2
    assert cv2(mm) > 1.5 * cv2(po)          # Poisson has CV^2 ~= 1
    # same mean offered rate to ~15%
    assert mm[-1] == pytest.approx(po[-1], rel=0.15)


def test_node_range_confines_tenant_traffic():
    tenants = (TenantSpec("lo", node_range=(0, 500)),
               TenantSpec("hi", node_range=(500, 2000), hot_prob=0.0))
    stream = generate_stream(2000, tenants, 3000, 80, seed=2)
    for r in stream:
        lo, hi = ((0, 500) if r.tenant == 0 else (500, 2000))
        assert (r.seeds >= lo).all() and (r.seeds < hi).all()
    with pytest.raises(ValueError):
        TenantSpec("bad", node_range=(100, 50)).resolve_range(2000)


def test_disjoint_union_offsets_components():
    a = rmat_graph(300, 6, 8, seed=1)
    b = uniform_graph(200, 4, 8, seed=2)
    u = disjoint_union([a, b])
    assert u.num_nodes == 500
    assert u.num_edges == a.num_edges + b.num_edges
    # component A preserved verbatim, component B offset by |A|
    for v in (0, 7, 299):
        assert np.array_equal(u.neighbors(v), a.neighbors(v))
    for v in (0, 3, 199):
        assert np.array_equal(u.neighbors(300 + v), b.neighbors(v) + 300)
    # no cross-component edges
    assert (u.indices[:a.num_edges] < 300).all()
    assert (u.indices[a.num_edges:] >= 300).all()


# -- deadline windows ----------------------------------------------------------

def _mk(rid, arrival, deadline=10e-3, seeds=(1,)):
    return ServeRequest(rid=rid, tenant=0, arrival_s=arrival,
                        seeds=np.asarray(seeds, np.int64),
                        deadline_s=deadline)


def test_deadline_policy_close_by_and_ema():
    pol = DeadlineWindowPolicy(DeadlineWindowConfig(
        max_window=4, ema=0.5, init_request_s=1e-4, safety=2.0))
    # close_by = arrival + deadline - safety * est(n), floored at arrival
    assert pol.close_by(1.0, 10e-3, 2) == pytest.approx(1.0 + 10e-3 - 4e-4)
    assert pol.close_by(1.0, 1e-4, 4) == 1.0      # slack already spent
    assert pol.full(4) and not pol.full(3)
    pol.observe(8e-4, 4)                          # 2e-4 per request
    assert pol.est_request_s == pytest.approx(0.5 * 1e-4 + 0.5 * 2e-4)
    pol.reset()
    assert pol.est_request_s == 1e-4


def test_batcher_batches_within_slack():
    pol = DeadlineWindowPolicy(DeadlineWindowConfig(
        max_window=4, init_request_s=1e-4, safety=1.0))
    batcher = SLOBatcher(pol)
    pending = deque([_mk(0, 0.0), _mk(1, 1e-4), _mk(2, 2e-4)])
    d = batcher.next_window(pending, busy_until_s=0.0)
    assert [r.rid for r in d.staged] == [0, 1, 2] and not d.shed
    assert not d.hit_cap
    # the controller opens the window when the oldest's slack is spent
    assert d.start_s == pytest.approx(pol.close_by(0.0, 10e-3, 3))
    assert not pending


def test_batcher_closes_at_depth_cap():
    pol = DeadlineWindowPolicy(DeadlineWindowConfig(
        max_window=2, init_request_s=1e-4, safety=1.0))
    batcher = SLOBatcher(pol)
    pending = deque([_mk(i, i * 1e-5) for i in range(5)])
    d = batcher.next_window(pending, busy_until_s=0.0)
    assert [r.rid for r in d.staged] == [0, 1] and d.hit_cap
    # a full window starts as soon as the engine can take it
    assert d.start_s == pytest.approx(1e-5)
    assert len(pending) == 3


def test_batcher_far_future_arrival_yields_singleton():
    pol = DeadlineWindowPolicy(DeadlineWindowConfig(max_window=8))
    batcher = SLOBatcher(pol)
    pending = deque([_mk(0, 0.0), _mk(1, 5.0)])
    d = batcher.next_window(pending, busy_until_s=0.0)
    assert [r.rid for r in d.staged] == [0]
    assert len(pending) == 1


def test_batcher_sheds_expired_requests():
    pol = DeadlineWindowPolicy(DeadlineWindowConfig(max_window=4))
    batcher = SLOBatcher(pol)
    pending = deque([_mk(0, 0.0, deadline=1e-3), _mk(1, 0.0, deadline=9.0)])
    d = batcher.next_window(pending, busy_until_s=5.0)   # engine backlogged
    assert [r.rid for r in d.shed] == [0]                # hopeless: shed
    assert [r.rid for r in d.staged] == [1]
    # with shedding disabled the dead request is served anyway
    keep = SLOBatcher(DeadlineWindowPolicy(
        DeadlineWindowConfig(max_window=4)), shed_expired=False)
    pending = deque([_mk(0, 0.0, deadline=1e-3)])
    d = keep.next_window(pending, busy_until_s=5.0)
    assert [r.rid for r in d.staged] == [0] and not d.shed


# -- engine --------------------------------------------------------------------

def _stream(graph, n=60, qps=2000, deadline=20e-3, tenants=2, seed=4):
    specs = tuple(
        TenantSpec(f"t{i}", hot_fraction=0.05, hot_prob=0.8, mean_seeds=3,
                   deadline_s=deadline,
                   arrival="mmpp" if i % 2 else "poisson")
        for i in range(tenants))
    return generate_stream(graph.num_nodes, specs, qps, n, seed=seed)


def test_merged_and_per_request_bit_identical(small_graph, small_feats):
    """Merging changes latency, never results: same stream, same sampled
    blocks, same feature rows, in both execution modes."""
    stream = _stream(small_graph)
    results = {}
    for merged in (True, False):
        engine = GNNServeEngine(small_graph, small_feats, GNNServeConfig(
            merged=merged, tenants=2, cache_lines=512, keep_features=True,
            seed=7))
        results[merged] = engine.run(_replay(stream))
    recs_m = {r.rid: r for r in results[True].records}
    recs_p = {r.rid: r for r in results[False].records}
    assert set(recs_m) == set(recs_p) == {r.rid for r in stream}
    served_both = 0
    for rid in recs_m:
        a, b = recs_m[rid], recs_p[rid]
        if a.rejected or b.rejected:
            continue
        served_both += 1
        assert np.array_equal(a.all_nodes, b.all_nodes)
        assert np.array_equal(a.features, b.features)
        assert np.array_equal(a.features, small_feats[a.all_nodes])
    assert served_both >= 50          # low load: nearly everything served


def test_every_request_retires_exactly_once(small_graph, small_feats):
    stream = _stream(small_graph, n=80, qps=30_000, deadline=2e-3)
    engine = GNNServeEngine(small_graph, small_feats,
                            GNNServeConfig(tenants=2, cache_lines=512,
                                           seed=7))
    res = engine.run(_replay(stream))
    assert sorted(r.rid for r in res.records) == [r.rid for r in stream]
    for r in res.served:
        assert r.completion_s >= r.start_s >= r.arrival_s
        assert r.window_size >= 1
        bd_sum = r.gather_s + r.forward_s
        assert r.latency_s >= bd_sum - 1e-12


def test_overload_sheds_and_counts_against_goodput(small_graph, small_feats):
    # everything arrives at once with a deadline far smaller than the
    # backlog: most requests must be shed, none silently dropped
    stream = _stream(small_graph, n=120, qps=2_000_000, deadline=5e-4)
    engine = GNNServeEngine(small_graph, small_feats,
                            GNNServeConfig(tenants=2, cache_lines=512,
                                           seed=7))
    res = engine.run(_replay(stream))
    assert len(res.records) == 120
    assert res.n_rejected > 0
    for r in res.records:
        if r.rejected:
            assert r.completion_s == 0.0 and not r.deadline_met
    met = sum(r.deadline_met for r in res.records)
    assert met < 120                      # goodput strictly below offered


def test_windows_form_under_load(small_graph, small_feats):
    stream = _stream(small_graph, n=80, qps=20_000)
    engine = GNNServeEngine(small_graph, small_feats,
                            GNNServeConfig(tenants=2, cache_lines=512,
                                           seed=7))
    res = engine.run(_replay(stream))
    assert res.mean_window > 1.5          # merging actually happened
    assert any(w.dedup_factor > 1.0 for w in res.windows)
    # tenant-pure windows: every window's records share one tenant
    by_window = {}
    for r in res.served:
        by_window.setdefault((r.start_s, r.completion_s), set()).add(r.tenant)
    assert all(len(t) == 1 for t in by_window.values())


def test_engine_reset_replays_bit_identically(small_graph, small_feats):
    stream = _stream(small_graph, n=40)
    engine = GNNServeEngine(small_graph, small_feats,
                            GNNServeConfig(tenants=2, cache_lines=512,
                                           seed=7))
    r1 = engine.run(_replay(stream))
    engine.reset()
    r2 = engine.run(_replay(stream))
    assert [(r.rid, r.completion_s) for r in r1.records] == \
        [(r.rid, r.completion_s) for r in r2.records]


# -- tenant cache isolation ----------------------------------------------------

def test_tenant_cache_partitions_are_isolated():
    tier = TenantCacheTier(num_lines=64, ways=8, tenants=2, seed=0)
    victim = np.arange(0, 24)
    noisy = np.arange(1000, 1480)
    tier.stage_tenants(np.zeros(len(victim), np.int64))
    tier.probe(victim)                               # cold fill
    tier.stage_tenants(np.zeros(len(victim), np.int64))
    assert tier.probe(victim).all()                  # resident
    # the noisy tenant storms its partition far past total capacity
    for chunk in np.split(noisy, 8):
        tier.stage_tenants(np.ones(len(chunk), np.int64))
        tier.probe(chunk)
    tier.stage_tenants(np.zeros(len(victim), np.int64))
    assert tier.probe(victim).all()                  # hot set untouched
    assert tier.hit_ratio(0) > tier.hit_ratio(1)


def test_tenant_cache_quota_sizing_and_staging_contract():
    tier = TenantCacheTier(num_lines=96, ways=8, tenants=3,
                           quotas=(2.0, 1.0, 1.0), seed=0)
    lines = [tier.partition_lines(t) for t in range(3)]
    assert all(n % 8 == 0 and n >= 8 for n in lines)
    assert lines[0] >= lines[1] == lines[2]
    with pytest.raises(ValueError):
        tier.stage_tenants(np.array([3]))            # tenant out of range
    tier.stage_tenants(np.array([0, 1]))
    with pytest.raises(ValueError):
        tier.probe(np.array([1, 2, 3]))              # length mismatch
    with pytest.raises(ValueError):
        TenantCacheTier(num_lines=64, ways=8, tenants=2, quotas=(1.0,))


def test_serve_gnn_presets(small_graph, small_feats):
    plane = DataPlaneSpec.preset("serve-gnn").build(
        small_graph, small_feats, cache_lines=256, tenants=2,
        tenant_quotas=(3.0, 1.0), seed=0)
    first = plane.store.tiers[0]
    assert isinstance(first, TenantCacheTier)
    assert first.tenants == 2
    assert first.partition_lines(0) > first.partition_lines(1)
    shared = DataPlaneSpec.preset("serve-gnn-shared").build(
        small_graph, small_feats, cache_lines=256, seed=0)
    assert not any(isinstance(t, TenantCacheTier) for t in shared.store.tiers)


def test_partitioned_victim_hit_ratio_beats_shared(small_graph, small_feats):
    """Engine-level isolation: with a scanning co-tenant, the victim's hit
    ratio in its guaranteed partition stays high."""
    specs = (TenantSpec("victim", hot_fraction=0.01, hot_prob=0.95,
                        mean_seeds=3, deadline_s=50e-3,
                        node_range=(0, 1000)),
             TenantSpec("noisy", hot_fraction=0.9, hot_prob=0.0,
                        mean_seeds=6, deadline_s=50e-3,
                        node_range=(1000, 2000)))
    stream = generate_stream(small_graph.num_nodes, specs, 4000, 120, seed=3)
    engine = GNNServeEngine(small_graph, small_feats, GNNServeConfig(
        tenants=2, cache_lines=512, tenant_quotas=(1.0, 1.0), seed=7))
    engine.run(_replay(stream))
    tier = engine._tenant_tier
    assert tier is not None
    assert tier.hit_ratio(0) > tier.hit_ratio(1)


# -- gather correctness property (any arrival pattern / tenant mix) ------------

def _assert_serve_rows_exact(graph, feats, stream):
    engine = GNNServeEngine(graph, feats, GNNServeConfig(
        tenants=max(r.tenant for r in stream) + 1, cache_lines=512,
        keep_features=True, seed=7))
    res = engine.run(_replay(stream))
    assert sorted(r.rid for r in res.records) == [r.rid for r in stream]
    served = res.served
    assert served
    for rec in served:
        assert np.array_equal(rec.features, feats[rec.all_nodes])


def test_serve_rows_match_direct_gather(small_graph, small_feats):
    _assert_serve_rows_exact(small_graph, small_feats,
                             _stream(small_graph, n=50, qps=8000))


def test_serve_rows_property_hypothesis(small_graph, small_feats):
    """Satellite property: under ANY arrival pattern and tenant mix, the
    feature rows each request receives from the serve path are bit-identical
    to gathering that request alone against the raw feature array."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(0, 10_000),
           qps=st.sampled_from([500, 5_000, 50_000]),
           n_tenants=st.integers(1, 3),
           deadline_ms=st.sampled_from([1.0, 5.0, 50.0]),
           bursty=st.booleans())
    def prop(seed, qps, n_tenants, deadline_ms, bursty):
        specs = tuple(
            TenantSpec(f"t{i}", hot_fraction=0.02 + 0.03 * i,
                       hot_prob=0.5 + 0.15 * i, mean_seeds=2 + i,
                       deadline_s=deadline_ms * 1e-3,
                       arrival="mmpp" if bursty and i % 2 else "poisson")
            for i in range(n_tenants))
        stream = generate_stream(small_graph.num_nodes, specs, qps, 30,
                                 seed=seed)
        _assert_serve_rows_exact(small_graph, small_feats, stream)

    prop()


def test_serve_runs_real_gnn_forward(small_graph, small_feats):
    jax = pytest.importorskip("jax")
    from repro.models.gnn import GNN, GNNConfig
    cfg = GNNConfig(model="sage", in_dim=16, hidden_dim=8, num_classes=5,
                    fanouts=(3, 2), use_pallas=False)
    gnn = GNN(cfg)
    params = gnn.init(jax.random.PRNGKey(0))
    stream = _stream(small_graph, n=6, qps=500)
    engine = GNNServeEngine(small_graph, small_feats, GNNServeConfig(
        fanouts=(3, 2), tenants=2, cache_lines=512, seed=7),
        model=gnn, params=params)
    res = engine.run(_replay(stream))
    for rec, req in zip(res.records, sorted(stream, key=lambda r: r.rid)):
        if rec.rejected:
            continue
        assert rec.logits is not None
        assert rec.logits.shape == (len(req.seeds), 5)
