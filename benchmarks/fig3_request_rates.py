"""Fig. 3 — feature-request generation rate of data preparation (host vs
device sampler) vs the training kernels' consumption rate.

Paper (A100 + EPYC): CPU prep 4.1 M req/s, GPU prep 77 M req/s, training
consumes 29 M req/s -> only device-side prep keeps the accelerator fed.
Here both run on one CPU core, so absolute numbers shrink together; the
reported quantity is the RATIO (device-prep / consumption), which must stay
>= 1 for the paper's conclusion to hold in this build.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.graph.synthetic import rmat_graph
from repro.models.gnn import GNN, GNNConfig, hop_indices
from repro.sampling.neighbor import (device_sample_blocks,
                                     host_sample_blocks, subgraph_sizes)


def main(batch=1024, fanouts=(10, 5)):
    g = rmat_graph(250_000, 12, 64, seed=0, name="igb-small-like")
    rng = np.random.default_rng(0)
    seeds = rng.integers(0, g.num_nodes, batch)
    n_req = subgraph_sizes(batch, fanouts)

    t_host = timeit(lambda: host_sample_blocks(g, seeds, fanouts, rng))
    host_rate = n_req / t_host

    csr = g.to_device()
    dseeds = jnp.asarray(seeds, jnp.int32)
    samp = jax.jit(lambda s, k: device_sample_blocks(csr, s, fanouts, k)[1])
    key = jax.random.PRNGKey(0)
    t_dev = timeit(lambda: samp(dseeds, key).block_until_ready())
    dev_rate = n_req / t_dev

    # consumption: GraphSAGE train step on the gathered features
    cfg = GNNConfig(model="sage", in_dim=64, hidden_dim=128, num_classes=47,
                    fanouts=fanouts, use_pallas=False)
    gnn = GNN(cfg)
    params = gnn.init(jax.random.PRNGKey(0))
    blocks = host_sample_blocks(g, seeds, fanouts, rng)
    feats = jnp.asarray(
        rng.standard_normal((len(blocks.all_nodes), 64)).astype(np.float32))
    hi = [jnp.asarray(i) for i in hop_indices(blocks)]
    labels = jnp.asarray(rng.integers(0, 47, batch))

    @jax.jit
    def train_step(p, f, h0, h1, h2, y):
        l, gr = jax.value_and_grad(gnn.loss)(p, f, [h0, h1, h2], y)
        return jax.tree.map(lambda a, b: a - 1e-3 * b, p, gr), l

    t_train = timeit(
        lambda: jax.block_until_ready(
            train_step(params, feats, hi[0], hi[1], hi[2], labels)))
    consume_rate = n_req / t_train

    row("fig3_host_prep_rate", t_host * 1e6,
        f"req_per_s={host_rate:,.0f}")
    row("fig3_device_prep_rate", t_dev * 1e6,
        f"req_per_s={dev_rate:,.0f}")
    row("fig3_train_consume_rate", t_train * 1e6,
        f"req_per_s={consume_rate:,.0f}")
    row("fig3_device_over_consume", 0.0,
        f"ratio={dev_rate / consume_rate:.2f}_host_ratio="
        f"{host_rate / consume_rate:.2f}")


if __name__ == "__main__":
    main()
