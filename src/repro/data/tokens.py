"""LM token pipeline fed by the GIDS prefetch machinery.

The paper's dataloader problem — keep accelerators fed from storage that is
slower than the compute — recurs in LM pretraining.  The same three pieces
apply and are reused directly:

  * storage tier: token shards live in memmapped files (the SSD namespace);
  * accumulator: Little's-law dispatch-ahead depth controls how many batch
    fetches are in flight (`DynamicAccessAccumulator`);
  * prefetch queue: sequences for future steps are staged ahead of the
    train loop exactly like sampled sub-graphs.

For the VLM/audio archs the per-example modality embeddings (patch/frame
tables) are fetched through the tiered `FeatureStore` — an embedding table
indexed by example id IS a node-feature table.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from pathlib import Path

import numpy as np

from repro.core.accumulator import AccumulatorConfig, DynamicAccessAccumulator
from repro.core.feature_store import FeatureStore
from repro.core.storage_sim import INTEL_OPTANE, SSDSpec


@dataclasses.dataclass
class TokenPipelineConfig:
    batch_size: int = 8
    seq_len: int = 1024
    vocab_size: int = 32000
    prefetch_depth: int = 4
    seed: int = 0
    # modality sidecar (vlm/audio): rows fetched per example from the store
    modality_dim: int = 0
    modality_tokens: int = 0


class TokenPipeline:
    """Iterates (tokens, labels[, modality]) batches from a memmap shard."""

    def __init__(self, shard_path: str | Path | None,
                 cfg: TokenPipelineConfig,
                 ssd: SSDSpec = INTEL_OPTANE,
                 modality_store: FeatureStore | None = None,
                 num_tokens: int = 1 << 22):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        if shard_path is None:                        # synthetic shard
            self.tokens = self.rng.integers(
                0, cfg.vocab_size, num_tokens).astype(np.int32)
        else:
            self.tokens = np.memmap(shard_path, dtype=np.int32, mode="r")
        self.modality_store = modality_store
        self.accumulator = DynamicAccessAccumulator(
            ssd, AccumulatorConfig(max_merge_iters=cfg.prefetch_depth))
        self._queue: deque = deque()
        self._cursor = 0

    def _snapshot(self) -> dict:
        return {"cursor": self._cursor,
                "rng": self.rng.bit_generator.state}

    def _fetch_one(self) -> dict:
        cfg = self.cfg
        n = cfg.batch_size * (cfg.seq_len + 1)
        if self._cursor + n > len(self.tokens):
            self._cursor = 0
        window = np.asarray(self.tokens[self._cursor:self._cursor + n])
        self._cursor += n
        window = window.reshape(cfg.batch_size, cfg.seq_len + 1)
        batch = {"tokens": window[:, :-1].copy(),
                 "labels": window[:, 1:].copy()}
        if self.modality_store is not None and cfg.modality_tokens:
            ids = self.rng.integers(0, self.modality_store.features.shape[0],
                                    cfg.batch_size * cfg.modality_tokens)
            rows, report = self.modality_store.gather(np.unique(ids))
            # re-expand to per-example layout
            lut = {u: i for i, u in enumerate(np.unique(ids))}
            take = np.array([lut[i] for i in ids])
            batch["patches"] = rows[take].reshape(
                cfg.batch_size, cfg.modality_tokens, -1)
            self.accumulator.update(report.n_requests, report.redirected)
        return batch

    def _refill(self) -> None:
        bytes_per = self.cfg.batch_size * self.cfg.seq_len * 4
        depth = max(self.cfg.prefetch_depth,
                    self.accumulator.merge_depth(max(bytes_per // 4096, 1)))
        depth = min(depth, 4 * self.cfg.prefetch_depth)
        while len(self._queue) < depth:
            # snapshot BEFORE fetching: checkpoints must record the logical
            # consumption position, not the prefetch frontier — otherwise a
            # restart silently skips every batch that was in flight.
            self._queue.append((self._snapshot(), self._fetch_one()))

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        self._refill()
        return self._queue.popleft()[1]

    # checkpointable logical position (fault tolerance)
    def state_dict(self) -> dict:
        return self._queue[0][0] if self._queue else self._snapshot()

    def load_state_dict(self, st: dict) -> None:
        self._cursor = st["cursor"]
        self.rng.bit_generator.state = st["rng"]
        self._queue.clear()
