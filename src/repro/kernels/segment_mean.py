"""Pallas TPU kernel: fixed-fanout neighbor aggregation (GraphSAGE mean).

After fixed-fanout sampling each destination node has exactly F (padded)
sampled neighbors, so the paper's segment aggregation becomes a gather +
mean over a (B, F) index matrix into an (N, D) feature table.

Grid: (B, D // bd, F) with the reduction dim F innermost: the output block
(1, bd) for destination b is revisited on *consecutive* steps and accumulated
in place (TPU grids execute sequentially; consecutive revisits keep the block
resident in VMEM — the idiomatic Pallas reduction pattern).  Neighbor rows
are DMA'd one at a time via scalar-prefetched indices — the same indirection
trick as `tiered_gather`.

Inputs
  idx:   (B, F) int32 neighbor ids (rows of `feats`)
  feats: (N, D)
Output
  out:   (B, D) = mean_f feats[idx[b, f]]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_pf, nbr_blk, out_ref, *, fanout: int):
    f = pl.program_id(2)  # innermost: consecutive revisits of the out block

    @pl.when(f == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += nbr_blk[...].astype(out_ref.dtype) / fanout


def segment_mean(idx: jax.Array, feats: jax.Array, *, block_d: int = 512,
                 interpret: bool = False) -> jax.Array:
    B, F = idx.shape
    _, D = feats.shape
    bd = min(block_d, D)
    assert D % bd == 0, (D, bd)

    def nbr_index(b, j, f, idx_pf):
        return (idx_pf[b * F + f], j)

    def out_index(b, j, f, idx_pf):
        del f, idx_pf
        return (b, j)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, D // bd, F),
        in_specs=[pl.BlockSpec((1, bd), nbr_index)],
        out_specs=pl.BlockSpec((1, bd), out_index),
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, fanout=F),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.float32),
        interpret=interpret,
        name="segment_mean",
    )
    return fn(idx.reshape(-1), feats).astype(feats.dtype)


segment_mean_cpu = functools.partial(segment_mean, interpret=True)
