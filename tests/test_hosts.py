"""Multi-host distributed data plane: link-priced host bursts, the
co-partitioned placement (one decision for features AND edge pages),
metis-lite min-cut growth, requester-model remote accounting, the
n_hosts=1 degeneracy (bit-identical to the single-host plane), topology
fault injection, host-level failure domains for replica spread, and
checkpoint round-trips of the whole host stack."""
import numpy as np
import pytest

from repro.core import (BrownoutEvent, CoPartitionedPlacement, FaultSchedule,
                        GIDSDataLoader, HostBurstResult, HostLinkSpec,
                        HostShardTier, LoaderConfig, NIC_100GBE, NIC_400GBE,
                        OutageEvent, ReplicatedPlacement, SAMSUNG_980PRO,
                        StorageTimeline, cut_edge_fraction, default_hosts,
                        make_placement, price_sharded_burst, requester_hosts)
from repro.core.hosts import independent_hosts
from repro.core.sharding import MetisLitePlacement, _grow_partitions
from repro.core.storage_sim import IO_BYTES
from repro.graph.synthetic import clustered_graph, rmat_graph


@pytest.fixture(scope="module")
def graph_and_feats():
    g = clustered_graph(8_000, 10, 16, communities=16, intra=0.9, seed=1)
    feats = np.random.default_rng(0).standard_normal(
        (g.num_nodes, 16)).astype(np.float32)
    return g, feats


def _mk(g, feats, plane="gids-hosts-merged", **kw):
    cfg = dict(batch_size=128, fanouts=(4, 3), data_plane=plane,
               cache_lines=128, window_depth=2, seed=3)
    cfg.update(kw)
    return GIDSDataLoader(g, feats, LoaderConfig(**cfg), ssd=SAMSUNG_980PRO)


def _batches(dl, n=6):
    return [b for _, b in zip(range(n), dl)]


def _blocks_equal(a, b):
    return (np.array_equal(a.seeds, b.seeds)
            and np.array_equal(a.all_nodes, b.all_nodes)
            and all(np.array_equal(x, y)
                    for x, y in zip(a.hop_nodes, b.hop_nodes)))


# -- host specs ----------------------------------------------------------------

def test_default_hosts_and_with_ssd():
    hosts = default_hosts(3)
    assert len(hosts) == 3
    assert all(h.link_bw == NIC_100GBE.link_bw for h in hosts)
    assert len({h.name for h in hosts}) == 3
    assert hosts[0].ssd is None
    filled = hosts[0].with_ssd(SAMSUNG_980PRO)
    assert filled.ssd is SAMSUNG_980PRO and hosts[0].ssd is None


def test_host_tier_spec_arity_validation(graph_and_feats):
    g, feats = graph_and_feats
    pol = make_placement("hash", 4, num_nodes=g.num_nodes)
    with pytest.raises(ValueError, match="host specs"):
        HostShardTier(feats, pol, hosts=default_hosts(3), graph=g)


# -- price_host_burst ----------------------------------------------------------

def test_price_host_burst_needs_host_specs():
    tl = StorageTimeline(SAMSUNG_980PRO)
    with pytest.raises(ValueError, match="host_specs"):
        tl.price_host_burst((10, 10), (5, 5), 64)


def test_zero_remote_prices_identical_to_sharded_burst():
    tl = StorageTimeline(SAMSUNG_980PRO,
                         shard_specs=(SAMSUNG_980PRO, SAMSUNG_980PRO))
    tl.host_specs = tuple(h.with_ssd(SAMSUNG_980PRO)
                          for h in default_hosts(2))
    rows, lines = (100, 140), (40, 55)
    host = tl.price_host_burst(rows, lines, 64, remote_lines=(0, 0))
    plain = price_sharded_burst((SAMSUNG_980PRO,) * 2, rows, lines, 64)
    assert isinstance(host, HostBurstResult)
    assert host.per_shard_s == plain.per_shard_s  # bit-equal, not approx
    assert host.elapsed_s == plain.elapsed_s
    assert host.link_s == (0.0, 0.0)
    assert host.remote_fraction == 0.0


def test_link_term_math_and_straggler():
    link = HostLinkSpec("test-link", link_bw=1e9, link_rtt_s=5e-6,
                        ssd=SAMSUNG_980PRO)
    tl = StorageTimeline(SAMSUNG_980PRO,
                         shard_specs=(SAMSUNG_980PRO, SAMSUNG_980PRO))
    tl.host_specs = (link, link)
    rows, lines, remote = (100, 100), (40, 40), (0, 30)
    burst = tl.price_host_burst(rows, lines, 64, remote_lines=remote)
    expected_link = 5e-6 + 30 * IO_BYTES / 1e9
    assert burst.link_s[0] == 0.0
    assert burst.link_s[1] == pytest.approx(expected_link)
    # the host serving remote lines is the straggler and sets elapsed
    assert burst.per_shard_s[1] == burst.local_s[1] + burst.link_s[1]
    assert burst.straggler == 1
    assert burst.elapsed_s == max(burst.per_shard_s)
    assert burst.remote_fraction == pytest.approx(30 / 80)
    # the pre-link result is preserved for fault/retry telemetry
    assert burst.local_burst is not None
    assert burst.local_burst.per_shard_s == burst.local_s


def test_faster_link_drains_faster():
    tl = StorageTimeline(SAMSUNG_980PRO,
                         shard_specs=(SAMSUNG_980PRO, SAMSUNG_980PRO))
    rows, lines, remote = (200, 200), (80, 80), (50, 50)
    times = {}
    for link in (NIC_100GBE, NIC_400GBE):
        tl.host_specs = tuple(h.with_ssd(SAMSUNG_980PRO)
                              for h in default_hosts(2, link=link))
        times[link.name] = tl.price_host_burst(
            rows, lines, 64, remote_lines=remote).elapsed_s
    assert times[NIC_400GBE.name] < times[NIC_100GBE.name]


# -- metis-lite placement ------------------------------------------------------

def test_metis_lite_needs_graph():
    with pytest.raises(ValueError, match="CSR adjacency"):
        MetisLitePlacement(4, num_nodes=100)


def test_metis_lite_balance_and_cut(graph_and_feats):
    g, _ = graph_and_feats
    pol = MetisLitePlacement(4, graph=g)
    tab = pol.shard_of(np.arange(g.num_nodes))
    assert set(np.unique(tab)) <= set(range(4))
    # balanced by edge mass (sampling-load proxy), not node count
    indeg = np.bincount(g.indices, minlength=g.num_nodes)
    w = 1 + indeg + np.diff(g.indptr)
    masses = np.bincount(tab, weights=w, minlength=4)
    assert masses.max() <= 1.2 * masses.min()
    cut_metis = cut_edge_fraction(g.indptr, g.indices, tab)
    hash_tab = make_placement("hash", 4, num_nodes=g.num_nodes).shard_of(
        np.arange(g.num_nodes))
    cut_hash = cut_edge_fraction(g.indptr, g.indices, hash_tab)
    # the gate property: grown partitions find the community structure
    assert cut_metis < 0.5 * cut_hash


def test_metis_lite_deterministic_and_state_roundtrip(graph_and_feats):
    g, _ = graph_and_feats
    a = MetisLitePlacement(4, graph=g)
    b = MetisLitePlacement(4, graph=g)
    ids = np.arange(g.num_nodes)
    assert np.array_equal(a.shard_of(ids), b.shard_of(ids))
    fresh = MetisLitePlacement(4, indptr=g.indptr, indices=g.indices)
    fresh.load_state_dict(a.state_dict())
    assert np.array_equal(fresh.shard_of(ids), a.shard_of(ids))


def test_grow_partitions_degenerate_cases():
    tab = _grow_partitions(np.array([0, 0, 0]), np.array([], np.int64), 1)
    assert np.array_equal(tab, [0, 0])
    # isolated nodes still all get assigned
    tab = _grow_partitions(np.zeros(9, np.int64), np.array([], np.int64), 4)
    assert (tab >= 0).all() and (tab < 4).all()


# -- co-partitioned placement --------------------------------------------------

def test_co_partition_agreement_and_fallthrough(graph_and_feats):
    g, _ = graph_and_feats
    base = MetisLitePlacement(4, graph=g)
    co = CoPartitionedPlacement(base)
    ids = np.arange(g.num_nodes)
    assert np.array_equal(co.shard_of(ids), co.topology_host_of(ids))
    assert co.n_shards == 4 and "metis-lite" in co.name
    # fallthrough to the base policy's state
    assert np.array_equal(co.table, base.table)
    st = co.state_dict()
    fresh = CoPartitionedPlacement(
        MetisLitePlacement(4, indptr=g.indptr, indices=g.indices))
    fresh.load_state_dict(st)
    assert np.array_equal(fresh.shard_of(ids), co.shard_of(ids))
    with pytest.raises(ValueError, match="does not match"):
        CoPartitionedPlacement(make_placement(
            "hash", 4, num_nodes=g.num_nodes)).load_state_dict(st)


def test_page_host_follows_first_edge_owner(graph_and_feats):
    g, _ = graph_and_feats
    co = CoPartitionedPlacement(MetisLitePlacement(4, graph=g))
    page_words = IO_BYTES // g.indices.dtype.itemsize
    pages = co.page_host_of(g.indptr, len(g.indices), page_words)
    n_pages = -(-len(g.indices) // page_words)
    assert pages.shape == (n_pages,)
    first_owner = np.searchsorted(
        np.asarray(g.indptr, np.int64),
        np.arange(n_pages, dtype=np.int64) * page_words, side="right") - 1
    assert np.array_equal(pages, co.shard_of(first_owner))


def test_requester_ties_break_to_own_host():
    # 0 -> 2, 1 -> 2: node 2's in-vote ties between hosts 0 and 1; node 3
    # has no in-edges at all — both stay with their own adjacency host
    indptr = np.array([0, 1, 2, 2, 2], np.int64)
    indices = np.array([2, 2], np.int64)
    topo = np.array([0, 1, 1, 2], np.int16)
    req = requester_hosts(indptr, indices, topo, 3)
    assert req[2] == 1 and req[3] == 2
    # a one-host cluster degenerates to the identity
    assert np.array_equal(requester_hosts(indptr, indices, topo, 1), topo)


def test_independent_hosts_decorrelated_from_hash():
    n = 4096
    topo = independent_hosts(n, 4, seed=0)
    feat = make_placement("hash", 4, num_nodes=n).shard_of(np.arange(n))
    assert set(np.unique(topo)) == set(range(4))
    agree = np.mean(topo == feat)
    assert 0.15 < agree < 0.35  # ~1/4 if truly decorrelated
    assert not np.array_equal(independent_hosts(n, 4, seed=1), topo)


# -- the host tier -------------------------------------------------------------

def test_host_tier_tables_and_telemetry(graph_and_feats):
    g, feats = graph_and_feats
    pol = MetisLitePlacement(4, graph=g)
    tier = HostShardTier(feats, pol, graph=g)
    ids = np.arange(g.num_nodes)
    assert tier.n_hosts == 4 and tier.co_partition
    assert np.array_equal(tier.topo_host_of(ids), tier.placement.shard_of(ids))
    assert 0.0 < tier.cut_edge_fraction() < 0.5
    assert 0.0 <= tier.remote_fraction() < 0.5
    # remote mask: rows served by their requester's host are local
    req = tier.requester_of(ids)
    assert not tier.remote_mask(ids, req).any()
    assert tier.remote_mask(ids, (req + 1) % 4).all()
    # page assignment rides the SAME host table
    pages = tier.topology_page_shard()
    page_words = IO_BYTES // g.indices.dtype.itemsize
    assert pages.shape == (-(-len(g.indices) // page_words),)
    specs = tier.resolve_hosts(SAMSUNG_980PRO)
    assert all(h.ssd is SAMSUNG_980PRO for h in specs)
    assert tier.resolve_shard_specs(SAMSUNG_980PRO) == (SAMSUNG_980PRO,) * 4


def test_independent_tier_decouples_namespaces(graph_and_feats):
    g, feats = graph_and_feats
    pol = make_placement("hash", 4, num_nodes=g.num_nodes)
    tier = HostShardTier(feats, pol, graph=g, co_partition=False)
    ids = np.arange(g.num_nodes)
    assert not tier.co_partition
    assert not np.array_equal(tier.topo_host_of(ids),
                              tier.placement.shard_of(ids))


# -- the loader: bit-identity and the placement payoff -------------------------

def test_one_host_plane_identical_to_single_host(graph_and_feats):
    g, feats = graph_and_feats
    ref = _batches(_mk(g, feats, plane="gids-merged"))
    one = _batches(_mk(g, feats, n_hosts=1))
    for a, b in zip(ref, one):
        assert np.array_equal(a.features, b.features)
        assert _blocks_equal(a.blocks, b.blocks)
        assert a.exposed_prep_s == b.exposed_prep_s  # modelled time too
        assert a.prep_time_s == b.prep_time_s


def test_features_bit_identical_across_host_counts(graph_and_feats):
    g, feats = graph_and_feats
    ref = _batches(_mk(g, feats, plane="gids-merged"))
    for n_hosts in (2, 4):
        for placement in ("hash", "metis-lite"):
            for co in (True, False):
                got = _batches(_mk(g, feats, n_hosts=n_hosts,
                                   placement=placement, co_partition=co))
                for a, b in zip(ref, got):
                    assert np.array_equal(a.features, b.features)
                    assert _blocks_equal(a.blocks, b.blocks)


def test_min_cut_co_partition_beats_hash_independent(graph_and_feats):
    g, feats = graph_and_feats
    win = _batches(_mk(g, feats, n_hosts=4, placement="metis-lite",
                       co_partition=True), n=10)
    lose = _batches(_mk(g, feats, n_hosts=4, placement="hash",
                        co_partition=False), n=10)
    t_win = np.mean([b.exposed_prep_s for b in win[4:]])
    t_lose = np.mean([b.exposed_prep_s for b in lose[4:]])
    assert t_win < t_lose


def test_host_plane_wires_timeline_and_reports(graph_and_feats):
    g, feats = graph_and_feats
    dl = _mk(g, feats, n_hosts=4, placement="metis-lite")
    assert dl.timeline.host_specs is not None
    assert len(dl.timeline.host_specs) == 4
    _batches(dl)
    burst = dl.timeline.shard_burst
    assert isinstance(burst, HostBurstResult)
    assert len(burst.link_s) == 4
    assert burst.remote_fraction > 0.0


# -- satellite: topology fault injection ---------------------------------------

def test_empty_schedule_bit_invisible_on_topology_path(graph_and_feats):
    g, feats = graph_and_feats
    kw = dict(plane="gids-topo-merged", n_shards=4, placement="hash")
    clean = _batches(_mk(g, feats, **kw))
    empty = _batches(_mk(g, feats, fault_schedule=FaultSchedule(events=()),
                         **kw))
    for a, b in zip(clean, empty):
        assert np.array_equal(a.features, b.features)
        assert a.exposed_prep_s == b.exposed_prep_s
        assert a.sample_time_s == b.sample_time_s


def test_topology_brownout_slows_sampling_not_data(graph_and_feats):
    g, feats = graph_and_feats
    kw = dict(plane="gids-topo-merged", n_shards=4, placement="hash")
    clean = _batches(_mk(g, feats, **kw))
    sched = FaultSchedule(events=(
        BrownoutEvent(shard=0, start=0, end=1000, multiplier=8.0),))
    slow = _batches(_mk(g, feats, fault_schedule=sched, **kw))
    for a, b in zip(clean, slow):
        assert np.array_equal(a.features, b.features)
        assert _blocks_equal(a.blocks, b.blocks)
    assert sum(b.sample_time_s for b in slow) \
        > sum(b.sample_time_s for b in clean)


def test_unsharded_topology_brownout_also_priced(graph_and_feats):
    g, feats = graph_and_feats
    kw = dict(plane="gids-topo-merged", n_shards=1, placement="range")
    clean = _batches(_mk(g, feats, **kw))
    sched = FaultSchedule(events=(
        BrownoutEvent(shard=0, start=0, end=1000, multiplier=8.0),))
    slow = _batches(_mk(g, feats, fault_schedule=sched, **kw))
    for a, b in zip(clean, slow):
        assert np.array_equal(a.features, b.features)
    assert sum(b.sample_time_s for b in slow) \
        > sum(b.sample_time_s for b in clean)


# -- satellite: host-level failure domains -------------------------------------

def test_replica_spread_across_hosts(graph_and_feats):
    g, _ = graph_and_feats
    base = MetisLitePlacement(4, graph=g)
    pol = ReplicatedPlacement(base, 2, failure_domains=np.arange(4))
    reps = pol.replicas_of(np.arange(g.num_nodes))
    # every row's copies live on DISTINCT hosts (= failure domains)
    assert (reps[:, 0] != reps[:, 1]).all()
    # distinct-domain case matches chained declustering bit-for-bit
    plain = ReplicatedPlacement(MetisLitePlacement(4, graph=g), 2)
    assert np.array_equal(reps, plain.replicas_of(np.arange(g.num_nodes)))


def test_failure_domain_validation(graph_and_feats):
    g, _ = graph_and_feats
    base = MetisLitePlacement(4, graph=g)
    with pytest.raises(ValueError, match="failure domain"):
        ReplicatedPlacement(base, 3, failure_domains=np.array([0, 0, 1, 1]))
    # two domains support two-way replication; copies land across domains
    pol = ReplicatedPlacement(base, 2,
                              failure_domains=np.array([0, 0, 1, 1]))
    reps = pol.replicas_of(np.arange(g.num_nodes))
    domains = np.array([0, 0, 1, 1])
    assert (domains[reps[:, 0]] != domains[reps[:, 1]]).all()


def test_whole_host_outage_fails_over_without_data_loss(graph_and_feats):
    g, feats = graph_and_feats
    kw = dict(n_hosts=4, placement="metis-lite", replication_factor=2)
    clean = _batches(_mk(g, feats, **kw))
    sched = FaultSchedule(events=(OutageEvent(shard=1, start=0, end=100),))
    faulted = _batches(_mk(g, feats, fault_schedule=sched, **kw))
    for a, b in zip(clean, faulted):
        assert np.array_equal(a.features, b.features)  # no data loss
        assert _blocks_equal(a.blocks, b.blocks)


def test_failure_domains_state_roundtrip(graph_and_feats):
    g, _ = graph_and_feats
    pol = ReplicatedPlacement(MetisLitePlacement(4, graph=g), 2,
                              failure_domains=np.arange(4))
    st = pol.state_dict()
    fresh = ReplicatedPlacement(
        MetisLitePlacement(4, indptr=g.indptr, indices=g.indices), 2,
        failure_domains=np.arange(4))
    fresh.load_state_dict(st)
    ids = np.arange(g.num_nodes)
    assert np.array_equal(fresh.replicas_of(ids), pol.replicas_of(ids))
    mismatched = ReplicatedPlacement(
        MetisLitePlacement(4, indptr=g.indptr, indices=g.indices), 2,
        failure_domains=np.array([0, 1, 0, 1]))
    with pytest.raises(ValueError, match="failure domains"):
        mismatched.load_state_dict(st)


# -- checkpoint round-trip -----------------------------------------------------

def test_host_plane_checkpoint_roundtrip(graph_and_feats):
    g, feats = graph_and_feats
    kw = dict(n_hosts=4, placement="metis-lite")
    ref = _batches(_mk(g, feats, **kw), n=8)
    part = _mk(g, feats, **kw)
    _batches(part, n=4)
    state = part.state_dict()
    r1, r2 = _mk(g, feats, **kw), _mk(g, feats, **kw)
    r1.load_state_dict(state)
    r2.load_state_dict(state)
    for i, (x, y) in enumerate(zip(_batches(r1, n=4), _batches(r2, n=4))):
        # resumed loaders agree bit-for-bit, prices included
        assert np.array_equal(x.features, y.features)
        assert x.exposed_prep_s == y.exposed_prep_s
        # and the data matches the uninterrupted stream
        assert np.array_equal(x.features, ref[4 + i].features)


def test_topology_injector_checkpoint_roundtrip(graph_and_feats):
    g, feats = graph_and_feats
    sched = FaultSchedule(events=(
        BrownoutEvent(shard=0, start=2, end=1000, multiplier=4.0),))
    kw = dict(plane="gids-topo-merged", n_shards=4, placement="hash",
              fault_schedule=sched)
    part = _mk(g, feats, **kw)
    _batches(part, n=4)
    state = part.state_dict()
    assert "topo_injector" in state["fault_state"]
    r1, r2 = _mk(g, feats, **kw), _mk(g, feats, **kw)
    r1.load_state_dict(state)
    r2.load_state_dict(state)
    assert r1.topo.timeline.injector.burst == part.topo.timeline.injector.burst
    for x, y in zip(_batches(r1, n=4), _batches(r2, n=4)):
        assert np.array_equal(x.features, y.features)
        assert x.sample_time_s == y.sample_time_s
        assert x.exposed_prep_s == y.exposed_prep_s
