"""GIDS dataloader end-to-end behaviour: mode ordering, accumulator
dynamics, telemetry coherence, pipeline-state resume, GNN training."""
import numpy as np
import pytest

from repro.core import (GIDSDataLoader, LoaderConfig, INTEL_OPTANE)
from repro.graph.synthetic import rmat_graph


@pytest.fixture(scope="module")
def graph_and_feats():
    g = rmat_graph(20_000, 12, 32, seed=1)
    feats = np.random.default_rng(0).standard_normal(
        (g.num_nodes, 32)).astype(np.float32)
    return g, feats


def _avg_prep(g, feats, mode, iters=12, **kw):
    dl = GIDSDataLoader(g, feats, LoaderConfig(
        batch_size=256, fanouts=(5, 5), data_plane=mode, cache_lines=4096,
        window_depth=4, **kw))
    ts = [dl.next_batch().prep_time_s for _ in range(iters)]
    return np.mean(ts[2:]), dl


def test_mode_ordering_gids_bam_mmap(graph_and_feats):
    """Paper headline direction: gids < bam << mmap prep time."""
    g, feats = graph_and_feats
    t_mmap, _ = _avg_prep(g, feats, "mmap")
    t_bam, _ = _avg_prep(g, feats, "bam")
    t_gids, _ = _avg_prep(g, feats, "gids")
    assert t_gids < t_bam < t_mmap
    assert t_mmap / t_gids > 10


def test_features_are_correct_rows(graph_and_feats):
    g, feats = graph_and_feats
    dl = GIDSDataLoader(g, feats, LoaderConfig(batch_size=64, fanouts=(4,),
                                               data_plane="gids",
                                               cache_lines=1024,
                                               window_depth=2))
    b = dl.next_batch()
    np.testing.assert_array_equal(b.features, feats[b.blocks.all_nodes])


def test_accumulator_merges_when_batches_small(graph_and_feats):
    g, feats = graph_and_feats
    _, dl_small = _avg_prep(g, feats, "gids")
    small_depth = dl_small.accumulator.merge_depth(
        dl_small._requests_per_iter)
    assert small_depth >= 1
    # tiny batches -> more merging needed to cover the threshold
    dl_tiny = GIDSDataLoader(g, feats, LoaderConfig(
        batch_size=8, fanouts=(2,), data_plane="gids", cache_lines=1024,
        window_depth=2))
    for _ in range(3):
        dl_tiny.next_batch()
    assert (dl_tiny.accumulator.merge_depth(dl_tiny._requests_per_iter)
            >= small_depth)


def test_redirect_rate_rises_with_cache(graph_and_feats):
    g, feats = graph_and_feats
    _, dl = _avg_prep(g, feats, "gids", iters=20)
    assert dl.accumulator.redirect_rate > 0.2
    report_requests = dl.store.cache.stats.accesses
    assert report_requests > 0


def test_telemetry_tiers_partition_requests(graph_and_feats):
    g, feats = graph_and_feats
    dl = GIDSDataLoader(g, feats, LoaderConfig(batch_size=128, fanouts=(4, 4),
                                               data_plane="gids",
                                               cache_lines=2048,
                                               window_depth=2))
    for _ in range(5):
        b = dl.next_batch()
        r = b.report
        assert r.n_hbm_hits + r.n_host_hits + r.n_storage == r.n_requests


def test_loader_state_resume(graph_and_feats):
    g, feats = graph_and_feats
    mk = lambda: GIDSDataLoader(g, feats, LoaderConfig(
        batch_size=64, fanouts=(4,), data_plane="gids", cache_lines=1024,
        window_depth=2, seed=9))
    a = mk()
    for _ in range(4):
        last_a = a.next_batch()
    st = a.state_dict()
    nxt_a = a.next_batch()

    b = mk()
    b.load_state_dict(st)
    nxt_b = b.next_batch()
    np.testing.assert_array_equal(nxt_a.blocks.seeds, nxt_b.blocks.seeds)


def test_unknown_sampler_rejected_at_construction():
    """Bad sampler names fail when the config is BUILT, not on first batch."""
    with pytest.raises(ValueError, match="unknown sampler"):
        LoaderConfig(sampler="graphsaint")


def test_ladies_sampler_end_to_end_parity(graph_and_feats):
    """sampler="ladies" through the whole pipeline: the loader's first batch
    must be exactly `ladies_sample_blocks` on the loader's own RNG stream,
    with features gathered for its node set and coherent telemetry."""
    from repro.sampling.ladies import ladies_sample_blocks
    g, feats = graph_and_feats
    cfg = LoaderConfig(batch_size=32, sampler="ladies",
                       ladies_layer_sizes=(64, 32), data_plane="gids",
                       cache_lines=1024, window_depth=2, seed=5)
    dl = GIDSDataLoader(g, feats, cfg)
    b = dl.next_batch()

    # replay the loader's sampling: same seed stream, same draws
    rng = np.random.default_rng(5)
    seeds = rng.choice(np.arange(g.num_nodes), size=32, replace=False)
    ref = ladies_sample_blocks(g, seeds, (64, 32), rng)
    np.testing.assert_array_equal(b.blocks.seeds, ref.seeds)
    for ha, hb in zip(b.blocks.hop_nodes, ref.hop_nodes):
        np.testing.assert_array_equal(ha, hb)
    np.testing.assert_array_equal(b.blocks.all_nodes, ref.all_nodes)
    np.testing.assert_array_equal(b.features, feats[ref.all_nodes])
    assert b.blocks.num_requests == 32 + 64 + 32
    r = b.report
    assert r.n_hbm_hits + r.n_host_hits + r.n_storage == r.n_requests
    assert b.prep_time_s > 0
    # and the plane keeps producing consistent batches past the first
    for _ in range(3):
        nb = dl.next_batch()
        np.testing.assert_array_equal(nb.features,
                                      feats[nb.blocks.all_nodes])


def test_token_pipeline_modality_store():
    from repro.core.feature_store import FeatureStore
    from repro.data.tokens import TokenPipeline, TokenPipelineConfig
    store = FeatureStore.synthetic(512, 16)
    cfg = TokenPipelineConfig(batch_size=4, seq_len=32, vocab_size=100,
                              modality_dim=16, modality_tokens=3)
    pipe = TokenPipeline(None, cfg, modality_store=store, num_tokens=1 << 14)
    b = next(pipe)
    assert b["tokens"].shape == (4, 32)
    assert b["labels"].shape == (4, 32)
    assert b["patches"].shape == (4, 3, 16)
    # labels are the shifted stream
    flat = np.concatenate([b["tokens"][0], [b["labels"][0, -1]]])
    np.testing.assert_array_equal(b["labels"][0], flat[1:])
