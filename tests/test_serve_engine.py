"""Continuous-batching serving engine: slot recycling, per-slot decode
positions, and agreement with single-request greedy decoding."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models.transformer import LM
from repro.serve import EngineConfig, EngineNotDrained, Request, ServeEngine


def _greedy_reference(model, params, prompt, n, max_seq):
    cache = model.init_cache(1, max_seq)
    logits, cache = model.prefill(
        params, {"tokens": jnp.asarray(prompt[None, :], jnp.int32)}, cache)
    toks = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(n - 1):
        lg, cache = model.decode_step(
            params, jnp.asarray([[toks[-1]]], jnp.int32), cache,
            jnp.int32(pos))
        toks.append(int(jnp.argmax(lg[0, -1])))
        pos += 1
    return toks


def test_engine_matches_single_request_decoding():
    cfg = configs.get("qwen2_1_5b", reduced=True)
    cfg = dataclasses.replace(cfg, param_dtype=jnp.float32,
                              compute_dtype=jnp.float32)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (7, 11, 5)]          # heterogeneous lengths
    N = 6

    engine = ServeEngine(model, params, EngineConfig(slots=2, max_seq=64))
    for i, p in enumerate(prompts):
        engine.submit(Request(rid=i, prompt=p, max_new_tokens=N))
    done = engine.run_until_drained()
    assert len(done) == 3
    assert all(r.done for r in done)

    for r in sorted(done, key=lambda r: r.rid):
        ref = _greedy_reference(model, params, prompts[r.rid], N, 64)
        assert r.generated[:N] == ref, (r.rid, r.generated, ref)


def test_engine_slot_recycling():
    cfg = configs.get("mamba2_1_3b", reduced=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(1))
    engine = ServeEngine(model, params, EngineConfig(slots=1, max_seq=48))
    rng = np.random.default_rng(1)
    for i in range(3):
        engine.submit(Request(rid=i,
                              prompt=rng.integers(0, cfg.vocab_size, 4)
                              .astype(np.int32),
                              max_new_tokens=3))
    done = engine.run_until_drained()
    assert len(done) == 3                      # 3 requests through 1 slot
    assert engine.kv_slots.occupancy == 0.0    # every slot recycled

    # a one-token request finishes at prefill and never holds a slot
    engine.submit(Request(rid=9,
                          prompt=rng.integers(0, cfg.vocab_size, 4)
                          .astype(np.int32),
                          max_new_tokens=1))
    (one,) = engine.run_until_drained()
    assert one.done and len(one.generated) == 1


def test_run_until_drained_raises_on_tick_exhaustion():
    """Exhausting max_ticks with work still in flight must raise (not
    silently return a partial result), carry the unfinished count and the
    requests that DID retire, and leave the engine resumable."""
    cfg = configs.get("mamba2_1_3b", reduced=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(1))
    engine = ServeEngine(model, params, EngineConfig(slots=1, max_seq=48))
    rng = np.random.default_rng(3)
    for i in range(3):
        engine.submit(Request(rid=i,
                              prompt=rng.integers(0, cfg.vocab_size, 4)
                              .astype(np.int32),
                              max_new_tokens=6))
    with pytest.raises(EngineNotDrained) as exc:
        engine.run_until_drained(max_ticks=2)
    err = exc.value
    assert err.unfinished >= 1
    assert err.unfinished + len(err.retired) == 3
    assert "2 ticks" in str(err)
    # the engine kept its state: draining can simply continue
    rest = engine.run_until_drained()
    assert len(err.retired) + len(rest) == 3
    assert not engine.queue and all(r is None for r in engine.active)


def test_slot_recycling_under_sustained_pressure():
    """Many more requests than slots, EOS-at-prefill one-token requests
    mixed with long decodes: the KV slot pool and the active list must
    never desync, and every request retires exactly once."""
    cfg = configs.get("mamba2_1_3b", reduced=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(1))
    engine = ServeEngine(model, params, EngineConfig(slots=2, max_seq=48))
    rng = np.random.default_rng(4)
    n_requests = 9
    for i in range(n_requests):
        engine.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, 3 + i % 4)
            .astype(np.int32),
            # thirds retire AT prefill (never hold a slot for decode),
            # the rest decode for a while under full occupancy
            max_new_tokens=1 if i % 3 == 0 else 8))
    retired = []
    for _ in range(200):
        retired.extend(engine.step())
        # invariant: every occupied slot is held in the KV pool and
        # vice versa — the pool can never leak or double-book
        active = sum(r is not None for r in engine.active)
        assert len(engine.kv_slots._held) == active
        assert engine.kv_slots.occupancy == active / engine.cfg.slots
        if not engine.queue and active == 0:
            break
    assert sorted(r.rid for r in retired) == list(range(n_requests))
    assert all(r.done for r in retired)
    assert engine.kv_slots.occupancy == 0.0
    for r in retired:
        expect = 1 if r.rid % 3 == 0 else 8
        assert len(r.generated) == expect, (r.rid, len(r.generated))


def test_engine_overlap_pricing():
    """Admission staging is priced like the training loader's prefetch:
    decodes already in flight when the tick starts hide it, only the excess
    is exposed — and a cold-start admission has nothing to hide behind."""
    cfg = configs.get("mamba2_1_3b", reduced=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(2)

    def mk_req(i, n=4):
        return Request(rid=i,
                       prompt=rng.integers(0, cfg.vocab_size, 4)
                       .astype(np.int32),
                       max_new_tokens=n)

    engine = ServeEngine(model, params, EngineConfig(
        slots=2, max_seq=48, admit_cost_s=1e-3, decode_cost_s=4e-4))
    st = engine.overlap_stats
    engine.submit(mk_req(0))
    engine.step()                 # cold start: no in-flight decode to hide
    assert st.prep_s_total == pytest.approx(1e-3)
    assert st.exposed_s_total == pytest.approx(1e-3)

    engine.submit(mk_req(1))
    engine.step()                 # admitted behind r0's in-flight decode
    assert st.prep_s_total == pytest.approx(2e-3)
    assert st.exposed_s_total == pytest.approx(1e-3 + (1e-3 - 4e-4))

    done = engine.run_until_drained()
    assert len(done) == 2 and st.staged_batches == 2
    assert 0.0 < st.hidden_fraction < 1.0

    # decode dominating the staging cost: the warm admission is free
    engine2 = ServeEngine(model, params, EngineConfig(
        slots=2, max_seq=48, admit_cost_s=1e-4, decode_cost_s=5e-4))
    engine2.submit(mk_req(0))
    engine2.step()
    engine2.submit(mk_req(1))
    engine2.step()
    st2 = engine2.overlap_stats
    assert st2.prep_s_total == pytest.approx(2e-4)
    assert st2.exposed_s_total == pytest.approx(1e-4)  # cold tick only
