"""Weighted reverse PageRank — the paper's hot-node metric (§3.3, after
Data Tiering [25]).

Reverse PageRank on G equals PageRank on G^T: a node that many sampled
walks *reach backwards* (i.e. that appears often as a sampled in-neighbor)
scores high, predicting feature-fetch frequency during neighborhood sampling.
"""
from __future__ import annotations

import numpy as np

from .csr import CSRGraph


def reverse_pagerank(graph: CSRGraph, *, damping: float = 0.85,
                     iters: int = 20, weights: np.ndarray | None = None
                     ) -> np.ndarray:
    """Power-iteration PageRank on the reversed graph.

    weights: optional per-node teleport weights (the "weighted" part —
    the paper seeds with training-node density; we default to uniform).
    """
    rev = graph.reverse()
    n = graph.num_nodes
    if weights is None:
        tele = np.full(n, 1.0 / n)
    else:
        tele = weights / weights.sum()
    deg = rev.degrees().astype(np.float64)
    # edges of rev: u -> v where original had v -> u
    rank = tele.copy()
    src = np.repeat(np.arange(n), deg.astype(np.int64))
    dst = rev.indices
    inv_deg = np.where(deg > 0, 1.0 / np.maximum(deg, 1), 0.0)
    for _ in range(iters):
        contrib = rank * inv_deg
        new = np.zeros(n)
        np.add.at(new, dst, contrib[src])
        dangling = rank[deg == 0].sum()
        rank = (1 - damping) * tele + damping * (new + dangling * tele)
    return rank


def hot_nodes(graph: CSRGraph, fraction: float, *, iters: int = 20,
              metric: np.ndarray | None = None) -> np.ndarray:
    """Top-`fraction` node ids by reverse PageRank (or a user metric),
    i.e. the set pinned into the constant CPU buffer."""
    score = metric if metric is not None else reverse_pagerank(graph, iters=iters)
    k = max(1, int(graph.num_nodes * fraction))
    return np.argsort(-score, kind="stable")[:k].astype(np.int64)
