"""Fig. 14 (overlap) — exposed data-preparation time vs model compute time.

The paper's decoupling claim (§3.2, Fig. 13): once data preparation for
batch k+1 runs concurrently with batch k's training compute, storage latency
stops adding serially to the iteration — prep is *exposed* only where it
exceeds the compute it hides behind.  This sweep drives the `gids-async`
prefetch plane with a synthetic model-compute time swept from 0 to well past
the modelled prep time and reports the exposed prep at each point: it must
fall to 0 once compute exceeds prep, while the raw prep time and the tier
splits stay bit-identical to the synchronous `gids` plane (the engine does
the same work, just earlier).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row
from repro.core import GIDSDataLoader, LoaderConfig
from repro.graph.synthetic import rmat_graph

# compute time as a multiple of the measured steady-state prep time
COMPUTE_RATIOS = (0.0, 0.25, 0.5, 0.75, 1.0, 1.5, 2.0, 4.0)


def _make_loader(g, feats, plane: str) -> GIDSDataLoader:
    return GIDSDataLoader(g, feats, LoaderConfig(
        batch_size=256, fanouts=(5, 5), data_plane=plane, cache_lines=4096,
        window_depth=4, seed=3))


def _run(g, feats, plane: str, compute_s: float, iters: int):
    dl = _make_loader(g, feats, plane)
    batches = [dl.next_batch(compute_s=compute_s) for _ in range(iters)]
    raw = float(np.mean([b.prep_time_s for b in batches[2:]]))
    exposed = float(np.mean([b.exposed_prep_s for b in batches[2:]]))
    return raw, exposed, batches


def sweep(num_nodes: int = 20_000, iters: int = 12) -> dict:
    g = rmat_graph(num_nodes, 12, 32, seed=1)
    feats = np.zeros((g.num_nodes, 32), np.float32)

    # calibrate: steady-state prep of the synchronous plane
    raw_sync, _, sync_batches = _run(g, feats, "gids", 0.0, iters)

    points = []
    for ratio in COMPUTE_RATIOS:
        compute_s = ratio * raw_sync
        raw, exposed, batches = _run(g, feats, "gids-async", compute_s, iters)
        # the async plane does the same gathers in the same order: raw prep
        # and tier splits must match the sync plane bit-for-bit
        assert raw == raw_sync, (raw, raw_sync)
        for bs, ba in zip(sync_batches, batches):
            assert bs.report == ba.report
        points.append({"compute_over_prep": ratio, "compute_s": compute_s,
                       "raw_prep_s": raw, "exposed_prep_s": exposed})
    return {"raw_prep_s": raw_sync, "points": points}


def headline(num_nodes: int = 20_000, iters: int = 12) -> dict:
    """Smoke numbers for BENCH_*.json: prep with no overlap vs fully hidden."""
    res = sweep(num_nodes, iters)
    by_ratio = {p["compute_over_prep"]: p for p in res["points"]}
    exposed_2x = by_ratio[2.0]["exposed_prep_s"]
    return {
        "raw_prep_us": res["raw_prep_s"] * 1e6,
        "exposed_prep_us_at_2x_compute": exposed_2x * 1e6,
        "hidden_fraction_at_2x_compute":
            1.0 - exposed_2x / max(res["raw_prep_s"], 1e-12),
    }


def main():
    res = sweep()
    for p in res["points"]:
        row(f"fig14_overlap_compute_{p['compute_over_prep']:.2f}x",
            p["exposed_prep_s"] * 1e6,
            f"compute_s={p['compute_s']:.6f}"
            f"_raw_prep_s={p['raw_prep_s']:.6f}"
            f"_exposed_prep_s={p['exposed_prep_s']:.6f}")


if __name__ == "__main__":
    main()
