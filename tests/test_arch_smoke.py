"""Per-architecture smoke tests (assignment requirement): reduced config of
the same family, one forward + one train step on CPU, asserting output
shapes and finiteness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models.transformer import LM
from repro.train.optimizer import OptimizerConfig
from repro.train import optimizer as opt_lib
from repro.train.steps import TrainConfig, make_train_step

B, S = 2, 32


def _batch(cfg):
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_seq, cfg.d_model)) * 0.1,
            jnp.float32)
    if cfg.frontend == "vision_stub":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.frontend_tokens, cfg.d_model)) * 0.1,
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = configs.get(arch, reduced=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    logits = jax.jit(model.forward)(params, batch)
    exp_s = S + (cfg.frontend_tokens if cfg.frontend == "vision_stub" else 0)
    assert logits.shape == (B, exp_s, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    ocfg = OptimizerConfig(name="adafactor" if cfg.moe_experts else "adamw",
                           lr=1e-3)
    step = jax.jit(make_train_step(model, TrainConfig(optimizer=ocfg)))
    opt_state = opt_lib.init(params, ocfg)
    new_params, new_state, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_state.step) == 1
    # params actually changed
    delta = sum(float(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)).sum())
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(new_params)))
    assert delta > 0


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full (published) configs carry the exact assigned dimensions."""
    expected = {
        "llama4_maverick_400b_a17b": (48, 5120, 40, 8, 8192, 202048),
        "arctic_480b": (35, 7168, 56, 8, 4864, 32000),
        "minicpm_2b": (40, 2304, 36, 36, 5760, 122753),
        "h2o_danube_1_8b": (24, 2560, 32, 8, 6912, 32000),
        "qwen3_14b": (40, 5120, 40, 8, 17408, 151936),
        "qwen2_1_5b": (28, 1536, 12, 2, 8960, 151936),
        "internvl2_1b": (24, 896, 14, 2, 4864, 151655),
        "whisper_small": (12, 768, 12, 12, 3072, 51865),
        "recurrentgemma_2b": (26, 2560, 10, 1, 7680, 256000),
        "mamba2_1_3b": (48, 2048, 0, 0, 0, 50280),
    }[arch]
    cfg = configs.get(arch)
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected


def test_moe_active_param_fraction():
    """llama4: ~400B total, ~17B active (the name's contract)."""
    from repro.launch.dryrun import active_param_count
    from repro.models.common import param_count
    cfg = configs.get("llama4_maverick_400b_a17b")
    model = LM(cfg)
    total = param_count(model.param_defs())
    active = active_param_count(model)
    assert 3.5e11 < total < 4.5e11, total
    assert 1.2e10 < active < 2.2e10, active


def test_mamba2_has_no_attention_params():
    cfg = configs.get("mamba2_1_3b", reduced=True)
    model = LM(cfg)
    leaves = jax.tree_util.tree_flatten_with_path(
        model.param_defs(), is_leaf=lambda x: hasattr(x, "axes"))[0]
    names = ["/".join(str(p) for p in path) for path, _ in leaves]
    assert not any("attn" in n for n in names)
    assert any("ssm" in n for n in names)
