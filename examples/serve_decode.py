"""Batched serving: prefill a prompt batch, then greedy-decode with the KV
/ recurrent-state cache — the same serve path the decode_32k / long_500k
dry-run cells lower at production scale.

This drives the raw prefill/decode steps directly on a static batch. For
*continuous* batching — requests admitted and retired mid-stream through a
KV slot pool — use `repro.serve.ServeEngine` instead: `submit()` requests,
then `run_until_drained()`, which raises `EngineNotDrained` (carrying the
unfinished count and the requests that did retire) rather than silently
returning a partial result if `max_ticks` is exhausted. The online *GNN*
analogue — bursty multi-tenant request streams over the tiered feature
data plane — is `repro.serve.GNNServeEngine`; see the tail of
`examples/quickstart.py`.

    PYTHONPATH=src python examples/serve_decode.py --arch recurrentgemma_2b \
        --batch 4 --prompt-len 32 --new-tokens 16
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.models.transformer import LM
from repro.train.steps import make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma_2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = configs.get(args.arch, reduced=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S, T = args.batch, args.prompt_len, args.new_tokens

    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_seq, cfg.d_model)) * 0.1,
            jnp.float32)
    if cfg.frontend == "vision_stub":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.frontend_tokens, cfg.d_model)) * 0.1,
            jnp.float32)

    cache = model.init_cache(B, S + T + 64)
    prefill = jax.jit(make_prefill_step(model))
    decode = jax.jit(make_decode_step(model), donate_argnums=(2,))

    t0 = time.time()
    logits, cache = prefill(params, batch, cache)
    tok = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)
    t_prefill = time.time() - t0
    print(f"{cfg.name}: prefill {B}x{S} in {t_prefill*1e3:.1f} ms")

    out = [tok]
    t0 = time.time()
    for t in range(T - 1):
        tok, cache = decode(params, tok, cache, jnp.int32(S + t))
        out.append(tok)
    dt = time.time() - t0
    gen = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(f"decoded {T} tokens/seq in {dt*1e3:.1f} ms "
          f"({B*T/max(dt,1e-9):,.0f} tok/s batch-aggregate)")
    for b in range(min(B, 2)):
        print(f"  seq{b}: {gen[b].tolist()}")


if __name__ == "__main__":
    main()
