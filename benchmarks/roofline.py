"""Aggregate experiments/dryrun/*.json into the §Roofline table
(markdown + CSV under experiments/), plus the data-plane stage roofline:
the per-stage split of priced prep time (sample / gather / feedback) read
from the observability plane's MetricsRegistry — the `stage_s.*` counters
the traced pipeline accumulates — instead of re-deriving it by walking
batches and reports."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import row

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
OUT = DRYRUN.parent

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_records():
    recs = []
    for fn in sorted(DRYRUN.glob("*.json")):
        r = json.loads(fn.read_text())
        if "hillclimb" in fn.name or r.get("tag"):
            continue
        recs.append(r)
    return recs


def fmt_table(recs, mesh: str) -> str:
    lines = ["| arch | shape | status | strat | peak GiB/dev | compute s | "
             "memory s | collective s | bottleneck | useful |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"],
                                         SHAPE_ORDER.index(r["shape"]))):
        if r["mesh"] != mesh:
            continue
        if not r.get("roofline"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['status']} |"
                         " — | — | — | — | — | — | — |")
            continue
        ro = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['status']} "
            f"| {r.get('strategy','')} "
            f"| {r['memory']['peak_per_device_gib']:.1f} "
            f"| {ro['compute_term']:.4f} | {ro['memory_term']:.4f} "
            f"| {ro['collective_term']:.4f} | {ro['bottleneck']} "
            f"| {ro['useful_ratio']:.3f} |")
    return "\n".join(lines)


def data_plane_stage_split(iters: int = 16) -> dict:
    """Per-stage priced-seconds split of the merged topo plane, consumed
    from the metrics registry of a traced run.  The split is exactly what
    the pricing charged (the counters are incremented with the same floats
    the batches carry), so ``prep == sample + gather + feedback`` holds to
    float eps, and the ``modelled_vs_measured`` series bounds how far the
    model's virtual clock sits from the simulation's wall clock."""
    import numpy as np

    from repro.core import GIDSDataLoader, LoaderConfig
    from repro.graph.synthetic import rmat_graph
    from repro.obs import Tracer

    g = rmat_graph(20_000, 12, 32, seed=1)
    feats = np.zeros((g.num_nodes, 32), np.float32)
    tracer = Tracer()
    dl = GIDSDataLoader(g, feats, LoaderConfig(
        batch_size=256, fanouts=(10, 5), data_plane="gids-topo-merged",
        cache_lines=4096, window_depth=4, seed=3), tracer=tracer)
    for _ in range(iters):
        dl.next_batch()

    m = tracer.metrics
    stages = {name: m.counter(f"stage_s.{name}").value
              for name in ("sample", "gather", "feedback", "prep")}
    n = m.counter("pipeline.batches").value or 1.0
    out = {f"{k}_s": v for k, v in stages.items()}
    out["n_batches"] = n
    out["split_residual_s"] = stages["prep"] - (
        stages["sample"] + stages["gather"] + stages["feedback"])
    gaps = [p["gap_s"]
            for name in m.names() if name.startswith("modelled_vs_measured.")
            for p in m.series(name).points]
    out["max_abs_model_gap_s"] = max((abs(x) for x in gaps), default=0.0)
    return out


def main():
    sp = data_plane_stage_split()
    n = sp["n_batches"]
    for stage in ("sample", "gather", "feedback"):
        share = (sp[f"{stage}_s"] / sp["prep_s"]) if sp["prep_s"] else 0.0
        row(f"roofline_dataplane_{stage}", sp[f"{stage}_s"] / n * 1e6,
            f"share={share:.3f}")
    row("roofline_dataplane_prep", sp["prep_s"] / n * 1e6,
        f"residual={sp['split_residual_s']:.3e}s_"
        f"model_gap={sp['max_abs_model_gap_s']:.3e}s")

    recs = load_records()
    OUT.mkdir(parents=True, exist_ok=True)
    ok = [r for r in recs if r.get("status") == "OK"]
    skip = [r for r in recs if str(r.get("status", "")).startswith("SKIP")]
    fail = [r for r in recs if str(r.get("status", "")).startswith("FAIL")]
    row("roofline_cells", 0.0,
        f"ok={len(ok)}_skip={len(skip)}_fail={len(fail)}")
    for mesh in ("16x16", "2x16x16"):
        md = fmt_table(recs, mesh)
        (OUT / f"roofline_{mesh}.md").write_text(md + "\n")
    # csv
    csv = ["arch,shape,mesh,status,strategy,peak_gib,compute_s,memory_s,"
           "collective_s,bottleneck,useful_ratio"]
    for r in recs:
        ro = r.get("roofline") or {}
        mem = r.get("memory") or {}
        csv.append(",".join(str(x) for x in [
            r["arch"], r["shape"], r["mesh"], r.get("status"),
            r.get("strategy", ""), mem.get("peak_per_device_gib", ""),
            ro.get("compute_term", ""), ro.get("memory_term", ""),
            ro.get("collective_term", ""), ro.get("bottleneck", ""),
            ro.get("useful_ratio", "")]))
    (OUT / "roofline.csv").write_text("\n".join(csv) + "\n")
    # headline stats for the bench log
    if ok:
        worst = min((r for r in ok if r["shape"] == "train_4k"),
                    key=lambda r: r["roofline"]["useful_ratio"],
                    default=None)
        if worst:
            row("roofline_worst_train_useful", 0.0,
                f"{worst['arch']}_{worst['mesh']}="
                f"{worst['roofline']['useful_ratio']:.3f}")
        collbound = [r for r in ok
                     if r["roofline"]["bottleneck"] == "collective"]
        row("roofline_collective_bound_cells", 0.0, str(len(collbound)))


if __name__ == "__main__":
    main()
