"""End-to-end training driver.

Runs any registered architecture (reduced or full config) with the GIDS
token pipeline, AdamW/Adafactor, checkpoint/restart and the step watchdog.
On this CPU container it drives reduced configs (examples, CI); pointed at a
TPU slice it is the production entry point — the mesh/sharding path is the
same one the dry-run proves out.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2_1_5b \
        --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.models.transformer import LM
from repro.train import checkpoint as ckpt_lib
from repro.train import optimizer as opt_lib
from repro.train import schedules
from repro.train.fault_tolerance import StepWatchdog, WatchdogConfig
from repro.train.optimizer import OptimizerConfig
from repro.train.steps import TrainConfig, make_train_step


def build(arch: str, reduced: bool, batch: int, seq: int,
          lr: float, total_steps: int, schedule: str,
          microbatches: int = 1):
    cfg = configs.get(arch, reduced=reduced)
    model = LM(cfg)
    ocfg = OptimizerConfig(name="adafactor" if cfg.moe_experts else "adamw",
                           lr=lr)
    sched = schedules.make(schedule, peak_lr=lr, warmup=max(total_steps // 20, 5),
                           total=total_steps)
    tcfg = TrainConfig(optimizer=ocfg, microbatches=microbatches,
                       schedule=sched)
    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0, 1))
    pipe_cfg = TokenPipelineConfig(batch_size=batch, seq_len=seq,
                                   vocab_size=cfg.vocab_size)
    mstore = None
    if cfg.frontend == "vision_stub":
        from repro.core.feature_store import FeatureStore
        mstore = FeatureStore.synthetic(4096, cfg.d_model)
        pipe_cfg = dataclasses.replace(pipe_cfg,
                                       modality_dim=cfg.d_model,
                                       modality_tokens=cfg.frontend_tokens)
    pipe = TokenPipeline(None, pipe_cfg, modality_store=mstore)
    return cfg, model, step_fn, pipe, ocfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_1_5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine", choices=["cosine", "wsd"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg, model, step_fn, pipe, ocfg = build(
        args.arch, args.reduced, args.batch, args.seq, args.lr, args.steps,
        args.schedule, args.microbatches)
    print(f"arch={cfg.name} family={cfg.family} "
          f"params will init on {jax.default_backend()}")

    params = model.init(jax.random.PRNGKey(args.seed))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    opt_state = opt_lib.init(params, ocfg)
    start_step = 0

    if args.ckpt_dir:
        latest = ckpt_lib.latest_step(args.ckpt_dir)
        if latest is not None:
            (params, opt_state), extra = ckpt_lib.restore(
                args.ckpt_dir, latest, (params, opt_state))
            pipe.load_state_dict(extra["pipeline"])
            start_step = latest
            print(f"resumed from step {latest}")

    watchdog = StepWatchdog(WatchdogConfig(checkpoint_every=args.ckpt_every))
    losses = []
    t_start = time.time()
    for step in range(start_step, args.steps):
        watchdog.start_step(step)
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        straggler = watchdog.end_step()
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            tok_s = (args.batch * args.seq) / max(watchdog.median_step_s,
                                                  1e-9)
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} tok/s {tok_s:,.0f}"
                  + (" [straggler]" if straggler else ""))
        if args.ckpt_dir and watchdog.should_checkpoint(step):
            ckpt_lib.save(args.ckpt_dir, step, (params, opt_state),
                          {"pipeline": pipe.state_dict()})

    wall = time.time() - t_start
    if args.ckpt_dir:
        ckpt_lib.save(args.ckpt_dir, args.steps, (params, opt_state),
                      {"pipeline": pipe.state_dict()})
    print(json.dumps({
        "arch": cfg.name, "params": n_params, "steps": args.steps,
        "first_loss": losses[0] if losses else None,
        "final_loss": float(np.mean(losses[-5:])) if losses else None,
        "wall_s": round(wall, 1),
    }))


if __name__ == "__main__":
    main()
