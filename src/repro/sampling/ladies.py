"""LADIES layer-wise importance sampling (Zou et al. [51]; paper §4.7).

Instead of per-node fan-out, LADIES samples a fixed number of nodes per
*layer*, with probability proportional to the squared row norm of the
normalized Laplacian restricted to the current frontier's columns — i.e.
p(u) ∝ sum_{v in frontier} A_hat[v,u]^2.

Host implementation (numpy) used by the pipeline; sizes per layer are fixed,
so downstream shapes remain static for jit.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.graph.csr import CSRGraph
from .neighbor import SampledBlocks


def ladies_sample_blocks(graph: CSRGraph, seeds: np.ndarray,
                         layer_sizes: Sequence[int],
                         rng: np.random.Generator) -> SampledBlocks:
    frontier = seeds.astype(np.int64)
    hop_nodes = []
    deg = np.diff(graph.indptr)
    for size in layer_sizes:
        # importance: p(u) ∝ Σ_{v∈frontier} (1/deg(v))^2 over edges v->u
        probs = np.zeros(graph.num_nodes)
        for v in frontier:
            nbrs = graph.indices[graph.indptr[v]:graph.indptr[v + 1]]
            if len(nbrs):
                probs[nbrs] += 1.0 / (len(nbrs) ** 2)
        total = probs.sum()
        if total <= 0:  # isolated frontier: fall back to uniform
            cand = rng.integers(0, graph.num_nodes, size)
        else:
            p = probs / total
            nnz = int((p > 0).sum())
            if nnz >= size:
                cand = rng.choice(graph.num_nodes, size=size, replace=False,
                                  p=p)
            else:  # fewer candidates than layer size: take all + pad
                cand = np.flatnonzero(p > 0)
                pad = rng.integers(0, graph.num_nodes, size - nnz)
                cand = np.concatenate([cand, pad])
        hop_nodes.append(cand.astype(np.int64))
        frontier = cand.astype(np.int64)
    all_nodes = np.unique(np.concatenate([seeds.astype(np.int64), *hop_nodes]))
    n_req = int(seeds.shape[0] + sum(h.shape[0] for h in hop_nodes))
    return SampledBlocks(seeds=seeds, hop_nodes=hop_nodes,
                         all_nodes=all_nodes, num_requests=n_req)
