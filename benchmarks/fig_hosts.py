"""Multi-host scaling — the distributed data plane's placement story.

Sweeps the host-sharded merged plane (`gids-hosts-merged`) over
``n_hosts ∈ {1, 2, 4, 8}`` × placement policy (hash / metis-lite) ×
co-partitioning (features+topology on one decision vs an independent hash
stripe for the adjacency) and pins the PR's claims:

  * features AND sampled blocks are bit-identical to the single-host
    plane at every point — hosts change modelled time and telemetry,
    never bytes — and the 1-host plane's modelled prep is EXACTLY the
    single-host plane's (the cluster degenerates cleanly);
  * metis-lite + co-partitioning beats hash + independent at 4 hosts by
    >= 1.5x exposed prep (the CI gate): the grown partitions track the
    graph's community structure, so most feature rows are requested by
    the host that owns them and skip the interconnect entirely;
  * the cut-edge fraction explains the win — it is the fraction of
    sampling traffic that pays a link transit, reported per point
    alongside per-host straggler telemetry.

The sweep runs on a community-structured graph (`clustered_graph`) for
the same reason DistDGL partitions ogbn-products with METIS rather than
hashing it: real GNN datasets cluster, and that locality is what a
min-cut placement converts into avoided network bytes.  Pure RMAT has no
cuttable structure (every recursion level scrambles endpoints), so it is
the wrong instrument for a placement study — `fig_shard_scaling` keeps
covering the placement-insensitive multi-queue story on RMAT.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row
from repro.core import GIDSDataLoader, LoaderConfig, SAMSUNG_980PRO
from repro.graph.synthetic import clustered_graph

HOST_COUNTS = (1, 2, 4, 8)
PLACEMENTS = ("hash", "metis-lite")


def _make_loader(g, feats, plane: str, **kw) -> GIDSDataLoader:
    return GIDSDataLoader(g, feats, LoaderConfig(
        batch_size=256, fanouts=(6, 4), data_plane=plane, cache_lines=256,
        window_depth=4, seed=3, **kw), ssd=SAMSUNG_980PRO)


def _run(g, feats, plane, iters, warmup, **kw):
    dl = _make_loader(g, feats, plane, **kw)
    batches = [dl.next_batch() for _ in range(iters)]
    prep = float(np.mean([b.exposed_prep_s for b in batches[warmup:]]))
    return prep, batches, dl


def sweep(num_nodes: int = 20_000, iters: int = 16, warmup: int = 6) -> dict:
    g = clustered_graph(num_nodes, 12, 64, communities=32, intra=0.9, seed=1)
    feats = np.random.default_rng(0).standard_normal(
        (g.num_nodes, 64)).astype(np.float32)

    # the single-host reference every cluster point must match bit-for-bit
    ref_prep, ref_batches, _ = _run(g, feats, "gids-merged", iters, warmup)

    points = []
    for placement in PLACEMENTS:
        for co in (True, False):
            for n in HOST_COUNTS:
                prep, batches, dl = _run(
                    g, feats, "gids-hosts-merged", iters, warmup,
                    n_hosts=n, placement=placement, co_partition=co)
                for br, bs in zip(ref_batches, batches):
                    np.testing.assert_array_equal(br.features, bs.features)
                    np.testing.assert_array_equal(br.blocks.all_nodes,
                                                  bs.blocks.all_nodes)
                if n == 1:
                    # the 1-host cluster IS the single-host plane: modelled
                    # prep identical float-for-float, not just data
                    for br, bs in zip(ref_batches, batches):
                        assert br.exposed_prep_s == bs.exposed_prep_s
                tier = dl.plane.store.tiers[-1]
                burst = dl.timeline.shard_burst
                points.append({
                    "placement": placement, "co_partition": co,
                    "n_hosts": n, "exposed_prep_s": prep,
                    "cut_edge_fraction": tier.cut_edge_fraction(),
                    "remote_fraction": tier.remote_fraction(),
                    "imbalance": burst.imbalance if burst else 1.0,
                    "straggler": burst.straggler if burst else 0,
                    "burst_remote_fraction": getattr(
                        burst, "remote_fraction", 0.0) if burst else 0.0,
                })

    by = {(p["placement"], p["co_partition"], p["n_hosts"]): p
          for p in points}
    # the placement payoff grows with host count: at every multi-host
    # point the min-cut co-partitioned plane beats the double-network-hop
    # baseline, and its cut stays a fraction of the hash stripe's
    for n in HOST_COUNTS[1:]:
        win = by[("metis-lite", True, n)]
        lose = by[("hash", False, n)]
        assert win["exposed_prep_s"] < lose["exposed_prep_s"], \
            f"metis-lite+co not winning at {n} hosts"
        assert win["cut_edge_fraction"] < 0.5 * lose["cut_edge_fraction"]
    return {"points": points, "single_host_prep_s": ref_prep}


def headline(num_nodes: int = 20_000, iters: int = 16) -> dict:
    """Smoke numbers for BENCH_*.json + the CI multi-host placement gate."""
    res = sweep(num_nodes, iters)
    by = {(p["placement"], p["co_partition"], p["n_hosts"]): p
          for p in res["points"]}
    out = {}
    for n in HOST_COUNTS:
        out[f"metis_co_{n}host_exposed_prep_us"] = \
            by[("metis-lite", True, n)]["exposed_prep_s"] * 1e6
        out[f"hash_indep_{n}host_exposed_prep_us"] = \
            by[("hash", False, n)]["exposed_prep_s"] * 1e6
    win, lose = by[("metis-lite", True, 4)], by[("hash", False, 4)]
    out["speedup_metis_co_vs_hash_indep_4hosts"] = (
        lose["exposed_prep_s"] / max(win["exposed_prep_s"], 1e-12))
    out["metis_co_4host_cut_edge_fraction"] = win["cut_edge_fraction"]
    out["hash_indep_4host_cut_edge_fraction"] = lose["cut_edge_fraction"]
    out["metis_co_4host_remote_fraction"] = win["remote_fraction"]
    out["metis_co_4host_imbalance"] = win["imbalance"]
    out["metis_co_4host_straggler"] = win["straggler"]
    # the sweep asserted exact prep equality at n_hosts=1 for every
    # placement; surface it as a gate-checkable flag
    out["hosts1_bit_identical"] = True
    return out


def main():
    res = sweep()
    row("fig_hosts_single_host_reference",
        res["single_host_prep_s"] * 1e6, "plane=gids-merged")
    for p in res["points"]:
        mode = "co" if p["co_partition"] else "indep"
        row(f"fig_hosts_{p['placement']}_{mode}_{p['n_hosts']}host",
            p["exposed_prep_s"] * 1e6,
            f"cut={p['cut_edge_fraction']:.3f}"
            f"_remote={p['remote_fraction']:.3f}"
            f"_imbalance={p['imbalance']:.2f}"
            f"_straggler={p['straggler']}")


if __name__ == "__main__":
    main()
