"""Tiered graph-topology store — hybrid placement for the *structure*
namespace (paper §2.3/§3.1: graph topology lives in GPU/CPU memory so GPU
threads sample without CPU round-trips; FastGL 2024 shows sampling itself is
a first-order GPU bottleneck; Data Tiering 2021 supplies the degree-aware
admission signal).

This module mirrors the feature data plane one namespace over: where
`core/tiers.py` partitions feature *rows* across an ordered tier stack, the
`TieredTopologyStore` partitions the CSR adjacency (`graph.indices`) into
4 KB *edge pages* and places each page in exactly one of three tiers —

  hbm      GPU-resident hot adjacency (high-degree head of the graph)
  host     pinned host memory, read zero-copy over PCIe
  storage  SSD-backed CSR pages, priced through `StorageTimeline` with the
           same page-granular IO accounting as the feature plane (a page IS
           a 4 KB line, so deduplicating a hop's edge reads per page is the
           topology analogue of `storage_sim.coalesce_lines`; with
           `n_shards > 1` the pages stripe across independent SSD queues
           via the SAME placement registry as `core/sharding.py` and price
           at the max over per-shard drains, `price_sharded_burst`)

Which page goes where is an *admission policy* resolved through a registry
(`register_admission` / `make_admission`) shaped exactly like the placement
registry in `core/sharding.py`:

  degree  — Data-Tiering-style expected-touch score: a page is hot in
            proportion to how often uniform neighbor sampling reads it
            (Σ over its edge words of (indeg(owner) + 1) / outdeg(owner),
            up to the shared fanout constant; the +1 smooths zero-indeg
            owners — see `page_scores`); hottest pages fill the GPU
            budget, the next-hottest the host budget, the tail sinks to
            storage
  range   — naive prefix placement in id order (good when ids are already
            degree-sorted, a skew-sensitivity baseline otherwise)
  random  — seeded random placement (the BaM-style no-information baseline)
  adaptive — degree admission that *learns*: seeded from the same static
            expected-touch score (bit-identical to `degree` at build), the
            store then records every hop's MEASURED page touches into a
            `TouchTable` (core/feedback.py) and `plan_refresh` /
            `commit_refresh` re-admit measured-hot pages into the GPU/host
            budgets between folds, promotion reads priced through the same
            hop model the sampler pays (`TopologyRefresher` decides when a
            refresh is worth its cost)

`indptr` ((N+1) * 8 B — two orders of magnitude smaller than `indices`) is
modelled as always GPU-resident; only edge-page reads are priced.

The sampling stage consumes this store through
`repro.sampling.tiered.tiered_sample_blocks`, which emits one
`TopologyGatherReport` per hop (edge pages by tier, coalesced IOs, modelled
hop time) — the report that finally makes `GIDSDataLoader.plan_next()` a
*priced* stage symmetrical to `execute()`.  The device data path is
`frontier_gather` (kernels/ops.py `tiered_frontier_gather`): resident pages
are gathered from the HBM hot-page array through the same Pallas
`tiered_gather` kernel the feature plane uses, non-resident pages ride the
staged fallback.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from .feedback import TouchTable
from .sharding import make_placement
from .storage_sim import (HBM_BW, INTEL_OPTANE, IO_BYTES, PCIE_GEN4_BW,
                          SSDSpec, StorageTimeline, host_sampling_hop_time)

#: Topology tier indices, fastest first — aligned with
#: `tiers.LATENCY_CLASSES` so telemetry vocabulary matches the feature plane.
TOPO_TIER_NAMES = ("hbm", "host", "storage")
TIER_HBM, TIER_HOST, TIER_STORAGE = 0, 1, 2


@dataclasses.dataclass(frozen=True)
class TopologyGatherReport:
    """Per-hop edge-page telemetry from one tiered sampling hop.

    n_frontier:     destination nodes sampled this hop
    n_edge_reads:   adjacency words actually read (degree-0 destinations
                    read nothing — their fan-out self-pads)
    pages_by_tier:  unique 4 KB edge pages touched, split (hbm, host,
                    storage).  A page is one IO line, so the storage entry
                    IS the hop's coalesced IO count: reads sharing a page
                    cost one IO, the topology twin of
                    `storage_sim.coalesce_lines`
    reads_by_tier:  the same edge reads split by serving tier
    shard_pages:    per-shard storage-page counts on a sharded namespace
                    (sums to `n_storage_ios`); empty when unsharded
    time_s:         modelled hop time (`StorageTimeline.price_topology_hop`)
    """

    hop: int
    n_frontier: int
    n_edge_reads: int
    pages_by_tier: tuple[int, int, int]
    reads_by_tier: tuple[int, int, int]
    shard_pages: tuple[int, ...] = ()
    time_s: float = 0.0

    @property
    def n_pages(self) -> int:
        return sum(self.pages_by_tier)

    @property
    def n_storage_ios(self) -> int:
        """Coalesced storage IOs: one per unique storage-tier page."""
        return self.pages_by_tier[TIER_STORAGE]

    @property
    def coalesce_factor(self) -> float:
        """Storage edge reads folded into each page-granular IO."""
        return self.reads_by_tier[TIER_STORAGE] / max(self.n_storage_ios, 1)


# -- admission-policy registry (same pattern as core/sharding.py) --------------

AdmissionFactory = Callable[..., np.ndarray]
_ADMISSIONS: dict[str, AdmissionFactory] = {}


def register_admission(name: str) -> Callable[[AdmissionFactory],
                                              AdmissionFactory]:
    """Register a factory ``(n_pages, *, gpu_pages, host_pages, page_score,
    seed) -> (n_pages,) int8 assignment`` (values `TIER_*`).  Factories
    receive every context keyword and ignore what they do not need, so
    score-, locality-, or feedback-driven policies slot in without touching
    the store."""
    def deco(fn: AdmissionFactory) -> AdmissionFactory:
        _ADMISSIONS[name] = fn
        return fn
    return deco


def admission_names() -> tuple[str, ...]:
    return tuple(sorted(_ADMISSIONS))


def make_admission(name: str, n_pages: int, *, gpu_pages: int,
                   host_pages: int, page_score: np.ndarray | None = None,
                   seed: int = 0) -> np.ndarray:
    try:
        factory = _ADMISSIONS[name]
    except KeyError:
        raise KeyError(f"unknown admission policy {name!r}; registered: "
                       f"{admission_names()}") from None
    assignment = np.asarray(factory(
        n_pages, gpu_pages=gpu_pages, host_pages=host_pages,
        page_score=page_score, seed=seed), np.int8)
    if assignment.shape != (n_pages,):
        raise ValueError(f"admission {name!r} returned shape "
                         f"{assignment.shape}, expected ({n_pages},)")
    return assignment


def _fill_by_order(order: np.ndarray, n_pages: int, gpu_pages: int,
                   host_pages: int) -> np.ndarray:
    """Assign tiers down a priority order: the first `gpu_pages` of `order`
    go to HBM, the next `host_pages` to pinned host, the rest to storage.
    Growing either budget only ever moves a page to a faster tier (nested
    prefixes), which is what makes modelled sampling time monotone in the
    GPU budget (benchmarks/fig7_sampling.py pins this)."""
    assignment = np.full(n_pages, TIER_STORAGE, np.int8)
    assignment[order[:gpu_pages]] = TIER_HBM
    assignment[order[gpu_pages:gpu_pages + host_pages]] = TIER_HOST
    return assignment


@register_admission("degree")
def _degree_admission(n_pages: int, *, gpu_pages: int, host_pages: int,
                      page_score=None, **_ctx) -> np.ndarray:
    """Data-Tiering-style: hottest pages (by expected sampled-edge touches)
    claim the fastest tiers."""
    if page_score is None:
        raise ValueError("degree admission needs per-page scores (build the "
                         "store via TieredTopologyStore.from_graph)")
    order = np.argsort(-np.asarray(page_score), kind="stable")
    return _fill_by_order(order, n_pages, gpu_pages, host_pages)


@register_admission("adaptive")
def _adaptive_admission(n_pages: int, *, gpu_pages: int, host_pages: int,
                        page_score=None, **_ctx) -> np.ndarray:
    """Feedback-seeded admission: identical to `degree` at build time (same
    static expected-touch prior, same stable ranking), then re-ranked online
    from measured touches via `TieredTopologyStore.plan_refresh` — a store
    built with this policy carries a `TouchTable` fed by every hop."""
    if page_score is None:
        raise ValueError("adaptive admission needs per-page scores (build "
                         "the store via TieredTopologyStore.from_graph)")
    order = np.argsort(-np.asarray(page_score), kind="stable")
    return _fill_by_order(order, n_pages, gpu_pages, host_pages)


@register_admission("range")
def _range_admission(n_pages: int, *, gpu_pages: int, host_pages: int,
                     **_ctx) -> np.ndarray:
    return _fill_by_order(np.arange(n_pages), n_pages, gpu_pages, host_pages)


@register_admission("random")
def _random_admission(n_pages: int, *, gpu_pages: int, host_pages: int,
                      seed=0, **_ctx) -> np.ndarray:
    order = np.random.default_rng(seed).permutation(n_pages)
    return _fill_by_order(order, n_pages, gpu_pages, host_pages)


def _page_geometry(indices: np.ndarray, page_bytes: int) -> tuple[int, int]:
    """(words per page, page count) for one CSR indices array — the single
    definition every page-id computation derives from."""
    page_words = max(1, page_bytes // indices.dtype.itemsize)
    return page_words, _n_pages(len(indices), page_words)


def _n_pages(n_words: int, page_words: int) -> int:
    return max(1, -(-n_words // page_words))


def page_scores(indptr: np.ndarray, indices: np.ndarray,
                page_words: int) -> np.ndarray:
    """Expected sampled-edge touches per page, up to the shared fanout
    constant: uniform neighbor sampling reads a word of node v's adjacency
    when v is in the frontier (frequency ∝ in-degree under neighbor-driven
    frontiers) and then picks uniformly among its deg(v) words — so each
    word scores (indeg(owner) + 1) / outdeg(owner), summed per page.  The
    +1 is Laplace smoothing: seed nodes enter the frontier regardless of
    in-degree, so a zero-indeg node's pages rank by 1/outdeg instead of
    collapsing into an arbitrary tie at zero."""
    n = len(indptr) - 1
    outdeg = np.diff(indptr)
    indeg = np.bincount(indices, minlength=n)
    owner = np.repeat(np.arange(n, dtype=np.int64), outdeg)
    word_score = (indeg[owner] + 1.0) / np.maximum(outdeg[owner], 1)
    page = np.arange(len(indices), dtype=np.int64) // page_words
    return np.bincount(page, weights=word_score,
                       minlength=_n_pages(len(indices), page_words))


# -- the store -----------------------------------------------------------------

class TieredTopologyStore:
    """Page-granular hybrid placement of one CSR adjacency.

    `assignment[p]` is the tier of edge page `p` (TIER_HBM / TIER_HOST /
    TIER_STORAGE over `indices[p*page_words : (p+1)*page_words]`);
    `page_shard[p]` the SSD queue a storage-resident page drains through
    (all zeros when `n_shards == 1`).  The store owns its own
    `StorageTimeline` — the topology namespace's queues are distinct from
    the feature namespace's, even when both model the same device class.
    """

    def __init__(self, graph, assignment: np.ndarray, *,
                 page_bytes: int = IO_BYTES, policy: str = "degree",
                 ssd: SSDSpec = INTEL_OPTANE, n_ssd: int = 1,
                 page_shard: np.ndarray | None = None,
                 shard_specs=None):
        self.graph = graph
        self.indptr = graph.indptr
        self.indices = graph.indices
        self.page_bytes = int(page_bytes)
        self.page_words, self.n_pages = _page_geometry(self.indices,
                                                       self.page_bytes)
        assignment = np.asarray(assignment, np.int8)
        if assignment.shape != (self.n_pages,):
            raise ValueError(f"assignment shape {assignment.shape} does not "
                             f"match {self.n_pages} edge pages")
        self.assignment = assignment
        self.policy = policy
        self.page_shard = (np.zeros(self.n_pages, np.int16)
                           if page_shard is None
                           else np.asarray(page_shard, np.int16))
        self.n_shards = (len(shard_specs) if shard_specs
                         else int(self.page_shard.max(initial=0)) + 1)
        self.timeline = StorageTimeline(ssd, n_ssd, shard_specs=shard_specs)
        # device-side hot adjacency for the tiered-frontier gather kernel:
        # slot table (page -> row in the compacted hot-page array), rows
        # materialized lazily — the numpy pricing path never pays for jax
        gpu_pages = np.nonzero(self.assignment == TIER_HBM)[0]
        self.page_slot = np.full(self.n_pages, -1, np.int32)
        self.page_slot[gpu_pages] = np.arange(len(gpu_pages), dtype=np.int32)
        self._gpu_pages = gpu_pages
        self._hot_pages_dev = None
        # the adaptive policy learns: every hop's measured page touches feed
        # this table, and plan_refresh/commit_refresh re-admit by it
        self.touches = (TouchTable(self.n_pages)
                        if policy == "adaptive" else None)

    # -- construction ----------------------------------------------------------
    @classmethod
    def from_graph(cls, graph, *, admission: str = "degree",
                   gpu_fraction: float = 0.25, host_fraction: float = 0.5,
                   page_bytes: int = IO_BYTES, ssd: SSDSpec = INTEL_OPTANE,
                   n_ssd: int = 1, n_shards: int = 1,
                   placement: str = "hash", shard_specs=None,
                   page_shard: np.ndarray | None = None,
                   seed: int = 0) -> "TieredTopologyStore":
        """Budgeted build: `gpu_fraction` / `host_fraction` of the edge pages
        go to the HBM / pinned-host tiers (clipped to a partition), placed by
        the registered `admission` policy; the remainder is storage-backed.
        With `n_shards > 1` the storage pages stripe across SSD queues via
        the placement registry shared with the feature plane
        (core/sharding.py) — the `degree` placement reuses the admission
        page scores as its hotness signal.

        An explicit `page_shard` overrides the placement registry: the
        co-partitioned host plane (core/hosts.py) passes the feature tier's
        own per-page host assignment here, so ONE placement decision drives
        both namespaces instead of two independent stripes."""
        page_words, n_pages = _page_geometry(graph.indices, page_bytes)
        gpu_pages = int(np.clip(round(gpu_fraction * n_pages), 0, n_pages))
        host_pages = int(np.clip(round(host_fraction * n_pages), 0,
                                 n_pages - gpu_pages))
        # the score pass is O(E); skip it when nothing consumes a score —
        # the built-in score-free policies ('range', 'random') with a
        # non-degree page placement.  User-registered admissions always get
        # one (they may rank by it, like 'degree' does).
        score = None
        if admission not in ("range", "random") or (
                n_shards > 1 and placement == "degree"):
            score = page_scores(graph.indptr, graph.indices, page_words)
        assignment = make_admission(admission, n_pages, gpu_pages=gpu_pages,
                                    host_pages=host_pages, page_score=score,
                                    seed=seed)
        if n_shards > 1 and n_ssd > 1:
            raise ValueError(
                f"n_ssd={n_ssd} with a {n_shards}-shard topology store: "
                "per-shard queues and the pooled multiplier would model "
                "the same devices twice — set n_shards only")
        if page_shard is not None:
            page_shard = np.asarray(page_shard, np.int16)
            if page_shard.shape != (n_pages,):
                raise ValueError(
                    f"page_shard shape {page_shard.shape} does not match "
                    f"{n_pages} edge pages")
            if shard_specs is None and n_shards > 1:
                shard_specs = (ssd,) * n_shards
        elif n_shards > 1:
            pol = make_placement(placement, n_shards, num_nodes=n_pages,
                                 degrees=score, seed=seed)
            page_shard = np.asarray(pol.shard_of(np.arange(n_pages)),
                                    np.int16)
            if shard_specs is None:
                shard_specs = (ssd,) * n_shards
        return cls(graph, assignment, page_bytes=page_bytes,
                   policy=admission, ssd=ssd, n_ssd=n_ssd,
                   page_shard=page_shard, shard_specs=shard_specs)

    # -- telemetry -------------------------------------------------------------
    def tier_pages(self) -> tuple[int, int, int]:
        """Edge pages resident per tier (hbm, host, storage)."""
        counts = np.bincount(self.assignment, minlength=3)
        return tuple(int(c) for c in counts[:3])

    def tier_bytes(self) -> tuple[int, int, int]:
        return tuple(c * self.page_bytes for c in self.tier_pages())

    def hop_report(self, edge_positions: np.ndarray, *, hop: int = 0,
                   n_frontier: int = 0) -> TopologyGatherReport:
        """Price one hop's adjacency reads: map each read edge position to
        its page, dedupe pages (page == 4 KB IO line, so this IS the
        coalescing step), split by tier/shard, and model the hop time."""
        pos = np.asarray(edge_positions, np.int64)
        if len(pos) == 0:
            return TopologyGatherReport(
                hop=hop, n_frontier=int(n_frontier), n_edge_reads=0,
                pages_by_tier=(0, 0, 0), reads_by_tier=(0, 0, 0),
                shard_pages=(self.n_shards > 1) * (0,) * self.n_shards)
        pages, read_counts = np.unique(pos // self.page_words,
                                       return_counts=True)
        if self.touches is not None:
            self.touches.observe(pages, read_counts)
        tiers = self.assignment[pages]
        pages_by_tier = tuple(
            int(c) for c in np.bincount(tiers, minlength=3)[:3])
        reads_by_tier = tuple(
            int(c) for c in np.bincount(tiers, weights=read_counts,
                                        minlength=3)[:3])
        shard_pages = ()
        if self.n_shards > 1:
            sm = tiers == TIER_STORAGE
            shard_pages = tuple(int(c) for c in np.bincount(
                self.page_shard[pages[sm]], minlength=self.n_shards))
        report = TopologyGatherReport(
            hop=hop, n_frontier=int(n_frontier), n_edge_reads=len(pos),
            pages_by_tier=pages_by_tier, reads_by_tier=reads_by_tier,
            shard_pages=shard_pages)
        report = dataclasses.replace(
            report, time_s=self.timeline.price_topology_hop(report))
        m = self.timeline.metrics
        if m is not None:
            # observability plane: per-hop edge-page telemetry (cumulative
            # counters the per-tier hit-ratio gauges are derived from)
            m.counter("topo.hops").inc()
            m.counter("topo.edge_reads").inc(report.n_edge_reads)
            for tier_name, count in zip(("hbm", "host", "storage"),
                                        pages_by_tier):
                m.counter(f"topo.pages_{tier_name}").inc(count)
            m.counter("topo.sample_s").inc(report.time_s)
        return report

    # -- online re-admission (the adaptive policy's refresh loop) --------------
    def plan_refresh(self):
        """Fold the measured page touches and propose a re-admission under
        the SAME tier budgets: hottest measured pages fill HBM, next-hottest
        pinned host, tail sinks to storage (the build-time ranking, re-run
        on live data).  Returns ``None`` when nothing would move, else
        ``(assignment, n_moved, cost_s, saving_s)`` where `cost_s` prices
        reading every promoted page once from the tier it is leaving (one
        pseudo-hop through `price_topology_hop` — promotion IOs are real)
        and `saving_s` is the modelled per-fold read-time delta: measured
        touch rate x (old tier's per-page service time - new tier's).  The
        caller (`TopologyRefresher`, core/feedback.py) commits only when
        the saving over its horizon beats the cost."""
        if self.touches is None:
            raise ValueError(
                "plan_refresh needs a feedback-enabled store — build it "
                "with admission='adaptive'")
        self.touches.fold()
        scores = self.touches.scores()
        gpu_budget, host_budget, _ = self.tier_pages()
        order = np.argsort(-scores, kind="stable")
        new = _fill_by_order(order, self.n_pages, gpu_budget, host_budget)
        moved = new != self.assignment
        if not moved.any():
            return None
        # promoted pages (moving to a faster tier, lower index) are read
        # once from the tier they leave; demotions are free drops
        promote = moved & (new < self.assignment)
        n_from_host = int((promote & (self.assignment == TIER_HOST)).sum())
        from_storage = promote & (self.assignment == TIER_STORAGE)
        n_from_storage = int(from_storage.sum())
        shard_pages = ()
        if self.n_shards > 1:
            shard_pages = tuple(int(c) for c in np.bincount(
                self.page_shard[np.nonzero(from_storage)[0]],
                minlength=self.n_shards))
        n_promoted = n_from_host + n_from_storage
        cost = 0.0
        if n_promoted:
            cost = self.timeline.price_topology_hop(TopologyGatherReport(
                hop=-1, n_frontier=0,
                n_edge_reads=n_promoted * self.page_words,
                pages_by_tier=(0, n_from_host, n_from_storage),
                reads_by_tier=(0, 0, 0), shard_pages=shard_pages))
        # per-page-read service time by tier: HBM reads at HBM bandwidth,
        # pinned host streams over PCIe, storage adds the device IO
        t_read = np.array([
            self.page_bytes / HBM_BW,
            self.page_bytes / PCIE_GEN4_BW,
            self.page_bytes / PCIE_GEN4_BW
            + 1.0 / self.timeline.spec.peak_iops])
        saving = float(np.sum(
            scores * (t_read[self.assignment] - t_read[new])))
        return new, int(moved.sum()), cost, saving

    def commit_refresh(self, assignment: np.ndarray) -> None:
        """Swap in a refreshed admission (from `plan_refresh`) and rebuild
        the device-side hot-page state.  Budget-preserving by construction —
        per-tier page counts must match the current assignment's, so a
        refresh can never silently grow a tier."""
        assignment = np.asarray(assignment, np.int8)
        if assignment.shape != (self.n_pages,):
            raise ValueError(f"refresh assignment shape {assignment.shape} "
                             f"does not match {self.n_pages} edge pages")
        new_counts = tuple(int(c) for c in
                           np.bincount(assignment, minlength=3)[:3])
        if new_counts != self.tier_pages():
            raise ValueError(
                f"refresh would change tier budgets {self.tier_pages()} -> "
                f"{new_counts}; re-admission must preserve them")
        self.assignment = assignment
        gpu_pages = np.nonzero(assignment == TIER_HBM)[0]
        self.page_slot = np.full(self.n_pages, -1, np.int32)
        self.page_slot[gpu_pages] = np.arange(len(gpu_pages), dtype=np.int32)
        self._gpu_pages = gpu_pages
        self._hot_pages_dev = None           # resident set changed: restage

    # -- device data path ------------------------------------------------------
    def hot_pages(self):
        """The compacted HBM-resident hot-page array, (H, page_words) in the
        adjacency dtype — row `page_slot[p]` holds page p's edge words.  A
        zero-budget store materializes a single dummy row so the kernel's
        clamped -1 slots stay in bounds."""
        if self._hot_pages_dev is None:
            import jax.numpy as jnp                   # deferred: numpy-only
            rows = (self._page_rows(self._gpu_pages)
                    if len(self._gpu_pages)
                    else np.zeros((1, self.page_words), self.indices.dtype))
            self._hot_pages_dev = jnp.asarray(rows)
        return self._hot_pages_dev

    def _page_rows(self, pages: np.ndarray) -> np.ndarray:
        """Materialize whole pages from the host CSR (tail page padded by
        clamping — offsets never address past the real edge count)."""
        idx = (np.asarray(pages, np.int64)[:, None] * self.page_words
               + np.arange(self.page_words, dtype=np.int64)[None, :])
        return self.indices[np.minimum(idx, len(self.indices) - 1)]

    def frontier_gather(self, edge_positions: np.ndarray,
                        use_pallas: bool = True) -> np.ndarray:
        """Gather sampled neighbor words through the tiered page store on
        device: unique touched pages are fetched once — HBM-resident ones
        from `hot_pages()` through the `tiered_gather` Pallas kernel,
        the rest from the staged (host/storage) fallback — then each read
        extracts its word (`ops.tiered_frontier_gather`).  Bit-identical to
        `graph.indices[edge_positions]`."""
        import jax.numpy as jnp
        from repro.kernels import ops
        pos = np.asarray(edge_positions, np.int64)
        pages, inverse = np.unique(pos // self.page_words,
                                   return_inverse=True)
        offsets = (pos % self.page_words).astype(np.int32)
        slots = self.page_slot[pages]
        # stage only the NON-resident pages' bytes: the kernel reads staged
        # row i iff slots[i] < 0 — gathering host rows for HBM-resident
        # pages would be pure wasted copy on the device data path
        staged = np.zeros((len(pages), self.page_words), self.indices.dtype)
        miss = slots < 0
        if miss.any():
            staged[miss] = self._page_rows(pages[miss])
        out = ops.tiered_frontier_gather(
            jnp.asarray(slots), self.hot_pages(), jnp.asarray(staged),
            jnp.asarray(inverse.astype(np.int32)), jnp.asarray(offsets),
            use_pallas=use_pallas)
        return np.asarray(out)


def host_sampling_time(reports) -> float:
    """The CPU-sampling baseline priced over the SAME hops a tiered run
    reported: per hop, `n_edge_reads` pointer-chasing DRAM reads (plus the
    indptr pair per frontier node) across `CPU_SAMPLE_THREADS`, the sampled
    block shipped over PCIe, and one host->device handoff
    (`storage_sim.host_sampling_hop_time`).  The fig7 benchmark gates
    tiered-beats-host on this model."""
    return sum(host_sampling_hop_time(r.n_edge_reads, r.n_frontier)
               for r in reports)
