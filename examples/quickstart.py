"""Quickstart: the GIDS dataloader in 40 lines.

Builds a synthetic power-law graph and streams mini-batches through four
declarative data planes — the paper's full GIDS stack (dynamic access
accumulator + constant CPU buffer + window-buffered cache), its prefetching
variant (gids-async: batch k+1 staged while batch k trains, only the excess
prep exposed), and the mmap/BaM baselines — printing each plane's tier split
and modelled data-prep time.  A data plane is a `DataPlaneSpec` preset (or
your own registered stack); the loader just consumes it.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (DataPlaneSpec, GIDSDataLoader, LoaderConfig,
                        SAMSUNG_980PRO)
from repro.graph.synthetic import rmat_graph

graph = rmat_graph(num_nodes=100_000, avg_degree=12, feature_dim=256,
                   seed=0)
features = np.random.default_rng(0).standard_normal(
    (graph.num_nodes, 256)).astype(np.float32)

print(f"graph: {graph.num_nodes:,} nodes, {graph.num_edges:,} edges, "
      f"features {features.nbytes/2**20:.0f} MiB")
print(f"registered data planes: {', '.join(DataPlaneSpec.names())}\n")

TRAIN_STEP_S = 2e-3          # pretend model compute, for the async overlap

for name in ("mmap", "bam", "gids", "gids-async"):
    spec = DataPlaneSpec.preset(name)
    loader = GIDSDataLoader(
        graph, features,
        LoaderConfig(batch_size=1024, fanouts=(10, 5), data_plane=spec,
                     cache_lines=8192, window_depth=8, cbuf_fraction=0.1),
        ssd=SAMSUNG_980PRO)
    prep, exposed = [], []
    for _ in range(10):
        # a prefetching plane (gids-async) stages the next batches ahead and
        # only prep in excess of the train step reaches the critical path
        batch = loader.next_batch(compute_s=TRAIN_STEP_S)
        prep.append(batch.prep_time_s)
        exposed.append(batch.exposed_prep_s)
    r = batch.report
    hit = loader.store.cache.stats.hit_ratio if loader.store.cache else 0.0
    tiers = " ".join(f"{t}={n}" for t, n in zip(r.tier_names, r.tier_counts))
    print(f"[{name:10s}] prep {np.mean(prep)*1e3:8.2f} ms/iter "
          f"(exposed {np.mean(exposed)*1e3:6.2f} ms) | "
          f"tier split {tiers} | cache hit {hit:.2f} | "
          f"lookahead depth {batch.merge_depth}")

print("\nfeatures gathered for the last batch:", batch.features.shape)
