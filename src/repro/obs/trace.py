"""Span tracer for the priced data plane, with Chrome trace-event export.

The data plane runs on two clocks.  *Virtual* (priced) time is what the
storage model charges — every `prep_time_s`, burst, and serve latency is
a deterministic float produced by `StorageTimeline`.  *Wall* time is how
long the Python simulation itself takes.  The tracer records both:

* **Virtual spans** form a tree per batch / serve window: the root span's
  duration is the priced time of the whole unit and its sequential
  children partition it (per-hop sampling, gather, feedback charge, ...).
  Parallel children (per-shard / per-host drains, fault recovery
  sub-events) overlay the parent on their own track and are excluded
  from the parent-sum reconciliation.  Virtual spans without an explicit
  start are laid out lazily at export time on per-track cursors, so the
  hot path only stores durations.
* **Wall spans** come from ``tracer.stage(name)`` context managers that
  measure ``time.perf_counter`` around a pipeline stage; attaching the
  priced duration via ``handle.modelled(dur_s)`` records a point in the
  ``modelled_vs_measured.<stage>`` series of the registry — the gap the
  ROADMAP wants as a tracked number.

Export is Chrome trace-event JSON (the ``traceEvents`` array form), which
Perfetto loads directly: virtual time on pid 1, wall time on pid 2, one
named thread (track) per pipeline / window / shard / host / tenant /
controller lane.

The default tracer everywhere is :data:`NULL_TRACER` — a shared no-op
whose methods return inert singletons, so instrumented code paths cost a
predicate or an empty call when tracing is off and the priced numbers
are bit-identical either way.
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager
from typing import Any, Iterator

from repro.obs.metrics import MetricsRegistry, NULL_METRICS

PID_VIRTUAL = 1
PID_WALL = 2

# span kinds
SPAN = "span"          # virtual interval with optional children
INSTANT = "instant"    # zero-duration virtual event
WALL = "wall"          # perf_counter-measured stage


def _jsonify(value: Any) -> Any:
    """Coerce span args to JSON-safe scalars (numpy included)."""
    if value is None or isinstance(value, (bool, int, str)):
        return value
    if isinstance(value, float):
        return value
    item = getattr(value, "item", None)
    if callable(item):
        try:
            return item()
        except (TypeError, ValueError):
            pass
    if isinstance(value, (list, tuple)):
        return [_jsonify(v) for v in value]
    try:
        return float(value)
    except (TypeError, ValueError):
        return str(value)


class Span:
    """One node of a trace tree; durations in (virtual or wall) seconds."""

    __slots__ = ("name", "cat", "kind", "track", "t0", "dur",
                 "wall_t0", "wall_dur", "parallel", "args", "children")

    def __init__(self, name: str, *, cat: str = "stage", kind: str = SPAN,
                 track: str | None = None, t0: float | None = None,
                 dur: float | None = None, parallel: bool = False,
                 args: dict | None = None):
        self.name = name
        self.cat = cat
        self.kind = kind
        self.track = track
        self.t0 = t0
        self.dur = dur
        self.wall_t0: float | None = None
        self.wall_dur: float | None = None
        self.parallel = parallel
        self.args = args or {}
        self.children: list[Span] = []

    # -- building ---------------------------------------------------------
    def child(self, name: str, dur: float = 0.0, *, cat: str = "stage",
              track: str | None = None, t0: float | None = None,
              parallel: bool = False, **args) -> "Span":
        sp = Span(name, cat=cat, kind=SPAN, track=track, t0=t0,
                  dur=float(dur), parallel=parallel, args=args)
        self.children.append(sp)
        return sp

    def event(self, name: str, *, cat: str = "event",
              track: str | None = None, t0: float | None = None,
              parallel: bool = True, **args) -> "Span":
        sp = Span(name, cat=cat, kind=INSTANT, track=track, t0=t0,
                  parallel=parallel, args=args)
        self.children.append(sp)
        return sp

    def close(self, dur: float | None = None) -> "Span":
        """Fix the span's duration (default: sum of sequential children)."""
        self.dur = float(self.sequential_sum() if dur is None else dur)
        return self

    def annotate(self, **args) -> "Span":
        self.args.update(args)
        return self

    def modelled(self, dur_s: float) -> "Span":
        """Attach the priced duration to a wall-clock stage span."""
        self.dur = float(dur_s)
        return self

    # -- reconciliation ---------------------------------------------------
    def sequential_sum(self) -> float:
        return float(sum(c.dur or 0.0 for c in self.children
                         if c.kind == SPAN and not c.parallel))

    def reconcile_error(self) -> float:
        """abs(dur - sum of sequential children), if it has any."""
        seq = [c for c in self.children if c.kind == SPAN and not c.parallel]
        if not seq or self.dur is None:
            return 0.0
        return abs(self.dur - self.sequential_sum())

    def walk(self) -> Iterator["Span"]:
        yield self
        for c in self.children:
            yield from c.walk()


class _NullSpan:
    """Inert span: every builder call returns itself and records nothing."""

    __slots__ = ()
    name = "<null>"
    dur = None
    t0 = None
    children: list = []
    args: dict = {}

    def child(self, name, dur=0.0, **kw):
        return self

    def event(self, name, **kw):
        return self

    def close(self, dur=None):
        return self

    def annotate(self, **kw):
        return self

    def modelled(self, dur_s):
        return self

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class Tracer:
    """Collects virtual span trees, instants, and wall-clock stage spans."""

    enabled = True

    def __init__(self, metrics: MetricsRegistry | None = None):
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._events: list[Span] = []      # top-level virtual spans/instants
        self._wall: list[Span] = []        # closed wall stage spans
        self._laid_out = False

    # -- building ---------------------------------------------------------
    def batch(self, name: str, *, track: str = "pipeline",
              cat: str = "batch", t0: float | None = None, **args) -> Span:
        """Open a top-level virtual span (a batch, window, or request)."""
        sp = Span(name, cat=cat, kind=SPAN, track=track, t0=t0, args=args)
        self._events.append(sp)
        self._laid_out = False
        return sp

    def instant(self, name: str, *, track: str = "controller",
                cat: str = "event", t0: float | None = None, **args) -> Span:
        """Record a zero-duration virtual event (controller commits etc.)."""
        sp = Span(name, cat=cat, kind=INSTANT, track=track, t0=t0, args=args)
        self._events.append(sp)
        self._laid_out = False
        return sp

    @contextmanager
    def stage(self, name: str, *, track: str = "loop", cat: str = "stage",
              **args):
        """Wall-clock a pipeline stage; ``handle.modelled(s)`` records the
        modelled-vs-measured gap for this stage into the registry."""
        sp = Span(name, cat=cat, kind=WALL, track=track, args=args)
        sp.wall_t0 = time.perf_counter()
        try:
            yield sp
        finally:
            sp.wall_dur = time.perf_counter() - sp.wall_t0
            self._wall.append(sp)
            if sp.dur is not None:
                self.metrics.series(f"modelled_vs_measured.{name}").append({
                    "modelled_s": sp.dur,
                    "measured_s": sp.wall_dur,
                    "gap_s": sp.wall_dur - sp.dur,
                })

    def reset(self) -> None:
        self._events.clear()
        self._wall.clear()
        self._laid_out = False
        self.metrics.reset()

    # -- inspection -------------------------------------------------------
    def roots(self) -> list[Span]:
        return [sp for sp in self._events if sp.kind == SPAN]

    def instants(self) -> list[Span]:
        return [sp for sp in self._events if sp.kind == INSTANT]

    def wall_spans(self) -> list[Span]:
        return list(self._wall)

    def spans(self) -> Iterator[Span]:
        for root in self._events:
            yield from root.walk()

    def max_reconcile_error(self) -> float:
        return max((sp.reconcile_error() for sp in self.spans()),
                   default=0.0)

    # -- layout -----------------------------------------------------------
    def _layout(self) -> None:
        """Assign start times to spans created without one: per-track
        cursors for top-level spans, sequential packing for children."""
        if self._laid_out:
            return
        clocks: dict[str, float] = {}
        for ev in self._events:
            if ev.track is None:
                ev.track = "pipeline"
            if ev.kind == INSTANT:
                if ev.t0 is None:
                    ev.t0 = max(clocks.values(), default=0.0)
                continue
            self._layout_tree(ev, clocks.get(ev.track, 0.0))
            clocks[ev.track] = max(clocks.get(ev.track, 0.0),
                                   ev.t0 + (ev.dur or 0.0))
        self._laid_out = True

    def _layout_tree(self, sp: Span, cursor: float) -> None:
        if sp.dur is None:
            sp.close()
        if sp.t0 is None:
            sp.t0 = cursor
        child_cursor = sp.t0
        for c in sp.children:
            if c.track is None:
                c.track = sp.track
            if c.kind == INSTANT:
                if c.t0 is None:
                    c.t0 = sp.t0 if c.parallel else child_cursor
                continue
            self._layout_tree(c, sp.t0 if c.parallel else child_cursor)
            if not c.parallel:
                child_cursor = c.t0 + (c.dur or 0.0)

    # -- export -----------------------------------------------------------
    def chrome_events(self) -> list[dict]:
        """Render as Chrome trace-event JSON objects (Perfetto-loadable):
        virtual time on pid 1, wall time on pid 2, one tid per track."""
        self._layout()
        events: list[dict] = [
            {"ph": "M", "pid": PID_VIRTUAL, "tid": 0, "ts": 0,
             "name": "process_name", "args": {"name": "virtual (priced)"}},
            {"ph": "M", "pid": PID_WALL, "tid": 0, "ts": 0,
             "name": "process_name", "args": {"name": "wall clock"}},
        ]
        tids: dict[tuple[int, str], int] = {}

        def tid_for(pid: int, track: str) -> int:
            key = (pid, track)
            tid = tids.get(key)
            if tid is None:
                tid = tids[key] = 1 + sum(1 for k in tids if k[0] == pid)
                events.append({"ph": "M", "pid": pid, "tid": tid, "ts": 0,
                               "name": "thread_name",
                               "args": {"name": track}})
            return tid

        def emit(sp: Span) -> None:
            tid = tid_for(PID_VIRTUAL, sp.track or "pipeline")
            args = {k: _jsonify(v) for k, v in sp.args.items()}
            if sp.kind == INSTANT:
                events.append({"name": sp.name, "cat": sp.cat, "ph": "i",
                               "s": "t", "pid": PID_VIRTUAL, "tid": tid,
                               "ts": sp.t0 * 1e6, "args": args})
                return
            events.append({"name": sp.name, "cat": sp.cat, "ph": "X",
                           "pid": PID_VIRTUAL, "tid": tid,
                           "ts": sp.t0 * 1e6, "dur": sp.dur * 1e6,
                           "args": args})
            for c in sp.children:
                emit(c)

        for ev in self._events:
            emit(ev)

        base = min((w.wall_t0 for w in self._wall), default=0.0)
        for w in self._wall:
            args = {k: _jsonify(v) for k, v in w.args.items()}
            if w.dur is not None:
                args["modelled_s"] = w.dur
                args["gap_s"] = w.wall_dur - w.dur
            events.append({"name": w.name, "cat": w.cat, "ph": "X",
                           "pid": PID_WALL,
                           "tid": tid_for(PID_WALL, w.track or "loop"),
                           "ts": (w.wall_t0 - base) * 1e6,
                           "dur": w.wall_dur * 1e6, "args": args})
        return events

    def write(self, path: str) -> list[dict]:
        events = self.chrome_events()
        with open(path, "w") as f:
            json.dump({"traceEvents": events,
                       "displayTimeUnit": "ms"}, f)
            f.write("\n")
        return events


class NullTracer(Tracer):
    """Shared zero-cost tracer: records nothing, returns inert handles."""

    enabled = False

    def __init__(self):
        self.metrics = NULL_METRICS
        self._events = []
        self._wall = []
        self._laid_out = True

    def batch(self, name, **kw):
        return NULL_SPAN

    def instant(self, name, **kw):
        return NULL_SPAN

    def stage(self, name, **kw):
        return NULL_SPAN          # _NullSpan is its own context manager

    def reset(self):
        pass

    def chrome_events(self):
        return []


NULL_TRACER = NullTracer()


def attach_burst_spans(parent: Span, burst: Any) -> None:
    """Overlay a priced sharded/host burst on a gather span: one parallel
    child per shard (or host) on its own track, plus fault retry / hedge /
    failover sub-events when the burst carries recovery telemetry."""
    per_shard = getattr(burst, "per_shard_s", None)
    if per_shard is None:
        return
    is_host = hasattr(burst, "link_s")
    prefix = "host" if is_host else "shard"
    for i, t in enumerate(per_shard):
        args: dict[str, Any] = {}
        for field, key in (("per_shard_rows", "rows"),
                           ("per_shard_lines", "lines")):
            vals = getattr(burst, field, None)
            if vals is not None:
                args[key] = int(vals[i])
        if is_host:
            args["local_s"] = float(burst.local_s[i])
            args["link_s"] = float(burst.link_s[i])
            remote = getattr(burst, "remote_lines", None)
            if remote is not None:
                args["remote_lines"] = int(remote[i])
        if float(t) <= 0.0 and not args.get("rows") and not args.get("lines"):
            continue
        parent.child(f"{prefix}{i}", float(t), cat="storage",
                     track=f"{prefix}{i}", parallel=True, **args)
    fault_src = getattr(burst, "local_burst", None) or burst
    recovery = getattr(fault_src, "recovery_events", None)
    if callable(recovery):
        for kind, shard, args in recovery():
            dur = float(args.pop("recovery_s", 0.0))
            parent.child(f"fault/{kind}", dur, cat="fault",
                         track=f"{prefix}{shard}", parallel=True,
                         shard=shard, **args)
