"""SLO-aware admission and batching for the GNN serve plane.

The training loader merges a FIXED lookahead depth of batches because epochs
have no deadlines; online serving merges *in-flight requests* instead, and
the binding constraint is the oldest staged request's SLO.  `SLOBatcher`
forms windows over an arrival-ordered stream under the
`DeadlineWindowPolicy` (core/accumulator.py):

  * the window keeps admitting compatible requests while the next arrival
    lands before `close_by = oldest.arrival + oldest.deadline -
    safety * est_service(n)` — i.e. while waiting for it cannot by itself
    cost the oldest request its SLO;
  * the depth cap (`DeadlineWindowConfig.max_window`) keeps the same
    buffer-memory guard the training accumulator's `max_merge_iters` has;
  * a backlogged engine (busy past `close_by`) keeps admitting until the
    accelerator frees up — batching is free when service can't start anyway
    (work conservation);
  * expired requests — ones whose deadline has already passed before they
    could even be staged — are shed at admission rather than sampled,
    gathered, and delivered dead (`shed_expired`); shed requests count
    against goodput, not against served-latency percentiles.

All requests in one `next_window` call are "compatible": same fanouts,
same model — the engine owns one (model, fanouts) pair and every stream
request targets it — and the same tenant: the engine hands this batcher
one tenant's pending queue at a time, so windows are tenant-pure and a
noisy tenant's arrivals can never inflate another tenant's window.
"""
from __future__ import annotations

import dataclasses
from collections import deque

from repro.core.accumulator import DeadlineWindowPolicy

from .workload import ServeRequest


@dataclasses.dataclass
class WindowDecision:
    """One formed window: the staged requests (arrival order), the requests
    shed at admission, the virtual time service may begin (before the
    engine's sampling-completion adjustment), and why the window closed."""

    staged: list[ServeRequest]
    shed: list[ServeRequest]
    start_s: float
    hit_cap: bool


class SLOBatcher:
    """Deadline-bounded window formation over a virtual-time stream."""

    def __init__(self, policy: DeadlineWindowPolicy,
                 shed_expired: bool = True):
        self.policy = policy
        self.shed_expired = shed_expired

    def _expired(self, req: ServeRequest, earliest_start_s: float) -> bool:
        return (self.shed_expired
                and earliest_start_s > req.arrival_s + req.deadline_s)

    def next_window(self, pending: deque[ServeRequest],
                    busy_until_s: float) -> WindowDecision | None:
        """Form the next window from the arrival-ordered `pending` queue.
        `busy_until_s` is when the accelerator frees up — service can never
        start earlier, and requests already hopeless by then are shed."""
        shed: list[ServeRequest] = []
        oldest: ServeRequest | None = None
        while pending:
            req = pending.popleft()
            if self._expired(req, max(busy_until_s, req.arrival_s)):
                shed.append(req)
                continue
            oldest = req
            break
        if oldest is None:
            return (WindowDecision(staged=[], shed=shed, start_s=busy_until_s,
                                   hit_cap=False) if shed else None)

        staged = [oldest]
        hit_cap = False
        while True:
            if self.policy.full(len(staged)):
                hit_cap = True
                break
            close_by = self.policy.close_by(
                oldest.arrival_s, oldest.deadline_s, len(staged))
            bound = max(close_by, busy_until_s)   # work conservation: admit
            if not pending:                       # while the engine is busy
                break
            nxt = pending[0]
            if nxt.arrival_s > bound:
                break
            pending.popleft()
            if self._expired(nxt, max(bound, nxt.arrival_s)):
                shed.append(nxt)
                continue
            staged.append(nxt)

        last_arrival = staged[-1].arrival_s
        if hit_cap:
            # a full window starts as soon as the engine can take it
            start = max(busy_until_s, last_arrival)
        else:
            # the controller waited for arrivals until the slack ran out —
            # it has no oracle for the next arrival time, so the window
            # opens exactly when the oldest request's slack is spent
            close_by = self.policy.close_by(
                oldest.arrival_s, oldest.deadline_s, len(staged))
            start = max(busy_until_s, last_arrival, close_by)
        return WindowDecision(staged=staged, shed=shed, start_s=start,
                              hit_cap=hit_cap)
