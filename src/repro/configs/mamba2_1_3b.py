"""mamba2-1.3b [ssm] — 48L d_model=2048, attn-free SSD (state-space
duality), ssm_state=128, headdim=64, expand=2, vocab=50280.  Runs long_500k
(O(1) decode state). [arXiv:2405.21060; unverified]
"""
import dataclasses
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b", family="ssm",
        num_layers=48, d_model=2048, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=128,
        tie_embeddings=True,
    )


def reduced_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=4, d_model=64, vocab_size=512, vocab_pad_to=64,
        ssm_state=16, ssm_headdim=8, ssm_chunk=8, remat=False)
