"""Online multi-tenant GNN inference over the shared tiered data plane.

`GNNServeEngine` runs sample -> gather -> GNN-forward per request against
the SAME data plane the training loader uses — a `TieredFeatureStore` built
from a `DataPlaneSpec` preset (default "serve-gnn": per-tenant partitioned
HBM cache + pinned-host hot set + direct storage) and, for priced
GPU-initiated sampling, a `TieredTopologyStore`.  The engine is a
virtual-time discrete-event simulation: arrivals come time-stamped from
`serve/workload.py`, every stage is priced by the storage-timeline models,
and no wall clock is involved, so runs are bit-reproducible.

Two execution modes share one code path:

  * merged (`config.merged=True`) — the tentpole: the `SLOBatcher`
    (serve/admission.py) forms deadline-bounded windows under the
    `DeadlineWindowPolicy`, compatible in-flight requests merge through the
    training plane's `merge_window`/`gather_merged` path (cross-REQUEST
    dedup is cross-batch dedup), and the window's storage rows coalesce
    into one priced burst; compatibility includes the tenant — windows are
    tenant-pure (see `run`);
  * per-request (`config.merged=False`) — the baseline: FIFO service, one
    tier fold and one `price_batch` burst per request, no dedup, no line
    coalescing across requests.

Sampling runs at ADMISSION (GPU-initiated, against the topology store) and
overlaps window formation — a window cannot start service before its last
staged sample lands, but slack usually hides sampling entirely; the
per-request baseline gets the same rule (sampling overlaps its queue wait).
Identical request streams produce bit-identical sampled blocks and feature
rows in both modes — merging changes latency, never results.

Every request retires with a priced latency breakdown: queue wait (window
formation + accelerator backlog), its own sampling hops, its share of the
window's gather burst (proportional to its row count), and forward compute
(modelled per-row cost; pass `model`/`params` to also run the real GNN
forward on the gathered rows).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Sequence

import numpy as np

from repro.core.accumulator import (DeadlineWindowConfig,
                                    DeadlineWindowPolicy, merge_window)
from repro.core.dataplane import DataPlane, DataPlaneSpec
from repro.core.storage_sim import SAMSUNG_980PRO, SSDSpec, StorageTimeline
from repro.core.tiers import TenantCacheTier
from repro.core.topology import TieredTopologyStore
from repro.sampling.neighbor import host_sample_blocks
from repro.sampling.tiered import tiered_sample_blocks

from .admission import SLOBatcher
from .workload import ServeRequest


@dataclasses.dataclass
class GNNServeConfig:
    fanouts: Sequence[int] = (10, 5)
    merged: bool = True             # deadline-bounded windows vs per-request
    data_plane: str = "serve-gnn"   # preset name or DataPlaneSpec
    cache_lines: int = 8192
    cache_ways: int = 8
    tenants: int = 1
    tenant_quotas: Sequence[float] | None = None
    # adaptive quotas (core/feedback.QuotaController): every
    # `quota_interval` served windows, re-split the tenant cache's line
    # budget by EMA-smoothed per-tenant miss traffic (each tenant floored
    # at `quota_floor` of the lines), via TenantCacheTier.repartition
    adaptive_quotas: bool = False
    quota_interval: int = 8
    quota_floor: float = 0.05
    cbuf_fraction: float = 0.05
    # deadline-bounded admission (core/accumulator.DeadlineWindowPolicy)
    max_window: int = 16
    slack_safety: float = 2.5       # heavy-tail fanouts make window service
                                    # variance large; the extra margin eats
                                    # slack, not the SLO
    shed_expired: bool = True
    # priced GPU-initiated sampling (core/topology.TieredTopologyStore)
    use_topology: bool = True
    topo_admission: str = "degree"
    topo_gpu_fraction: float = 0.25
    topo_host_fraction: float = 0.5
    # modelled forward compute: one launch per WINDOW (batching amortizes
    # the launch constant), base + per_row * total window rows
    forward_base_s: float = 3e-5
    forward_per_row_s: float = 2e-8
    keep_features: bool = False     # retain gathered rows on each record
    seed: int = 0


@dataclasses.dataclass
class RequestRecord:
    """One retired request with its priced latency breakdown."""

    rid: int
    tenant: int
    arrival_s: float
    deadline_s: float
    rejected: bool = False          # shed at admission (goodput, not p99)
    start_s: float = 0.0            # window service start
    completion_s: float = 0.0
    queue_wait_s: float = 0.0       # arrival -> service start
    sample_s: float = 0.0           # own sampling hops (priced)
    gather_s: float = 0.0           # share of the window burst
    forward_s: float = 0.0          # modelled forward compute
    window_size: int = 0            # requests in the serving window
    n_rows: int = 0                 # unique feature rows of this request
    all_nodes: np.ndarray | None = None
    features: np.ndarray | None = None   # kept iff config.keep_features
    logits: np.ndarray | None = None     # set iff a model was supplied

    @property
    def latency_s(self) -> float:
        return self.completion_s - self.arrival_s

    @property
    def deadline_met(self) -> bool:
        return (not self.rejected
                and self.latency_s <= self.deadline_s + 1e-12)


@dataclasses.dataclass
class WindowTrace:
    start_s: float
    n_requests: int
    burst_s: float
    service_s: float
    dedup_factor: float
    hit_cap: bool


@dataclasses.dataclass
class ServeResult:
    records: list[RequestRecord]
    windows: list[WindowTrace]
    # per-tenant cumulative cache hit ratio from the serving tier — the
    # quota controller's input surfaced in served telemetry (empty when the
    # plane has no tenant tier)
    tenant_hit_ratios: dict[int, float] = dataclasses.field(
        default_factory=dict)
    # committed quota re-splits: (window index, new quota shares) per
    # QuotaController event; empty on static-quota runs
    quota_trace: list[tuple[int, tuple[float, ...]]] = dataclasses.field(
        default_factory=list)

    @property
    def served(self) -> list[RequestRecord]:
        return [r for r in self.records if not r.rejected]

    @property
    def n_rejected(self) -> int:
        return sum(r.rejected for r in self.records)

    def latencies_s(self, tenant: int | None = None) -> np.ndarray:
        return np.array([r.latency_s for r in self.served
                         if tenant is None or r.tenant == tenant])

    def _pct(self, q: float, tenant: int | None) -> float:
        lat = self.latencies_s(tenant)
        return float(np.percentile(lat, q)) if len(lat) else float("nan")

    def p50_s(self, tenant: int | None = None) -> float:
        return self._pct(50, tenant)

    def p99_s(self, tenant: int | None = None) -> float:
        return self._pct(99, tenant)

    @property
    def makespan_s(self) -> float:
        served = self.served
        if not served:
            return 0.0
        return (max(r.completion_s for r in served)
                - min(r.arrival_s for r in self.records))

    def goodput_qps(self, tenant: int | None = None) -> float:
        """Completions within deadline per second of makespan — rejected
        and late requests produce no goodput."""
        span = self.makespan_s
        if span <= 0:
            return 0.0
        met = sum(r.deadline_met for r in self.records
                  if tenant is None or r.tenant == tenant)
        return met / span

    def offered_qps(self) -> float:
        if len(self.records) < 2:
            return 0.0
        arrivals = sorted(r.arrival_s for r in self.records)
        return (len(arrivals) - 1) / max(arrivals[-1] - arrivals[0], 1e-12)

    def mean_breakdown_s(self) -> dict:
        served = self.served
        if not served:
            return {k: 0.0 for k in
                    ("queue_wait_s", "sample_s", "gather_s", "forward_s")}
        n = len(served)
        return {
            "queue_wait_s": sum(r.queue_wait_s for r in served) / n,
            "sample_s": sum(r.sample_s for r in served) / n,
            "gather_s": sum(r.gather_s for r in served) / n,
            "forward_s": sum(r.forward_s for r in served) / n,
        }

    @property
    def mean_window(self) -> float:
        if not self.windows:
            return 0.0
        return sum(w.n_requests for w in self.windows) / len(self.windows)


class GNNServeEngine:
    """Virtual-time online inference engine over the shared data plane.

    `plane` / `topo` may be passed in to SHARE an existing data plane (e.g.
    the training loader's) — by default the engine builds its own from
    `config.data_plane`.  `model`/`params` (a `repro.models.gnn.GNN`)
    optionally run the real forward per request; timing always uses the
    modelled forward cost so load sweeps don't need jax.
    """

    def __init__(self, graph, features, config: GNNServeConfig | None = None,
                 ssd: SSDSpec = SAMSUNG_980PRO,
                 plane: DataPlane | None = None,
                 topo: TieredTopologyStore | None = None,
                 model=None, params=None):
        self.graph = graph
        self.features = np.asarray(features)
        self.config = cfg = config or GNNServeConfig()
        self.ssd = ssd
        if plane is None:
            plane = DataPlaneSpec.resolve(cfg.data_plane).build(
                graph, self.features,
                cache_lines=cfg.cache_lines, cache_ways=cfg.cache_ways,
                cbuf_fraction=cfg.cbuf_fraction, tenants=cfg.tenants,
                tenant_quotas=cfg.tenant_quotas, seed=cfg.seed)
        self.plane = plane
        self.store = plane.store
        backstop = self.store.tiers[-1]
        shard_specs = None
        if hasattr(backstop, "resolve_shard_specs"):
            shard_specs = backstop.resolve_shard_specs(ssd)
        self.timeline = StorageTimeline(ssd, 1, shard_specs=shard_specs)
        if topo is None and cfg.use_topology:
            topo = TieredTopologyStore.from_graph(
                graph, admission=cfg.topo_admission,
                gpu_fraction=cfg.topo_gpu_fraction,
                host_fraction=cfg.topo_host_fraction,
                ssd=ssd, seed=cfg.seed)
        self.topo = topo
        self.model, self.params = model, params
        self.policy = DeadlineWindowPolicy(DeadlineWindowConfig(
            max_window=cfg.max_window if cfg.merged else 1,
            safety=cfg.slack_safety))
        self.batcher = SLOBatcher(self.policy,
                                  shed_expired=cfg.shed_expired)
        self._tenant_tier = next(
            (t for t in self.store.tiers if isinstance(t, TenantCacheTier)),
            None)
        self.quota_controller = self._make_quota_controller()
        self._sample_cache: dict = {}

    def _make_quota_controller(self):
        if not (self.config.adaptive_quotas and self._tenant_tier is not None
                and self._tenant_tier.tenants > 1):
            return None
        from repro.core.feedback import QuotaController
        return QuotaController(self._tenant_tier,
                               interval=self.config.quota_interval,
                               floor=self.config.quota_floor)

    # -- stages ----------------------------------------------------------------
    def _sample(self, req: ServeRequest):
        """GPU-initiated sampling at admission, memoized per request.  The
        RNG stream is keyed by (engine seed, rid) — NOT by service order —
        so a request samples the same blocks whether it is served merged,
        per-request, or after a demotion; with a topology store the
        hop-page reads are priced and the modelled time returned."""
        hit = self._sample_cache.get(req.rid)
        if hit is not None:
            return hit
        rng = np.random.default_rng([self.config.seed, req.rid])
        if self.topo is not None:
            blocks = tiered_sample_blocks(self.graph, self.topo, req.seeds,
                                          self.config.fanouts, rng)
            out = (blocks, float(blocks.sample_time_s))
        else:
            out = (host_sample_blocks(self.graph, req.seeds,
                                      self.config.fanouts, rng), 0.0)
        self._sample_cache[req.rid] = out
        return out

    def _forward_s(self, n_rows: int) -> float:
        """One batched forward launch over `n_rows` gathered rows — the
        window pays the launch constant once, which is the other half of
        what merging buys (the per-request baseline pays it per request)."""
        return (self.config.forward_base_s
                + self.config.forward_per_row_s * n_rows)

    def _run_model(self, blocks, rows: np.ndarray):
        if self.model is None:
            return None
        import jax.numpy as jnp
        from repro.models.gnn import hop_indices
        hi = [jnp.asarray(h) for h in hop_indices(blocks)]
        return np.asarray(self.model.forward(self.params,
                                             jnp.asarray(rows), hi))

    def _stage_tenants(self, merged, staged: list[ServeRequest]) -> None:
        """Announce the serving tenant of each unique node to the tenant
        tier: the first requester (admission order) owns the fill for this
        window; later requesters share the deduplicated row."""
        if self._tenant_tier is None:
            return
        tenant_of = np.full(merged.n_unique, -1, np.int64)
        for i, req in enumerate(staged):
            inv = merged.batch_inverse(i)
            fresh = tenant_of[inv] < 0
            tenant_of[inv[fresh]] = req.tenant
        self._tenant_tier.stage_tenants(tenant_of)

    # -- main loop -------------------------------------------------------------
    def run(self, requests: Sequence[ServeRequest]) -> ServeResult:
        """Serve an arrival-time-stamped stream to completion.

        Windows are TENANT-PURE: each tenant has its own pending queue and
        a window only merges requests of one tenant.  Isolation extends to
        the batch dimension — a noisy tenant's burst can inflate its own
        windows but never another tenant's, and a victim request's latency
        reflects its own tenant's cache partition, not whoever happened to
        share the window.  Tenants still share the one engine: service is
        FCFS across tenants by oldest waiting request.
        """
        queues: dict[int, deque] = {}
        for r in sorted(requests, key=lambda r: (r.arrival_s, r.rid)):
            queues.setdefault(r.tenant, deque()).append(r)
        records: list[RequestRecord] = []
        windows: list[WindowTrace] = []
        self._sample_cache.clear()
        busy = 0.0
        while any(queues.values()):
            tenant = min((t for t, q in queues.items() if q),
                         key=lambda t: queues[t][0].arrival_s)
            pending = queues[tenant]
            decision = self.batcher.next_window(pending, busy)
            if decision is None:
                continue
            for req in decision.shed:
                records.append(RequestRecord(
                    rid=req.rid, tenant=req.tenant, arrival_s=req.arrival_s,
                    deadline_s=req.deadline_s, rejected=True))
            if not decision.staged:
                continue
            # a staged request whose sampling would land after the oldest
            # request's slack bound would push the whole window — and that
            # deadline — out by its own sampling tail.  It doesn't hold the
            # window hostage: demote it to the next window (its sample is
            # memoized, nothing re-runs).  The oldest always stays — the
            # window exists for its deadline — and the bound is its slack,
            # not the intended open time, so a backlogged cap-closed window
            # may slip a little to keep its depth (amortization is worth
            # more than an early start while slack remains).
            oldest = decision.staged[0]
            bound = max(decision.start_s, self.policy.close_by(
                oldest.arrival_s, oldest.deadline_s, len(decision.staged)))
            staged, demoted = [oldest], []
            for req in decision.staged[1:]:
                _, sample_s = self._sample(req)
                if req.arrival_s + sample_s <= bound:
                    staged.append(req)
                else:
                    demoted.append(req)
            for req in reversed(demoted):    # arrival order preserved
                pending.appendleft(req)
            decision.staged = staged
            busy = self._execute(decision, records, windows)
            # close the quota loop once per served window: the controller
            # watches the tenant tier's cumulative counters and repartitions
            # when smoothed miss traffic drifts past its dead band
            if self.quota_controller is not None:
                self.quota_controller.step()
        records.sort(key=lambda r: r.rid)
        result = ServeResult(records=records, windows=windows)
        if self._tenant_tier is not None:
            result.tenant_hit_ratios = {
                t: self._tenant_tier.hit_ratio(t)
                for t in range(self._tenant_tier.tenants)}
        if self.quota_controller is not None:
            result.quota_trace = list(self.quota_controller.events)
        return result

    def _execute(self, decision, records, windows) -> float:
        staged = decision.staged
        samples = [self._sample(r) for r in staged]
        # service cannot start before the last staged sample lands —
        # sampling is admission-time GPU work overlapping window formation
        start = max([decision.start_s]
                    + [r.arrival_s + s for r, (_, s) in zip(staged, samples)])
        blocks = [b for b, _ in samples]
        merged = merge_window([b.all_nodes for b in blocks])
        self._stage_tenants(merged, staged)

        if len(staged) == 1 and not self.config.merged:
            # per-request baseline: one fold, one un-coalesced burst whose
            # overlap efficiency comes from this request's own storage
            # concurrency alone (no accumulator ramping across requests)
            rows, report = self.store.gather(blocks[0].all_nodes)
            rows_list = [rows]
            burst_s = self.timeline.price_batch(
                report, outstanding=max(report.n_storage, 1))
            dedup = 1.0
        else:
            rows_list, _, wrep = self.store.gather_merged(merged)
            burst_s = self.timeline.price_merged_burst(wrep)
            dedup = wrep.dedup_factor

        total_rows = sum(len(b.all_nodes) for b in blocks)
        forward_total_s = self._forward_s(total_rows)
        t = start + burst_s + forward_total_s
        for req, (blk, sample_s), rows in zip(staged, samples, rows_list):
            n_rows = len(blk.all_nodes)
            rec = RequestRecord(
                rid=req.rid, tenant=req.tenant, arrival_s=req.arrival_s,
                deadline_s=req.deadline_s, start_s=start, completion_s=t,
                queue_wait_s=start - req.arrival_s, sample_s=sample_s,
                gather_s=burst_s * n_rows / max(total_rows, 1),
                forward_s=forward_total_s * n_rows / max(total_rows, 1),
                window_size=len(staged),
                n_rows=n_rows, all_nodes=blk.all_nodes)
            if self.config.keep_features:
                rec.features = rows
            if self.model is not None:
                rec.logits = self._run_model(blk, rows)
            records.append(rec)
        service_s = t - start
        # the policy's estimate absorbs the sampling-completion push-out of
        # `start` past the batcher's intended open time, so close_by leaves
        # room for it on the next window
        self.policy.observe(t - decision.start_s, len(staged))
        windows.append(WindowTrace(
            start_s=start, n_requests=len(staged), burst_s=burst_s,
            service_s=service_s, dedup_factor=dedup,
            hit_cap=decision.hit_cap))
        return t

    def reset(self) -> None:
        """Fresh caches, fresh RNG, fresh service estimate — a reset engine
        replays a stream bit-identically."""
        self.plane.reset()
        # the topology store is stateless (fixed page assignment) — nothing
        # to reset there
        self.policy.reset()
        # plane.reset restored the construction-time quotas; the controller
        # restarts from the same initial demand estimate
        self.quota_controller = self._make_quota_controller()
        self._sample_cache.clear()
