"""Storage device models (paper §4.2, Table 1 constants).

No NVMe devices exist in this container, so benchmarks run against a
discrete-event simulator parameterised with the paper's measured constants.
The *algorithms* under test (accumulator, cache, constant buffer) are real;
only the device timing is modelled.

Units: seconds, bytes. IO granularity is the 4 KB cache-line the paper uses.
"""
from __future__ import annotations

import dataclasses
import heapq
import warnings

import numpy as np


@dataclasses.dataclass(frozen=True)
class SSDSpec:
    name: str
    peak_iops: float          # 4KB reads / s / SSD
    latency_s: float          # device read latency
    latency_cv: float = 0.15  # coefficient of variation for the event sim

    @property
    def peak_bw(self) -> float:
        return self.peak_iops * IO_BYTES


IO_BYTES = 4096
# Paper §4.2: Optane 1.5M IOPs / 11us; 980Pro 700K IOPs / 324us.
# +25us kernel-launch/init overhead (T_i add-on), 5us termination.
INTEL_OPTANE = SSDSpec("intel-optane", peak_iops=1.5e6, latency_s=11e-6)
SAMSUNG_980PRO = SSDSpec("samsung-980pro", peak_iops=0.7e6, latency_s=324e-6)
T_INIT_SW = 25e-6
T_TERM = 5e-6

PCIE_GEN4_BW = 32e9          # GPU ingress (paper: ~32 GB/s)
HOST_DRAM_BW = 100e9         # constant-buffer service bandwidth
HBM_BW = 1555e9              # A100 HBM2 (Table 1); v5e would be 819e9
# OS page-fault cost dominating the mmap baseline (~few us of kernel time per
# fault plus readahead pollution); calibrated so the mmap baseline reproduces
# the paper's Fig. 5 stage breakdown shape.
MMAP_FAULT_OVERHEAD_S = 4e-6
# -- topology (sampling-stage) constants ---------------------------------------
# CPU-sampling baseline (paper Fig. 3/7: the "CPU sampling" path): adjacency
# reads are dependent pointer chases — one random DRAM access each — spread
# across a thread pool, and every hop ends in a host->device handoff.
HOST_RANDOM_READ_S = 100e-9  # random DRAM access (row miss + pointer chase)
CPU_SAMPLE_THREADS = 16
HOP_SYNC_S = 10e-6           # per-hop CPU->GPU handoff (copy launch + sync)
# GPU-initiated sampling pays one kernel launch per hop; device reads inside
# the hop are covered by the tier terms of `price_topology_hop`.
TOPO_HOP_LAUNCH_S = 5e-6


@dataclasses.dataclass
class BurstResult:
    n_requests: int
    elapsed_s: float
    achieved_iops_per_ssd: float
    efficiency: float  # achieved / peak


def model_burst(spec: SSDSpec, n_requests: int, n_ssd: int = 1) -> BurstResult:
    """Paper Eq. 2-3 analytic model: a burst of `n_requests` concurrent
    accesses spends T_i (latency+sw init) + T_s (steady drain at peak IOPs)
    + T_t; efficiency = T_s / total."""
    t_i = spec.latency_s + T_INIT_SW
    t_s = n_requests / (spec.peak_iops * n_ssd)
    total = t_i + t_s + T_TERM
    achieved = n_requests / (total * n_ssd)
    return BurstResult(n_requests, total, achieved, achieved / spec.peak_iops)


def required_accesses(spec: SSDSpec, target_efficiency: float,
                      n_ssd: int = 1) -> int:
    """Invert Eq. 2-3: N = rho * peak * (T_i + T_t) * n_ssd / (1 - rho)."""
    rho = min(target_efficiency, 0.999)
    t_fixed = spec.latency_s + T_INIT_SW + T_TERM
    return int(np.ceil(rho * spec.peak_iops * n_ssd * t_fixed / (1.0 - rho)))


def simulate_burst(spec: SSDSpec, n_requests: int, n_ssd: int = 1,
                   queue_depth: int | None = None, seed: int = 0
                   ) -> BurstResult:
    """Discrete-event validation of the analytic model ("measured" curve of
    Fig. 8): per-request latency ~ N(lat, cv*lat); each SSD drains its queue
    at peak_iops once requests arrive; queue_depth limits in-flight requests
    (defaults to all — BaM-style massive concurrency)."""
    rng = np.random.default_rng(seed)
    qd = queue_depth or n_requests
    per_ssd = np.array_split(np.arange(n_requests), n_ssd)
    worst = 0.0
    for reqs in per_ssd:
        n = len(reqs)
        if n == 0:
            continue
        service = 1.0 / spec.peak_iops
        lat = np.maximum(rng.normal(spec.latency_s,
                                    spec.latency_cv * spec.latency_s, n), 0)
        # in-flight window of qd: request i issues when completion i-qd done
        complete = np.zeros(n)
        next_free = 0.0  # device channel availability
        for i in range(n):
            issue = T_INIT_SW if i < qd else complete[i - qd]
            start_service = max(issue + lat[i], next_free)
            next_free = start_service + service
            complete[i] = start_service + service
        worst = max(worst, complete[-1] + T_TERM)
    achieved = n_requests / (worst * n_ssd)
    return BurstResult(n_requests, worst, achieved, achieved / spec.peak_iops)


def coalesce_lines(node_ids: np.ndarray, bytes_per_row: int,
                   io_bytes: int = IO_BYTES,
                   shard: np.ndarray | None = None) -> int:
    """Number of `io_bytes`-granule IOs needed to fetch the given storage
    rows, assuming rows are laid out contiguously by node id (the storage
    namespace is the feature array itself).

    Rows narrower than one IO line share it: a 256-dim float32 row is 1 KB,
    so 4 consecutive rows ride one 4 KB line and the merged executor issues
    a single IO for all of them (`rows_per_line = io_bytes // bytes_per_row`,
    row-aligned — a row never straddles two lines in this model).  Rows at
    or above the line size cost `ceil(bytes_per_row / io_bytes)` IOs each
    and nothing coalesces.

    With `shard` (per-row shard ids from a sharded storage tier) coalescing
    is SHARD-LOCAL: the line key is the `(shard, line)` tuple, because two
    rows that share a logical 4 KB line but live on different devices are
    two physical IOs — one per queue — and merging them would under-price
    every sharded plane."""
    n = len(node_ids)
    if n == 0 or bytes_per_row <= 0:
        return 0
    if bytes_per_row >= io_bytes:
        return n * int(-(-bytes_per_row // io_bytes))
    rows_per_line = io_bytes // bytes_per_row
    if rows_per_line <= 1:
        return n
    lines = np.asarray(node_ids, np.int64) // rows_per_line
    if shard is None:
        return len(np.unique(lines))
    key = np.asarray(shard, np.int64) * (int(lines.max()) + 1) + lines
    return len(np.unique(key))


def coalesce_lines_by_shard(node_ids: np.ndarray, shard: np.ndarray,
                            n_shards: int, bytes_per_row: int,
                            io_bytes: int = IO_BYTES) -> np.ndarray:
    """Per-shard 4 KB IO counts after shard-local coalescing, (n_shards,).
    Sums to `coalesce_lines(..., shard=shard)`; feeds the per-shard queue
    drain in `price_sharded_burst`.  One vectorized (shard, line) unique +
    bincount pass — no per-shard rescans."""
    shard = np.asarray(shard)
    node_ids = np.asarray(node_ids, np.int64)
    n = len(node_ids)
    if n == 0 or bytes_per_row <= 0:
        return np.zeros(n_shards, np.int64)
    if bytes_per_row >= io_bytes:
        per_row = int(-(-bytes_per_row // io_bytes))
        return np.bincount(shard, minlength=n_shards).astype(np.int64) \
            * per_row
    rows_per_line = io_bytes // bytes_per_row
    if rows_per_line <= 1:
        return np.bincount(shard, minlength=n_shards).astype(np.int64)
    lines = node_ids // rows_per_line
    stride = int(lines.max()) + 1
    key = shard.astype(np.int64) * stride + lines
    uniq = np.unique(key)
    return np.bincount(uniq // stride, minlength=n_shards).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class ShardedBurstResult:
    """Per-shard drain telemetry for one storage burst over a sharded
    namespace.  `elapsed_s` is the max over shards — the slowest queue sets
    the critical path — and `straggler` names which shard that was, so
    placement skew and heterogeneous devices are measurable, not just
    modelled."""

    per_shard_s: tuple[float, ...]
    per_shard_rows: tuple[int, ...]
    per_shard_lines: tuple[int, ...]
    spec_names: tuple[str, ...]
    ssd_bytes: int                    # total line-capped transfer bytes

    @property
    def n_shards(self) -> int:
        return len(self.per_shard_s)

    @property
    def elapsed_s(self) -> float:
        return max(self.per_shard_s) if self.per_shard_s else 0.0

    @property
    def straggler(self) -> int:
        """Index of the shard whose queue drained last."""
        return int(np.argmax(self.per_shard_s)) if self.per_shard_s else 0

    @property
    def straggler_spec(self) -> str:
        return self.spec_names[self.straggler] if self.spec_names else ""

    @property
    def imbalance(self) -> float:
        """Queue imbalance: slowest shard's drain over the mean drain.  1.0
        = perfectly balanced; the modelled speedup lost to placement skew or
        a straggler device."""
        mean = float(np.mean(self.per_shard_s)) if self.per_shard_s else 0.0
        return self.elapsed_s / mean if mean > 0 else 1.0


@dataclasses.dataclass(frozen=True)
class HostBurstResult(ShardedBurstResult):
    """A sharded burst priced at HOST granularity (core/hosts.py): each
    shard is a host whose drain composes its LOCAL storage queue with the
    link transit of the 4 KB lines other hosts requested from it.
    `per_shard_s` already includes the link term — `elapsed_s`, straggler
    and imbalance telemetry therefore see network skew, not just device
    skew.  `local_s`/`link_s` split each host's drain into the two
    components, and `local_burst` keeps the pre-link result (including any
    `FaultedBurstResult` retry/failover telemetry) intact."""

    link_s: tuple[float, ...] = ()
    local_s: tuple[float, ...] = ()
    remote_lines: tuple[int, ...] = ()
    local_burst: ShardedBurstResult | None = None

    @property
    def remote_fraction(self) -> float:
        """Share of this burst's 4 KB lines that crossed a host link."""
        lines = sum(self.per_shard_lines)
        return sum(self.remote_lines) / lines if lines else 0.0


def price_sharded_burst(specs, shard_rows, shard_lines, bytes_per_row: int,
                        io_bytes: int = IO_BYTES,
                        shard_outstanding=None) -> ShardedBurstResult:
    """Price one storage burst over a sharded namespace: each shard drains
    its OWN queue at its OWN `SSDSpec` (Eq. 2-3 efficiency from that queue's
    concurrency alone — outstanding requests on shard a do not help shard b
    ramp), and the burst completes at the max over shards.

    `shard_rows` / `shard_lines` are per-shard unique storage row and
    coalesced 4 KB IO counts (`coalesce_lines_by_shard`); per-shard transfer
    is capped at line granularity exactly like the unsharded
    `price_merged_burst` accounting.  `shard_outstanding` overrides the
    per-shard queue depth used for the efficiency ramp (defaults to each
    shard's actual row count — the burst's real concurrency)."""
    specs = tuple(specs)
    shard_rows = tuple(int(r) for r in shard_rows)
    shard_lines = tuple(int(l) for l in shard_lines)
    if not (len(specs) == len(shard_rows) == len(shard_lines)):
        raise ValueError(
            f"shard arity mismatch: {len(specs)} specs, {len(shard_rows)} "
            f"row counts, {len(shard_lines)} line counts")
    if shard_outstanding is None:
        shard_outstanding = shard_rows
    per_shard_s, total_bytes = [], 0
    for spec, rows, lines, out in zip(specs, shard_rows, shard_lines,
                                      shard_outstanding):
        if rows <= 0:
            per_shard_s.append(0.0)
            continue
        eff = model_burst(spec, max(int(out), 1), n_ssd=1).efficiency
        ssd_bytes = min(rows * bytes_per_row, lines * io_bytes)
        total_bytes += ssd_bytes
        per_shard_s.append(ssd_bytes / (spec.peak_bw * eff))
    return ShardedBurstResult(
        per_shard_s=tuple(per_shard_s), per_shard_rows=shard_rows,
        per_shard_lines=shard_lines,
        spec_names=tuple(s.name for s in specs), ssd_bytes=total_bytes)


def host_sampling_hop_time(n_edge_reads: int, n_frontier: int,
                           id_bytes: int = 8,
                           threads: int = CPU_SAMPLE_THREADS) -> float:
    """One hop of the CPU-sampling baseline: `n_edge_reads` sampled
    adjacency words plus the indptr pair per frontier node, each a random
    DRAM access amortized over `threads`; the sampled block ships to the
    device over PCIe and the hop ends in one host->device handoff.  The
    GPU-tiered counterpart is `StorageTimeline.price_topology_hop` — the
    fig7 sampling benchmark compares the two on identical hops."""
    if n_edge_reads <= 0 and n_frontier <= 0:
        return 0.0
    reads = n_edge_reads + 2 * n_frontier
    t_cpu = reads * HOST_RANDOM_READ_S / max(threads, 1)
    t_xfer = n_edge_reads * id_bytes / PCIE_GEN4_BW
    return t_cpu + t_xfer + HOP_SYNC_S


def overlap_exposed(prep_s: float, compute_s: float) -> float:
    """max(0, prep - compute): the prep time left on the critical path after
    `compute_s` seconds of concurrent model compute hid the rest.  Pure —
    `StorageTimeline.price_batch_overlapped` and the serve engine's
    admission pricing share it."""
    return max(0.0, prep_s - max(compute_s, 0.0))


class StorageTimeline:
    """Accumulates modelled time for a training run (Fig. 13/14 E2E bench).

    Serves batches of requests split across tiers; returns elapsed time for
    the storage portion assuming perfect overlap within a batch (GIDS) or
    serial page-fault handling (mmap baseline).

    With `shard_specs` set (the loader wires it from a `ShardedStorageTier`
    backstop) the storage portion is priced per shard — each shard drains
    its own queue at its own device and the batch completes at the max over
    shards — and `shard_burst` keeps the most recent per-shard drain
    telemetry (`ShardedBurstResult`: straggler shard, queue imbalance).
    With a `MetricsRegistry` attached on `metrics` (the tracer wires one),
    every priced burst also folds its telemetry into the registry —
    observation only, never feeding back into pricing.
    """

    def __init__(self, spec: SSDSpec, n_ssd: int = 1, shard_specs=None):
        self.spec, self.n_ssd = spec, n_ssd
        self.shard_specs = tuple(shard_specs) if shard_specs else None
        self._last_shard_burst: ShardedBurstResult | None = None
        # observability plane (repro.obs): an attached MetricsRegistry
        # receives per-burst telemetry via `_note_burst`; None records
        # nothing (the default, and the zero-cost no-op tracer path)
        self.metrics = None
        # multi-host plane (core/hosts.py): when the loader wires a tuple of
        # HostLinkSpec here, sharded bursts route through `price_host_burst`
        # — each shard is a host and remote lines pay its link; None keeps
        # every price on the single-host path
        self.host_specs = None
        # fault plane (core/faults.py): when a FaultInjector is attached,
        # every priced storage burst ticks its schedule and faulted bursts
        # are re-priced with retries / failover / hedging; None (the
        # default) leaves every price bit-identical to the fault-free plane
        self.injector = None

    # -- burst telemetry ---------------------------------------------------
    @property
    def shard_burst(self) -> ShardedBurstResult | None:
        """Most recent per-shard drain telemetry (supported accessor)."""
        return self._last_shard_burst

    @property
    def host_burst(self) -> "HostBurstResult | None":
        """The last burst, iff it was priced at host granularity."""
        burst = self._last_shard_burst
        return burst if isinstance(burst, HostBurstResult) else None

    @property
    def last_shard_burst(self) -> ShardedBurstResult | None:
        warnings.warn(
            "StorageTimeline.last_shard_burst is deprecated; read "
            "shard_burst, or the per-burst telemetry in the attached "
            "MetricsRegistry (repro.obs)", DeprecationWarning, stacklevel=2)
        return self._last_shard_burst

    @last_shard_burst.setter
    def last_shard_burst(self, burst) -> None:
        warnings.warn(
            "StorageTimeline.last_shard_burst is deprecated; burst "
            "telemetry is recorded by the pricing paths themselves",
            DeprecationWarning, stacklevel=2)
        self._last_shard_burst = burst

    @property
    def last_host_burst(self) -> "HostBurstResult | None":
        warnings.warn(
            "StorageTimeline.last_host_burst is deprecated; read "
            "host_burst, or the hosts.* metrics in the attached "
            "MetricsRegistry (repro.obs)", DeprecationWarning, stacklevel=2)
        return self.host_burst

    def reset_telemetry(self) -> None:
        """Drop cross-burst telemetry (checkpoint restore calls this so a
        resumed run never reports the pre-restore epoch's last burst)."""
        self._last_shard_burst = None

    def _note_burst(self, burst: ShardedBurstResult) -> None:
        """Record one priced burst: keeps the `shard_burst` accessor fresh
        and, when a registry is attached, folds imbalance / remote-traffic /
        fault-recovery telemetry into it.  Observation only — pricing never
        reads anything written here."""
        self._last_shard_burst = burst
        m = self.metrics
        if m is None:
            return
        m.counter("storage.bursts").inc()
        m.counter("storage.ssd_bytes").inc(burst.ssd_bytes)
        m.histogram("storage.imbalance").observe(burst.imbalance)
        m.gauge("storage.last_straggler").set(burst.straggler)
        if isinstance(burst, HostBurstResult):
            m.histogram("hosts.remote_fraction").observe(
                burst.remote_fraction)
            m.counter("hosts.remote_lines").inc(sum(burst.remote_lines))
            m.counter("hosts.link_s").inc(sum(burst.link_s))
        fault_src = getattr(burst, "local_burst", None) or burst
        recovery = getattr(fault_src, "recovery_events", None)
        if callable(recovery):
            for kind, shard, args in recovery():
                m.counter(f"faults.{kind}_events").inc()
                if "lines" in args:
                    m.counter(f"faults.{kind}_lines").inc(args["lines"])
                if "saving_s" in args:
                    m.counter("faults.hedge_saving_s").inc(args["saving_s"])

    def _fault_adjust(self, burst: ShardedBurstResult,
                      bytes_per_row: int,
                      io_bytes: int = IO_BYTES) -> ShardedBurstResult:
        """Run one priced burst through the attached fault injector (no-op
        without one — the same object comes back, floats untouched)."""
        if self.injector is None:
            return burst
        specs = self.shard_specs or (self.spec,) * burst.n_shards
        return self.injector.price_burst(specs, burst, bytes_per_row,
                                         io_bytes)

    def price_host_burst(self, shard_rows, shard_lines, bytes_per_row: int,
                         io_bytes: int = IO_BYTES, shard_outstanding=None,
                         remote_lines=None) -> HostBurstResult:
        """Price one burst over a CLUSTER (core/hosts.py): shard h is a
        host, whose drain composes its local storage burst with a link-
        transit term, and the burst completes at the max over hosts.

        Each host first drains its local queue exactly like
        `price_sharded_burst` (same per-queue Eq. 2-3 efficiency, same line
        cap, same fault adjustment), then ships the `remote_lines[h]` 4 KB
        lines that OTHER hosts requested from it over its own link:

            t_h = t_local_h + (rtt_h + remote_lines[h] * io / link_bw_h)

        with the link term added only when remote lines exist — a host
        serving purely local traffic prices bit-identically to the single-
        host sharded path (float-for-float: `t + 0.0` is never computed).
        A 1-host cluster therefore reproduces the PR 8 plane exactly, and
        the metis-lite-vs-hash benchmark measures exactly the cross-host
        line traffic the placement was supposed to remove."""
        hosts = self.host_specs
        if hosts is None:
            raise ValueError(
                "price_host_burst needs host_specs wired — only host-"
                "storage planes (core/hosts.py) price over links")
        specs = self.shard_specs or tuple(
            (h.ssd if h.ssd is not None else self.spec) for h in hosts)
        local = price_sharded_burst(specs, shard_rows, shard_lines,
                                    bytes_per_row, io_bytes,
                                    shard_outstanding)
        local = self._fault_adjust(local, bytes_per_row, io_bytes)
        if remote_lines is None or len(tuple(remote_lines)) == 0:
            remote_lines = (0,) * local.n_shards
        remote_lines = tuple(int(r) for r in remote_lines)
        if not (len(hosts) == local.n_shards == len(remote_lines)):
            raise ValueError(
                f"host arity mismatch: {len(hosts)} hosts, "
                f"{local.n_shards} queues, {len(remote_lines)} remote "
                "line counts")
        link_s = tuple(
            (h.link_rtt_s + r * io_bytes / h.link_bw) if r > 0 else 0.0
            for h, r in zip(hosts, remote_lines))
        per_host_s = tuple(t if l == 0.0 else t + l
                           for t, l in zip(local.per_shard_s, link_s))
        return HostBurstResult(
            per_shard_s=per_host_s, per_shard_rows=local.per_shard_rows,
            per_shard_lines=local.per_shard_lines,
            spec_names=tuple(h.name for h in hosts),
            ssd_bytes=local.ssd_bytes, link_s=link_s,
            local_s=local.per_shard_s, remote_lines=remote_lines,
            local_burst=local)

    def price_batch(self, report, outstanding: int,
                    policy: str = "overlapped") -> float:
        """Price one gather from its `GatherReport` tier split.

        policy "overlapped": storage requests overlap under the
        accumulator-maintained outstanding count (GIDS/BaM planes);
        "page_fault": every request is a serially-handled page fault (the
        mmap baseline — redirection tiers don't exist, so the whole batch
        hits storage)."""
        bpr = report.bytes_per_row
        if policy == "page_fault":
            return self.mmap_batch_time(n_storage=report.n_requests,
                                        n_page_cache=0, feat_bytes=bpr)
        if policy == "overlapped":
            if self.shard_specs and getattr(report, "shard_rows", ()):
                return self.gids_batch_time_sharded(
                    shard_rows=report.shard_rows, n_host=report.n_host_hits,
                    n_hbm=report.n_hbm_hits, feat_bytes=bpr,
                    outstanding=outstanding,
                    remote_rows=getattr(report, "remote_rows", ()))
            return self.gids_batch_time(
                n_storage=report.n_storage, n_host=report.n_host_hits,
                n_hbm=report.n_hbm_hits, feat_bytes=bpr,
                outstanding=outstanding)
        raise ValueError(f"unknown pricing policy {policy!r}")

    def price_batch_overlapped(self, prep_s: float, compute_s: float) -> float:
        """Exposed (critical-path) prep time when data preparation for batch
        k+1 runs concurrently with batch k's model compute (paper §3.2: the
        decoupled stages hide storage latency behind training).  `compute_s`
        seconds of the prep are hidden; only the excess is exposed:

            exposed = max(0, prep_s - compute_s)

        A synchronous plane passes compute_s=0 and exposes everything."""
        return overlap_exposed(prep_s, compute_s)

    def price_merged_burst(self, report, outstanding: int | None = None,
                           io_bytes: int = IO_BYTES) -> float:
        """Price a merged window's gather as ONE storage burst (§3.2's merge
        made real — see `GIDSDataLoader.execute_window`).

        `report` is the window-level `CoalescedReport` over the *unique*
        request set: `n_storage` counts unique storage-bound rows,
        `n_storage_lines` the 4 KB IOs after line coalescing, and the
        host/HBM hit counts cover unique redirections.  Accounting matches
        `gids_batch_time` (per-row bytes, concurrent links, PCIe cap on
        host+storage ingress) so the comparison against the per-batch path
        isolates the dedup win; the SSD transfer is additionally capped at
        line granularity — when unique rows densely share IO lines, whole-
        line fetches (`n_storage_lines * io_bytes`) move fewer bytes than
        row-by-row reads and the device serves the smaller of the two.

        Efficiency comes from the burst's ACTUAL concurrency — the unique
        storage row requests the merged executor really issues in one burst
        — not the accumulator's modelled outstanding; the Eq. 2-3 ramp is
        paid once per window instead of once per batch.

        On a sharded namespace (`shard_specs` set and the report carrying
        per-shard row/line counts) the SSD term is the max over per-shard
        queue drains (`price_sharded_burst`) instead of one pooled burst;
        PCIe still caps the combined ingress.

        Returns TOTAL window seconds; the caller amortizes per batch."""
        bpr = report.bytes_per_row
        n_rows = report.n_storage
        if self.shard_specs and getattr(report, "shard_rows", ()):
            shard_lines = (report.shard_lines if
                           getattr(report, "shard_lines", ())
                           else report.shard_rows)
            if self.host_specs is not None:
                # host plane: the report's per-host remote line counts (the
                # second coalescing level) ride each serving host's link
                burst = self.price_host_burst(
                    report.shard_rows, shard_lines, bpr, io_bytes,
                    remote_lines=getattr(report, "remote_lines", ()))
            else:
                burst = price_sharded_burst(self.shard_specs,
                                            report.shard_rows, shard_lines,
                                            bpr, io_bytes)
                burst = self._fault_adjust(burst, bpr, io_bytes)
            self._note_burst(burst)
            t_ssd, ssd_bytes = burst.elapsed_s, burst.ssd_bytes
        else:
            lines = getattr(report, "n_storage_lines", n_rows)
            if outstanding is None:
                outstanding = max(n_rows, 1)
            eff = model_burst(self.spec, max(outstanding, 1),
                              self.n_ssd).efficiency
            ssd_bytes = min(n_rows * bpr, lines * io_bytes) if n_rows else 0
            t_ssd = ssd_bytes / (self.spec.peak_bw * self.n_ssd * eff) \
                if n_rows else 0.0
            if self.injector is not None:
                # the unsharded plane is one storage queue: wrap the burst
                # so the fault schedule prices it the same way
                burst = self._fault_adjust(
                    ShardedBurstResult((t_ssd,), (n_rows,), (int(lines),),
                                       (self.spec.name,), int(ssd_bytes)),
                    bpr, io_bytes)
                self._note_burst(burst)
                t_ssd, ssd_bytes = burst.elapsed_s, burst.ssd_bytes
        n_host, n_hbm = report.n_host_hits, report.n_hbm_hits
        t_host = n_host * bpr / HOST_DRAM_BW if n_host else 0.0
        t_hbm = n_hbm * bpr / HBM_BW if n_hbm else 0.0
        t_pcie = (ssd_bytes + n_host * bpr) / PCIE_GEN4_BW
        return max(t_ssd, t_host, t_hbm, t_pcie)

    def price_topology_hop(self, report, io_bytes: int = IO_BYTES) -> float:
        """Price one GPU-initiated sampling hop over a tiered topology store
        (core/topology.py).  `report` is a `TopologyGatherReport`: unique
        4 KB edge pages touched, split (hbm, host, storage).

        HBM-resident pages read at HBM bandwidth; pinned-host pages stream
        zero-copy over PCIe; storage pages are page-granular IOs — one
        4 KB line each, already deduplicated (the topology twin of
        `coalesce_lines`) — served as one burst whose elapsed time comes
        from the Eq. 2-3 model at the burst's own concurrency.  On a
        sharded topology namespace (`shard_specs` set and the report
        carrying per-shard page counts) the burst completes at the MAX over
        per-shard queue drains (`price_sharded_burst`), exactly like the
        feature plane's merged burst.  Tier reads overlap (GPU threads
        cover all three paths concurrently); the pinned-host pages' own
        service link IS PCIe (zero-copy reads), so they appear only inside
        the combined host+storage PCIe ingress cap — no separate host
        term; every hop pays one kernel launch."""
        n_hbm, n_host, n_sto = report.pages_by_tier
        if report.n_edge_reads <= 0:
            return 0.0
        t_hbm = n_hbm * io_bytes / HBM_BW
        t_sto = 0.0
        if n_sto:
            shard_pages = getattr(report, "shard_pages", ())
            if self.shard_specs and shard_pages:
                burst = price_sharded_burst(self.shard_specs, shard_pages,
                                            shard_pages, io_bytes, io_bytes)
                # topology edge-page reads see brownouts/outages too: the
                # same injector seam as the feature plane's merged burst
                # (an empty schedule returns the burst untouched)
                burst = self._fault_adjust(burst, io_bytes, io_bytes)
                self._note_burst(burst)
                t_sto = burst.elapsed_s
            else:
                t_sto = model_burst(self.spec, n_sto, self.n_ssd).elapsed_s
                if self.injector is not None:
                    # unsharded topology namespace = one storage queue:
                    # wrap the hop's page burst so the schedule prices it
                    burst = self._fault_adjust(
                        ShardedBurstResult((t_sto,), (n_sto,), (n_sto,),
                                           (self.spec.name,),
                                           n_sto * io_bytes),
                        io_bytes, io_bytes)
                    self._note_burst(burst)
                    t_sto = burst.elapsed_s
        t_pcie = (n_host + n_sto) * io_bytes / PCIE_GEN4_BW
        return TOPO_HOP_LAUNCH_S + max(t_hbm, t_sto, t_pcie)

    def price_migration(self, from_shard, to_shard, bytes_per_row: int,
                        n_shards: int | None = None,
                        io_bytes: int = IO_BYTES) -> float:
        """Price a placement migration: what it actually costs to MOVE rows
        between shards (the adaptive plane's rebalancing is never free).

        `from_shard[i]` / `to_shard[i]` are the source and destination shard
        of row i; rows whose shard does not change are ignored.  Every moved
        row is one read on its source queue and one write on its destination
        queue — rows wider than an IO line pay line-granular IOs — so each
        queue drains its reads+writes at its own `SSDSpec` via the Eq. 2-3
        burst model and the migration completes at the MAX over queues,
        exactly like a gather burst.  The moved bytes additionally transit
        host memory twice (source SSD -> host -> destination SSD) under the
        PCIe cap.  The `ShardRebalancer` (core/feedback.py) commits a
        migration only when the modelled imbalance saving over its
        amortization horizon exceeds this cost, then charges the cost back
        into subsequent batches."""
        src = np.asarray(from_shard, np.int64)
        dst = np.asarray(to_shard, np.int64)
        if src.shape != dst.shape:
            raise ValueError(
                f"migration arity mismatch: {src.shape} source vs "
                f"{dst.shape} destination shards")
        moved = src != dst
        src, dst = src[moved], dst[moved]
        if len(src) == 0:
            return 0.0
        if n_shards is None:
            n_shards = len(self.shard_specs) if self.shard_specs \
                else int(max(src.max(), dst.max())) + 1
        specs = self.shard_specs or (self.spec,) * n_shards
        per_queue = np.bincount(src, minlength=n_shards) \
            + np.bincount(dst, minlength=n_shards)
        lines_per_row = max(1, -(-bytes_per_row // io_bytes))
        burst = price_sharded_burst(
            specs, tuple(per_queue), tuple(per_queue * lines_per_row),
            bytes_per_row, io_bytes)
        t_pcie = 2 * len(src) * bytes_per_row / PCIE_GEN4_BW
        t_link = 0.0
        if self.host_specs is not None and len(self.host_specs) == n_shards:
            # host plane: a moved row leaves its source host and enters its
            # destination host over each one's link — per_queue already
            # counts both endpoints, and the slowest link gates the move
            t_link = max(
                (h.link_rtt_s + int(q) * bytes_per_row / h.link_bw
                 for h, q in zip(self.host_specs, per_queue) if q > 0),
                default=0.0)
        return max(burst.elapsed_s, t_pcie, t_link)

    def gids_batch_time(self, n_storage: int, n_host: int, n_hbm: int,
                        feat_bytes: int, outstanding: int) -> float:
        """GIDS: storage requests overlapped (efficiency from the accumulator's
        maintained outstanding count), host/HBM redirections run concurrently
        on their own links; PCIe caps combined host+storage ingress.
        `feat_bytes` is the size of ONE feature row — counts scale it."""
        eff = model_burst(self.spec, max(outstanding, 1), self.n_ssd).efficiency
        ssd_bw = self.spec.peak_bw * self.n_ssd * eff
        t_ssd = n_storage * feat_bytes / ssd_bw if n_storage else 0.0
        if self.injector is not None:
            lines = n_storage * max(1, -(-feat_bytes // IO_BYTES))
            burst = self._fault_adjust(
                ShardedBurstResult((t_ssd,), (n_storage,), (int(lines),),
                                   (self.spec.name,),
                                   int(n_storage * feat_bytes)),
                feat_bytes)
            self._note_burst(burst)
            t_ssd = burst.elapsed_s
        t_host = n_host * feat_bytes / HOST_DRAM_BW if n_host else 0.0
        t_hbm = n_hbm * feat_bytes / HBM_BW if n_hbm else 0.0
        pcie_bytes = (n_storage + n_host) * feat_bytes
        t_pcie = pcie_bytes / PCIE_GEN4_BW
        return max(t_ssd, t_host, t_hbm, t_pcie)

    def gids_batch_time_sharded(self, shard_rows, n_host: int, n_hbm: int,
                                feat_bytes: int, outstanding: int,
                                remote_rows=()) -> float:
        """GIDS batch pricing over a sharded namespace: the accumulator's
        maintained outstanding count splits across shard queues in
        proportion to each shard's share of the batch's storage rows, each
        shard drains at its own spec with the efficiency of ITS queue alone,
        and the storage term is the slowest shard's drain.  Host/HBM links
        and the PCIe ingress cap match `gids_batch_time` exactly, so a
        1-shard plane prices identically to the unsharded one.

        On a host plane (`host_specs` wired) `remote_rows[h]` counts the
        batch rows host h serves to OTHER hosts; they ship line-granular
        over h's link via `price_host_burst`."""
        shard_rows = tuple(int(r) for r in shard_rows)
        total = sum(shard_rows)
        shard_out = tuple(
            max(int(round(outstanding * r / total)), 1) if r else 0
            for r in shard_rows) if total else shard_rows
        specs = self.shard_specs or (self.spec,) * len(shard_rows)
        # per-batch pricing is row-granular (no merged-window coalescing):
        # lines = rows keeps the line cap at exactly the row bytes
        shard_lines = tuple(-(-r * feat_bytes // IO_BYTES)
                            for r in shard_rows)
        if self.host_specs is not None:
            remote_lines = tuple(
                -(-int(r) * feat_bytes // IO_BYTES) for r in remote_rows) \
                if remote_rows else None
            burst = self.price_host_burst(
                shard_rows, shard_lines, feat_bytes,
                shard_outstanding=shard_out, remote_lines=remote_lines)
        else:
            burst = price_sharded_burst(specs, shard_rows, shard_lines,
                                        feat_bytes,
                                        shard_outstanding=shard_out)
            burst = self._fault_adjust(burst, feat_bytes)
        self._note_burst(burst)
        t_host = n_host * feat_bytes / HOST_DRAM_BW if n_host else 0.0
        t_hbm = n_hbm * feat_bytes / HBM_BW if n_hbm else 0.0
        t_pcie = (total + n_host) * feat_bytes / PCIE_GEN4_BW
        return max(burst.elapsed_s, t_host, t_hbm, t_pcie)

    def mmap_batch_time(self, n_storage: int, n_page_cache: int,
                        feat_bytes: int, cpu_threads: int = 16) -> float:
        """mmap baseline: page faults served with limited overlap (readahead
        gives ~cpu_threads-deep concurrency), plus per-fault kernel overhead.
        `feat_bytes` is the size of ONE feature row; rows wider than the 4 KB
        IO line fault once per line (no double-scaling against counts)."""
        lines = max(1, feat_bytes // IO_BYTES)
        faults = n_storage * lines
        t_fault = faults * (MMAP_FAULT_OVERHEAD_S / cpu_threads)
        t_dev = faults * self.spec.latency_s / cpu_threads \
            + faults / (self.spec.peak_iops * self.n_ssd)
        t_hit = n_page_cache * feat_bytes / HOST_DRAM_BW
        return t_fault + t_dev + t_hit
