"""Window-buffered software-defined cache (paper §3.4, Fig. 6).

BaM's application-defined GPU cache uses random eviction; GIDS's window
buffering looks *ahead* at the node IDs already sampled for the next W
mini-batches (sampling runs ahead of training — see accumulator) and pins
cache lines that will be reused:

  1. window buffer holds sampled node IDs of the next W iterations
  2. the incoming batch is compared against the window
  3. per-node future-reuse counts are derived
  4. cache metadata stores the counter; counter > 0 == "USE" (un-evictable)
  5. each reuse decrements; at 0 the line returns to "safe to evict"

This module is the *reference* implementation (numpy, set-associative).  A
jittable JAX twin lives in `cache_jax.py`; property tests assert agreement.

Geometry: `num_sets x ways` direct-indexed by `node_id % num_sets` (node ids
are uniform-hashed upstream by the RMAT generator's id scrambling; a cheap
multiplicative hash decorrelates pathological strides).
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

# 32-bit Fibonacci hash (shared bit-exactly with the JAX twin, which runs
# with x64 disabled)
_HASH_MULT = np.uint32(0x9E3779B9)


def _hash_ids(ids: np.ndarray, num_sets: int) -> np.ndarray:
    with np.errstate(over="ignore"):
        h = (ids.astype(np.uint32) * _HASH_MULT) >> np.uint32(8)
    return (h % np.uint32(num_sets)).astype(np.int64)


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    fills: int = 0
    bypasses: int = 0   # miss with no evictable way (contention)
    evictions: int = 0

    @property
    def accesses(self) -> int:
        return self.hits + self.misses

    @property
    def hit_ratio(self) -> float:
        return self.hits / self.accesses if self.accesses else 0.0


class WindowBufferedCache:
    """Set-associative software cache with future-reuse pinning.

    window_depth = 0 degenerates to the BaM baseline (random eviction,
    no pinning) — exactly the paper's Fig. 11 baseline.
    """

    def __init__(self, num_lines: int, ways: int = 8, window_depth: int = 0,
                 seed: int = 0, evict: str = "random"):
        assert num_lines % ways == 0
        assert evict in ("random", "first")
        self.num_sets = num_lines // ways
        self.ways = ways
        self.window_depth = window_depth
        self.evict = evict
        self._seed = seed
        self.tags = np.full((self.num_sets, ways), -1, dtype=np.int64)
        self.reuse = np.zeros((self.num_sets, ways), dtype=np.int64)
        self.window: deque[np.ndarray] = deque()
        self.stats = CacheStats()
        self._rng = np.random.default_rng(seed)

    def reset(self) -> None:
        """Return to the exact post-construction state (metadata, stats,
        window, AND eviction rng) — checkpoint-resume must be
        indistinguishable from a freshly-built cache."""
        self.tags.fill(-1)
        self.reuse.fill(0)
        self.window.clear()
        self.stats = CacheStats()
        self._rng = np.random.default_rng(self._seed)

    # -- window management ---------------------------------------------------
    def push_window(self, future_nodes: np.ndarray) -> None:
        """Insert the (deduplicated) sampled node list of a *future*
        iteration (Fig. 6 step 1).  Reuse counters of already-cached lines
        are incremented (steps 2-5): counter > 0 == "USE" state."""
        if self.window_depth == 0:
            return
        self.window.append(future_nodes)
        assert len(self.window) <= self.window_depth, "window overfull"
        self._bump_counters(future_nodes, +1)

    def _bump_counters(self, nodes: np.ndarray, delta: int) -> None:
        sets = _hash_ids(nodes, self.num_sets)
        for s, n in zip(sets, nodes):
            w = np.nonzero(self.tags[s] == n)[0]
            if len(w):
                self.reuse[s, w[0]] = max(0, self.reuse[s, w[0]] + delta)

    def _future_count(self, node: int) -> int:
        return sum(int((w == node).sum()) for w in self.window)

    # -- access path -----------------------------------------------------------
    def access(self, nodes: np.ndarray,
               multiplicity: np.ndarray | None = None) -> np.ndarray:
        """Process one mini-batch's (deduplicated) feature requests.

        Invariant: on entry the window's front is this very batch (it was
        pushed while still in the future).  It leaves the window now; its
        counter contributions are consumed by the per-node decrements below
        ("the counter value is decreased each time the node is reused during
        the feature aggregation stage"), so the pop does not bulk-decrement.
        Returns the hit mask.

        `multiplicity` switches to merged-window semantics (see
        `access_merged`): no window pop here — the caller already retired
        the consumed entries — and each resident node's counter consumes
        its full multiplicity instead of one reuse."""
        if multiplicity is None and self.window_depth > 0 and self.window:
            self.window.popleft()
        sets = _hash_ids(nodes, self.num_sets)
        hits = np.zeros(len(nodes), dtype=bool)
        for i, (s, n) in enumerate(zip(sets, nodes)):
            ways = self.tags[s]
            w = np.nonzero(ways == n)[0]
            if len(w):
                hits[i] = True
                self.stats.hits += 1
                j = int(w[0])
                dec = 1 if multiplicity is None else int(multiplicity[i])
                self.reuse[s, j] = max(0, int(self.reuse[s, j]) - dec)
                continue
            self.stats.misses += 1
            self._fill(s, int(n))
        return hits

    def access_merged(self, nodes: np.ndarray,
                      multiplicity: np.ndarray) -> np.ndarray:
        """Merged-window access: ONE deduplicated probe standing in for a
        whole window of consecutive batches' accesses (the merged-window
        executor gathers the window in one aggregation pass).

        Each resident node's counter consumes its full window
        `multiplicity` (the number of merged batches requesting it) at once
        — every reuse the pushes reserved happens inside this single pass,
        so deferring the decrements would leave lines pinned forever and
        silently shrink capacity.  The caller retires the consumed window
        entries and pushes the NEXT window's BEFORE this access
        (`TieredFeatureStore.retire_window` + the loader's window sync), so
        fills pin lines by the upcoming window's reuse, exactly like the
        per-batch path's look-ahead.  Returns the hit mask over `nodes`."""
        return self.access(nodes, multiplicity=multiplicity)

    def _fill(self, s: int, node: int) -> None:
        ways = self.tags[s]
        empty = np.nonzero(ways == -1)[0]
        if len(empty):
            w = int(empty[0])
        else:
            safe = np.nonzero(self.reuse[s] == 0)[0]
            if len(safe) == 0:
                self.stats.bypasses += 1   # all ways pinned: serve uncached
                return
            # random among safe ways (paper: BaM random eviction within the
            # safe-to-evict set); "first" is the deterministic twin used to
            # cross-validate against the jittable JAX implementation.
            w = int(self._rng.choice(safe)) if self.evict == "random" \
                else int(safe[0])
            self.stats.evictions += 1
        self.tags[s, w] = node
        self.stats.fills += 1
        if self.window_depth > 0:
            self.reuse[s, w] = self._future_count(node)
        else:
            self.reuse[s, w] = 0

    # -- introspection ---------------------------------------------------------
    def lookup(self, nodes: np.ndarray) -> np.ndarray:
        """Resident cache line index (set*ways+way) per node, -1 if absent.
        Read-only — no stats, no fills; used to render a GatherPlan as the
        slot array for the `tiered_gather` kernel."""
        sets = _hash_ids(np.asarray(nodes), self.num_sets)
        out = np.full(len(nodes), -1, dtype=np.int64)
        for i, (s, n) in enumerate(zip(sets, nodes)):
            w = np.nonzero(self.tags[s] == n)[0]
            if len(w):
                out[i] = s * self.ways + w[0]
        return out

    def pinned_lines(self) -> int:
        return int((self.reuse > 0).sum())

    def occupancy(self) -> float:
        return float((self.tags >= 0).mean())


def run_trace(cache: WindowBufferedCache, batches: list[np.ndarray]
              ) -> CacheStats:
    """Feed a trace of per-iteration (deduplicated) node lists through the
    cache with look-ahead: prime the window with the first W batches (the
    sampler runs W iterations ahead — accumulator §3.2 makes this free),
    then each access pops itself off the front and pushes batch i+W."""
    W = cache.window_depth
    for b in batches[:W]:
        cache.push_window(b)
    for i, b in enumerate(batches):
        cache.access(b)
        if W > 0 and i + W < len(batches):
            cache.push_window(batches[i + W])
    return cache.stats
