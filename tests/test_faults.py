"""Fault-tolerant storage data plane: schedule/policy validation, burst
re-pricing math (brownout, outage failover, retry ladder, hedged reads),
replicated placement, plan-time failover routing, shard health monitoring,
drain-driven rebalancing, the data-bit-identity invariant under arbitrary
fault schedules, checkpoint/resume replay of recovery decisions, and the
serve-plane brownout ladder with its shed/degrade accounting."""
import numpy as np
import pytest

from repro.core import (BrownoutEvent, FailoverRouter, FaultInjector,
                        FaultSchedule, FaultedBurstResult, FlakyReadsEvent,
                        GIDSDataLoader, HedgePolicy, LoaderConfig,
                        OutageEvent, ReplicatedPlacement, RetryPolicy,
                        SAMSUNG_980PRO, ShardHealthMonitor,
                        ShardedBurstResult, make_placement)
from repro.core.sharding import AdaptivePlacement
from repro.graph.synthetic import rmat_graph


@pytest.fixture(scope="module")
def graph_and_feats():
    g = rmat_graph(10_000, 12, 16, seed=1)
    feats = np.random.default_rng(0).standard_normal(
        (g.num_nodes, 16)).astype(np.float32)
    return g, feats


def _mk(g, feats, seed=7, **kw):
    cfg = dict(batch_size=256, fanouts=(2,), data_plane="gids-merged-sharded",
               cache_lines=512, window_depth=4, n_shards=4,
               placement="degree", seed=seed)
    cfg.update(kw)
    return GIDSDataLoader(g, feats, LoaderConfig(**cfg))


def _clean_burst(per_shard_s, rows, lines, bytes_per_row=64):
    return ShardedBurstResult(
        per_shard_s=tuple(per_shard_s), per_shard_rows=tuple(rows),
        per_shard_lines=tuple(lines),
        spec_names=(SAMSUNG_980PRO.name,) * len(rows),
        ssd_bytes=int(sum(r * bytes_per_row for r in rows)))


# -- schedule / policy validation ----------------------------------------------

def test_event_validation():
    with pytest.raises(ValueError, match="interval"):
        BrownoutEvent(shard=0, start=5, end=5, multiplier=2.0)
    with pytest.raises(ValueError, match="interval"):
        OutageEvent(shard=0, start=-1, end=3)
    with pytest.raises(ValueError, match="shard must be >= 0"):
        OutageEvent(shard=-1, start=0, end=3)
    with pytest.raises(ValueError, match="never speeds a queue up"):
        BrownoutEvent(shard=0, start=0, end=4, multiplier=0.5)
    with pytest.raises(ValueError, match="use OutageEvent"):
        FlakyReadsEvent(shard=0, start=0, end=4, fail_prob=1.0)
    with pytest.raises(TypeError, match="unknown fault event"):
        FaultSchedule(events=("not-an-event",))
    with pytest.raises(ValueError, match="max_retries"):
        RetryPolicy(max_retries=-1)
    with pytest.raises(ValueError, match="backoff cap"):
        RetryPolicy(backoff_base_s=1e-3, backoff_cap_s=1e-4)
    with pytest.raises(ValueError, match="quantile"):
        HedgePolicy(quantile=1.5)
    with pytest.raises(ValueError, match="factor"):
        HedgePolicy(factor=0.5)


def test_injector_validation():
    sched = FaultSchedule(events=(OutageEvent(shard=5, start=0, end=2),))
    with pytest.raises(ValueError, match="targets shard 5"):
        FaultInjector(sched, n_shards=4)
    with pytest.raises(ValueError, match="replication 8 exceeds"):
        FaultInjector(FaultSchedule(), n_shards=4, replication=8)


# -- burst re-pricing math -----------------------------------------------------

def test_quiet_burst_returns_clean_object():
    """No active event -> the SAME clean result object (bit-identity)."""
    inj = FaultInjector(FaultSchedule(
        events=(BrownoutEvent(shard=0, start=10, end=20, multiplier=4.0),)),
        n_shards=2)
    clean = _clean_burst([1e-3, 2e-3], [100, 200], [50, 100])
    out = inj.price_burst((SAMSUNG_980PRO,) * 2, clean, bytes_per_row=64)
    assert out is clean
    assert inj.burst == 1 and inj.n_faulted_bursts == 0


def test_brownout_multiplies_shard_drain():
    inj = FaultInjector(FaultSchedule(
        events=(BrownoutEvent(shard=1, start=0, end=4, multiplier=10.0),),
        hedge=None), n_shards=2)
    clean = _clean_burst([1e-3, 2e-3], [100, 200], [50, 100])
    out = inj.price_burst((SAMSUNG_980PRO,) * 2, clean, bytes_per_row=64)
    assert isinstance(out, FaultedBurstResult)
    assert out.per_shard_s[0] == clean.per_shard_s[0]
    assert out.per_shard_s[1] == pytest.approx(10.0 * clean.per_shard_s[1])
    # rows/lines — the data — are the clean burst's, untouched
    assert out.per_shard_rows == clean.per_shard_rows
    assert out.per_shard_lines == clean.per_shard_lines
    assert out.clean_per_shard_s == clean.per_shard_s


def test_outage_fails_over_to_replica():
    inj = FaultInjector(FaultSchedule(
        events=(OutageEvent(shard=0, start=0, end=2),), hedge=None),
        n_shards=3, replication=2)
    clean = _clean_burst([1e-3, 1e-3, 1e-3], [100, 100, 100], [50, 50, 50])
    out = inj.price_burst((SAMSUNG_980PRO,) * 3, clean, bytes_per_row=64)
    assert out.per_shard_s[0] == 0.0            # dead shard serves nothing
    assert out.per_shard_s[1] > clean.per_shard_s[1]   # replica absorbed it
    assert out.failed_over_lines[0] == 50
    assert out.ssd_bytes > clean.ssd_bytes      # duplicate IOs are priced
    assert inj.first_failover_burst == 0


def test_outage_without_replica_ladders_to_deadline():
    retry = RetryPolicy(max_retries=2, read_deadline_s=1e-3)
    inj = FaultInjector(FaultSchedule(
        events=(OutageEvent(shard=0, start=0, end=2),), retry=retry,
        hedge=None), n_shards=2)
    clean = _clean_burst([1e-3, 1e-3], [100, 100], [50, 50])
    out = inj.price_burst((SAMSUNG_980PRO,) * 2, clean, bytes_per_row=64)
    assert out.per_shard_s[0] == pytest.approx(
        clean.per_shard_s[0] + retry.read_deadline_s * 3)


def test_flaky_reads_price_retry_ladder_deterministically():
    sched = FaultSchedule(
        events=(FlakyReadsEvent(shard=0, start=0, end=8, fail_prob=0.3),),
        hedge=None, seed=11)
    clean = _clean_burst([1e-3, 1e-3], [400, 400], [200, 200])
    inj = FaultInjector(sched, n_shards=2)
    out1 = inj.price_burst((SAMSUNG_980PRO,) * 2, clean, bytes_per_row=64)
    assert out1.retried_lines[0] > 0
    assert out1.per_shard_s[0] > clean.per_shard_s[0]
    # the draw is a pure function of (seed, burst, shard): replay matches
    inj2 = FaultInjector(sched, n_shards=2)
    out2 = inj2.price_burst((SAMSUNG_980PRO,) * 2, clean, bytes_per_row=64)
    assert out1.per_shard_s == out2.per_shard_s
    assert out1.retried_lines == out2.retried_lines


def test_hedge_cuts_the_straggler():
    inj = FaultInjector(FaultSchedule(
        events=(BrownoutEvent(shard=2, start=0, end=4, multiplier=10.0),),
        hedge=HedgePolicy(quantile=0.5, factor=2.0),
        retry=RetryPolicy(read_deadline_s=1.0)), n_shards=4, replication=2)
    clean = _clean_burst([1e-3] * 4, [100] * 4, [50] * 4)
    out = inj.price_burst((SAMSUNG_980PRO,) * 4, clean, bytes_per_row=64)
    assert out.hedged_shard == 2
    assert out.hedge_replica == 3               # (2 + 1) % 4
    assert out.hedged_lines > 0
    assert out.hedge_saving_s > 0
    assert out.per_shard_s[2] < 10.0 * clean.per_shard_s[2]
    assert inj.n_hedged_bursts == 1 and inj.first_hedge_burst == 0


def test_hedge_needs_replicas():
    inj = FaultInjector(FaultSchedule(
        events=(BrownoutEvent(shard=2, start=0, end=4, multiplier=10.0),)),
        n_shards=4, replication=1)
    clean = _clean_burst([1e-3] * 4, [100] * 4, [50] * 4)
    out = inj.price_burst((SAMSUNG_980PRO,) * 4, clean, bytes_per_row=64)
    assert out.hedged_shard == -1
    assert out.per_shard_s[2] == pytest.approx(10.0 * clean.per_shard_s[2])


def test_injector_state_roundtrip_and_mismatch():
    sched = FaultSchedule(
        events=(BrownoutEvent(shard=0, start=0, end=9, multiplier=3.0),),
        seed=5)
    inj = FaultInjector(sched, n_shards=2, replication=2)
    clean = _clean_burst([1e-3, 1e-3], [100, 100], [50, 50])
    for _ in range(3):
        inj.price_burst((SAMSUNG_980PRO,) * 2, clean, bytes_per_row=64)
    state = inj.state_dict()
    fresh = FaultInjector(sched, n_shards=2, replication=2)
    fresh.load_state_dict(state)
    assert fresh.burst == 3
    assert fresh.n_faulted_bursts == inj.n_faulted_bursts
    other = FaultInjector(sched, n_shards=2)
    with pytest.raises(ValueError, match="would diverge"):
        other.load_state_dict(state)


# -- replicated placement ------------------------------------------------------

def test_replicated_placement_validation():
    base = make_placement("hash", 4, num_nodes=100)
    with pytest.raises(ValueError, match="hash placement"):
        ReplicatedPlacement(base, replication_factor=1)
    with pytest.raises(ValueError, match="distinct shards"):
        ReplicatedPlacement(base, replication_factor=8)
    single = make_placement("hash", 1, num_nodes=100)
    with pytest.raises(ValueError, match="one shard"):
        ReplicatedPlacement(single, replication_factor=2)


def test_replicated_placement_replicas_distinct():
    base = make_placement("degree", 4,
                          degrees=np.random.default_rng(0)
                          .integers(0, 50, 200))
    pol = ReplicatedPlacement(base, replication_factor=3)
    assert pol.name == "replicated(degree)x3"
    ids = np.arange(200)
    reps = pol.replicas_of(ids)
    assert reps.shape == (200, 3)
    np.testing.assert_array_equal(reps[:, 0], base.shard_of(ids))
    np.testing.assert_array_equal(pol.shard_of(ids), base.shard_of(ids))
    for j in range(3):      # chained declustering: distinct per node
        for k in range(j + 1, 3):
            assert (reps[:, j] != reps[:, k]).all()


def test_replicated_placement_state_roundtrip_and_mismatch():
    base = make_placement("hash", 4, num_nodes=100)
    pol = ReplicatedPlacement(base, replication_factor=2)
    state = pol.state_dict()
    pol.load_state_dict(state)          # round-trips
    other = ReplicatedPlacement(make_placement("hash", 4, num_nodes=100),
                                replication_factor=3)
    with pytest.raises(ValueError, match="never held the replica"):
        other.load_state_dict(state)


def test_replicated_placement_delegates_adaptive_seam():
    base = AdaptivePlacement(4, np.random.default_rng(0).integers(0, 50, 80))
    pol = ReplicatedPlacement(base, replication_factor=2)
    # the adaptive attributes reach through the wrapper
    assert pol.table is base.table
    pol.touches.observe(np.arange(80))
    pol.touches.fold()
    new, moved = pol.plan_drain(0)
    assert len(moved) > 0 and (new[moved] != 0).all()


# -- failover router -----------------------------------------------------------

def test_failover_router_requires_replicas():
    base = make_placement("hash", 4, num_nodes=100)
    with pytest.raises(ValueError, match="ReplicatedPlacement"):
        FailoverRouter(base)


def test_failover_router_routes_outage_reads_to_replica():
    base = make_placement("hash", 4, num_nodes=400)
    pol = ReplicatedPlacement(base, replication_factor=2)
    inj = FaultInjector(FaultSchedule(
        events=(OutageEvent(shard=1, start=0, end=10),)),
        n_shards=4, replication=2)
    router = FailoverRouter(pol, injector=inj)
    ids = np.arange(400)
    primary = pol.shard_of(ids)
    routed = router.route(ids, primary)
    assert not (routed == 1).any()              # nothing reads a dead shard
    moved = routed != primary
    assert moved.any() and (primary[moved] == 1).all()
    np.testing.assert_array_equal(routed[moved], (primary[moved] + 1) % 4)
    assert router.n_rerouted == int(moved.sum())


def test_failover_router_healthy_plane_is_identity():
    pol = ReplicatedPlacement(make_placement("hash", 4, num_nodes=100), 2)
    router = FailoverRouter(pol)
    primary = pol.shard_of(np.arange(100))
    assert router.route(np.arange(100), primary) is primary


# -- shard health monitor ------------------------------------------------------

def test_health_monitor_flags_browning_shard():
    mon = ShardHealthMonitor(4, alpha=0.5, degraded_factor=2.0, min_bursts=3)
    slow = _clean_burst([1e-3, 1e-3, 1e-3, 8e-3], [100] * 4, [50] * 4)
    for _ in range(4):
        mon.observe(slow)
    assert list(mon.degraded()) == [3]
    assert mon.worst() == 3
    assert mon.healthiest([2, 3]) == 2
    assert mon.first_flag_burst == 3
    state = mon.state_dict()
    fresh = ShardHealthMonitor(4, alpha=0.5, degraded_factor=2.0,
                               min_bursts=3)
    fresh.load_state_dict(state)
    assert list(fresh.degraded()) == [3]
    with pytest.raises(ValueError):
        ShardHealthMonitor(2).load_state_dict(state)


def test_health_monitor_normalizes_by_rows():
    """A shard that is slow only because it holds more rows is healthy."""
    mon = ShardHealthMonitor(2, min_bursts=2, degraded_factor=2.5)
    skew = _clean_burst([1e-3, 8e-3], [100, 800], [50, 400])
    for _ in range(4):
        mon.observe(skew)
    assert len(mon.degraded()) == 0


# -- loader integration: identity, recovery, checkpoint ------------------------

SCHED_BROWNOUT = FaultSchedule(
    events=(BrownoutEvent(shard=2, start=1, end=9, multiplier=10.0),))
SCHED_CHAOS = FaultSchedule(
    events=(BrownoutEvent(shard=2, start=1, end=9, multiplier=10.0),
            OutageEvent(shard=0, start=4, end=7),
            FlakyReadsEvent(shard=1, start=2, end=12, fail_prob=0.2)),
    seed=3)


def test_loader_fault_free_schedule_bit_identical(graph_and_feats):
    """An EMPTY schedule prices (and gathers) bit-identically to no
    schedule at all — the fault plane is invisible until a fault fires."""
    g, feats = graph_and_feats
    a = _mk(g, feats)
    b = _mk(g, feats, fault_schedule=FaultSchedule())
    for _ in range(8):
        ba, bb = a.next_batch(), b.next_batch()
        assert ba.prep_time_s == bb.prep_time_s
        assert ba.exposed_prep_s == bb.exposed_prep_s
        np.testing.assert_array_equal(ba.features, bb.features)


def test_loader_faults_never_touch_data(graph_and_feats):
    """Any schedule perturbs timing only: features and sampled blocks are
    bit-identical to the fault-free loader, prep time is never cheaper."""
    g, feats = graph_and_feats
    clean = _mk(g, feats)
    chaos = _mk(g, feats, fault_schedule=SCHED_CHAOS, replication_factor=2)
    slower = 0
    for _ in range(12):
        bc, bf = clean.next_batch(), chaos.next_batch()
        np.testing.assert_array_equal(bc.blocks.all_nodes,
                                      bf.blocks.all_nodes)
        np.testing.assert_array_equal(bc.features, bf.features)
        slower += bf.prep_time_s > bc.prep_time_s
    assert slower > 0                           # the chaos was priced
    assert chaos.fault_injector.n_faulted_bursts > 0


def test_loader_replication_requires_sharded_plane(graph_and_feats):
    g, feats = graph_and_feats
    with pytest.raises(ValueError, match="no replica queues"):
        GIDSDataLoader(g, feats, LoaderConfig(
            batch_size=128, fanouts=(2,), data_plane="gids-merged",
            cache_lines=512, replication_factor=2))


def test_loader_hedging_beats_naive_brownout(graph_and_feats):
    """Hedged reads + plan-time failover recover a large share of what a
    single-shard brownout costs an unreplicated plane."""
    g, feats = graph_and_feats
    naive = _mk(g, feats, fault_schedule=SCHED_BROWNOUT)
    hedged = _mk(g, feats, fault_schedule=SCHED_BROWNOUT,
                 replication_factor=2)
    t_naive = sum(naive.next_batch().exposed_prep_s for _ in range(12))
    t_hedged = sum(hedged.next_batch().exposed_prep_s for _ in range(12))
    assert hedged.fault_injector.n_hedged_bursts \
        + hedged.store.tiers[-1].router.n_rerouted > 0
    assert t_naive > 1.3 * t_hedged


def test_checkpoint_mid_brownout_replays_recovery(graph_and_feats):
    """Resume from a checkpoint taken mid-schedule: the injector's burst
    counter (the only state recovery decisions depend on) rides the
    checkpoint, so two resumed loaders replay the SAME retry/hedge
    decisions and prices, the schedule does not restart from burst 0, and
    the data stream still matches the uninterrupted run bit-for-bit."""
    g, feats = graph_and_feats
    kw = dict(fault_schedule=SCHED_CHAOS, replication_factor=2)
    full = _mk(g, feats, **kw)
    ref = [full.next_batch() for _ in range(12)]

    part = _mk(g, feats, **kw)
    for _ in range(5):
        part.next_batch()
    state = part.state_dict()
    r1, r2 = _mk(g, feats, **kw), _mk(g, feats, **kw)
    r1.load_state_dict(state)
    r2.load_state_dict(state)
    # the schedule position survives the checkpoint — no restart to 0
    assert r1.fault_injector.burst == part.fault_injector.burst
    assert r1.health.state_dict()["bursts"] \
        == part.health.state_dict()["bursts"]
    for i in range(5, 12):
        b1, b2 = r1.next_batch(), r2.next_batch()
        # resumed loaders agree bit-for-bit: same prices, same recovery
        assert b1.prep_time_s == b2.prep_time_s
        np.testing.assert_array_equal(b1.features, b2.features)
        # and the DATA matches the uninterrupted stream (identity holds
        # across the checkpoint seam, whatever the fault timing)
        np.testing.assert_array_equal(b1.blocks.all_nodes,
                                      ref[i].blocks.all_nodes)
        np.testing.assert_array_equal(b1.features, ref[i].features)
    assert r1.fault_injector.state_dict() == r2.fault_injector.state_dict()


def test_checkpoint_fault_state_requires_fault_plane(graph_and_feats):
    g, feats = graph_and_feats
    faulted = _mk(g, feats, fault_schedule=SCHED_CHAOS)
    faulted.next_batch()
    state = faulted.state_dict()
    plain = _mk(g, feats)
    with pytest.raises(ValueError, match="fault"):
        plain.load_state_dict(state)


# -- drain-driven rebalancing --------------------------------------------------

def test_plan_drain_empties_the_hot_set():
    pol = AdaptivePlacement(4, np.random.default_rng(0).integers(1, 50, 100))
    pol.touches.observe(np.arange(100))     # everything equally hot
    pol.touches.fold()
    new, moved = pol.plan_drain(2)
    assert (new != 2).all()                 # every hot on-2 row evacuated
    assert len(moved) == int((pol.table == 2).sum())
    with pytest.raises(ValueError, match="adaptive"):
        pol.plan_drain(7)
    with pytest.raises(ValueError, match="adaptive"):
        AdaptivePlacement(1, np.arange(10)).plan_drain(0)


def test_rebalancer_drains_degraded_shard(graph_and_feats):
    """Sustained brownout -> monitor flags the shard -> the rebalancer's
    next window emits a 'drain' migration off the sick queue."""
    g, feats = graph_and_feats
    dl = _mk(g, feats, placement="adaptive", rebalance_interval=4,
             migration_horizon=64,
             fault_schedule=FaultSchedule(events=(
                 BrownoutEvent(shard=2, start=0, end=40, multiplier=25.0),)))
    for _ in range(48):     # ~12 priced bursts: enough for the monitor's
        dl.next_batch()     # min_bursts warmup AND a rebalance interval
    reasons = {ev.reason for ev in dl.rebalancer.events}
    assert "drain" in reasons
    drain = next(ev for ev in dl.rebalancer.events if ev.reason == "drain")
    assert drain.n_moved > 0


# -- property: data identity under ANY schedule --------------------------------

def test_features_identical_under_any_fault_schedule_property(
        graph_and_feats):
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    g, feats = graph_and_feats

    def interval(max_burst=14):
        return st.tuples(st.integers(0, max_burst - 1),
                         st.integers(1, max_burst)).map(
            lambda se: (min(se), max(min(se) + 1, max(se))))

    events = st.lists(st.one_of(
        st.builds(lambda s, iv, m: BrownoutEvent(s, iv[0], iv[1], m),
                  st.integers(0, 3), interval(), st.floats(1.0, 30.0)),
        st.builds(lambda s, iv: OutageEvent(s, iv[0], iv[1]),
                  st.integers(0, 3), interval()),
        st.builds(lambda s, iv, p: FlakyReadsEvent(s, iv[0], iv[1], p),
                  st.integers(0, 3), interval(),
                  st.floats(0.0, 0.6))), min_size=0, max_size=4)

    @settings(max_examples=8, deadline=None)
    @given(events=events, seed=st.integers(0, 6),
           placement=st.sampled_from(["hash", "degree"]),
           replication=st.sampled_from([1, 2, 3]),
           hedged=st.booleans())
    def check(events, seed, placement, replication, hedged):
        sched = FaultSchedule(
            events=tuple(events), seed=seed,
            hedge=HedgePolicy() if hedged else None)
        clean = _mk(g, feats, placement=placement, seed=seed)
        chaos = _mk(g, feats, placement=placement, seed=seed,
                    fault_schedule=sched, replication_factor=replication)
        for _ in range(6):
            bc, bf = clean.next_batch(), chaos.next_batch()
            np.testing.assert_array_equal(bc.blocks.all_nodes,
                                          bf.blocks.all_nodes)
            np.testing.assert_array_equal(bc.features, bf.features)
            assert bf.prep_time_s >= bc.prep_time_s or not events

    check()


# -- serve plane: brownout ladder + shed/degrade accounting --------------------

@pytest.fixture(scope="module")
def serve_setup(graph_and_feats):
    from repro.serve import TenantSpec, generate_stream
    g, _ = graph_and_feats
    feats = np.random.default_rng(0).standard_normal(
        (g.num_nodes, 512)).astype(np.float32)
    reqs = generate_stream(
        g.num_nodes, [TenantSpec(name="t0", deadline_s=3e-3, mean_seeds=8)],
        offered_qps=500, n_requests=150, seed=3)
    return g, feats, reqs


def _serve(g, feats, reqs, **over):
    from repro.serve import GNNServeConfig, GNNServeEngine
    cfg = dict(seed=5, cache_lines=256)
    cfg.update(over)
    eng = GNNServeEngine(g, feats, GNNServeConfig(**cfg))
    return eng.run(reqs), eng


def test_brownout_controller_ladder():
    from repro.serve import BrownoutController, GNNServeConfig
    ctl = BrownoutController(GNNServeConfig(
        brownout=True, brownout_degrade_at=2.0, brownout_stale_at=4.0,
        brownout_shed_at=8.0, brownout_recover=0.7, brownout_alpha=1.0))
    for _ in range(3):                          # establish the baseline
        assert ctl.observe(1e-3, 1000) == 0
    assert ctl.pressure == pytest.approx(1.0)
    # 10x per-row pressure climbs ONE level per window, not all at once
    assert ctl.observe(1e-2, 1000) == 1
    assert ctl.observe(1e-2, 1000) == 2
    assert ctl.observe(1e-2, 1000) == 3
    assert ctl.observe(1e-2, 1000) == 3         # ladder saturates
    # a stale-only window (nothing gathered) carries no signal
    assert ctl.observe(0.0, 0) == 3
    # recovery needs pressure BELOW recover * the threshold it climbed past
    for _ in range(8):
        ctl.observe(1e-3, 1000)
    assert ctl.level == 0
    assert ctl.level_trace[0] == (4, 1)


def test_serve_fault_free_plane_is_bit_identical(serve_setup):
    """A serve engine with the fault knobs at their defaults is the PR 7
    engine: same records, same floats."""
    g, feats, reqs = serve_setup
    r0, _ = _serve(g, feats, reqs)
    r1, _ = _serve(g, feats, reqs, fault_schedule=None, brownout=False)
    assert len(r0.records) == len(r1.records)
    for a, b in zip(r0.records, r1.records):
        assert a.completion_s == b.completion_s
        assert a.gather_s == b.gather_s
        assert not a.stale and a.degraded_level == 0


def test_serve_faults_never_touch_row_bytes(serve_setup):
    """Brownout + controller change WHO is served and WHEN — never the
    bytes of any served row (stale rows come from the same feature
    matrix)."""
    from repro.core import BrownoutEvent, FaultSchedule
    g, feats, reqs = serve_setup
    sched = FaultSchedule(events=(
        BrownoutEvent(shard=0, start=3, end=10_000, multiplier=10.0),))
    r, _ = _serve(g, feats, reqs, fault_schedule=sched, brownout=True,
                  keep_features=True)
    for rec in r.served:
        np.testing.assert_array_equal(rec.features,
                                      feats[rec.all_nodes])
        if rec.stale:
            assert rec.staleness_s > 0
    assert r.n_stale_served > 0                 # the ladder reached level 2


def test_serve_brownout_degrades_instead_of_missing(serve_setup):
    from repro.core import BrownoutEvent, FaultSchedule
    g, feats, reqs = serve_setup
    sched = FaultSchedule(events=(
        BrownoutEvent(shard=0, start=3, end=10_000, multiplier=10.0),))
    r0, _ = _serve(g, feats, reqs)
    rn, _ = _serve(g, feats, reqs, fault_schedule=sched)
    rc, eng = _serve(g, feats, reqs, fault_schedule=sched, brownout=True)
    assert eng.brownout.level_trace                 # the ladder moved
    assert rc.n_degraded > 0
    # the controller holds the survivor p99 under the un-mitigated one
    assert rc.p99_s() < rn.p99_s()
    assert rc.attainment() > rn.attainment()
    assert rc.shed_fraction < 0.2


def test_serve_result_shed_accounting(serve_setup):
    """Satellite: shed / degraded / deadline-missed are DISTINCT buckets —
    n_rejected splits by reason, served-but-late is never counted as
    shed, and attainment covers offered load while goodput covers time."""
    from repro.core import BrownoutEvent, FaultSchedule
    g, feats, reqs = serve_setup
    sched = FaultSchedule(events=(
        BrownoutEvent(shard=0, start=3, end=10_000, multiplier=10.0),))
    r, _ = _serve(g, feats, reqs, fault_schedule=sched, brownout=True)
    assert r.n_rejected == r.n_shed_expired + r.n_shed_brownout
    for rec in r.records:
        if rec.rejected:
            assert rec.shed_reason in ("expired", "brownout")
            assert not rec.deadline_met         # shed produces no goodput
        else:
            assert rec.shed_reason is None
    # served-but-late is its own bucket, disjoint from shed
    assert r.n_deadline_missed == sum(
        not rec.deadline_met for rec in r.served)
    met = sum(rec.deadline_met for rec in r.records)
    assert r.attainment() == pytest.approx(met / len(r.records))
    assert r.goodput_qps() == pytest.approx(met / r.makespan_s)
    assert r.n_stale_served <= r.n_degraded
