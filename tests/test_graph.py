"""Graph substrate: CSR invariants, reverse, PageRank, constant buffer."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.constant_buffer import ConstantBuffer
from repro.graph.csr import from_edge_list
from repro.graph.pagerank import hot_nodes, reverse_pagerank
from repro.graph.synthetic import rmat_graph, uniform_graph


@given(seed=st.integers(0, 100), n=st.integers(10, 200))
@settings(max_examples=20, deadline=None)
def test_csr_reverse_is_involution(seed, n):
    g = uniform_graph(n, 4, 0, seed=seed)
    rr = g.reverse().reverse()
    np.testing.assert_array_equal(g.indptr, rr.indptr)
    # within each row, neighbor multisets must match
    for v in range(n):
        np.testing.assert_array_equal(np.sort(g.neighbors(v)),
                                      np.sort(rr.neighbors(v)))


def test_reverse_edge_count_preserved():
    g = rmat_graph(500, 8, 0, seed=3)
    assert g.reverse().num_edges == g.num_edges


def test_pagerank_is_distribution_and_favors_indegree():
    g = rmat_graph(2000, 10, 0, seed=1)
    pr = reverse_pagerank(g, iters=30)
    assert pr.shape == (2000,)
    assert abs(pr.sum() - 1.0) < 1e-6
    assert (pr >= 0).all()
    indeg = np.bincount(g.indices, minlength=g.num_nodes)
    top = np.argsort(-pr)[:50]
    assert indeg[top].mean() > indeg.mean() * 2


def test_constant_buffer_membership():
    g = rmat_graph(1000, 8, 4, seed=0)
    feats = np.random.default_rng(0).standard_normal((1000, 4)
                                                     ).astype(np.float32)
    cb = ConstantBuffer.from_graph(g, 0.1, features=feats)
    assert cb.size == 100
    ids = np.arange(1000)
    mask = cb.redirect_mask(ids)
    assert mask.sum() == 100
    got = cb.gather(cb.pinned_ids)
    np.testing.assert_array_equal(got, feats[cb.pinned_ids])


def test_constant_buffer_pagerank_beats_random_on_skewed_traffic():
    """Fig. 10's reason to exist: pagerank pinning redirects more sampled
    traffic than random pinning on a power-law graph."""
    from repro.sampling.neighbor import host_sample_blocks
    g = rmat_graph(5000, 10, 4, seed=2)
    rng = np.random.default_rng(0)
    pr_buf = ConstantBuffer.from_graph(g, 0.05, selection="pagerank")
    rnd_buf = ConstantBuffer.from_graph(g, 0.05, selection="random", seed=1)
    hits_pr = hits_rnd = total = 0
    for _ in range(10):
        blocks = host_sample_blocks(g, rng.integers(0, 5000, 128),
                                    (5, 5), rng)
        hits_pr += pr_buf.redirect_mask(blocks.all_nodes).sum()
        hits_rnd += rnd_buf.redirect_mask(blocks.all_nodes).sum()
        total += len(blocks.all_nodes)
    assert hits_pr > 1.5 * hits_rnd, (hits_pr, hits_rnd, total)


def test_dataset_registry_scales():
    from repro.graph.datasets import REGISTRY
    igb = REGISTRY["IGB-Full"]
    assert igb.feature_bytes > 1_000_000_000_000      # ~1.1 TB (Table 4)
    assert REGISTRY["IGBH-Full"].heterogeneous
    g = REGISTRY["IGB-tiny"].materialize()
    assert g.num_nodes == 100_000
