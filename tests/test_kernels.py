"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _arr(shape, dtype):
    x = RNG.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("B,L,D", [(8, 32, 128), (64, 256, 512),
                                   (16, 8, 1024), (128, 1024, 256)])
def test_tiered_gather_sweep(B, L, D, dtype):
    slots = jnp.asarray(RNG.integers(-1, L, B), jnp.int32)
    cache = _arr((L, D), dtype)
    staged = _arr((B, D), dtype)
    out = ops.tiered_gather(slots, cache, staged)
    exp = ref.tiered_gather_ref(slots, cache, staged)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32))


@pytest.mark.parametrize("block_b", [1, 2, 8, 64])
def test_tiered_gather_row_blocked_bit_identical(block_b):
    """Row blocking changes the DMA schedule, never the bytes: every block_b
    (including the legacy single-row layout) matches the oracle exactly."""
    from repro.kernels.tiered_gather import tiered_gather_cpu
    B, L, D = 48, 64, 256
    slots = jnp.asarray(RNG.integers(-1, L, B), jnp.int32)
    cache = _arr((L, D), jnp.float32)
    staged = _arr((B, D), jnp.float32)
    out = tiered_gather_cpu(slots, cache, staged, block_b=block_b)
    exp = ref.tiered_gather_ref(slots, cache, staged)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


@pytest.mark.parametrize("B,D,block_b,block_d",
                         [(13, 100, 4, 64),    # ragged in both dims
                          (5, 36, 8, 512),     # blocks larger than array
                          (16, 129, 1, 128),   # legacy path, ragged D
                          (7, 512, 2, 512)])   # ragged B only
def test_tiered_gather_ragged_shapes(B, D, block_b, block_d):
    """D % block_d != 0 (and B % block_b != 0) clamp to the real extents
    instead of asserting — interpret-mode check of the padded edge blocks."""
    from repro.kernels.tiered_gather import tiered_gather_cpu
    L = 32
    slots = jnp.asarray(RNG.integers(-1, L, B), jnp.int32)
    cache = _arr((L, D), jnp.float32)
    staged = _arr((B, D), jnp.float32)
    out = tiered_gather_cpu(slots, cache, staged, block_b=block_b,
                            block_d=block_d)
    exp = ref.tiered_gather_ref(slots, cache, staged)
    assert out.shape == (B, D)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


@pytest.mark.parametrize("U,L,D,N,block_b",
                         [(16, 32, 128, 40, 1),   # index-map indirection
                          (16, 32, 128, 40, 8),   # blocked gather + take
                          (7, 8, 200, 19, 1),     # ragged D, legacy path
                          (7, 8, 200, 19, 4)])    # ragged D, blocked path
def test_tiered_gather_unique_indirection(U, L, D, N, block_b):
    """The deduped-gather entry consumes (U, D) staged tiles and an (N,)
    inverse index; output must equal the plain gather on expanded inputs
    (what the merged-window executor replaces), on every layout."""
    from repro.kernels.tiered_gather import tiered_gather_unique_cpu
    slots = jnp.asarray(RNG.integers(-1, L, U), jnp.int32)
    cache = _arr((L, D), jnp.float32)
    staged = _arr((U, D), jnp.float32)
    inverse = jnp.asarray(RNG.integers(0, U, N), jnp.int32)
    exp = ref.tiered_gather_ref(slots, cache, staged)[inverse]
    out = tiered_gather_unique_cpu(slots, cache, staged, inverse,
                                   block_b=block_b)
    assert out.shape == (N, D)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))
    # the jit'd public entry and its oracle fallback agree too
    np.testing.assert_array_equal(
        np.asarray(ops.tiered_gather_unique(slots, cache, staged, inverse)),
        np.asarray(exp))
    np.testing.assert_array_equal(
        np.asarray(ops.tiered_gather_unique(slots, cache, staged, inverse,
                                            use_pallas=False)),
        np.asarray(exp))


def test_tiered_gather_all_hits_all_misses():
    cache = _arr((16, 128), jnp.float32)
    staged = _arr((8, 128), jnp.float32)
    hit = jnp.asarray(RNG.integers(0, 16, 8), jnp.int32)
    np.testing.assert_allclose(ops.tiered_gather(hit, cache, staged),
                               cache[hit])
    miss = jnp.full((8,), -1, jnp.int32)
    np.testing.assert_allclose(ops.tiered_gather(miss, cache, staged),
                               staged)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("B,F,N,D", [(16, 5, 100, 128), (64, 10, 1000, 256),
                                     (8, 25, 64, 512)])
def test_segment_mean_sweep(B, F, N, D, dtype):
    idx = jnp.asarray(RNG.integers(0, N, (B, F)), jnp.int32)
    feats = _arr((N, D), dtype)
    out = ops.segment_mean(idx, feats)
    exp = ref.segment_mean_ref(idx, feats)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize(
    "B,H,KV,Sq,Sk,hd,causal,window",
    [(2, 4, 4, 128, 128, 64, True, None),     # MHA causal
     (2, 8, 2, 128, 128, 64, True, None),     # GQA
     (1, 4, 1, 256, 256, 128, True, None),    # MQA
     (2, 4, 2, 128, 128, 64, True, 32),       # sliding window
     (2, 4, 4, 100, 164, 64, False, None),    # cross-ish, padded blocks
     (1, 2, 2, 64, 512, 64, True, None)],     # long kv (decode-like)
)
def test_flash_attention_sweep(B, H, KV, Sq, Sk, hd, causal, window, dtype):
    q = _arr((B, H, Sq, hd), dtype)
    k = _arr((B, KV, Sk, hd), dtype)
    v = _arr((B, KV, Sk, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window)
    exp = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 3e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_matches_model_attention():
    """The kernel agrees with the model-layer einsum attention path."""
    from repro.models.common import ModelConfig
    from repro.models import layers as L

    cfg = ModelConfig(name="t", family="dense", num_layers=1, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                      param_dtype=jnp.float32, compute_dtype=jnp.float32,
                      pos_embed="none")
    key = jax.random.PRNGKey(0)
    from repro.models.common import init_params
    p = init_params(L.attention_defs(cfg), key)
    x = _arr((2, 64, 64), jnp.float32)
    out_einsum, _ = L.attention(p, x, cfg, causal=True)
    # same computation through the kernel
    B, S, D = x.shape
    q = (x @ p["wq"]).reshape(B, S, 4, 16).transpose(0, 2, 1, 3)
    k = (x @ p["wk"]).reshape(B, S, 2, 16).transpose(0, 2, 1, 3)
    v = (x @ p["wv"]).reshape(B, S, 2, 16).transpose(0, 2, 1, 3)
    att = ops.flash_attention(q, k, v, causal=True)
    out_kernel = att.transpose(0, 2, 1, 3).reshape(B, S, 64) @ p["wo"]
    np.testing.assert_allclose(out_kernel, out_einsum, rtol=2e-4, atol=2e-4)


def test_model_forward_flash_equals_einsum():
    """End-to-end: a model configured with attn_impl='flash' (the Pallas
    kernel) matches the einsum attention path."""
    import dataclasses
    import repro.configs as configs
    from repro.models.transformer import LM

    base = configs.get("h2o_danube_1_8b", reduced=True)
    base = dataclasses.replace(base, param_dtype=jnp.float32,
                               compute_dtype=jnp.float32)
    m1 = LM(base)
    m2 = LM(dataclasses.replace(base, attn_impl="flash"))
    params = m1.init(jax.random.PRNGKey(3))
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 32), 0,
                              base.vocab_size)
    l1 = m1.forward(params, {"tokens": toks})
    l2 = m2.forward(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=2e-3, atol=2e-3)
