"""Jittable (device-resident) twin of the window-buffered software cache.

TPU adaptation: on the GPU, BaM cache metadata lives in device memory and is
mutated by thousands of threads; on TPU the idiomatic equivalent is cache
metadata as jit-carried state (tags / reuse / slot arrays in HBM) updated by
a compiled step function, so cache maintenance fuses into the input pipeline
step and never round-trips to the host.

Semantics match `software_cache.WindowBufferedCache(evict="first")` exactly
(property-tested).  Padding node id = -1 (ignored).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

_HASH_MULT = 0x9E3779B9  # 32-bit Fibonacci hash, matches the numpy twin


class CacheState(NamedTuple):
    tags: jnp.ndarray    # (num_sets, ways) int32 node id, -1 = empty
    reuse: jnp.ndarray   # (num_sets, ways) int32 future-reuse counter
    slots: jnp.ndarray   # (num_sets, ways) int32 backing-row index in the
                         # HBM feature cache (constant layout: set*ways+way)
    hits: jnp.ndarray    # () int64 running counters
    misses: jnp.ndarray  # ()
    bypasses: jnp.ndarray  # ()


def init_cache(num_lines: int, ways: int = 8) -> CacheState:
    assert num_lines % ways == 0
    num_sets = num_lines // ways
    return CacheState(
        tags=jnp.full((num_sets, ways), -1, jnp.int32),
        reuse=jnp.zeros((num_sets, ways), jnp.int32),
        slots=jnp.arange(num_lines, dtype=jnp.int32).reshape(num_sets, ways),
        hits=jnp.zeros((), jnp.int64),
        misses=jnp.zeros((), jnp.int64),
        bypasses=jnp.zeros((), jnp.int64),
    )


def _set_of(ids: jnp.ndarray, num_sets: int) -> jnp.ndarray:
    h = (ids.astype(jnp.uint32) * jnp.uint32(_HASH_MULT)) >> jnp.uint32(8)
    return (h % jnp.uint32(num_sets)).astype(jnp.int32)


@partial(jax.jit, static_argnames=())
def push_window(state: CacheState, nodes: jnp.ndarray) -> CacheState:
    """Bump reuse counters for cached lines appearing in a future batch.
    `nodes` is deduplicated, padded with -1."""
    num_sets = state.tags.shape[0]
    sets = _set_of(nodes, num_sets)

    def body(i, st):
        tags, reuse = st
        n, s = nodes[i], sets[i]
        match = (tags[s] == n) & (n >= 0)
        inc = match.astype(reuse.dtype)
        return tags, reuse.at[s].add(inc)

    tags, reuse = jax.lax.fori_loop(0, nodes.shape[0], body,
                                    (state.tags, state.reuse))
    return state._replace(tags=tags, reuse=reuse)


def access(state: CacheState, nodes: jnp.ndarray,
           future_counts: jnp.ndarray) -> tuple[CacheState, jnp.ndarray,
                                                jnp.ndarray]:
    """Lookup + fill for the current batch (already popped off the window).

    future_counts[i] = occurrences of nodes[i] in the remaining window
    (computed by the host pipeline or by `count_in_window`).  Returns
    (new_state, hit_mask, slot_or_minus1) where slot is the backing row in
    the HBM feature cache (for hits and successful fills).
    """
    num_sets, ways = state.tags.shape
    sets = _set_of(nodes, num_sets)
    B = nodes.shape[0]

    def body(i, carry):
        tags, reuse, hits, misses, bypasses, hit_mask, slot_out = carry
        n, s, fc = nodes[i], sets[i], future_counts[i]
        valid = n >= 0
        row_tags = tags[s]
        row_reuse = reuse[s]
        match = row_tags == n
        is_hit = valid & jnp.any(match)
        way_hit = jnp.argmax(match)
        # decrement consumed reservation on hit
        new_reuse_hit = row_reuse.at[way_hit].set(
            jnp.maximum(row_reuse[way_hit] - 1, 0))
        # fill path: first empty way, else first safe (reuse==0) way
        empty = row_tags == -1
        safe = row_reuse == 0
        has_empty = jnp.any(empty)
        has_safe = jnp.any(safe)
        way_fill = jnp.where(has_empty, jnp.argmax(empty), jnp.argmax(safe))
        can_fill = valid & ~is_hit & (has_empty | has_safe)
        new_tags_fill = row_tags.at[way_fill].set(n)
        new_reuse_fill = row_reuse.at[way_fill].set(fc)

        row_tags2 = jnp.where(can_fill, new_tags_fill, row_tags)
        row_reuse2 = jnp.where(is_hit, new_reuse_hit,
                               jnp.where(can_fill, new_reuse_fill, row_reuse))
        tags = tags.at[s].set(jnp.where(valid, row_tags2, row_tags))
        reuse = reuse.at[s].set(jnp.where(valid, row_reuse2, row_reuse))

        hits += is_hit.astype(jnp.int64)
        misses += (valid & ~is_hit).astype(jnp.int64)
        bypasses += (valid & ~is_hit & ~(has_empty | has_safe)).astype(jnp.int64)
        hit_mask = hit_mask.at[i].set(is_hit)
        way = jnp.where(is_hit, way_hit, way_fill)
        slot = jnp.where(valid & (is_hit | can_fill),
                         state.slots[s, way], -1)
        slot_out = slot_out.at[i].set(slot)
        return tags, reuse, hits, misses, bypasses, hit_mask, slot_out

    init = (state.tags, state.reuse, state.hits, state.misses, state.bypasses,
            jnp.zeros(B, bool), jnp.full(B, -1, jnp.int32))
    tags, reuse, hits, misses, bypasses, hit_mask, slots = \
        jax.lax.fori_loop(0, B, body, init)
    new_state = state._replace(tags=tags, reuse=reuse, hits=hits,
                               misses=misses, bypasses=bypasses)
    return new_state, hit_mask, slots


access = jax.jit(access)


@jax.jit
def count_in_window(nodes: jnp.ndarray, window: jnp.ndarray) -> jnp.ndarray:
    """future_counts[i] = #occurrences of nodes[i] in `window` (W, B) of
    future batches (padded with -1)."""
    flat = window.reshape(-1)
    eq = nodes[:, None] == flat[None, :]
    eq &= (nodes >= 0)[:, None]
    return eq.sum(axis=1).astype(jnp.int32)
