from repro.core.tiers import KVSlotTier
from .engine import EngineConfig, Request, ServeEngine

__all__ = ["EngineConfig", "KVSlotTier", "Request", "ServeEngine"]
