"""Schema validation for exported traces — the CI gate of ISSUE 10.

Checks two layers:

* **Event level** (Chrome trace-event JSON): every event carries the
  required keys with sane types, complete (``ph == "X"``) events have
  non-negative ``ts``/``dur``, and metadata events name their tracks.
* **Structure level** (the tracer's span trees): every child lies inside
  its parent's interval, sequential siblings do not run backwards, and
  top-level spans on each track have monotone (non-decreasing) start
  times — serve requests may overlap while queued, but never regress.

Both return a list of problem strings; empty means valid.
"""
from __future__ import annotations

from typing import Any

from repro.obs.trace import SPAN, Span, Tracer

_EPS = 1e-9


def validate_events(events: list[dict]) -> list[str]:
    """Validate a Chrome trace-event list (the ``traceEvents`` array)."""
    problems: list[str] = []
    if not isinstance(events, list):
        return ["traceEvents is not a list"]
    for i, ev in enumerate(events):
        where = f"event[{i}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "i", "M"):
            problems.append(f"{where}: unsupported ph {ph!r}")
            continue
        for key in ("name", "pid", "tid"):
            if key not in ev:
                problems.append(f"{where}: missing {key!r}")
        if not isinstance(ev.get("name"), str):
            problems.append(f"{where}: name is not a string")
        if ph == "M":
            if ev.get("name") not in ("process_name", "thread_name"):
                problems.append(f"{where}: unknown metadata {ev.get('name')!r}")
            elif not isinstance(ev.get("args", {}).get("name"), str):
                problems.append(f"{where}: metadata without args.name")
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"{where}: bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"{where}: bad dur {dur!r}")
        if ph == "i" and ev.get("s") not in ("t", "p", "g"):
            problems.append(f"{where}: instant without scope 's'")
        args = ev.get("args", {})
        if not isinstance(args, dict):
            problems.append(f"{where}: args is not an object")
    return problems


def _check_tree(sp: Span, problems: list[str], path: str) -> None:
    if sp.dur is None or sp.t0 is None:
        problems.append(f"{path}: span not laid out")
        return
    if sp.dur < 0:
        problems.append(f"{path}: negative duration {sp.dur!r}")
    end = sp.t0 + sp.dur
    cursor = sp.t0
    for c in sp.children:
        cpath = f"{path}/{c.name}"
        if c.t0 is None:
            problems.append(f"{cpath}: child not laid out")
            continue
        cdur = c.dur or 0.0
        if c.t0 < sp.t0 - _EPS or c.t0 + cdur > end + _EPS:
            problems.append(
                f"{cpath}: child [{c.t0:.9f}, {c.t0 + cdur:.9f}] escapes "
                f"parent [{sp.t0:.9f}, {end:.9f}]")
        if c.kind == SPAN and not c.parallel:
            if c.t0 < cursor - _EPS:
                problems.append(
                    f"{cpath}: sequential child starts at {c.t0:.9f} before "
                    f"cursor {cursor:.9f}")
            cursor = c.t0 + cdur
        if c.kind == SPAN:
            _check_tree(c, problems, cpath)


def validate_tracer(tracer: Tracer) -> list[str]:
    """Validate the tracer's span structure (pre-export invariants)."""
    tracer._layout()
    problems: list[str] = []
    last_start: dict[str, float] = {}
    for ev in tracer._events:
        track = ev.track or "pipeline"
        if ev.t0 is None:
            problems.append(f"{ev.name}: top-level span not laid out")
            continue
        if ev.t0 < last_start.get(track, 0.0) - _EPS:
            problems.append(
                f"{ev.name}: track {track!r} start {ev.t0:.9f} regresses "
                f"below {last_start[track]:.9f}")
        last_start[track] = max(last_start.get(track, 0.0), ev.t0)
        if ev.kind == SPAN:
            _check_tree(ev, problems, ev.name)
    for w in tracer._wall:
        if w.wall_t0 is None or w.wall_dur is None or w.wall_dur < 0:
            problems.append(f"{w.name}: wall span not closed")
    return problems


def validate_trace(tracer_or_events: Any) -> list[str]:
    """Full gate: structure (when given a Tracer) plus exported events."""
    if isinstance(tracer_or_events, Tracer):
        problems = validate_tracer(tracer_or_events)
        problems += validate_events(tracer_or_events.chrome_events())
        return problems
    if isinstance(tracer_or_events, dict):
        return validate_events(tracer_or_events.get("traceEvents", []))
    return validate_events(tracer_or_events)
