"""Adaptive data plane: TouchTable EMA + checkpointing, priced shard
migration (AdaptivePlacement / ShardRebalancer / price_migration), online
topology re-admission (TopologyRefresher), tenant-quota re-partitioning
(QuotaController / TenantCacheTier.repartition), bit-identity of adaptive
planes to their static twins on drift-free workloads, and the hypothesis
properties: migration preserves the namespace partition, features stay
bit-identical across migration, and a checkpoint taken mid-migration-epoch
resumes the same assignment."""
import numpy as np
import pytest

from repro.core import (AdaptivePlacement, AmortizedCost, GIDSDataLoader,
                        INTEL_OPTANE, LoaderConfig, QuotaController,
                        SAMSUNG_980PRO, ShardRebalancer, StorageTimeline,
                        TenantCacheTier, TouchTable, make_placement,
                        placement_names)
from repro.graph.synthetic import rmat_graph
from repro.serve import (GNNServeConfig, GNNServeEngine, TenantSpec,
                         generate_stream)


@pytest.fixture(scope="module")
def graph_and_feats():
    g = rmat_graph(10_000, 12, 16, seed=1)
    feats = np.random.default_rng(0).standard_normal(
        (g.num_nodes, 16)).astype(np.float32)
    return g, feats


def _mk(g, feats, plane, seed=7, **kw):
    cfg = dict(batch_size=128, fanouts=(4, 4), cache_lines=2048,
               window_depth=4, seed=seed)
    cfg.update(kw)
    return GIDSDataLoader(g, feats, LoaderConfig(data_plane=plane, **cfg))


def _hot_sets(g, n_shards=4):
    """The adversarial drift: each hot set is exactly one shard of the
    static degree deal, so static placement serializes on one queue."""
    table = make_placement("degree", n_shards,
                           degrees=np.diff(g.indptr)).table
    return [np.nonzero(table == s)[0] for s in range(n_shards)]


# -- TouchTable ----------------------------------------------------------------

def test_touch_table_ema_folds():
    t = TouchTable(8, alpha=0.5)
    t.observe(np.array([1, 1, 3]))
    np.testing.assert_array_equal(t.scores(), 0.0)      # nothing folded yet
    t.fold()
    assert t.scores()[1] == 1.0 and t.scores()[3] == 0.5
    t.fold()                                            # empty interval decays
    assert t.scores()[1] == 0.5
    t.observe(np.array([0, 1]), counts=np.array([4.0, 2.0]))
    t.fold()
    assert t.scores()[0] == 2.0
    assert t.scores()[1] == 0.25 + 1.0                  # decayed + fresh
    assert t.folds == 3


def test_touch_table_validation():
    with pytest.raises(ValueError, match="size"):
        TouchTable(0)
    with pytest.raises(ValueError, match="alpha"):
        TouchTable(4, alpha=0.0)
    with pytest.raises(ValueError, match="alpha"):
        TouchTable(4, alpha=1.5)
    t = TouchTable(4)
    t.observe(np.empty(0, np.int64))                    # no-op, no crash
    np.testing.assert_array_equal(t.pending, 0.0)


def test_touch_table_checkpoint_roundtrips_mid_interval():
    t = TouchTable(16, alpha=0.25)
    t.observe(np.arange(8))
    t.fold()
    t.observe(np.array([3, 3]))                         # open bucket
    state = t.state_dict()
    fresh = TouchTable(16, alpha=0.5)
    fresh.load_state_dict(state)
    assert fresh.alpha == 0.25 and fresh.folds == 1
    np.testing.assert_array_equal(fresh.ema, t.ema)
    np.testing.assert_array_equal(fresh.pending, t.pending)
    fresh.fold(), t.fold()
    np.testing.assert_array_equal(fresh.scores(), t.scores())
    with pytest.raises(ValueError, match="touch table checkpointed over"):
        TouchTable(8).load_state_dict(state)


# -- AmortizedCost -------------------------------------------------------------

def test_amortized_cost_drains_over_horizon():
    debt = AmortizedCost(4)
    assert debt.charge() == 0.0
    debt.add(1.0)
    charges = [debt.charge() for _ in range(5)]
    assert charges[:4] == [0.25] * 4
    assert charges[4] == 0.0
    assert debt.outstanding_s == 0.0
    debt.add(0.4)
    debt.charge()
    debt.add(0.1)                                       # blends into the rest
    total = 0.3 + 0.1
    drained = 0.0
    for _ in range(64):
        drained += debt.charge()
    assert drained == pytest.approx(total)
    with pytest.raises(ValueError, match="horizon"):
        AmortizedCost(0)
    with pytest.raises(ValueError, match="cost"):
        debt.add(-1.0)


# -- AdaptivePlacement ---------------------------------------------------------

def test_adaptive_registered_and_seeds_from_degree():
    assert "adaptive" in placement_names()
    degrees = np.random.default_rng(3).zipf(1.5, 4096).astype(np.int64)
    adaptive = make_placement("adaptive", 4, degrees=degrees)
    static = make_placement("degree", 4, degrees=degrees)
    assert isinstance(adaptive, AdaptivePlacement)
    np.testing.assert_array_equal(adaptive.table, static.table)


def test_adaptive_plan_rebalance_restripes_hot_leaves_cold():
    pol = AdaptivePlacement(4, np.ones(1000, np.int64))
    # all measured traffic lands on the 32 nodes the table puts on shard 0
    hot = np.nonzero(pol.table == 0)[0][:32]
    pol.touches.observe(hot)
    pol.touches.fold()
    new, moved = pol.plan_rebalance()
    assert pol.touches.scores().max() > 0
    # proposal only — nothing mutated until commit
    assert (pol.table != new).any() and len(moved) > 0
    # the hot set is re-dealt round-robin: one quarter per shard
    counts = np.bincount(new[hot], minlength=4)
    np.testing.assert_array_equal(counts, 8)
    # the untouched cold tail stays exactly where it was
    cold = np.setdiff1d(np.arange(1000), hot)
    np.testing.assert_array_equal(new[cold], pol.table[cold])
    pol.commit(new)
    np.testing.assert_array_equal(pol.table, new)


def test_adaptive_plan_rebalance_cold_table_moves_nothing():
    pol = AdaptivePlacement(2, np.arange(100))
    new, moved = pol.plan_rebalance()
    assert len(moved) == 0
    np.testing.assert_array_equal(new, pol.table)


def test_adaptive_commit_validation():
    pol = AdaptivePlacement(2, np.arange(100))
    with pytest.raises(ValueError, match="adaptive placement commit shape"):
        pol.commit(np.zeros(50, np.int16))
    bad = pol.table.copy()
    bad[0] = 7
    with pytest.raises(ValueError, match="no longer partitions"):
        pol.commit(bad)


def test_adaptive_state_dict_carries_touches():
    pol = AdaptivePlacement(4, np.random.default_rng(0).integers(
        1, 50, 500))
    pol.touches.observe(np.arange(100))
    pol.touches.fold()
    new, _ = pol.plan_rebalance()
    pol.commit(new)
    fresh = AdaptivePlacement(4, np.ones(500, np.int64))
    fresh.load_state_dict(pol.state_dict())
    np.testing.assert_array_equal(fresh.table, pol.table)
    np.testing.assert_array_equal(fresh.touches.scores(),
                                  pol.touches.scores())


def test_placement_restore_errors_name_the_policy():
    """Satellite: every placement restore failure says WHICH policy refused,
    so a mixed-plane checkpoint mismatch is attributable from the message."""
    range_pol = make_placement("range", 4, num_nodes=1000)
    with pytest.raises(ValueError, match="range placement checkpointed"):
        make_placement("range", 4, num_nodes=2000).load_state_dict(
            range_pol.state_dict())
    adaptive = AdaptivePlacement(4, np.ones(100, np.int64))
    small = AdaptivePlacement(4, np.ones(50, np.int64))
    with pytest.raises(ValueError, match="adaptive placement table shape"):
        small.load_state_dict(
            {**adaptive.state_dict(), "touches": small.touches.state_dict()})
    degree = make_placement("degree", 4, degrees=np.ones(100, np.int64))
    with pytest.raises(ValueError, match="degree placement table shape"):
        make_placement("degree", 4,
                       degrees=np.ones(50, np.int64)).load_state_dict(
            degree.state_dict())


# -- price_migration -----------------------------------------------------------

def test_price_migration_zero_moves_is_free():
    tl = StorageTimeline(SAMSUNG_980PRO)
    shard = np.array([0, 1, 2, 3])
    assert tl.price_migration(shard, shard, 1024) == 0.0
    assert tl.price_migration(np.empty(0), np.empty(0), 1024) == 0.0


def test_price_migration_shape_mismatch():
    tl = StorageTimeline(SAMSUNG_980PRO)
    with pytest.raises(ValueError, match="arity"):
        tl.price_migration(np.array([0, 1]), np.array([1]), 1024)


def test_price_migration_scales_with_moved_rows():
    tl = StorageTimeline(SAMSUNG_980PRO)
    small = tl.price_migration(np.zeros(100), np.ones(100), 1024,
                               n_shards=4)
    big = tl.price_migration(np.zeros(10_000), np.ones(10_000), 1024,
                             n_shards=4)
    assert 0.0 < small < big


def test_price_migration_heterogeneous_straggler_pays_more():
    """A migration queue landing on the slow device sets the critical
    path, exactly like a gather burst."""
    fast = StorageTimeline(INTEL_OPTANE)
    fast.shard_specs = (INTEL_OPTANE,) * 4
    slow = StorageTimeline(INTEL_OPTANE)
    slow.shard_specs = (SAMSUNG_980PRO, INTEL_OPTANE, INTEL_OPTANE,
                        INTEL_OPTANE)
    src = np.zeros(4000, np.int64)          # every move reads from shard 0
    dst = np.arange(4000) % 4
    keep = dst != 0
    assert slow.price_migration(src[keep], dst[keep], 1024) \
        > fast.price_migration(src[keep], dst[keep], 1024)


# -- ShardRebalancer -----------------------------------------------------------

def test_rebalancer_requires_adaptive_placement(graph_and_feats):
    g, feats = graph_and_feats
    dl = _mk(g, feats, "gids-merged-sharded", n_shards=4,
             placement="degree")
    with pytest.raises(ValueError, match="placement='adaptive'"):
        ShardRebalancer(dl.store.tiers[-1], dl.timeline, bytes_per_row=64)
    assert dl.rebalancer is None            # loader skips static placements
    adaptive_dl = _mk(g, feats, "gids-merged-sharded", n_shards=4,
                      placement="adaptive")
    with pytest.raises(ValueError, match="interval"):
        ShardRebalancer(adaptive_dl.store.tiers[-1], adaptive_dl.timeline,
                        bytes_per_row=64, interval=0)


def test_adaptive_plane_bit_identical_to_degree_without_drift(
        graph_and_feats):
    """The static control: uniform workload → the economics gate never
    fires, so adaptive == degree in floats AND bytes, with zero
    migrations."""
    g, feats = graph_and_feats
    a = _mk(g, feats, "gids-merged-sharded", n_shards=4, placement="degree")
    b = _mk(g, feats, "gids-merged-sharded", n_shards=4,
            placement="adaptive")
    for _ in range(10):
        ba, bb = a.next_batch(), b.next_batch()
        np.testing.assert_array_equal(ba.features, bb.features)
        assert ba.prep_time_s == bb.prep_time_s
        assert ba.report.tier_counts == bb.report.tier_counts
    assert b.rebalancer.n_migrations == 0


def _drifted_adaptive(g, feats, batches=24, **kw):
    """An adaptive loader driven through hot-set drift hard enough to
    commit at least one priced migration."""
    dl = _mk(g, feats, "gids-merged-sharded", n_shards=4,
             placement="adaptive", batch_size=256, fanouts=(2,),
             cache_lines=512, rebalance_interval=4, migration_horizon=64,
             **kw)
    hot = _hot_sets(g)
    dl.train_ids = hot[0]
    for _ in range(batches):
        dl.next_batch()
    return dl


def test_rebalancer_commits_priced_migration_under_drift(graph_and_feats):
    g, feats = graph_and_feats
    dl = _drifted_adaptive(g, feats)
    assert dl.rebalancer.n_migrations >= 1
    ev = dl.rebalancer.events[0]
    assert ev.n_moved > 0 and ev.cost_s > 0.0
    assert ev.imbalance_before >= dl.rebalancer.threshold
    assert ev.predicted_saving_s * dl.rebalancer.horizon > ev.cost_s
    assert dl.rebalancer.total_migration_cost_s == \
        pytest.approx(sum(e.cost_s for e in dl.rebalancer.events))
    # the migration actually moved the measured-hot nodes off one queue
    table = dl.store.tiers[-1].placement.table
    hot = _hot_sets(g)[0]
    counts = np.bincount(table[hot], minlength=4)
    assert counts.max() < len(hot)          # no longer all on shard 0


# -- hypothesis properties (satellite) -----------------------------------------

def test_migration_preserves_partition_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(n_shards=st.sampled_from([2, 3, 4, 8]),
           n_nodes=st.integers(16, 400),
           seed=st.integers(0, 10),
           folds=st.integers(1, 4))
    def check(n_shards, n_nodes, seed, folds):
        rng = np.random.default_rng(seed)
        pol = AdaptivePlacement(n_shards,
                                rng.integers(0, 50, n_nodes))
        for _ in range(folds):
            pol.touches.observe(rng.integers(0, n_nodes, n_nodes // 2))
            pol.touches.fold()
            new, moved = pol.plan_rebalance()
            pol.commit(new)
            # the invariant: every node still maps to exactly one live shard
            assert pol.table.shape == (n_nodes,)
            assert ((pol.table >= 0) & (pol.table < n_shards)).all()
            np.testing.assert_array_equal(pol.shard_of(np.arange(n_nodes)),
                                          pol.table)

    check()


def test_features_bit_identical_across_migration_property(graph_and_feats):
    """Migration moves rows between modelled queues, never changes bytes:
    an adaptive loader that committed migrations returns the same features
    as a static degree loader on the same seed."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    g, feats = graph_and_feats
    hot = _hot_sets(g)

    @settings(max_examples=3, deadline=None)
    @given(seed=st.integers(0, 5))
    def check(seed):
        loaders = {}
        batches = {}
        for pol in ("degree", "adaptive"):
            dl = _mk(g, feats, "gids-merged-sharded", n_shards=4,
                     placement=pol, seed=seed, batch_size=256, fanouts=(2,),
                     cache_lines=512, rebalance_interval=4,
                     migration_horizon=64)
            dl.train_ids = hot[0]
            batches[pol] = [dl.next_batch() for _ in range(16)]
            loaders[pol] = dl
        assert loaders["adaptive"].rebalancer.n_migrations >= 1
        for ba, bb in zip(batches["degree"], batches["adaptive"]):
            np.testing.assert_array_equal(ba.blocks.all_nodes,
                                          bb.blocks.all_nodes)
            np.testing.assert_array_equal(ba.features, bb.features)

    check()


def test_checkpoint_mid_migration_resumes_assignment_property(
        graph_and_feats):
    """A checkpoint taken after migrations committed (touch table
    mid-interval) restores the SAME shard assignment and learned scores —
    resumed loaders agree with the original and each other bit-for-bit."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    g, feats = graph_and_feats

    @settings(max_examples=3, deadline=None)
    @given(extra=st.integers(1, 7))
    def check(extra):
        dl = _drifted_adaptive(g, feats, batches=16 + extra)
        assert dl.rebalancer.n_migrations >= 1
        state = dl.state_dict()
        probe = np.arange(0, g.num_nodes, 41)
        resumed = []
        for _ in range(2):
            r = _mk(g, feats, "gids-merged-sharded", n_shards=4,
                    placement="adaptive", batch_size=256, fanouts=(2,),
                    cache_lines=512, rebalance_interval=4,
                    migration_horizon=64)
            r.load_state_dict(state)
            resumed.append(r)
        for r in resumed:
            tier = r.store.tiers[-1]
            np.testing.assert_array_equal(
                tier.shard_of(probe), dl.store.tiers[-1].shard_of(probe))
            np.testing.assert_array_equal(
                tier.placement.touches.scores(),
                dl.store.tiers[-1].placement.touches.scores())
        r1, r2 = resumed
        r1.train_ids = r2.train_ids = _hot_sets(g)[0]
        for _ in range(4):
            b1, b2 = r1.next_batch(), r2.next_batch()
            np.testing.assert_array_equal(b1.features, b2.features)
            assert b1.prep_time_s == b2.prep_time_s

    check()


# -- TopologyRefresher ---------------------------------------------------------

def _topo_loader(g, feats, admission):
    return GIDSDataLoader(g, feats, LoaderConfig(
        batch_size=256, fanouts=(5, 3), data_plane="gids-topo",
        cache_lines=2048, topo_admission=admission, topo_gpu_fraction=0.05,
        topo_host_fraction=0.25, seed=7, rebalance_interval=4,
        migration_horizon=64))


def test_topology_adaptive_admission_matches_degree_then_refreshes(
        graph_and_feats):
    g, feats = graph_and_feats
    a, b = _topo_loader(g, feats, "degree"), _topo_loader(g, feats,
                                                          "adaptive")
    # identical initial admission: adaptive seeds from the degree ranking
    np.testing.assert_array_equal(a.topo.assignment, b.topo.assignment)
    assert a.topo.touches is None and b.topo.touches is not None
    assert a.topo_refresher is None and b.topo_refresher is not None
    quarters = np.array_split(np.arange(g.num_nodes), 4)
    for epoch in range(2):
        a.train_ids = b.train_ids = quarters[epoch]
        for _ in range(16):
            ba, bb = a.next_batch(), b.next_batch()
            # refresh moves pages between tiers, never edges
            np.testing.assert_array_equal(ba.blocks.all_nodes,
                                          bb.blocks.all_nodes)
    assert b.topo_refresher.n_refreshes >= 1
    ev = b.topo_refresher.events[0]
    assert ev.n_moved > 0 and ev.cost_s > 0.0
    # ...and every committed refresh preserved the tier budgets
    assert a.topo.tier_pages() == b.topo.tier_pages()


def test_topology_commit_refresh_validates_budgets(graph_and_feats):
    g, feats = graph_and_feats
    dl = _topo_loader(g, feats, "adaptive")
    topo = dl.topo
    with pytest.raises(ValueError, match="edge pages"):
        topo.commit_refresh(np.zeros(3, np.int8))
    grown = topo.assignment.copy()
    grown[:] = 0                            # everything in HBM: budget blown
    with pytest.raises(ValueError, match="preserve"):
        topo.commit_refresh(grown)


def test_topology_plan_refresh_requires_feedback(graph_and_feats):
    g, feats = graph_and_feats
    dl = _topo_loader(g, feats, "degree")
    with pytest.raises(ValueError, match="admission='adaptive'"):
        dl.topo.plan_refresh()


# -- TenantCacheTier.repartition -----------------------------------------------

def test_repartition_resizes_and_carries_stats():
    tier = TenantCacheTier(num_lines=256, ways=8, tenants=2, seed=3)
    lines_before = [tier.partition_lines(t) for t in range(2)]
    assert lines_before[0] == lines_before[1]
    tier.partitions[0].stats.hits = 40
    tier.partitions[0].stats.misses = 10
    tier.repartition((3.0, 1.0))
    assert tier.partition_lines(0) > tier.partition_lines(1)
    # cumulative telemetry survives the rebuild
    assert tier.hit_ratio(0) == pytest.approx(0.8)
    assert tier.hit_ratios() == (pytest.approx(0.8), 0.0)
    assert tier.quotas == (3.0, 1.0)
    with pytest.raises(ValueError, match="one capacity share"):
        tier.repartition((1.0,))
    with pytest.raises(ValueError, match="positive"):
        tier.repartition((1.0, 0.0))


def test_tenant_tier_reset_restores_initial_quotas():
    tier = TenantCacheTier(num_lines=256, ways=8, tenants=2,
                           quotas=(1.0, 1.0), seed=3)
    tier.repartition((5.0, 1.0))
    tier.partitions[0].stats.hits = 7
    tier.reset()
    assert tier.quotas == (1.0, 1.0)
    assert tier.partition_lines(0) == tier.partition_lines(1)
    assert tier.hit_ratios() == (0.0, 0.0)  # cold, replay-identical


# -- QuotaController -----------------------------------------------------------

def test_quota_controller_validation():
    single = TenantCacheTier(num_lines=64, ways=8, tenants=1)
    with pytest.raises(ValueError, match="two tenants"):
        QuotaController(single)
    tier = TenantCacheTier(num_lines=64, ways=8, tenants=2)
    with pytest.raises(ValueError, match="floor"):
        QuotaController(tier, floor=0.6)


def test_quota_controller_shifts_toward_measured_misses():
    tier = TenantCacheTier(num_lines=512, ways=8, tenants=2, seed=1)
    ctrl = QuotaController(tier, interval=2, floor=0.1, deadband=0.02)
    # tenant 0 misses 9x harder than tenant 1 over the interval
    tier.partitions[0].stats.misses += 90
    tier.partitions[1].stats.misses += 10
    assert ctrl.step() is False             # mid-interval: no decision
    assert ctrl.step() is True
    assert tier.quotas[0] > tier.quotas[1]
    assert ctrl.n_repartitions == 1 and ctrl.events[0][0] == 2
    # every tenant keeps at least the floor
    total = sum(tier.quotas)
    assert min(q / total for q in tier.quotas) >= ctrl.floor - 1e-12
    # no traffic → no decision (demand signal unchanged)
    assert ctrl.step() is False and ctrl.step() is False


def test_quota_controller_deadband_suppresses_noise():
    tier = TenantCacheTier(num_lines=512, ways=8, tenants=2, seed=1)
    ctrl = QuotaController(tier, interval=1, floor=0.1, deadband=0.2)
    tier.partitions[0].stats.misses += 11
    tier.partitions[1].stats.misses += 9    # 55/45: inside the dead band
    assert ctrl.step() is False
    assert tier.quotas == (0.5, 0.5)


# -- serve-plane integration ---------------------------------------------------

def _serve_stream(num_nodes):
    tenants = (
        TenantSpec("big", rate_share=2.0, hot_fraction=0.12, hot_prob=0.95,
                   deadline_s=4e-3),
        TenantSpec("small", rate_share=1.0, hot_fraction=0.004,
                   hot_prob=0.95, deadline_s=4e-3),
    )
    return generate_stream(num_nodes, tenants, offered_qps=3000,
                           n_requests=240, seed=3)


def test_serve_result_rolls_up_tenant_hit_ratios(graph_and_feats):
    g, feats = graph_and_feats
    engine = GNNServeEngine(g, feats, GNNServeConfig(
        tenants=2, cache_lines=2048, seed=5))
    res = engine.run(list(_serve_stream(g.num_nodes)))
    assert set(res.tenant_hit_ratios) == {0, 1}
    for t, ratio in res.tenant_hit_ratios.items():
        assert 0.0 <= ratio <= 1.0
        assert ratio == pytest.approx(engine._tenant_tier.hit_ratio(t))
    assert res.quota_trace == []            # static quotas: nothing moved
    assert engine.quota_controller is None


def test_serve_adaptive_quotas_repartition_online(graph_and_feats):
    g, feats = graph_and_feats
    stream = _serve_stream(g.num_nodes)
    engine = GNNServeEngine(g, feats, GNNServeConfig(
        tenants=2, cache_lines=2048, adaptive_quotas=True, quota_interval=8,
        seed=5))
    assert engine.quota_controller is not None
    res = engine.run(list(stream))
    assert len(res.quota_trace) >= 1
    window, quotas = res.quota_trace[0]
    assert window % 8 == 0 and len(quotas) == 2
    assert sum(quotas) == pytest.approx(1.0)
    # reset → replay is bit-identical (controller and quotas rebuilt)
    engine.reset()
    assert engine._tenant_tier.quotas == engine._tenant_tier._init_quotas
    res2 = engine.run(list(stream))
    assert res2.quota_trace == res.quota_trace
    assert res2.p99_s() == res.p99_s()
    assert res2.tenant_hit_ratios == res.tenant_hit_ratios
