"""Feedback-driven data-plane control — the telemetry loop, closed.

Every earlier layer of this repo emits telemetry the policies ignore: the
sharded burst pricing reports per-queue drain imbalance
(`ShardedBurstResult.imbalance`), every sampling hop reports which edge
pages it touched (`TopologyGatherReport`), and the tenant cache reports
per-tenant hit ratios — yet placement, admission, and quotas are all frozen
at construction.  Data Tiering (arXiv 2111.05894) stops at exactly this
point: a *static* reuse score computed before training starts.  This module
goes past it: a mutable, checkpointed `TouchTable` accumulates MEASURED
touches online, and three controllers spend that signal —

  ShardRebalancer   — feature-shard migration.  When the measured queue
                      imbalance crosses a threshold, re-stripe the
                      measured-hot nodes round-robin across shards
                      (`AdaptivePlacement.plan_rebalance`, core/sharding.py)
                      and MOVE the rows.  Moving rows costs real IOs
                      (`StorageTimeline.price_migration`), so the controller
                      commits only when the modelled saving over its
                      amortization horizon exceeds the migration's own cost,
                      and the committed cost is charged back into subsequent
                      batches (`AmortizedCost`) — rebalancing is a priced
                      bet, not a free lunch.
  TopologyRefresher — the same loop one namespace over: measured-hot edge
                      pages are promoted into the GPU/host budgets between
                      folds (`TieredTopologyStore.plan_refresh`), with the
                      promotion reads priced through the same hop model the
                      sampler pays.
  QuotaController   — online re-partitioning of the serve plane's
                      per-tenant cache quotas from measured per-tenant miss
                      traffic (`TenantCacheTier.repartition`), EMA-smoothed
                      with a dead band so quota moves track demand shifts
                      instead of noise.

All three are *virtual-time* controllers: decisions are functions of priced
telemetry, never the wall clock, so adaptive runs stay bit-reproducible —
and bit-identical to their static twins until the first commit (the
adaptive policies seed from the same static priors).

On a multi-host plane (core/hosts.py) the same loop applies unchanged:
`CoPartitionedPlacement.__getattr__` forwards the adaptive seam, and
`StorageTimeline.price_migration` adds a link-transit term when
`timeline.host_specs` is set — a cross-host row move pays the interconnect,
not just the SSD queues, so the rebalancer's bet is priced against the real
distributed cost.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.obs import NULL_TRACER


class TouchTable:
    """Mutable, checkpointed EMA of measured per-entry touches.

    One slot per namespace entry (feature node, edge page, ...).  `observe`
    accumulates raw touch counts into a pending bucket; `fold` closes the
    measurement interval by folding the bucket into the exponential moving
    average — `ema = (1 - alpha) * ema + alpha * pending` — so `scores()`
    tracks the recent touch *rate per interval* and old hot sets decay
    instead of pinning their placement forever.  `state_dict` round-trips
    both the folded average and the open bucket, so a checkpoint taken
    mid-interval resumes the same learned state (the adaptive placements
    carry this through the tier checkpoint path, exactly like
    `DegreePlacement.table`).
    """

    def __init__(self, size: int, alpha: float = 0.5):
        if size < 1:
            raise ValueError(f"TouchTable needs a namespace, got size {size}")
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.size = int(size)
        self.alpha = float(alpha)
        self.ema = np.zeros(self.size, np.float64)
        self.pending = np.zeros(self.size, np.float64)
        self.folds = 0

    def observe(self, ids: np.ndarray, counts: np.ndarray | None = None
                ) -> None:
        """Record measured touches: +1 per id, or `counts[i]` touches of
        `ids[i]` (the merged executor passes the window multiplicity, the
        topology store its per-page read counts)."""
        ids = np.asarray(ids, np.int64)
        if len(ids) == 0:
            return
        if counts is None:
            np.add.at(self.pending, ids, 1.0)
        else:
            np.add.at(self.pending, ids,
                      np.asarray(counts, np.float64))

    def fold(self) -> None:
        """Close the measurement interval: fold the pending bucket into the
        EMA and start the next interval empty."""
        self.ema *= 1.0 - self.alpha
        self.ema += self.alpha * self.pending
        self.pending[:] = 0.0
        self.folds += 1

    def scores(self) -> np.ndarray:
        """The learned per-entry touch rate (per fold interval)."""
        return self.ema

    # -- checkpoint ------------------------------------------------------------
    def state_dict(self) -> dict:
        return {"size": self.size, "alpha": self.alpha, "folds": self.folds,
                "ema": self.ema.copy(), "pending": self.pending.copy()}

    def load_state_dict(self, state: dict) -> None:
        if int(state.get("size", self.size)) != self.size:
            raise ValueError(
                f"touch table checkpointed over {state.get('size')} entries, "
                f"namespace has {self.size}")
        self.alpha = float(state.get("alpha", self.alpha))
        self.folds = int(state.get("folds", 0))
        self.ema = np.asarray(state["ema"], np.float64).copy()
        self.pending = np.asarray(state["pending"], np.float64).copy()


class AmortizedCost:
    """A priced one-off cost paid back over subsequent bursts.

    `add(cost_s)` books a committed migration's modelled seconds;
    `charge()` returns the next burst's share — outstanding / horizon,
    recomputed at each booking so overlapping migrations blend — until the
    debt drains.  The loader folds each charge into that batch's
    `prep_time_s`, which is what makes adaptive-vs-static comparisons net
    of migration IOs rather than pretending the rows teleported."""

    def __init__(self, horizon: int):
        if horizon < 1:
            raise ValueError(f"amortization horizon must be >= 1, "
                             f"got {horizon}")
        self.horizon = int(horizon)
        self.outstanding_s = 0.0
        self._per_charge = 0.0

    def add(self, cost_s: float) -> None:
        if cost_s < 0:
            raise ValueError(f"cost must be >= 0, got {cost_s}")
        self.outstanding_s += float(cost_s)
        self._per_charge = self.outstanding_s / self.horizon

    def charge(self) -> float:
        c = min(self.outstanding_s, self._per_charge)
        self.outstanding_s -= c
        return c


@dataclasses.dataclass(frozen=True)
class MigrationEvent:
    """One committed shard migration, for telemetry and the convergence
    benchmark: when it happened (burst index), how many rows moved, what
    moving them cost, and what the model predicted the move would buy."""

    burst: int
    n_moved: int
    cost_s: float
    imbalance_before: float
    predicted_saving_s: float       # per burst, over the horizon
    reason: str = "imbalance"       # "imbalance" | "drain" (health-driven)


class ShardHealthMonitor:
    """EMA of per-shard burst latencies — the fault plane's detector.

    `observe` feeds every priced `ShardedBurstResult` into a per-shard EMA
    of PER-ROW drain time (``per_shard_s / per_shard_rows``): normalizing by
    rows makes natural placement skew invisible — a shard that is slow
    because it holds more of the batch looks healthy per row — while device
    slowness (brownout, flaky retries) shows up directly.  A shard is
    `degraded` when its per-row EMA exceeds ``degraded_factor`` times the
    median across tracked shards, after at least `min_bursts` observations
    (cold starts don't flap).  The flag set is what the `FailoverRouter`
    routes around and what the `ShardRebalancer` drains
    (`AdaptivePlacement.plan_drain`); `healthiest` picks the replica with
    the lowest EMA for hedges and failover.

    Pure virtual-time telemetry: state is a function of the priced bursts
    observed, so adaptive fault handling stays bit-reproducible."""

    def __init__(self, n_shards: int, alpha: float = 0.3,
                 degraded_factor: float = 2.5, min_bursts: int = 4):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        if degraded_factor <= 1.0:
            raise ValueError(f"degraded_factor must be > 1, "
                             f"got {degraded_factor}")
        self.n_shards = int(n_shards)
        self.alpha = float(alpha)
        self.degraded_factor = float(degraded_factor)
        self.min_bursts = int(min_bursts)
        self.reset()

    def reset(self) -> None:
        self.ema = np.zeros(self.n_shards, np.float64)
        self.seen = np.zeros(self.n_shards, np.int64)
        self._degraded = np.empty(0, np.int64)
        self._bursts = 0
        self.first_flag_burst = -1

    def observe(self, burst) -> None:
        """Fold one priced burst's per-shard drains into the EMAs and
        recompute the degraded set."""
        t = np.asarray(burst.per_shard_s, np.float64)
        rows = np.asarray(burst.per_shard_rows, np.float64)
        if len(t) != self.n_shards:
            raise ValueError(
                f"burst spans {len(t)} shards, monitor tracks "
                f"{self.n_shards}")
        self._bursts += 1
        m = rows > 0
        per_row = np.zeros_like(t)
        per_row[m] = t[m] / rows[m]
        fresh = m & (self.seen == 0)
        self.ema[fresh] = per_row[fresh]
        seasoned = m & (self.seen > 0)
        self.ema[seasoned] = (1.0 - self.alpha) * self.ema[seasoned] \
            + self.alpha * per_row[seasoned]
        self.seen[m] += 1
        tracked = (self.seen >= self.min_bursts) & (self.ema > 0)
        if int(tracked.sum()) < 2:
            self._degraded = np.empty(0, np.int64)
            return
        median = float(np.median(self.ema[tracked]))
        self._degraded = np.nonzero(
            tracked & (self.ema > self.degraded_factor * median))[0]
        if len(self._degraded) and self.first_flag_burst < 0:
            self.first_flag_burst = self._bursts

    def degraded(self) -> np.ndarray:
        """Shards currently flagged as browning out (may be empty)."""
        return self._degraded

    def worst(self) -> int:
        """The degraded shard with the highest per-row EMA, or -1."""
        bad = self._degraded
        if len(bad) == 0:
            return -1
        return int(bad[np.argmax(self.ema[bad])])

    def healthiest(self, candidates) -> int:
        """The candidate shard with the lowest per-row EMA (ties: first)."""
        cand = np.asarray(candidates, np.int64)
        if len(cand) == 0:
            raise ValueError("healthiest() of no candidate shards")
        return int(cand[np.argmin(self.ema[cand])])

    # -- checkpoint ------------------------------------------------------------
    def state_dict(self) -> dict:
        return {"n_shards": self.n_shards, "alpha": self.alpha,
                "degraded_factor": self.degraded_factor,
                "min_bursts": self.min_bursts, "bursts": self._bursts,
                "ema": self.ema.copy(), "seen": self.seen.copy(),
                "degraded": self._degraded.copy(),
                "first_flag_burst": self.first_flag_burst}

    def load_state_dict(self, state: dict) -> None:
        if int(state.get("n_shards", self.n_shards)) != self.n_shards:
            raise ValueError(
                f"shard health monitor checkpointed over "
                f"{state.get('n_shards')} shards, plane has {self.n_shards}")
        self.alpha = float(state.get("alpha", self.alpha))
        self.degraded_factor = float(state.get("degraded_factor",
                                               self.degraded_factor))
        self.min_bursts = int(state.get("min_bursts", self.min_bursts))
        self._bursts = int(state.get("bursts", 0))
        self.ema = np.asarray(state["ema"], np.float64).copy()
        self.seen = np.asarray(state["seen"], np.int64).copy()
        self._degraded = np.asarray(state.get("degraded", ()),
                                    np.int64).copy()
        self.first_flag_burst = int(state.get("first_flag_burst", -1))


class ShardRebalancer:
    """Online feature-shard migration from measured touches.

    Drives an `AdaptivePlacement` (core/sharding.py) sitting under a
    `ShardedStorageTier`: every priced burst the loader records the batch's
    touched nodes (`observe`) and ticks `step()`; every `interval` bursts
    the touch table folds and, if the most recent burst's measured queue
    imbalance (`StorageTimeline.shard_burst`) exceeds `threshold`, the
    policy proposes re-striping the measured-hot nodes round-robin.  The
    proposal commits ONLY when

        (elapsed - mean per-shard drain) * horizon  >  migration cost

    i.e. the modelled time the imbalance is costing per burst, over the
    amortization horizon, must beat the priced IO cost of actually moving
    the rows (`StorageTimeline.price_migration`).  Committed costs are
    charged back into subsequent bursts via `AmortizedCost` — `step()`
    returns each burst's share and the loader folds it into prep time."""

    def __init__(self, tier, timeline, bytes_per_row: int, *,
                 interval: int = 8, threshold: float = 1.25,
                 horizon: int = 64, cooldown: int = 2):
        placement = getattr(tier, "placement", None)
        if placement is None or not hasattr(placement, "plan_rebalance"):
            raise ValueError(
                "ShardRebalancer needs a sharded backstop with an adaptive "
                f"placement (got {getattr(placement, 'name', None)!r}) — "
                "build the plane with placement='adaptive'")
        if interval < 1:
            raise ValueError(f"feedback interval must be >= 1, "
                             f"got {interval}")
        self.tier = tier
        self.placement = placement
        self.timeline = timeline
        self.bytes_per_row = int(bytes_per_row)
        self.interval = int(interval)
        self.threshold = float(threshold)
        self.horizon = int(horizon)
        self.cooldown = int(cooldown)
        self.debt = AmortizedCost(horizon)
        self.events: list[MigrationEvent] = []
        self._bursts = 0
        self._cooldown = 0
        # fault plane: when a ShardHealthMonitor is wired (the loader does
        # it for fault-enabled planes), a degraded shard triggers a DRAIN —
        # evacuate its measured-hot rows — ahead of the imbalance trigger
        self.monitor = None
        # observability plane: commits emit instant events + commit-cost
        # counters; the shared no-op tracer records nothing
        self.tracer = NULL_TRACER

    def observe(self, node_ids: np.ndarray,
                counts: np.ndarray | None = None) -> None:
        self.placement.touches.observe(node_ids, counts)

    def step(self) -> float:
        """One tick per priced burst: consider a migration at the interval
        boundary, and return this burst's amortized migration charge."""
        self._bursts += 1
        if self._bursts % self.interval == 0:
            self._consider()
        return self.debt.charge()

    def _consider(self) -> None:
        self.placement.touches.fold()
        # post-commit cooldown: the imbalance telemetry needs a few folds to
        # reflect the NEW table (EMA lag would otherwise trigger a chain of
        # low-value follow-up migrations right after a big one)
        if self._cooldown > 0:
            self._cooldown -= 1
            return
        burst = self.timeline.shard_burst
        if burst is None:
            return
        # health-driven drain first: a browning-out queue is a stronger
        # signal than imbalance (the max-over-shards pricing rides it
        # every burst), and evacuating its hot rows is the one move that
        # helps even when the namespace is perfectly level
        drain_shard = self.monitor.worst() if self.monitor is not None \
            and hasattr(self.placement, "plan_drain") else -1
        if drain_shard >= 0:
            new_table, moved = self.placement.plan_drain(drain_shard)
            reason = "drain"
        else:
            if burst.imbalance < self.threshold:
                return
            new_table, moved = self.placement.plan_rebalance()
            reason = "imbalance"
        if len(moved) == 0:
            return
        cost = self.timeline.price_migration(
            self.placement.table[moved], new_table[moved],
            self.bytes_per_row, n_shards=self.placement.n_shards)
        # the imbalance is costing (elapsed - mean drain) per burst; a
        # perfectly rebalanced namespace drains in ~the mean
        saving = burst.elapsed_s - float(np.mean(burst.per_shard_s))
        if saving * self.horizon <= cost:
            return                              # the model says: not a win
        self.placement.commit(new_table)
        self.debt.add(cost)
        self._cooldown = self.cooldown
        self.events.append(MigrationEvent(
            burst=self._bursts, n_moved=int(len(moved)), cost_s=float(cost),
            imbalance_before=float(burst.imbalance),
            predicted_saving_s=float(saving), reason=reason))
        self.tracer.instant(
            "migration", track="controller", cat="controller",
            burst=self._bursts, n_moved=int(len(moved)),
            cost_s=float(cost), imbalance_before=float(burst.imbalance),
            reason=reason)
        self.tracer.metrics.counter("controller.migrations").inc()
        self.tracer.metrics.counter("controller.migration_cost_s").inc(
            float(cost))

    @property
    def n_migrations(self) -> int:
        return len(self.events)

    @property
    def total_migration_cost_s(self) -> float:
        return sum(e.cost_s for e in self.events)


@dataclasses.dataclass(frozen=True)
class RefreshEvent:
    """One committed topology re-admission."""

    burst: int
    n_moved: int
    cost_s: float
    predicted_saving_s: float       # per fold interval


class TopologyRefresher:
    """Online topology re-admission from measured page touches.

    The topology twin of `ShardRebalancer`: a `TieredTopologyStore` built
    with `admission="adaptive"` records every hop's touched edge pages into
    its own `TouchTable`; every `interval` priced bursts this controller
    folds the table and asks the store for a refreshed placement
    (`plan_refresh`) — measured-hot pages promoted into the GPU/host
    budgets, cold residents demoted to keep the budgets exact.  Promotion
    reads are priced through the same hop model the sampler pays, and the
    plan commits only when the modelled per-interval read-time saving over
    the horizon exceeds that cost.  Committed costs amortize into
    subsequent bursts like shard migrations."""

    def __init__(self, topo, *, interval: int = 8, horizon: int = 32,
                 cooldown: int = 2):
        if getattr(topo, "touches", None) is None:
            raise ValueError(
                "TopologyRefresher needs a feedback-enabled store — build "
                "it with admission='adaptive'")
        if interval < 1:
            raise ValueError(f"feedback interval must be >= 1, "
                             f"got {interval}")
        self.topo = topo
        self.interval = int(interval)
        self.horizon = int(horizon)
        self.cooldown = int(cooldown)
        self.debt = AmortizedCost(horizon)
        self.events: list[RefreshEvent] = []
        self.tracer = NULL_TRACER
        self._bursts = 0
        self._cooldown = 0

    def step(self) -> float:
        self._bursts += 1
        if self._bursts % self.interval == 0:
            self._consider()
        return self.debt.charge()

    def _consider(self) -> None:
        if self._cooldown > 0:
            self._cooldown -= 1
            self.topo.touches.fold()
            return
        plan = self.topo.plan_refresh()
        if plan is None:
            return
        assignment, n_moved, cost, saving = plan
        if saving * self.horizon <= cost:
            return
        self.topo.commit_refresh(assignment)
        self.debt.add(cost)
        self._cooldown = self.cooldown
        self.events.append(RefreshEvent(
            burst=self._bursts, n_moved=int(n_moved), cost_s=float(cost),
            predicted_saving_s=float(saving)))
        self.tracer.instant(
            "topo_refresh", track="controller", cat="controller",
            burst=self._bursts, n_moved=int(n_moved), cost_s=float(cost),
            predicted_saving_s=float(saving))
        self.tracer.metrics.counter("controller.refreshes").inc()
        self.tracer.metrics.counter("controller.refresh_cost_s").inc(
            float(cost))

    @property
    def n_refreshes(self) -> int:
        return len(self.events)


class QuotaController:
    """Online re-partitioning of per-tenant cache quotas from measured miss
    traffic.

    Watches a `TenantCacheTier`'s cumulative per-tenant hit/access counters
    (the same `hit_ratio(tenant)` telemetry `ServeResult` now rolls up);
    every `interval` served windows it computes each tenant's share of the
    interval's MISSES — the demand signal: a tenant missing a lot either
    has a working set its quota can't hold or traffic its partition can't
    absorb — EMA-smooths it, floors every tenant at `floor` so a quiet
    tenant is never squeezed to zero, and calls
    `TenantCacheTier.repartition` when the smoothed target moves any quota
    by more than `deadband`.  The dead band plus EMA keep the controller
    tracking demand shifts instead of chasing noise (repartitioning rebuilds
    partitions cold, a real cost paid in subsequent misses)."""

    def __init__(self, tier, *, interval: int = 8, floor: float = 0.05,
                 alpha: float = 0.5, deadband: float = 0.05):
        if getattr(tier, "tenants", 1) < 2:
            raise ValueError("quota control needs at least two tenants")
        if not 0.0 < floor < 1.0 / tier.tenants:
            raise ValueError(
                f"floor {floor} must be in (0, 1/{tier.tenants}) so every "
                "tenant keeps a positive share with room to differentiate")
        self.tier = tier
        self.interval = int(interval)
        self.floor = float(floor)
        self.alpha = float(alpha)
        self.deadband = float(deadband)
        total = sum(tier.quotas)
        self.demand = np.array([q / total for q in tier.quotas], np.float64)
        self.events: list[tuple[int, tuple[float, ...]]] = []
        self._windows = 0
        self._snap = self._counters()
        self.tracer = NULL_TRACER

    def _counters(self) -> list[tuple[int, int]]:
        return [(c.stats.hits, c.stats.accesses)
                for c in self.tier.partitions]

    def step(self) -> bool:
        """One tick per served window; True iff a repartition committed."""
        self._windows += 1
        if self._windows % self.interval:
            return False
        now = self._counters()
        misses = np.array([(a1 - a0) - (h1 - h0)
                           for (h0, a0), (h1, a1)
                           in zip(self._snap, now)], np.float64)
        self._snap = now
        total = misses.sum()
        if total <= 0:
            return False
        self.demand = (1.0 - self.alpha) * self.demand \
            + self.alpha * (misses / total)
        share = self.demand / self.demand.sum()
        t = self.tier.tenants
        target = self.floor + (1.0 - t * self.floor) * share
        cur_total = sum(self.tier.quotas)
        current = np.array([q / cur_total for q in self.tier.quotas])
        if np.abs(target - current).max() < self.deadband:
            return False
        quotas = tuple(float(q) for q in target)
        self.tier.repartition(quotas)
        self.events.append((self._windows, quotas))
        self.tracer.instant(
            "quota_repartition", track="controller", cat="controller",
            window=self._windows, quotas=list(quotas))
        self.tracer.metrics.counter("controller.repartitions").inc()
        return True

    @property
    def n_repartitions(self) -> int:
        return len(self.events)
