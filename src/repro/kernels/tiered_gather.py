"""Pallas TPU kernel: two-tier feature gather (the GIDS aggregation hot-spot).

The paper's feature-aggregation kernel lets each GPU thread fetch one feature
vector from the BaM software cache or (on miss) from an NVMe request buffer.
TPU adaptation: there are no per-thread random accesses; instead the gather
over the HBM-resident cache + host-staged miss buffer is expressed as a
scalar-prefetch gather — request slot ids are known before the block runs, so
the kernel can issue the cache-row DMAs itself.  The paper's
thread-per-request access pattern becomes TPU-native double-buffered row DMA
(HBM->VMEM) with the slot table prefetched to SMEM.

The request dimension is *blocked* (FastGL-style): each grid step serves
`block_b` request rows, so the pipelined staged/out DMAs move `(block_b, bd)`
tiles instead of `(1, bd)` slivers and the per-row cache DMAs overlap each
other inside the step.  `block_b=1` degenerates to the original
one-row-per-step layout (same grid, same DMA shapes) and all block sizes are
bit-identical — blocking changes the transfer schedule, never the bytes.

Inputs
  slots:   (B,)  int32; >= 0 -> row in `cache`; -1 -> row i of `staged`
  cache:   (L, D) feature cache rows resident in HBM
  staged:  (B, D) host-staged rows (miss path; row i used iff slots[i] < 0)
Output
  out:     (B, D)

Grid: (B // block_b, D // bd) after padding — `block_b` request rows per grid
step, feature dim blocked so a tile always fits VMEM (bd aligned to the
128-lane VPU width).  The staged tile streams through the automatic pipeline;
cache rows are gathered by explicit per-row async copies (slot indices come
from the prefetched slot table) into a VMEM scratch tile, then a per-row
select merges the two — branch-free next to the DMAs.  Ragged extents clamp
instead of asserting: `D % block_d != 0` shrinks the feature block to a
divisor of D (padding D would copy the whole cache), and a ragged request
dim is padded with -1 slots and sliced back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _row_dma(cache_hbm, scratch, sems, slot, r, j, bd):
    """The (1, bd) cache-row copy for block row `r` — built identically at
    start and wait time (the descriptor is recreated, the semaphore pairs
    the two halves)."""
    return pltpu.make_async_copy(
        cache_hbm.at[pl.ds(slot, 1), pl.ds(j * bd, bd)],
        scratch.at[pl.ds(r, 1), :],
        sems.at[r],
    )


def _kernel(slots_pf, cache_hbm, staged_blk, out_ref, scratch, sems, *,
            block_b: int, bd: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    base = i * block_b
    # launch every row DMA before waiting on any: the copies overlap each
    # other and the staged tile's pipeline DMA.  -1 slots clamp to row 0 —
    # a valid, discarded read keeps the schedule branch-free.
    for r in range(block_b):
        slot = jnp.maximum(slots_pf[base + r], 0)
        _row_dma(cache_hbm, scratch, sems, slot, r, j, bd).start()
    for r in range(block_b):
        slot = jnp.maximum(slots_pf[base + r], 0)
        _row_dma(cache_hbm, scratch, sems, slot, r, j, bd).wait()
    rows = jax.lax.broadcasted_iota(jnp.int32, (block_b, 1), 0) + base
    use_cache = slots_pf[rows] >= 0
    out_ref[...] = jnp.where(use_cache, scratch[...], staged_blk[...])


def _single_row_kernel(slots_pf, cache_blk, staged_blk, out_ref):
    i = pl.program_id(0)
    use_cache = slots_pf[i] >= 0
    out_ref[...] = jnp.where(use_cache, cache_blk[...], staged_blk[...])


def _unique_row_kernel(inv_pf, slots_pf, cache_blk, staged_blk, out_ref):
    i = pl.program_id(0)
    use_cache = slots_pf[inv_pf[i]] >= 0
    out_ref[...] = jnp.where(use_cache, cache_blk[...], staged_blk[...])


def _single_row_call(slots, cache, staged, bd, interpret):
    """The original one-request-per-step layout (`block_b=1`): the BlockSpec
    `index_map` itself selects which cache row to DMA, so the automatic
    pipeline double-buffers the (1, bd) row copies."""
    B, = slots.shape
    _, D = cache.shape

    def cache_index(i, j, slots_pf):
        return (jnp.maximum(slots_pf[i], 0), j)  # clamp: -1 rows unused

    def staged_index(i, j, slots_pf):
        del slots_pf
        return (i, j)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, D // bd),
        in_specs=[
            pl.BlockSpec((1, bd), cache_index),
            pl.BlockSpec((1, bd), staged_index),
        ],
        out_specs=pl.BlockSpec((1, bd), staged_index),
    )
    return pl.pallas_call(
        _single_row_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), staged.dtype),
        interpret=interpret,
        name="tiered_gather",
    )(slots, cache, staged)


def _blocked_call(slots, cache, staged, bb, bd, interpret):
    """Row-blocked layout (`block_b>1`): staged/out stream as (bb, bd) tiles,
    cache rows are gathered by explicit in-kernel DMAs from HBM."""
    B, = slots.shape
    _, D = cache.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B // bb, D // bd),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),     # cache stays in HBM
            pl.BlockSpec((bb, bd), lambda i, j, s: (i, j)),
        ],
        out_specs=pl.BlockSpec((bb, bd), lambda i, j, s: (i, j)),
        scratch_shapes=[pltpu.VMEM((bb, bd), staged.dtype),
                        pltpu.SemaphoreType.DMA((bb,))],
    )
    return pl.pallas_call(
        functools.partial(_kernel, block_b=bb, bd=bd),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), staged.dtype),
        interpret=interpret,
        name="tiered_gather",
    )(slots, cache, staged)


def _pad_to(x: jax.Array, axis: int, size: int, value=0) -> jax.Array:
    short = size - x.shape[axis]
    if short == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, short)
    return jnp.pad(x, widths, constant_values=value)


def tiered_gather(slots: jax.Array, cache: jax.Array, staged: jax.Array,
                  *, block_b: int | None = None, block_d: int = 512,
                  interpret: bool = False) -> jax.Array:
    if block_b is None:
        # the blocked layout's Mosaic lowering (in-kernel DMA from an
        # ANY-space ref) hasn't run on a device yet: compiled TPU calls
        # default to the proven single-row layout until it has (ROADMAP:
        # TPU validation); pass block_b explicitly to opt in
        compiled_tpu = not interpret and jax.default_backend() == "tpu"
        block_b = 1 if compiled_tpu else 8
    B, = slots.shape
    L, D = cache.shape
    assert staged.shape == (B, D), (staged.shape, B, D)
    bd = min(block_d, D)
    bb = min(block_b, B)

    # ragged feature dim: shrink the block to a divisor of D when a usable
    # one exists — padding D would copy the whole (L, D) cache, the largest
    # array in the data plane, on every call.  Only a pathological D (no
    # divisor >= 128 below block_d) falls back to the padded copy.
    if D % bd != 0:
        div = next(d for d in range(bd, 0, -1) if D % d == 0)
        if div >= min(128, D):
            bd = div

    # remaining ragged edges: pad the request dim with -1 slots (staged
    # zeros pass through the select) and, on the fallback only, the feature
    # dim with zero columns; the result is sliced back — clamping to the
    # real extents instead of asserting divisibility.
    Bp = -(-B // bb) * bb
    Dp = -(-D // bd) * bd
    slots_p = _pad_to(jnp.asarray(slots, jnp.int32), 0, Bp, value=-1)
    staged_p = _pad_to(_pad_to(staged, 1, Dp), 0, Bp)
    cache_p = _pad_to(cache, 1, Dp)

    if bb == 1:
        out = _single_row_call(slots_p, cache_p, staged_p, bd, interpret)
    else:
        out = _blocked_call(slots_p, cache_p, staged_p, bb, bd, interpret)
    if (Bp, Dp) != (B, D):
        out = out[:B, :D]
    return out


def _unique_single_row_call(inverse, slots, cache, staged_u, bd, interpret):
    """Expanded one-row-per-step layout over DEDUPED inputs: the scalar-
    prefetched inverse index redirects both the cache-row DMA and the staged
    tile to the output row's *unique* request, so the kernel consumes (U, bd)
    staged tiles while writing the (N, bd) expanded output."""
    N, = inverse.shape
    _, D = cache.shape

    def cache_index(i, j, inv_pf, slots_pf):
        return (jnp.maximum(slots_pf[inv_pf[i]], 0), j)

    def staged_index(i, j, inv_pf, slots_pf):
        del slots_pf
        return (inv_pf[i], j)

    def out_index(i, j, inv_pf, slots_pf):
        del inv_pf, slots_pf
        return (i, j)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(N, D // bd),
        in_specs=[
            pl.BlockSpec((1, bd), cache_index),
            pl.BlockSpec((1, bd), staged_index),
        ],
        out_specs=pl.BlockSpec((1, bd), out_index),
    )
    return pl.pallas_call(
        _unique_row_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, D), staged_u.dtype),
        interpret=interpret,
        name="tiered_gather_unique",
    )(inverse, slots, cache, staged_u)


def tiered_gather_unique(slots: jax.Array, cache: jax.Array,
                         staged: jax.Array, inverse: jax.Array,
                         *, block_b: int | None = None, block_d: int = 512,
                         interpret: bool = False) -> jax.Array:
    """Gather-from-unique-rows indirection for the merged-window executor.

    `slots`/`staged` cover the window's U *unique* requests (each unique row
    staged once — the storage dedup carried onto the device); `inverse` (N,)
    maps every original request to its unique row.  Returns the (N, D)
    expanded gather, bit-identical to
    `tiered_gather(slots[inverse], cache, staged[inverse])` without ever
    materializing the duplicated staged buffer.

    The single-row layout threads `inverse` through the BlockSpec index maps
    (the expansion is pure DMA scheduling); the row-blocked layout gathers
    the unique rows once through the blocked kernel and expands with one
    HBM-local take."""
    U, = slots.shape
    L, D = cache.shape
    assert staged.shape == (U, D), (staged.shape, U, D)
    if block_b is None:
        compiled_tpu = not interpret and jax.default_backend() == "tpu"
        block_b = 1 if compiled_tpu else 8
    if min(block_b, U) > 1:
        uniq = tiered_gather(slots, cache, staged, block_b=block_b,
                             block_d=block_d, interpret=interpret)
        return jnp.take(uniq, inverse, axis=0)

    bd = min(block_d, D)
    if D % bd != 0:
        div = next(d for d in range(bd, 0, -1) if D % d == 0)
        if div >= min(128, D):
            bd = div
    Dp = -(-D // bd) * bd
    out = _unique_single_row_call(
        jnp.asarray(inverse, jnp.int32), jnp.asarray(slots, jnp.int32),
        _pad_to(cache, 1, Dp), _pad_to(staged, 1, Dp), bd, interpret)
    if Dp != D:
        out = out[:, :D]
    return out


def frontier_gather(page_slots: jax.Array, hot_pages: jax.Array,
                    staged_pages: jax.Array, inverse: jax.Array,
                    offsets: jax.Array, *, block_b: int | None = None,
                    block_d: int = 512, interpret: bool = False) -> jax.Array:
    """Tiered-frontier gather for GPU-initiated sampling
    (core/topology.py): fetch each unique 4 KB edge *page* a hop touched
    exactly once through the tiered row kernel — HBM-resident hot pages via
    their slot DMA, the rest from the staged (host/storage) fallback — then
    extract each sampled read's neighbor word.

    `page_slots` (P,) index `hot_pages` (H, W) or -1 for staged row i of
    `staged_pages` (P, W); `inverse` (N,) maps each of the hop's N edge
    reads to its page, `offsets` (N,) to its word within the page.  The
    page fetch IS `tiered_gather` (pages are feature-rows of width W =
    page_words), so the validated single-row/blocked DMA layouts carry
    over unchanged; the word extraction is one vectorized take."""
    pages = tiered_gather(page_slots, hot_pages, staged_pages,
                          block_b=block_b, block_d=block_d,
                          interpret=interpret)
    return pages[inverse, offsets]


tiered_gather_cpu = functools.partial(tiered_gather, interpret=True)
tiered_gather_unique_cpu = functools.partial(tiered_gather_unique,
                                             interpret=True)
frontier_gather_cpu = functools.partial(frontier_gather, interpret=True)
