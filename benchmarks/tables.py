"""Tables 1/2/3/4 — system + dataset registries echoed for the record, and
the kernel microbenchmarks (tiered gather / segment mean vs oracles)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.graph import datasets as D


def main():
    for spec in (D.OGBN_PAPERS100M, D.IGB_FULL, D.MAG240M, D.IGBH_FULL):
        row(f"table2_{spec.name}", 0.0,
            f"nodes={spec.num_nodes}_edges={spec.num_edges}"
            f"_dim={spec.feature_dim}_hetero={spec.heterogeneous}"
            f"_feature_TB={spec.feature_bytes/1e12:.2f}")
    for spec in (D.IGB_TINY, D.IGB_SMALL, D.IGB_MEDIUM, D.IGB_LARGE):
        row(f"table3_{spec.name}", 0.0,
            f"nodes={spec.num_nodes}_edges={spec.num_edges}"
            f"_exec_nodes={spec.exec_nodes}")

    # kernel micro-bench (interpret mode on CPU: correctness-speed only)
    from repro.kernels import ops, ref
    rng = np.random.default_rng(0)
    slots = jnp.asarray(rng.integers(-1, 4096, 1024), jnp.int32)
    cache = jnp.asarray(rng.standard_normal((4096, 1024)), jnp.float32)
    staged = jnp.asarray(rng.standard_normal((1024, 1024)), jnp.float32)
    t_k = timeit(lambda: ops.tiered_gather(slots, cache, staged)
                 .block_until_ready(), iters=3)
    t_r = timeit(lambda: ops.tiered_gather(slots, cache, staged,
                                           use_pallas=False)
                 .block_until_ready(), iters=3)
    row("kernel_tiered_gather", t_k * 1e6,
        f"interpret_vs_oracle={t_k/t_r:.1f}x_rows=1024_dim=1024")

    idx = jnp.asarray(rng.integers(0, 4096, (512, 10)), jnp.int32)
    t_k = timeit(lambda: ops.segment_mean(idx, cache).block_until_ready(),
                 iters=3)
    t_r = timeit(lambda: ops.segment_mean(idx, cache, use_pallas=False)
                 .block_until_ready(), iters=3)
    row("kernel_segment_mean", t_k * 1e6,
        f"interpret_vs_oracle={t_k/t_r:.1f}x_dst=512_fanout=10")


if __name__ == "__main__":
    main()
