"""Observability-plane benchmark + CI gate (ISSUE 10).

Runs the merged training plane and the multi-tenant serve plane with an
ENABLED tracer and pins the three properties that make tracing safe to
leave on:

  * **bit-invisibility** — prep floats, sampled blocks, and gathered
    bytes are exactly equal to an untraced run of the same config;
  * **span-sum reconciliation** — every batch span tree sums to its
    `Batch.prep_time_s` (and serve request spans to end-to-end latency)
    within float eps;
  * **valid export** — the merged-window trace renders as well-formed
    Chrome trace-event JSON (nested spans, monotone per-track starts),
    loadable in Perfetto.

`export()` writes the Perfetto artifact (`trace.json`) and the metrics
snapshot (`metrics.json`) that `benchmarks/run.py --trace` publishes from
CI; `headline()` returns the gate booleans for BENCH_*.json.
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import row
from repro.core import GIDSDataLoader, LoaderConfig, SAMSUNG_980PRO
from repro.graph.synthetic import rmat_graph
from repro.obs import Tracer, validate_trace

RECONCILE_EPS = 1e-9


def _graph_and_feats(num_nodes: int = 20_000, seed: int = 1):
    g = rmat_graph(num_nodes, 12, 32, seed=seed)
    feats = np.random.default_rng(0).standard_normal(
        (g.num_nodes, 32)).astype(np.float32)
    return g, feats


def _loader(g, feats, tracer=None, preset: str = "gids-topo-merged"):
    return GIDSDataLoader(g, feats, LoaderConfig(
        batch_size=256, fanouts=(10, 5), data_plane=preset,
        cache_lines=4096, window_depth=4, seed=3),
        ssd=SAMSUNG_980PRO, tracer=tracer)


def traced_run(iters: int = 16, preset: str = "gids-topo-merged"):
    """One traced merged-window run plus its untraced twin's batches."""
    g, feats = _graph_and_feats()
    plain = _loader(g, feats, preset=preset)
    untraced = [plain.next_batch() for _ in range(iters)]
    tracer = Tracer()
    dl = _loader(g, feats, tracer=tracer, preset=preset)
    traced = [dl.next_batch() for _ in range(iters)]
    return tracer, traced, untraced


def _bit_invisible(traced, untraced) -> bool:
    for a, b in zip(traced, untraced):
        if a.prep_time_s != b.prep_time_s:
            return False
        if a.sample_time_s != b.sample_time_s:
            return False
        if not np.array_equal(a.blocks.all_nodes, b.blocks.all_nodes):
            return False
        if not np.array_equal(a.features, b.features):
            return False
    return True


def _spans_reconciled(tracer, traced) -> tuple[bool, float]:
    roots = [r for r in tracer.roots() if r.name == "batch"]
    if len(roots) != len(traced):
        return False, float("inf")
    err = max((abs(r.dur - b.prep_time_s)
               for r, b in zip(roots, traced)), default=0.0)
    err = max(err, tracer.max_reconcile_error())
    return err <= RECONCILE_EPS, err


def headline(iters: int = 16) -> dict:
    tracer, traced, untraced = traced_run(iters=iters)
    problems = validate_trace(tracer)
    events = tracer.chrome_events()
    reconciled, err = _spans_reconciled(tracer, traced)
    snap = tracer.metrics.snapshot()
    gap_points = sum(v["n"] for k, v in snap.items()
                     if k.startswith("modelled_vs_measured."))
    return {
        "tracer_bit_invisible": _bit_invisible(traced, untraced),
        "spans_reconciled": reconciled,
        "max_reconcile_error": err,
        "trace_valid": not problems,
        "n_trace_problems": len(problems),
        "n_trace_events": len(events),
        "n_batch_spans": sum(1 for r in tracer.roots()
                             if r.name == "batch"),
        "n_metric_keys": len(snap),
        "modelled_vs_measured_points": gap_points,
    }


def export(trace_path: str = "trace.json",
           metrics_path: str = "metrics.json", iters: int = 16) -> dict:
    """Write the Perfetto trace + metrics snapshot artifacts for CI and
    return the headline gate numbers computed from the same run."""
    tracer, traced, untraced = traced_run(iters=iters)
    problems = validate_trace(tracer)
    events = tracer.write(trace_path)
    snap = tracer.metrics.snapshot()
    with open(metrics_path, "w") as f:
        json.dump(snap, f, indent=2, default=float)
        f.write("\n")
    reconciled, err = _spans_reconciled(tracer, traced)
    print(f"# wrote {trace_path} ({len(events)} events) and "
          f"{metrics_path} ({len(snap)} metrics)", flush=True)
    return {
        "tracer_bit_invisible": _bit_invisible(traced, untraced),
        "spans_reconciled": reconciled,
        "max_reconcile_error": err,
        "trace_valid": not problems,
        "n_trace_problems": len(problems),
        "n_trace_events": len(events),
    }


def main():
    out = headline()
    row("trace/bit_invisible", 0.0, str(out["tracer_bit_invisible"]))
    row("trace/spans_reconciled", 0.0,
        f"max_err={out['max_reconcile_error']:.3e}")
    row("trace/valid_chrome_json", 0.0,
        f"{out['n_trace_events']} events, "
        f"{out['n_trace_problems']} problems")
    row("trace/metrics", 0.0,
        f"{out['n_metric_keys']} keys, "
        f"{out['modelled_vs_measured_points']} gap points")


if __name__ == "__main__":
    main()
