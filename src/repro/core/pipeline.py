"""GIDSDataLoader — the end-to-end data-preparation pipeline (paper Fig. 1).

The loader is a genuine *two-stage pipeline*, split so prefetch can overlap
data preparation with model compute (§3.2):

  stage 1, `plan_next()`  — sampling + admit-side staging: refill the
    lookahead deque (sampling runs `merge_depth` iterations AHEAD under the
    accumulator), push future node lists into the windowed tiers
    (`admit()`), pop the next batch's blocks, and snapshot the sampler PRNG
    for checkpoint resume.  Produces a `BatchPlan`.
  stage 2, `execute(plan)` — data movement + pricing: fold the tier stack
    over the plan's nodes into one `GatherPlan`, gather the actual feature
    rows, feed accumulator telemetry, and price the batch from its tier
    split.  Produces a `Batch`.

`next_batch()` composes the stages.  On a synchronous plane the two run
back-to-back inside the call; on a prefetching plane (`DataPlaneSpec` with
`prefetch > 0`, e.g. the `gids-async` preset) a `PrefetchEngine`
(core/prefetch.py) has already staged the next `prefetch` batches ahead of
consumption, and `next_batch(compute_s=...)` re-prices the batch's
*exposed* prep time against the model-compute seconds it overlapped
(`Batch.exposed_prep_s = max(0, prep - compute)`); the raw `prep_time_s`
and every other `Batch` field stay bit-identical to the sync plane.

On a *merged* plane (`DataPlaneSpec.merge_execute`, e.g. the `gids-merged`
preset) stage 2 runs over a whole WINDOW of plans at once
(`plan_window()` / `execute_window()`): the accumulator's merge depth stops
being a pricing assumption and becomes the executed unit.  The window's
request lists are deduplicated into a `MergedWindow`
(`np.unique(..., return_inverse=True)`), the tier stack folds ONCE over the
unique set, each unique row is gathered exactly once, storage-bound rows
sharing a 4 KB IO line coalesce into single IOs, and the window is priced
as one storage burst (`StorageTimeline.price_merged_burst`) amortized
equally across its batches.  Per-batch features are bit-identical to the
per-batch path (the inverse index scatters unique rows back); each `Batch`
carries a `CoalescedReport` — the per-batch tier split plus the window-wide
merge telemetry (`window_batches`, `window_requests`, `n_unique`,
`n_duplicate`, `n_storage_unique`, `n_storage_lines`).  With
`prefetch > 0` as well (`gids-merged-async`) the prefetch engine stages
whole merged windows ahead of consumption.

On a *sharded* plane (`gids-sharded`, `gids-merged-sharded`) the storage
backstop is a `ShardedStorageTier`: the feature namespace is partitioned
across `LoaderConfig.n_shards` SSD queues by a registered placement policy
(`LoaderConfig.placement`; core/sharding.py), every storage-bound request
carries its shard id through the `GatherPlan`, 4 KB-line coalescing is
shard-local, and pricing completes each burst at the MAX over per-shard
queue drains (`storage_sim.price_sharded_burst` — the loader wires the
tier's per-shard `SSDSpec`s into `StorageTimeline.shard_specs`, and
`timeline.shard_burst` reports the straggler shard and queue
imbalance).  Features, blocks, and per-tier counts are bit-identical to the
unsharded plane — only the storage pricing and shard telemetry change.

On a *multi-host* plane (`gids-hosts`, `gids-hosts-merged`; core/hosts.py)
the backstop is a `HostShardTier`: the same shard vocabulary at host
granularity.  Each shard is a host (`HostLinkSpec` — interconnect + local
SSD), one co-partitioned placement decision drives the feature rows AND
the CSR edge pages of every node, and each storage-bound request carries a
remote bit (serving host != requesting host) through the `GatherPlan`.
Pricing routes through `StorageTimeline.price_host_burst`: each host's
local queue drain plus the link transit of the 4 KB lines other hosts
requested from it, completing at the max over hosts.  Features, blocks,
and per-tier counts are bit-identical to the single-host plane for ANY
host count and placement — hosts change pricing and telemetry, never
bytes — and `n_hosts=1` prices bit-identically too.

On a *topology* plane (`DataPlaneSpec.topology`, presets `gids-topo` /
`gids-topo-merged`) stage 1 itself is PRICED: sampling runs against a
`TieredTopologyStore` (core/topology.py) whose CSR edge pages are placed
across GPU/host/storage tiers by a registered admission policy, each hop
emits a `TopologyGatherReport` (edge pages by tier, coalesced page IOs,
modelled hop time), and the summed sampling time folds into
`Batch.prep_time_s` — so `exposed_prep_s` finally covers the whole Fig. 1
prep path, sampling and gather.  Blocks and features stay bit-identical to
the corresponding un-tiered plane (the tiered sampler shares the host
sampler's RNG stream and math).

Other orchestration, common to both stages:

  * the accumulator recomputes the merge depth from live telemetry
    (requests/iter, redirection rate);
  * feature gathers flow through a *pluggable tier stack*
    (`TieredFeatureStore`, see core/tiers.py) folded into one gather plan
    per batch;
  * the storage timeline prices each batch from the plan's tier split
    (benchmarks); the actual bytes are returned for real training.

Which tiers exist and how time is priced is declared by a `DataPlaneSpec`
(core/dataplane.py), not by mode strings.  The paper's three baselines are
presets of the same machinery:

  LoaderConfig(data_plane="gids")   # window cache + host cbuf + storage
  LoaderConfig(data_plane="bam")    # random-eviction cache + storage
  LoaderConfig(data_plane="mmap")   # storage only, page-fault pricing

or any registered/user-composed spec:

  LoaderConfig(data_plane=DataPlaneSpec.preset("gids-async"))

The old `mode="gids"` kwarg maps onto the preset of the same name through a
deprecation shim.
"""
from __future__ import annotations

import dataclasses
import warnings
from collections import deque
from typing import Iterator, Sequence

import numpy as np

from repro.graph.csr import CSRGraph
from repro.obs import NULL_TRACER, attach_burst_spans
from repro.sampling.neighbor import host_sample_blocks, SampledBlocks
from repro.sampling.ladies import ladies_sample_blocks
from .accumulator import DynamicAccessAccumulator, AccumulatorConfig
from .dataplane import DataPlane, DataPlaneSpec
from .feature_store import GatherReport
from .feedback import ShardHealthMonitor, ShardRebalancer, TopologyRefresher
from .prefetch import PrefetchEngine
from .storage_sim import SSDSpec, StorageTimeline, INTEL_OPTANE
from .topology import TieredTopologyStore

#: Sampler names the loader knows how to drive.  `LoaderConfig` validates
#: at construction — an unknown sampler fails when the config is built, not
#: on the first batch.
SAMPLERS = ("neighbor", "ladies")


@dataclasses.dataclass
class LoaderConfig:
    batch_size: int = 4096
    fanouts: Sequence[int] = (10, 5, 5)       # 3 sampling layers (paper §4.1)
    sampler: str = "neighbor"                  # or "ladies"
    ladies_layer_sizes: Sequence[int] = (512, 512, 512)
    data_plane: DataPlaneSpec | str | None = None  # preset name or spec;
                                               # None resolves to "gids"
    window_depth: int = 8                      # paper default
    cache_lines: int = 1 << 15                 # 8GB @4KB in paper; scaled here
    cache_ways: int = 8
    cbuf_fraction: float = 0.1                 # 10% of dataset (paper default)
    cbuf_selection: str = "pagerank"
    target_efficiency: float = 0.95
    n_ssd: int = 1
    # sharded-storage planes (gids-sharded / gids-merged-sharded): how many
    # SSD shards partition the feature namespace, and which registered
    # placement policy (core/sharding.py) decides node -> shard
    n_shards: int = 1
    placement: str = "hash"
    # multi-host planes (gids-hosts / gids-hosts-merged; core/hosts.py):
    # the storage backstop partitions across n_hosts HOSTS — each with its
    # own interconnect link and local SSD — under the same placement
    # registry ("metis-lite" adds min-cut partitioning over the CSR).
    # co_partition=True (default) drives a node's feature rows AND its CSR
    # edge pages off ONE placement decision; False stripes the adjacency
    # independently (the double-network-hop baseline).  host_link overrides
    # the 100GbE default (a HostLinkSpec, or one per host)
    n_hosts: int = 1
    co_partition: bool = True
    host_link: "object | None" = None
    # topology plane (gids-topo / gids-topo-merged): fraction of the CSR
    # edge pages resident in GPU memory / pinned host memory (remainder is
    # storage-backed), and which registered admission policy
    # (core/topology.py) ranks pages into the budgets
    topo_admission: str = "degree"
    topo_gpu_fraction: float = 0.25
    topo_host_fraction: float = 0.5
    # adaptive data plane (core/feedback.py; placement="adaptive" and/or
    # topo_admission="adaptive"): every `rebalance_interval` priced bursts
    # the controllers fold measured touches and consider a re-placement —
    # shard migration when the measured queue imbalance exceeds
    # `imbalance_threshold`, topology page re-admission when measured-hot
    # pages sit in slow tiers — committing only when the modelled saving
    # over `migration_horizon` future bursts beats the move's own priced IO
    # cost, which is then amortized into subsequent batches' prep
    rebalance_interval: int = 8
    imbalance_threshold: float = 1.25
    migration_horizon: int = 64
    # fault plane (core/faults.py): a seeded FaultSchedule injected into
    # every priced storage burst — per-shard brownouts, outages, transient
    # line failures, priced retries and hedged reads.  None (the default)
    # prices bit-identically to the fault-free plane.  replication_factor
    # wraps the placement in k-way ReplicatedPlacement so failover and
    # hedges have live replica queues to go to
    fault_schedule: "object | None" = None
    replication_factor: int = 1
    seed: int = 0
    # deprecated spelling of data_plane; kept so old call sites keep running
    mode: dataclasses.InitVar[str | None] = None

    def __post_init__(self, mode: str | None) -> None:
        if self.sampler not in SAMPLERS:
            raise ValueError(
                f"unknown sampler {self.sampler!r}; known samplers: "
                f"{SAMPLERS}")
        # an explicitly-set data_plane always wins over the deprecated mode
        # kwarg: dataclasses.replace() re-feeds the shimmed `mode` read back
        # through __init__, and must not revert a data_plane change or
        # degrade a spec object to its bare name
        if self.data_plane is None:
            if mode is not None:
                warnings.warn(
                    "LoaderConfig(mode=...) is deprecated; use "
                    "data_plane=<preset name> or "
                    "data_plane=DataPlaneSpec.preset(...)",
                    DeprecationWarning, stacklevel=3)
            self.data_plane = mode if mode is not None else "gids"

    def __getattr__(self, name: str):
        # read-side half of the shim: old call sites also *read* cfg.mode
        # (the InitVar is consumed by __init__ and never stored).  No
        # deprecation warning here — dataclasses.replace() reads it on
        # every call
        if name == "mode":
            dp = self.__dict__.get("data_plane", "gids")
            return dp if isinstance(dp, str) else dp.name
        raise AttributeError(
            f"{type(self).__name__!r} object has no attribute {name!r}")


# the InitVar's class-level default (mode = None) would shadow the
# __getattr__ read shim; the generated __init__ keeps its own reference
del LoaderConfig.mode


@dataclasses.dataclass
class BatchPlan:
    """Stage-1 output: what to gather, plus the resume point.  `snapshot` is
    the sampler state *before* this batch was sampled, so a checkpoint taken
    while the batch is staged-but-unconsumed replays it deterministically."""

    blocks: SampledBlocks
    merge_depth: int
    snapshot: dict


@dataclasses.dataclass
class Batch:
    blocks: SampledBlocks
    features: np.ndarray          # rows for blocks.all_nodes
    report: GatherReport
    prep_time_s: float            # modelled data-preparation time; on a
                                  # topology plane this INCLUDES sampling
    merge_depth: int
    # modelled sampling time folded into prep_time_s (0 on planes without a
    # topology store; per-hop detail lives on blocks.hop_reports)
    sample_time_s: float = 0.0
    # critical-path prep after prefetch overlap; None at construction
    # resolves to prep_time_s (synchronous planes expose everything)
    exposed_prep_s: float | None = None

    def __post_init__(self) -> None:
        if self.exposed_prep_s is None:
            self.exposed_prep_s = self.prep_time_s


class GIDSDataLoader:
    def __init__(self, graph: CSRGraph, features: np.ndarray,
                 config: LoaderConfig | None = None,
                 ssd: SSDSpec = INTEL_OPTANE,
                 train_ids: np.ndarray | None = None,
                 tracer=None):
        self.graph = graph
        self.config = cfg = config or LoaderConfig()
        self.rng = np.random.default_rng(cfg.seed)
        self.train_ids = (train_ids if train_ids is not None
                          else np.arange(graph.num_nodes))
        self.spec = DataPlaneSpec.resolve(cfg.data_plane)
        self.plane: DataPlane = self.spec.build(graph, features, config=cfg)
        self.store = self.plane.store
        self.accumulator = DynamicAccessAccumulator(
            ssd, AccumulatorConfig(target_efficiency=cfg.target_efficiency,
                                   n_ssd=cfg.n_ssd,
                                   max_merge_iters=max(cfg.window_depth, 8)))
        self.timeline = StorageTimeline(ssd, cfg.n_ssd)
        # a sharded backstop prices per shard queue: hand the timeline the
        # per-shard device specs (heterogeneous arrays keep their own; a
        # spec-less tier inherits this loader's device on every shard)
        backstop = self.store.tiers[-1]
        if hasattr(backstop, "resolve_shard_specs"):
            if getattr(backstop, "n_shards", 1) > 1 and cfg.n_ssd > 1:
                raise ValueError(
                    f"n_ssd={cfg.n_ssd} with a {backstop.n_shards}-shard "
                    "storage tier: the legacy pooled-queue multiplier and "
                    "per-shard queues model the same devices twice — on a "
                    "sharded plane set n_shards (one queue per SSD) and "
                    "leave n_ssd=1")
            self.timeline.shard_specs = backstop.resolve_shard_specs(ssd)
        # multi-host backstop (core/hosts.py): the timeline additionally
        # needs each host's link spec — sharded bursts then price through
        # price_host_burst, composing local drains with link transit
        if hasattr(backstop, "resolve_hosts"):
            self.timeline.host_specs = backstop.resolve_hosts(ssd)
        # topology plane: sampling reads a tiered adjacency store and is
        # priced per hop (plan_next becomes a priced stage).  The store owns
        # its own StorageTimeline — the edge-page namespace drains separate
        # queues from the feature namespace
        self.topo: TieredTopologyStore | None = None
        if self.plane.topology:
            if cfg.sampler != "neighbor":
                raise ValueError(
                    f"topology plane {self.spec.name!r} requires the "
                    f"'neighbor' sampler (got {cfg.sampler!r}): LADIES "
                    "scores whole frontier columns, not page-local "
                    "adjacency reads, so its storage traffic is not "
                    "page-priceable")
            if hasattr(backstop, "topology_page_shard") \
                    and backstop.n_shards > 1:
                # co-partitioned cluster: the feature backstop's OWN host
                # table places the CSR edge pages — one placement decision
                # drives both namespaces, not two independent stripes
                topo_kwargs = dict(
                    n_shards=backstop.n_shards,
                    page_shard=backstop.topology_page_shard(),
                    shard_specs=backstop.resolve_shard_specs(ssd))
            else:
                topo_kwargs = dict(n_shards=cfg.n_shards,
                                   placement=cfg.placement)
            self.topo = TieredTopologyStore.from_graph(
                graph, admission=cfg.topo_admission,
                gpu_fraction=cfg.topo_gpu_fraction,
                host_fraction=cfg.topo_host_fraction,
                ssd=ssd, n_ssd=cfg.n_ssd, seed=cfg.seed, **topo_kwargs)
        # adaptive data plane: an adaptive placement/admission gets its
        # feedback controller (core/feedback.py).  Both tick once per priced
        # burst in _feedback_step; a static plane carries None and pays
        # nothing
        self.rebalancer: ShardRebalancer | None = None
        if hasattr(getattr(backstop, "placement", None), "plan_rebalance"):
            self.rebalancer = ShardRebalancer(
                backstop, self.timeline,
                bytes_per_row=features.shape[1] * features.dtype.itemsize,
                interval=cfg.rebalance_interval,
                threshold=cfg.imbalance_threshold,
                horizon=cfg.migration_horizon)
        self.topo_refresher: TopologyRefresher | None = None
        if self.topo is not None and self.topo.touches is not None:
            self.topo_refresher = TopologyRefresher(
                self.topo, interval=cfg.rebalance_interval,
                horizon=cfg.migration_horizon)
        # fault plane (core/faults.py): schedule-driven burst re-pricing,
        # per-shard health telemetry, and replica failover routing.  All
        # three stay None on a fault-free, unreplicated plane — which is
        # what keeps every default preset bit-identical.
        self.fault_injector = None
        self.health: ShardHealthMonitor | None = None
        n_queue_shards = getattr(backstop, "n_shards", 1)
        if cfg.replication_factor > 1 \
                and not hasattr(backstop, "placement"):
            raise ValueError(
                f"replication_factor={cfg.replication_factor} needs a "
                "sharded storage backstop (a *-sharded data plane with "
                "n_shards >= 2) — the unsharded plane has no replica "
                "queues to fail over to")
        if cfg.fault_schedule is not None:
            from .faults import FaultInjector
            self.fault_injector = FaultInjector(
                cfg.fault_schedule, n_queue_shards,
                replication=cfg.replication_factor)
            self.timeline.injector = self.fault_injector
            if self.topo is not None:
                # the topology namespace drains its own queues, so it gets
                # its OWN injector (independent burst counter) over the
                # same schedule: edge-page reads see brownouts/outages too
                self.topo.timeline.injector = FaultInjector(
                    cfg.fault_schedule, self.topo.n_shards)
        if n_queue_shards > 1 and (cfg.fault_schedule is not None
                                   or cfg.replication_factor > 1):
            self.health = ShardHealthMonitor(n_queue_shards)
            if self.rebalancer is not None:
                self.rebalancer.monitor = self.health
        if cfg.replication_factor > 1:
            from .faults import FailoverRouter
            backstop.router = FailoverRouter(
                backstop.placement, monitor=self.health,
                injector=self.fault_injector)
        self._lookahead: deque[tuple[dict, SampledBlocks]] = deque()
        self._win_idx = 0   # lookahead entries already pushed to cache window
        # merged-window planes stage whole executed windows here (snapshot
        # kept per batch so a checkpoint mid-window resumes that batch)
        self._merged_ready: deque[tuple[dict, Batch]] = deque()
        self._requests_per_iter = 0
        self.prefetch = (PrefetchEngine(self, self.plane.prefetch_depth)
                         if self.plane.prefetch_depth > 0 else None)
        # observability plane (repro.obs): off by default through the shared
        # no-op tracer.  An enabled tracer observes stage timings, builds
        # per-batch span trees, and receives burst/controller telemetry in
        # its MetricsRegistry — but never feeds back into sampling or
        # pricing, so features, blocks, and every priced float are
        # bit-identical either way.
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._batch_index = 0
        self._window_index = 0
        if self.tracer.enabled:
            self.timeline.metrics = self.tracer.metrics
            if self.topo is not None:
                self.topo.timeline.metrics = self.tracer.metrics
            if self.rebalancer is not None:
                self.rebalancer.tracer = self.tracer
            if self.topo_refresher is not None:
                self.topo_refresher.tracer = self.tracer
            if hasattr(backstop, "record_metrics"):
                # static cluster telemetry (cut fraction, expected remote
                # share) — computed once, it never changes without a commit
                backstop.record_metrics(self.tracer.metrics)

    # -- sampling -------------------------------------------------------------
    def _sample_one(self) -> SampledBlocks:
        cfg = self.config
        seeds = self.rng.choice(self.train_ids, size=cfg.batch_size,
                                replace=len(self.train_ids) < cfg.batch_size)
        if cfg.sampler == "neighbor":
            if self.topo is not None:
                # same math, same RNG stream — blocks bit-identical to the
                # host sampler, plus per-hop priced TopologyGatherReports
                from repro.sampling.tiered import tiered_sample_blocks
                return tiered_sample_blocks(self.graph, self.topo, seeds,
                                            cfg.fanouts, self.rng,
                                            tracer=self.tracer)
            return host_sample_blocks(self.graph, seeds, cfg.fanouts, self.rng)
        elif cfg.sampler == "ladies":
            return ladies_sample_blocks(self.graph, seeds,
                                        cfg.ladies_layer_sizes, self.rng)
        raise ValueError(cfg.sampler)

    def _refill_lookahead(self) -> int:
        """Run sampling ahead until the accumulator's merge depth is covered.
        Planes without lookahead (mmap) sample synchronously, depth 1; a
        windowed tier floors the depth at its window size.  A merged plane
        samples one cache-window PAST the merge window, so the merged access
        can pin its fills by the NEXT window's reuse (the per-batch path
        gets the same look-ahead one batch at a time)."""
        if not self.plane.lookahead:
            depth = 1
        else:
            depth = self.accumulator.merge_depth(
                max(self._requests_per_iter, 1))
            depth = max(depth, self.plane.min_lookahead)
        sample_ahead = depth
        if self.plane.merge_execute:
            sample_ahead = depth + self.plane.min_lookahead
        while len(self._lookahead) < sample_ahead:
            # snapshot the sampler PRNG before sampling so a checkpoint
            # resumes at the logical consumption point, not the sampling
            # frontier (the lookahead queue is rebuilt deterministically)
            snap = {"rng": self.rng.bit_generator.state,
                    "requests_per_iter": self._requests_per_iter}
            self._lookahead.append((snap, self._sample_one()))
        self._sync_window()
        return depth

    def _sync_window(self) -> None:
        """Keep the windowed tier's look-ahead = first `window_depth`
        lookahead entries.  The lookahead may run deeper than the window
        (accumulator merge depth > window depth); extra batches are
        sampled-ahead only."""
        wt = self.store.windowed_tier
        if wt is None or wt.window_depth == 0:
            return
        while (len(wt.window) < wt.window_depth
               and self._win_idx < len(self._lookahead)):
            self.store.push_window(
                self._lookahead[self._win_idx][1].all_nodes)
            self._win_idx += 1

    # -- the two pipeline stages ----------------------------------------------
    def plan_next(self) -> BatchPlan:
        """Stage 1: sampling + admit-side staging.  Refills the lookahead
        (sampling ahead, window admits), pops the next batch's blocks."""
        with self.tracer.stage("plan_next") as sp:
            depth = self._refill_lookahead()
            snap, blocks = self._lookahead.popleft()
            self._win_idx = max(0, self._win_idx - 1)
            self._requests_per_iter = blocks.num_requests
            sp.modelled(float(getattr(blocks, "sample_time_s", 0.0)))
        return BatchPlan(blocks=blocks, merge_depth=depth, snapshot=snap)

    def execute(self, plan: BatchPlan) -> Batch:
        """Stage 2: data movement + pricing.  Folds the tier stack over the
        plan's nodes, gathers the rows, prices the tier split."""
        blocks = plan.blocks
        with self.tracer.stage("execute") as sp:
            rows, report = self.store.gather(blocks.all_nodes)
            self.accumulator.update(report.n_requests, report.redirected)

            outstanding = self.accumulator.outstanding(blocks.num_requests)
            prev_burst = self.timeline.shard_burst
            gather_s = self.plane.price(self.timeline, report, outstanding)
            charge = self._feedback_step(blocks.all_nodes, None)
            t = gather_s + charge
            # a topology plane priced the sampling stage when the blocks were
            # drawn (plan_next); prep now covers the full Fig. 1 path
            sample_s = float(getattr(blocks, "sample_time_s", 0.0))
            sp.modelled(t + sample_s)
            if self.tracer.enabled:
                self._trace_batch(blocks, report, gather_s, charge,
                                  t + sample_s, prev_burst)
        return Batch(blocks=blocks, features=rows, report=report,
                     prep_time_s=t + sample_s, merge_depth=plan.merge_depth,
                     sample_time_s=sample_s)

    def _feedback_step(self, node_ids: np.ndarray,
                       counts: np.ndarray | None) -> float:
        """One adaptive-plane tick per priced burst: record the burst's
        measured node touches, let each controller consider a (priced)
        re-placement, and return the burst's amortized share of any
        committed migration cost — folded into prep, so adaptive-vs-static
        comparisons are net of migration IOs.  A static plane returns 0.0
        without touching a thing."""
        charge = 0.0
        if self.health is not None \
                and self.timeline.shard_burst is not None:
            # the monitor sees every priced burst's per-shard drains —
            # detection is a function of priced telemetry, nothing else
            self.health.observe(self.timeline.shard_burst)
        if self.rebalancer is not None:
            self.rebalancer.observe(node_ids, counts)
            charge += self.rebalancer.step()
        if self.topo_refresher is not None:
            charge += self.topo_refresher.step()
        return charge

    # -- span-tree construction (enabled tracer only) --------------------------
    def _trace_hops(self, root, blocks) -> None:
        for r in getattr(blocks, "hop_reports", ()):
            hbm, host, sto = r.pages_by_tier
            root.child(f"sample/hop{r.hop}", float(r.time_s), cat="sample",
                       edge_reads=r.n_edge_reads, frontier=r.n_frontier,
                       pages_hbm=hbm, pages_host=host, pages_storage=sto)

    def _trace_batch(self, blocks, report, gather_s: float, charge: float,
                     prep_s: float, prev_burst, window: int | None = None
                     ) -> None:
        """One per-batch virtual span tree: root duration is exactly
        `Batch.prep_time_s`, sequential children partition it into the
        per-hop sampling, the priced gather, and any feedback charge;
        per-shard/per-host drains (and fault recovery sub-events) overlay
        the gather span on their own tracks."""
        tr = self.tracer
        args = {"index": self._batch_index, "requests": report.n_requests}
        if window is not None:
            args["window"] = window
        root = tr.batch("batch", track="pipeline", **args)
        self._trace_hops(root, blocks)
        g = root.child("gather", float(gather_s), cat="gather",
                       n_storage=report.n_storage,
                       n_host=report.n_host_hits, n_hbm=report.n_hbm_hits)
        burst = self.timeline.shard_burst
        if burst is not None and burst is not prev_burst:
            attach_burst_spans(g, burst)
        if charge:
            root.child("feedback", float(charge), cat="feedback")
        root.close(float(prep_s))
        self._record_batch_metrics(blocks, gather_s, charge, prep_s)
        self._batch_index += 1

    def _trace_window(self, plans, window_report, gather_s: float,
                      charge: float, burst_s: float, prev_burst) -> None:
        """A merged window's spans: one window-level span (merged gather +
        feedback, on its own track) whose duration is the window's total
        priced burst, plus one batch tree per plan whose gather child is the
        batch's amortized share of that burst."""
        tr = self.tracer
        win = tr.batch("window", track="window", cat="window",
                       index=self._window_index, batches=len(plans),
                       requests=window_report.window_requests,
                       unique=window_report.n_unique)
        g = win.child("merged_gather", float(gather_s), cat="gather",
                      n_storage=window_report.n_storage,
                      n_lines=window_report.n_storage_lines,
                      n_host=window_report.n_host_hits,
                      n_hbm=window_report.n_hbm_hits)
        burst = self.timeline.shard_burst
        if burst is not None and burst is not prev_burst:
            attach_burst_spans(g, burst)
        if charge:
            win.child("feedback", float(charge), cat="feedback")
        win.close(float(burst_s))
        m = tr.metrics
        if window_report.n_unique:
            m.histogram("merged.dedup_factor").observe(
                window_report.window_requests / window_report.n_unique)
        if window_report.n_storage_lines:
            m.histogram("merged.coalesce_factor").observe(
                window_report.n_storage_unique
                / window_report.n_storage_lines)
        prep = burst_s / len(plans)
        for p in plans:
            sample_s = float(getattr(p.blocks, "sample_time_s", 0.0))
            root = tr.batch("batch", track="pipeline",
                            index=self._batch_index,
                            window=self._window_index)
            self._trace_hops(root, p.blocks)
            root.child("gather_share", float(prep), cat="gather",
                       window=self._window_index)
            root.close(float(prep + sample_s))
            self._record_batch_metrics(p.blocks, prep, 0.0, prep + sample_s)
            self._batch_index += 1
        self._window_index += 1

    def _record_batch_metrics(self, blocks, gather_s: float, charge: float,
                              prep_s: float) -> None:
        """Fold one batch's per-stage priced seconds and the tier stack's
        cumulative hit telemetry into the registry (benchmarks/roofline.py
        decomposes the Fig. 1 prep path from exactly these counters)."""
        from .tiers import record_tier_metrics
        m = self.tracer.metrics
        m.counter("pipeline.batches").inc()
        m.counter("stage_s.sample").inc(
            float(getattr(blocks, "sample_time_s", 0.0)))
        m.counter("stage_s.gather").inc(float(gather_s))
        m.counter("stage_s.feedback").inc(float(charge))
        m.counter("stage_s.prep").inc(float(prep_s))
        record_tier_metrics(self.store.tiers, m)

    # -- merged-window execution ------------------------------------------------
    def plan_window(self) -> list[BatchPlan]:
        """Stage 1 for a whole merged window: plan `merge_depth` consecutive
        batches (the depth the first plan's accumulator decision reports —
        the lookahead already holds that many staged samples).  Each plan
        keeps its own resume snapshot, so a checkpoint mid-window restores
        the exact unconsumed batch."""
        plans = [self.plan_next()]
        if self.plane.merge_execute:
            while len(plans) < plans[0].merge_depth:
                plans.append(self.plan_next())
        return plans

    def execute_window(self, plans: Sequence[BatchPlan]) -> list[Batch]:
        """Stage 2 for a merged window: dedupe the plans' request lists into
        one `MergedWindow`, fold the tier stack once over the unique set,
        gather each unique row exactly once, scatter rows back per batch via
        the inverse index, and price the whole window as one line-coalesced
        storage burst amortized equally across its batches.

        Features are bit-identical to `execute()` run per plan; the reports
        (tier telemetry) and modelled times differ — that difference IS the
        modelled speedup of the §3.2 merge."""
        with self.tracer.stage("execute_window", n_plans=len(plans)) as sp:
            merged = self.accumulator.merge(
                [p.blocks.all_nodes for p in plans])
            # retire the consumed window entries and stage the NEXT window's
            # into the freed slots: the one merged access then consumes this
            # window's reuse reservations (multiplicity decrements) while its
            # fills pin lines the upcoming window will reuse
            self.store.retire_window(len(plans))
            self._sync_window()
            rows_list, reports, window_report = \
                self.store.gather_merged(merged)
            # one telemetry update per window: the merged burst's unique
            # split (what actually reached storage), not per-batch raw counts
            self.accumulator.update(window_report.n_requests,
                                    window_report.redirected)
            prev_burst = self.timeline.shard_burst
            gather_s = self.timeline.price_merged_burst(window_report)
            # the window is one priced burst, so it is one feedback tick:
            # the unique request set (with window multiplicity) is what the
            # plane measured, and any migration charge amortizes across the
            # window's batches exactly like the burst itself
            charge = self._feedback_step(merged.unique_nodes,
                                         merged.batch_multiplicity())
            burst_s = gather_s + charge
            sp.modelled(burst_s)
            if self.tracer.enabled:
                self._trace_window(plans, window_report, gather_s, charge,
                                   burst_s, prev_burst)
            prep = burst_s / len(plans)
            # each batch's own priced sampling time rides on top of its
            # amortized share of the window's feature burst
            out = []
            for p, rows, rep in zip(plans, rows_list, reports):
                sample_s = float(getattr(p.blocks, "sample_time_s", 0.0))
                out.append(Batch(blocks=p.blocks, features=rows, report=rep,
                                 prep_time_s=prep + sample_s,
                                 merge_depth=len(plans),
                                 sample_time_s=sample_s))
        return out

    # -- iteration -------------------------------------------------------------
    def __iter__(self) -> Iterator[Batch]:
        while True:
            yield self.next_batch()

    def next_batch(self, compute_s: float = 0.0) -> Batch:
        """Deliver the next batch.  `compute_s` is the model-compute time of
        the iteration this batch's preparation overlapped with; a prefetching
        plane discounts the exposed prep time by it (a synchronous plane
        exposes the full prep and ignores it)."""
        if self.prefetch is not None:
            return self.prefetch.next(compute_s)
        if self.plane.merge_execute:
            if not self._merged_ready:
                plans = self.plan_window()
                for p, b in zip(plans, self.execute_window(plans)):
                    self._merged_ready.append((p.snapshot, b))
            return self._merged_ready.popleft()[1]
        return self.execute(self.plan_next())

    # -- state for checkpoint/restart (fault tolerance) -----------------------
    def state_dict(self) -> dict:
        if self.prefetch is not None:
            snap = self.prefetch.oldest_snapshot()
            if snap is not None:
                return self._with_tier_state(dict(snap))
        if self._merged_ready:
            # mid-window: the oldest executed-but-unconsumed batch's snapshot
            return self._with_tier_state(dict(self._merged_ready[0][0]))
        if self._lookahead:
            return self._with_tier_state(dict(self._lookahead[0][0]))
        return self._with_tier_state(
            {"rng": self.rng.bit_generator.state,
             "requests_per_iter": self._requests_per_iter})

    def _with_tier_state(self, state: dict) -> dict:
        """Attach durable tier state (shard placement assignment) to a
        sampler snapshot.  Cache contents rebuild deterministically on
        resume and are deliberately absent; placement is namespace layout
        and must round-trip.

        Capture happens at CHECKPOINT time, not when the snapshot's batch
        was staged (a per-snapshot copy would clone the whole placement
        table for every staged batch — prohibitive at real node counts).
        Contract for a future mutable placement: quiesce staged batches
        (drain the prefetch queue / finish the merged window) before
        mutating, else resume replays staged work under the post-mutation
        assignment."""
        tier_state = self.store.state_dict()
        if tier_state:
            state["tier_state"] = tier_state
        # fault plane: the injector's burst counter (what retry/hedge
        # decisions are a function of) and the health EMAs must resume —
        # a mid-brownout checkpoint replays the same recovery choices
        fault_state = {}
        if self.fault_injector is not None:
            fault_state["injector"] = self.fault_injector.state_dict()
        if self.topo is not None and self.topo.timeline.injector is not None:
            fault_state["topo_injector"] = \
                self.topo.timeline.injector.state_dict()
        if self.health is not None:
            fault_state["monitor"] = self.health.state_dict()
        if fault_state:
            state["fault_state"] = fault_state
        return state

    def load_state_dict(self, state: dict) -> None:
        self.rng.bit_generator.state = state["rng"]
        self._requests_per_iter = state["requests_per_iter"]
        self._lookahead.clear()
        self._win_idx = 0
        # resume must be bit-identical to a freshly-built loader fed the same
        # state: drop tier contents AND the accumulator's merge-depth EMA
        # (and any batches the prefetch engine staged past the resume point)
        if self.prefetch is not None:
            self.prefetch.reset()
        self._merged_ready.clear()
        self.plane.reset()
        if "tier_state" in state:
            self.store.load_state_dict(state["tier_state"])
        fault_state = state.get("fault_state", {})
        if "injector" in fault_state:
            if self.fault_injector is None:
                raise ValueError(
                    "checkpoint carries fault-injector state but this "
                    "plane has no fault_schedule — resume with the same "
                    "LoaderConfig.fault_schedule or recovery decisions "
                    "diverge from the checkpointed run")
            self.fault_injector.load_state_dict(fault_state["injector"])
        elif self.fault_injector is not None:
            self.fault_injector.reset()
        topo_injector = None if self.topo is None \
            else self.topo.timeline.injector
        if "topo_injector" in fault_state:
            if topo_injector is None:
                raise ValueError(
                    "checkpoint carries a topology-plane fault-injector "
                    "state but this plane has none — resume with the same "
                    "fault_schedule on the same topology preset")
            topo_injector.load_state_dict(fault_state["topo_injector"])
        elif topo_injector is not None:
            topo_injector.reset()
        if "monitor" in fault_state:
            if self.health is None:
                raise ValueError(
                    "checkpoint carries shard-health state but this plane "
                    "has no monitor (no fault_schedule / replication)")
            self.health.load_state_dict(fault_state["monitor"])
        elif self.health is not None:
            self.health.reset()
        self.accumulator.reset_telemetry()
        # telemetry is epoch-local: a resumed run must never report the
        # pre-restore run's last burst (or its spans / registry contents)
        # as its own — pricing state above already reset, so clearing the
        # observers cannot change any priced float
        self.timeline.reset_telemetry()
        if self.topo is not None:
            self.topo.timeline.reset_telemetry()
        self.tracer.reset()
