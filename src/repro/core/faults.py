"""Fault axis for the storage data plane — seeded chaos, priced recovery.

The paper's premise is that thousands of in-flight GPU-initiated storage
accesses tolerate *latency* (Eq. 2-3), but every queue in the modelled plane
is healthy forever.  At terabyte scale shard stalls, tail blowups, and
device outages are the common case, and the max-over-shards burst pricing
means ONE degraded queue silently sets every batch's critical path.  This
module makes that failure mode explicit and priced:

  FaultSchedule  — a declarative, seeded schedule of fault events over
                   priced-burst intervals: per-shard brownouts (latency
                   multipliers), hard shard outages, and transient per-line
                   read failures, plus the retry/hedge policies that govern
                   recovery.
  FaultInjector  — plugs into `StorageTimeline`: every priced storage burst
                   ticks the schedule, and bursts with an active fault are
                   re-priced with capped exponential-backoff retries,
                   per-shard read deadlines, replica failover for dead
                   shards, and HEDGED READS — the straggling shard's
                   residual IOs duplicated to a replica once its drain
                   passes a latency quantile, completion at whichever copy
                   lands first.
  FailoverRouter — the plan-time half: reads whose primary shard is dead
                   (injector outage) or degraded (`ShardHealthMonitor` EMA,
                   core/feedback.py) are routed to the healthiest live
                   replica of a `ReplicatedPlacement` (core/sharding.py)
                   before the burst is even formed.

The invariant throughout: faults perturb *timing and routing only, never
data*.  Gathered features and sampled blocks are bit-identical to the
fault-free run (the injector only ever re-prices bursts and re-routes
queue assignments — bytes always come from the same feature rows), and a
burst with no active fault returns the clean price bit-for-bit, so a
fault-free schedule is indistinguishable from no schedule at all.

Determinism: transient-failure draws come from `default_rng([seed, burst,
shard])` — a pure function of the schedule seed and the burst index, never
of call order — so checkpoint/resume replays the exact retry and hedge
decisions (the injector's burst counter rides `state_dict`).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .storage_sim import IO_BYTES, ShardedBurstResult, model_burst


# -- the schedule --------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BrownoutEvent:
    """Shard `shard` drains `multiplier`x slower during bursts
    ``[start, end)`` — the browning-out device: thermal throttle, background
    GC, a neighbour saturating the channel."""

    shard: int
    start: int
    end: int
    multiplier: float

    def __post_init__(self) -> None:
        _check_interval(self, self.start, self.end)
        if self.multiplier < 1.0:
            raise ValueError(
                f"brownout multiplier must be >= 1 (got {self.multiplier}); "
                "a fault never speeds a queue up")


@dataclasses.dataclass(frozen=True)
class OutageEvent:
    """Shard `shard` serves NOTHING during bursts ``[start, end)`` — a dead
    device.  With replicas its reads fail over wholesale; without, they
    ladder through deadline-long retries until the device recovers."""

    shard: int
    start: int
    end: int

    def __post_init__(self) -> None:
        _check_interval(self, self.start, self.end)


@dataclasses.dataclass(frozen=True)
class FlakyReadsEvent:
    """During bursts ``[start, end)`` each of shard `shard`'s line reads
    fails independently with probability `fail_prob` per attempt (CRC
    errors, link resets) and is retried with capped exponential backoff."""

    shard: int
    start: int
    end: int
    fail_prob: float

    def __post_init__(self) -> None:
        _check_interval(self, self.start, self.end)
        if not 0.0 <= self.fail_prob < 1.0:
            raise ValueError(
                f"fail_prob must be in [0, 1) (got {self.fail_prob}); a "
                "read that always fails is an outage — use OutageEvent")


def _check_interval(event, start: int, end: int) -> None:
    if event.shard < 0:
        raise ValueError(f"{type(event).__name__} shard must be >= 0 "
                         f"(got {event.shard})")
    if start < 0 or end <= start:
        raise ValueError(
            f"{type(event).__name__} interval [{start}, {end}) is empty or "
            "negative — intervals are half-open in priced-burst indices")


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Recovery pricing for failed reads: attempt k waits
    ``min(backoff_base * 2^(k-1), backoff_cap)`` then re-drains the failed
    lines; after `max_retries` the final attempt always succeeds (faults
    cost time, never data).  `read_deadline_s` caps how long any shard's
    reads are waited on before recovery engages — it bounds when a hedge
    fires and prices each attempt against a dead shard."""

    max_retries: int = 3
    backoff_base_s: float = 20e-6
    backoff_cap_s: float = 500e-6
    read_deadline_s: float = 2e-3

    def __post_init__(self) -> None:
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, "
                             f"got {self.max_retries}")
        if self.backoff_base_s < 0 or self.backoff_cap_s < self.backoff_base_s:
            raise ValueError(
                f"backoff cap {self.backoff_cap_s} must be >= base "
                f"{self.backoff_base_s} >= 0")


@dataclasses.dataclass(frozen=True)
class HedgePolicy:
    """Hedged reads: once the straggling shard's drain passes
    ``factor * quantile(per-shard drains, quantile)`` (capped by the read
    deadline), its residual IOs are duplicated to its least-loaded live
    replica and the shard completes at whichever copy lands first.  Hedging
    needs replicas (`ReplicatedPlacement`) and engages only on bursts with
    an active fault — a healthy plane never pays duplicate IOs."""

    quantile: float = 0.5
    factor: float = 2.0

    def __post_init__(self) -> None:
        if not 0.0 <= self.quantile <= 1.0:
            raise ValueError(f"hedge quantile must be in [0, 1], "
                             f"got {self.quantile}")
        if self.factor < 1.0:
            raise ValueError(f"hedge factor must be >= 1, got {self.factor}")


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """A seeded, declarative fault schedule: WHAT goes wrong WHEN (event
    intervals in priced-burst indices) and how recovery is priced (retry /
    hedge policies).  Immutable and cheap to share across planes; the
    mutable run state (burst counter, telemetry) lives on `FaultInjector`."""

    events: tuple = ()
    retry: RetryPolicy = RetryPolicy()
    hedge: HedgePolicy | None = HedgePolicy()
    seed: int = 0

    def __post_init__(self) -> None:
        object.__setattr__(self, "events", tuple(self.events))
        for ev in self.events:
            if not isinstance(ev, (BrownoutEvent, OutageEvent,
                                   FlakyReadsEvent)):
                raise TypeError(
                    f"unknown fault event {type(ev).__name__}; schedule "
                    "events are BrownoutEvent / OutageEvent / "
                    "FlakyReadsEvent")

    @property
    def max_shard(self) -> int:
        return max((ev.shard for ev in self.events), default=-1)

    def any_active(self, burst: int) -> bool:
        return any(ev.start <= burst < ev.end for ev in self.events)


@dataclasses.dataclass(frozen=True)
class FaultedBurstResult(ShardedBurstResult):
    """A `ShardedBurstResult` re-priced under active faults.  Inherited
    `per_shard_s` are the EFFECTIVE drains (after brownout, retries,
    failover, hedging); `clean_per_shard_s` keeps the fault-free drains so
    telemetry can show what the fault cost and what recovery bought back."""

    burst_index: int = -1
    clean_per_shard_s: tuple = ()
    retried_lines: tuple = ()       # per-shard lines re-read by the ladder
    failed_over_lines: tuple = ()   # per-shard lines served by a replica
    hedged_shard: int = -1          # straggler whose residual was duplicated
    hedge_replica: int = -1         # replica that absorbed the hedge
    hedged_lines: int = 0
    hedge_saving_s: float = 0.0

    def recovery_events(self) -> list[tuple[str, int, dict]]:
        """The burst's recovery actions as ``(kind, shard, args)`` rows —
        the tracer renders them as fault sub-events on the shard's track,
        and the metrics registry counts them.  ``recovery_s`` in the args
        is each shard's effective-minus-clean drain: the time the fault
        actually added on that queue after recovery."""
        events: list[tuple[str, int, dict]] = []

        def extra_s(shard: int) -> float:
            if shard < len(self.clean_per_shard_s):
                return max(0.0, self.per_shard_s[shard]
                           - self.clean_per_shard_s[shard])
            return 0.0

        for shard, lines in enumerate(self.retried_lines):
            if lines:
                events.append(("retry", shard, {
                    "lines": int(lines), "recovery_s": extra_s(shard)}))
        for shard, lines in enumerate(self.failed_over_lines):
            if lines:
                events.append(("failover", shard, {
                    "lines": int(lines), "recovery_s": extra_s(shard)}))
        if self.hedged_shard >= 0:
            events.append(("hedge", int(self.hedged_shard), {
                "lines": int(self.hedged_lines),
                "replica": int(self.hedge_replica),
                "saving_s": float(self.hedge_saving_s)}))
        return events


class FaultInjector:
    """Mutable fault-plane run state: ticks the schedule once per priced
    storage burst and re-prices faulted bursts (see `price_burst`).  The
    burst counter is the only state recovery decisions depend on, and it
    rides `state_dict` — resume replays the same retries and hedges."""

    def __init__(self, schedule: FaultSchedule, n_shards: int,
                 replication: int = 1):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        if schedule.max_shard >= n_shards:
            raise ValueError(
                f"fault schedule targets shard {schedule.max_shard} but the "
                f"plane has {n_shards} shard(s) — the event would never "
                "fire; fix the schedule or the plane")
        if replication > n_shards:
            raise ValueError(
                f"replication {replication} exceeds n_shards {n_shards}")
        self.schedule = schedule
        self.n_shards = int(n_shards)
        self.replication = max(int(replication), 1)
        self.reset()

    def reset(self) -> None:
        self._burst = 0
        self.n_faulted_bursts = 0
        self.n_retries = 0
        self.n_retried_lines = 0
        self.n_hedged_bursts = 0
        self.n_hedged_lines = 0
        self.n_failed_over_lines = 0
        self.hedge_saving_s = 0.0
        self.first_hedge_burst = -1
        self.first_failover_burst = -1

    @property
    def burst(self) -> int:
        """Index of the NEXT burst to be priced — what plan-time routing
        (`FailoverRouter`) peeks at before pricing ticks it."""
        return self._burst

    def replica_shards(self, shard: int) -> tuple[int, ...]:
        """The replica queues holding shard `shard`'s rows: replica j of a
        row lives on ``(primary + j) % n_shards`` — the same rule
        `ReplicatedPlacement.replicas_of` applies per node, so burst-level
        recovery and plan-level routing agree."""
        return tuple((int(shard) + j) % self.n_shards
                     for j in range(1, self.replication))

    def _active(self, burst: int):
        mult = np.ones(self.n_shards, np.float64)
        outage = np.zeros(self.n_shards, bool)
        pfail = np.zeros(self.n_shards, np.float64)
        for ev in self.schedule.events:
            if not ev.start <= burst < ev.end:
                continue
            if isinstance(ev, BrownoutEvent):
                mult[ev.shard] *= ev.multiplier
            elif isinstance(ev, OutageEvent):
                outage[ev.shard] = True
            else:
                pfail[ev.shard] = 1.0 - (1.0 - pfail[ev.shard]) \
                    * (1.0 - ev.fail_prob)
        return mult, outage, pfail

    def outage_shards(self, burst: int | None = None) -> tuple[int, ...]:
        b = self._burst if burst is None else burst
        return tuple(ev.shard for ev in self.schedule.events
                     if isinstance(ev, OutageEvent) and ev.start <= b < ev.end)

    def price_burst(self, specs, clean: ShardedBurstResult,
                    bytes_per_row: int,
                    io_bytes: int = IO_BYTES) -> ShardedBurstResult:
        """Re-price one storage burst under the schedule, ticking it.

        A quiet burst (no active event) returns `clean` — the same object,
        the same floats — which is what keeps a fault-free schedule
        bit-identical to no schedule.  A faulted burst is re-priced shard
        by shard: brownout multipliers first, then outage failover (a dead
        shard's lines drain on its least-loaded live replica; with no
        replicas the reads ladder through deadline-long attempts), then the
        transient-failure retry ladder (seeded binomial failure counts,
        capped exponential backoff, the failed lines re-drained at the
        shard's own Eq. 2-3 efficiency), and finally a hedged read for the
        straggler.  Only times and routing change — rows and lines are the
        clean burst's."""
        b = self._burst
        self._burst += 1
        mult, outage, pfail = self._active(b)
        if not (outage.any() or (mult != 1.0).any() or (pfail > 0.0).any()):
            return clean
        self.n_faulted_bursts += 1
        retry = self.schedule.retry
        rows = np.asarray(clean.per_shard_rows, np.int64)
        lines = np.asarray(clean.per_shard_lines, np.int64)
        t = np.asarray(clean.per_shard_s, np.float64) * mult
        n = len(t)
        extra_bytes = 0
        retried = np.zeros(n, np.int64)
        failed_over = np.zeros(n, np.int64)

        clean_t = np.asarray(clean.per_shard_s, np.float64)
        shard_bytes = np.minimum(rows * int(bytes_per_row),
                                 lines * int(io_bytes)).astype(np.float64)
        # recovery IOs (retries, failover, hedges) are GPU-initiated like
        # every other access: they join the burst's in-flight pool, so an
        # idle queue serves them at the Eq. 2-3 efficiency of the whole
        # burst's concurrency — never at the tiny recovery sub-burst's own
        concurrency = max(int(rows.sum()), 1)

        def drain_s(src: int, dst: int, n_lines: int) -> float:
            """Price `n_lines` of shard `src`'s IOs re-issued on queue
            `dst`: the bytes are the source's clean byte share of those
            lines (the same row-vs-line min() the clean burst paid), served
            at the destination queue's effective bandwidth — measured from
            its own clean drain when it is busy this burst, modelled at the
            burst's concurrency when idle — under the destination's
            brownout multiplier."""
            if n_lines <= 0 or lines[src] <= 0:
                return 0.0
            bytes_moved = shard_bytes[src] * (n_lines / float(lines[src]))
            if clean_t[dst] > 0 and shard_bytes[dst] > 0:
                bw = shard_bytes[dst] / clean_t[dst]
            else:
                spec = specs[dst]
                bw = spec.peak_bw * model_burst(spec,
                                                concurrency).efficiency
            return bytes_moved / bw * float(mult[dst])

        for s in np.nonzero(outage & (rows > 0))[0]:
            s = int(s)
            live = [r for r in self.replica_shards(s)
                    if not outage[r] and r != s]
            if live:
                r = min(live, key=lambda q: t[q])
                t[r] += drain_s(s, r, int(lines[s]))
                t[s] = 0.0
                failed_over[s] = lines[s]
                extra_bytes += int(lines[s]) * io_bytes
                self.n_failed_over_lines += int(lines[s])
                if self.first_failover_burst < 0:
                    self.first_failover_burst = b
            else:
                # nowhere to go: every read ladders through deadline-capped
                # attempts and completes when the device recovers
                t[s] += retry.read_deadline_s * (retry.max_retries + 1)

        for s in np.nonzero((pfail > 0.0) & ~outage & (lines > 0))[0]:
            s = int(s)
            rng = np.random.default_rng([self.schedule.seed, b, s])
            fail = int(rng.binomial(int(lines[s]), pfail[s]))
            k = 0
            while fail > 0 and k < retry.max_retries:
                k += 1
                backoff = min(retry.backoff_base_s * 2.0 ** (k - 1),
                              retry.backoff_cap_s)
                t[s] += backoff + drain_s(s, s, fail)
                retried[s] += fail
                self.n_retries += 1
                fail = int(rng.binomial(fail, pfail[s]))
            self.n_retried_lines += int(retried[s])

        hedged_shard = hedge_replica = -1
        hedged_lines = 0
        hedge_saving = 0.0
        hedge = self.schedule.hedge
        if hedge is not None and self.replication > 1:
            busy = t[(rows > 0) & (t > 0.0)]
            if len(busy) >= 2:
                thr = hedge.factor * float(np.quantile(busy, hedge.quantile))
                if retry.read_deadline_s > 0:
                    thr = min(thr, retry.read_deadline_s)
                s = int(np.argmax(t))
                if t[s] > thr and not outage[s] and lines[s] > 0:
                    live = [r for r in self.replica_shards(s)
                            if not outage[r] and r != s]
                    if live:
                        r = min(live, key=lambda q: t[q])
                        resid = int(np.ceil(lines[s] * (t[s] - thr) / t[s]))
                        # duplicated IOs queue behind the replica's own burst
                        done = max(thr, float(t[r])) + drain_s(s, r, resid)
                        if done < t[s]:
                            hedge_saving = float(t[s]) - done
                            t[s] = done
                            hedged_shard, hedge_replica = s, r
                            hedged_lines = resid
                            extra_bytes += resid * io_bytes
                            self.n_hedged_bursts += 1
                            self.n_hedged_lines += resid
                            self.hedge_saving_s += hedge_saving
                            if self.first_hedge_burst < 0:
                                self.first_hedge_burst = b

        return FaultedBurstResult(
            per_shard_s=tuple(float(x) for x in t),
            per_shard_rows=clean.per_shard_rows,
            per_shard_lines=clean.per_shard_lines,
            spec_names=clean.spec_names,
            ssd_bytes=int(clean.ssd_bytes) + extra_bytes,
            burst_index=b,
            clean_per_shard_s=clean.per_shard_s,
            retried_lines=tuple(int(x) for x in retried),
            failed_over_lines=tuple(int(x) for x in failed_over),
            hedged_shard=hedged_shard, hedge_replica=hedge_replica,
            hedged_lines=hedged_lines, hedge_saving_s=float(hedge_saving))

    # -- checkpoint ------------------------------------------------------------
    def state_dict(self) -> dict:
        return {"n_shards": self.n_shards, "seed": self.schedule.seed,
                "replication": self.replication, "burst": self._burst,
                "n_faulted_bursts": self.n_faulted_bursts,
                "n_retries": self.n_retries,
                "n_retried_lines": self.n_retried_lines,
                "n_hedged_bursts": self.n_hedged_bursts,
                "n_hedged_lines": self.n_hedged_lines,
                "n_failed_over_lines": self.n_failed_over_lines,
                "hedge_saving_s": self.hedge_saving_s,
                "first_hedge_burst": self.first_hedge_burst,
                "first_failover_burst": self.first_failover_burst}

    def load_state_dict(self, state: dict) -> None:
        if int(state.get("n_shards", self.n_shards)) != self.n_shards \
                or int(state.get("seed", self.schedule.seed)) \
                != self.schedule.seed \
                or int(state.get("replication", self.replication)) \
                != self.replication:
            raise ValueError(
                f"fault-injector checkpoint ({state.get('n_shards')} shards, "
                f"seed {state.get('seed')}, x{state.get('replication')}) "
                f"does not match this plane ({self.n_shards} shards, seed "
                f"{self.schedule.seed}, x{self.replication}) — resumed "
                "retry/hedge decisions would diverge")
        self._burst = int(state["burst"])
        self.n_faulted_bursts = int(state.get("n_faulted_bursts", 0))
        self.n_retries = int(state.get("n_retries", 0))
        self.n_retried_lines = int(state.get("n_retried_lines", 0))
        self.n_hedged_bursts = int(state.get("n_hedged_bursts", 0))
        self.n_hedged_lines = int(state.get("n_hedged_lines", 0))
        self.n_failed_over_lines = int(state.get("n_failed_over_lines", 0))
        self.hedge_saving_s = float(state.get("hedge_saving_s", 0.0))
        self.first_hedge_burst = int(state.get("first_hedge_burst", -1))
        self.first_failover_burst = int(state.get("first_failover_burst", -1))


class FailoverRouter:
    """Plan-time read rerouting over a `ReplicatedPlacement`.

    `route` rewrites the per-node shard assignment BEFORE the burst forms:
    nodes whose primary shard is dead (an injector outage active at the
    burst about to be priced) or degraded (flagged by the
    `ShardHealthMonitor`) are sent to their healthiest live replica —
    lowest monitor EMA among the node's replica shards, nearest replica
    when no monitor is wired.  Nodes with no live replica keep their
    primary (the burst pricing then charges the outage ladder).

    Routing only moves reads between queues that hold the same bytes, so
    gathered features are untouched; with no bad shards the primary
    assignment is returned as-is — a healthy plane routes bit-identically
    to an unrouted one."""

    def __init__(self, placement, monitor=None, injector=None):
        if not hasattr(placement, "replicas_of"):
            raise ValueError(
                "FailoverRouter needs a replicated placement "
                f"(got {getattr(placement, 'name', None)!r}) — wrap the "
                "policy in ReplicatedPlacement (replication_factor >= 2)")
        self.placement = placement
        self.monitor = monitor
        self.injector = injector
        self.n_rerouted = 0
        self.first_reroute_burst = -1

    def bad_shards(self) -> frozenset[int]:
        bad = set()
        if self.injector is not None:
            bad.update(self.injector.outage_shards())
        if self.monitor is not None:
            bad.update(int(s) for s in self.monitor.degraded())
        return frozenset(bad)

    def route(self, node_ids: np.ndarray,
              primary: np.ndarray) -> np.ndarray:
        bad = self.bad_shards()
        if not bad:
            return primary
        primary = np.asarray(primary, np.int16)
        bad_arr = np.fromiter(bad, np.int16, len(bad))
        mask = np.isin(primary, bad_arr)
        if not mask.any():
            return primary
        reps = self.placement.replicas_of(np.asarray(node_ids)[mask])
        choice = reps[:, 0].astype(np.int16)        # no live replica: stay
        best = np.full(len(choice), np.inf)
        ema = self.monitor.ema if self.monitor is not None else None
        for j in range(1, reps.shape[1]):
            cand = reps[:, j]
            ok = ~np.isin(cand.astype(np.int16), bad_arr)
            score = ema[cand] if ema is not None \
                else np.full(len(cand), float(j))
            take = ok & (score < best)
            choice[take] = cand[take].astype(np.int16)
            best[take] = score[take]
        routed = primary.copy()
        routed[mask] = choice
        moved = int(np.count_nonzero(routed != primary))
        if moved:
            self.n_rerouted += moved
            if self.first_reroute_burst < 0:
                self.first_reroute_burst = (self.injector.burst
                                            if self.injector is not None
                                            else 0)
        return routed
