"""Metrics registry for the observability plane.

A :class:`MetricsRegistry` is a flat namespace of typed instruments —
counters, gauges, histograms, and series — that replaces the scattered
``last_*`` attributes the data plane used to grow per subsystem.  Call
sites get-or-create instruments by name (``registry.counter("x").inc()``),
so instrumentation never has to pre-declare anything, and
:meth:`MetricsRegistry.snapshot` renders the whole registry as a
JSON-safe dict for ``benchmarks/run.py --trace``.

The null registry (:data:`NULL_METRICS`) backs the no-op tracer: every
``counter()/gauge()/...`` call returns a shared inert instrument, so
instrumented code stays unconditional while the disabled path allocates
nothing.
"""
from __future__ import annotations

from typing import Any


class Counter:
    """Monotone accumulator (counts or accumulated seconds)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def snapshot(self) -> dict[str, Any]:
        return {"type": "counter", "value": self.value}


class Gauge:
    """Last-write-wins scalar (ratios, imbalance, level settings)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: float | None = None

    def set(self, value: float) -> None:
        self.value = float(value)

    def snapshot(self) -> dict[str, Any]:
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Streaming summary: count / total / min / max / mean."""

    __slots__ = ("name", "count", "total", "min", "max")

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.min: float | None = None
        self.max: float | None = None

    def observe(self, value: float) -> None:
        value = float(value)
        self.count += 1
        self.total += value
        self.min = value if self.min is None else min(self.min, value)
        self.max = value if self.max is None else max(self.max, value)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def snapshot(self) -> dict[str, Any]:
        return {"type": "histogram", "count": self.count,
                "total": self.total, "mean": self.mean,
                "min": self.min, "max": self.max}


class Series:
    """Append-only sequence of points (dicts or scalars), kept in order.

    Used for the first-class ``modelled_vs_measured`` gap series: one
    point per traced stage invocation, carrying both clocks.
    """

    __slots__ = ("name", "points")

    def __init__(self, name: str):
        self.name = name
        self.points: list[Any] = []

    def append(self, point: Any) -> None:
        self.points.append(point)

    def __len__(self) -> int:
        return len(self.points)

    def snapshot(self) -> dict[str, Any]:
        return {"type": "series", "n": len(self.points),
                "points": list(self.points)}


class MetricsRegistry:
    """Get-or-create namespace of instruments, snapshot-able as JSON."""

    def __init__(self):
        self._instruments: dict[str, Any] = {}

    def _get(self, name: str, cls):
        inst = self._instruments.get(name)
        if inst is None:
            inst = self._instruments[name] = cls(name)
        elif not isinstance(inst, cls):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(inst).__name__}, requested {cls.__name__}")
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def series(self, name: str) -> Series:
        return self._get(name, Series)

    def get(self, name: str):
        return self._instruments.get(name)

    def names(self) -> list[str]:
        return sorted(self._instruments)

    def snapshot(self) -> dict[str, Any]:
        return {name: self._instruments[name].snapshot()
                for name in sorted(self._instruments)}

    def reset(self) -> None:
        self._instruments.clear()


class _NullInstrument:
    """Inert instrument shared by every name on the null registry."""

    __slots__ = ()
    name = "<null>"
    value = None
    count = 0
    total = 0.0
    mean = 0.0
    min = None
    max = None
    points: list = []

    def inc(self, amount: float = 1.0) -> None:
        pass

    def set(self, value: float) -> None:
        pass

    def observe(self, value: float) -> None:
        pass

    def append(self, point: Any) -> None:
        pass

    def __len__(self) -> int:
        return 0

    def snapshot(self) -> dict[str, Any]:
        return {"type": "null"}


_NULL_INSTRUMENT = _NullInstrument()


class NullMetrics(MetricsRegistry):
    """No-op registry: accepts every call, records nothing."""

    def __init__(self):
        super().__init__()

    def _get(self, name: str, cls):
        return _NULL_INSTRUMENT

    def snapshot(self) -> dict[str, Any]:
        return {}

    def reset(self) -> None:
        pass


NULL_METRICS = NullMetrics()
