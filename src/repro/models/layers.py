"""Shared neural layers: norms, rotary embeddings, attention, MLP, MoE.

All functions are pure (params in, activations out) and shard_map/pjit
friendly: tensor layouts keep batch leading and feature dims contiguous so
the sharding rules in `common.py` propagate without resharding.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain as _constrain
from repro.models.common import ModelConfig, ParamDef


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def norm_defs(cfg: ModelConfig, shape=None) -> dict:
    shape = shape or (cfg.d_model,)
    d = {"scale": ParamDef(shape, ("embed",) * len(shape), jnp.float32,
                           init="ones")}
    if cfg.norm_type == "layernorm":
        d["bias"] = ParamDef(shape, ("embed",) * len(shape), jnp.float32,
                             init="zeros")
    return d


def apply_norm(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"] + p["bias"]
    else:
        var = (xf ** 2).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"]
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------
def attention_defs(cfg: ModelConfig, cross: bool = False) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    d = {
        "wq": ParamDef((D, H * hd), ("embed", "qkv"), cfg.param_dtype,
                       init="lecun"),
        "wk": ParamDef((D, KV * hd), ("embed", "qkv"), cfg.param_dtype,
                       init="lecun"),
        "wv": ParamDef((D, KV * hd), ("embed", "qkv"), cfg.param_dtype,
                       init="lecun"),
        "wo": ParamDef((H * hd, D), ("qkv", "embed"), cfg.param_dtype,
                       init="lecun"),
    }
    if cfg.qkv_bias:
        d["bq"] = ParamDef((H * hd,), ("qkv",), jnp.float32, init="zeros")
        d["bk"] = ParamDef((KV * hd,), ("qkv",), jnp.float32, init="zeros")
        d["bv"] = ParamDef((KV * hd,), ("qkv",), jnp.float32, init="zeros")
    if cfg.qk_norm:
        d["q_norm"] = ParamDef((hd,), ("head_dim",), jnp.float32, init="ones")
        d["k_norm"] = ParamDef((hd,), ("head_dim",), jnp.float32, init="ones")
    return d


def _rms(x, scale, eps):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt((xf ** 2).mean(-1, keepdims=True) + eps) * scale
    return y.astype(x.dtype)


def _mask_bias(Sq, Sk, q_offset, causal, window, dtype):
    """q_offset: scalar, or (B,) per-sequence offsets (slot decoding).
    Returns (Sq, Sk) or (B, 1, 1, Sq, Sk)."""
    vec = jnp.ndim(q_offset) == 1
    if vec:
        q_pos = q_offset[:, None, None] + jnp.arange(Sq)[None, :, None]
        k_pos = jnp.arange(Sk)[None, None, :]
    else:
        q_pos = q_offset + jnp.arange(Sq)[:, None]
        k_pos = jnp.arange(Sk)[None, :]
    ok = k_pos <= q_pos if causal else \
        jnp.broadcast_to(jnp.array(True), jnp.broadcast_shapes(
            q_pos.shape, k_pos.shape))
    if causal and window is not None:
        ok &= k_pos > q_pos - window
    elif window is not None:
        ok = ok & (k_pos > q_pos - window)
    bias = jnp.where(ok, 0.0, -1e30).astype(dtype)
    if vec:
        bias = bias[:, None, None, :, :]
    return bias


def attention(p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
              kv_x: jnp.ndarray | None = None,
              positions: jnp.ndarray | None = None,
              kv_cache: tuple | None = None,
              cache_index: jnp.ndarray | None = None,
              causal: bool = True,
              window: int | None = None,
              static_kv: bool = False) -> tuple[jnp.ndarray, tuple | None]:
    """Multi-head attention with GQA / SWA / qk-norm / bias / cache.

    kv_x:      source for K,V (cross-attention) — defaults to x
    kv_cache:  (k, v) of shape (B, S_cache, KV, hd); when given with
               cache_index, new K/V are written at that index (prefill
               writes the whole prompt at 0; decode writes 1 row at pos)
    static_kv: cross-attention cache — if kv_x is given, encode it into the
               cache once (prefill); if kv_x is None, reuse the cache
               verbatim without computing K/V (decode)
    returns (out, new_cache)
    """
    B, Sq, D = x.shape
    H, KVh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd

    q = jnp.einsum("bsd,dn->bsn", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
    q = q.reshape(B, Sq, H, hd)
    if cfg.qk_norm:
        q = _rms(q, p["q_norm"], cfg.norm_eps)

    new_cache = None
    q_offset = 0
    if static_kv and kv_x is None:
        # cross-attention decode: cache holds the encoded memory
        assert kv_cache is not None
        k, v = kv_cache
        new_cache = kv_cache
    else:
        src = x if kv_x is None else kv_x
        k = jnp.einsum("bsd,dn->bsn", src, p["wk"])
        v = jnp.einsum("bsd,dn->bsn", src, p["wv"])
        if cfg.qkv_bias:
            k = k + p["bk"].astype(k.dtype)
            v = v + p["bv"].astype(v.dtype)
        k = k.reshape(B, src.shape[1], KVh, hd)
        v = v.reshape(B, src.shape[1], KVh, hd)
        if cfg.qk_norm:
            k = _rms(k, p["k_norm"], cfg.norm_eps)
        # cache_index may be a scalar or a per-sequence (B,) vector
        # (continuous-batching slots decode at different positions)
        idx_vec = None
        if cache_index is not None:
            idx_vec = jnp.broadcast_to(jnp.asarray(cache_index,
                                                   jnp.int32), (B,)) \
                if jnp.ndim(cache_index) <= 1 else cache_index
        if cfg.pos_embed == "rope" and kv_x is None:
            if positions is None:
                positions = jnp.arange(Sq)
                if kv_cache is not None and idx_vec is not None:
                    positions = positions[None, :] + idx_vec[:, None]
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
        if kv_cache is not None:
            ck, cv = kv_cache                   # (B, S_cache, KV, hd)
            if Sq == 1 and cache_index is not None:
                # decode: one-hot masked write — elementwise along the
                # sequence-sharded cache dim, so no resharding.  A
                # dynamic-update-slice at a runtime index along a sharded
                # dim makes XLA all-gather the whole cache per token
                # (measured 1 GiB/token/layer on llama4 decode_32k).
                hot = (jnp.arange(ck.shape[1])[None, :]
                       == idx_vec[:, None])[:, :, None, None]
                ck = jnp.where(hot, k.astype(ck.dtype), ck)
                cv = jnp.where(hot, v.astype(cv.dtype), cv)
            else:
                idx = cache_index if cache_index is not None else 0
                ck = jax.lax.dynamic_update_slice(
                    ck, k.astype(ck.dtype), (0, idx, 0, 0))
                cv = jax.lax.dynamic_update_slice(
                    cv, v.astype(cv.dtype), (0, idx, 0, 0))
            if not static_kv:
                q_offset = idx_vec if idx_vec is not None else 0
                k, v = ck, cv
            new_cache = (ck, cv)

    group = H // KVh
    # attention core is batch-parallel: shard B over every divisible mesh
    # axis (head counts like 56/40/14 don't divide a 16-way model axis, and
    # head-sharded scores otherwise lower to f32[S,S] partial-sum
    # all-reduces — measured 21 GiB/layer on arctic-480b)
    q = _constrain(q, "attn_act")
    k = _constrain(k, "attn_act")
    v = _constrain(v, "attn_act")

    if cfg.attn_impl in ("flash", "flash_stub") and kv_x is None:
        causal_here = causal and not static_kv
        if cfg.attn_impl == "flash":
            # Pallas blocked-attention kernel (kernels/flash_attention.py):
            # no S^2 materialisation; interpret-mode on CPU, Mosaic on TPU.
            from repro.kernels import ops as _kops
            att = _kops.flash_attention(
                q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                v.transpose(0, 2, 1, 3), causal=causal_here, window=window)
            out = att.transpose(0, 2, 1, 3).reshape(B, Sq, H * hd)
        else:
            # dry-run stand-in with the KERNEL's HBM I/O (reads q and the
            # FULL k/v exactly once, writes o; no S^2 traffic).  The
            # kernel's FLOPs are re-added analytically by the dry-run
            # (XLA cannot cost custom calls).
            kk = jnp.repeat(k.mean(1, keepdims=True), group, axis=2)
            vv = jnp.repeat(v.mean(1, keepdims=True), group, axis=2)
            out = (q + kk + vv).reshape(B, Sq, H * hd)
        out = _constrain(out, "attn_out")
        out = jnp.einsum("bsn,nd->bsd", out, p["wo"])
        return out, new_cache

    # (B,S,H,hd) -> heads-major for the score einsum
    qh = q.reshape(B, Sq, KVh, group, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qh, k) * (hd ** -0.5)
    decode = kv_cache is not None and cache_index is not None and Sq == 1
    scores = _constrain(scores,
                        "attn_scores_decode" if decode else "attn_scores")
    Sk = k.shape[1]
    bias = _mask_bias(Sq, Sk, q_offset,
                      causal and kv_x is None and not static_kv,
                      window, scores.dtype)
    if (kv_cache is not None and cache_index is not None and kv_x is None
            and not static_kv):
        # self-attention over a cache: mask unwritten slots (per sequence)
        valid = (jnp.arange(Sk)[None, :]
                 <= (idx_vec[:, None] + Sq - 1))[:, None, None, None, :]
        bias = bias + jnp.where(valid, 0.0, -1e30).astype(bias.dtype)
    scores = scores + bias
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1
                           ).astype(x.dtype)
    probs = _constrain(probs,
                       "attn_scores_decode" if decode else "attn_scores")
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v).reshape(B, Sq, H * hd)
    out = _constrain(out, "attn_out")
    out = jnp.einsum("bsn,nd->bsd", out, p["wo"])
    return out, new_cache


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------
def mlp_defs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    D, F = cfg.d_model, d_ff or cfg.d_ff
    if cfg.act == "silu_gated":
        return {
            "w1": ParamDef((D, F), ("embed", "ffn"), cfg.param_dtype,
                           init="lecun"),
            "w3": ParamDef((D, F), ("embed", "ffn"), cfg.param_dtype,
                           init="lecun"),
            "w2": ParamDef((F, D), ("ffn", "embed"), cfg.param_dtype,
                           init="lecun"),
        }
    return {  # gelu (whisper)
        "w1": ParamDef((D, F), ("embed", "ffn"), cfg.param_dtype,
                       init="lecun"),
        "b1": ParamDef((F,), ("ffn",), jnp.float32, init="zeros"),
        "w2": ParamDef((F, D), ("ffn", "embed"), cfg.param_dtype,
                       init="lecun"),
        "b2": ParamDef((D,), ("embed",), jnp.float32, init="zeros"),
    }


def mlp(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.act == "silu_gated":
        h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
        return h @ p["w2"]
    h = jax.nn.gelu(x @ p["w1"] + p["b1"].astype(x.dtype))
    return h @ p["w2"] + p["b2"].astype(x.dtype)


# --------------------------------------------------------------------------
# Mixture of Experts — capacity-based scatter dispatch (Switch-style).
#
# Chosen over the dense-einsum dispatch (which materialises an (E, N, D)
# tensor and inflates HLO FLOPs by E/top_k — measured 9.9 TiB temp at
# llama4/train_4k) and over sort-based dropless routing (global sorts lower
# poorly under SPMD).  Memory: one (N*k, E) fp32 one-hot for the
# position-in-expert cumsum ≈ 0.5 GB global at N=1M, E=128 — 2 MB/device.
# --------------------------------------------------------------------------
def moe_defs(cfg: ModelConfig) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.moe_experts
    d = {
        "router": ParamDef((D, E), ("embed", None), jnp.float32,
                           init="normal", scale=0.1),
        "w1": ParamDef((E, D, F), ("expert", "expert_ffn", None),
                       cfg.param_dtype, init="lecun"),
        "w3": ParamDef((E, D, F), ("expert", "expert_ffn", None),
                       cfg.param_dtype, init="lecun"),
        "w2": ParamDef((E, F, D), ("expert", None, "expert_ffn"),
                       cfg.param_dtype, init="lecun"),
    }
    if cfg.moe_shared_expert:
        d["shared"] = mlp_defs(cfg)
    if cfg.moe_dense_residual:
        d["dense"] = mlp_defs(cfg)
    return d


def moe_block(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    B, S, D = x.shape
    E, k = cfg.moe_experts, cfg.moe_top_k
    N = B * S
    xf = x.reshape(N, D)

    # router matmul in compute dtype, softmax in f32: casting the (N, D)
    # INPUT up to f32 instead makes the whole dispatch backward f32
    # (measured +16 GiB/layer of f32 gradient all-reduces on arctic-480b)
    logits = (xf @ p["router"].astype(xf.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, sel = jax.lax.top_k(probs, k)                     # (N, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    C = int((k * N / E) * cfg.moe_capacity_factor)
    C = max(8, -(-C // 8) * 8)
    eid = sel.reshape(-1)                                    # (N*k,)
    onehot = jax.nn.one_hot(eid, E, dtype=jnp.float32)       # (N*k, E)
    pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1.0
    pos = pos.astype(jnp.int32)
    keep = pos < C
    slot = jnp.where(keep, eid * C + pos, E * C)             # overflow -> drop

    token_of = jnp.arange(N * k, dtype=jnp.int32) // k
    # gather-based dispatch: scatter only the int32 token ids into the
    # (E*C+1,) slot table, then gather rows.  Scattering the rows directly
    # (.at[slot].set(xf[token_of])) makes XLA materialise and all-gather a
    # u32[N*k, D] index tensor — measured 2x56 GiB/layer on arctic-480b.
    dispatch = jnp.full((E * C + 1,), N, jnp.int32).at[slot].set(token_of)
    xf_pad = jnp.concatenate([xf, jnp.zeros((1, D), xf.dtype)], axis=0)
    xe = xf_pad[dispatch[:E * C]]
    xe = _constrain(xe.reshape(E, C, D), "moe_dispatch")

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w1"]))
    h = h * jnp.einsum("ecd,edf->ecf", xe, p["w3"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["w2"])              # (E, C, D)
    ye = _constrain(ye, "moe_dispatch")

    ypad = jnp.concatenate([ye.reshape(E * C, D),
                            jnp.zeros((1, D), ye.dtype)], 0)
    contrib = ypad[slot] * gates.reshape(-1)[:, None].astype(ye.dtype)
    y = contrib.reshape(N, k, D).sum(axis=1)

    if cfg.moe_shared_expert:
        y = y + mlp(p["shared"], xf, cfg)
    if cfg.moe_dense_residual:
        y = y + mlp(p["dense"], xf, cfg)
    return y.reshape(B, S, D)


def moe_aux_loss(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Switch load-balancing loss: E * Σ_e f_e · p_e."""
    B, S, D = x.shape
    xf = x.reshape(-1, D)
    probs = jax.nn.softmax(xf.astype(jnp.float32) @ p["router"], -1)
    top = jnp.argmax(probs, -1)
    f = jnp.mean(jax.nn.one_hot(top, cfg.moe_experts, dtype=jnp.float32), 0)
    pbar = probs.mean(0)
    return cfg.moe_experts * jnp.sum(f * pbar)
