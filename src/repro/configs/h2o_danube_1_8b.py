"""h2o-danube-1.8b [dense] — 24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000; llama+mistral mix with sliding-window attention (4096).
Runs long_500k: SWA is O(S*w). [arXiv:2401.16818; hf]
"""
import dataclasses
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b", family="dense",
        num_layers=24, d_model=2560, num_heads=32, num_kv_heads=8,
        d_ff=6912, vocab_size=32000,
        attn_window=4096,
    )


def reduced_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, vocab_pad_to=64, attn_window=16,
        remat=False)
