"""Fig. 8 — achieved SSD bandwidth vs number of overlapping accesses:
analytic model (Eq. 2-3) against the discrete-event simulator, for Intel
Optane and Samsung 980 Pro; plus the model's N for 95% of peak (the paper
reports 812 predicted / 1024 measured for Optane — our Eq. 2-3 constants
land in the same regime)."""
from __future__ import annotations

from benchmarks.common import row
from repro.core.storage_sim import (INTEL_OPTANE, SAMSUNG_980PRO,
                                    model_burst, required_accesses,
                                    simulate_burst)


def main():
    for spec in (INTEL_OPTANE, SAMSUNG_980PRO):
        pts = []
        for n in (32, 128, 512, 1024, 4096, 16384, 65536):
            m = model_burst(spec, n).efficiency
            s = simulate_burst(spec, n, seed=0).efficiency
            pts.append(f"{n}:{m:.3f}/{s:.3f}")
        row(f"fig8_curve_{spec.name}", 0.0, " ".join(pts))
        n95 = required_accesses(spec, 0.95)
        meas = simulate_burst(spec, n95, seed=0).efficiency
        row(f"fig8_n95_{spec.name}", 0.0,
            f"model_N={n95}_sim_eff_at_N={meas:.3f}")


if __name__ == "__main__":
    main()
