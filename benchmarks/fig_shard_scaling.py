"""Shard scaling — the paper's multi-SSD story (§4.2) with real per-shard
queues instead of the analytic ``n_ssd`` multiplier.

Sweeps the sharded merged plane (`gids-merged-sharded`) over
``n_shards ∈ {1, 2, 4, 8}`` × placement policy (hash / range / degree /
skewed, see core/sharding.py) and pins three claims:

  * features are bit-identical to the UNSHARDED plane at every point —
    sharding changes pricing and telemetry, never bytes;
  * under balanced placement, modelled exposed prep is monotonically
    non-increasing in shard count (each shard drains its own queue, the
    batch completes at the slowest one);
  * a deliberately skewed hash degrades gracefully: slower than balanced
    placement at the same shard count, still no slower than one shard.

Also prices a heterogeneous array (one 980Pro straggler among Optanes) to
exercise the straggler telemetry end to end.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row
from repro.core import (GIDSDataLoader, INTEL_OPTANE, LoaderConfig,
                        SAMSUNG_980PRO)
from repro.graph.synthetic import rmat_graph

SHARD_COUNTS = (1, 2, 4, 8)
PLACEMENTS = ("hash", "range", "degree", "skewed")
BALANCED = ("hash", "range", "degree")


def _make_loader(g, feats, plane: str, n_shards: int,
                 placement: str) -> GIDSDataLoader:
    return GIDSDataLoader(g, feats, LoaderConfig(
        batch_size=256, fanouts=(6, 4), data_plane=plane, cache_lines=2048,
        window_depth=4, n_shards=n_shards, placement=placement, seed=3),
        ssd=SAMSUNG_980PRO)


def _run(g, feats, plane, n_shards, placement, iters, warmup):
    dl = _make_loader(g, feats, plane, n_shards, placement)
    batches = [dl.next_batch() for _ in range(iters)]
    prep = float(np.mean([b.exposed_prep_s for b in batches[warmup:]]))
    return prep, batches, dl


def sweep(num_nodes: int = 20_000, iters: int = 16, warmup: int = 6) -> dict:
    g = rmat_graph(num_nodes, 12, 64, seed=1)
    feats = np.random.default_rng(0).standard_normal(
        (g.num_nodes, 64)).astype(np.float32)

    # the unsharded reference every sharded point must match bit-for-bit
    _, ref_batches, _ = _run(g, feats, "gids-merged", 1, "hash",
                             iters, warmup)

    points = []
    for placement in PLACEMENTS:
        for n in SHARD_COUNTS:
            prep, batches, dl = _run(g, feats, "gids-merged-sharded", n,
                                     placement, iters, warmup)
            for br, bs in zip(ref_batches, batches):
                np.testing.assert_array_equal(br.features, bs.features)
                assert br.report.tier_counts == bs.report.tier_counts
            burst = dl.timeline.shard_burst
            points.append({
                "placement": placement, "n_shards": n,
                "exposed_prep_s": prep,
                "imbalance": burst.imbalance if burst else 1.0,
                "straggler": burst.straggler if burst else 0,
            })

    by = {(p["placement"], p["n_shards"]): p for p in points}
    for placement in BALANCED:            # monotone non-increasing scaling
        preps = [by[(placement, n)]["exposed_prep_s"] for n in SHARD_COUNTS]
        assert all(b <= a * 1.001 for a, b in zip(preps, preps[1:])), \
            f"{placement}: prep not monotone over shards: {preps}"
    # graceful degradation: skewed is worse than hash at 4 shards, but the
    # straggler queue still only holds ~half the namespace — no cliff
    assert by[("skewed", 4)]["exposed_prep_s"] \
        >= by[("hash", 4)]["exposed_prep_s"]
    assert by[("skewed", 4)]["exposed_prep_s"] \
        <= by[("hash", 1)]["exposed_prep_s"] * 1.001

    # heterogeneous array: one 980Pro among Optanes sets the critical path
    dl = GIDSDataLoader(g, feats, LoaderConfig(
        batch_size=256, fanouts=(6, 4),
        data_plane="gids-merged-sharded", cache_lines=2048, window_depth=4,
        n_shards=4, placement="hash", seed=3), ssd=INTEL_OPTANE)
    dl.timeline.shard_specs = (SAMSUNG_980PRO, INTEL_OPTANE, INTEL_OPTANE,
                               INTEL_OPTANE)
    for _ in range(iters):
        dl.next_batch()
    het = dl.timeline.shard_burst
    return {"points": points, "hetero": {
        "straggler": het.straggler, "straggler_spec": het.straggler_spec,
        "imbalance": het.imbalance}}


def headline(num_nodes: int = 20_000, iters: int = 16) -> dict:
    """Smoke numbers for BENCH_*.json + the CI shard-scaling gate."""
    res = sweep(num_nodes, iters)
    by = {(p["placement"], p["n_shards"]): p for p in res["points"]}
    out = {}
    for n in SHARD_COUNTS:
        out[f"hash_{n}shard_exposed_prep_us"] = \
            by[("hash", n)]["exposed_prep_s"] * 1e6
    out["prep_speedup_4shard_vs_1shard"] = (
        by[("hash", 1)]["exposed_prep_s"]
        / max(by[("hash", 4)]["exposed_prep_s"], 1e-12))
    out["skewed_4shard_exposed_prep_us"] = \
        by[("skewed", 4)]["exposed_prep_s"] * 1e6
    out["skewed_4shard_imbalance"] = by[("skewed", 4)]["imbalance"]
    out["hetero_straggler_shard"] = res["hetero"]["straggler"]
    out["hetero_straggler_spec"] = res["hetero"]["straggler_spec"]
    out["hetero_imbalance"] = res["hetero"]["imbalance"]
    return out


def main():
    res = sweep()
    for p in res["points"]:
        row(f"fig_shard_scaling_{p['placement']}_{p['n_shards']}ssd",
            p["exposed_prep_s"] * 1e6,
            f"imbalance={p['imbalance']:.3f}_straggler={p['straggler']}")
    het = res["hetero"]
    row("fig_shard_scaling_hetero_1x980pro_3xoptane", 0.0,
        f"straggler_shard={het['straggler']}"
        f"_spec={het['straggler_spec']}_imbalance={het['imbalance']:.2f}")


if __name__ == "__main__":
    main()
