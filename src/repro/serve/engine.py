"""Batched serving engine: slot-based continuous batching over the
prefill/decode steps the dry-run proves out at production scale.

GIDS principles carry over to serving:
  * the request queue is the accumulator's dispatch-ahead pool — admissions
    are batched so the decode step always runs at full slot occupancy
    (latency of admission hidden behind in-flight decodes);
  * per-slot KV cache blocks are the software-cache lines: the slot pool is
    literally a data-plane tier (`KVSlotTier`, built through the "serve-kv"
    `DataPlaneSpec` preset) — a request "hits" while it holds a slot, a
    finished request's slot is "safe to evict" and recycled;
  * admission staging gets the training loop's overlap pricing: per tick,
    the modelled prefill/staging cost of admitted requests is discounted by
    the decode compute it ran behind
    (`StorageTimeline.price_batch_overlapped`), and `overlap_stats` reports
    how much of the admission prep the decode loop hid.

Single-host reference implementation (the pjit'd steps are the same ones
the 512-chip dry-run compiles; here they run on the local device).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dataplane import DataPlaneSpec
from repro.core.prefetch import PrefetchStats
from repro.core.storage_sim import overlap_exposed
from repro.core.tiers import KVSlotTier
from repro.models.transformer import LM


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 16
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False
    kv_key: int = -1                # slot-pool key, assigned at admission


@dataclasses.dataclass
class EngineConfig:
    slots: int = 4                  # concurrent sequences (batch dim)
    max_seq: int = 256
    eos_token: int = -1             # -1: never stops early
    # modelled timing for the overlap accounting (0 = don't model)
    admit_cost_s: float = 0.0       # prefill/staging cost per admission
    decode_cost_s: float = 0.0      # compute cost of one decode tick


class ServeEngine:
    """Admit -> prefill-into-slot -> step-decode loop.

    Decode runs over ALL slots every step (static shapes for jit); empty
    slots compute garbage that is masked out — the standard TPU serving
    trade (occupancy vs recompile).
    """

    def __init__(self, model: LM, params, cfg: EngineConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.cache = model.init_cache(cfg.slots, cfg.max_seq)
        kv_bytes = sum(x.nbytes for x in jax.tree.leaves(self.cache))
        (self.kv_slots,) = DataPlaneSpec.preset("serve-kv").build_stack(
            slots=cfg.slots,
            bytes_per_slot=kv_bytes // max(cfg.slots, 1))
        assert isinstance(self.kv_slots, KVSlotTier)
        self.positions = np.zeros(cfg.slots, np.int32)   # next write index
        self.active: list[Optional[Request]] = [None] * cfg.slots
        self.queue: deque[Request] = deque()
        self._admit_seq = 0      # slot-pool key: admission order, not the
                                 # caller-supplied rid (rids may collide)
        self.overlap_stats = PrefetchStats()  # admission prep vs decode hide
        self._decode = jax.jit(self._decode_impl, donate_argnums=(1,))
        self._next_tok = np.zeros((cfg.slots, 1), np.int32)

    # -- jitted steps ----------------------------------------------------------
    def _decode_impl(self, token, cache, index_vec):
        # index_vec: (slots,) per-slot decode positions (continuous
        # batching — each slot advances independently; the one-hot cache
        # write and mask logic in layers.attention take vector indices)
        logits, cache = self.model.decode_step(self.params, token, cache,
                                               index_vec)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt[:, None], cache

    # -- admission -------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> list[Request]:
        """Admit queued requests into free slots; returns requests that
        finished AT prefill (max_new_tokens=1 or EOS on the first token) —
        they never occupy a slot for decoding."""
        retired = []
        while self.queue:
            slot = self.kv_slots.acquire(self._admit_seq)
            if slot is None:                   # pool full: stay queued
                break
            assert self.active[slot] is None, \
                "slot pool and active list out of sync"
            req = self.queue.popleft()
            req.kv_key = self._admit_seq
            self._admit_seq += 1
            S = len(req.prompt)
            # prefill this slot: run the prompt through a slot-batched
            # forward (batch of 1 padded into the slot position).
            batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
            sub_cache = self.model.init_cache(1, self.cfg.max_seq)
            logits, sub_cache = self.model.prefill(self.params, batch,
                                                   sub_cache)
            tok = int(jnp.argmax(logits[0, -1]))
            req.generated.append(tok)
            if (len(req.generated) >= req.max_new_tokens
                    or tok == self.cfg.eos_token):
                req.done = True
                retired.append(req)
                self.kv_slots.release(req.kv_key)
                continue
            # splice the slot's cache rows in
            self.cache = jax.tree.map(
                lambda full, one: full.at[:, slot:slot + 1].set(one)
                if full.ndim >= 2 else full,
                self.cache, sub_cache)
            self._next_tok[slot, 0] = tok
            self.positions[slot] = S
            self.active[slot] = req
        return retired

    # -- main loop ---------------------------------------------------------------
    def step(self) -> list[Request]:
        """One engine tick: admit waiting requests, one decode step for all
        active slots, retire finished requests.  Returns retired.

        Overlap accounting: the modelled staging cost of this tick's
        admissions overlaps the decode compute of requests already in flight
        *before* the tick — a cold-start admission has no decode to hide
        behind and is fully exposed — so only the excess is hidden, exactly
        like the training loader's prefetch pricing."""
        was_decoding = any(r is not None for r in self.active)
        admitted_before = self._admit_seq
        retired = self._admit()
        n_admitted = self._admit_seq - admitted_before
        prep_s = n_admitted * self.cfg.admit_cost_s
        compute_s = self.cfg.decode_cost_s if was_decoding else 0.0
        # staged_batches counts admissions; consumed_batches is left at 0 —
        # serve has no per-batch consumer, only the prep/exposed totals and
        # hidden_fraction carry meaning here
        self.overlap_stats.staged_batches += n_admitted
        self.overlap_stats.prep_s_total += prep_s
        self.overlap_stats.exposed_s_total += \
            overlap_exposed(prep_s, compute_s)
        if not any(r is not None for r in self.active):
            return retired
        tok, self.cache = self._decode(
            jnp.asarray(self._next_tok), self.cache,
            jnp.asarray(self.positions))
        tok_np = np.asarray(tok)
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            t = int(tok_np[slot, 0])
            req.generated.append(t)
            self.positions[slot] += 1
            if (len(req.generated) >= req.max_new_tokens
                    or t == self.cfg.eos_token
                    or self.positions[slot] >= self.cfg.max_seq - 1):
                req.done = True
                retired.append(req)
                self.active[slot] = None
                self.kv_slots.release(req.kv_key)  # slot safe-to-evict
            else:
                self._next_tok[slot, 0] = t
        return retired

    def run_until_drained(self, max_ticks: int = 1000) -> list[Request]:
        """Step until queue and slots are empty.  If `max_ticks` runs out
        first, raise `EngineNotDrained` carrying the retired requests and
        the unfinished count — silently returning a partial result would
        let callers drop queued/active work on the floor."""
        out = []
        for _ in range(max_ticks):
            out.extend(self.step())
            if not self.queue and all(r is None for r in self.active):
                return out
        unfinished = len(self.queue) + sum(r is not None for r in self.active)
        if unfinished:
            raise EngineNotDrained(unfinished, out, max_ticks)
        return out


class EngineNotDrained(RuntimeError):
    """`run_until_drained` exhausted its tick budget with work still queued
    or decoding.  `retired` holds the requests that DID finish (the engine
    keeps its state, so calling `run_until_drained` again continues)."""

    def __init__(self, unfinished: int, retired: list[Request],
                 max_ticks: int):
        super().__init__(
            f"engine not drained after {max_ticks} ticks: {unfinished} "
            f"request(s) still queued or decoding ({len(retired)} retired)")
        self.unfinished = unfinished
        self.retired = retired
