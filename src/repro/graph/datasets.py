"""Dataset registry — paper Tables 2, 3 and 4, plus the scaled-down synthetic
stand-ins executed in this container.

Every benchmark reports against a `DatasetSpec`; the paper-scale entries carry
the true row counts so projections (bytes, request counts) use real numbers
even when execution uses the scaled graph.
"""
from __future__ import annotations

import dataclasses
import functools

from .csr import CSRGraph
from .synthetic import rmat_graph


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    num_nodes: int
    num_edges: int
    feature_dim: int
    heterogeneous: bool = False
    feature_dtype_bytes: int = 4
    # execution scale: nodes actually instantiated when materialised here
    exec_nodes: int = 0

    @property
    def feature_bytes(self) -> int:
        return self.num_nodes * self.feature_dim * self.feature_dtype_bytes

    @property
    def avg_degree(self) -> int:
        return max(1, self.num_edges // max(1, self.num_nodes))

    def materialize(self, seed: int = 0) -> CSRGraph:
        n = self.exec_nodes or self.num_nodes
        return rmat_graph(n, self.avg_degree, self.feature_dim, seed=seed,
                          name=self.name)


# ---- paper Table 2 (real-world) -------------------------------------------
OGBN_PAPERS100M = DatasetSpec("ogbn-papers100M", 111_059_956, 1_615_685_872,
                              128, exec_nodes=200_000)
IGB_FULL = DatasetSpec("IGB-Full", 269_364_174, 3_995_777_033, 1024,
                       exec_nodes=200_000)
MAG240M = DatasetSpec("MAG240M", 244_160_499, 1_728_364_232, 768,
                      heterogeneous=True, exec_nodes=200_000)
IGBH_FULL = DatasetSpec("IGBH-Full", 547_306_935, 5_812_005_639, 1024,
                        heterogeneous=True, exec_nodes=200_000)

# ---- paper Table 3 (micro-benchmarks) --------------------------------------
IGB_TINY = DatasetSpec("IGB-tiny", 100_000, 547_416, 1024,
                       exec_nodes=100_000)
IGB_SMALL = DatasetSpec("IGB-small", 1_000_000, 12_070_502, 1024,
                        exec_nodes=250_000)
IGB_MEDIUM = DatasetSpec("IGB-medium", 10_000_000, 120_077_694, 1024,
                         exec_nodes=500_000)
IGB_LARGE = DatasetSpec("IGB-large", 100_000_000, 1_223_571_364, 1024,
                        exec_nodes=500_000)

REGISTRY = {d.name: d for d in [
    OGBN_PAPERS100M, IGB_FULL, MAG240M, IGBH_FULL,
    IGB_TINY, IGB_SMALL, IGB_MEDIUM, IGB_LARGE,
]}


@functools.lru_cache(maxsize=8)
def load(name: str, seed: int = 0) -> CSRGraph:
    return REGISTRY[name].materialize(seed)
