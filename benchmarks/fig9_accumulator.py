"""Fig. 9 — GPU PCIe ingress bandwidth during aggregation, with and
without the dynamic storage access accumulator, BaM vs GIDS, batch sizes
32/64/128, two Optane SSDs, IGB-Full stand-in, fan-out (5,5).

Paper: accumulator lifts BaM 7.6->9.8, 9.4->10.4, 10.1->10.6 GB/s and GIDS
by 1.95x/1.46x/1.31x (GIDS redirects requests, so fewer storage accesses
remain to cover latency — the accumulator matters MORE)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row
from repro.core import GIDSDataLoader, LoaderConfig, INTEL_OPTANE
from repro.core.storage_sim import StorageTimeline
from repro.graph.datasets import IGB_FULL


def effective_bw(dl: GIDSDataLoader, accumulate: bool, iters=10):
    """PCIe ingress bandwidth (storage + host-buffer bytes crossing the
    link), as Fig. 9 measures.  Outstanding counts use the *deduplicated
    storage-bound* requests of one iteration (no_acc) vs merge_depth
    iterations (acc) — redirected requests occupy no SSD queue slots."""
    tl = dl.timeline
    bws = []
    for _ in range(iters):
        b = dl.next_batch()
        r = b.report
        if accumulate:
            depth = dl.accumulator.merge_depth(max(r.n_storage, 1))
            outstanding = depth * r.n_storage
        else:
            outstanding = r.n_storage
        t = tl.gids_batch_time(r.n_storage, r.n_host_hits, r.n_hbm_hits,
                               r.bytes_per_row, outstanding)
        ingress = (r.n_storage + r.n_host_hits) * r.bytes_per_row
        bws.append(ingress / t)
    return float(np.mean(bws[2:]))


def main():
    g = IGB_FULL.materialize()
    feats_dim = IGB_FULL.feature_dim
    feats = np.zeros((g.num_nodes, 1), np.float32)  # id-only (bandwidth sim)

    for batch in (32, 64, 128):
        for mode in ("bam", "gids"):
            cfg = LoaderConfig(batch_size=batch, fanouts=(5, 5), data_plane=mode,
                               cache_lines=1 << 14, window_depth=8,
                               n_ssd=2, cbuf_fraction=0.1)
            out = {}
            for acc in (False, True):
                dl = GIDSDataLoader(g, feats, cfg, ssd=INTEL_OPTANE)
                # bytes_per_row must reflect the 1024-dim f32 rows of IGB
                dl.store.feature_dim = feats_dim
                bw = effective_bw(dl, accumulate=acc)
                out[acc] = bw
            row(f"fig9_{mode}_b{batch}", 0.0,
                f"no_acc={out[False]/1e9:.2f}GBps_acc={out[True]/1e9:.2f}"
                f"GBps_gain={out[True]/out[False]:.2f}x")


if __name__ == "__main__":
    main()
