"""Fig. 3 — feature-request generation rate of data preparation (host vs
device sampler) vs the training kernels' consumption rate, plus the online
analogue: request service rates through the serve plane.

Paper (A100 + EPYC): CPU prep 4.1 M req/s, GPU prep 77 M req/s, training
consumes 29 M req/s -> only device-side prep keeps the accelerator fed.
Here both run on one CPU core, so absolute numbers shrink together; the
reported quantity is the RATIO (device-prep / consumption), which must stay
>= 1 for the paper's conclusion to hold in this build.

The serve section asks the same question under arrival dynamics instead of
epoch order: at a fixed offered load, what request rate does the engine
actually serve within SLO (goodput), and where does the latency go (queue
wait / sampling / gather burst share / forward)?  Merged deadline-bounded
admission vs per-request execution — the request-rate gap is Fig. 3's
prep-rate gap re-expressed for online inference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.graph.csr import device_index_dtype
from repro.graph.synthetic import rmat_graph
from repro.models.gnn import GNN, GNNConfig, hop_indices
from repro.sampling.neighbor import (device_sample_blocks,
                                     host_sample_blocks, subgraph_sizes)
from repro.serve import GNNServeConfig, GNNServeEngine, TenantSpec, \
    generate_stream


def prep_vs_consume(batch=1024, fanouts=(10, 5)):
    g = rmat_graph(250_000, 12, 64, seed=0, name="igb-small-like")
    rng = np.random.default_rng(0)
    seeds = rng.integers(0, g.num_nodes, batch)
    n_req = subgraph_sizes(batch, fanouts)

    t_host = timeit(lambda: host_sample_blocks(g, seeds, fanouts, rng))
    host_rate = n_req / t_host

    csr = g.to_device()
    # the device sampler's id dtype must match the graph's (int64 past 2^31
    # ids) — a hard-coded int32 would silently truncate on big graphs
    dseeds = jnp.asarray(seeds, device_index_dtype(g.num_nodes, g.num_edges))
    samp = jax.jit(lambda s, k: device_sample_blocks(csr, s, fanouts, k)[1])
    key = jax.random.PRNGKey(0)
    t_dev = timeit(lambda: samp(dseeds, key).block_until_ready())
    dev_rate = n_req / t_dev

    # consumption: GraphSAGE train step on the gathered features
    cfg = GNNConfig(model="sage", in_dim=64, hidden_dim=128, num_classes=47,
                    fanouts=fanouts, use_pallas=False)
    gnn = GNN(cfg)
    params = gnn.init(jax.random.PRNGKey(0))
    blocks = host_sample_blocks(g, seeds, fanouts, rng)
    feats = jnp.asarray(
        rng.standard_normal((len(blocks.all_nodes), 64)).astype(np.float32))
    hi = [jnp.asarray(i) for i in hop_indices(blocks)]
    labels = jnp.asarray(rng.integers(0, 47, batch))

    @jax.jit
    def train_step(p, f, h0, h1, h2, y):
        l, gr = jax.value_and_grad(gnn.loss)(p, f, [h0, h1, h2], y)
        return jax.tree.map(lambda a, b: a - 1e-3 * b, p, gr), l

    t_train = timeit(
        lambda: jax.block_until_ready(
            train_step(params, feats, hi[0], hi[1], hi[2], labels)))
    consume_rate = n_req / t_train

    row("fig3_host_prep_rate", t_host * 1e6,
        f"req_per_s={host_rate:,.0f}")
    row("fig3_device_prep_rate", t_dev * 1e6,
        f"req_per_s={dev_rate:,.0f}")
    row("fig3_train_consume_rate", t_train * 1e6,
        f"req_per_s={consume_rate:,.0f}")
    row("fig3_device_over_consume", 0.0,
        f"ratio={dev_rate / consume_rate:.2f}_host_ratio="
        f"{host_rate / consume_rate:.2f}")


def serve_request_rates(offered_qps=8000, n_requests=400):
    graph = rmat_graph(20_000, 12, 64, seed=7)
    feats = np.random.default_rng(0).standard_normal(
        (graph.num_nodes, 64)).astype(np.float32)
    tenants = (
        TenantSpec("steady", hot_fraction=0.03, hot_prob=0.9, mean_seeds=4,
                   arrival="poisson"),
        TenantSpec("bursty", hot_fraction=0.5, hot_prob=0.2, mean_seeds=8,
                   arrival="mmpp", burst_factor=8.0, burst_fraction=0.1),
    )
    requests = generate_stream(graph.num_nodes, tenants, offered_qps,
                               n_requests, seed=11)
    for merged in (True, False):
        engine = GNNServeEngine(
            graph, feats, GNNServeConfig(merged=merged, tenants=2, seed=3))
        res = engine.run([type(r)(r.rid, r.tenant, r.arrival_s,
                                  r.seeds.copy(), r.deadline_s)
                          for r in requests])
        bd = res.mean_breakdown_s()
        mode = "merged" if merged else "per_request"
        row(f"fig3_serve_{mode}_rate", res.p99_s() * 1e6,
            f"goodput_qps={res.goodput_qps():,.0f}"
            f"_offered={res.offered_qps():,.0f}"
            f"_p50_us={res.p50_s()*1e6:.0f}"
            f"_wait_us={bd['queue_wait_s']*1e6:.0f}"
            f"_sample_us={bd['sample_s']*1e6:.0f}"
            f"_gather_us={bd['gather_s']*1e6:.0f}"
            f"_forward_us={bd['forward_s']*1e6:.0f}"
            f"_win={res.mean_window:.1f}")


def main(batch=1024, fanouts=(10, 5)):
    prep_vs_consume(batch, fanouts)
    serve_request_rates()


if __name__ == "__main__":
    main()
