"""Fig. 15 — feature-aggregation time with layer-wise (LADIES) vs
neighborhood sampling, mmap-DGL vs BaM vs GIDS.

Paper: GIDS 412x over DGL, 1.92x over BaM with LADIES."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row
from repro.core import GIDSDataLoader, LoaderConfig, SAMSUNG_980PRO
from repro.graph.datasets import IGB_FULL


def agg_time(mode, sampler, iters=8):
    g = IGB_FULL.materialize()
    feats = np.zeros((g.num_nodes, 1), np.float32)
    cfg = LoaderConfig(batch_size=256, fanouts=(10, 5),
                       sampler=sampler, ladies_layer_sizes=(2048, 2048),
                       data_plane=mode, cache_lines=1 << 13, window_depth=8,
                       cbuf_fraction=0.1 if mode == "gids" else 0.0)
    dl = GIDSDataLoader(g, feats, cfg, ssd=SAMSUNG_980PRO)
    dl.store.feature_dim = IGB_FULL.feature_dim
    ts = [dl.next_batch().prep_time_s for _ in range(iters)]
    return float(np.mean(ts[2:]))


def main():
    for sampler in ("neighbor", "ladies"):
        times = {m: agg_time(m, sampler) for m in ("mmap", "bam", "gids")}
        row(f"fig15_{sampler}", times["gids"] * 1e6,
            f"mmap_s={times['mmap']:.3f}_bam_s={times['bam']:.4f}"
            f"_gids_s={times['gids']:.4f}"
            f"_speedup_vs_mmap={times['mmap']/times['gids']:.0f}x"
            f"_vs_bam={times['bam']/times['gids']:.2f}x")


if __name__ == "__main__":
    main()
