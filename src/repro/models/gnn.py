"""GNN models (the paper's training domain): GraphSAGE, GCN, GAT.

Models operate on fixed-fanout sampled blocks (repro.sampling.neighbor):
the dataloader delivers a deduplicated feature table `feats` (U, D) for the
union of sampled nodes plus per-hop index arrays mapping hop nodes to table
rows.  The innermost aggregation gathers straight from the table via the
`segment_mean` Pallas kernel (the paper's aggregation hot-spot); outer hops
aggregate already-transformed activations with reshape-mean.

Layer semantics (GraphSAGE-mean, [11]):
    h_dst' = act(W_self h_dst + W_nbr mean_{n in N(dst)} h_n)
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.models.common import ParamDef, init_params
from repro.sampling.neighbor import SampledBlocks


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    model: str = "sage"              # sage | gcn | gat
    in_dim: int = 1024
    hidden_dim: int = 128            # paper §4.1: hidden 128
    num_classes: int = 47
    fanouts: Sequence[int] = (10, 5, 5)
    num_heads: int = 4               # gat
    use_pallas: bool = True


def hop_indices(blocks: SampledBlocks) -> list[np.ndarray]:
    """Map seeds + each hop's node ids to rows of blocks.all_nodes
    (all_nodes is sorted-unique, so searchsorted is exact)."""
    table = blocks.all_nodes
    out = [np.searchsorted(table, blocks.seeds.astype(np.int64))]
    for h in blocks.hop_nodes:
        out.append(np.searchsorted(table, h))
    return [o.astype(np.int32) for o in out]


class GNN:
    def __init__(self, cfg: GNNConfig):
        self.cfg = cfg
        self.L = len(cfg.fanouts)

    def param_defs(self) -> dict:
        cfg = self.cfg
        dims = [cfg.in_dim] + [cfg.hidden_dim] * self.L
        defs: dict = {}
        for l in range(self.L):
            d_in, d_out = dims[l], dims[l + 1]
            layer = {
                "w_self": ParamDef((d_in, d_out), ("embed", "ffn"),
                                   jnp.float32, init="lecun"),
                "w_nbr": ParamDef((d_in, d_out), ("embed", "ffn"),
                                  jnp.float32, init="lecun"),
                "b": ParamDef((d_out,), ("ffn",), jnp.float32, init="zeros"),
            }
            if cfg.model == "gat":
                layer["attn_src"] = ParamDef((cfg.num_heads,
                                              d_out // cfg.num_heads),
                                             (None, None), jnp.float32,
                                             init="normal")
                layer["attn_dst"] = ParamDef((cfg.num_heads,
                                              d_out // cfg.num_heads),
                                             (None, None), jnp.float32,
                                             init="normal")
            defs[f"layer{l}"] = layer
        defs["head"] = {
            "w": ParamDef((cfg.hidden_dim, cfg.num_classes),
                          ("ffn", None), jnp.float32, init="lecun"),
            "b": ParamDef((cfg.num_classes,), (None,), jnp.float32,
                          init="zeros"),
        }
        return defs

    def init(self, key: jax.Array) -> dict:
        return init_params(self.param_defs(), key)

    # -- aggregation ----------------------------------------------------------
    def _aggregate(self, x_nbr: jnp.ndarray, fanout: int) -> jnp.ndarray:
        n = x_nbr.shape[0] // fanout
        return x_nbr.reshape(n, fanout, -1).mean(axis=1)

    def _layer(self, p: dict, x_dst, x_nbr_mean, x_nbr=None, fanout=None):
        cfg = self.cfg
        if cfg.model == "gcn":
            deg = 1 + (fanout or 1)
            h = (x_dst + x_nbr_mean * (fanout or 1)) / deg
            return jax.nn.relu(h @ p["w_self"] + p["b"])
        if cfg.model == "gat" and x_nbr is not None:
            H = cfg.num_heads
            n, f = x_dst.shape[0], fanout
            hd = p["w_nbr"].shape[1] // H
            zd = (x_dst @ p["w_self"]).reshape(n, H, hd)
            zn = (x_nbr @ p["w_nbr"]).reshape(n, f, H, hd)
            es = (zd * p["attn_src"]).sum(-1)                  # (n, H)
            en = (zn * p["attn_dst"]).sum(-1)                  # (n, f, H)
            e = jax.nn.leaky_relu(es[:, None, :] + en, 0.2)
            a = jax.nn.softmax(e, axis=1)
            agg = (a[..., None] * zn).sum(axis=1)              # (n, H, hd)
            return jax.nn.elu(agg.reshape(n, H * hd) + p["b"])
        # sage
        return jax.nn.relu(x_dst @ p["w_self"] + x_nbr_mean @ p["w_nbr"]
                           + p["b"])

    # -- forward ----------------------------------------------------------------
    def forward(self, params: dict, feats: jnp.ndarray,
                hop_idx: list[jnp.ndarray]) -> jnp.ndarray:
        """feats: (U, D) deduplicated gathered features; hop_idx: per-hop
        row indices (len L+1, hop 0 = seeds). Returns seed logits.

        Standard block-wise mini-batch computation: after GNN layer t, the
        activations cover hop levels 0..L-t; layer t consumes level lvl+1
        into level lvl.  The first layer's aggregation reads straight from
        the deduplicated feature table via the segment_mean kernel (fused
        gather+mean — the paper's aggregation stage); later layers
        reshape-mean already-materialised activations.
        """
        cfg = self.cfg
        fanouts = list(cfg.fanouts)
        L = self.L
        h = [feats[hop_idx[lvl]] for lvl in range(L + 1)]
        for t in range(L):
            p = params[f"layer{t}"]
            new_h = []
            for lvl in range(L - t):
                f = fanouts[lvl]
                if t == 0:
                    idx = hop_idx[lvl + 1].reshape(-1, f)
                    nbr_mean = ops.segment_mean(idx, feats,
                                                use_pallas=cfg.use_pallas)
                else:
                    nbr_mean = self._aggregate(h[lvl + 1], f)
                x_nbr = h[lvl + 1] if cfg.model == "gat" else None
                new_h.append(self._layer(p, h[lvl], nbr_mean,
                                         x_nbr=x_nbr, fanout=f))
            h = new_h
        logits = h[0] @ params["head"]["w"] + params["head"]["b"]
        return logits

    def loss(self, params, feats, hop_idx, labels) -> jnp.ndarray:
        logits = self.forward(params, feats, hop_idx)
        lse = jax.nn.logsumexp(logits, axis=-1)
        lab = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        return jnp.mean(lse - lab)
