"""Recurrent mixers: RG-LRU (recurrentgemma/Griffin) and Mamba-2 SSD.

Both expose a sequence form (train / prefill — parallel across S via
associative scan or chunked recurrence) and a single-step form (decode —
O(1) state update, which is why these archs run the long_500k shape).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, ParamDef

RG_LRU_C = 8.0


# --------------------------------------------------------------------------
# depthwise causal conv (width K), shared by both mixers
# --------------------------------------------------------------------------
def causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray
                ) -> jnp.ndarray:
    """x: (B,S,C), w: (K,C) depthwise, b: (C,)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return out + b.astype(out.dtype)


def conv_step(state: jnp.ndarray, x_t: jnp.ndarray, w: jnp.ndarray,
              b: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """state: (B,K-1,C) trailing inputs; x_t: (B,1,C)."""
    window = jnp.concatenate([state, x_t], axis=1)        # (B,K,C)
    out = jnp.einsum("bkc,kc->bc", window, w) + b
    return window[:, 1:, :], out[:, None, :].astype(x_t.dtype)


# --------------------------------------------------------------------------
# RG-LRU block (Griffin recurrent residual block)
# --------------------------------------------------------------------------
def rglru_defs(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    W = cfg.lru_width or D
    pd = cfg.param_dtype
    return {
        "w_in": ParamDef((D, W), ("embed", "lru"), pd, init="lecun"),
        "w_gate": ParamDef((D, W), ("embed", "lru"), pd, init="lecun"),
        "conv_w": ParamDef((4, W), ("conv", "lru"), jnp.float32,
                           init="normal", scale=0.5),
        "conv_b": ParamDef((W,), ("lru",), jnp.float32, init="zeros"),
        "wa": ParamDef((W, W), ("lru", None), pd, init="lecun"),
        "ba": ParamDef((W,), ("lru",), jnp.float32, init="zeros"),
        "wx": ParamDef((W, W), ("lru", None), pd, init="lecun"),
        "bx": ParamDef((W,), ("lru",), jnp.float32, init="zeros"),
        # Λ init so decay a ≈ U(0.9, 0.999) at r=1 (Griffin §2.4)
        "lam": ParamDef((W,), ("lru",), jnp.float32, init="ones",
                        scale=1.0),
        "w_out": ParamDef((W, D), ("lru", "embed"), pd, init="lecun"),
    }


class RGLRUState(NamedTuple):
    conv: jnp.ndarray   # (B, 3, W)
    h: jnp.ndarray      # (B, W) f32


def rglru_init_state(cfg: ModelConfig, batch: int) -> RGLRUState:
    W = cfg.lru_width or cfg.d_model
    return RGLRUState(conv=jnp.zeros((batch, 3, W), cfg.compute_dtype),
                      h=jnp.zeros((batch, W), jnp.float32))


def _rglru_gates(p: dict, u: jnp.ndarray):
    """u: post-conv input (..., W) -> decay a, driven input b (f32)."""
    r = jax.nn.sigmoid(u.astype(jnp.float32) @ p["wa"].astype(jnp.float32)
                       + p["ba"])
    i = jax.nn.sigmoid(u.astype(jnp.float32) @ p["wx"].astype(jnp.float32)
                       + p["bx"])
    log_a = -RG_LRU_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) \
        * (i * u.astype(jnp.float32))
    return a, b


def rglru_block(p: dict, x: jnp.ndarray, cfg: ModelConfig,
                state: RGLRUState | None = None, *,
                return_state: bool = False
                ) -> tuple[jnp.ndarray, RGLRUState | None]:
    """x: (B,S,D).

    state None  -> sequence mode (train/prefill): parallel associative scan;
                   pass return_state=True (prefill) to also emit the final
                   recurrent + conv state.
    state given -> single-step decode (S must be 1).
    """
    gate = jax.nn.gelu(x @ p["w_gate"])
    u_raw = x @ p["w_in"]

    if state is None:
        u = causal_conv(u_raw, p["conv_w"], p["conv_b"]).astype(u_raw.dtype)
        a, b = _rglru_gates(p, u)

        def op(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2

        _, h = jax.lax.associative_scan(op, (a, b), axis=1)
        new_state = None
        if return_state:
            tail = u_raw[:, -3:, :]
            pad = 3 - tail.shape[1]
            if pad > 0:
                tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
            new_state = RGLRUState(conv=tail.astype(cfg.compute_dtype),
                                   h=h[:, -1, :])
    else:
        new_conv, u1 = conv_step(state.conv, u_raw, p["conv_w"], p["conv_b"])
        a, b = _rglru_gates(p, u1)
        h1 = a[:, 0] * state.h + b[:, 0]
        h = h1[:, None, :]
        new_state = RGLRUState(conv=new_conv, h=h1)

    y = (h.astype(x.dtype) * gate) @ p["w_out"]
    return y, new_state


# --------------------------------------------------------------------------
# Mamba-2 SSD block (state-space duality, chunked)
# --------------------------------------------------------------------------
def ssd_defs(cfg: ModelConfig) -> dict:
    D, DI, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    pd = cfg.param_dtype
    conv_ch = DI + 2 * N
    return {
        "wz": ParamDef((D, DI), ("embed", "ssm_inner"), pd, init="lecun"),
        "wx": ParamDef((D, DI), ("embed", "ssm_inner"), pd, init="lecun"),
        "wB": ParamDef((D, N), ("embed", "ssm_state"), pd, init="lecun"),
        "wC": ParamDef((D, N), ("embed", "ssm_state"), pd, init="lecun"),
        "wdt": ParamDef((D, H), ("embed", "ssm_heads"), pd, init="lecun"),
        "dt_bias": ParamDef((H,), ("ssm_heads",), jnp.float32, init="zeros"),
        "A_log": ParamDef((H,), ("ssm_heads",), jnp.float32, init="ones"),
        "D_skip": ParamDef((H,), ("ssm_heads",), jnp.float32, init="ones"),
        "conv_w": ParamDef((4, conv_ch), ("conv", None), jnp.float32,
                           init="normal", scale=0.5),
        "conv_b": ParamDef((conv_ch,), (None,), jnp.float32, init="zeros"),
        "norm": ParamDef((DI,), ("ssm_inner",), jnp.float32, init="ones"),
        "w_out": ParamDef((DI, D), ("ssm_inner", "embed"), pd, init="lecun"),
    }


class SSDState(NamedTuple):
    conv: jnp.ndarray   # (B, 3, DI + 2N)
    h: jnp.ndarray      # (B, H, P, N) f32


def ssd_init_state(cfg: ModelConfig, batch: int) -> SSDState:
    DI, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    return SSDState(conv=jnp.zeros((batch, 3, DI + 2 * N), cfg.compute_dtype),
                    h=jnp.zeros((batch, H, P, N), jnp.float32))


def _gated_rmsnorm(y: jnp.ndarray, z: jnp.ndarray, scale: jnp.ndarray,
                   eps: float) -> jnp.ndarray:
    g = (y * jax.nn.silu(z)).astype(jnp.float32)
    out = g * jax.lax.rsqrt((g ** 2).mean(-1, keepdims=True) + eps) * scale
    return out


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """a: (..., Q) -> (..., Q, Q) lower-tri pairwise sums
    L[i,j] = sum_{j < k <= i} a_k  (i >= j), -inf above diagonal."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    ii = jnp.arange(Q)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_block(p: dict, x: jnp.ndarray, cfg: ModelConfig,
              state: SSDState | None = None, *,
              return_state: bool = False
              ) -> tuple[jnp.ndarray, SSDState | None]:
    """Mamba-2 mixer. x: (B,S,D) -> (B,S,D).  Modes as in rglru_block."""
    Bsz, S, D = x.shape
    DI, N = cfg.d_inner, cfg.ssm_state
    H, P = cfg.ssm_heads, cfg.ssm_headdim
    z = x @ p["wz"]
    xc_raw = jnp.concatenate([x @ p["wx"], x @ p["wB"], x @ p["wC"]], axis=-1)
    xc = xc_raw
    dt = jax.nn.softplus(
        (x @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])     # (B,S,H)
    A = -jnp.exp(p["A_log"])                                    # (H,)

    if state is None:
        xc = jax.nn.silu(causal_conv(xc, p["conv_w"], p["conv_b"])
                         ).astype(x.dtype)
        xs, Bm, Cm = jnp.split(xc, [DI, DI + N], axis=-1)
        xs = xs.reshape(Bsz, S, H, P)
        # pad S to a chunk multiple; padded steps get dt=0 (identity decay,
        # zero input) so outputs before S and the final state are exact.
        Q = cfg.ssm_chunk
        S_pad = -(-S // Q) * Q
        if S_pad != S:
            pad = ((0, 0), (0, S_pad - S))
            xs = jnp.pad(xs, pad + ((0, 0), (0, 0)))
            dt = jnp.pad(dt, pad + ((0, 0),))
            Bm = jnp.pad(Bm, pad + ((0, 0),))
            Cm = jnp.pad(Cm, pad + ((0, 0),))
        y, h_final = _ssd_chunked(xs, dt, A, Bm, Cm, p["D_skip"],
                                  cfg.ssm_chunk)
        if S_pad != S:
            y = y[:, :S]
        new_state = None
        if return_state:
            tail = xc_raw[:, -3:, :]
            pad = 3 - tail.shape[1]
            if pad > 0:
                tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
            new_state = SSDState(conv=tail.astype(cfg.compute_dtype),
                                 h=h_final)
    else:
        new_conv, xc1 = conv_step(state.conv, xc, p["conv_w"], p["conv_b"])
        xc1 = jax.nn.silu(xc1).astype(x.dtype)
        xs, Bm, Cm = jnp.split(xc1, [DI, DI + N], axis=-1)
        xs = xs.reshape(Bsz, 1, H, P)
        dtA = jnp.exp(dt[:, 0] * A)                             # (B,H)
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0],
                         Bm[:, 0].astype(jnp.float32),
                         xs[:, 0].astype(jnp.float32))
        h = dtA[:, :, None, None] * state.h + dBx
        y = jnp.einsum("bhpn,bn->bhp", h, Cm[:, 0].astype(jnp.float32))
        y = y + p["D_skip"][:, None] * xs[:, 0].astype(jnp.float32)
        y = y.reshape(Bsz, 1, DI)
        new_state = SSDState(conv=new_conv, h=h)

    y = _gated_rmsnorm(y.astype(jnp.float32), z.astype(jnp.float32),
                       p["norm"], cfg.norm_eps).astype(x.dtype)
    return y @ p["w_out"], new_state


def _ssd_chunked(xs, dt, A, Bm, Cm, D_skip, Q: int) -> jnp.ndarray:
    """Chunked SSD scan (Mamba-2 Alg. 1, single B/C group).

    xs: (B,S,H,P); dt: (B,S,H) f32; A: (H,); Bm/Cm: (B,S,N).
    Sequential lax.scan across S/Q chunks carrying the (B,H,P,N) state;
    quadratic attention-like compute within each chunk.
    """
    Bsz, S, H, P = xs.shape
    N = Bm.shape[-1]
    nc = S // Q
    assert nc * Q == S, (S, Q)
    xs = xs.reshape(Bsz, nc, Q, H, P).astype(jnp.float32)
    dt = dt.reshape(Bsz, nc, Q, H)
    Bm = Bm.reshape(Bsz, nc, Q, N).astype(jnp.float32)
    Cm = Cm.reshape(Bsz, nc, Q, N).astype(jnp.float32)

    a = dt * A                                  # (B,nc,Q,H) log-decay
    a_cs = jnp.cumsum(a, axis=2)
    # intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(a.transpose(0, 1, 3, 2)))          # (B,nc,H,Q,Q)
    y_diag = jnp.einsum("bcln,bcsn,bchls,bcsh,bcshp->bclhp",
                        Cm, Bm, L, dt, xs)
    # per-chunk input states
    decay_states = jnp.exp(a_cs[:, :, -1:, :] - a_cs)      # (B,nc,Q,H)
    states = jnp.einsum("bcsn,bcsh,bcsh,bcshp->bchpn",
                        Bm, decay_states, dt, xs)
    chunk_decay = jnp.exp(a_cs[:, :, -1, :])               # (B,nc,H)

    # inter-chunk linear recurrence h_c = cd_c * h_{c-1} + st_c via
    # associative scan over the chunk axis (log-depth, while-free — fully
    # visible to HLO cost analysis, unlike lax.scan's hidden trip count)
    def op(c1, c2):
        a1, s1 = c1
        a2, s2 = c2
        return a1 * a2, a2[:, :, :, None, None] * s1 + s2

    _, h_inc = jax.lax.associative_scan(op, (chunk_decay, states), axis=1)
    h_last = h_inc[:, -1]                                  # (B,H,P,N)
    h_prev = jnp.concatenate(
        [jnp.zeros_like(h_inc[:, :1]), h_inc[:, :-1]], axis=1)
    # inter-chunk contribution
    y_off = jnp.einsum("bcln,bclh,bchpn->bclhp",
                       Cm, jnp.exp(a_cs), h_prev)
    y = y_diag + y_off + D_skip[:, None] * xs              # (B,nc,Q,H,P)
    return y.reshape(Bsz, S, H * P), h_last
