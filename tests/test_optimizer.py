"""Optimizers, schedules, ZeRO-1 pspecs, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import compression
from repro.train import optimizer as opt_lib
from repro.train import schedules
from repro.train.optimizer import OptimizerConfig


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_minimizes_quadratic(name):
    target = jnp.asarray(np.random.default_rng(0).standard_normal((4, 256)),
                         jnp.float32)
    params = {"w": jnp.zeros((4, 256), jnp.float32)}
    cfg = OptimizerConfig(name=name, lr=0.1, weight_decay=0.0,
                          factored_min_dim=4)
    state = opt_lib.init(params, cfg)

    def loss_fn(p):
        return jnp.mean((p["w"] - target) ** 2)

    loss0 = float(loss_fn(params))
    for _ in range(150):
        grads = jax.grad(loss_fn)(params)
        params, state, _ = opt_lib.update(grads, state, params, cfg,
                                          jnp.float32(0.1))
    assert float(loss_fn(params)) < 0.05 * loss0


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros((8,), jnp.float32)}
    cfg = OptimizerConfig(name="adamw", lr=1.0, grad_clip=1.0,
                          weight_decay=0.0)
    state = opt_lib.init(params, cfg)
    huge = {"w": jnp.full((8,), 1e6, jnp.float32)}
    _, _, gn = opt_lib.update(huge, state, params, cfg, jnp.float32(1.0))
    assert float(gn) > 1e5  # reported norm is pre-clip


def test_schedules():
    cos = schedules.make("cosine", peak_lr=1.0, warmup=10, total=100)
    assert float(cos(0)) == 0.0
    assert float(cos(10)) == pytest.approx(1.0, abs=1e-3)
    assert float(cos(100)) == pytest.approx(0.1, abs=1e-3)
    w = schedules.make("wsd", peak_lr=1.0, warmup=10, total=100,
                       decay_fraction=0.2)
    assert float(w(50)) == 1.0                     # stable plateau
    assert float(w(99)) < 0.1                      # decay tail
    assert float(w(5)) == pytest.approx(0.5, abs=1e-6)


def test_zero1_pspec_places_data_axis():
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()        # (1, 1) ("data", "model") on one device
    # dim0 replicated & divisible -> gets 'data'
    assert opt_lib.zero1_pspec(P(None, "model"), (8, 16), mesh) \
        == P("data", "model")
    # model dim untouched, no divisible dim -> unchanged
    assert opt_lib.zero1_pspec(P("model",), (7,), mesh) == P("model")


def test_compression_error_feedback_telescopes():
    """Sum of dequantized grads converges to sum of true grads — the error
    feedback invariant that makes int8 cross-pod reduction safe."""
    rng = np.random.default_rng(0)
    grads = [{"w": jnp.asarray(rng.standard_normal((64,)) * (10.0 ** rng.integers(-3, 3)), jnp.float32)}
             for _ in range(50)]
    err = compression.init_error(grads[0])
    applied_sum = jnp.zeros((64,))
    true_sum = jnp.zeros((64,))
    for g in grads:
        deq, err = compression.compress_grads(g, err)
        applied_sum = applied_sum + deq["w"]
        true_sum = true_sum + g["w"]
    resid = float(jnp.abs(applied_sum - true_sum).max())
    # residual is bounded by one quantization step, not O(n_steps)
    last_scale = float(jnp.max(jnp.abs(grads[-1]["w"] + err["w"]))) / 127.0
    assert resid <= 2 * max(last_scale, 1e-6)


def test_int8_quantize_roundtrip_bound():
    x = jnp.asarray(np.random.default_rng(1).standard_normal(1000),
                    jnp.float32)
    q, s = compression.int8_quantize(x)
    err = jnp.abs(compression.int8_dequantize(q, s) - x)
    assert float(err.max()) <= float(s) * 0.5 + 1e-7
