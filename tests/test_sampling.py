"""Samplers: shape contracts + every sampled edge is a real edge
(property), host/device agreement on the neighbor relation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.graph.synthetic import rmat_graph, uniform_graph
from repro.sampling.ladies import ladies_sample_blocks
from repro.sampling.neighbor import (device_sample_blocks,
                                     host_sample_blocks, subgraph_sizes)


def _edge_set(g):
    es = set()
    for v in range(g.num_nodes):
        for u in g.neighbors(v):
            es.add((v, int(u)))
    return es


@given(seed=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_host_sampler_edges_are_real(seed):
    g = rmat_graph(500, 6, 8, seed=seed % 7)
    rng = np.random.default_rng(seed)
    seeds = rng.integers(0, g.num_nodes, 16)
    blocks = host_sample_blocks(g, seeds, (3, 2), rng)
    assert blocks.hop_nodes[0].shape == (16 * 3,)
    assert blocks.hop_nodes[1].shape == (16 * 3 * 2,)
    es = _edge_set(g)
    frontier = seeds
    for f, hop in zip((3, 2), blocks.hop_nodes):
        parents = np.repeat(frontier, f)
        for p, c in zip(parents, hop):
            assert (int(p), int(c)) in es or int(p) == int(c)  # self-pad
        frontier = hop


def test_device_sampler_matches_contract():
    g = uniform_graph(400, 8, 4, seed=1)
    csr = g.to_device()
    seeds = jnp.arange(8, dtype=jnp.int32)
    hops, flat = jax.jit(
        lambda s, k: device_sample_blocks(csr, s, (4, 2), k)
    )(seeds, jax.random.PRNGKey(0))
    assert hops[0].shape == (8 * 4,)
    assert hops[1].shape == (8 * 4 * 2,)
    assert flat.shape == (8 + 32 + 64,)
    es = _edge_set(g)
    parents = np.repeat(np.asarray(seeds), 4)
    for p, c in zip(parents, np.asarray(hops[0])):
        assert (int(p), int(c)) in es or int(p) == int(c)


def test_subgraph_sizes_closed_form():
    assert subgraph_sizes(1, (3, 2)) == 1 + 3 + 6  # paper Fig. 2
    assert subgraph_sizes(4, (10, 5, 5)) == 4 * (1 + 10 + 50 + 250)


def test_ladies_fixed_layer_sizes():
    g = rmat_graph(1000, 8, 8, seed=2)
    rng = np.random.default_rng(0)
    blocks = ladies_sample_blocks(g, rng.integers(0, 1000, 32),
                                  (64, 64), rng)
    assert blocks.hop_nodes[0].shape == (64,)
    assert blocks.hop_nodes[1].shape == (64,)
    assert blocks.num_requests == 32 + 64 + 64


def test_ladies_importance_bias():
    """High in-degree nodes should be sampled more often by LADIES."""
    g = rmat_graph(2000, 10, 8, seed=3)
    rng = np.random.default_rng(1)
    counts = np.zeros(g.num_nodes)
    for _ in range(20):
        blocks = ladies_sample_blocks(g, rng.integers(0, 2000, 16),
                                      (128,), rng)
        counts[blocks.hop_nodes[0]] += 1
    indeg = np.bincount(g.indices, minlength=g.num_nodes)
    hot = np.argsort(-indeg)[:100]
    cold = np.argsort(-indeg)[-1000:]
    assert counts[hot].mean() > counts[cold].mean()
