"""Two-tier feature store — the data plane of the GIDS dataloader.

Tier 0: device software cache (HBM)      — window-buffered, §3.4
Tier 1: constant host buffer (pinned)    — hot nodes, §3.3
Tier 2: storage (memmap file or array)   — everything, §3.1

`gather()` is a *real* data path: it returns the actual feature rows (from a
numpy memmap standing in for the SSD namespace) and a `GatherReport` with the
tier split, which the storage simulator prices for benchmarks and the
accumulator consumes as telemetry.  The device-side gather of cached rows is
performed by the `tiered_gather` Pallas kernel when running jitted.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile

import numpy as np

from .constant_buffer import ConstantBuffer
from .software_cache import WindowBufferedCache


@dataclasses.dataclass
class GatherReport:
    n_requests: int
    n_hbm_hits: int
    n_host_hits: int
    n_storage: int
    feat_bytes: int

    @property
    def redirected(self) -> int:
        return self.n_hbm_hits + self.n_host_hits


class FeatureStore:
    def __init__(self, features: np.ndarray,
                 cache: WindowBufferedCache | None = None,
                 constant_buffer: ConstantBuffer | None = None):
        self.features = features
        self.cache = cache
        self.cbuf = constant_buffer
        self.feature_dim = features.shape[1]
        self.itemsize = features.dtype.itemsize

    # -- construction ---------------------------------------------------------
    @classmethod
    def memmap(cls, path: str, num_nodes: int, dim: int,
               dtype=np.float32, create: bool = False, seed: int = 0,
               **kw) -> "FeatureStore":
        """Features in a file accessed via memmap — the storage namespace.
        (The mmap *baseline dataloader* also reads through this; GIDS differs
        in the orchestration around it, not the bytes.)"""
        mode = "w+" if create else "r+"
        arr = np.memmap(path, dtype=dtype, mode=mode, shape=(num_nodes, dim))
        if create:
            rng = np.random.default_rng(seed)
            step = max(1, num_nodes // 64)
            for i in range(0, num_nodes, step):
                j = min(num_nodes, i + step)
                arr[i:j] = rng.standard_normal((j - i, dim), dtype=np.float32)
            arr.flush()
        return cls(arr, **kw)

    @classmethod
    def synthetic(cls, num_nodes: int, dim: int, dtype=np.float32,
                  seed: int = 0, **kw) -> "FeatureStore":
        rng = np.random.default_rng(seed)
        feats = rng.standard_normal((num_nodes, dim)).astype(dtype)
        return cls(feats, **kw)

    # -- data plane -----------------------------------------------------------
    def gather(self, node_ids: np.ndarray) -> tuple[np.ndarray, GatherReport]:
        """Fetch feature rows for (deduplicated) node_ids through the tiers."""
        n = len(node_ids)
        hbm_hits = np.zeros(n, dtype=bool)
        if self.cache is not None:
            hbm_hits = self.cache.access(node_ids)
        host_hits = np.zeros(n, dtype=bool)
        if self.cbuf is not None:
            host_hits = ~hbm_hits & self.cbuf.redirect_mask(node_ids)
        n_storage = int(n - hbm_hits.sum() - host_hits.sum())
        rows = np.asarray(self.features[node_ids])
        report = GatherReport(
            n_requests=n,
            n_hbm_hits=int(hbm_hits.sum()),
            n_host_hits=int(host_hits.sum()),
            n_storage=n_storage,
            feat_bytes=self.feature_dim * self.itemsize,
        )
        return rows, report

    def push_window(self, future_nodes: np.ndarray) -> None:
        if self.cache is not None:
            self.cache.push_window(future_nodes)
