"""Span-sum reconciliation: the trace is an ACCOUNTING of priced time,
so every batch span tree must sum to its `Batch.prep_time_s` and every
serve record's latency breakdown must sum to its end-to-end latency —
exactly for the training pipeline (the spans are built from the very
floats the pricing produced) and within float eps for the serve plane
(whose breakdown re-associates sums).

The sweep runs as seeded parametrized cases everywhere; when `hypothesis`
is installed the same invariants are additionally fuzzed over random
loader shapes."""
import numpy as np
import pytest

from repro.core import GIDSDataLoader, LoaderConfig
from repro.graph.synthetic import rmat_graph
from repro.obs import Tracer, validate_trace

EPS = 1e-9


@pytest.fixture(scope="module")
def graph_and_feats():
    g = rmat_graph(4_000, 12, 16, seed=2)
    feats = np.random.default_rng(1).standard_normal(
        (g.num_nodes, 24)).astype(np.float32)
    return g, feats


def _run_traced(g, feats, preset, n_batches=8, **kw):
    tr = Tracer()
    dl = GIDSDataLoader(g, feats, LoaderConfig(
        batch_size=128, fanouts=(5, 5), data_plane=preset,
        cache_lines=2048, window_depth=4, **kw), tracer=tr)
    batches = [dl.next_batch() for _ in range(n_batches)]
    return tr, batches


PRESETS = [
    ("gids", {}),
    ("gids-merged", {}),
    ("gids-topo-merged", {}),
    ("gids-merged-sharded", {"n_shards": 4}),
    ("gids-hosts-merged", {"n_hosts": 4, "placement": "metis-lite"}),
]


@pytest.mark.parametrize("preset,kw", PRESETS,
                         ids=[p for p, _ in PRESETS])
def test_batch_span_tree_sums_to_prep_time(graph_and_feats, preset, kw):
    """Each batch root's duration IS its prep time, and its sequential
    children account for it with zero (exact float) error — the spans are
    built from the same floats the pricing path produced."""
    g, feats = graph_and_feats
    tr, batches = _run_traced(g, feats, preset, **kw)
    roots = [r for r in tr.roots() if r.name == "batch"]
    assert len(roots) == len(batches)
    for root, batch in zip(roots, batches):
        assert root.dur == batch.prep_time_s
        assert root.reconcile_error() == 0.0
    assert tr.max_reconcile_error() <= EPS
    assert validate_trace(tr) == []


@pytest.mark.parametrize("preset,kw", PRESETS,
                         ids=[p for p, _ in PRESETS])
def test_window_spans_account_merged_bursts(graph_and_feats, preset, kw):
    """On merged planes the window span's duration equals the sum of its
    member batches' gather shares plus the feedback charge — i.e. merged
    amortization is conserved, nothing is double- or under-counted."""
    g, feats = graph_and_feats
    tr, _ = _run_traced(g, feats, preset, **kw)
    batch_roots = [r for r in tr.roots() if r.name == "batch"]
    for win in (r for r in tr.roots() if r.name == "window"):
        gather = next(c for c in win.children if c.name == "merged_gather")
        members = [b for b in batch_roots
                   if b.args.get("window") == win.args["index"]]
        assert len(members) == win.args["batches"]
        shares = [sp.dur for b in members for sp in b.walk()
                  if sp.name == "gather_share"]
        assert len(shares) == len(members)
        assert abs(sum(shares) - win.dur) <= EPS * max(win.dur, 1.0)
        assert gather.dur <= win.dur + EPS


def test_serve_breakdown_sums_to_latency():
    """Every served record: queue wait + window burst + batched forward
    == end-to-end latency (the span children), and the request's OWN
    shares never exceed the window totals."""
    from repro.serve import (GNNServeConfig, GNNServeEngine, TenantSpec,
                             generate_stream)
    g = rmat_graph(2_000, 10, 16, seed=3)
    feats = np.random.default_rng(0).standard_normal(
        (g.num_nodes, 16)).astype(np.float32)
    reqs = generate_stream(
        g.num_nodes, [TenantSpec("a"), TenantSpec("b", arrival="mmpp")],
        offered_qps=2000, n_requests=48, seed=5)
    tr = Tracer()
    cfg = GNNServeConfig(fanouts=(5, 3), cache_lines=512, tenants=2)
    result = GNNServeEngine(g, feats, cfg, tracer=tr).run(reqs)

    req_spans = {sp.args["rid"]: sp for sp in tr.roots()
                 if sp.name == "request"}
    assert len(req_spans) == len(result.served)
    for rec in result.served:
        sp = req_spans[rec.rid]
        assert sp.reconcile_error() <= EPS
        assert abs(sp.dur - rec.latency_s) <= EPS
        parts = {c.name: c for c in sp.children}
        assert parts["queue_wait"].dur == rec.queue_wait_s
        # the record's shares are fractions of the window totals
        assert rec.gather_s <= parts["gather"].dur + EPS
        assert rec.forward_s <= parts["forward"].dur + EPS
    assert validate_trace(tr) == []


def test_serve_window_spans_match_window_traces():
    from repro.serve import (GNNServeConfig, GNNServeEngine, TenantSpec,
                             generate_stream)
    g = rmat_graph(2_000, 10, 16, seed=3)
    feats = np.random.default_rng(0).standard_normal(
        (g.num_nodes, 16)).astype(np.float32)
    reqs = generate_stream(g.num_nodes, [TenantSpec("a")],
                           offered_qps=1500, n_requests=32, seed=7)
    tr = Tracer()
    result = GNNServeEngine(
        g, feats, GNNServeConfig(fanouts=(4, 3), cache_lines=512),
        tracer=tr).run(reqs)
    spans = [r for r in tr.roots() if r.name == "serve_window"]
    assert len(spans) == len(result.windows)
    for sp, w in zip(spans, result.windows):
        assert sp.t0 == w.start_s
        assert sp.dur == w.service_s
        gather = next(c for c in sp.children if c.name == "gather")
        assert gather.dur == w.burst_s
        assert sp.reconcile_error() <= EPS


def test_modelled_vs_measured_gap_recorded_per_stage(graph_and_feats):
    g, feats = graph_and_feats
    tr, _ = _run_traced(g, feats, "gids-topo-merged")
    snap = tr.metrics.snapshot()
    gaps = {k: v for k, v in snap.items()
            if k.startswith("modelled_vs_measured.")}
    assert {"modelled_vs_measured.plan_next",
            "modelled_vs_measured.execute_window",
            "modelled_vs_measured.sample"} <= set(gaps)
    for series in gaps.values():
        for p in series["points"]:
            assert p["gap_s"] == p["measured_s"] - p["modelled_s"]
            assert p["measured_s"] >= 0.0


# -- fuzzed sweep (hypothesis when installed, seeded grid otherwise) -----------

try:
    import hypothesis
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # the container may not ship hypothesis
    HAVE_HYPOTHESIS = False


def _check_reconciles(batch_size, fanout, window_depth, n_batches):
    g = rmat_graph(1_000, 8, 8, seed=4)
    feats = np.zeros((g.num_nodes, 8), np.float32)
    tr = Tracer()
    dl = GIDSDataLoader(g, feats, LoaderConfig(
        batch_size=batch_size, fanouts=(fanout, fanout),
        data_plane="gids-merged", cache_lines=1024,
        window_depth=window_depth), tracer=tr)
    batches = [dl.next_batch() for _ in range(n_batches)]
    roots = [r for r in tr.roots() if r.name == "batch"]
    for root, batch in zip(roots, batches):
        assert root.dur == batch.prep_time_s
        assert root.reconcile_error() == 0.0
    assert validate_trace(tr) == []


if HAVE_HYPOTHESIS:
    @hypothesis.given(batch_size=st.integers(16, 256),
                      fanout=st.integers(2, 8),
                      window_depth=st.integers(1, 6),
                      n_batches=st.integers(1, 10))
    @hypothesis.settings(max_examples=20, deadline=None)
    def test_reconciliation_fuzzed(batch_size, fanout, window_depth,
                                   n_batches):
        _check_reconciles(batch_size, fanout, window_depth, n_batches)
else:
    @pytest.mark.parametrize("batch_size,fanout,window_depth,n_batches", [
        (16, 2, 1, 3), (64, 5, 3, 7), (256, 8, 6, 10), (37, 3, 2, 5),
        (128, 6, 4, 8),
    ])
    def test_reconciliation_fuzzed(batch_size, fanout, window_depth,
                                   n_batches):
        _check_reconciles(batch_size, fanout, window_depth, n_batches)
