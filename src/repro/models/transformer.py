"""Unified LM covering all 10 assigned architectures.

One model class; the config selects the layer plan:

  dense / vlm        [("attn_dense",) x L]                    (scan)
  moe interleave=1   [("attn_moe",) x L]                      (arctic)
  moe interleave=2   [("attn_dense", "attn_moe") x L/2]       (llama4)
  hybrid 1:2         [("rec","rec","attn") x L//3 + remainder] (recurrentgemma)
  ssm                [("ssm",) x L]                           (mamba2)
  encdec             encoder [("enc",) x Le] + decoder [("dec",) x Ld]

Layers are stacked along a leading axis and executed with `jax.lax.scan`
(compile time independent of depth; remat-able per group).  Serving carries a
per-group cache pytree (KV / RG-LRU / SSD states) through the same scan.

Sharding: models are mesh-agnostic; activation constraints are applied via a
context (`activation_sharding`) set by the launcher, so the same forward
lowers for 1 CPU device (smoke tests) or a 512-chip mesh (dry-run).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import recurrent as R
from repro.models.common import (ModelConfig, ParamDef, init_params,
                                 tree_map_defs)

from repro.distributed.ctx import activation_sharding, constrain as _constrain  # noqa: F401 (re-export)


# --------------------------------------------------------------------------
# per-kind block definitions
# --------------------------------------------------------------------------
def _block_defs(cfg: ModelConfig, kind: str) -> dict:
    if kind == "attn_dense":
        return {"ln1": L.norm_defs(cfg), "attn": L.attention_defs(cfg),
                "ln2": L.norm_defs(cfg), "mlp": L.mlp_defs(cfg)}
    if kind == "attn_moe":
        return {"ln1": L.norm_defs(cfg), "attn": L.attention_defs(cfg),
                "ln2": L.norm_defs(cfg), "moe": L.moe_defs(cfg)}
    if kind == "rec":
        return {"ln1": L.norm_defs(cfg), "rec": R.rglru_defs(cfg),
                "ln2": L.norm_defs(cfg), "mlp": L.mlp_defs(cfg)}
    if kind == "attn_local":
        return {"ln1": L.norm_defs(cfg), "attn": L.attention_defs(cfg),
                "ln2": L.norm_defs(cfg), "mlp": L.mlp_defs(cfg)}
    if kind == "ssm":
        return {"ln1": L.norm_defs(cfg), "ssm": R.ssd_defs(cfg)}
    if kind == "enc":
        return {"ln1": L.norm_defs(cfg), "attn": L.attention_defs(cfg),
                "ln2": L.norm_defs(cfg), "mlp": L.mlp_defs(cfg)}
    if kind == "dec":
        return {"ln1": L.norm_defs(cfg), "attn": L.attention_defs(cfg),
                "lnx": L.norm_defs(cfg), "xattn": L.attention_defs(cfg),
                "ln2": L.norm_defs(cfg), "mlp": L.mlp_defs(cfg)}
    raise ValueError(kind)


def _stack(defs: Any, n: int) -> Any:
    return tree_map_defs(
        lambda d: ParamDef((n,) + d.shape, ("layers",) + d.axes, d.dtype,
                           d.init, d.scale), defs)


# --------------------------------------------------------------------------
# cache structures (per kind)
# --------------------------------------------------------------------------
def _kv_cache_shape(cfg: ModelConfig, batch: int, seq: int):
    return (batch, seq, cfg.num_kv_heads, cfg.hd)


def _init_block_cache(cfg: ModelConfig, kind: str, batch: int, seq: int,
                      enc_seq: int = 0):
    dt = cfg.compute_dtype
    if kind in ("attn_dense", "attn_moe", "attn_local"):
        shape = _kv_cache_shape(cfg, batch, seq)
        return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
    if kind == "rec":
        return R.rglru_init_state(cfg, batch)._asdict()
    if kind == "ssm":
        return R.ssd_init_state(cfg, batch)._asdict()
    if kind == "dec":
        shape = _kv_cache_shape(cfg, batch, seq)
        xshape = _kv_cache_shape(cfg, batch, enc_seq)
        return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
                "xk": jnp.zeros(xshape, dt), "xv": jnp.zeros(xshape, dt)}
    raise ValueError(kind)


# --------------------------------------------------------------------------
# the model
# --------------------------------------------------------------------------
class LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.plan = self._layer_plan()          # [(kinds tuple, n_groups)]

    # ---- plan ----------------------------------------------------------------
    def _layer_plan(self) -> list[tuple[tuple[str, ...], int]]:
        cfg = self.cfg
        if cfg.family == "ssm":
            return [(("ssm",), cfg.num_layers)]
        if cfg.family == "hybrid":
            k = cfg.hybrid_attn_every
            group = ("rec",) * (k - 1) + ("attn_local",)
            n, rem = divmod(cfg.num_layers, k)
            plan = [(group, n)]
            if rem:
                plan.append((("rec",) * rem, 1))
            return plan
        if cfg.family == "encdec":
            return [(("dec",), cfg.num_layers)]
        if cfg.moe_experts:
            il = cfg.moe_interleave
            group = ("attn_dense",) * (il - 1) + ("attn_moe",)
            assert cfg.num_layers % il == 0, (cfg.num_layers, il)
            return [(group, cfg.num_layers // il)]
        return [(("attn_dense",), cfg.num_layers)]

    # ---- params ----------------------------------------------------------------
    def param_defs(self) -> dict:
        cfg = self.cfg
        V, D = cfg.padded_vocab, cfg.d_model
        defs: dict = {
            "embed": ParamDef((V, D), ("vocab", "embed"), cfg.param_dtype,
                              init="normal"),
            "final_norm": L.norm_defs(cfg),
            "stacks": [
                _stack({f"b{i}": _block_defs(cfg, kind)
                        for i, kind in enumerate(kinds)}, n)
                for kinds, n in self.plan
            ],
        }
        if not cfg.tie_embeddings:
            defs["unembed"] = ParamDef((D, V), ("embed", "vocab"),
                                       cfg.param_dtype, init="normal")
        if cfg.pos_embed == "learned":
            defs["pos"] = ParamDef((cfg.max_position, D), (None, "embed"),
                                   cfg.param_dtype, init="normal")
        if cfg.family == "encdec":
            defs["encoder"] = {
                "stack": _stack({"b0": _block_defs(cfg, "enc")},
                                cfg.encoder_layers),
                "final_norm": L.norm_defs(cfg),
                "pos": ParamDef((cfg.encoder_seq, D), (None, "embed"),
                                cfg.param_dtype, init="normal"),
                "frontend": ParamDef((D, D), ("embed", None),
                                     cfg.param_dtype, init="lecun"),
            }
        if cfg.frontend == "vision_stub":
            defs["frontend"] = ParamDef((D, D), ("embed", None),
                                        cfg.param_dtype, init="lecun")
        return defs

    def init(self, key: jax.Array) -> dict:
        return init_params(self.param_defs(), key)

    # ---- blocks ----------------------------------------------------------------
    def _apply_block(self, kind: str, p: dict, x, *, mode="train",
                     cache=None, index=None, enc_out=None):
        """mode: train (no cache) | prefill (seq, fill cache) | decode
        (single step against cache)."""
        cfg = self.cfg
        res_scale = cfg.residual_scale
        new_cache = dict(cache) if cache is not None else None
        if kind in ("attn_dense", "attn_moe", "attn_local", "enc", "dec"):
            h = L.apply_norm(p["ln1"], x, cfg)
            window = None
            if kind == "attn_local":
                window = cfg.local_window
            elif cfg.attn_window is not None:
                window = cfg.attn_window
            kv = None if cache is None else (cache["k"], cache["v"])
            a, kv_new = L.attention(p["attn"], h, cfg, kv_cache=kv,
                                    cache_index=index,
                                    causal=(kind != "enc"), window=window)
            if kv_new is not None:
                new_cache["k"], new_cache["v"] = kv_new
            x = x + res_scale * a
            if kind == "dec":
                h = L.apply_norm(p["lnx"], x, cfg)
                xkv = (cache["xk"], cache["xv"]) if cache is not None else None
                a, xkv_new = L.attention(
                    p["xattn"], h, cfg, kv_x=enc_out, kv_cache=xkv,
                    cache_index=None, causal=False,
                    static_kv=cache is not None)
                if cache is not None and xkv_new is not None:
                    new_cache["xk"], new_cache["xv"] = xkv_new
                x = x + res_scale * a
            h = L.apply_norm(p["ln2"], x, cfg)
            if kind == "attn_moe":
                m = L.moe_block(p["moe"], h, cfg)
            else:
                m = L.mlp(p["mlp"], h, cfg)
            return x + res_scale * m, new_cache
        if kind == "rec":
            h = L.apply_norm(p["ln1"], x, cfg)
            st = R.RGLRUState(**cache) if mode == "decode" else None
            r, st_new = R.rglru_block(p["rec"], h, cfg, st,
                                      return_state=(mode == "prefill"))
            if st_new is not None:
                new_cache = st_new._asdict()
            x = x + res_scale * r
            h = L.apply_norm(p["ln2"], x, cfg)
            return x + res_scale * L.mlp(p["mlp"], h, cfg), new_cache
        if kind == "ssm":
            h = L.apply_norm(p["ln1"], x, cfg)
            st = R.SSDState(**cache) if mode == "decode" else None
            s, st_new = R.ssd_block(p["ssm"], h, cfg, st,
                                    return_state=(mode == "prefill"))
            if st_new is not None:
                new_cache = st_new._asdict()
            return x + res_scale * s, new_cache
        raise ValueError(kind)

    # ---- stacked application ----------------------------------------------------
    def _run_stacks(self, params: dict, x, *, mode="train", caches=None,
                    index=None, enc_out=None):
        cfg = self.cfg
        new_caches = []
        for si, (kinds, n) in enumerate(self.plan):
            stack_params = params["stacks"][si]
            stack_cache = None if caches is None else caches[si]

            if stack_cache is None:
                def train_fn(carry, gp, _kinds=kinds):
                    h = carry
                    for i, kind in enumerate(_kinds):
                        h, _ = self._apply_block(kind, gp[f"b{i}"], h,
                                                 mode="train",
                                                 enc_out=enc_out)
                    return h, 0.0

                fn = train_fn
                if cfg.remat:
                    fn = jax.checkpoint(
                        train_fn,
                        policy=jax.checkpoint_policies.nothing_saveable)
                if cfg.scan_unroll:
                    for g in range(n):
                        gp = jax.tree.map(lambda a: a[g], stack_params)
                        x, _ = fn(x, gp)
                else:
                    x, _ = jax.lax.scan(fn, x, stack_params)
                new_caches.append(None)
            else:
                def serve_fn(carry, xs, _kinds=kinds):
                    h = carry
                    gp, gc = xs
                    out_c = {}
                    for i, kind in enumerate(_kinds):
                        h, nc = self._apply_block(kind, gp[f"b{i}"], h,
                                                  mode=mode,
                                                  cache=gc[f"b{i}"],
                                                  index=index,
                                                  enc_out=enc_out)
                        out_c[f"b{i}"] = nc
                    return h, out_c

                if cfg.scan_unroll:
                    outs = []
                    for g in range(n):
                        gp = jax.tree.map(lambda a: a[g], stack_params)
                        gc = jax.tree.map(lambda a: a[g], stack_cache)
                        x, oc = serve_fn(x, (gp, gc))
                        outs.append(oc)
                    out_caches = jax.tree.map(
                        lambda *xs: jnp.stack(xs), *outs)
                else:
                    x, out_caches = jax.lax.scan(serve_fn, x,
                                                 (stack_params, stack_cache))
                new_caches.append(out_caches)
        return x, new_caches

    # ---- embedding / head ----------------------------------------------------
    def _embed(self, params: dict, batch: dict):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = params["embed"][tokens].astype(cfg.compute_dtype)
        x = x * cfg.embed_scale
        if cfg.pos_embed == "learned":
            pos = batch.get("positions")
            if pos is None:
                pos = jnp.arange(tokens.shape[1])
            x = x + params["pos"][pos].astype(x.dtype)
        if cfg.frontend == "vision_stub" and "patches" in batch:
            pe = batch["patches"].astype(x.dtype) @ params["frontend"]
            x = jnp.concatenate([pe, x], axis=1)
        return _constrain(x, "activations")

    def _head(self, params: dict, x) -> jnp.ndarray:
        cfg = self.cfg
        x = L.apply_norm(params["final_norm"], x, cfg)
        if cfg.tie_embeddings:
            logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
        else:
            logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])
        # mask padded vocab
        if cfg.padded_vocab != cfg.vocab_size:
            mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
            logits = jnp.where(mask, logits, -1e30)
        return logits

    def _encode(self, params: dict, batch: dict):
        """whisper encoder over stub frame embeddings (B, S_enc, D)."""
        cfg = self.cfg
        enc = params["encoder"]
        x = batch["frames"].astype(cfg.compute_dtype) @ enc["frontend"]
        x = x + enc["pos"][jnp.arange(x.shape[1])].astype(x.dtype)

        def enc_fn(carry, gp):
            h, _ = self._apply_block("enc", gp["b0"], carry, mode="train")
            return h, 0.0

        fn = jax.checkpoint(enc_fn) if cfg.remat else enc_fn
        if cfg.scan_unroll:
            for g in range(cfg.encoder_layers):
                x, _ = fn(x, jax.tree.map(lambda a: a[g], enc["stack"]))
        else:
            x, _ = jax.lax.scan(fn, x, enc["stack"])
        return L.apply_norm(enc["final_norm"], x, cfg)

    # ---- public API ----------------------------------------------------------
    def forward(self, params: dict, batch: dict) -> jnp.ndarray:
        """Teacher-forced logits (training / prefill-no-cache)."""
        enc_out = None
        if self.cfg.family == "encdec":
            enc_out = self._encode(params, batch)
        x = self._embed(params, batch)
        x, _ = self._run_stacks(params, x, enc_out=enc_out)
        return self._head(params, x)

    def loss(self, params: dict, batch: dict) -> jnp.ndarray:
        cfg = self.cfg
        logits = self.forward(params, batch)
        labels = batch["labels"]
        if cfg.frontend == "vision_stub" and "patches" in batch:
            logits = logits[:, batch["patches"].shape[1]:, :]
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        lab = jnp.take_along_axis(
            logits.astype(jnp.float32), labels[..., None], axis=-1)[..., 0]
        nll = lse - lab
        mask = (labels >= 0).astype(jnp.float32)
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)

    # ---- serving ----------------------------------------------------------
    def init_cache(self, batch_size: int, seq_len: int) -> list:
        cfg = self.cfg
        caches = []
        for kinds, n in self.plan:
            group = {}
            for i, kind in enumerate(kinds):
                c = _init_block_cache(cfg, kind, batch_size, seq_len,
                                      enc_seq=cfg.encoder_seq)
                group[f"b{i}"] = c
            # stack along leading layer axis
            caches.append(jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n,) + a.shape), group))
        return caches

    def prefill(self, params: dict, batch: dict, cache: list):
        """Run the prompt through the model, filling the cache; returns
        (last-token logits, cache)."""
        enc_out = None
        if self.cfg.family == "encdec":
            enc_out = self._encode(params, batch)
        x = self._embed(params, batch)
        x, new_caches = self._run_stacks(params, x, mode="prefill",
                                         caches=cache, index=jnp.int32(0),
                                         enc_out=enc_out)
        logits = self._head(params, x[:, -1:, :])
        return logits, new_caches

    def decode_step(self, params: dict, token: jnp.ndarray,
                    cache: list, index: jnp.ndarray):
        """One decode step. token: (B, 1); index: scalar position."""
        batch = {"tokens": token}
        if self.cfg.pos_embed == "learned":
            batch["positions"] = (index[:, None] if index.ndim == 1
                                  else index[None])
        x = self._embed(params, batch)
        x, new_caches = self._run_stacks(params, x, mode="decode",
                                         caches=cache, index=index)
        return self._head(params, x), new_caches
