"""Constant CPU buffer (paper §3.3).

Pins the features of hot nodes (top weighted-reverse-PageRank) in host
memory; feature requests for pinned nodes are redirected off the SSD,
amplifying effective aggregation bandwidth until the PCIe link saturates.

`membership` is a dense node->slot map (int32, -1 = not pinned): O(N) ints,
which is exactly how the CUDA implementation indexes it; fine at billions of
nodes (4 GB per 10^9 nodes, host-resident).
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph
from repro.graph.pagerank import hot_nodes


class ConstantBuffer:
    def __init__(self, num_nodes: int, pinned_ids: np.ndarray,
                 features: np.ndarray | None = None):
        self.membership = np.full(num_nodes, -1, dtype=np.int32)
        self.membership[pinned_ids] = np.arange(len(pinned_ids),
                                                dtype=np.int32)
        self.pinned_ids = pinned_ids
        # rows stored in pinned order; optional (id-only mode for simulation)
        self.rows = features[pinned_ids] if features is not None else None

    @classmethod
    def from_graph(cls, graph: CSRGraph, fraction: float,
                   features: np.ndarray | None = None,
                   metric: np.ndarray | None = None,
                   selection: str = "pagerank", seed: int = 0,
                   ) -> "ConstantBuffer":
        """selection: 'pagerank' (paper default), 'degree', or 'random'
        (the Fig. 10 ablation)."""
        if selection == "pagerank":
            ids = hot_nodes(graph, fraction, metric=metric)
        elif selection == "degree":
            k = max(1, int(graph.num_nodes * fraction))
            ids = np.argsort(-graph.degrees(), kind="stable")[:k]
        elif selection == "random":
            rng = np.random.default_rng(seed)
            k = max(1, int(graph.num_nodes * fraction))
            ids = rng.choice(graph.num_nodes, size=k, replace=False)
        else:
            raise ValueError(selection)
        return cls(graph.num_nodes, ids.astype(np.int64), features)

    def lookup(self, node_ids: np.ndarray) -> np.ndarray:
        """slot per request, -1 = not pinned (goes to storage)."""
        return self.membership[node_ids]

    def redirect_mask(self, node_ids: np.ndarray) -> np.ndarray:
        return self.membership[node_ids] >= 0

    def gather(self, node_ids: np.ndarray) -> np.ndarray:
        assert self.rows is not None
        slots = self.membership[node_ids]
        assert (slots >= 0).all(), "gather() on un-pinned ids"
        return self.rows[slots]

    @property
    def size(self) -> int:
        return len(self.pinned_ids)
