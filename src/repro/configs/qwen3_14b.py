"""qwen3-14b [dense] — 40L d_model=5120 40H (GQA kv=8) d_ff=17408
vocab=151936; qk_norm. [hf:Qwen/Qwen3-8B; hf]
"""
import dataclasses
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b", family="dense",
        num_layers=40, d_model=5120, num_heads=40, num_kv_heads=8,
        d_ff=17408, vocab_size=151936, head_dim=128,
        qk_norm=True,
    )


def reduced_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=160, vocab_size=512, vocab_pad_to=64, head_dim=16,
        remat=False)
