"""Pallas TPU kernel: blocked attention with online softmax.

Substrate hot-spot for the LM backbones (not a paper contribution, but the
dominant compute of every assigned architecture).  Supports:
  * causal masking
  * sliding-window attention (h2o-danube SWA, recurrentgemma local attn)
  * GQA (q head h reads kv head h * KV // H) via BlockSpec index maps

Layout: q (B, H, S, dh), k/v (B, KV, S, dh).  Grid (B*H, Sq/bq, Sk/bk) with
the kv dim innermost; running max / sum / accumulator live in VMEM scratch
and are rescaled online (Flash-Attention-2 schedule).  Softmax statistics in
f32 regardless of input dtype; MXU matmuls take bf16/f32 inputs directly.

Causal + window blocks that are fully masked are skipped by clamping the kv
grid extent per q block (block-sparse iteration, the TPU analogue of
persistent-CTA early-exit).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int | None,
            bq: int, bk: int, seq_k: int):
    kb = pl.program_id(2)
    qb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)            # (bq, dh)
    k = k_ref[0].astype(jnp.float32)            # (bk, dh)
    v = v_ref[0].astype(jnp.float32)            # (bk, dh)
    s = jnp.einsum("qd,kd->qk", q, k) * scale   # (bq, bk) f32

    q_pos = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), dtype=jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    mask &= k_pos < seq_k                       # kv padding
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                          # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (all NEG_INF): keep exp at 0
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jnp.einsum("qk,kd->qd", p, v)
    m_ref[...] = m_new

    @pl.when(kb == pl.num_programs(2) - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False) -> jax.Array:
    B, H, Sq, dh = q.shape
    _, KV, Sk, _ = k.shape
    assert H % KV == 0, (H, KV)
    group = H // KV
    scale = scale if scale is not None else dh ** -0.5
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    # pad seq lengths to block multiples
    Sq_p = -(-Sq // bq) * bq
    Sk_p = -(-Sk // bk) * bk
    if Sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Sq_p - Sq), (0, 0)))
    if Sk_p != Sk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Sk_p - Sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Sk_p - Sk), (0, 0)))

    qf = q.reshape(B * H, Sq_p, dh)
    kf = k.reshape(B * KV, Sk_p, dh)
    vf = v.reshape(B * KV, Sk_p, dh)

    def q_index(h, i, j):
        del j
        return (h, i, 0)

    def kv_index(h, i, j):
        del i
        return (h // group, j, 0)

    grid = (B * H, Sq_p // bq, Sk_p // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          bq=bq, bk=bk, seq_k=Sk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), q_index),
            pl.BlockSpec((1, bk, dh), kv_index),
            pl.BlockSpec((1, bk, dh), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), q_index),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max m
            pltpu.VMEM((bq, 1), jnp.float32),    # running denom l
            pltpu.VMEM((bq, dh), jnp.float32),   # output accumulator
        ],
        out_shape=jax.ShapeDtypeStruct((B * H, Sq_p, dh), q.dtype),
        interpret=interpret,
        name="flash_attention",
    )(qf, kf, vf)
    return out.reshape(B, H, Sq_p, dh)[:, :, :Sq, :]
