"""Fault-tolerant data plane — fault injection (core/faults.py), replicated
placement with hedged/retried reads, and serve-plane brownout degradation
(serve/gnn_engine.py BrownoutController).

The fault axis is virtual like everything else: a seeded FaultSchedule keys
brownouts / outages / flaky reads to the loader's priced-burst index, the
injector re-prices each burst (retry ladders, failover to the chained
replica, hedged duplicate of the straggler shard), and the health monitor /
rebalancer react to the *priced* symptoms.  Faults perturb timing and
routing only — never data — so every scenario here asserts bit-identity of
the sampled blocks and gathered bytes alongside the timing claims.

Four scenarios, all deterministic:

  * brownout_hedge (GATED): one shard of four browns out 10x for 8 bursts.
    An unreplicated plane eats the straggler; 2-way chained declustering
    plus hedged reads + plan-time failover must recover >= 1.3x of the
    exposed prep end-to-end (`hedged_vs_naive_speedup >= 1.3` in CI).
  * fault_identity (GATED): a chaos schedule (brownout + hard outage +
    flaky reads) over a replicated plane — sampled blocks and feature
    bytes must match the fault-free loader bit-for-bit, and prep must
    never get cheaper than clean.
  * faultfree_identity (GATED): an EMPTY schedule and the serve engine
    with fault knobs at defaults must price bit-identically to a plane
    with no fault machinery constructed at all.  (The committed BENCH
    baseline comparison separately pins the PR 7 floats.)
  * serve_brownout (GATED): gather-dominated serving under a persistent
    10x single-shard brownout.  The BrownoutController's priced ladder
    (fanout shrink -> stale serving -> shed) must hold the victim p99
    within 1.5x of the fault-free p99 while shedding < 20% of load.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row
from repro.core import (BrownoutEvent, FaultSchedule, FlakyReadsEvent,
                        GIDSDataLoader, LoaderConfig, OutageEvent)
from repro.graph.synthetic import rmat_graph
from repro.serve import (GNNServeConfig, GNNServeEngine, TenantSpec,
                         generate_stream)

N_SHARDS = 4
BATCHES = 48          # window_depth=4 -> 12 priced bursts span the schedule

SCHED_BROWNOUT = FaultSchedule(
    events=(BrownoutEvent(shard=2, start=1, end=9, multiplier=10.0),))
SCHED_CHAOS = FaultSchedule(
    events=(BrownoutEvent(shard=2, start=1, end=9, multiplier=10.0),
            OutageEvent(shard=0, start=4, end=7),
            FlakyReadsEvent(shard=1, start=2, end=12, fail_prob=0.2)),
    seed=3)


def _graph_and_feats(dim: int = 16):
    g = rmat_graph(10_000, 12, 16, seed=1)
    feats = np.random.default_rng(0).standard_normal(
        (g.num_nodes, dim)).astype(np.float32)
    return g, feats


def _loader(g, feats, **over) -> GIDSDataLoader:
    kw = dict(batch_size=256, fanouts=(2,), data_plane="gids-merged-sharded",
              cache_lines=512, window_depth=4, n_shards=N_SHARDS,
              placement="degree", seed=7)
    kw.update(over)
    return GIDSDataLoader(g, feats, LoaderConfig(**kw))


def brownout_hedge() -> dict:
    """Single-shard 10x brownout: unreplicated vs 2-way replicated with
    hedged reads and plan-time failover.  The CI-gated headline."""
    g, feats = _graph_and_feats()
    naive = _loader(g, feats, fault_schedule=SCHED_BROWNOUT)
    hedged = _loader(g, feats, fault_schedule=SCHED_BROWNOUT,
                     replication_factor=2)
    t_naive = sum(naive.next_batch().exposed_prep_s for _ in range(BATCHES))
    t_hedged = sum(hedged.next_batch().exposed_prep_s for _ in range(BATCHES))
    inj = hedged.fault_injector
    return {
        "naive_prep_s": t_naive,
        "hedged_prep_s": t_hedged,
        "speedup": t_naive / max(t_hedged, 1e-12),
        "n_hedged_bursts": inj.n_hedged_bursts,
        "n_rerouted": hedged.store.tiers[-1].router.n_rerouted,
        "first_hedge_burst": inj.first_hedge_burst,
        "hedge_saving_us": inj.hedge_saving_s * 1e6,
    }


def fault_identity() -> dict:
    """Chaos schedule vs fault-free: the data stream must be bit-identical
    and the faulted plane must never price cheaper than clean."""
    g, feats = _graph_and_feats()
    clean = _loader(g, feats)
    chaos = _loader(g, feats, fault_schedule=SCHED_CHAOS,
                    replication_factor=2)
    identical, never_cheaper, slower = True, True, 0
    for _ in range(BATCHES):
        bc, bf = clean.next_batch(), chaos.next_batch()
        identical &= (np.array_equal(bc.blocks.all_nodes,
                                     bf.blocks.all_nodes)
                      and np.array_equal(bc.features, bf.features))
        never_cheaper &= bf.prep_time_s >= bc.prep_time_s
        slower += bf.prep_time_s > bc.prep_time_s
    inj = chaos.fault_injector
    return {
        "data_identical": bool(identical and never_cheaper and slower > 0),
        "n_faulted_bursts": inj.n_faulted_bursts,
        "n_retried_lines": inj.n_retried_lines,
        "n_failed_over_lines": inj.n_failed_over_lines,
    }


def faultfree_identity() -> dict:
    """An empty schedule (and default serve fault knobs) must be invisible:
    bit-identical prep floats and feature bytes to a plane that never
    constructed the fault machinery."""
    g, feats = _graph_and_feats()
    plain = _loader(g, feats)
    empty = _loader(g, feats, fault_schedule=FaultSchedule())
    loader_ok = all(
        (lambda a, b: a.prep_time_s == b.prep_time_s
         and a.exposed_prep_s == b.exposed_prep_s
         and np.array_equal(a.features, b.features))(
             plain.next_batch(), empty.next_batch())
        for _ in range(8))

    gs, feats_s, reqs = _serve_workload()
    r0 = GNNServeEngine(gs, feats_s, GNNServeConfig(
        seed=5, cache_lines=256)).run(reqs)
    r1 = GNNServeEngine(gs, feats_s, GNNServeConfig(
        seed=5, cache_lines=256, fault_schedule=None,
        brownout=False)).run(reqs)
    serve_ok = len(r0.records) == len(r1.records) and all(
        a.completion_s == b.completion_s and a.gather_s == b.gather_s
        and not b.stale and b.degraded_level == 0
        for a, b in zip(r0.records, r1.records))
    return {"identical": bool(loader_ok and serve_ok)}


def _serve_workload():
    """Gather-dominated serving: wide rows + a small cache make the storage
    burst (not window formation) set the tail, so a shard brownout hurts
    and the controller's ladder has something to trade away."""
    g, feats = _graph_and_feats(dim=512)
    reqs = generate_stream(
        g.num_nodes, [TenantSpec(name="t0", deadline_s=3e-3, mean_seeds=8)],
        offered_qps=500, n_requests=300, seed=3)
    return g, feats, list(reqs)


def serve_brownout() -> dict:
    """Persistent 10x brownout on one serve shard: un-mitigated vs the
    BrownoutController ladder.  The CI-gated claim is bounded degradation:
    victim p99 within 1.5x of fault-free while shedding < 20%."""
    g, feats, reqs = _serve_workload()
    sched = FaultSchedule(events=(
        BrownoutEvent(shard=0, start=3, end=10_000, multiplier=10.0),))

    def run(**over):
        cfg = dict(seed=5, cache_lines=256)
        cfg.update(over)
        eng = GNNServeEngine(g, feats, GNNServeConfig(**cfg))
        return eng.run(reqs), eng

    free, _ = run()
    naive, _ = run(fault_schedule=sched)
    ctl, eng = run(fault_schedule=sched, brownout=True)
    return {
        "free_p99_ms": free.p99_s() * 1e3,
        "naive_p99_ms": naive.p99_s() * 1e3,
        "ctl_p99_ms": ctl.p99_s() * 1e3,
        "naive_p99_ratio": naive.p99_s() / max(free.p99_s(), 1e-12),
        "ctl_p99_ratio": ctl.p99_s() / max(free.p99_s(), 1e-12),
        "naive_attainment": naive.attainment(),
        "ctl_attainment": ctl.attainment(),
        "shed_fraction": ctl.shed_fraction,
        "n_shed_brownout": ctl.n_shed_brownout,
        "n_degraded": ctl.n_degraded,
        "n_stale_served": ctl.n_stale_served,
        "ladder_peak": max((lv for _, lv in eng.brownout.level_trace),
                           default=0),
    }


def headline() -> dict:
    """Smoke numbers for BENCH_*.json + the CI fault gates."""
    hedge = brownout_hedge()
    ident = fault_identity()
    free = faultfree_identity()
    serve = serve_brownout()
    return {
        "hedged_vs_naive_speedup": hedge["speedup"],
        "naive_prep_us": hedge["naive_prep_s"] * 1e6,
        "hedged_prep_us": hedge["hedged_prep_s"] * 1e6,
        "n_hedged_bursts": hedge["n_hedged_bursts"],
        "n_rerouted": hedge["n_rerouted"],
        "fault_data_identical": ident["data_identical"],
        "chaos_n_faulted_bursts": ident["n_faulted_bursts"],
        "faultfree_identical": free["identical"],
        "serve_free_p99_ms": serve["free_p99_ms"],
        "serve_naive_p99_ratio": serve["naive_p99_ratio"],
        "serve_ctl_p99_ratio": serve["ctl_p99_ratio"],
        "serve_naive_attainment": serve["naive_attainment"],
        "serve_ctl_attainment": serve["ctl_attainment"],
        "serve_shed_fraction": serve["shed_fraction"],
        "serve_n_stale_served": serve["n_stale_served"],
    }


def main() -> None:
    hedge = brownout_hedge()
    row("fig_faults_brownout_naive", hedge["naive_prep_s"] * 1e6,
        "unreplicated_total_exposed_prep")
    row("fig_faults_brownout_hedged", hedge["hedged_prep_s"] * 1e6,
        f"speedup={hedge['speedup']:.3f}x"
        f"_hedged_bursts={hedge['n_hedged_bursts']}"
        f"_rerouted={hedge['n_rerouted']}"
        f"_first_hedge_burst={hedge['first_hedge_burst']}"
        f"_saving_us={hedge['hedge_saving_us']:.1f}")
    ident = fault_identity()
    row("fig_faults_chaos_identity", 0.0,
        f"data_identical={ident['data_identical']}"
        f"_faulted_bursts={ident['n_faulted_bursts']}"
        f"_retried_lines={ident['n_retried_lines']}"
        f"_failed_over_lines={ident['n_failed_over_lines']}")
    free = faultfree_identity()
    row("fig_faults_faultfree_identity", 0.0,
        f"identical={free['identical']}")
    serve = serve_brownout()
    row("fig_faults_serve_brownout", serve["ctl_p99_ms"] * 1e3,
        f"p99_ratio_naive={serve['naive_p99_ratio']:.3f}"
        f"->ctl={serve['ctl_p99_ratio']:.3f}"
        f"_attainment={serve['naive_attainment']:.3f}"
        f"->{serve['ctl_attainment']:.3f}"
        f"_shed={serve['shed_fraction']:.3f}"
        f"_stale={serve['n_stale_served']}"
        f"_ladder_peak={serve['ladder_peak']}")


if __name__ == "__main__":
    main()
