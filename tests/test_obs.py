"""Observability plane (repro.obs): metrics registry semantics, span-tree
layout and Chrome export, trace validation, and — the load-bearing
invariant — bit-invisibility: an enabled tracer never changes a single
priced number, sampled block, or gathered byte anywhere in the data
plane, including across a mid-window checkpoint/resume."""
import json
import warnings

import numpy as np
import pytest

from repro.core import GIDSDataLoader, LoaderConfig
from repro.graph.synthetic import rmat_graph
from repro.obs import (Counter, Gauge, Histogram, MetricsRegistry,
                       NULL_METRICS, NULL_TRACER, Tracer, attach_burst_spans,
                       validate_events, validate_trace, validate_tracer)


@pytest.fixture(scope="module")
def graph_and_feats():
    g = rmat_graph(4_000, 12, 16, seed=7)
    feats = np.random.default_rng(3).standard_normal(
        (g.num_nodes, 24)).astype(np.float32)
    return g, feats


def _loader(g, feats, preset, tracer=None, **kw):
    cfg = LoaderConfig(batch_size=128, fanouts=(5, 5), data_plane=preset,
                       cache_lines=2048, window_depth=4, **kw)
    return GIDSDataLoader(g, feats, cfg, tracer=tracer)


# -- metrics registry ----------------------------------------------------------

def test_registry_instruments():
    m = MetricsRegistry()
    m.counter("a").inc()
    m.counter("a").inc(2.5)
    m.gauge("g").set(4.0)
    h = m.histogram("h")
    for v in (1.0, 2.0, 3.0):
        h.observe(v)
    m.series("s").append({"x": 1})
    assert m.counter("a").value == 3.5
    assert m.gauge("g").value == 4.0
    assert h.count == 3 and h.mean == 2.0 and h.min == 1.0 and h.max == 3.0
    snap = m.snapshot()
    assert snap["a"]["type"] == "counter" and snap["a"]["value"] == 3.5
    assert snap["h"]["count"] == 3
    assert snap["s"]["points"] == [{"x": 1}]
    json.dumps(snap)   # snapshot must be JSON-serializable as-is
    m.reset()
    assert m.snapshot() == {}


def test_registry_get_or_create_is_stable():
    m = MetricsRegistry()
    assert m.counter("x") is m.counter("x")
    with pytest.raises(TypeError):
        m.gauge("x")   # name already bound to a different instrument type


def test_null_metrics_inert():
    NULL_METRICS.counter("x").inc(5)
    NULL_METRICS.histogram("y").observe(1.0)
    assert NULL_METRICS.snapshot() == {}


def test_instrument_classes_standalone():
    c, g, h = Counter("c"), Gauge("g"), Histogram("h")
    c.inc(2)
    g.set(-1.0)
    h.observe(0.5)
    assert c.value == 2 and g.value == -1.0 and h.count == 1


# -- span trees and export -----------------------------------------------------

def test_span_tree_layout_and_reconcile():
    tr = Tracer()
    root = tr.batch("batch", index=0)
    root.child("sample", 2.0)
    root.child("gather", 3.0)
    root.child("shard0", 2.5, track="shard0", parallel=True)
    root.close()
    assert root.dur == 5.0                       # sequential sum
    assert root.reconcile_error() == 0.0
    assert tr.max_reconcile_error() == 0.0
    assert validate_tracer(tr) == []
    # lazy layout: children packed from the root start, parallel overlay at t0
    seq = [c for c in root.children if not c.parallel]
    assert seq[0].t0 == root.t0 and seq[1].t0 == root.t0 + 2.0
    par = [c for c in root.children if c.parallel][0]
    assert par.t0 == root.t0


def test_chrome_export_schema():
    tr = Tracer()
    root = tr.batch("batch")
    root.child("gather", 1.0, rows=np.int64(7))
    root.close()
    tr.instant("migration", cost_s=0.25)
    with tr.stage("plan_next") as sp:
        sp.modelled(1.0)
    events = tr.chrome_events()
    assert validate_events(events) == []
    by_ph = {}
    for ev in events:
        by_ph.setdefault(ev["ph"], []).append(ev)
    assert len(by_ph["X"]) == 3 and len(by_ph["i"]) == 1
    # numpy args were jsonified
    gather = next(e for e in by_ph["X"] if e["name"] == "gather")
    assert gather["args"]["rows"] == 7 and isinstance(
        gather["args"]["rows"], int)
    json.dumps(events)


def test_trace_write_is_perfetto_loadable(tmp_path):
    tr = Tracer()
    tr.batch("b").child("g", 1.0)
    path = tmp_path / "trace.json"
    tr.write(str(path))
    doc = json.loads(path.read_text())
    assert "traceEvents" in doc
    assert validate_trace(doc) == []


def test_validate_catches_escaping_child():
    tr = Tracer()
    root = tr.batch("b")
    root.child("too-long", 2.0)
    root.close(1.0)           # child escapes parent interval
    assert any("escapes" in p for p in validate_tracer(tr))


def test_modelled_vs_measured_series():
    tr = Tracer()
    with tr.stage("execute") as sp:
        sp.modelled(0.25)
    pts = tr.metrics.series("modelled_vs_measured.execute").points
    assert len(pts) == 1
    p = pts[0]
    assert p["modelled_s"] == 0.25 and p["measured_s"] >= 0.0
    assert p["gap_s"] == p["measured_s"] - p["modelled_s"]


def test_null_tracer_records_nothing():
    s = NULL_TRACER.batch("b")
    assert s.child("x", 1.0) is s
    with NULL_TRACER.stage("s") as sp:
        sp.modelled(1.0)
    assert NULL_TRACER.chrome_events() == []
    assert NULL_TRACER.metrics.snapshot() == {}


def test_attach_burst_spans_duck_typed():
    class FakeBurst:
        per_shard_s = (0.5, 0.0)
        per_shard_rows = (10, 0)
        per_shard_lines = (4, 0)

        def recovery_events(self):
            return [("retry", 0, {"lines": 2, "recovery_s": 0.1})]

    tr = Tracer()
    root = tr.batch("b")
    g = root.child("gather", 0.5)
    attach_burst_spans(g, FakeBurst())
    names = [c.name for c in g.children]
    assert names == ["shard0", "fault/retry"]      # zero-work shard skipped
    assert all(c.parallel for c in g.children)
    root.close()
    assert validate_tracer(tr) == []


# -- bit-invisibility over the priced pipeline ---------------------------------

PRESETS = ["gids", "gids-merged", "gids-topo-merged", "gids-merged-sharded",
           "gids-hosts-merged"]


def _preset_kwargs(preset):
    if preset == "gids-merged-sharded":
        return {"n_shards": 4}
    if preset == "gids-hosts-merged":
        return {"n_hosts": 4, "placement": "metis-lite"}
    return {}


@pytest.mark.parametrize("preset", PRESETS)
def test_tracer_bit_invisible(graph_and_feats, preset):
    """Enabled tracer vs no tracer: every priced time and every gathered
    byte must be EXACTLY equal — observation never perturbs the plane."""
    g, feats = graph_and_feats
    kw = _preset_kwargs(preset)
    plain = _loader(g, feats, preset, **kw)
    traced = _loader(g, feats, preset, tracer=Tracer(), **kw)
    for _ in range(6):
        a, b = plain.next_batch(), traced.next_batch()
        assert a.prep_time_s == b.prep_time_s
        assert a.sample_time_s == b.sample_time_s
        np.testing.assert_array_equal(a.blocks.all_nodes, b.blocks.all_nodes)
        np.testing.assert_array_equal(a.features, b.features)
    probs = validate_trace(traced.tracer)
    assert probs == [], probs[:5]


def test_tracer_bit_invisible_across_checkpoint(graph_and_feats):
    """Checkpoint mid-window and resume, once untraced and once traced:
    the traced pair must replay the untraced pair bit-for-bit.  (A resumed
    stream may legitimately re-price its open window differently from a
    never-interrupted run; the tracer must not add to that.)"""
    g, feats = graph_and_feats

    def resume_run(tracer_factory):
        first = _loader(g, feats, "gids-merged", tracer=tracer_factory())
        got = [first.next_batch() for _ in range(3)]
        state = first.state_dict()
        resumed = _loader(g, feats, "gids-merged", tracer=tracer_factory())
        resumed.load_state_dict(state)
        got += [resumed.next_batch() for _ in range(3)]
        return got, resumed

    want, _ = resume_run(lambda: None)
    got, resumed = resume_run(Tracer)
    for a, b in zip(want, got):
        assert a.prep_time_s == b.prep_time_s
        np.testing.assert_array_equal(a.features, b.features)
    assert validate_trace(resumed.tracer) == []


def test_trace_covers_pipeline_stages(graph_and_feats):
    g, feats = graph_and_feats
    tr = Tracer()
    dl = _loader(g, feats, "gids-topo-merged", tracer=tr)
    for _ in range(6):
        dl.next_batch()
    roots = tr.roots()
    names = {sp.name for r in roots for sp in r.walk()}
    assert any(r.name.startswith("window") for r in roots)
    assert any(n.startswith("sample/hop") for n in names)
    assert "merged_gather" in names and "gather_share" in names
    wall = {w.name for w in dl.tracer.wall_spans()}
    assert {"plan_next", "execute_window", "sample"} <= wall
    snap = tr.metrics.snapshot()
    assert snap["pipeline.batches"]["value"] >= 6.0
    assert "topo.hops" in snap and "topo.edge_reads" in snap
    assert any(k.startswith("modelled_vs_measured.") for k in snap)
    assert any(k.startswith("tier.") and k.endswith("hit_ratio")
               for k in snap)


def test_fault_recovery_spans(graph_and_feats):
    """Retry/hedge/failover telemetry surfaces as parallel fault spans and
    faults.* counters when a schedule injects into a traced sharded run."""
    from repro.core.faults import (BrownoutEvent, FaultSchedule,
                                   FlakyReadsEvent, OutageEvent)
    g, feats = graph_and_feats
    fs = FaultSchedule(events=(
        BrownoutEvent(shard=2, start=0, end=90, multiplier=10.0),
        OutageEvent(shard=0, start=1, end=7),
        FlakyReadsEvent(shard=1, start=0, end=90, fail_prob=0.4)), seed=3)
    tr = Tracer()
    dl = _loader(g, feats, "gids-merged-sharded", tracer=tr, n_shards=4,
                 placement="degree", fault_schedule=fs,
                 replication_factor=2)
    for _ in range(16):
        dl.next_batch()
    fault_spans = [sp for r in tr.roots() for sp in r.walk()
                   if sp.name.startswith("fault/")]
    assert fault_spans, "fault schedule produced no fault spans"
    snap = tr.metrics.snapshot()
    assert any(k.startswith("faults.") for k in snap)
    assert snap["storage.bursts"]["value"] > 0   # sharded bursts were noted
    assert validate_trace(tr) == []


# -- telemetry reset on restore (the stale-burst regression) -------------------

def test_restore_clears_stale_burst_telemetry(graph_and_feats):
    """load_state_dict must drop the pre-restore epoch's last burst and
    telemetry: a restored loader reports None until it prices a burst of
    its own, instead of resurfacing another run's straggler profile."""
    g, feats = graph_and_feats
    tr = Tracer()
    dl = _loader(g, feats, "gids-merged-sharded", tracer=tr, n_shards=4)
    for _ in range(4):
        dl.next_batch()
    assert dl.timeline.shard_burst is not None
    state = dl.state_dict()
    assert dl.tracer.metrics.snapshot()   # non-empty before restore

    dl.load_state_dict(state)
    assert dl.timeline.shard_burst is None
    assert dl.tracer.roots() == []
    assert dl.tracer.metrics.snapshot() == {}
    # and the loader still runs after the reset
    assert dl.next_batch().prep_time_s > 0.0
    assert dl.timeline.shard_burst is not None


def test_deprecated_accessors_warn(graph_and_feats):
    g, feats = graph_and_feats
    dl = _loader(g, feats, "gids-merged")
    dl.next_batch()
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        burst = dl.timeline.last_shard_burst
        _ = dl.timeline.last_host_burst
    assert burst is dl.timeline.shard_burst
    assert len(caught) == 2
    assert all(issubclass(w.category, DeprecationWarning) for w in caught)


# -- serve engine --------------------------------------------------------------

def _serve_setup():
    from repro.serve import TenantSpec, generate_stream
    g = rmat_graph(2_000, 10, 16, seed=3)
    feats = np.random.default_rng(0).standard_normal(
        (g.num_nodes, 16)).astype(np.float32)
    tenants = [TenantSpec("a"), TenantSpec("b", arrival="mmpp")]
    reqs = generate_stream(g.num_nodes, tenants, offered_qps=2000,
                           n_requests=40, seed=5)
    return g, feats, reqs


def test_serve_tracer_bit_invisible():
    from repro.serve import GNNServeConfig, GNNServeEngine
    g, feats, reqs = _serve_setup()
    cfg = GNNServeConfig(fanouts=(5, 3), cache_lines=512, tenants=2)
    r0 = GNNServeEngine(g, feats, cfg).run(reqs)
    tr = Tracer()
    r1 = GNNServeEngine(g, feats, cfg, tracer=tr).run(reqs)
    for a, b in zip(r0.records, r1.records):
        assert (a.rid, a.latency_s, a.queue_wait_s, a.sample_s, a.gather_s,
                a.forward_s, a.rejected) == \
               (b.rid, b.latency_s, b.queue_wait_s, b.sample_s, b.gather_s,
                b.forward_s, b.rejected)
    probs = validate_trace(tr)
    assert probs == [], probs[:5]
    snap = tr.metrics.snapshot()
    assert snap["serve.requests"]["value"] == len(reqs)
    assert snap["serve.windows"]["value"] == len(r1.windows)
    # one request span per served request, on its tenant's track
    req_spans = [r for r in tr.roots() if r.name == "request"]
    assert len(req_spans) == len(r1.served)
    assert {sp.track for sp in req_spans} <= {"tenant0", "tenant1"}
