"""Model configuration + parameter-spec machinery.

Every architecture declares its parameters once as `ParamDef`s (shape +
logical axes + init); from that single source we derive
  * materialised params (`init_params`),
  * abstract params with shardings for the dry-run (`abstract_params`),
  * PartitionSpecs under a given sharding strategy (`param_pspecs`).

Logical axis names are resolved to mesh axes by a rules table; any dim not
divisible by its mesh-axis size falls back to replication (this is what makes
the zoo's awkward head counts — 40, 56, 36, 14, 10 — compile on a fixed
16-way model axis without padding heads; see DESIGN.md "head-agnostic TP").
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# --------------------------------------------------------------------------
# configuration
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    vocab_pad_to: int = 2048
    norm_type: str = "rms"           # rms | layernorm
    norm_eps: float = 1e-6
    act: str = "silu_gated"          # silu_gated | gelu
    pos_embed: str = "rope"          # rope | learned | none
    rope_theta: float = 10_000.0
    qk_norm: bool = False            # qwen3
    qkv_bias: bool = False           # qwen2 / internvl2 backbone
    attn_window: int | None = None   # sliding-window attention (h2o-danube)
    max_position: int = 32768        # learned-pos table size (whisper)
    tie_embeddings: bool = False
    residual_scale: float = 1.0      # minicpm depth-scaled residuals
    embed_scale: float = 1.0         # minicpm mup-style embedding scale
    # --- MoE ---------------------------------------------------------------
    moe_experts: int = 0
    moe_top_k: int = 1
    moe_interleave: int = 1          # layer i is MoE iff i % interleave ==
                                     # interleave-1 (llama4: every 2nd)
    moe_shared_expert: bool = False  # llama4
    moe_dense_residual: bool = False # arctic: dense FFN parallel to MoE
    moe_capacity_factor: float = 1.25
    # --- SSM / hybrid --------------------------------------------------------
    ssm_state: int = 0               # mamba2 N
    ssm_headdim: int = 64            # mamba2 P
    ssm_expand: int = 2              # d_inner = expand * d_model
    ssm_chunk: int = 128             # SSD chunk length
    hybrid_attn_every: int = 0       # recurrentgemma: attn layer every 3rd
    lru_width: int = 0               # RG-LRU width (0 -> d_model)
    local_window: int = 2048         # recurrentgemma local attention window
    # --- encoder-decoder / frontends -----------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 1500          # whisper: 30 s of 20 ms frames
    frontend: str | None = None      # audio_stub | vision_stub
    frontend_tokens: int = 256       # vlm: patch embeddings per image
    # --- numerics -------------------------------------------------------------
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    attn_impl: str = "einsum"        # einsum (dry-run/XLA-costable) | flash
    scan_unroll: bool = False        # python-loop layers (exact HLO cost
                                     # accounting in the dry-run ladder)
    moe_2d_dispatch: bool = False    # serving: shard dispatch d_model over
                                     # data (weight-stationary experts) —
                                     # see launch/specs.activation_specs

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_to
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def is_moe_layer(self, i: int) -> bool:
        if not self.moe_experts:
            return False
        return (i % self.moe_interleave) == (self.moe_interleave - 1)

    def is_attn_layer(self, i: int) -> bool:
        """hybrid archs: which layers are (local) attention."""
        if self.family != "hybrid":
            return True
        k = self.hybrid_attn_every
        return k > 0 and (i % k) == (k - 1)


# --------------------------------------------------------------------------
# parameter definitions
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]          # logical axis per dim
    dtype: Any = jnp.bfloat16
    init: str = "normal"                  # normal | zeros | ones | lecun
    scale: float = 1.0

    def initializer(self, key: jax.Array) -> jnp.ndarray:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "lecun":
            fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
            std = math.sqrt(1.0 / fan_in)
        else:
            std = 0.02 * self.scale
        return (jax.random.normal(key, self.shape, jnp.float32) * std
                ).astype(self.dtype)


ParamTree = Any  # nested dict[str, ParamDef | ParamTree]


# --------------------------------------------------------------------------
# sharding rules
# --------------------------------------------------------------------------
# logical axis -> mesh axis (or None). Tuple values shard over multiple axes.
def sharding_rules(strategy: str, multi_pod: bool = False) -> dict:
    batch = ("pod", "data") if multi_pod else ("data",)
    base = {
        "batch": batch,
        "seq": None,
        "layers": None,            # scan dim, never sharded
        "vocab": "model",
        "embed": None,             # d_model
        "qkv": "model",            # fused head*hd projection dim
        "heads": "model",          # falls back to None if not divisible
        "kv_heads": "model",
        "head_dim": None,
        "ffn": "model",
        "expert": "model",         # EP
        "expert_ffn": None,
        "ssm_inner": "model",
        "ssm_heads": "model",
        "ssm_state": None,
        "lru": "model",
        "conv": None,
    }
    if strategy == "tp":
        pass
    elif strategy == "fsdp_tp":
        # ZeRO-3 style: additionally shard the d_model dim of weights over
        # the data axis; XLA all-gathers per scanned layer.
        base["embed"] = "data"
        base["expert_ffn"] = "data"
    elif strategy == "ep_tp":
        # serving layout: weight-stationary experts — expert dim over DATA
        # (128/16 = 8 per row), ffn dims over model; no per-token weight
        # gathers and no partial-sum ARs at the expert matmuls.
        base["expert"] = "data"
        base["expert_ffn"] = None
    elif strategy == "dp":
        for k in ("vocab", "qkv", "heads", "kv_heads", "ffn", "expert",
                  "ssm_inner", "ssm_heads", "lru"):
            base[k] = None
    else:
        raise ValueError(strategy)
    return base


def resolve_pspec(pdef: ParamDef, rules: dict, mesh: Mesh) -> P:
    """Logical axes -> PartitionSpec with divisibility fallback."""
    used: set = set()
    out = []
    for dim, ax in zip(pdef.shape, pdef.axes):
        mesh_ax = rules.get(ax) if ax else None
        if mesh_ax is None:
            out.append(None)
            continue
        axes_tuple = (mesh_ax,) if isinstance(mesh_ax, str) else tuple(mesh_ax)
        size = int(np.prod([mesh.shape[a] for a in axes_tuple]))
        if dim % size != 0 or any(a in used for a in axes_tuple):
            out.append(None)
            continue
        used.update(axes_tuple)
        out.append(mesh_ax)
    return P(*out)


def tree_map_defs(fn: Callable[[ParamDef], Any], defs: ParamTree) -> Any:
    return jax.tree.map(fn, defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def init_params(defs: ParamTree, key: jax.Array) -> Any:
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    vals = [d.initializer(k) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(defs: ParamTree, rules: dict, mesh: Mesh) -> Any:
    def mk(d: ParamDef):
        spec = resolve_pspec(d, rules, mesh)
        return jax.ShapeDtypeStruct(d.shape, d.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return tree_map_defs(mk, defs)


def param_pspecs(defs: ParamTree, rules: dict, mesh: Mesh) -> Any:
    return tree_map_defs(lambda d: resolve_pspec(d, rules, mesh), defs)


def param_count(defs: ParamTree) -> int:
    leaves = jax.tree.leaves(defs,
                             is_leaf=lambda x: isinstance(x, ParamDef))
    return sum(int(np.prod(d.shape)) for d in leaves)
