"""Fig. 7 — graph sampling throughput: host (CPU) vs device (GPU/TPU jit)
vs the tiered topology plane (core/topology.py), with degree skew on/off.

Two claim families:

* measured wall-clock: the jitted device sampler vs the numpy host sampler
  across graph scales (the original Fig. 7 shape);
* modelled sampling time: the tiered topology store prices every hop's
  edge-page reads (GPU hot adjacency / pinned host / storage-backed CSR
  pages) against the CPU-sampling baseline
  (`storage_sim.host_sampling_hop_time`) on IDENTICAL hops, and the
  modelled time must be MONOTONE non-increasing in the GPU-tier budget
  (degree-aware admission assigns nested prefixes — asserted here, gated
  in `run.py --json` via `headline()`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.core import INTEL_OPTANE, TieredTopologyStore, host_sampling_time
from repro.graph.datasets import IGB_MEDIUM, IGB_SMALL, IGB_TINY
from repro.graph.synthetic import rmat_graph, uniform_graph
from repro.sampling.neighbor import (device_sample_blocks,
                                     host_sample_blocks, run_sample_hops)

GPU_BUDGET_SWEEP = (0.0, 0.1, 0.25, 0.5, 1.0)


def sample_hops(g, batch, fanouts, seed=0):
    """Sample once (through the samplers' shared driver); return the
    per-hop (read positions, frontier size) pairs.  Re-pricing those hops
    against different stores is then pure page accounting — no re-sampling
    per sweep point."""
    rng = np.random.default_rng(seed)
    seeds = rng.integers(0, g.num_nodes, batch)
    hops = []
    run_sample_hops(g, seeds, fanouts, rng,
                    hop_cb=lambda hop, pos, nf: hops.append((pos, nf)))
    return hops


def price_hops(topo, hops):
    return [topo.hop_report(pos, hop=i, n_frontier=nf)
            for i, (pos, nf) in enumerate(hops)]


def budget_sweep(g, hops):
    """Modelled tiered time per GPU budget over the SAME sampled hops —
    only the page placement changes between points, so the asserted
    monotonicity is exactly the nested-admission-prefix claim."""
    times = []
    for f in GPU_BUDGET_SWEEP:
        topo = TieredTopologyStore.from_graph(
            g, admission="degree", gpu_fraction=f, host_fraction=0.5,
            ssd=INTEL_OPTANE)
        times.append(sum(r.time_s for r in price_hops(topo, hops)))
    assert all(b <= a * 1.0001 + 1e-12 for a, b in zip(times, times[1:])), \
        f"tiered sampling time not monotone in GPU budget: {times}"
    return times


def headline(num_nodes: int = 50_000, batch: int = 4096,
             fanouts=(10, 5)) -> dict:
    """Smoke numbers for BENCH_*.json + the CI topo-beats-host gate:
    modelled tiered sampling (default budgets, degree admission) must beat
    the modelled CPU-sampling baseline on the degree-SKEWED config."""
    out = {}
    skewed_g = rmat_graph(num_nodes, 12, 0, seed=1)
    uniform_g = uniform_graph(num_nodes, 12, 0, seed=1)
    skewed_hops = sample_hops(skewed_g, batch, fanouts)
    for tag, g, hops in (
            ("skewed", skewed_g, skewed_hops),
            ("uniform", uniform_g, sample_hops(uniform_g, batch, fanouts))):
        topo = TieredTopologyStore.from_graph(
            g, admission="degree", gpu_fraction=0.25, host_fraction=0.5,
            ssd=INTEL_OPTANE)
        reports = price_hops(topo, hops)
        t_host = host_sampling_time(reports)
        t_tiered = sum(r.time_s for r in reports)
        out[f"{tag}_host_sample_us"] = t_host * 1e6
        out[f"{tag}_tiered_sample_us"] = t_tiered * 1e6
        out[f"{tag}_sample_speedup_tiered_vs_host"] = t_host / t_tiered
        last = reports[-1]
        out[f"{tag}_last_hop_pages_hbm"] = last.pages_by_tier[0]
        out[f"{tag}_last_hop_pages_host"] = last.pages_by_tier[1]
        out[f"{tag}_last_hop_pages_storage"] = last.pages_by_tier[2]
        out[f"{tag}_last_hop_coalesce_factor"] = last.coalesce_factor
    sweep = budget_sweep(skewed_g, skewed_hops)
    for f, t in zip(GPU_BUDGET_SWEEP, sweep):
        out[f"tiered_sample_us_gpu{f:g}"] = t * 1e6
    out["sample_speedup_tiered_vs_host"] = \
        out["skewed_sample_speedup_tiered_vs_host"]
    return out


def main(batch=512, fanouts=(10, 5)):
    # measured wall-clock across scales (original Fig. 7)
    for spec in (IGB_TINY, IGB_SMALL, IGB_MEDIUM):
        g = spec.materialize()
        rng = np.random.default_rng(0)
        seeds = rng.integers(0, g.num_nodes, batch)
        t_host = timeit(lambda: host_sample_blocks(g, seeds, fanouts, rng),
                        iters=3)
        csr = g.to_device()
        dseeds = jnp.asarray(seeds, jnp.int32)
        samp = jax.jit(
            lambda s, k: device_sample_blocks(csr, s, fanouts, k)[1])
        key = jax.random.PRNGKey(0)
        t_dev = timeit(lambda: samp(dseeds, key).block_until_ready(),
                       iters=3)
        row(f"fig7_sampling_{spec.name}", t_host * 1e6,
            f"host_ms={t_host*1e3:.2f}_device_ms={t_dev*1e3:.2f}"
            f"_speedup={t_host/t_dev:.2f}x_nodes={g.num_nodes}")

    # modelled tiered-topology sampling, degree skew on/off + budget sweep
    res = headline()
    for tag in ("skewed", "uniform"):
        row(f"fig7_tiered_{tag}", res[f"{tag}_tiered_sample_us"],
            f"host_us={res[f'{tag}_host_sample_us']:.1f}"
            f"_speedup={res[f'{tag}_sample_speedup_tiered_vs_host']:.2f}x"
            f"_lasthop_pages_hbm={res[f'{tag}_last_hop_pages_hbm']}"
            f"_host={res[f'{tag}_last_hop_pages_host']}"
            f"_storage={res[f'{tag}_last_hop_pages_storage']}")
    for f in GPU_BUDGET_SWEEP:
        row(f"fig7_tiered_budget_gpu{f:g}",
            res[f"tiered_sample_us_gpu{f:g}"], "monotone_in_gpu_budget")


if __name__ == "__main__":
    main()
