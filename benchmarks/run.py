# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows; `python -m benchmarks.run [--quick]`.  `--json [path]` is the CI
# smoke mode: fig13 + fig14 + shard-scaling + fig7-sampling + serve-load +
# adaptive + fault + multi-host + trace headline numbers as JSON (default
# BENCH_pr10.json) so the perf trajectory is recorded per PR.  `--baseline
# PATH` compares the fresh numbers against a committed earlier BENCH_*.json
# and exits non-zero if the `gids` preset's e2e regressed — and, because
# every deterministic path must stay bit-identical across the adaptive-,
# fault-, host-plane, and observability PRs, the gids numbers must match
# the baseline EXACTLY, not just within tolerance (the fig13 gids run now
# executes with an ENABLED tracer, so the exact-equality gate doubles as
# the tracing bit-invisibility gate).  `--trace` additionally exports the
# Perfetto trace-event artifact and the metrics snapshot.
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

BASELINE_TOLERANCE = 1.05       # gids e2e may not exceed baseline by >5%


def check_baseline(payload: dict, baseline_path: str) -> None:
    """Gate on both gids e2e AND gids exposed prep: e2e is dominated by the
    fixed modelled train step, so the prep gate is the sensitive one (a 5%
    e2e tolerance alone would let the data plane regress severalfold)."""
    with open(baseline_path) as f:
        baseline = json.load(f)
    for key, unit in (("gids_e2e_s", "s"), ("gids_exposed_prep_us", "us")):
        fresh = payload["fig13_e2e"][key]
        ref = baseline["fig13_e2e"][key]
        if fresh > ref * BASELINE_TOLERANCE:
            raise SystemExit(
                f"PERF REGRESSION: {key} {fresh:.6f}{unit} vs baseline "
                f"{ref:.6f}{unit} ({baseline_path}) exceeds the "
                f"{BASELINE_TOLERANCE:.2f}x tolerance")
        # the adaptive plane must not perturb static planes at all: the
        # model is deterministic, so the gids preset has to reproduce the
        # committed baseline bit-for-bit, not merely within tolerance
        if fresh != ref:
            raise SystemExit(
                f"DETERMINISM REGRESSION: {key} {fresh!r}{unit} must be "
                f"bit-identical to baseline {ref!r}{unit} ({baseline_path})")
        print(f"# baseline check OK: {key} {fresh:.6f}{unit} == "
              f"{ref:.6f}{unit} ({baseline_path})", flush=True)


def write_json_smoke(path: str, baseline: str | None = None,
                     trace: bool = False) -> None:
    from benchmarks import (fig7_sampling, fig13_e2e, fig14_overlap,
                            fig_adaptive, fig_faults, fig_hosts,
                            fig_serve_load, fig_shard_scaling, fig_trace)
    payload = {
        "fig13_e2e": fig13_e2e.headline(),
        "fig14_overlap": fig14_overlap.headline(),
        "fig_shard_scaling": fig_shard_scaling.headline(),
        "fig7_sampling": fig7_sampling.headline(),
        "fig_serve_load": fig_serve_load.headline(),
        "fig_adaptive": fig_adaptive.headline(),
        "fig_faults": fig_faults.headline(),
        "fig_hosts": fig_hosts.headline(),
        "fig_trace": (fig_trace.export() if trace
                      else fig_trace.headline()),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"# wrote {path}", flush=True)
    print(json.dumps(payload, indent=2))
    merged = payload["fig13_e2e"]
    if merged["e2e_speedup_gids_merged_vs_gids"] < 1.0:
        raise SystemExit(
            "MERGED REGRESSION: the gids-merged preset must beat gids e2e "
            f"(got {merged['e2e_speedup_gids_merged_vs_gids']:.4f}x)")
    shards = payload["fig_shard_scaling"]
    if shards["prep_speedup_4shard_vs_1shard"] <= 1.0:
        raise SystemExit(
            "SHARD-SCALING REGRESSION: 4-shard exposed prep must be "
            "strictly below 1-shard (got "
            f"{shards['prep_speedup_4shard_vs_1shard']:.4f}x speedup)")
    sampling = payload["fig7_sampling"]
    if sampling["sample_speedup_tiered_vs_host"] <= 1.0:
        raise SystemExit(
            "TOPOLOGY REGRESSION: tiered sampling must beat the CPU-"
            "sampling baseline on the degree-skewed smoke config (got "
            f"{sampling['sample_speedup_tiered_vs_host']:.4f}x)")
    serve = payload["fig_serve_load"]
    if serve["merged_max_qps"] <= serve["per_request_max_qps"]:
        raise SystemExit(
            "SERVE REGRESSION: deadline-bounded merged admission must "
            "sustain strictly more QPS at the fixed p99 target than "
            f"per-request execution (merged {serve['merged_max_qps']:,.0f} "
            f"vs per-request {serve['per_request_max_qps']:,.0f})")
    if (serve["victim_p99_partitioned_s"]
            >= serve["victim_p99_shared_s"]):
        raise SystemExit(
            "ISOLATION REGRESSION: the tenant-partitioned cache must bound "
            "victim p99 under the noisy tenant strictly below the shared "
            f"cache (partitioned {serve['victim_p99_partitioned_s']*1e3:.3f}"
            f"ms vs shared {serve['victim_p99_shared_s']*1e3:.3f}ms)")
    adaptive = payload["fig_adaptive"]
    if adaptive["adaptive_vs_degree_speedup"] < 1.0:
        raise SystemExit(
            "ADAPTIVE REGRESSION: adaptive placement must beat static "
            "degree end-to-end under hot-set rotation, net of priced "
            "migration IOs (got "
            f"{adaptive['adaptive_vs_degree_speedup']:.4f}x)")
    if not adaptive["static_bit_identical"]:
        raise SystemExit(
            "ADAPTIVE REGRESSION: on a drift-free workload the adaptive "
            "plane must be bit-identical to static degree placement with "
            f"zero migrations (migrations="
            f"{adaptive['static_n_migrations']})")
    if not adaptive["topo_blocks_identical"]:
        raise SystemExit(
            "ADAPTIVE REGRESSION: topology refresh moves pages between "
            "tiers, never edges — sampled blocks diverged from the static "
            "degree admission")
    faults = payload["fig_faults"]
    if faults["hedged_vs_naive_speedup"] < 1.3:
        raise SystemExit(
            "FAULT REGRESSION: hedged reads + replicated failover must "
            "recover >= 1.3x of a single-shard 10x brownout vs the "
            "unreplicated plane (got "
            f"{faults['hedged_vs_naive_speedup']:.4f}x)")
    if not faults["fault_data_identical"]:
        raise SystemExit(
            "FAULT REGRESSION: faults perturb timing and routing only — "
            "sampled blocks or feature bytes diverged from the fault-free "
            "loader under the chaos schedule")
    if not faults["faultfree_identical"]:
        raise SystemExit(
            "FAULT REGRESSION: an empty fault schedule must be invisible — "
            "prep floats or record timings diverged from a plane with no "
            "fault machinery")
    if (faults["serve_ctl_p99_ratio"] > 1.5
            or faults["serve_shed_fraction"] >= 0.2):
        raise SystemExit(
            "FAULT REGRESSION: serve brownout control must keep victim p99 "
            "within 1.5x of fault-free while shedding < 20% (got ratio "
            f"{faults['serve_ctl_p99_ratio']:.4f}x, shed "
            f"{faults['serve_shed_fraction']:.4f})")
    hosts = payload["fig_hosts"]
    if hosts["speedup_metis_co_vs_hash_indep_4hosts"] < 1.5:
        raise SystemExit(
            "HOST-PLACEMENT REGRESSION: metis-lite + co-partitioning must "
            "beat hash + independent topology by >= 1.5x exposed prep at 4 "
            "hosts (got "
            f"{hosts['speedup_metis_co_vs_hash_indep_4hosts']:.4f}x)")
    if not hosts["hosts1_bit_identical"]:
        raise SystemExit(
            "HOST-PLANE REGRESSION: the 1-host cluster must degenerate to "
            "the single-host plane exactly — modelled prep floats diverged")
    obs = payload["fig_trace"]
    if not obs["tracer_bit_invisible"]:
        raise SystemExit(
            "OBSERVABILITY REGRESSION: an enabled tracer changed a priced "
            "float, a sampled block, or a gathered byte — tracing must be "
            "bit-invisible")
    if not obs["spans_reconciled"]:
        raise SystemExit(
            "OBSERVABILITY REGRESSION: batch span trees no longer sum to "
            "Batch.prep_time_s (max reconcile error "
            f"{obs['max_reconcile_error']:.3e})")
    if not obs["trace_valid"]:
        raise SystemExit(
            "OBSERVABILITY REGRESSION: exported trace failed schema "
            f"validation ({obs['n_trace_problems']} problems) — spans must "
            "be well-formed, nested, and monotone per track")
    if baseline:
        check_baseline(payload, baseline)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the slow E2E figures")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", nargs="?", const="BENCH_pr10.json",
                    default=None, metavar="PATH",
                    help="smoke mode: write fig13/fig14/shard-scaling/"
                         "fig7-sampling/serve-load/adaptive/fault/multi-host/"
                         "trace headline numbers to PATH (default "
                         "BENCH_pr10.json) and exit")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="with --json: fail if the gids preset's e2e "
                         "regressed vs this earlier BENCH_*.json")
    ap.add_argument("--trace", action="store_true",
                    help="with --json: also export the Perfetto trace "
                         "(trace.json) and metrics snapshot (metrics.json) "
                         "artifacts from a traced merged-window run")
    args = ap.parse_args()

    if args.json:
        write_json_smoke(args.json, baseline=args.baseline,
                         trace=args.trace)
        return

    from benchmarks import (fig3_request_rates, fig7_sampling,
                            fig8_bandwidth_model, fig9_accumulator,
                            fig10_constant_buffer, fig11_window_buffering,
                            fig12_cache_size, fig13_e2e, fig14_overlap,
                            fig15_ladies, fig_adaptive, fig_faults,
                            fig_hosts, fig_serve_load, fig_shard_scaling,
                            fig_trace, roofline, tables)
    suites = [
        ("tables", tables.main),
        ("fig3", fig3_request_rates.main),
        ("fig_serve_load", fig_serve_load.main),
        ("fig_adaptive", fig_adaptive.main),
        ("fig_faults", fig_faults.main),
        ("fig7", fig7_sampling.main),
        ("fig8", fig8_bandwidth_model.main),
        ("fig9", fig9_accumulator.main),
        ("fig10", fig10_constant_buffer.main),
        ("fig11", fig11_window_buffering.main),
        ("fig12", fig12_cache_size.main),
        ("fig13_14", fig13_e2e.main),
        ("fig14_overlap", fig14_overlap.main),
        ("fig15", fig15_ladies.main),
        ("fig_shard_scaling", fig_shard_scaling.main),
        ("fig_hosts", fig_hosts.main),
        ("fig_trace", fig_trace.main),
        ("roofline", roofline.main),
    ]
    if args.quick:
        suites = [s for s in suites if s[0] not in ("fig13_14", "fig3")]
    if args.only:
        suites = [s for s in suites if s[0] == args.only]

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        t0 = time.time()
        try:
            fn()
            print(f"# suite {name} done in {time.time()-t0:.1f}s",
                  flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# suite {name} FAILED", flush=True)
            traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == '__main__':
    main()
