"""Sharded storage namespace — placement policies for multi-SSD planes.

The paper's throughput headline scales with the number of SSDs (§4.2's burst
model is parameterised by ``n_ssd``), and the BaM lineage behind GIDS treats
the storage namespace as a *striped array of independent queues*: each shard
drains at its own device's rate and the batch completes when the slowest
shard does.  This module owns the question "which shard holds node i" — a
pluggable `PlacementPolicy` resolved through a registry, so the
`ShardedStorageTier` (core/tiers.py), the per-shard burst pricing
(`storage_sim.price_sharded_burst`), and a future across-hosts variant all
share one placement vocabulary:

  hash    — Fibonacci-hash striping; balanced in expectation for any id
            distribution (the default)
  range   — contiguous id blocks, one per shard; preserves the namespace's
            physical row order (coalescing-friendly, skew-prone on power-law
            access patterns)
  degree  — degree-aware striping: nodes sorted by degree, dealt round-robin
            across shards so the hot high-degree head of a power-law graph
            never lands on one queue
  skewed  — a deliberately imbalanced hash (shard 0 oversubscribed) used by
            `benchmarks/fig_shard_scaling.py` to show the modelled plane
            degrades gracefully, not cliff-like, under bad placement
  adaptive — degree striping that *learns*: starts bit-identical to `degree`
            and re-stripes measured-hot nodes round-robin when the
            `ShardRebalancer` (core/feedback.py) decides a priced migration
            pays for itself

The static policies are pure functions of the node id namespace (plus static
graph metadata for `degree`), so shard assignment is deterministic and
checkpoint-stable; `state_dict`/`load_state_dict` round-trip the assignment
so the mutable `adaptive` policy (online rebalancing) inherits resume
support — its learned touch table rides the same checkpoint path.
"""
from __future__ import annotations

from typing import Callable, Protocol, runtime_checkable

import numpy as np

from .feedback import TouchTable

#: Fibonacci multiplier shared with the software cache's set hash — a
#: different shift keeps shard striping decorrelated from set indexing.
_FIB = np.uint64(0x9E3779B97F4A7C15)


def _mix(ids: np.ndarray) -> np.ndarray:
    """The shared Fibonacci mix both hash-family policies stripe with —
    one definition so their bit recipes can never silently diverge."""
    return (ids.astype(np.uint64) * _FIB) >> np.uint64(40)


@runtime_checkable
class PlacementPolicy(Protocol):
    """Maps node ids onto storage shards.  `shard_of` must be deterministic
    between calls (the merged executor and the pricing model both resolve the
    same ids) and total over the id namespace."""

    name: str
    n_shards: int

    def shard_of(self, node_ids: np.ndarray) -> np.ndarray: ...

    def state_dict(self) -> dict: ...

    def load_state_dict(self, state: dict) -> None: ...


class _PolicyBase:
    """Shared shape checks + default (parameter-only) checkpoint state."""

    name = "placement"

    def __init__(self, n_shards: int):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.n_shards = int(n_shards)

    def _ids(self, node_ids: np.ndarray) -> np.ndarray:
        return np.asarray(node_ids, dtype=np.int64)

    def state_dict(self) -> dict:
        return {"name": self.name, "n_shards": self.n_shards}

    def load_state_dict(self, state: dict) -> None:
        if state.get("name", self.name) != self.name \
                or state.get("n_shards", self.n_shards) != self.n_shards:
            raise ValueError(
                f"placement state {state.get('name')!r}/"
                f"{state.get('n_shards')} does not match policy "
                f"{self.name!r}/{self.n_shards}")


# -- registry ------------------------------------------------------------------

PlacementFactory = Callable[..., PlacementPolicy]
_PLACEMENTS: dict[str, PlacementFactory] = {}


def register_placement(name: str) -> Callable[[PlacementFactory],
                                              PlacementFactory]:
    """Register a factory ``(n_shards, *, num_nodes, degrees, graph, seed)
    -> PlacementPolicy`` under `name`.  The factory receives every context
    keyword and ignores what it does not need, so new policies (locality-,
    score-, or host-topology-aware) slot in without touching callers —
    `metis-lite` below consumes the full CSR via `graph`."""
    def deco(fn: PlacementFactory) -> PlacementFactory:
        _PLACEMENTS[name] = fn
        return fn
    return deco


def placement_names() -> tuple[str, ...]:
    return tuple(sorted(_PLACEMENTS))


def make_placement(name: str, n_shards: int, *, num_nodes: int | None = None,
                   degrees: np.ndarray | None = None, graph=None,
                   seed: int = 0) -> PlacementPolicy:
    try:
        factory = _PLACEMENTS[name]
    except KeyError:
        raise KeyError(f"unknown placement policy {name!r}; registered: "
                       f"{placement_names()}") from None
    return factory(n_shards, num_nodes=num_nodes, degrees=degrees,
                   graph=graph, seed=seed)


# -- the built-in policies -----------------------------------------------------

class HashPlacement(_PolicyBase):
    """Fibonacci-hash striping: balanced in expectation regardless of the id
    distribution, no per-node state."""

    name = "hash"

    def shard_of(self, node_ids: np.ndarray) -> np.ndarray:
        mixed = _mix(self._ids(node_ids))
        return (mixed % np.uint64(self.n_shards)).astype(np.int16)


@register_placement("hash")
def _make_hash(n_shards: int, **_ctx) -> HashPlacement:
    return HashPlacement(n_shards)


class RangePlacement(_PolicyBase):
    """Contiguous id blocks: shard s owns rows
    ``[s * rows_per_shard, (s+1) * rows_per_shard)``.  Keeps each shard's
    rows physically adjacent (a range shard is one file / one namespace),
    at the cost of skew when hot ids cluster."""

    name = "range"

    def __init__(self, n_shards: int, num_nodes: int):
        super().__init__(n_shards)
        if num_nodes is None or num_nodes < 1:
            raise ValueError("range placement needs the namespace size "
                             "(num_nodes)")
        self.num_nodes = int(num_nodes)
        self.rows_per_shard = -(-self.num_nodes // self.n_shards)  # ceil

    def shard_of(self, node_ids: np.ndarray) -> np.ndarray:
        shard = self._ids(node_ids) // self.rows_per_shard
        return np.clip(shard, 0, self.n_shards - 1).astype(np.int16)

    def state_dict(self) -> dict:
        return {**super().state_dict(), "num_nodes": self.num_nodes}

    def load_state_dict(self, state: dict) -> None:
        super().load_state_dict(state)
        # shard boundaries derive from the namespace size: restoring against
        # a different-size feature array would silently shift every boundary
        if state.get("num_nodes", self.num_nodes) != self.num_nodes:
            raise ValueError(
                f"{self.name} placement checkpointed over "
                f"{state.get('num_nodes')} nodes, namespace has "
                f"{self.num_nodes} — shard boundaries would shift")


@register_placement("range")
def _make_range(n_shards: int, *, num_nodes=None, **_ctx) -> RangePlacement:
    return RangePlacement(n_shards, num_nodes)


class DegreePlacement(_PolicyBase):
    """Degree-aware striping: nodes sorted by degree (descending, stable)
    are dealt round-robin across shards, so the hot high-degree head of a
    power-law graph spreads over every queue instead of hammering one.  The
    assignment is a materialized per-node table — the part a checkpoint must
    round-trip, and the seam an online rebalancer would mutate."""

    name = "degree"

    def __init__(self, n_shards: int, degrees: np.ndarray):
        super().__init__(n_shards)
        if degrees is None:
            raise ValueError("degree placement needs per-node degrees "
                             "(pass a graph to the tier factory)")
        degrees = np.asarray(degrees)
        order = np.argsort(-degrees, kind="stable")
        table = np.empty(len(degrees), np.int16)
        table[order] = np.arange(len(degrees), dtype=np.int64) % self.n_shards
        self.table = table

    def shard_of(self, node_ids: np.ndarray) -> np.ndarray:
        return self.table[self._ids(node_ids)]

    def state_dict(self) -> dict:
        return {**super().state_dict(), "table": self.table.copy()}

    def load_state_dict(self, state: dict) -> None:
        super().load_state_dict(state)
        table = np.asarray(state["table"], np.int16)
        if table.shape != self.table.shape:
            # name the failing policy: multi-namespace checkpoints restore
            # several placements and "a table mismatched" is undebuggable
            raise ValueError(
                f"{self.name} placement table shape {table.shape} does not "
                f"match namespace {self.table.shape}")
        self.table = table.copy()


@register_placement("degree")
def _make_degree(n_shards: int, *, degrees=None, **_ctx) -> DegreePlacement:
    return DegreePlacement(n_shards, degrees)


class AdaptivePlacement(DegreePlacement):
    """Feedback-driven striping — `degree` that learns from measured touches.

    The initial table is *exactly* the degree deal (same stable sort, same
    round-robin), so an adaptive plane is bit-identical to a static `degree`
    plane until the first migration commits — static workloads pay nothing
    for turning feedback on.  A `TouchTable` (core/feedback.py) accumulates
    the measured per-node touches; `plan_rebalance()` proposes re-striping
    only the measured-hot nodes (score > 0) round-robin in score order,
    leaving the untouched cold tail wherever it already lives — that is what
    keeps migrations affordable: the moved set scales with the hot set, not
    the namespace.

    The policy is mechanism, not policy-about-policy: *when* to commit is
    the `ShardRebalancer`'s call (imbalance trigger + priced cost/benefit);
    `commit()` just swaps the table after validating it still partitions
    the namespace.  Table and touch table both ride `state_dict`, so a
    checkpoint taken mid-migration-epoch resumes the same assignment and
    the same learned scores."""

    name = "adaptive"

    def __init__(self, n_shards: int, degrees: np.ndarray,
                 alpha: float = 0.5):
        super().__init__(n_shards, degrees)
        self.touches = TouchTable(len(self.table), alpha=alpha)

    def plan_drain(self, shard: int) -> tuple[np.ndarray, np.ndarray]:
        """Propose evacuating the measured-hot rows OFF one shard — the
        fault plane's move when the `ShardHealthMonitor` flags a queue as
        browning out.  Hot nodes currently placed on `shard` are dealt
        round-robin by descending score across the OTHER shards; the cold
        tail stays put (a slow queue still holds its bytes — the drain
        moves the rows that are costing time, not the namespace).  Returns
        ``(new_table, moved_ids)`` like `plan_rebalance`; the
        `ShardRebalancer` prices and commits it."""
        if self.n_shards < 2 or not 0 <= int(shard) < self.n_shards:
            raise ValueError(
                f"{self.name} placement cannot drain shard {shard} of "
                f"{self.n_shards} — draining needs another shard to "
                "absorb the hot set")
        scores = self.touches.scores()
        hot = scores > scores.max() * 0.01 if scores.max() > 0 \
            else np.zeros(len(scores), bool)
        on = np.nonzero(hot & (self.table == int(shard)))[0]
        new = self.table.copy()
        if len(on):
            order = on[np.argsort(-scores[on], kind="stable")]
            others = np.array(
                [s for s in range(self.n_shards) if s != int(shard)],
                np.int16)
            new[order] = others[np.arange(len(order)) % len(others)]
        moved = np.nonzero(new != self.table)[0]
        return new, moved

    def plan_rebalance(self) -> tuple[np.ndarray, np.ndarray]:
        """Propose a re-striped table: measured-hot nodes dealt round-robin
        by descending score.  Returns ``(new_table, moved_ids)``; nothing is
        mutated — the caller decides whether the move is worth its price."""
        scores = self.touches.scores()
        # re-deal only the measurably hot: nodes whose decayed EMA has
        # fallen below 1% of the current peak stay where they are, so the
        # moved set (and the migration bill) tracks the LIVE hot set
        # instead of accreting every node ever touched
        hot = np.nonzero(scores > scores.max() * 0.01)[0] \
            if scores.max() > 0 else np.empty(0, np.int64)
        new = self.table.copy()
        if len(hot):
            order = hot[np.argsort(-scores[hot], kind="stable")]
            new[order] = (np.arange(len(order), dtype=np.int64)
                          % self.n_shards).astype(np.int16)
        moved = np.nonzero(new != self.table)[0]
        return new, moved

    def commit(self, new_table: np.ndarray) -> None:
        new_table = np.asarray(new_table, np.int16)
        if new_table.shape != self.table.shape:
            raise ValueError(
                f"{self.name} placement commit shape {new_table.shape} does "
                f"not match namespace {self.table.shape}")
        if len(new_table) and (new_table.min() < 0
                               or new_table.max() >= self.n_shards):
            raise ValueError(
                f"{self.name} placement commit maps nodes outside "
                f"[0, {self.n_shards}) — namespace no longer partitions")
        self.table = new_table.copy()

    def state_dict(self) -> dict:
        return {**super().state_dict(),
                "touches": self.touches.state_dict()}

    def load_state_dict(self, state: dict) -> None:
        super().load_state_dict(state)
        self.touches.load_state_dict(state["touches"])


@register_placement("adaptive")
def _make_adaptive(n_shards: int, *, degrees=None, **_ctx
                   ) -> AdaptivePlacement:
    return AdaptivePlacement(n_shards, degrees)


class MetisLitePlacement(_PolicyBase):
    """Greedy min-cut partitioning over the CSR — the distributed plane's
    locality policy (a METIS stand-in: BFS-grown balanced partitions, no
    external solver).

    Partitions are grown one at a time.  Each starts from the highest-
    degree unassigned seed and repeatedly absorbs the unassigned nodes
    with positive *gain* — the count of already-absorbed nodes pointing at
    them — best-gain-first (stable order), up to the balance target
    ``ceil(n / n_shards)``; when the frontier dries up (disconnected
    remainder) the next seed restarts it.  Growing along out-edges is what
    makes the policy pay off under the requester model (core/hosts.py): a
    node joins the partition holding most of its IN-neighbours, which is
    exactly the host that will request its feature row.

    Fully deterministic (argsort/argmax tie-breaks are positional), every
    partition is capped at the balance target, and the assignment is a
    materialized table that rides `state_dict` like `degree`'s."""

    name = "metis-lite"

    def __init__(self, n_shards: int, graph=None, indptr=None, indices=None,
                 num_nodes: int | None = None):
        super().__init__(n_shards)
        if graph is not None:
            indptr = getattr(graph, "indptr", indptr)
            indices = getattr(graph, "indices", indices)
        if indptr is None or indices is None:
            raise ValueError(
                "metis-lite placement needs the CSR adjacency — build the "
                "plane with a graph in context (the loader passes it)")
        indptr = np.asarray(indptr, np.int64)
        indices = np.asarray(indices, np.int64)
        n = len(indptr) - 1
        if num_nodes is not None and int(num_nodes) != n:
            raise ValueError(
                f"metis-lite graph has {n} nodes but the namespace has "
                f"{num_nodes} rows — co-partitioning needs one host table "
                "covering both")
        self.table = _grow_partitions(indptr, indices, self.n_shards)

    def shard_of(self, node_ids: np.ndarray) -> np.ndarray:
        return self.table[self._ids(node_ids)]

    def state_dict(self) -> dict:
        return {**super().state_dict(), "table": self.table.copy()}

    def load_state_dict(self, state: dict) -> None:
        super().load_state_dict(state)
        table = np.asarray(state["table"], np.int16)
        if table.shape != self.table.shape:
            raise ValueError(
                f"{self.name} placement table shape {table.shape} does not "
                f"match namespace {self.table.shape}")
        self.table = table.copy()


def _flat_adjacency(indptr: np.ndarray, take: np.ndarray,
                    indices: np.ndarray) -> np.ndarray:
    """All of `take`'s neighbours in one flat gather (CSR slice concat)."""
    counts = np.diff(indptr)[take]
    total = int(counts.sum())
    if not total:
        return indices[:0]
    flat = np.repeat(indptr[take] - (np.cumsum(counts) - counts),
                     counts) + np.arange(total)
    return indices[flat]


def _grow_partitions(indptr: np.ndarray, indices: np.ndarray,
                     k: int) -> np.ndarray:
    """The metis-lite growth loop: k balanced partitions, (N,) int16.

    Growth gain counts edges in BOTH directions (a candidate's edges into
    the growing partition plus the partition's edges into the candidate —
    the transpose CSR is built once), because the cut the multi-host plane
    pays for is symmetric: a cross-host edge costs a remote topology page
    on the sampling side and a remote feature row on the gather side
    (`requester_hosts`, core/hosts.py).

    Partitions are balanced by EDGE MASS (1 + in-degree + out-degree per
    node — METIS vertex weights), not node count: neighbor sampling lands
    on a node in proportion to its degree, so equal node counts on a
    power-law graph would pile nearly all sampled traffic onto whichever
    host drew the hub core and its SSD queue would straggle every burst.

    All k partitions grow ROUND-ROBIN, one absorption chunk each per
    round, from k distinct seeds.  Sequential growth would let partition
    0 harvest the tightest cluster and leave the last partition a bin of
    leftovers that requests everything remotely — interleaving keeps both
    the cut and the remote-serving load spread across hosts."""
    n = len(indptr) - 1
    table = np.full(n, -1, np.int16)
    if k <= 1 or n == 0:
        table[:] = 0
        return table
    indptr = np.asarray(indptr, np.int64)
    indices = np.asarray(indices, np.int64)
    # transpose CSR: r_indices[r_indptr[u]:r_indptr[u+1]] = in-neighbours
    outdeg = np.diff(indptr)
    owner = np.repeat(np.arange(n, dtype=np.int64), outdeg)
    order = np.argsort(indices, kind="stable")
    r_indices = owner[order]
    r_indptr = np.zeros(n + 1, np.int64)
    np.cumsum(np.bincount(indices, minlength=n), out=r_indptr[1:])
    deg = outdeg + np.diff(r_indptr)  # total degree seeds the densest hub
    w = 1 + deg  # per-node mass: expected sampled traffic, never zero
    target = -(-int(w.sum()) // k)  # ceil: the mass cap per partition
    gains = np.zeros((k, n), np.int64)  # per-partition: edges touching p
    masses = np.zeros(k, np.int64)
    active = True
    while active:
        active = False
        for p in range(k):
            if masses[p] >= target:
                continue
            gain = gains[p]
            cand = np.nonzero((table == -1) & (gain > 0))[0]
            if len(cand) == 0:
                unassigned = np.nonzero(table == -1)[0]
                if len(unassigned) == 0:
                    continue
                # (re)seed: densest unassigned node anchors the partition
                take = unassigned[np.argmax(deg[unassigned])][None]
            else:
                # absorb majority-internal candidates in bulk; when the
                # frontier is only weakly attached (gain 1-2 via stray
                # cross-cluster edges), cross it a few best-ratio nodes at
                # a time instead of flooding — raw gain > 0 would leak the
                # partition through every rewired edge and shred the cut
                ratio = gain[cand] / deg[cand]
                strong = ratio >= 0.5
                if strong.any():
                    cand = cand[strong]
                    order = np.argsort(-gain[cand], kind="stable")
                else:
                    order = np.argsort(-ratio, kind="stable")[:32]
                fill = np.cumsum(w[cand[order]])
                fit = fill <= target - masses[p]
                fit[0] = True  # always absorb the best candidate
                take = cand[order[fit]]
            table[take] = p
            masses[p] += int(w[take].sum())
            np.add.at(gain, _flat_adjacency(indptr, take, indices), 1)
            np.add.at(gain, _flat_adjacency(r_indptr, take, r_indices), 1)
            active = True
    leftover = np.nonzero(table == -1)[0]
    if len(leftover):
        # mass overshoot can exhaust later partitions' budgets: pack the
        # remainder onto the lightest partitions deterministically
        for v in leftover[np.argsort(-w[leftover], kind="stable")]:
            dest = int(np.argmin(masses))
            table[v] = dest
            masses[dest] += w[v]
    return table


@register_placement("metis-lite")
def _make_metis_lite(n_shards: int, *, graph=None, num_nodes=None, **_ctx
                     ) -> MetisLitePlacement:
    return MetisLitePlacement(n_shards, graph=graph, num_nodes=num_nodes)


class ReplicatedPlacement:
    """k-way replication wrapped around ANY registered placement policy.

    Replica j of a node whose primary shard is s lives on
    ``(s + j) % n_shards`` — chained declustering, so losing one shard
    spreads its read load over its neighbours instead of doubling one
    queue.  `shard_of` still answers with the PRIMARY (the fault-free plane
    routes and prices bit-identically to the bare policy); the extra
    copies exist for the fault plane: `FailoverRouter` (core/faults.py)
    re-routes reads off dead/degraded primaries at plan time, and the
    `FaultInjector`'s burst pricing drains a dead shard's IOs — and a
    straggler's hedged residual — on the replica queues.

    Replication perturbs routing, never data: every replica of a row holds
    the same bytes, so gathered features cannot depend on which copy
    served them.  Attribute access falls through to the wrapped policy, so
    an adaptive base keeps its `table`/`touches`/`plan_rebalance` seam and
    the `ShardRebalancer` works unchanged."""

    def __init__(self, base: PlacementPolicy, replication_factor: int,
                 failure_domains=None):
        k = int(replication_factor)
        name = getattr(base, "name", "placement")
        # fail loudly at construction: a bad replica map discovered at
        # failover time is an outage, not an exception
        if k < 2:
            raise ValueError(
                f"{name} placement: replication_factor must be >= 2 "
                f"(got {k}); use the bare policy for an unreplicated plane")
        if base.n_shards < 2:
            raise ValueError(
                f"{name} placement: replication needs n_shards >= 2 "
                f"(got {base.n_shards}) — with one shard every replica "
                "lands on the queue it is supposed to survive")
        if k > base.n_shards:
            raise ValueError(
                f"{name} placement: replication_factor {k} exceeds "
                f"n_shards {base.n_shards} — replicas of one node must "
                "land on distinct shards")
        self.base = base
        self.replication_factor = k
        self.n_shards = base.n_shards
        self.name = f"replicated({name})x{k}"
        # fault-aware spread: `failure_domains[s]` names the domain (host,
        # rack, ...) shard s lives in, and replica j walks s+1, s+2, ...
        # skipping shards whose domain is already used — so no two copies
        # of a row share a domain and a whole-domain outage cannot lose
        # data.  With None, or all-distinct domains (each HOST its own
        # domain — the core/hosts.py plane), the walk degenerates to the
        # chained-declustering formula above, bit-identically.
        self.failure_domains = None
        self._replica_map = None
        if failure_domains is not None:
            domains = np.asarray(failure_domains, np.int64)
            if domains.shape != (self.n_shards,):
                raise ValueError(
                    f"{self.name} placement: failure_domains shape "
                    f"{domains.shape} does not match {self.n_shards} shards")
            if len(np.unique(domains)) < k:
                raise ValueError(
                    f"{self.name} placement: only "
                    f"{len(np.unique(domains))} failure domain(s) for "
                    f"replication factor {k} — copies of one row would "
                    "share a domain and die together")
            self.failure_domains = domains
            rep = np.empty((self.n_shards, k), np.int64)
            for s in range(self.n_shards):
                rep[s, 0] = s
                used = {int(domains[s])}
                j, step = 1, 1
                while j < k:
                    t = (s + step) % self.n_shards
                    if int(domains[t]) not in used:
                        rep[s, j] = t
                        used.add(int(domains[t]))
                        j += 1
                    step += 1
            self._replica_map = rep

    def shard_of(self, node_ids: np.ndarray) -> np.ndarray:
        return self.base.shard_of(node_ids)

    def replica_shards(self, shard: int) -> tuple[int, ...]:
        """The replica queues for primary shard `shard` (excludes it)."""
        if self._replica_map is not None:
            return tuple(int(t) for t in self._replica_map[int(shard), 1:])
        return tuple((int(shard) + j) % self.n_shards
                     for j in range(1, self.replication_factor))

    def replicas_of(self, node_ids: np.ndarray) -> np.ndarray:
        """``(len(node_ids), k)`` shard matrix; column 0 is the primary."""
        primary = np.asarray(self.base.shard_of(node_ids), np.int64)
        if self._replica_map is not None:
            return self._replica_map[primary]
        offsets = np.arange(self.replication_factor, dtype=np.int64)
        return (primary[:, None] + offsets[None, :]) % self.n_shards

    def state_dict(self) -> dict:
        domains = None if self.failure_domains is None \
            else self.failure_domains.copy()
        return {"name": self.name, "n_shards": self.n_shards,
                "replication_factor": self.replication_factor,
                "failure_domains": domains,
                "base": self.base.state_dict()}

    def load_state_dict(self, state: dict) -> None:
        k = state.get("replication_factor")
        if state.get("name", self.name) != self.name \
                or k != self.replication_factor:
            raise ValueError(
                f"{self.name} placement: checkpoint replica map "
                f"{state.get('name')!r} (x{k}) does not match "
                f"x{self.replication_factor} — failover would route reads "
                "to shards that never held the replica")
        saved = state.get("failure_domains", self.failure_domains)
        ours = self.failure_domains
        if (saved is None) != (ours is None) or (
                ours is not None
                and not np.array_equal(np.asarray(saved), ours)):
            raise ValueError(
                f"{self.name} placement: checkpoint failure domains do not "
                "match — the replica map would route failover reads to "
                "shards that never held the copy")
        self.base.load_state_dict(state["base"])

    def __getattr__(self, attr: str):
        # the adaptive seam (table / touches / plan_rebalance / plan_drain /
        # commit) and any policy-specific state fall through to the base
        return getattr(self.base, attr)


class SkewedPlacement(_PolicyBase):
    """A deliberately bad hash for the degradation benchmark: shard 0 gets
    `n_shards` weight slots to every other shard's one, so it owns
    ``n / (2n - 1)`` of the namespace (half, in the large-n limit) and the
    max-over-shards pricing exposes the straggler queue."""

    name = "skewed"

    def __init__(self, n_shards: int):
        super().__init__(n_shards)
        weights = np.ones(self.n_shards, np.int64)
        weights[0] = self.n_shards
        self.slots = np.repeat(np.arange(self.n_shards, dtype=np.int16),
                               weights)

    def shard_of(self, node_ids: np.ndarray) -> np.ndarray:
        mixed = _mix(self._ids(node_ids))
        return self.slots[mixed % np.uint64(len(self.slots))]


@register_placement("skewed")
def _make_skewed(n_shards: int, **_ctx) -> SkewedPlacement:
    return SkewedPlacement(n_shards)
