"""Activation-sharding context.

Models are mesh-agnostic; launchers install a spec table here and model code
calls `constrain(x, name)` at propagation anchor points (post-embed, MoE
dispatch, cache layouts).  Outside any context this is the identity, so the
same forward runs on 1 CPU device (tests) and 512 chips (dry-run).
"""
from __future__ import annotations

import contextlib

import jax

_SPECS: dict | None = None


@contextlib.contextmanager
def activation_sharding(specs: dict):
    global _SPECS
    prev, _SPECS = _SPECS, specs
    try:
        yield
    finally:
        _SPECS = prev


def constrain(x, name: str):
    if _SPECS and name in _SPECS and _SPECS[name] is not None:
        return jax.lax.with_sharding_constraint(x, _SPECS[name])
    return x


def current_specs() -> dict | None:
    return _SPECS
