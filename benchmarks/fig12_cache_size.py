"""Fig. 12 — window buffering (depth 16) vs random eviction across GPU
software-cache sizes (4/8/16 GB scaled to this container's graph).

Paper: window buffering wins 1.20x/1.18x/1.12x, and a 4 GB cache WITH the
window beats a 16 GB cache without it."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row
from repro.core import GIDSDataLoader, LoaderConfig, INTEL_OPTANE
from repro.graph.datasets import IGB_FULL


def run(lines: int, depth: int, iters=30):
    g = IGB_FULL.materialize()
    feats = np.zeros((g.num_nodes, 1), np.float32)
    # paper ratio: cache lines ~ nodes of one mini-batch (1M lines vs ~1M
    # sampled nodes); batch 512 x (10,5) gives ~8-12k uniques vs 2^12-2^14
    # line caches -> same regime.
    dl = GIDSDataLoader(
        g, feats,
        LoaderConfig(batch_size=512, fanouts=(10, 5), data_plane="gids",
                     cache_lines=lines, window_depth=depth,
                     cbuf_fraction=0.0),
        ssd=INTEL_OPTANE)
    dl.store.feature_dim = IGB_FULL.feature_dim
    ts = [dl.next_batch().prep_time_s for _ in range(iters)]
    return dl.store.cache.stats.hit_ratio, float(np.mean(ts[5:]))


def main():
    # 4/8/16 GB at 4 KB lines scale to 2^12/2^13/2^14 lines on the
    # 200k-node stand-in (same cache:graph ratio as paper's 4GB:IGB-Full)
    results = {}
    for tag, lines in (("4GB", 1 << 12), ("8GB", 1 << 13),
                       ("16GB", 1 << 14)):
        h0, t0 = run(lines, 0)
        h1, t1 = run(lines, 16)
        results[tag] = (h0, t0, h1, t1)
        row(f"fig12_{tag}", t1 * 1e6,
            f"hit_rand={h0:.3f}_hit_window={h1:.3f}_speedup={t0/t1:.2f}x")
    # paper's kicker: small cache + window >= big cache without
    small_window_t = results["4GB"][3]
    big_rand_t = results["16GB"][1]
    row("fig12_4GB_window_vs_16GB_rand", 0.0,
        f"ratio={big_rand_t/small_window_t:.2f}x"
        f"{'_CONFIRMED' if small_window_t <= big_rand_t else '_NOT_CONFIRMED'}")


if __name__ == "__main__":
    main()
