"""Online inference request streams for the GNN serve plane.

Offline training drives the data plane in epoch order; serving is driven by
*arrival dynamics*.  This module generates the request streams the
`GNNServeEngine` consumes, fully deterministic from a seed:

  * arrivals — Poisson (memoryless baseline) or bursty MMPP (a two-state
    Markov-modulated Poisson process: a low-rate background state and a
    high-rate burst state with exponentially-distributed dwell times, the
    standard model for flash-crowd traffic);
  * seed fanouts — heavy-tailed (shifted-Pareto) per-request seed counts:
    most requests score a handful of nodes, a tail scores many;
  * tenant mixes — each arrival belongs to a tenant whose draws are skewed
    toward a tenant-private HOT SET (the per-user neighbourhood a
    recommender hits over and over), with `hot_prob` mass on the hot set
    and the rest uniform over the whole graph.  Hot-set skew is what makes
    the software-cache tier matter online, and per-tenant hot sets are what
    the tenant-partitioned cache isolates.

Every request carries its arrival time, tenant, seed nodes, and SLO
deadline; the stream is sorted by arrival and rid-stamped in arrival order.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's traffic and locality profile."""

    name: str
    rate_share: float = 1.0         # share of the offered load
    hot_fraction: float = 0.03      # fraction of the node space in the hot set
    hot_prob: float = 0.9           # P(seed drawn from the hot set)
    mean_seeds: int = 4             # mean seeds per request
    max_seeds: int = 64             # heavy-tail clip
    seed_tail: float = 1.5          # Pareto shape; smaller = heavier tail
    deadline_s: float = 3e-3        # SLO budget from arrival
    arrival: str = "poisson"        # "poisson" | "mmpp"
    burst_factor: float = 6.0       # MMPP: burst-state rate multiplier
    burst_fraction: float = 0.15    # MMPP: fraction of time in burst state
    burst_cycle_s: float = 0.02     # MMPP: mean on+off cycle length
    # half-open node-id range this tenant's seeds (and hot set) come from;
    # None = the whole graph.  With a `graph.csr.disjoint_union` graph this
    # pins each tenant to its own component — the colocated-datasets layout
    node_range: tuple[int, int] | None = None

    def resolve_range(self, num_nodes: int) -> tuple[int, int]:
        lo, hi = self.node_range or (0, num_nodes)
        if not 0 <= lo < hi <= num_nodes:
            raise ValueError(f"node_range {self.node_range} outside "
                             f"[0, {num_nodes})")
        return lo, hi


@dataclasses.dataclass
class ServeRequest:
    """One inference request: score `seeds` against the model by
    `arrival_s + deadline_s`."""

    rid: int
    tenant: int
    arrival_s: float
    seeds: np.ndarray               # (k,) int64 seed node ids
    deadline_s: float


def poisson_arrivals(rate_qps: float, n: int,
                     rng: np.random.Generator) -> np.ndarray:
    """n arrival times of a homogeneous Poisson process (exponential gaps)."""
    if rate_qps <= 0:
        raise ValueError(f"rate must be positive, got {rate_qps}")
    return np.cumsum(rng.exponential(1.0 / rate_qps, n))


def mmpp_arrivals(rate_qps: float, n: int, rng: np.random.Generator,
                  burst_factor: float = 6.0, burst_fraction: float = 0.15,
                  cycle_s: float = 0.02) -> np.ndarray:
    """n arrival times of a 2-state Markov-modulated Poisson process.

    The process alternates between a burst state (rate `m * base`) and a
    background state (rate `base`), with exponential dwell times averaging
    `burst_fraction * cycle_s` and `(1 - burst_fraction) * cycle_s`.  `base`
    is chosen so the long-run mean rate equals `rate_qps`:

        mean = base * (f * m + (1 - f))  =>  base = rate / (f*m + 1 - f)

    Same mean load as the Poisson stream, far burstier gaps — the stress
    test for deadline-bounded window formation.
    """
    if burst_factor < 1:
        raise ValueError(f"burst_factor must be >= 1, got {burst_factor}")
    f = burst_fraction
    base = rate_qps / (f * burst_factor + (1.0 - f))
    rates = (base, base * burst_factor)             # (background, burst)
    dwells = ((1.0 - f) * cycle_s, f * cycle_s)
    out = np.empty(n)
    t, got, state = 0.0, 0, 0
    state_end = t + rng.exponential(dwells[state])
    while got < n:
        gap = rng.exponential(1.0 / rates[state])
        if t + gap < state_end:
            t += gap
            out[got] = t
            got += 1
        else:
            # the memoryless gap does not survive the rate change: restart
            # the clock at the state boundary under the new rate
            t = state_end
            state = 1 - state
            state_end = t + rng.exponential(dwells[state])
    return out


def _seed_counts(spec: TenantSpec, n: int,
                 rng: np.random.Generator) -> np.ndarray:
    """Heavy-tailed per-request seed counts: 1 + scaled Pareto, clipped.
    The scale puts the pre-clip mean at `mean_seeds` (shifted-Pareto mean
    is 1 + scale/(tail-1) for tail > 1)."""
    scale = max(spec.mean_seeds - 1, 0) * max(spec.seed_tail - 1, 0.05)
    draw = 1 + rng.pareto(spec.seed_tail, n) * scale
    return np.minimum(draw.astype(np.int64), spec.max_seeds).clip(1)


def tenant_hot_set(num_nodes: int, spec: TenantSpec, tenant: int,
                   seed: int) -> np.ndarray:
    """The tenant-private hot node set: a uniform sample without
    replacement from the tenant's node range, keyed by (stream seed,
    tenant) so distinct tenants get distinct (possibly overlapping) hot
    sets."""
    lo, hi = spec.resolve_range(num_nodes)
    size = max(1, int((hi - lo) * spec.hot_fraction))
    rng = np.random.default_rng(seed * 1009 + tenant)
    return np.sort(lo + rng.choice(hi - lo, size=size, replace=False))


def generate_stream(num_nodes: int, tenants: Sequence[TenantSpec],
                    offered_qps: float, n_requests: int,
                    seed: int = 0) -> list[ServeRequest]:
    """Generate a merged multi-tenant request stream.

    Each tenant runs its own arrival process at `rate_share`-weighted rate
    (so a bursty tenant stays bursty inside the mix); per-tenant request
    counts are proportional to the shares; the merged stream is sorted by
    arrival and rid-stamped in arrival order.
    """
    if not tenants:
        raise ValueError("need at least one TenantSpec")
    shares = np.array([t.rate_share for t in tenants], float)
    if (shares <= 0).any():
        raise ValueError("rate shares must be positive")
    shares = shares / shares.sum()
    counts = np.maximum(1, np.round(shares * n_requests).astype(int))

    requests: list[ServeRequest] = []
    for ti, (spec, n) in enumerate(zip(tenants, counts)):
        rng = np.random.default_rng([seed, ti])
        rate = offered_qps * shares[ti]
        if spec.arrival == "poisson":
            arrivals = poisson_arrivals(rate, n, rng)
        elif spec.arrival == "mmpp":
            arrivals = mmpp_arrivals(rate, n, rng, spec.burst_factor,
                                     spec.burst_fraction, spec.burst_cycle_s)
        else:
            raise ValueError(f"unknown arrival process {spec.arrival!r} "
                             "(expected 'poisson' or 'mmpp')")
        hot = tenant_hot_set(num_nodes, spec, ti, seed)
        lo, hi = spec.resolve_range(num_nodes)
        n_seeds = _seed_counts(spec, n, rng)
        for arrival, k in zip(arrivals, n_seeds):
            from_hot = rng.random(k) < spec.hot_prob
            seeds = np.where(from_hot,
                             rng.choice(hot, k),
                             rng.integers(lo, hi, k)).astype(np.int64)
            requests.append(ServeRequest(
                rid=-1, tenant=ti, arrival_s=float(arrival),
                seeds=np.unique(seeds), deadline_s=spec.deadline_s))
    requests.sort(key=lambda r: r.arrival_s)
    for i, r in enumerate(requests):
        r.rid = i
    return requests
