"""End-to-end GNN training through the GIDS dataloader: loss decreases on a
learnable synthetic task (features encode the label)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GIDSDataLoader, LoaderConfig
from repro.graph.synthetic import rmat_graph
from repro.models.gnn import GNN, GNNConfig, hop_indices


@pytest.mark.parametrize("model", ["sage", "gcn", "gat"])
def test_gnn_learns(model):
    rng = np.random.default_rng(0)
    g = rmat_graph(4000, 10, 16, seed=1)
    n_classes = 5
    labels_all = rng.integers(0, n_classes, g.num_nodes)
    # features = one-hot(label) + noise -> learnable from self features
    feats = (2.0 * np.eye(n_classes, 16)[labels_all]
             + 0.1 * rng.standard_normal((g.num_nodes, 16))
             ).astype(np.float32)

    cfg = GNNConfig(model=model, in_dim=16, hidden_dim=32,
                    num_classes=n_classes, fanouts=(4, 3))
    gnn = GNN(cfg)
    params = gnn.init(jax.random.PRNGKey(0))
    dl = GIDSDataLoader(g, feats, LoaderConfig(
        batch_size=128, fanouts=cfg.fanouts, data_plane="gids",
        cache_lines=2048, window_depth=2))

    @jax.jit
    def step(params, feats_b, h0, h1, h2, labels):
        loss, grads = jax.value_and_grad(gnn.loss)(
            params, feats_b, [h0, h1, h2], labels)
        params = jax.tree.map(lambda p, g_: p - 0.2 * g_, params, grads)
        return params, loss

    losses = []
    for _ in range(60):
        b = dl.next_batch()
        hi = [jnp.asarray(i) for i in hop_indices(b.blocks)]
        lab = jnp.asarray(labels_all[b.blocks.seeds])
        params, loss = step(params, jnp.asarray(b.features),
                            hi[0], hi[1], hi[2], lab)
        losses.append(float(loss))
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert np.isfinite(losses).all()
    assert last < first * 0.8, (first, last)


def test_hop_indices_roundtrip():
    from repro.sampling.neighbor import host_sample_blocks
    g = rmat_graph(1000, 8, 8, seed=2)
    rng = np.random.default_rng(0)
    blocks = host_sample_blocks(g, rng.integers(0, 1000, 16), (3, 2), rng)
    hi = hop_indices(blocks)
    np.testing.assert_array_equal(blocks.all_nodes[hi[0]], blocks.seeds)
    for level, hop in enumerate(blocks.hop_nodes, start=1):
        np.testing.assert_array_equal(blocks.all_nodes[hi[level]], hop)
