"""Architecture registry: one module per assigned architecture.

`get(name)` returns the full published config; `get(name, reduced=True)`
returns the smoke-test reduction (same family/topology, tiny dims).
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "llama4_maverick_400b_a17b",
    "arctic_480b",
    "minicpm_2b",
    "h2o_danube_1_8b",
    "qwen3_14b",
    "qwen2_1_5b",
    "internvl2_1b",
    "whisper_small",
    "recurrentgemma_2b",
    "mamba2_1_3b",
]

def normalize(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get(name: str, reduced: bool = False):
    mod = importlib.import_module(f"repro.configs.{normalize(name)}")
    return mod.reduced_config() if reduced else mod.config()


def all_configs(reduced: bool = False):
    return {a: get(a, reduced) for a in ARCH_IDS}
