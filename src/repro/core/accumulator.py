"""Dynamic storage access accumulator (paper §3.2).

The accumulator exploits the logical independence of (sampling, aggregation)
from the training stage: it runs sampling *ahead* of training and merges the
storage requests of consecutive mini-batch data preparations until the number
of outstanding storage accesses crosses the analytic threshold (Eq. 2-3)
needed to hit the target fraction of peak SSD throughput.

Redirected accesses (GPU-cache hits, constant-buffer hits) do not occupy SSD
queue slots, so the controller tracks the measured redirection rate and
re-inflates the merge depth accordingly — this is the "dynamic" part.

TPU adaptation: "outstanding storage accesses" become outstanding prefetch
requests in the host->device staging pipeline; the same Little's-law model
applies with the staging link's latency/throughput constants, and the merge
depth doubles as the dispatch-ahead depth of the async pipeline.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .storage_sim import SSDSpec, required_accesses


@dataclasses.dataclass
class AccumulatorConfig:
    target_efficiency: float = 0.95
    n_ssd: int = 1
    max_merge_iters: int = 16       # buffer-memory guard (paper: "excessive
                                    # buffer memory usage" bound)
    ema: float = 0.9                # smoothing for the redirection estimate


@dataclasses.dataclass
class MergedWindow:
    """The §3.2 merge made concrete: the union of `n_batches` consecutive
    mini-batch request lists, deduplicated so each unique row is fetched
    from storage exactly once.

    unique_nodes: (U,) sorted unique node ids across the window
    inverse:      (sum_i B_i,) index into `unique_nodes`; batch i's slice
                  reconstructs its request list in original order
                  (`unique_nodes[inverse[offsets[i]:offsets[i+1]]]`) and is
                  the scatter index that expands unique feature rows back to
                  per-batch feature arrays
    offsets:      (n_batches + 1,) slice boundaries into `inverse`
    """

    unique_nodes: np.ndarray
    inverse: np.ndarray
    offsets: np.ndarray

    @property
    def n_batches(self) -> int:
        return len(self.offsets) - 1

    @property
    def n_requests(self) -> int:
        return int(self.offsets[-1])

    @property
    def n_unique(self) -> int:
        return len(self.unique_nodes)

    @property
    def n_duplicate(self) -> int:
        """Rows the per-batch path would have fetched again."""
        return self.n_requests - self.n_unique

    @property
    def dedup_factor(self) -> float:
        return self.n_requests / max(self.n_unique, 1)

    def batch_inverse(self, i: int) -> np.ndarray:
        return self.inverse[self.offsets[i]:self.offsets[i + 1]]

    def batch_multiplicity(self) -> np.ndarray:
        """Per-unique-node count of merged batches requesting it (each
        batch's request list is already deduplicated, so occurrences in the
        inverse == batches).  Windowed tiers consume this many reuse
        reservations in one merged access."""
        return np.bincount(self.inverse, minlength=self.n_unique)


def merge_window(node_lists) -> MergedWindow:
    """Merge consecutive batches' request lists into one deduplicated burst:
    `np.unique(..., return_inverse=True)` over the concatenation gives the
    unique set (gathered once) and the inverse index (scatters rows back to
    each batch).  This is the accumulator's merge *executed*, not just its
    depth computed."""
    lists = [np.asarray(x) for x in node_lists]
    if not lists:
        raise ValueError("merge_window needs at least one batch")
    offsets = np.zeros(len(lists) + 1, np.int64)
    np.cumsum([len(x) for x in lists], out=offsets[1:])
    unique, inverse = np.unique(np.concatenate(lists), return_inverse=True)
    return MergedWindow(unique_nodes=unique,
                        inverse=inverse.astype(np.int64),
                        offsets=offsets)


class DynamicAccessAccumulator:
    """Decides how many future iterations' sampling to merge.

    update(n_sampled, n_redirected) feeds per-iteration telemetry;
    merge_depth(requests_per_iter) returns the number of iterations whose
    data preparation should be in flight simultaneously.
    """

    def __init__(self, spec: SSDSpec, config: AccumulatorConfig | None = None):
        self.spec = spec
        self.config = config or AccumulatorConfig()
        self.threshold = required_accesses(
            spec, self.config.target_efficiency, self.config.n_ssd)
        self._redirect_rate = 0.0

    # -- telemetry ----------------------------------------------------------
    def update(self, n_sampled: int, n_redirected: int) -> None:
        if n_sampled <= 0:
            return
        r = n_redirected / n_sampled
        a = self.config.ema
        self._redirect_rate = a * self._redirect_rate + (1 - a) * r

    @property
    def redirect_rate(self) -> float:
        return self._redirect_rate

    def reset_telemetry(self) -> None:
        """Drop the redirection-rate EMA back to the fresh-accumulator state.
        Checkpoint resume calls this so a restored loader and a freshly-built
        loader make bit-identical merge-depth decisions."""
        self._redirect_rate = 0.0

    # -- policy --------------------------------------------------------------
    def storage_fraction(self) -> float:
        return max(1.0 - self._redirect_rate, 1e-3)

    def merge_depth(self, requests_per_iter: int) -> int:
        """Iterations to merge so that outstanding *storage-bound* requests
        >= threshold: depth * requests * (1 - redirect_rate) >= N_access."""
        if requests_per_iter <= 0:
            return 1
        eff_per_iter = requests_per_iter * self.storage_fraction()
        depth = int(-(-self.threshold // max(eff_per_iter, 1.0)))  # ceil
        return max(1, min(depth, self.config.max_merge_iters))

    def outstanding(self, requests_per_iter: int) -> int:
        d = self.merge_depth(requests_per_iter)
        return int(d * requests_per_iter * self.storage_fraction())

    # -- merge execution ------------------------------------------------------
    def merge(self, node_lists) -> MergedWindow:
        """Execute the merge the depth policy only *sizes*: union the staged
        batches' request lists into one deduplicated window whose unique set
        is gathered once and issued as a single storage burst."""
        return merge_window(node_lists)
