"""Checkpointing: bitwise roundtrip, atomic commit, retention, resume
determinism with the data pipeline (fault tolerance)."""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.train import checkpoint as ckpt


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "layer": {"w": jnp.asarray(rng.standard_normal((8, 16)),
                                   jnp.float32),
                  "b": jnp.asarray(rng.standard_normal(16), jnp.bfloat16)},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip_bitwise(tmp_path):
    tree = _tree()
    ckpt.save(tmp_path, 10, tree, {"note": "x"})
    like = jax.tree.map(lambda a: jnp.zeros_like(a), tree)
    restored, extra = ckpt.restore(tmp_path, 10, like)
    assert extra == {"note": "x"}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_atomic_commit_no_tmp_left(tmp_path):
    ckpt.save(tmp_path, 3, _tree())
    assert not list(tmp_path.glob("*.tmp"))
    assert (tmp_path / "step_00000003" / "manifest.json").exists()


def test_latest_and_retention(tmp_path):
    for s in (1, 2, 3, 4, 5):
        ckpt.save(tmp_path, s, _tree(s))
    assert ckpt.latest_step(tmp_path) == 5
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(kept) == 3                     # keep last 3
    assert kept[-1] == "step_00000005"


def test_corrupt_tmp_is_ignored(tmp_path):
    ckpt.save(tmp_path, 9, _tree())
    # a crashed writer leaves a .tmp dir behind — must not be visible
    (tmp_path / "step_00000011.tmp").mkdir()
    assert ckpt.latest_step(tmp_path) == 9


def test_resume_is_deterministic(tmp_path):
    """Train 6 steps straight vs 3 + crash + resume 3: identical params
    (pipeline state checkpointing closes the data-order loophole)."""
    from repro.data.tokens import TokenPipeline, TokenPipelineConfig

    def make(seed=0):
        cfg = TokenPipelineConfig(batch_size=2, seq_len=8, vocab_size=64,
                                  seed=seed)
        return TokenPipeline(None, cfg, num_tokens=4096)

    def step(w, batch):
        toks = jnp.asarray(batch["tokens"], jnp.float32)
        g = jnp.mean(toks) * 0.01
        return w - g

    # straight run
    pipe = make()
    w = jnp.ones(())
    for _ in range(6):
        w = step(w, next(pipe))
    w_straight = float(w)

    # interrupted run
    pipe = make()
    w = jnp.ones(())
    for _ in range(3):
        w = step(w, next(pipe))
    ckpt.save(tmp_path / "r", 3, {"w": w}, {"pipe": pipe.state_dict()})
    # "crash": rebuild everything from the checkpoint
    pipe2 = make()
    restored, extra = ckpt.restore(tmp_path / "r", 3,
                                   {"w": jnp.zeros(())})
    pipe2.load_state_dict(extra["pipe"])
    w2 = restored["w"]
    for _ in range(3):
        w2 = step(w2, next(pipe2))
    assert float(w2) == w_straight


def test_run_with_restarts_reaches_target(tmp_path):
    from repro.train.fault_tolerance import run_with_restarts

    calls = {"made": 0}

    def make_state(restore_step):
        calls["made"] += 1
        if restore_step is None:
            return jnp.zeros(()), 0
        restored, _ = ckpt.restore(tmp_path, restore_step, jnp.zeros(()))
        return restored, restore_step

    def train_one(state, step):
        return state + 1.0

    final, steps = run_with_restarts(make_state, train_one, 20,
                                     ckpt_dir=tmp_path, save_every=5,
                                     inject_failure_at=12)
    assert steps == 20
    assert float(final) == 20.0
    assert calls["made"] == 2                 # initial + one restart


def test_elastic_restore_with_new_shardings(tmp_path):
    """Restore onto explicit NamedShardings of a (different) mesh — the
    elastic scale-up/down path: a checkpoint written under one topology
    re-shards onto whatever mesh the restarted job has."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh

    tree = _tree(3)
    ckpt.save(tmp_path, 1, tree)
    mesh = make_host_mesh()
    sh = {
        "layer": {"w": NamedSharding(mesh, P("data", None)),
                  "b": NamedSharding(mesh, P())},
        "step": NamedSharding(mesh, P()),
    }
    like = jax.tree.map(lambda a: jnp.zeros_like(a), tree)
    restored, _ = ckpt.restore(tmp_path, 1, like, shardings=sh)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert restored["layer"]["w"].sharding.spec == P("data", None)
