"""Gradient compression for the slow cross-pod (DCN) axis.

Within a pod, gradient all-reduce rides 50 GB/s ICI links; across pods it
crosses data-center network an order of magnitude slower.  The standard
mitigation is to compress only the cross-pod hop:

    g_local  = all_reduce(g, axis="data")        # fast ICI, full precision
    q, scale = int8_quantize(g_local + error)    # error-feedback residual
    g_global = all_reduce_int8(q) * scale        # slow DCN, 4x fewer bytes
    error    = g_local - dequant(q)              # carried to next step

`psum_compressed` implements this with jax.shard_map over the pod axis only
(other mesh axes stay under automatic partitioning).  Error feedback makes
the quantization noise telescoping: the *sum* of applied updates converges
to the sum of true gradients (Karimireddy et al., 2019).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def int8_quantize(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_grads(grads: Any, error: Any) -> tuple[Any, Any]:
    """Quantize grads+error to int8; returns (dequantized, new_error).
    Pure function — composes with any collective placement."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = int8_quantize(g32)
        deq = int8_dequantize(q, s)
        return deq, g32 - deq

    out = jax.tree.map(one, grads, error)
    deq = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    return deq, new_err


def init_error(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def psum_compressed(grads: Any, error: Any, mesh: Mesh,
                    axis: str = "pod") -> tuple[Any, Any]:
    """Cross-axis all-reduce with int8 payload + error feedback.

    grads enter already reduced over the fast axes (XLA inserts those);
    here each leaf is quantized, summed over `axis` with an int32
    accumulator (no overflow for <= 2^23 pods), and dequantized.
    """
    if axis not in mesh.shape or mesh.shape[axis] == 1:
        return grads, error

    def body(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = int8_quantize(g32)
        qsum = jax.lax.psum(q.astype(jnp.int32), axis)
        ssum = jax.lax.pmax(s, axis)  # shared conservative scale
        total = qsum.astype(jnp.float32) * ssum
        return total, g32 - int8_dequantize(q, s)

    fn = jax.shard_map(
        lambda g, e: jax.tree.map(body, g, e),
        mesh=mesh,
        in_specs=(P(), P()), out_specs=(P(), P()),
        check_vma=False,
    )
    out = fn(grads, error)
    summed = jax.tree.map(lambda t: t[0], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    return summed, new_err
