"""Window-buffered software cache: paper §3.4 semantics + invariants +
numpy/JAX twin agreement (property-based)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.software_cache import WindowBufferedCache, run_trace


def zipf_trace(n_batches, batch, n_nodes, seed=0, a=1.3):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_batches):
        ids = rng.zipf(a, size=batch * 4) % n_nodes
        out.append(np.unique(ids)[:batch])
    return out


def test_stats_invariants():
    cache = WindowBufferedCache(256, ways=4, window_depth=4)
    trace = zipf_trace(30, 64, 2000)
    stats = run_trace(cache, trace)
    assert stats.hits + stats.misses == stats.accesses
    assert stats.fills <= stats.misses
    assert stats.fills + stats.bypasses == stats.misses
    assert 0.0 <= stats.hit_ratio <= 1.0


def test_window_buffering_beats_random_eviction():
    """Fig. 11: deeper windows raise the hit ratio on a skewed trace."""
    trace = zipf_trace(60, 128, 4000, seed=3)
    ratios = []
    for depth in (0, 4, 8):
        cache = WindowBufferedCache(512, ways=4, window_depth=depth, seed=7)
        ratios.append(run_trace(cache, trace).hit_ratio)
    assert ratios[1] >= ratios[0]
    assert ratios[2] >= ratios[0]
    assert ratios[2] > ratios[0] + 0.01  # depth 8 is materially better


def test_pinned_lines_never_evicted():
    """A line with positive future-reuse counter must survive until its
    reuses are consumed (the USE state of Fig. 6)."""
    cache = WindowBufferedCache(8, ways=2, window_depth=2, seed=0)
    hot = np.array([1])
    cold_batches = [np.array([9, 17, 25, 33]), np.array([41, 49, 57, 65])]
    cache.push_window(hot)       # future batch containing node 1
    cache.push_window(cold_batches[0])
    cache.access(np.array([1]))  # miss -> fill; window shows no future reuse
    # reinsert with future reuse: push window with node 1 again
    cache.push_window(hot)
    sets = cache.tags == 1
    assert sets.any()
    assert cache.reuse[sets][0] >= 1
    # storm of conflicting fills cannot evict node 1's line
    for b in cold_batches:
        cache.access(b)
        cache.push_window(b + 100)
    assert (cache.tags == 1).any(), "pinned line was evicted"


def test_window_zero_is_bam_baseline():
    cache = WindowBufferedCache(64, ways=4, window_depth=0)
    cache.access(np.array([1, 2, 3]))
    assert (cache.reuse == 0).all()
    assert len(cache.window) == 0


@given(st.integers(0, 2**31 - 1), st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_numpy_jax_twins_agree(seed, depth):
    """The jittable cache (first-safe eviction) matches the numpy
    reference step for step on random traces."""
    import jax.numpy as jnp
    from repro.core import cache_jax

    rng = np.random.default_rng(seed)
    trace = [np.unique(rng.integers(0, 300, 24)) for _ in range(8)]
    B = max(len(b) for b in trace)
    npc = WindowBufferedCache(32, ways=4, window_depth=depth, evict="first")
    jc = cache_jax.init_cache(32, ways=4)

    W = depth
    window: list = []
    for b in trace[:W]:
        npc.push_window(b)
        pad = np.full(B, -1, np.int64)
        pad[:len(b)] = b
        jc = cache_jax.push_window(jc, jnp.asarray(pad, jnp.int32))
        window.append(pad)
    for i, b in enumerate(trace):
        pad = np.full(B, -1, np.int64)
        pad[:len(b)] = b
        if window:
            window.pop(0)
        rest = (np.stack(window) if window
                else np.full((1, B), -1, np.int64))
        fc = cache_jax.count_in_window(jnp.asarray(pad, jnp.int32),
                                       jnp.asarray(rest, jnp.int32))
        hits_np = npc.access(b)
        jc, hits_j, _ = cache_jax.access(jc, jnp.asarray(pad, jnp.int32),
                                         fc)
        np.testing.assert_array_equal(hits_np, np.asarray(hits_j)[:len(b)])
        nxt = i + W
        if W > 0 and nxt < len(trace):
            nb = trace[nxt]
            npc.push_window(nb)
            padn = np.full(B, -1, np.int64)
            padn[:len(nb)] = nb
            jc = cache_jax.push_window(jc, jnp.asarray(padn, jnp.int32))
            window.append(padn)
    assert int(jc.hits) == npc.stats.hits
    assert int(jc.misses) == npc.stats.misses
    np.testing.assert_array_equal(
        np.sort(np.asarray(jc.tags).ravel()),
        np.sort(npc.tags.ravel()).astype(np.int32))
