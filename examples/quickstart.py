"""Quickstart: the GIDS dataloader in 40 lines.

Builds a synthetic power-law graph, turns on all three GIDS techniques
(dynamic access accumulator, constant CPU buffer, window-buffered cache),
and streams mini-batches, printing the tier split and modelled data-prep
time vs the mmap baseline.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import GIDSDataLoader, LoaderConfig, SAMSUNG_980PRO
from repro.graph.synthetic import rmat_graph

graph = rmat_graph(num_nodes=100_000, avg_degree=12, feature_dim=256,
                   seed=0)
features = np.random.default_rng(0).standard_normal(
    (graph.num_nodes, 256)).astype(np.float32)

print(f"graph: {graph.num_nodes:,} nodes, {graph.num_edges:,} edges, "
      f"features {features.nbytes/2**20:.0f} MiB\n")

for mode in ("mmap", "bam", "gids"):
    loader = GIDSDataLoader(
        graph, features,
        LoaderConfig(batch_size=1024, fanouts=(10, 5), mode=mode,
                     cache_lines=8192, window_depth=8, cbuf_fraction=0.1),
        ssd=SAMSUNG_980PRO)
    prep = []
    for _ in range(10):
        batch = loader.next_batch()
        prep.append(batch.prep_time_s)
    r = batch.report
    hit = loader.store.cache.stats.hit_ratio if loader.store.cache else 0.0
    print(f"[{mode:4s}] prep {np.mean(prep)*1e3:8.2f} ms/iter | "
          f"tier split hbm={r.n_hbm_hits} host={r.n_host_hits} "
          f"ssd={r.n_storage} | cache hit {hit:.2f} | "
          f"lookahead depth {batch.merge_depth}")

print("\nfeatures gathered for the last batch:", batch.features.shape)
