"""Tiered feature store — the data plane of the GIDS dataloader.

`TieredFeatureStore` folds an *ordered, pluggable stack* of `Tier`s
(`core/tiers.py`) into a single `GatherPlan` per request batch: each tier is
offered the requests every faster tier declined, so the per-request tier
assignment is a partition by construction.  The paper's fixed hierarchy is
one such stack —

  hbm-cache  (window-buffered software cache, §3.4)
  host-cbuf  (constant pinned-host buffer,   §3.3)
  storage    (memmap standing in for the SSD namespace, §3.1)

— declared by the `gids` preset in `core/dataplane.py`; `bam` and `mmap` are
shorter stacks of the same tiers, and user stacks compose freely.

`gather()` is a *real* data path: it returns the actual feature rows and a
`GatherReport` whose per-tier counts feed the storage-timeline pricing
(`StorageTimeline.price_batch`).  The plan's `kernel_slots` array feeds the
`tiered_gather` Pallas kernel (see `device_rows` for the reference HBM row
store, `tiers.DeviceStoreTier` for the jittable one).

`FeatureStore` survives as a thin compatibility wrapper that builds the
classic cache/cbuf/storage stack from keyword components.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .constant_buffer import ConstantBuffer
from .software_cache import WindowBufferedCache
from .storage_sim import IO_BYTES, coalesce_lines, coalesce_lines_by_shard
from .tiers import (ConstantBufferTier, DeviceCacheTier, GatherPlan,
                    StorageTier, Tier, build_plan, build_plan_merged)


@dataclasses.dataclass(frozen=True)
class GatherReport:
    """Per-batch tier split.  `bytes_per_row` is the size of ONE feature row
    (dim * itemsize) — multiply by a count to get transfer bytes.  The
    `n_hbm_hits` / `n_host_hits` / `n_storage` views aggregate tiers by
    latency class so pricing and telemetry are stack-shape-agnostic.

    On a sharded storage namespace `shard_rows` carries the per-shard split
    of this report's storage-bound requests (`n_shards` entries summing to
    `n_storage`); empty on an unsharded plane.  Per-shard pricing and the
    straggler/imbalance telemetry key off it.

    On a multi-host plane (core/hosts.py) `remote_rows` additionally splits
    out, per SERVING shard, the storage rows requested by a different host
    — the traffic that rides each host's link in
    `StorageTimeline.price_host_burst`.  Empty everywhere else."""

    n_requests: int
    bytes_per_row: int
    tier_names: tuple[str, ...]
    tier_classes: tuple[str, ...]
    tier_counts: tuple[int, ...]
    n_shards: int = 1
    shard_rows: tuple[int, ...] = ()
    remote_rows: tuple[int, ...] = ()

    def _class_count(self, latency_class: str) -> int:
        return sum(n for c, n in zip(self.tier_classes, self.tier_counts)
                   if c == latency_class)

    @property
    def n_hbm_hits(self) -> int:
        return self._class_count("hbm")

    @property
    def n_host_hits(self) -> int:
        return self._class_count("host")

    @property
    def n_storage(self) -> int:
        return self._class_count("storage")

    @property
    def redirected(self) -> int:
        return self.n_requests - self.n_storage

    @property
    def shard_imbalance(self) -> float:
        """Max-over-mean of the per-shard storage row counts; 1.0 when
        balanced (or unsharded).  Row-count imbalance — the time-domain
        version (device-aware) lives on `ShardedBurstResult`."""
        if not self.shard_rows or sum(self.shard_rows) == 0:
            return 1.0
        return max(self.shard_rows) / (sum(self.shard_rows)
                                       / len(self.shard_rows))

    @classmethod
    def from_plan(cls, plan: GatherPlan, bytes_per_row: int) -> "GatherReport":
        ns = plan.n_shards
        shard_rows, remote_rows = (), ()
        if ns > 1:
            shard_rows = tuple(int(c) for c in plan.shard_counts())
            if plan.remote is not None:
                remote_rows = tuple(int(c) for c in plan.remote_counts())
        return cls(
            n_requests=len(plan.node_ids),
            bytes_per_row=bytes_per_row,
            tier_names=tuple(t.name for t in plan.tiers),
            tier_classes=tuple(t.latency_class for t in plan.tiers),
            tier_counts=tuple(int(c) for c in plan.counts()),
            n_shards=ns, shard_rows=shard_rows, remote_rows=remote_rows,
        )


@dataclasses.dataclass(frozen=True)
class CoalescedReport(GatherReport):
    """`GatherReport` for a gather executed inside a merged window.

    The base fields keep their per-scope meaning (`n_requests` /
    `tier_counts` cover whatever request set this report describes — one
    batch's requests for the per-batch reports the loader attaches to each
    `Batch`, the unique set for the window-level report that prices the
    burst).  The extra fields carry the window-wide merge telemetry, shared
    by every report of the same window:

    window_batches:   batches merged into this window
    window_requests:  total requests across the window (duplicates included)
    n_unique:         unique rows in the window (gathered exactly once)
    n_duplicate:      window_requests - n_unique — storage fetches the
                      per-batch path would have re-issued
    n_storage_unique: unique rows the fold assigned to the storage tier
    n_storage_lines:  4 KB IOs after coalescing storage rows that share a
                      line (< n_storage_unique when rows are narrower than
                      one line and neighbours were both requested).
                      Coalescing is SHARD-LOCAL on a sharded namespace —
                      rows sharing a logical line but living on different
                      shards are separate IOs
    shard_lines:      per-shard coalesced IO counts (sums to
                      n_storage_lines); empty on an unsharded plane.
                      Pairs with the inherited `shard_rows` to drive the
                      max-over-shards burst pricing
    remote_lines:     host planes only — per serving host, the coalesced
                      4 KB IOs requested by OTHER hosts: the second level
                      of the two-level merge (dedup per host first, then
                      line-granular link transit per host-local queue).
                      Feeds `price_host_burst`'s link term
    """

    window_batches: int = 1
    window_requests: int = 0
    n_unique: int = 0
    n_duplicate: int = 0
    n_storage_unique: int = 0
    n_storage_lines: int = 0
    shard_lines: tuple[int, ...] = ()
    remote_lines: tuple[int, ...] = ()

    @property
    def dedup_factor(self) -> float:
        return self.window_requests / max(self.n_unique, 1)

    @property
    def coalesce_factor(self) -> float:
        return self.n_storage_unique / max(self.n_storage_lines, 1)


class TieredFeatureStore:
    """An ordered tier stack folded into one gather plan per batch.

    The last tier must be a storage backstop exposing `.features` (the
    authoritative rows); faster tiers only redirect requests off it.
    """

    def __init__(self, tiers: Sequence[Tier]):
        from .tiers import LATENCY_CLASSES
        tiers = tuple(tiers)
        if not tiers:
            raise ValueError("empty tier stack")
        for t in tiers:
            if t.latency_class not in LATENCY_CLASSES:
                raise ValueError(
                    f"tier {t.name!r} has unknown latency_class "
                    f"{t.latency_class!r}; pricing/telemetry aggregate by "
                    f"class and only know {LATENCY_CLASSES}")
        backstop = tiers[-1]
        if backstop.latency_class != "storage" \
                or not hasattr(backstop, "features"):
            raise ValueError(
                f"last tier {backstop.name!r} "
                f"({backstop.latency_class}) is not a storage backstop")
        for i, t in enumerate(tiers):
            # window semantics need the tier to see EVERY batch: access
            # consumes the reuse reservations that push_window made, and a
            # faster tier claiming requests first would leave counters
            # incrementing forever (lines pinned, capacity silently shrinks)
            if getattr(t, "window_depth", 0) > 0 and i != 0:
                raise ValueError(
                    f"windowed tier {t.name!r} must be first in the stack "
                    f"(got position {i}): tiers above it would starve its "
                    "reuse-counter decrements")
        self.tiers = tiers
        self.features = backstop.features
        self.feature_dim = self.features.shape[1]
        self.itemsize = self.features.dtype.itemsize
        self.last_plan: GatherPlan | None = None

    # -- compatibility views ---------------------------------------------------
    @property
    def cache(self) -> WindowBufferedCache | None:
        for t in self.tiers:
            c = getattr(t, "cache", None)
            if isinstance(c, WindowBufferedCache):
                return c
        return None

    @property
    def cbuf(self) -> ConstantBuffer | None:
        for t in self.tiers:
            if isinstance(t, ConstantBufferTier):
                return t.cbuf
        return None

    @property
    def windowed_tier(self) -> Tier | None:
        """First tier with a look-ahead window (drives lookahead sync)."""
        for t in self.tiers:
            if hasattr(t, "window_depth") and hasattr(t, "window"):
                return t
        return None

    # -- data plane -----------------------------------------------------------
    def plan(self, node_ids: np.ndarray) -> GatherPlan:
        return build_plan(self.tiers, node_ids)

    def gather(self, node_ids: np.ndarray) -> tuple[np.ndarray, GatherReport]:
        """Fetch feature rows for (deduplicated) node_ids through the tiers."""
        plan = self.plan(node_ids)
        # a device-store tier at the top already gathered this batch's rows
        # on device during its probe — don't fetch them from the backstop
        # a second time
        rows = getattr(plan.tiers[0], "last_rows", None)
        if rows is None or len(rows) != len(node_ids):
            rows = np.asarray(self.features[node_ids])
        report = GatherReport.from_plan(
            plan, bytes_per_row=self.feature_dim * self.itemsize)
        self.last_plan = plan
        return rows, report

    def gather_merged(self, merged, io_bytes: int = IO_BYTES):
        """Dedup-aware fold: gather a whole merged window through ONE tier
        fold over its unique request set.

        `merged` is an `accumulator.MergedWindow` (unique_nodes + inverse +
        per-batch offsets).  The tier stack is folded once over the unique
        set, each unique row is fetched exactly once (from the device tier's
        probe rows when the top tier is a device store, else from the
        backstop), and rows are scattered back to per-batch feature arrays
        via the inverse index — so per-batch features are bit-identical to
        `gather()` called per batch, while storage never re-fetches a row
        two in-flight batches share.  Storage-bound unique rows that share a
        4 KB IO line coalesce into single IOs (`coalesce_lines`).

        Returns `(rows_list, reports, window_report)`: per-batch feature
        arrays, per-batch `CoalescedReport`s (batch-local tier split +
        window-wide merge telemetry), and the window-level report over the
        unique set that `StorageTimeline.price_merged_burst` prices."""
        unique = merged.unique_nodes
        plan = build_plan_merged(self.tiers, unique,
                                 merged.batch_multiplicity())
        rows = getattr(plan.tiers[0], "last_rows", None)
        if rows is None or len(rows) != len(unique):
            rows = np.asarray(self.features[unique])
        bytes_per_row = self.feature_dim * self.itemsize

        storage_mask = plan.storage_mask()
        n_storage_unique = int(storage_mask.sum())
        n_shards = plan.n_shards
        # shard-local coalescing: the line key is (shard, line) — rows on
        # the same logical 4 KB line but different devices are separate IOs
        shard = plan.shard if plan.shard is not None \
            else np.where(storage_mask, 0, -1).astype(np.int16)
        shard_rows, shard_lines = (), ()
        remote_rows, remote_lines = (), ()
        if n_shards > 1:
            shard_rows = tuple(int(c) for c in np.bincount(
                shard[storage_mask], minlength=n_shards))
            per_shard = coalesce_lines_by_shard(
                unique[storage_mask], shard[storage_mask], n_shards,
                bytes_per_row, io_bytes)
            shard_lines = tuple(int(c) for c in per_shard)
            n_storage_lines = int(per_shard.sum())
            if plan.remote is not None and plan.remote.any():
                # two-level merge, level 2: of each host's deduplicated
                # line set, the lines requested by OTHER hosts transit its
                # link (level 1 — the (shard, line) dedup above — already
                # collapsed duplicate remote rows into one line)
                rm = storage_mask & plan.remote
                remote_rows = tuple(int(c) for c in np.bincount(
                    shard[rm], minlength=n_shards))
                remote_lines = tuple(int(c) for c in coalesce_lines_by_shard(
                    unique[rm], shard[rm], n_shards, bytes_per_row,
                    io_bytes))
        else:
            n_storage_lines = coalesce_lines(unique[storage_mask],
                                             bytes_per_row, io_bytes)
        window_stats = dict(
            window_batches=merged.n_batches,
            window_requests=merged.n_requests,
            n_unique=merged.n_unique,
            n_duplicate=merged.n_duplicate,
            n_storage_unique=n_storage_unique,
            n_storage_lines=n_storage_lines,
            shard_lines=shard_lines,
            remote_lines=remote_lines,
        )
        tier_meta = dict(
            bytes_per_row=bytes_per_row,
            tier_names=tuple(t.name for t in plan.tiers),
            tier_classes=tuple(t.latency_class for t in plan.tiers),
            n_shards=n_shards,
        )
        window_report = CoalescedReport(
            n_requests=merged.n_unique,
            tier_counts=tuple(int(c) for c in plan.counts()),
            shard_rows=shard_rows, remote_rows=remote_rows,
            **tier_meta, **window_stats)

        rows_list, reports = [], []
        for i in range(merged.n_batches):
            inv = merged.batch_inverse(i)
            rows_list.append(rows[inv])
            counts = np.bincount(plan.assignment[inv],
                                 minlength=len(plan.tiers))
            batch_shard_rows, batch_remote_rows = (), ()
            if n_shards > 1:
                bsm = shard[inv] >= 0
                batch_shard_rows = tuple(int(c) for c in np.bincount(
                    shard[inv][bsm], minlength=n_shards))
                if plan.remote is not None:
                    brm = bsm & plan.remote[inv]
                    batch_remote_rows = tuple(int(c) for c in np.bincount(
                        shard[inv][brm], minlength=n_shards))
            reports.append(CoalescedReport(
                n_requests=len(inv),
                tier_counts=tuple(int(c) for c in counts),
                shard_rows=batch_shard_rows, remote_rows=batch_remote_rows,
                **tier_meta, **window_stats))
        self.last_plan = plan
        return rows_list, reports, window_report

    def push_window(self, future_nodes: np.ndarray) -> None:
        """Announce a future batch to every tier (window pinning etc.)."""
        for t in self.tiers:
            t.admit(future_nodes)

    def retire_window(self, n_batches: int) -> None:
        """Drop the windowed tier's look-ahead entries for `n_batches`
        consumed batches.  The merged executor calls this (then re-syncs the
        window) BEFORE `gather_merged`, so the one merged access both
        consumes the current window's reuse reservations (the multiplicity
        decrements) and pins fills by the NEXT window's — mirroring what
        `n_batches` per-batch accesses would have done one at a time."""
        wt = self.windowed_tier
        if wt is None or wt.window_depth == 0:
            return
        for _ in range(min(n_batches, len(wt.window))):
            wt.window.popleft()

    def reset(self) -> None:
        for t in self.tiers:
            t.reset()

    # -- checkpoint ------------------------------------------------------------
    def state_dict(self) -> dict:
        """Durable per-tier state, keyed by tier name.  Only tiers exposing
        `state_dict` contribute (today: the sharded backstop's placement
        assignment — cache contents are deliberately NOT checkpointed, they
        rebuild deterministically on resume)."""
        return {t.name: t.state_dict() for t in self.tiers
                if hasattr(t, "state_dict")}

    def load_state_dict(self, state: dict) -> None:
        by_name = {t.name: t for t in self.tiers}
        for name, tier_state in state.items():
            tier = by_name.get(name)
            if tier is None or not hasattr(tier, "load_state_dict"):
                raise ValueError(
                    f"checkpoint carries state for tier {name!r} but the "
                    f"stack has no such stateful tier "
                    f"({sorted(by_name)}) — plane/checkpoint mismatch")
            tier.load_state_dict(tier_state)

    def device_rows(self, tier_index: int = 0) -> np.ndarray:
        """The HBM row store of a device tier, as the `tiered_gather` Pallas
        kernel consumes it.  A `DeviceStoreTier` keeps the array resident and
        hands it over; for the metadata-only `DeviceCacheTier` reference it
        is materialized from the tags (line i = feature row of its resident
        tag, zeros when empty)."""
        tier = self.tiers[tier_index]
        if hasattr(tier, "device_rows"):
            return tier.device_rows()
        tags = tier.cache.tags.reshape(-1)
        rows = np.zeros((len(tags), self.features.shape[1]),
                        self.features.dtype)
        resident = tags >= 0
        rows[resident] = self.features[tags[resident]]
        return rows


class FeatureStore(TieredFeatureStore):
    """Classic keyword construction of the cache/cbuf/storage stack —
    compatibility wrapper over `TieredFeatureStore`; new code should build a
    stack via `DataPlaneSpec` (core/dataplane.py)."""

    def __init__(self, features: np.ndarray,
                 cache: WindowBufferedCache | None = None,
                 constant_buffer: ConstantBuffer | None = None):
        tiers: list[Tier] = []
        if cache is not None:
            tiers.append(DeviceCacheTier(cache))
        if constant_buffer is not None:
            tiers.append(ConstantBufferTier(
                constant_buffer,
                row_bytes=features.shape[1] * features.dtype.itemsize))
        tiers.append(StorageTier(features))
        super().__init__(tiers)

    # -- construction ---------------------------------------------------------
    @classmethod
    def memmap(cls, path: str, num_nodes: int, dim: int,
               dtype=np.float32, create: bool = False, seed: int = 0,
               **kw) -> "FeatureStore":
        """Features in a file accessed via memmap — the storage namespace.
        (The mmap *baseline dataloader* also reads through this; GIDS differs
        in the orchestration around it, not the bytes.)"""
        mode = "w+" if create else "r+"
        arr = np.memmap(path, dtype=dtype, mode=mode, shape=(num_nodes, dim))
        if create:
            rng = np.random.default_rng(seed)
            step = max(1, num_nodes // 64)
            for i in range(0, num_nodes, step):
                j = min(num_nodes, i + step)
                arr[i:j] = rng.standard_normal((j - i, dim), dtype=np.float32)
            arr.flush()
        return cls(arr, **kw)

    @classmethod
    def synthetic(cls, num_nodes: int, dim: int, dtype=np.float32,
                  seed: int = 0, **kw) -> "FeatureStore":
        rng = np.random.default_rng(seed)
        feats = rng.standard_normal((num_nodes, dim)).astype(dtype)
        return cls(feats, **kw)
