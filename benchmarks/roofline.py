"""Aggregate experiments/dryrun/*.json into the §Roofline table
(markdown + CSV under experiments/)."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import row

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
OUT = DRYRUN.parent

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_records():
    recs = []
    for fn in sorted(DRYRUN.glob("*.json")):
        r = json.loads(fn.read_text())
        if "hillclimb" in fn.name or r.get("tag"):
            continue
        recs.append(r)
    return recs


def fmt_table(recs, mesh: str) -> str:
    lines = ["| arch | shape | status | strat | peak GiB/dev | compute s | "
             "memory s | collective s | bottleneck | useful |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"],
                                         SHAPE_ORDER.index(r["shape"]))):
        if r["mesh"] != mesh:
            continue
        if not r.get("roofline"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['status']} |"
                         " — | — | — | — | — | — | — |")
            continue
        ro = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['status']} "
            f"| {r.get('strategy','')} "
            f"| {r['memory']['peak_per_device_gib']:.1f} "
            f"| {ro['compute_term']:.4f} | {ro['memory_term']:.4f} "
            f"| {ro['collective_term']:.4f} | {ro['bottleneck']} "
            f"| {ro['useful_ratio']:.3f} |")
    return "\n".join(lines)


def main():
    recs = load_records()
    ok = [r for r in recs if r.get("status") == "OK"]
    skip = [r for r in recs if str(r.get("status", "")).startswith("SKIP")]
    fail = [r for r in recs if str(r.get("status", "")).startswith("FAIL")]
    row("roofline_cells", 0.0,
        f"ok={len(ok)}_skip={len(skip)}_fail={len(fail)}")
    for mesh in ("16x16", "2x16x16"):
        md = fmt_table(recs, mesh)
        (OUT / f"roofline_{mesh}.md").write_text(md + "\n")
    # csv
    csv = ["arch,shape,mesh,status,strategy,peak_gib,compute_s,memory_s,"
           "collective_s,bottleneck,useful_ratio"]
    for r in recs:
        ro = r.get("roofline") or {}
        mem = r.get("memory") or {}
        csv.append(",".join(str(x) for x in [
            r["arch"], r["shape"], r["mesh"], r.get("status"),
            r.get("strategy", ""), mem.get("peak_per_device_gib", ""),
            ro.get("compute_term", ""), ro.get("memory_term", ""),
            ro.get("collective_term", ""), ro.get("bottleneck", ""),
            ro.get("useful_ratio", "")]))
    (OUT / "roofline.csv").write_text("\n".join(csv) + "\n")
    # headline stats for the bench log
    if ok:
        worst = min((r for r in ok if r["shape"] == "train_4k"),
                    key=lambda r: r["roofline"]["useful_ratio"],
                    default=None)
        if worst:
            row("roofline_worst_train_useful", 0.0,
                f"{worst['arch']}_{worst['mesh']}="
                f"{worst['roofline']['useful_ratio']:.3f}")
        collbound = [r for r in ok
                     if r["roofline"]["bottleneck"] == "collective"]
        row("roofline_collective_bound_cells", 0.0, str(len(collbound)))


if __name__ == "__main__":
    main()
