"""Property tests for the multi-host plane: for ANY graph, host count,
placement policy, and co-partitioning choice, the distributed plane's
features and sampled blocks are bit-identical to the single-host plane —
hosts change modelled time and telemetry, never data — and under
`CoPartitionedPlacement` the feature host and topology host agree for
every node."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import (CoPartitionedPlacement, GIDSDataLoader, LoaderConfig,
                        SAMSUNG_980PRO, make_placement)
from repro.graph.synthetic import clustered_graph, rmat_graph

SETTINGS = settings(max_examples=15, deadline=None,
                    suppress_health_check=(HealthCheck.too_slow,))


def _graph(kind, n, seed):
    if kind == "clustered":
        return clustered_graph(n, 6, 8, communities=8, intra=0.85, seed=seed)
    return rmat_graph(n, 6, 8, seed=seed)


def _features(n, seed):
    return np.random.default_rng(seed).standard_normal(
        (n, 8)).astype(np.float32)


def _run(g, feats, plane, n_batches=4, **kw):
    cfg = LoaderConfig(batch_size=48, fanouts=(3, 2), data_plane=plane,
                       cache_lines=64, window_depth=2, seed=11, **kw)
    dl = GIDSDataLoader(g, feats, cfg, ssd=SAMSUNG_980PRO)
    return [dl.next_batch() for _ in range(n_batches)], dl


@SETTINGS
@given(kind=st.sampled_from(["clustered", "rmat"]),
       n=st.integers(min_value=300, max_value=900),
       gseed=st.integers(min_value=0, max_value=7),
       n_hosts=st.integers(min_value=1, max_value=4),
       placement=st.sampled_from(["hash", "metis-lite", "degree"]),
       co=st.booleans())
def test_host_plane_data_bit_identical_to_single_host(
        kind, n, gseed, n_hosts, placement, co):
    g = _graph(kind, n, gseed)
    feats = _features(g.num_nodes, gseed)
    ref, _ = _run(g, feats, "gids-merged")
    got, _ = _run(g, feats, "gids-hosts-merged", n_hosts=n_hosts,
                  placement=placement, co_partition=co)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a.features, b.features)
        np.testing.assert_array_equal(a.blocks.seeds, b.blocks.seeds)
        np.testing.assert_array_equal(a.blocks.all_nodes, b.blocks.all_nodes)
        for ha, hb in zip(a.blocks.hop_nodes, b.blocks.hop_nodes):
            np.testing.assert_array_equal(ha, hb)


@SETTINGS
@given(kind=st.sampled_from(["clustered", "rmat"]),
       n=st.integers(min_value=300, max_value=900),
       gseed=st.integers(min_value=0, max_value=7),
       n_hosts=st.integers(min_value=2, max_value=5),
       placement=st.sampled_from(["hash", "metis-lite", "degree", "range"]))
def test_co_partitioned_hosts_agree_per_node(kind, n, gseed, n_hosts,
                                             placement):
    g = _graph(kind, n, gseed)
    pol = CoPartitionedPlacement(make_placement(
        placement, n_hosts, num_nodes=g.num_nodes, graph=g,
        degrees=np.diff(g.indptr)))
    ids = np.arange(g.num_nodes)
    np.testing.assert_array_equal(pol.shard_of(ids),
                                  pol.topology_host_of(ids))


@SETTINGS
@given(n=st.integers(min_value=300, max_value=900),
       gseed=st.integers(min_value=0, max_value=7),
       n_hosts=st.integers(min_value=2, max_value=4))
def test_loader_tier_agreement_under_co_partition(n, gseed, n_hosts):
    g = _graph("clustered", n, gseed)
    feats = _features(g.num_nodes, gseed)
    _, dl = _run(g, feats, "gids-hosts-merged", n_hosts=n_hosts,
                 placement="metis-lite", co_partition=True)
    tier = dl.plane.store.tiers[-1]
    ids = np.arange(g.num_nodes)
    np.testing.assert_array_equal(tier.placement.shard_of(ids),
                                  tier.topo_host_of(ids))
