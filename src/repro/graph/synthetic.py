"""Synthetic graph generators (RMAT / uniform) standing in for IGB/OGB.

Table 2/3 of the paper list IGB-tiny..IGB-Full and ogbn-papers100M etc.
We reproduce their *shape* (node count, avg degree, feature dim, skew) with
RMAT generators so every benchmark is runnable offline.  `datasets.py`
registers paper-scale specs plus the scaled-down variants actually executed.
"""
from __future__ import annotations

import numpy as np

from .csr import CSRGraph, from_edge_list


def rmat_edges(num_nodes: int, num_edges: int, *, a=0.57, b=0.19, c=0.19,
               seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Recursive-matrix (RMAT) edge generator — power-law degree skew like
    real citation/web graphs (hot nodes exist, which the constant-buffer
    experiments need)."""
    rng = np.random.default_rng(seed)
    scale = int(np.ceil(np.log2(max(num_nodes, 2))))
    src = np.zeros(num_edges, dtype=np.int64)
    dst = np.zeros(num_edges, dtype=np.int64)
    for level in range(scale):
        r = rng.random(num_edges)
        src_bit = (r >= a + b).astype(np.int64)
        # quadrant probabilities: [a, b; c, d]
        dst_bit = (((r >= a) & (r < a + b)) | (r >= a + b + c)).astype(np.int64)
        src = (src << 1) | src_bit
        dst = (dst << 1) | dst_bit
    src %= num_nodes
    dst %= num_nodes
    keep = src != dst
    return src[keep], dst[keep]


def rmat_graph(num_nodes: int, avg_degree: int, feature_dim: int,
               *, seed: int = 0, name: str = "rmat") -> CSRGraph:
    src, dst = rmat_edges(num_nodes, num_nodes * avg_degree, seed=seed)
    return from_edge_list(src, dst, num_nodes, feature_dim=feature_dim,
                          name=name)


def clustered_graph(num_nodes: int, avg_degree: int, feature_dim: int,
                    *, communities: int = 32, intra: float = 0.9,
                    seed: int = 0, name: str = "clustered") -> CSRGraph:
    """Community-structured power-law graph — the locality real GNN
    datasets have and pure RMAT lacks.

    ogbn-products / IGB-style graphs partition well (METIS finds cuts in
    the few-percent range) because their edges cluster: products co-bought,
    papers co-cited.  Pure RMAT scrambles endpoints at every recursion
    level, so no partitioner can find a good cut and multi-host placement
    studies degenerate.  This generator keeps RMAT's hub skew *within* each
    community (each block is its own small RMAT) and rewires a
    `1 - intra` fraction of destinations uniformly across the whole graph,
    so cut quality is a controllable property: `intra=0.9` leaves a
    ~10 % floor for an oracle partitioner, `intra=0.0` degenerates to a
    scrambled graph."""
    if not 0.0 <= intra <= 1.0:
        raise ValueError(f"intra must be in [0, 1], got {intra}")
    communities = max(1, min(int(communities), num_nodes))
    rng = np.random.default_rng(seed)
    bounds = np.linspace(0, num_nodes, communities + 1).astype(np.int64)
    srcs, dsts = [], []
    for c in range(communities):
        lo, hi = int(bounds[c]), int(bounds[c + 1])
        m = hi - lo
        if m <= 1:
            continue
        s, d = rmat_edges(m, m * avg_degree, seed=seed + 7919 * (c + 1))
        srcs.append(s + lo)
        dsts.append(d + lo)
    src = np.concatenate(srcs) if srcs else np.zeros(0, np.int64)
    dst = np.concatenate(dsts) if dsts else np.zeros(0, np.int64)
    rewire = rng.random(len(dst)) >= intra
    dst[rewire] = rng.integers(0, num_nodes, int(rewire.sum()))
    keep = src != dst
    return from_edge_list(src[keep], dst[keep], num_nodes,
                          feature_dim=feature_dim, name=name)


def uniform_graph(num_nodes: int, avg_degree: int, feature_dim: int,
                  *, seed: int = 0, name: str = "uniform") -> CSRGraph:
    rng = np.random.default_rng(seed)
    e = num_nodes * avg_degree
    src = rng.integers(0, num_nodes, e)
    dst = rng.integers(0, num_nodes, e)
    keep = src != dst
    return from_edge_list(src[keep], dst[keep], num_nodes,
                          feature_dim=feature_dim, name=name)
