# The paper's primary contribution: the GIDS dataloader — storage-direct
# feature aggregation with dynamic access accumulation (§3.2), constant
# host buffer (§3.3), and window-buffered device software cache (§3.4).
from .accumulator import AccumulatorConfig, DynamicAccessAccumulator
from .constant_buffer import ConstantBuffer
from .feature_store import FeatureStore, GatherReport
from .pipeline import Batch, GIDSDataLoader, LoaderConfig
from .software_cache import CacheStats, WindowBufferedCache, run_trace
from .storage_sim import (INTEL_OPTANE, SAMSUNG_980PRO, SSDSpec,
                          StorageTimeline, model_burst, required_accesses,
                          simulate_burst)

__all__ = [
    "AccumulatorConfig", "DynamicAccessAccumulator", "ConstantBuffer",
    "FeatureStore", "GatherReport", "Batch", "GIDSDataLoader", "LoaderConfig",
    "CacheStats", "WindowBufferedCache", "run_trace", "INTEL_OPTANE",
    "SAMSUNG_980PRO", "SSDSpec", "StorageTimeline", "model_burst",
    "required_accesses", "simulate_burst",
]
