"""Production mesh builders.

Single pod: 16 x 16 = 256 chips (v5e pod), axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model); the pod axis
composes with data for batch sharding (DP across pods over DCN).

Functions, not module constants — importing this module never touches jax
device state (device count is locked on first jax init, and smoke tests must
see 1 CPU device while the dry-run sees 512 placeholders).
"""
from __future__ import annotations

import jax

try:                                # jax >= 0.5 names axis modes explicitly;
    from jax.sharding import AxisType   # older releases are Auto-only and
except ImportError:                     # take no axis_types kwarg
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def batch_axes(multi_pod: bool = False):
    return ("pod", "data") if multi_pod else ("data",)


def make_host_mesh():
    """Degenerate 1x1 mesh over whatever devices exist (tests/examples)."""
    n = len(jax.devices())
    return _make_mesh((n, 1), ("data", "model"))
