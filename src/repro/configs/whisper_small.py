"""whisper-small [audio] — enc-dec, 12L each side, d_model=768 12H (MHA)
d_ff=3072 vocab=51865; conv frontend STUB (input_specs provides precomputed
mel-frame embeddings (B, 1500, D)), learned positions, LayerNorm, GELU MLP.
[arXiv:2212.04356; unverified]
"""
import dataclasses
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="encdec",
        num_layers=12, encoder_layers=12, d_model=768, num_heads=12,
        num_kv_heads=12, d_ff=3072, vocab_size=51865,
        norm_type="layernorm", act="gelu", pos_embed="learned",
        encoder_seq=1500, frontend="audio_stub",
    )


def reduced_config() -> ModelConfig:
    return dataclasses.replace(
        config(), num_layers=2, encoder_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=128, vocab_size=512, vocab_pad_to=64,
        encoder_seq=32, max_position=128, remat=False)
