"""Optimizers built from scratch (no optax): AdamW and Adafactor, with
mixed precision (bf16 params + f32 master/moments) and ZeRO-1 style
optimizer-state sharding over the data axis.

State layout is a plain pytree so pjit shards it like any other input; the
ZeRO-1 pspec helper places optimizer moments on the data axis along the
first replicated-and-divisible dim of each parameter.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"              # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    master_dtype: Any = jnp.float32
    # adafactor
    factored_min_dim: int = 128
    decay_rate: float = 0.8


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any
    master: Any                      # f32 master copy of bf16 params


class AdafactorState(NamedTuple):
    step: jnp.ndarray
    vr: Any                          # row second-moment (factored)
    vc: Any                          # col second-moment (factored)
    v: Any                           # full second-moment (unfactored leaves)
    master: Any


def _global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def _clip(grads, max_norm):
    gn = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------
def adamw_init(params, cfg: OptimizerConfig) -> AdamWState:
    f32 = lambda p: jnp.zeros(p.shape, cfg.master_dtype)
    # copy=True: an f32 param must not alias its master (donation safety)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(f32, params),
        v=jax.tree.map(f32, params),
        master=jax.tree.map(
            lambda p: jnp.array(p, dtype=cfg.master_dtype, copy=True),
            params),
    )


def adamw_update(grads, state: AdamWState, params, cfg: OptimizerConfig,
                 lr: jnp.ndarray):
    grads, gn = _clip(grads, cfg.grad_clip)
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(g, m, v, w):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        w = w - lr * (u + cfg.weight_decay * w)
        return m, v, w

    out = jax.tree.map(upd, grads, state.m, state.v, state.master)
    m = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    master = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda w, p: w.astype(p.dtype), master, params)
    return new_params, AdamWState(step, m, v, master), gn


# --------------------------------------------------------------------------
# Adafactor (factored second moments — the memory-sane choice for the
# 400B/480B MoE archs: ~4.07 bytes/param of state vs AdamW's 12)
# --------------------------------------------------------------------------
def _factored(shape, min_dim) -> bool:
    return len(shape) >= 2 and shape[-1] >= min_dim and shape[-2] >= min_dim


def adafactor_init(params, cfg: OptimizerConfig) -> AdafactorState:
    def vr(p):
        return (jnp.zeros(p.shape[:-1], jnp.float32)
                if _factored(p.shape, cfg.factored_min_dim) else jnp.zeros((1,)))

    def vc(p):
        return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                if _factored(p.shape, cfg.factored_min_dim) else jnp.zeros((1,)))

    def vfull(p):
        return (jnp.zeros((1,)) if _factored(p.shape, cfg.factored_min_dim)
                else jnp.zeros(p.shape, jnp.float32))

    return AdafactorState(
        step=jnp.zeros((), jnp.int32),
        vr=jax.tree.map(vr, params),
        vc=jax.tree.map(vc, params),
        v=jax.tree.map(vfull, params),
        master=jax.tree.map(
            lambda p: jnp.array(p, dtype=cfg.master_dtype, copy=True),
            params),
    )


def adafactor_update(grads, state: AdafactorState, params,
                     cfg: OptimizerConfig, lr: jnp.ndarray):
    grads, gn = _clip(grads, cfg.grad_clip)
    step = state.step + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** (-cfg.decay_rate)

    def upd(g, vr, vc, v, w):
        g2 = g * g + 1e-30
        if _factored(g.shape, cfg.factored_min_dim):
            vr = beta2 * vr + (1 - beta2) * g2.mean(axis=-1)
            vc = beta2 * vc + (1 - beta2) * g2.mean(axis=-2)
            r = vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), 1e-30)
            u = g / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :]
                     + cfg.eps)
        else:
            v = beta2 * v + (1 - beta2) * g2
            u = g / (jnp.sqrt(v) + cfg.eps)
        # update clipping (Adafactor RMS rule)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms)
        w = w - lr * (u + cfg.weight_decay * w)
        return vr, vc, v, w

    out = jax.tree.map(upd, grads, state.vr, state.vc, state.v, state.master)
    pick = lambda i: jax.tree.map(lambda o: o[i], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
    vr, vc, v, master = pick(0), pick(1), pick(2), pick(3)
    new_params = jax.tree.map(lambda w, p: w.astype(p.dtype), master, params)
    return new_params, AdafactorState(step, vr, vc, v, master), gn


# --------------------------------------------------------------------------
# unified facade
# --------------------------------------------------------------------------
def init(params, cfg: OptimizerConfig):
    return (adamw_init if cfg.name == "adamw" else adafactor_init)(params, cfg)


def update(grads, state, params, cfg: OptimizerConfig, lr):
    fn = adamw_update if cfg.name == "adamw" else adafactor_update
    return fn(grads, state, params, cfg, lr)


# --------------------------------------------------------------------------
# ZeRO-1: shard optimizer state over the data axis
# --------------------------------------------------------------------------
def zero1_pspec(param_spec: P, shape: tuple, mesh: Mesh,
                axis: str = "data") -> P:
    """Place `axis` on the first replicated dim divisible by its size;
    leaves the param's own model-parallel dims untouched."""
    n = mesh.shape[axis]
    spec = list(param_spec) + [None] * (len(shape) - len(param_spec))
    used = {a for s in spec if s for a in ((s,) if isinstance(s, str) else s)}
    if axis in used:
        return P(*spec)
    for i, (s, dim) in enumerate(zip(spec, shape)):
        if s is None and dim % n == 0 and dim >= n:
            spec[i] = axis
            return P(*spec)
    return P(*spec)


def adamw_state_pspecs(params_shapes, params_pspecs, mesh, zero1=True):
    def z(spec, shape):
        return zero1_pspec(spec, shape, mesh) if zero1 else spec
    like = jax.tree.map(z, params_pspecs, params_shapes)
    return AdamWState(step=P(), m=like, v=like, master=like)


def adafactor_state_pspecs(params_shapes, params_pspecs, mesh, zero1=True,
                           factored_min_dim=128):
    def z(spec, shape):
        return zero1_pspec(spec, shape, mesh) if zero1 else spec

    def row(spec, shape):
        if _factored(shape, factored_min_dim):
            s = list(spec)[:len(shape) - 1]
            return P(*s)
        return P()

    def col(spec, shape):
        if _factored(shape, factored_min_dim):
            s = list(spec)
            s = s[:len(shape) - 2] + s[len(shape) - 1:len(shape)]
            return P(*s)
        return P()

    def full(spec, shape):
        return z(spec, shape) if not _factored(shape, factored_min_dim) \
            else P()

    return AdafactorState(
        step=P(),
        vr=jax.tree.map(row, params_pspecs, params_shapes),
        vc=jax.tree.map(col, params_pspecs, params_shapes),
        v=jax.tree.map(full, params_pspecs, params_shapes),
        master=jax.tree.map(z, params_pspecs, params_shapes),
    )


def opt_state_pspecs(name: str, params_shapes, params_pspecs, mesh,
                     zero1: bool = True):
    if name == "adamw":
        return adamw_state_pspecs(params_shapes, params_pspecs, mesh, zero1)
    return adafactor_state_pspecs(params_shapes, params_pspecs, mesh, zero1)


def abstract_state(name: str, params_abstract, cfg: OptimizerConfig):
    """ShapeDtypeStruct tree of the optimizer state (dry-run, no alloc)."""
    zeros = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params_abstract)
    return jax.eval_shape(lambda p: init(p, cfg), zeros)
