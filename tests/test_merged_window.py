"""Merged-window gather executor: cross-batch dedup, 4KB-line coalescing,
bit-identical features vs the per-batch path, merged-burst pricing, and the
vectorized tier fast paths that feed it."""
import numpy as np
import pytest

from repro.core import (CoalescedReport, DataPlaneSpec, GIDSDataLoader,
                        INTEL_OPTANE, KVSlotTier, LoaderConfig,
                        SAMSUNG_980PRO, StorageTimeline, coalesce_lines,
                        merge_window)
from repro.core.storage_sim import IO_BYTES
from repro.graph.synthetic import rmat_graph


@pytest.fixture(scope="module")
def graph_and_feats():
    g = rmat_graph(10_000, 12, 16, seed=1)
    feats = np.random.default_rng(0).standard_normal(
        (g.num_nodes, 16)).astype(np.float32)
    return g, feats


def _mk(g, feats, plane, seed=7, **kw):
    cfg = dict(batch_size=128, fanouts=(4, 4), cache_lines=2048,
               window_depth=4, seed=seed)
    cfg.update(kw)
    return GIDSDataLoader(g, feats, LoaderConfig(data_plane=plane, **cfg))


# -- merge_window mechanics ----------------------------------------------------

def test_merge_window_roundtrip():
    lists = [np.array([3, 1, 7]), np.array([1, 9]), np.array([7, 7, 2])]
    m = merge_window(lists)
    assert m.n_batches == 3 and m.n_requests == 8
    assert m.n_unique == 5 and m.n_duplicate == 3
    np.testing.assert_array_equal(m.unique_nodes, [1, 2, 3, 7, 9])
    for i, lst in enumerate(lists):
        np.testing.assert_array_equal(
            m.unique_nodes[m.batch_inverse(i)], lst)


def test_merge_window_multiplicity():
    m = merge_window([np.array([1, 2]), np.array([2, 3]), np.array([2])])
    # node 2 appears in all three batches, 1 and 3 in one each
    by_node = dict(zip(m.unique_nodes.tolist(),
                       m.batch_multiplicity().tolist()))
    assert by_node == {1: 1, 2: 3, 3: 1}


# -- line coalescing -----------------------------------------------------------

def test_coalesce_lines_below_line_size():
    # 1 KB rows: 4 rows per 4 KB line
    assert coalesce_lines(np.array([0, 1, 2, 3]), 1024) == 1
    assert coalesce_lines(np.array([0, 4, 8]), 1024) == 3
    assert coalesce_lines(np.array([0, 1, 4, 5, 8]), 1024) == 3
    # duplicates inside a line never add IOs
    assert coalesce_lines(np.array([0, 0, 1]), 1024) == 1


def test_coalesce_lines_at_line_size():
    # 4 KB rows: one IO per row, nothing coalesces
    assert coalesce_lines(np.array([0, 1, 2]), IO_BYTES) == 3


def test_coalesce_lines_above_line_size():
    # 8 KB rows: two IOs per row
    assert coalesce_lines(np.array([0, 1, 2]), 2 * IO_BYTES) == 6
    # a non-multiple width rounds up per row (9 KB -> 3 lines)
    assert coalesce_lines(np.array([0, 1]), 9 * 1024) == 6


def test_coalesce_lines_edge_cases():
    assert coalesce_lines(np.array([], dtype=np.int64), 1024) == 0
    # row wider than half a line but below it: floor says 1 row/line
    assert coalesce_lines(np.array([0, 1, 2]), 3000) == 3


# -- merged executor: bit-identity + telemetry ---------------------------------

def _assert_same_data(ba, bb):
    np.testing.assert_array_equal(ba.blocks.seeds, bb.blocks.seeds)
    np.testing.assert_array_equal(ba.blocks.all_nodes, bb.blocks.all_nodes)
    np.testing.assert_array_equal(ba.features, bb.features)


def test_merged_features_bit_identical_to_per_batch(graph_and_feats):
    g, feats = graph_and_feats
    a, b = _mk(g, feats, "gids"), _mk(g, feats, "gids-merged")
    for _ in range(12):
        _assert_same_data(a.next_batch(), b.next_batch())


def test_merged_async_bit_identical_and_overlap(graph_and_feats):
    g, feats = graph_and_feats
    a, b = _mk(g, feats, "gids-merged"), _mk(g, feats, "gids-merged-async")
    assert b.prefetch is not None
    for _ in range(10):
        ba, bb = a.next_batch(), b.next_batch(compute_s=1e-3)
        _assert_same_data(ba, bb)
        assert ba.report == bb.report
        assert ba.prep_time_s == bb.prep_time_s
        assert bb.exposed_prep_s == pytest.approx(
            max(0.0, bb.prep_time_s - 1e-3))


def test_merged_report_telemetry(graph_and_feats):
    g, feats = graph_and_feats
    dl = _mk(g, feats, "gids-merged")
    batches = [dl.next_batch() for _ in range(8)]
    for b in batches:
        r = b.report
        assert isinstance(r, CoalescedReport)
        assert r.window_batches == b.merge_depth >= 1
        assert r.n_unique + r.n_duplicate == r.window_requests
        assert r.n_unique <= r.window_requests
        assert r.n_storage_unique <= r.n_unique
        # 64-byte rows (16-dim float32): many rows per 4 KB line, so the
        # coalesced IO count must undercut the unique storage row count
        assert r.n_storage_lines <= r.n_storage_unique
    steady = batches[-1].report
    assert steady.n_storage_lines < steady.n_storage_unique
    assert steady.dedup_factor > 1.0


def test_merged_window_amortizes_one_burst(graph_and_feats):
    """Every batch of one window shares the burst price and telemetry."""
    g, feats = graph_and_feats
    dl = _mk(g, feats, "gids-merged")
    first = dl.next_batch()
    window = [first] + [dl.next_batch()
                        for _ in range(first.merge_depth - 1)]
    assert len({b.prep_time_s for b in window}) == 1
    assert len({b.report.n_unique for b in window}) == 1
    assert len({b.report.window_requests for b in window}) == 1
    # per-batch tier counts still cover each batch's own requests
    for b in window:
        assert sum(b.report.tier_counts) == len(b.blocks.all_nodes)


def test_merged_prep_beats_per_batch(graph_and_feats):
    """The point of the PR: dedup + coalescing + one amortized burst make
    the merged plane's modelled prep cheaper than the per-batch plane's."""
    g, feats = graph_and_feats
    a, b = _mk(g, feats, "gids"), _mk(g, feats, "gids-merged")
    pa = [a.next_batch().prep_time_s for _ in range(20)]
    pb = [b.next_batch().prep_time_s for _ in range(20)]
    assert np.mean(pb[4:]) < np.mean(pa[4:])


def test_merged_resume_mid_window(graph_and_feats):
    """A checkpoint taken with executed-but-unconsumed batches staged
    resumes bit-identically on merged, per-batch, and async-merged
    loaders."""
    g, feats = graph_and_feats
    src = _mk(g, feats, "gids-merged")
    for _ in range(3):                      # stops mid-window (window >= 4)
        src.next_batch()
    st = src.state_dict()
    cont = [src.next_batch() for _ in range(6)]

    for plane in ("gids-merged", "gids", "gids-merged-async"):
        fresh = _mk(g, feats, plane)
        fresh.load_state_dict(st)
        for exp in cont:
            got = fresh.next_batch()
            np.testing.assert_array_equal(exp.blocks.seeds, got.blocks.seeds)
            np.testing.assert_array_equal(exp.features, got.features)


def test_merge_execute_requires_overlapped_pricing():
    with pytest.raises(ValueError, match="merge_execute"):
        DataPlaneSpec.preset("mmap").with_(name="mmap-merged",
                                           merge_execute=True)


def test_merged_presets_registered():
    for name in ("gids-merged", "gids-merged-async"):
        spec = DataPlaneSpec.preset(name)
        assert spec.merge_execute
        assert [t.kind for t in spec.tiers] == [
            t.kind for t in DataPlaneSpec.preset("gids").tiers]
    assert DataPlaneSpec.preset("gids-merged-async").prefetch > 0


# -- hypothesis: bit-identity across presets, depths, mid-window resume --------

def test_merged_bit_identity_property(graph_and_feats):
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    g, feats = graph_and_feats
    base_presets = ["gids", "bam", "pinned-host"]

    @settings(max_examples=10, deadline=None)
    @given(
        base=st.sampled_from(base_presets),
        prefetch=st.sampled_from([0, 2]),
        window_depth=st.integers(1, 4),
        batch_size=st.sampled_from([16, 64]),
        resume_after=st.integers(0, 5),
        seed=st.integers(0, 3),
    )
    def check(base, prefetch, window_depth, batch_size, resume_after, seed):
        spec = DataPlaneSpec.preset(base)
        merged_spec = spec.with_(name=f"{base}-merged-test",
                                 merge_execute=True, prefetch=prefetch)
        kw = dict(batch_size=batch_size, window_depth=window_depth,
                  seed=seed)
        a = _mk(g, feats, spec, **kw)
        b = _mk(g, feats, merged_spec, **kw)
        for _ in range(6):
            _assert_same_data(a.next_batch(), b.next_batch())
        # checkpoint the merged loader mid-stream (possibly mid-window),
        # resume a fresh per-batch loader from it: identical continuation
        for _ in range(resume_after):
            b.next_batch()
        st_b = b.state_dict()
        cont = [b.next_batch() for _ in range(4)]
        fresh = _mk(g, feats, spec, **kw)
        fresh.load_state_dict(st_b)
        for exp in cont:
            got = fresh.next_batch()
            np.testing.assert_array_equal(exp.blocks.seeds, got.blocks.seeds)
            np.testing.assert_array_equal(exp.features, got.features)

    check()


# -- merged-burst pricing ------------------------------------------------------

def _rep(**kw):
    base = dict(n_requests=kw.pop("n_unique_req", 100),
                bytes_per_row=kw.pop("bytes_per_row", 256),
                tier_names=("hbm-cache", "host-cbuf", "storage"),
                tier_classes=("hbm", "host", "storage"),
                tier_counts=kw.pop("tier_counts", (0, 0, 100)))
    return CoalescedReport(**base, **kw)


def test_price_merged_burst_monotone_in_rows():
    tl = StorageTimeline(SAMSUNG_980PRO)
    t_small = tl.price_merged_burst(_rep(
        tier_counts=(0, 0, 100), n_storage_unique=100, n_storage_lines=50))
    t_big = tl.price_merged_burst(_rep(
        tier_counts=(0, 0, 1000), n_storage_unique=1000,
        n_storage_lines=500))
    assert 0 < t_small < t_big


def test_price_merged_burst_coalescing_caps_wide_rows():
    """At 4 KB rows the line transfer equals the row transfer; coalesced
    line counts below the row count must price cheaper."""
    tl = StorageTimeline(INTEL_OPTANE)
    dense = tl.price_merged_burst(_rep(
        bytes_per_row=IO_BYTES, tier_counts=(0, 0, 64),
        n_storage_unique=64, n_storage_lines=32))
    sparse = tl.price_merged_burst(_rep(
        bytes_per_row=IO_BYTES, tier_counts=(0, 0, 64),
        n_storage_unique=64, n_storage_lines=64))
    assert dense < sparse


def test_price_merged_burst_zero_storage():
    tl = StorageTimeline(INTEL_OPTANE)
    t = tl.price_merged_burst(_rep(
        tier_counts=(100, 0, 0), n_storage_unique=0, n_storage_lines=0))
    assert t >= 0.0


# -- vectorized tier fast paths ------------------------------------------------

def test_kv_slot_probe_vectorized_matches_membership():
    tier = KVSlotTier(slots=4)
    for rid in (3, 5, 9):
        tier.acquire(rid)
    ids = np.array([1, 3, 5, 7, 9, 11])
    np.testing.assert_array_equal(
        tier.probe(ids), [int(r) in tier._held for r in ids])
    tier.release(5)
    np.testing.assert_array_equal(
        tier.probe(np.array([5, 9])), [False, True])
    assert tier.probe(np.array([], dtype=np.int64)).shape == (0,)


def test_device_store_future_counts_vectorized():
    pytest.importorskip("jax")
    from repro.core.tiers import DeviceStoreTier
    feats = np.random.default_rng(0).standard_normal((64, 8)) \
        .astype(np.float32)
    tier = DeviceStoreTier(feats, num_lines=32, ways=8, window_depth=4)
    windows = [np.array([1, 2, 3]), np.array([2, 3, 4, 2]),
               np.array([3, 9])]
    tier.window.extend(windows)
    ids = np.array([1, 2, 3, 4, 9, 50])
    got = tier._future_counts(ids)
    expect = np.zeros(len(ids), np.int32)
    for w in windows:                      # the pre-vectorization oracle
        expect += np.isin(ids, w).astype(np.int32)
    np.testing.assert_array_equal(got, expect)
    tier.window.clear()
    np.testing.assert_array_equal(tier._future_counts(ids),
                                  np.zeros(len(ids), np.int32))


def test_device_store_lookup_slots_vectorized():
    pytest.importorskip("jax")
    from repro.core.software_cache import _hash_ids
    from repro.core.tiers import DeviceStoreTier
    feats = np.random.default_rng(1).standard_normal((256, 8)) \
        .astype(np.float32)
    tier = DeviceStoreTier(feats, num_lines=64, ways=8)
    tier.probe(np.arange(40))              # fill some lines
    ids = np.arange(60)
    got = tier.lookup_slots(ids)
    tags = np.asarray(tier.store.cache.tags)
    slots = np.asarray(tier.store.cache.slots)
    sets = _hash_ids(ids, tags.shape[0])
    expect = np.full(len(ids), -1, np.int32)   # per-node reference loop
    for i, (s, n) in enumerate(zip(sets, ids)):
        w = np.nonzero(tags[s] == n)[0]
        if len(w):
            expect[i] = slots[s, w[0]]
    np.testing.assert_array_equal(got, expect)
