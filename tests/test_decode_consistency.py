"""Serving correctness: prefill + incremental decode must reproduce
teacher-forced logits for every family (KV cache, RG-LRU state, SSD state,
cross-attention cache)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models.transformer import LM

CASES = [
    ("qwen3_14b", {}),                                   # GQA + qk_norm
    ("qwen2_1_5b", {}),                                  # QKV bias
    ("minicpm_2b", {}),                                  # MHA + scaled resid
    ("h2o_danube_1_8b", {}),                             # sliding window
    ("llama4_maverick_400b_a17b",
     {"moe_capacity_factor": 100.0}),                    # MoE (lossless cap)
    ("arctic_480b", {"moe_capacity_factor": 100.0}),     # MoE top-2 + dense
    ("recurrentgemma_2b", {}),                           # RG-LRU hybrid
    ("mamba2_1_3b", {}),                                 # SSD
    ("whisper_small", {}),                               # enc-dec cross attn
]


@pytest.mark.parametrize("arch,extra", CASES, ids=[c[0] for c in CASES])
def test_decode_matches_teacher_forcing(arch, extra):
    cfg = configs.get(arch, reduced=True)
    cfg = dataclasses.replace(cfg, param_dtype=jnp.float32,
                              compute_dtype=jnp.float32, **extra)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(42))
    B, S, E = 2, 16, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + E), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.encoder_seq, cfg.d_model)) * 0.1
    tf_logits = model.forward(params, batch)

    pre = dict(batch)
    pre["tokens"] = toks[:, :S]
    cache = model.init_cache(B, S + E)
    lg, cache = model.prefill(params, pre, cache)
    errs = [np.abs(np.asarray(lg[:, -1]) - np.asarray(tf_logits[:, S - 1])
                   ).max()]
    for t in range(E):
        lg, cache = model.decode_step(params, toks[:, S + t:S + t + 1],
                                      cache, jnp.int32(S + t))
        errs.append(np.abs(np.asarray(lg[:, 0])
                           - np.asarray(tf_logits[:, S + t])).max())
    assert max(errs) < 1e-3, errs


def test_swa_decode_only_sees_window():
    """With window w, decode logits are invariant to tokens older than w."""
    cfg = configs.get("h2o_danube_1_8b", reduced=True)
    # receptive field after L layers is L*(w-1); keep the perturbed prefix
    # strictly outside it: 3 layers * 3 = 9 back from position 39.
    cfg = dataclasses.replace(cfg, param_dtype=jnp.float32,
                              compute_dtype=jnp.float32, attn_window=4,
                              pos_embed="none")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 40
    t1 = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    # perturb tokens far outside the window of the last position
    t2 = t1.at[:, :4].set((t1[:, :4] + 7) % cfg.vocab_size)
    l1 = model.forward(params, {"tokens": t1})
    l2 = model.forward(params, {"tokens": t2})
    np.testing.assert_allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]),
                               atol=1e-4)
